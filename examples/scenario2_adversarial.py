"""Scenario 2 (paper §4): identifying adversarial attacks via saliency
dispersion.

Claudia's workflow: a production image classifier starts misbehaving; the
saliency maps of attacked inputs show *diffused* attention.  The store holds
saliency masks for a mixed clean/attacked population; the paper's query

    SELECT mask_id FROM MasksDatabaseView
    ORDER BY CP(mask, full_img, (0.2, 0.6)) DESC LIMIT 25;

retrieves the most-dispersed masks.  We report precision/recall against the
planted ground truth and the I/O the index saved.

    PYTHONPATH=src python examples/scenario2_adversarial.py
"""

import numpy as np

from repro.core import CHIConfig, MaskStore, queries
from repro.core.store import MASK_META_DTYPE
from repro.data.masks import object_boxes, saliency_masks


def main():
    n, h, w = 2000, 128, 128
    boxes = object_boxes(n, h, w, seed=11)
    masks, attacked = saliency_masks(n, h, w, seed=10,
                                     attacked_fraction=0.03, boxes=boxes)
    meta = np.zeros(n, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(n)
    meta["image_id"] = np.arange(n)
    cfg = CHIConfig(grid=16, num_bins=20, height=h, width=w)
    store = MaskStore.create_memory(masks, meta, cfg)
    n_attacked = int(attacked.sum())
    print(f"population: {n} masks, {n_attacked} attacked (unknown to the DB)")

    k = 25
    (ids, scores), stats = queries.run(queries.SCENARIO2_TOPK, store)
    hits = attacked[store.positions_of(ids)]
    print(f"\n{queries.SCENARIO2_TOPK}")
    print(f"top-{k} dispersion: precision={hits.mean():.0%}, "
          f"recall={hits.sum() / max(n_attacked, 1):.0%}")
    print(f"index decided {stats.n_decided_by_bounds}/{stats.n_candidates}; "
          f"loaded {stats.load_fraction:.1%} of mask bytes "
          f"in {stats.n_rounds} verification rounds")

    # interactive flow: the attendee tightens the range after looking at the
    # returned masks (demo's custom upper/lower bounds)
    sql = ("SELECT mask_id FROM MasksDatabaseView "
           "ORDER BY CP(mask, full_img, (0.25, 0.5)) DESC LIMIT 25;")
    (ids2, _), stats2 = queries.run(sql, store)
    hits2 = attacked[store.positions_of(ids2)]
    print(f"\nrefined range (0.25, 0.5): precision={hits2.mean():.0%}, "
          f"loaded {stats2.load_fraction:.1%}")


if __name__ == "__main__":
    main()
