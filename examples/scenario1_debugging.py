"""Scenario 1 (paper §4): the full debug → query → augment → retrain loop,
as an end-to-end training driver.

A small LM ("the classifier") is trained with a planted spurious
correlation: for half the examples a background token pattern predicts the
labels, so the model learns to attend outside the "object span".  We then:

  1. harvest attention masks into a MaskSearch store (token-grid masks),
  2. run the paper's Top-K query — lowest normalized attention inside the
     object-span ROI — to retrieve the spurious examples,
  3. augment: re-randomize the background (outside-ROI) tokens of the
     retrieved examples (labels unchanged),
  4. retrain on the augmented stream and re-measure the query:
     attention-inside-ROI should rise.

    PYTHONPATH=src python examples/scenario1_debugging.py [--steps 120]
"""

import argparse

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import load_smoke
from repro.core import CHIConfig, MaskStore, queries, saliency
from repro.core.store import MASK_META_DTYPE
from repro.models import build_model
from repro.train.optimizer import OptConfig
from repro.train.train_loop import init_train_state, make_train_step

SEQ = 64
OBJ = (16, 48)          # the "object" span: tokens 16..48
GRID = 8                # token-grid mask: 8x8


def make_batch(rng, cfg, batch, spurious_frac=0.5):
    """Sequences whose labels are predictable from the object span — but a
    background shortcut (tokens outside OBJ) leaks the same signal for a
    fraction of examples."""
    tokens = rng.integers(0, cfg.vocab_size, (batch, SEQ), dtype=np.int64)
    signal = rng.integers(0, 8, batch)
    # object span carries the signal
    tokens[:, OBJ[0]:OBJ[0] + 8] = signal[:, None] * 8 + np.arange(8)
    # the shortcut: background repeats the signal for `spurious_frac`
    leak = rng.random(batch) < spurious_frac
    tokens[leak, :8] = (signal[leak, None] * 8 + np.arange(8))
    labels = np.full((batch, SEQ), -1, np.int64)
    labels[:, -1] = signal  # predict the signal at the last position
    return {"tokens": tokens.astype(np.int32),
            "labels": labels.astype(np.int32)}, leak


def harvest_masks(model, params, batch):
    maps = model.attention_maps(params, batch)        # (B, heads, S, S)
    # per-example mask: where does the *last* position attend?
    att = jnp.mean(maps, axis=1)[:, -1, :]            # (B, S)
    return np.asarray(saliency.tokens_to_grid(
        saliency.normalize01(att, axis=(-1,)), GRID, GRID), np.float32)


def attention_in_roi(masks):
    span = np.zeros(SEQ, bool)
    span[OBJ[0]:OBJ[1]] = True
    grid_mask = span.reshape(GRID, GRID)
    return (masks * grid_mask[None]).sum((1, 2)) / masks.sum((1, 2))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=16)
    args = ap.parse_args()

    cfg = load_smoke("granite_3_2b")
    model = build_model(cfg)
    opt_cfg = OptConfig(learning_rate=1e-3, warmup_steps=10,
                        total_steps=2 * args.steps)
    params, _, opt = init_train_state(model, jax.random.PRNGKey(0), opt_cfg)
    step = jax.jit(make_train_step(model, opt_cfg))
    rng = np.random.default_rng(0)

    # -- phase 1: train with the spurious shortcut ------------------------
    for s in range(args.steps):
        batch, _ = make_batch(rng, cfg, args.batch)
        params, opt, metrics = step(params, opt, batch)
    print(f"phase-1 loss: {float(metrics['loss']):.3f}")

    # -- harvest masks + index ---------------------------------------------
    probe, leak = make_batch(rng, cfg, args.batch)
    masks = harvest_masks(model, params, probe)
    n = len(masks)
    meta = np.zeros(n, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(n)
    meta["image_id"] = np.arange(n)
    chi_cfg = CHIConfig(grid=GRID, num_bins=8, height=GRID, width=GRID)
    store = MaskStore.create_memory(masks, meta, chi_cfg)

    # ROI = the object span, as grid rows
    roi = np.array([OBJ[0] // GRID, 0, OBJ[1] // GRID, GRID], np.int32)
    rois = np.tile(roi, (n, 1))

    # -- the paper's query: least attention inside the object ROI ---------
    k = max(n // 4, 2)
    sql = (f"SELECT mask_id FROM MasksDatabaseView ORDER BY "
           f"CP(mask, roi, (0.5, 1.0)) / AREA(roi) ASC LIMIT {k};")
    (ids, scores), stats = queries.run(sql, store, provided_rois=rois)
    flagged = store.positions_of(ids)
    in_roi_before = attention_in_roi(masks).mean()
    print(f"query flagged {len(ids)} examples "
          f"(verified {stats.n_verified}/{stats.n_candidates}); "
          f"{leak[flagged].mean():.0%} of flagged have the planted shortcut; "
          f"mean attention-in-ROI: {in_roi_before:.3f}")

    # -- augment: randomize the background of flagged examples ------------
    def augment(batch, flagged_rows):
        toks = batch["tokens"].copy()
        back = np.ones(SEQ, bool)
        back[OBJ[0]:OBJ[1]] = False
        r = np.random.default_rng(1)
        for row in flagged_rows:
            toks[row, back] = r.integers(0, cfg.vocab_size, back.sum())
        return dict(batch, tokens=toks)

    # -- phase 2: retrain on augmented stream ------------------------------
    for s in range(args.steps):
        batch, lk = make_batch(rng, cfg, args.batch)
        batch = augment(batch, np.nonzero(lk)[0])  # online augmentation
        params, opt, metrics = step(params, opt, batch)
    print(f"phase-2 loss: {float(metrics['loss']):.3f}")

    masks2 = harvest_masks(model, params, probe)
    in_roi_after = attention_in_roi(masks2).mean()
    print(f"mean attention-in-ROI after augment+retrain: {in_roi_after:.3f} "
          f"(was {in_roi_before:.3f})")
    if in_roi_after > in_roi_before:
        print("=> model now relies more on the object span (Scenario-1 win)")


if __name__ == "__main__":
    main()
