"""Scenario 6: saliency-vs-attention discrepancy as a first-class operator.

The demo paper's marquee applications — spurious-correlation hunting and
"exploring discrepancies between model saliency and human attention" — are
queries over *pairs* of masks for the same image.  This scenario runs them
through the dual-mask operator (DESIGN.md §9) instead of the MASK_AGG
group path: per image, mask_type 1 (model saliency) pairs with mask_type 2
(human attention), and

  * ``ORDER BY IOU(saliency, attention, t, t) ASC``  surfaces the images
    where the model looks *away* from where humans look;
  * ``WHERE PAIR_DIFF(saliency, attention, t, t) > X`` filters for images
    with a large model-only region (the spurious-correlation signature);

both pruned by cell-decomposed pair bounds from the two roles' CHI rows —
skipping a pair skips the bytes of **two** masks.

    PYTHONPATH=src python examples/scenario6_discrepancy.py
"""

import numpy as np

from repro.core import CHIConfig, MaskStore, queries
from repro.core.store import MASK_META_DTYPE
from repro.data.masks import object_boxes, saliency_masks


def build_store(n_images=500, h=128, w=128, misaligned_fraction=0.08):
    """Per image: a model-saliency mask (type 1) and a human-attention mask
    (type 2); a planted fraction of images has off-object human gaze."""
    rng = np.random.default_rng(3)
    boxes = object_boxes(n_images, h, w, seed=4)
    model, _ = saliency_masks(n_images, h, w, seed=5, boxes=boxes,
                              in_box_fraction=1.0)
    misaligned = rng.random(n_images) < misaligned_fraction
    jitter, _ = saliency_masks(n_images, h, w, seed=6, boxes=boxes,
                               in_box_fraction=1.0)
    human_aligned = np.clip(0.9 * model + 0.25 * jitter, 0.0, 1.0 - 1e-6)
    human_off, _ = saliency_masks(n_images, h, w, seed=7, boxes=None)
    human = np.where(misaligned[:, None, None], human_off, human_aligned)

    masks = np.stack([model, human], axis=1).reshape(-1, h, w)
    n = len(masks)
    meta = np.zeros(n, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(n)
    meta["image_id"] = np.arange(n) // 2
    meta["mask_type"] = np.arange(n) % 2 + 1
    cfg = CHIConfig(grid=16, num_bins=16, height=h, width=w)
    return MaskStore.create_memory(masks, meta, cfg), misaligned


def main():
    store, misaligned = build_store()
    n_images = len(store) // 2
    print(f"{n_images} images × (saliency, attention); "
          f"{int(misaligned.sum())} planted misalignments")

    (img_ids, ious), stats = queries.run(queries.SCENARIO6_DISCREPANCY,
                                         store, verify_batch=64)
    hits = misaligned[img_ids].mean()
    print(f"\n{queries.SCENARIO6_DISCREPANCY}")
    print(f"25 lowest-IoU images: precision={hits:.0%} "
          f"(IoU range {ious[0]:.3f}..{ious[-1]:.3f})")
    print(f"pairs verified: {stats.n_verified}/{stats.n_candidates} "
          f"(naive decodes every pair)")

    diff_sql = ("SELECT image_id FROM MasksDatabaseView "
                "WHERE PAIR_DIFF(saliency, attention, 0.6, 0.6) > 1000 "
                "ORDER BY PAIR_DIFF(saliency, attention, 0.6, 0.6) "
                "DESC LIMIT 25;")
    (d_ids, d_counts), d_stats = queries.run(diff_sql, store,
                                             verify_batch=64)
    print(f"\n{diff_sql}")
    print(f"{len(d_ids)} images where the model attends ≥1000 px the "
          f"humans ignore; planted precision="
          f"{misaligned[d_ids].mean():.0%}" if len(d_ids) else "no hits")
    print(f"pairs verified: {d_stats.n_verified}/{d_stats.n_candidates}, "
          f"decided by pair bounds alone: {d_stats.n_decided_by_bounds}")

    # sanity: aligned images have much higher IoU
    (_, top_ious), _ = queries.run(
        "SELECT image_id FROM MasksDatabaseView "
        "ORDER BY IOU(saliency, attention, 0.6, 0.6) DESC LIMIT 5;",
        store, verify_batch=64)
    print(f"\nbest-aligned IoUs: {np.round(top_ious, 3)}")


if __name__ == "__main__":
    main()
