"""Quickstart: build a mask DB, index it, run the paper's three query types.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import CHIConfig, MaskStore, queries
from repro.core.store import MASK_META_DTYPE
from repro.data.masks import object_boxes, saliency_masks


def main():
    # 1. a small mask database: 2 mask types (saliency + human attention)
    #    per image, with per-image object boxes
    n, h, w = 400, 128, 128
    rois = object_boxes(n, h, w, seed=1)
    masks, attacked = saliency_masks(n, h, w, seed=0, attacked_fraction=0.15,
                                     boxes=rois)
    meta = np.zeros(n, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(n)
    meta["image_id"] = np.arange(n) // 2
    meta["mask_type"] = np.arange(n) % 2 + 1

    # 2. index it (CHI) — in-memory tier for the quickstart
    cfg = CHIConfig(grid=16, num_bins=16, height=h, width=w)
    store = MaskStore.create_memory(masks, meta, cfg)
    print(f"DB: {n} masks {h}x{w}; CHI is "
          f"{cfg.index_bytes(n) / cfg.mask_bytes(n):.1%} of the mask bytes")

    # 3. Filter query (paper §2)
    sql = ("SELECT mask_id FROM MasksDatabaseView "
           "WHERE CP(mask, roi, (0.8, 1.0)) / AREA(roi) < 0.02;")
    ids, stats = queries.run(sql, store, provided_rois=rois[meta["mask_id"]])
    print(f"\nFILTER  {sql}\n  -> {len(ids)} masks; "
          f"verified {stats.n_verified}/{stats.n_candidates} "
          f"({stats.load_fraction:.1%} of mask bytes loaded)")

    # 4. Top-K query (Scenario 2: most diffused attention)
    (ids, scores), stats = queries.run(queries.SCENARIO2_TOPK, store)
    hits = attacked[store.positions_of(ids)].sum()
    print(f"\nTOPK    {queries.SCENARIO2_TOPK}\n  -> top-25 dispersion; "
          f"{hits} of 25 are the planted 'attacked' masks; "
          f"verified {stats.n_verified}/{stats.n_candidates}")

    # 5. Aggregation query (Scenario 3: model-vs-human attention IoU)
    (img_ids, ious), stats = queries.run(queries.SCENARIO3_IOU, store)
    print(f"\nAGG     {queries.SCENARIO3_IOU}\n  -> 25 lowest-IoU images; "
          f"worst IoU={ious[0]:.3f}; verified {stats.n_verified} groups")


if __name__ == "__main__":
    main()
