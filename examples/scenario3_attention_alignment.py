"""Scenario 3 (paper §4): model saliency vs human attention discrepancies.

Bob's workflow: for each image the store holds TWO masks (mask_type 1 =
model saliency, mask_type 2 = human attention).  The paper's aggregation
query thresholds both, groups by image, and ranks by IoU ascending — images
where the model looks *away* from where humans look.

We plant a fraction of "misaligned" images (human attention displaced from
the model blob) and check the query surfaces them.

    PYTHONPATH=src python examples/scenario3_attention_alignment.py
"""

import numpy as np

from repro.core import CHIConfig, MaskStore, queries
from repro.core.store import MASK_META_DTYPE
from repro.data.masks import object_boxes, saliency_masks


def main():
    n_images, h, w = 600, 128, 128
    rng = np.random.default_rng(3)
    boxes = object_boxes(n_images, h, w, seed=4)
    # model saliency: mostly in-box
    model_masks, _ = saliency_masks(n_images, h, w, seed=5, boxes=boxes,
                                    in_box_fraction=1.0)
    # human attention: the same region the model looks at, with human-ish
    # jitter — EXCEPT for planted misaligned images (random off-object gaze)
    misaligned = rng.random(n_images) < 0.08
    jitter, _ = saliency_masks(n_images, h, w, seed=6, boxes=boxes,
                               in_box_fraction=1.0)
    human_aligned = np.clip(0.9 * model_masks + 0.25 * jitter, 0.0,
                            1.0 - 1e-6)
    human_off, _ = saliency_masks(n_images, h, w, seed=7, boxes=None)
    human_masks = np.where(misaligned[:, None, None], human_off,
                           human_aligned)

    masks = np.stack([model_masks, human_masks], axis=1).reshape(-1, h, w)
    n = len(masks)
    meta = np.zeros(n, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(n)
    meta["image_id"] = np.arange(n) // 2
    meta["mask_type"] = np.arange(n) % 2 + 1
    cfg = CHIConfig(grid=16, num_bins=20, height=h, width=w)
    store = MaskStore.create_memory(masks, meta, cfg)
    print(f"{n_images} images × 2 mask types; "
          f"{int(misaligned.sum())} planted misalignments")

    (img_ids, ious), stats = queries.run(queries.SCENARIO3_IOU, store)
    hits = misaligned[img_ids].mean()
    print(f"\n{queries.SCENARIO3_IOU}")
    print(f"25 lowest-IoU images: precision={hits:.0%} "
          f"(IoU range {ious[0]:.3f}..{ious[-1]:.3f})")
    print(f"groups verified: {stats.n_verified}/{stats.n_candidates}")

    # sanity: aligned images have much higher IoU
    (top_ids, top_ious), _ = queries.run(
        "SELECT image_id, CP(intersect(mask > 0.8), full_img, (0.5, 2.0)) "
        "/ CP(union(mask > 0.8), full_img, (0.5, 2.0)) AS iou "
        "FROM MasksDatabaseView WHERE mask_type IN (1, 2) "
        "GROUP BY image_id ORDER BY iou DESC LIMIT 5;", store)
    print(f"best-aligned IoUs: {np.round(top_ious, 3)}")


if __name__ == "__main__":
    main()
