"""Scenario 4: the demo GUI's interactive refine loop, against the service.

A user debugging a model iterates: sweep a filter threshold until the
result set looks right (every refinement reuses the cached CHI bounds
pass), then page through a top-k ranking 25 rows at a time (each "next
page" resumes the verification frontier instead of re-running), while a
second analyst's concurrent queries share verification I/O through the
fused scheduler.

``--backend {host,device,mesh}`` replays the same session transcript on
any execution backend (core/backend.py): the host path loads mask bytes
from disk per verification batch; the device path verifies against the
HBM-resident tier (watch the disk column go to zero); the mesh path runs
the sharded shard_map steps over every local device.

    PYTHONPATH=src python examples/scenario4_interactive_session.py
    PYTHONPATH=src python examples/scenario4_interactive_session.py --backend device
"""

import argparse
import os
import shutil
import tempfile
import time

import numpy as np

from repro.core import CHIConfig, MaskStore
from repro.core.store import MASK_META_DTYPE
from repro.data.masks import object_boxes, saliency_masks
from repro.service import MaskSearchService


def build_db(root, n=600, size=128):
    rois = object_boxes(n, size, size, seed=1)
    masks, _ = saliency_masks(n, size, size, seed=0, attacked_fraction=0.15,
                              boxes=rois)
    meta = np.zeros(n, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(n)
    meta["image_id"] = np.arange(n) // 2
    meta["mask_type"] = np.arange(n) % 2 + 1
    cfg = CHIConfig(grid=16, num_bins=16, height=size, width=size)
    store = MaskStore.create_disk(os.path.join(root, "db"), masks, meta, cfg)
    return store, rois


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="host",
                    choices=("host", "device", "mesh"),
                    help="execution backend for the whole session")
    ap.add_argument("--explain", action="store_true",
                    help="print the EXPLAIN ANALYZE operator tree for the "
                         "ranking and a per-page phase-latency line")
    args = ap.parse_args()

    tmp = tempfile.mkdtemp(prefix="masksearch_s4_")
    try:
        store, rois = build_db(tmp)
        svc = MaskSearchService(store, provided_rois=rois,
                                backend=args.backend)
        print(f"== backend: {svc.backend.name} ==\n")
        mb = 1 / 1e6

        # -- 1. threshold refine loop (filter) --------------------------------
        print("== refine loop: sweeping the Scenario-1 threshold ==")
        for thr in (0.10, 0.06, 0.04, 0.02):
            sql = ("SELECT mask_id FROM MasksDatabaseView WHERE "
                   f"CP(mask, roi, (0.8, 1.0)) / AREA(roi) < {thr};")
            out = svc.query(sql)
            st = out["stats"]
            print(f"  thr={thr:<5} -> {len(out['ids']):>3} masks | verified "
                  f"{st['n_verified']:>3}/{st['n_candidates']} | "
                  f"loaded {st['bytes_loaded'] * mb:6.2f} MB | "
                  f"bounds cache hits={svc.planner.bounds_cache.info.hits}")
        print("  (one CHI pass served the whole sweep)\n")

        # -- 1b. cost-based conjunction: pyramid ladder + reorder -------------
        if args.explain:
            from repro.core import queries
            from repro.obs.explain import explain_analyze
            area = 128 * 128
            conj = ("SELECT mask_id FROM MasksDatabaseView WHERE "
                    f"CP(mask, full_img, (0.25, 1.0)) > {0.01 * area} AND "
                    f"CP(mask, full_img, (0.75, 1.0)) > {0.3 * area};")
            rep = explain_analyze(store, queries.parse(conj).plan)
            filt = next(c for c in rep["tree"]["children"]
                        if c["op"] == "Filter")
            print("== EXPLAIN ANALYZE: conjunctive WHERE through the "
                  "cost-based optimizer ==")
            print(f"  conjunct order: {filt['order']} "
                  f"({'reordered' if filt['reordered'] else 'plan order'}) | "
                  f"tier ladder: {' -> '.join(map(str, filt['tier_grids']))}")
            for leaf in filt["leaves"]:
                print(f"    start_tier={leaf['start_tier']} "
                      f"est_reject={leaf.get('est_reject', 'n/a')} "
                      f"actual={leaf.get('actual_reject', 'n/a')} "
                      f"ladder={leaf.get('ladder', '(skipped)')}")
            print(f"  index bytes touched: "
                  f"{rep['stats']['chi_bytes'] * mb:.2f} MB\n")

        # -- 2. repeated query: warm result cache -----------------------------
        out = svc.query(sql)
        print(f"== repeat last query: cache_hit={out['cache_hit']}, "
              f"bytes_loaded={out['stats']['bytes_loaded']} ==\n")

        # -- 3. paginated top-k session ---------------------------------------
        print("== session: dispersion ranking, 25 rows per page ==")
        topk = ("SELECT mask_id FROM MasksDatabaseView ORDER BY "
                "CP(mask, full_img, (0.2, 0.6)) DESC LIMIT 25;")
        if args.explain:
            rep = svc.query("EXPLAIN ANALYZE " + topk)
            print("-- EXPLAIN ANALYZE (first page's worth of work) --")
            for line in rep["text"].splitlines():
                print(f"  {line}")
            print()
        page = svc.query(topk, session=True, page_size=25)
        sid = page["session"]
        prev_bound = prev_verify = 0.0
        for i in range(4):
            t0 = time.perf_counter()
            if i:
                page = svc.next_page(sid)
            wall = time.perf_counter() - t0
            st = page["stats"]
            ids = page["page"]["ids"]
            print(f"  page {i + 1}: rows {page['page']['offset']:>3}-"
                  f"{page['served'] - 1:>3} (first id {ids[0]:>4}) | "
                  f"cumulative verified {st['n_verified']:>3} | "
                  f"loaded {st['bytes_loaded'] * mb:6.2f} MB")
            if args.explain:
                # run stats are cumulative: the delta is this page's work
                db_, dv = (st["bound_time_s"] - prev_bound,
                           st["verify_time_s"] - prev_verify)
                prev_bound, prev_verify = (st["bound_time_s"],
                                           st["verify_time_s"])
                other = max(wall - db_ - dv, 0.0)
                print(f"          phases: bounds {db_ * 1e3:6.1f} ms | "
                      f"verify {dv * 1e3:6.1f} ms | "
                      f"serve+other {other * 1e3:6.1f} ms")
        print("  (each page resumed the frontier — no re-runs)\n")

        # -- 4. a second analyst: fused concurrent queries --------------------
        print("== concurrent workload: fused verification ==")
        sqls = ["SELECT mask_id FROM MasksDatabaseView ORDER BY "
                f"CP(mask, full_img, ({lv}, {lv + 0.4})) DESC LIMIT 25;"
                for lv in (0.15, 0.2, 0.25, 0.3)]
        svc.submit_batch(sqls)
        sch = svc.scheduler.stats
        print(f"  {len(sqls)} queries -> {sch.fused_passes} fused kernel "
              f"passes ({sch.fused_descriptors} CP descriptors over "
              f"{sch.fused_masks} union mask loads)\n")

        # -- 5. the bill ------------------------------------------------------
        stats = svc.stats()
        cache = stats["shared_cache"]
        io = stats["store_io"]
        print("== service stats ==")
        print(f"  queries: {stats['queries']}")
        print(f"  result cache: {stats['result_cache']}")
        print(f"  bounds cache: {stats['bounds_cache']}")
        print(f"  shared-load cache: hit_rate={cache['hit_rate']:.1%}, "
              f"bytes_saved={cache['bytes_saved'] * mb:.2f} MB")
        print(f"  disk: {io['files_read']} files, "
              f"{io['bytes_read'] * mb:.2f} MB read "
              f"(modeled EBS {io['modeled_ebs_time_s']:.2f}s)")
        svc.close()
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
