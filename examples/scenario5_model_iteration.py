"""Scenario 5: model iteration — the database changes *between* queries.

The paper's motivating workflows are iterative: a model is retrained, its
saliency maps are regenerated, and the analyst re-runs the same queries to
see what moved.  This scenario drives that loop against the mutable,
epoch-versioned store:

1. ingest model v1's saliency masks and run the debugging queries
   (top-k "most saliency outside the object box" + a filter);
2. "retrain" — regenerate the masks for a subset of images (v2 is less
   attacked) and **re-ingest them under the same mask_ids**
   (``on_conflict="update"``: bytes + CHI rows replaced incrementally,
   the store epoch advances, every pre-epoch cache entry becomes
   unreachable);
3. re-run the same queries and diff the top-k — which suspects the
   retrain cleared, which remain;
4. append a fresh batch of masks for images the new model saw for the
   first time, and show the incremental chunked index absorbing it.

    PYTHONPATH=src python examples/scenario5_model_iteration.py
    PYTHONPATH=src python examples/scenario5_model_iteration.py --backend device
"""

import argparse

import numpy as np

from repro.core import CHIConfig, MaskStore
from repro.core.store import MASK_META_DTYPE
from repro.data.masks import object_boxes, saliency_masks
from repro.service import MaskSearchService

TOPK = ("SELECT mask_id FROM MasksDatabaseView ORDER BY "
        "CP(mask, full_img, (0.5, 1.0)) DESC LIMIT 15;")
FILTER = ("SELECT mask_id FROM MasksDatabaseView WHERE "
          "CP(mask, full_img, (0.5, 1.0)) > 1500;")


def build_v1(n, size):
    rois = object_boxes(n, size, size, seed=11)
    masks, attacked = saliency_masks(n, size, size, seed=10,
                                     attacked_fraction=0.3, boxes=rois,
                                     in_box_fraction=0.6)
    meta = np.zeros(n, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(n)
    meta["image_id"] = np.arange(n)
    meta["model_id"] = 1
    meta["mask_type"] = 1
    cfg = CHIConfig(grid=16, num_bins=16, height=size, width=size)
    return MaskStore.create_memory(masks, meta, cfg), rois, attacked


def retrain_v2(n, size, rois):
    """The retrained model: saliency concentrates back inside the boxes."""
    masks, _ = saliency_masks(n, size, size, seed=20, attacked_fraction=0.05,
                              boxes=rois, in_box_fraction=0.95)
    return masks


def diff_topk(before, after):
    b, a = list(before), list(after)
    stayed = [m for m in a if m in b]
    entered = [m for m in a if m not in b]
    cleared = [m for m in b if m not in a]
    return stayed, entered, cleared


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-masks", type=int, default=400)
    ap.add_argument("--size", type=int, default=128)
    ap.add_argument("--backend", default="host",
                    choices=("host", "device", "mesh"))
    args = ap.parse_args()

    store, rois, attacked = build_v1(args.n_masks, args.size)
    svc = MaskSearchService(store, provided_rois=rois, backend=args.backend)
    print(f"== model iteration on backend {svc.backend.name} ==\n")

    # -- round 1: model v1 -------------------------------------------------
    out1 = svc.query(TOPK)
    flt1 = svc.query(FILTER)
    print(f"[v1 / epoch {svc.stats()['epoch']}] "
          f"top-15 high-saliency suspects: {out1['ids'][:8]}…")
    print(f"[v1] filter matches: {len(flt1['ids'])} masks "
          f"(verified {out1['stats']['n_verified']}"
          f"/{out1['stats']['n_candidates']} for the ranking)\n")

    # -- retrain: regenerate masks for the flagged images and re-ingest ----
    suspects = np.asarray(out1["ids"], np.int64)
    v2 = retrain_v2(args.n_masks, args.size, rois)
    r = svc.ingest(v2[suspects], mask_ids=suspects, model_ids=2,
                   on_conflict="update")
    print(f"[retrain] re-ingested {r['updated']} masks for model v2 → "
          f"epoch {r['epoch']} (CHI rows patched incrementally, "
          f"{len(store.chi_chunks)} chunk(s))")

    # -- round 2: same queries, new epoch ----------------------------------
    out2 = svc.query(TOPK)
    flt2 = svc.query(FILTER)
    assert not out2["cache_hit"], "pre-epoch cache entry must not be served"
    stayed, entered, cleared = diff_topk(out1["ids"], out2["ids"])
    print(f"\n[v2 / epoch {svc.stats()['epoch']}] top-15 diff vs v1:")
    print(f"  cleared by retrain : {len(cleared):3d}  {cleared[:6]}…")
    print(f"  still suspicious   : {len(stayed):3d}  {stayed[:6]}…")
    print(f"  new entrants       : {len(entered):3d}  {entered[:6]}…")
    print(f"  filter matches     : {len(flt1['ids'])} → {len(flt2['ids'])}")

    # -- new images: append rides in as one new CHI chunk ------------------
    n_new = 50
    fresh_rois = object_boxes(n_new, args.size, args.size, seed=31)
    fresh, _ = saliency_masks(n_new, args.size, args.size, seed=30,
                              attacked_fraction=0.05, boxes=fresh_rois,
                              in_box_fraction=0.95)
    r = svc.ingest(fresh, image_ids=args.n_masks + np.arange(n_new),
                   model_ids=2)
    print(f"\n[append] {r['appended']} masks for unseen images → "
          f"epoch {r['epoch']}, {r['n_masks']} total, "
          f"{len(store.chi_chunks)} CHI chunk(s) — no existing row re-indexed")
    out3 = svc.query(TOPK)
    st = svc.stats()
    print(f"[v2+new] top-15 now: {out3['ids'][:8]}…")
    print(f"\nservice stats: epoch={st['epoch']} n_masks={st['n_masks']} "
          f"result_cache={st['result_cache']['hits']}h/"
          f"{st['result_cache']['misses']}m "
          f"bounds_cache={st['bounds_cache']['hits']}h/"
          f"{st['bounds_cache']['misses']}m")
    svc.close()


if __name__ == "__main__":
    main()
