"""Execution-backend benchmark — host vs device (vs mesh) physical layers.

Times the two phases the ExecBackend protocol splits out, on the standard
serving workload (2000 masks, 128×128):

  * backend_bounds_*   — the filter phase: CHI bounds for a CP and for a
                         ratio expression over every candidate.
  * backend_verify_*   — the verification phase: exact per-term counts for
                         256-mask batches covering the whole store (the
                         device backend gathers from the HBM-resident tier;
                         the host loads through the store).
  * backend_e2e_*      — one filtered top-k plan end to end per backend.

Prints ``name,us_per_call,derived`` CSV rows (harness contract) and, with
``--json PATH``, writes ``BENCH_backend.json`` with jax backend + device
count metadata.

    PYTHONPATH=src python benchmarks/bench_backend.py --json BENCH_backend.json
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def _row(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def _setup(n_masks: int, size: int):
    from repro.core import CHIConfig, MaskStore
    from repro.core.store import MASK_META_DTYPE
    from repro.data.masks import object_boxes, saliency_masks

    rois = object_boxes(n_masks, size, size, seed=1)
    masks, _ = saliency_masks(n_masks, size, size, seed=7,
                              attacked_fraction=0.2, boxes=rois,
                              in_box_fraction=0.9)
    meta = np.zeros(n_masks, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(n_masks)
    meta["image_id"] = np.arange(n_masks) // 2
    meta["mask_type"] = np.arange(n_masks) % 2 + 1
    cfg = CHIConfig(grid=16, num_bins=16, height=size, width=size)
    return MaskStore.create_memory(masks, meta, cfg), rois


def _time(fn, repeat: int = 5) -> float:
    fn()                                   # warmup / compile
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def bench_bounds(store, rois, backends, record):
    from repro.core.exprs import BinOp, CP, MaskEvalContext, RoiArea

    exprs = {"cp": CP(None, 0.2, 0.6),
             "ratio": BinOp("/", CP("provided", 0.8, 1.0),
                            RoiArea("provided"))}
    record["bounds"] = {}
    for ename, expr in exprs.items():
        per_backend = {}
        for name, be in backends.items():
            ctx = MaskEvalContext(store, np.arange(len(store)), rois)
            t = _time(lambda be=be, ctx=ctx, expr=expr:
                      be.bounds(ctx, expr))
            per_backend[name] = t
            _row(f"backend_bounds_{ename}_{name}", t,
                 f"masks_per_s={len(store) / max(t, 1e-9):.0f}")
        base = per_backend["host"]
        record["bounds"][ename] = {
            **{n: {"latency_s": t} for n, t in per_backend.items()},
            "device_speedup_vs_host":
                base / max(per_backend.get("device", base), 1e-9),
        }


def bench_verify(store, rois, backends, record):
    from repro.core.exprs import CP, MaskEvalContext

    terms = {CP(None, 0.2, 0.6), CP("provided", 0.8, 1.0)}
    batch_size = 256
    batches = [np.arange(i, min(i + batch_size, len(store)))
               for i in range(0, len(store), batch_size)]
    n_counts = len(store) * len(terms)
    record["verify"] = {}
    for name, be in backends.items():
        def sweep(be=be):
            # fresh context each sweep: no cross-iteration load caching
            ctx = MaskEvalContext(store, np.arange(len(store)), rois,
                                  partial_rows=False)
            for b in batches:
                be.verify_counts(ctx, b, terms)
        t = _time(sweep, repeat=3)
        _row(f"backend_verify_{name}", t,
             f"counts_per_s={n_counts / max(t, 1e-9):.0f};"
             f"batches={len(batches)}")
        record["verify"][name] = {"latency_s": t,
                                  "counts_per_s": n_counts / max(t, 1e-9)}
    base = record["verify"]["host"]["latency_s"]
    if "device" in record["verify"]:
        record["verify"]["device_speedup_vs_host"] = (
            base / max(record["verify"]["device"]["latency_s"], 1e-9))


def bench_e2e(store, rois, backends, record):
    from repro.core.exprs import Cmp, CP
    from repro.core.plan import LogicalPlan, run_plan

    plan = LogicalPlan(predicate=Cmp(CP("provided", 0.8, 1.0), ">", 200.0),
                       order_by=CP(None, 0.2, 0.6), k=25)
    record["e2e_filtered_topk"] = {}
    ref = None
    for name, be in backends.items():
        payload = {}

        def once(be=be, payload=payload):
            payload["out"] = run_plan(store, plan, provided_rois=rois,
                                      verify_batch=256, backend=be)
        t = _time(once, repeat=3)
        (ids, _), stats = payload["out"]
        if ref is None:
            ref = list(ids)
        assert list(ids) == ref, f"backend {name} diverged"
        _row(f"backend_e2e_{name}", t,
             f"verified={stats.n_verified}/{stats.n_candidates}")
        record["e2e_filtered_topk"][name] = {
            "latency_s": t, "n_verified": int(stats.n_verified)}


def bench_packed(n_masks, size, record):
    """Bitpacked binary tier vs float tier on the same binary masks — the
    ISSUE 8 acceptance numbers: ids bit-identical, ``bytes_ratio`` ≥ 8
    (words are 1/32 the float bytes; both stores verify the same residue),
    and exactly one fused bounds+verify megakernel launch per round."""
    from repro.core import CHIConfig, MaskStore
    from repro.core.exprs import CP
    from repro.core.plan import LogicalPlan, run_plan
    from repro.core.store import MASK_META_DTYPE
    from repro.data.masks import object_boxes, saliency_masks
    from repro.obs import REGISTRY

    boxes = object_boxes(n_masks, size, size, seed=1)
    m, _ = saliency_masks(n_masks, size, size, seed=7,
                          attacked_fraction=0.2, boxes=boxes,
                          in_box_fraction=0.9)
    masks = (m > 0.5).astype(np.float32)
    meta = np.zeros(n_masks, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(n_masks)
    meta["image_id"] = np.arange(n_masks) // 2
    meta["mask_type"] = np.arange(n_masks) % 2 + 1
    cfg = CHIConfig(grid=16, num_bins=16, height=size, width=size)
    stores = {
        "float": MaskStore.create_memory(masks, meta, cfg),
        "packed": MaskStore.create_memory(masks, meta.copy(), cfg,
                                          packed=True),
    }
    # grid-misaligned ROI so CHI bounds leave a residue to verify
    roi = (3, 5, size - 3, size - 1)
    plan = LogicalPlan(order_by=CP(roi, 0.5, 1.5), k=25)

    def launches():
        snap = REGISTRY.snapshot().get(
            "masksearch_kernel_launches_total", {})
        return snap.get("kernel=fused_bounds_verify", 0.0)

    out = {}
    ref_ids = None
    for name, store in stores.items():
        payload = {}

        def once(store=store, payload=payload):
            payload["out"] = run_plan(store, plan, verify_batch=256)

        n0 = launches()
        t = _time(once, repeat=3)
        n_launch = launches() - n0
        (ids, _), stats = payload["out"]
        if ref_ids is None:
            ref_ids = list(ids)
        assert list(ids) == ref_ids, "packed tier diverged from float"
        out[name] = {"latency_s": t,
                     "bytes_loaded": int(stats.bytes_loaded),
                     "n_verified": int(stats.n_verified),
                     "n_rounds": int(stats.n_rounds)}
        derived = (f"bytes={stats.bytes_loaded};"
                   f"verified={stats.n_verified}/{stats.n_candidates}")
        if name == "packed":
            # 4 timed runs (warmup + 3): launches divide evenly per round
            out[name]["megakernel_launches_per_round"] = (
                n_launch / max(4 * stats.n_rounds, 1))
            derived += f";megakernel_per_round=" \
                       f"{out[name]['megakernel_launches_per_round']:.2f}"
        _row(f"backend_packed_{name}", t, derived)
    out["bytes_ratio"] = (out["float"]["bytes_loaded"]
                          / max(out["packed"]["bytes_loaded"], 1))
    out["latency_ratio"] = (out["float"]["latency_s"]
                            / max(out["packed"]["latency_s"], 1e-9))
    record["packed"] = {"e2e_topk": out}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-masks", type=int, default=2000)
    ap.add_argument("--size", type=int, default=128)
    ap.add_argument("--skip-mesh", action="store_true",
                    help="benchmark host/device only")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    import jax
    from repro.core.backend import get_backend

    print("name,us_per_call,derived")
    store, rois = _setup(args.n_masks, args.size)
    names = ["host", "device"] + ([] if args.skip_mesh else ["mesh"])
    backends = {n: get_backend(store, n) for n in names}
    record = {"config": {"n_masks": args.n_masks, "size": args.size,
                         "jax_backend": jax.default_backend(),
                         "device_count": jax.device_count(),
                         "backends": names}}
    bench_bounds(store, rois, backends, record)
    bench_verify(store, rois, backends, record)
    bench_e2e(store, rois, backends, record)
    bench_packed(args.n_masks, args.size, record)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
        print(f"# wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
