"""Dual-mask (pair) query benchmark — discrepancy queries vs decode-all-pairs.

Prints ``name,us_per_call,derived`` CSV rows (harness contract) and, with
``--json PATH``, writes a machine-readable record (``BENCH_pair.json``).

Measured (disk tier, metered bytes):
  * pair_iou_topk / pair_iou_naive   — ``ORDER BY IOU(saliency, attention,
                                       t, t) ASC LIMIT k`` through the
                                       cell-decomposed pair bounds vs the
                                       naive baseline that decodes every
                                       (saliency, attention) pair.
                                       ``bytes_ratio`` is the headline —
                                       the acceptance bar is ≥3×.
  * pair_filter / pair_filter_naive  — ``WHERE PAIR_DIFF(...) > T``: most
                                       images decided from the two roles'
                                       CHI rows alone.

    PYTHONPATH=src python benchmarks/bench_pair.py --json BENCH_pair.json
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import numpy as np


def _setup(n_images: int, size: int, tmpdir: str) -> str:
    from repro.core import CHIConfig, MaskStore
    from repro.core.store import MASK_META_DTYPE
    from repro.data.masks import object_boxes, saliency_masks

    rng = np.random.default_rng(3)
    boxes = object_boxes(n_images, size, size, seed=4)
    model, _ = saliency_masks(n_images, size, size, seed=5, boxes=boxes,
                              in_box_fraction=1.0)
    misaligned = rng.random(n_images) < 0.08
    jitter, _ = saliency_masks(n_images, size, size, seed=6, boxes=boxes,
                               in_box_fraction=1.0)
    aligned = np.clip(0.9 * model + 0.25 * jitter, 0.0, 1.0 - 1e-6)
    off, _ = saliency_masks(n_images, size, size, seed=7, boxes=None)
    human = np.where(misaligned[:, None, None], off, aligned)

    masks = np.stack([model, human], axis=1).reshape(-1, size, size)
    n = len(masks)
    meta = np.zeros(n, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(n)
    meta["image_id"] = np.arange(n) // 2
    meta["mask_type"] = np.arange(n) % 2 + 1
    cfg = CHIConfig(grid=16, num_bins=16, height=size, width=size)
    root = os.path.join(tmpdir, "db")
    MaskStore.create_disk(root, masks, meta, cfg)
    return root


def _row(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def _run_pair(root, sql, verify_batch=64, use_index=True):
    from repro.core import MaskStore, queries
    from repro.core.plan import run_plan

    store = MaskStore.open_disk(root)
    plan = queries.parse(sql).plan
    t0 = time.perf_counter()
    payload, stats = run_plan(store, plan, use_index=use_index,
                              verify_batch=verify_batch)
    elapsed = time.perf_counter() - t0
    return payload, stats, store.io.bytes_read, elapsed


def bench_query(root, name, sql, record):
    payload, stats, idx_bytes, t_idx = _run_pair(root, sql)
    naive, nstats, naive_bytes, t_naive = _run_pair(root, sql,
                                                    use_index=False)
    ids = payload[0] if isinstance(payload, tuple) else payload
    ids0 = naive[0] if isinstance(naive, tuple) else naive
    assert list(ids) == list(ids0), (name, ids, ids0)   # pruning is exact
    if isinstance(payload, tuple):
        np.testing.assert_allclose(payload[1], naive[1])
    ratio = naive_bytes / max(idx_bytes, 1)
    _row(name, t_idx,
         f"bytes={idx_bytes};verified={stats.n_verified}/"
         f"{stats.n_candidates};hits={len(ids)}")
    _row(f"{name}_naive", t_naive,
         f"bytes={naive_bytes};prune_gain={ratio:.2f}x_bytes")
    record[name] = {
        "sql": sql,
        "indexed": {"latency_s": t_idx, "bytes_loaded": int(idx_bytes),
                    "n_verified": int(stats.n_verified),
                    "n_candidates": int(stats.n_candidates),
                    "n_decided_by_bounds": int(stats.n_decided_by_bounds),
                    "n_hits": int(len(ids))},
        "naive_decode_all_pairs": {"latency_s": t_naive,
                                   "bytes_loaded": int(naive_bytes)},
        "bytes_ratio": ratio,
        "latency_ratio": t_naive / max(t_idx, 1e-9),
    }


IOU_TOPK = ("SELECT image_id FROM MasksDatabaseView "
            "ORDER BY IOU(saliency, attention, 0.6, 0.6) ASC LIMIT 25;")
DIFF_FILTER = ("SELECT image_id FROM MasksDatabaseView "
               "WHERE PAIR_DIFF(saliency, attention, 0.6, 0.6) > 600;")


def _setup_binary(n_images: int, size: int, tmpdir: str, packed: bool) -> str:
    """Binarized variant of ``_setup``: same planted misalignment, values
    thresholded to {0, 1} so both the float and the packed disk tier can
    ingest them (the 0.6 thresholds in the SQL then select the set bits)."""
    from repro.core import CHIConfig, MaskStore
    from repro.core.store import MASK_META_DTYPE
    from repro.data.masks import object_boxes, saliency_masks

    rng = np.random.default_rng(3)
    boxes = object_boxes(n_images, size, size, seed=4)
    model, _ = saliency_masks(n_images, size, size, seed=5, boxes=boxes,
                              in_box_fraction=1.0)
    misaligned = rng.random(n_images) < 0.08
    off, _ = saliency_masks(n_images, size, size, seed=7, boxes=None)
    human = np.where(misaligned[:, None, None], off, model)
    masks = (np.stack([model, human], axis=1).reshape(-1, size, size)
             > 0.5).astype(np.float32)
    n = len(masks)
    meta = np.zeros(n, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(n)
    meta["image_id"] = np.arange(n) // 2
    meta["mask_type"] = np.arange(n) % 2 + 1
    cfg = CHIConfig(grid=16, num_bins=16, height=size, width=size)
    root = os.path.join(tmpdir, "pdb" if packed else "fdb")
    MaskStore.create_disk(root, masks, meta, cfg, packed=packed)
    return root


def bench_packed(n_images, size, tmpdir, record):
    """Packed vs float disk tier on identical binary pair data: ids must
    match bit-for-bit and the packed leg's metered bytes are the headline
    (``bytes_ratio`` = float bytes / packed bytes, acceptance ≥ 8×)."""
    out = {"sql": IOU_TOPK}
    ids_by_tier = {}
    for tier, packed in (("float", False), ("packed", True)):
        root = _setup_binary(n_images, size, tmpdir, packed)
        payload, stats, nbytes, t = _run_pair(root, IOU_TOPK)
        ids_by_tier[tier] = list(payload[0] if isinstance(payload, tuple)
                                 else payload)
        _row(f"pair_packed_{tier}", t,
             f"bytes={nbytes};verified={stats.n_verified}/"
             f"{stats.n_candidates}")
        out[tier] = {"latency_s": t, "bytes_loaded": int(nbytes),
                     "n_verified": int(stats.n_verified),
                     "n_decided_by_bounds": int(stats.n_decided_by_bounds)}
    assert ids_by_tier["packed"] == ids_by_tier["float"], \
        "packed pair tier diverged from float"
    out["bytes_ratio"] = (out["float"]["bytes_loaded"]
                          / max(out["packed"]["bytes_loaded"], 1))
    out["latency_ratio"] = (out["float"]["latency_s"]
                            / max(out["packed"]["latency_s"], 1e-9))
    record["pair_packed"] = out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-images", type=int, default=1000)
    ap.add_argument("--size", type=int, default=128)
    ap.add_argument("--json", default=None,
                    help="also write a JSON record to this path")
    ap.add_argument("--packed", action="store_true",
                    help="also bench the bitpacked binary tier vs the "
                         "float tier on binarized pair data")
    args = ap.parse_args()

    import jax

    print("name,us_per_call,derived")
    tmpdir = tempfile.mkdtemp(prefix="masksearch_pair_")
    record = {"config": {"n_images": args.n_images, "size": args.size,
                         "jax_backend": jax.default_backend(),
                         "device_count": jax.device_count()}}
    try:
        t0 = time.perf_counter()
        root = _setup(args.n_images, args.size, tmpdir)
        _row("db_ingest_total", time.perf_counter() - t0,
             f"n_pairs={args.n_images};size={args.size}")
        bench_query(root, "pair_iou_topk", IOU_TOPK, record)
        bench_query(root, "pair_filter", DIFF_FILTER, record)
        if args.packed:
            bench_packed(args.n_images, args.size, tmpdir, record)
    finally:
        shutil.rmtree(tmpdir, ignore_errors=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
        print(f"# wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
