"""Serving-path benchmark — the interactive service's cache and fusion wins.

Prints ``name,us_per_call,derived`` CSV rows (harness contract) and, with
``--json PATH``, writes a machine-readable record (``BENCH_serve.json``).

Measured:
  * serve_cold / serve_warm   — one-shot query latency, cold vs result-cache
                                hit (warm must load zero bytes).
  * serve_refine              — threshold sweep: bounds-cache reuse vs
                                re-planning each query cold.
  * serve_pagination          — 4 session pages vs 4 growing one-shot runs.
  * serve_fused / serve_serial — Q concurrent top-k queries through the
                                fused scheduler vs serial unshared runs
                                (bytes shared is the headline).
  * serve_filtered_topk / serve_filtered_naive — a predicate-tree WHERE
                                composed with ORDER BY … LIMIT: three-valued
                                bounds pruning vs the naive filter-then-rank
                                full scan (bytes avoided is the headline).

    PYTHONPATH=src python benchmarks/bench_serve.py --json BENCH_serve.json
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import numpy as np


def _setup(n_masks: int, size: int, tmpdir: str):
    from repro.core import CHIConfig, MaskStore
    from repro.core.store import MASK_META_DTYPE
    from repro.data.masks import object_boxes, saliency_masks

    rois = object_boxes(n_masks, size, size, seed=1)
    masks, _ = saliency_masks(n_masks, size, size, seed=7,
                              attacked_fraction=0.2, boxes=rois,
                              in_box_fraction=0.9)
    meta = np.zeros(n_masks, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(n_masks)
    meta["image_id"] = np.arange(n_masks) // 2
    meta["mask_type"] = np.arange(n_masks) % 2 + 1
    cfg = CHIConfig(grid=16, num_bins=16, height=size, width=size)
    MaskStore.create_disk(os.path.join(tmpdir, "db"), masks, meta, cfg)
    return os.path.join(tmpdir, "db"), rois


def _row(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def _fresh_service(root, rois=None, **kw):
    from repro.core import MaskStore
    from repro.service import MaskSearchService
    return MaskSearchService(MaskStore.open_disk(root), provided_rois=rois,
                             **kw)


TOPK = ("SELECT mask_id FROM MasksDatabaseView ORDER BY "
        "CP(mask, full_img, (0.2, 0.6)) DESC LIMIT 25;")


def _phase_seconds(svc):
    """Per-phase wall-time totals from the service's phase histogram."""
    return {phase: summ["sum_s"]
            for phase, summ in svc.stats()["phases"].items()}


def bench_cold_warm(root, record):
    svc = _fresh_service(root)
    t0 = time.perf_counter()
    svc.query(TOPK)
    t_cold = time.perf_counter() - t0
    cold_bytes = svc.store.io.bytes_read
    cold_phases = _phase_seconds(svc)

    warm_times = []
    for _ in range(5):
        t0 = time.perf_counter()
        out = svc.query(TOPK)
        warm_times.append(time.perf_counter() - t0)
    t_warm = float(np.median(warm_times))
    warm_bytes = svc.store.io.bytes_read - cold_bytes
    assert out["cache_hit"] and warm_bytes == 0
    _row("serve_cold", t_cold, f"bytes={cold_bytes}")
    _row("serve_warm", t_warm, f"bytes={warm_bytes};"
         f"speedup={t_cold / max(t_warm, 1e-9):.0f}x")
    record["cold"] = {"latency_s": t_cold, "bytes_loaded": cold_bytes,
                      "phase_s": cold_phases}
    record["warm"] = {"latency_s": t_warm, "bytes_loaded": warm_bytes,
                      "speedup_vs_cold": t_cold / max(t_warm, 1e-9)}
    phases = ";".join(f"{k}={v * 1e3:.1f}ms"
                      for k, v in sorted(cold_phases.items()))
    _row("serve_cold_phases", sum(cold_phases.values()), phases)
    svc.close()


def bench_refine(root, rois, record):
    sweep = [0.10, 0.08, 0.06, 0.04, 0.02]
    sql = ("SELECT mask_id FROM MasksDatabaseView WHERE "
           "CP(mask, roi, (0.8, 1.0)) / AREA(roi) < {};")

    svc = _fresh_service(root, rois)
    t0 = time.perf_counter()
    for thr in sweep:
        svc.query(sql.format(thr))
    t_svc = time.perf_counter() - t0
    hits = svc.planner.bounds_cache.info.hits
    svc.close()

    # baseline: each refinement re-plans cold (fresh service per query)
    t0 = time.perf_counter()
    for thr in sweep:
        one = _fresh_service(root, rois)
        one.query(sql.format(thr))
        one.close()
    t_cold = time.perf_counter() - t0
    _row("serve_refine_sweep5", t_svc,
         f"bounds_hits={hits};vs_cold={t_cold / max(t_svc, 1e-9):.2f}x")
    record["refine"] = {"sweep": sweep, "latency_s": t_svc,
                        "bounds_cache_hits": hits,
                        "cold_latency_s": t_cold}


def bench_pagination(root, record):
    from repro.core import MaskStore, engine, queries
    svc = _fresh_service(root)
    t0 = time.perf_counter()
    page = svc.query(TOPK, session=True, page_size=25)
    for _ in range(3):
        page = svc.next_page(page["session"])
    t_sess = time.perf_counter() - t0
    sess_bytes = svc.store.io.bytes_read
    sess_verified = page["stats"]["n_verified"]
    sess_phases = _phase_seconds(svc)
    svc.close()

    store = MaskStore.open_disk(root)
    plan = queries.parse(TOPK)
    t0 = time.perf_counter()
    for k in (25, 50, 75, 100):
        engine.topk_query(store, plan.expr, k, desc=plan.desc)
    t_rerun = time.perf_counter() - t0
    rerun_bytes = store.io.bytes_read
    _row("serve_session_4pages", t_sess,
         f"bytes={sess_bytes};verified={sess_verified}")
    _row("serve_rerun_4pages", t_rerun,
         f"bytes={rerun_bytes};session_gain="
         f"{rerun_bytes / max(sess_bytes, 1):.2f}x_bytes")
    record["pagination"] = {
        "session": {"latency_s": t_sess, "bytes_loaded": sess_bytes,
                    "n_verified": sess_verified, "phase_s": sess_phases},
        "rerun": {"latency_s": t_rerun, "bytes_loaded": rerun_bytes},
    }


def bench_fused(root, record):
    from repro.core import MaskStore, queries
    sqls = ["SELECT mask_id FROM MasksDatabaseView ORDER BY "
            f"CP(mask, full_img, ({lv:.2f}, {lv + 0.4:.2f})) DESC LIMIT 25;"
            for lv in (0.15, 0.20, 0.25, 0.30, 0.35)]

    svc = _fresh_service(root, verify_batch=256)
    t0 = time.perf_counter()
    svc.submit_batch(sqls)
    t_fused = time.perf_counter() - t0
    fused_bytes = svc.store.io.bytes_read
    saved = svc.store.cache_stats.bytes_saved
    passes = svc.scheduler.stats.fused_passes
    svc.close()

    serial_store = MaskStore.open_disk(root)
    t0 = time.perf_counter()
    for s in sqls:
        queries.parse(s).run(serial_store)
    t_serial = time.perf_counter() - t0
    serial_bytes = serial_store.io.bytes_read
    assert fused_bytes < serial_bytes
    _row("serve_fused_q5", t_fused,
         f"bytes={fused_bytes};fused_passes={passes};bytes_saved={saved}")
    _row("serve_serial_q5", t_serial,
         f"bytes={serial_bytes};share_gain="
         f"{serial_bytes / max(fused_bytes, 1):.2f}x_bytes")
    record["fused"] = {
        "n_queries": len(sqls),
        "fused": {"latency_s": t_fused, "bytes_loaded": fused_bytes,
                  "fused_passes": passes, "cache_bytes_saved": saved},
        "serial_unshared": {"latency_s": t_serial,
                            "bytes_loaded": serial_bytes},
        "bytes_ratio": serial_bytes / max(fused_bytes, 1),
    }


FILTERED_TOPK = (
    "SELECT mask_id FROM MasksDatabaseView "
    "WHERE CP(mask, roi, (0.8, 1.0)) > 200 "
    "AND NOT CP(mask, full_img, (0.2, 0.6)) < 100 "
    "ORDER BY CP(mask, full_img, (0.2, 0.6)) DESC LIMIT 25;")


def bench_filtered_topk(root, rois, record):
    """Predicate tree + ranking through one run vs naive filter-then-rank."""
    from repro.core import MaskStore, queries
    from repro.core.plan import run_plan

    svc = _fresh_service(root, rois, verify_batch=256)
    t0 = time.perf_counter()
    out = svc.query(FILTERED_TOPK)
    t_idx = time.perf_counter() - t0
    idx_bytes = svc.store.io.bytes_read
    verified = out["stats"]["n_verified"]
    cands = out["stats"]["n_candidates"]
    n_hits = len(out["ids"])
    svc.close()

    store = MaskStore.open_disk(root)
    plan = queries.parse(FILTERED_TOPK).plan
    t0 = time.perf_counter()
    (ids0, _), _ = run_plan(store, plan, provided_rois=rois,
                            use_index=False)
    t_naive = time.perf_counter() - t0
    naive_bytes = store.io.bytes_read
    assert [int(x) for x in ids0] == out["ids"]      # pruning is exact

    _row("serve_filtered_topk", t_idx,
         f"bytes={idx_bytes};verified={verified}/{cands};hits={n_hits}")
    _row("serve_filtered_naive", t_naive,
         f"bytes={naive_bytes};prune_gain="
         f"{naive_bytes / max(idx_bytes, 1):.2f}x_bytes")
    record["filtered_topk"] = {
        "sql": FILTERED_TOPK,
        "indexed": {"latency_s": t_idx, "bytes_loaded": idx_bytes,
                    "n_verified": verified, "n_candidates": cands,
                    "n_hits": n_hits},
        "naive_filter_then_rank": {"latency_s": t_naive,
                                   "bytes_loaded": naive_bytes},
        "bytes_ratio": naive_bytes / max(idx_bytes, 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-masks", type=int, default=2000)
    ap.add_argument("--size", type=int, default=128)
    ap.add_argument("--json", default=None,
                    help="also write a JSON record to this path")
    args = ap.parse_args()

    import jax

    print("name,us_per_call,derived")
    tmpdir = tempfile.mkdtemp(prefix="masksearch_serve_")
    record = {"config": {"n_masks": args.n_masks, "size": args.size,
                         "jax_backend": jax.default_backend(),
                         "device_count": jax.device_count()}}
    try:
        t0 = time.perf_counter()
        root, rois = _setup(args.n_masks, args.size, tmpdir)
        _row("db_ingest_total", time.perf_counter() - t0,
             f"n={args.n_masks};size={args.size}")
        bench_cold_warm(root, record)
        bench_refine(root, rois, record)
        bench_pagination(root, record)
        bench_fused(root, record)
        bench_filtered_topk(root, rois, record)
    finally:
        shutil.rmtree(tmpdir, ignore_errors=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
        print(f"# wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
