"""Concurrency benchmark — the async serving tier vs the threaded server
under thousands of concurrent zipfian sessions (DESIGN.md §14).

An asyncio load generator opens one connection per logical session and
drives a zipfian multi-tenant workload (tenant and query template both
zipf-distributed, like real multi-user traffic: one hot tenant, a long
tail) against each front in turn:

  * the legacy ``ThreadingHTTPServer`` (:mod:`repro.service.server`) —
    thread per request, HTTP/1.0 close-per-request, listen backlog 5;
    the client reconnects per request and retries refused connects,
    which is exactly the pain the tier removes;
  * the async tier (:mod:`repro.service.asyncserver`) — keep-alive
    connections, admission control, weighted-fair batch dispatch into
    cross-tenant fused verification.

Headlines: sustained QPS, p50/p99 latency, shed rate (clean 429s with
``Retry-After`` vs the baseline's refused connects), and fused-pass
tenant width (nonzero ``cross_tenant_passes`` is the tentpole
acceptance).  Prints ``name,us_per_call,derived`` CSV rows (harness
contract) and, with ``--json PATH``, writes the machine-readable record
(``BENCH_concurrency.json``).

    PYTHONPATH=src python benchmarks/bench_concurrency.py \
        --sessions 1200 --json BENCH_concurrency.json
    PYTHONPATH=src python benchmarks/bench_concurrency.py --tiny \
        --json /tmp/bench_concurrency.json        # the CI smoke flags
"""

from __future__ import annotations

import argparse
import asyncio
import json
import threading
import time

import numpy as np

TOPK_TMPL = ("SELECT mask_id FROM MasksDatabaseView ORDER BY "
             "CP(mask, full_img, ({lo:.2f}, {hi:.2f})) DESC LIMIT {k};")
FILTER_TMPL = ("SELECT mask_id FROM MasksDatabaseView WHERE "
               "CP(mask, full_img, (0.3, 0.7)) > {t};")


def _templates():
    sqls = [TOPK_TMPL.format(lo=0.1 + 0.05 * i, hi=0.5 + 0.05 * i, k=5 + i)
            for i in range(8)]
    sqls += [FILTER_TMPL.format(t=100 + 25 * i) for i in range(4)]
    return sqls


def _zipf_probs(n: int, s: float) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1) ** s
    return w / w.sum()


# -- minimal asyncio HTTP/1.x client ---------------------------------------

async def _read_response(reader) -> tuple[int, dict, float | None]:
    status_line = await reader.readline()
    if not status_line:
        raise ConnectionError("server closed before status line")
    status = int(status_line.split()[1])
    headers: dict = {}
    while True:
        line = await reader.readline()
        if line in (b"\r\n", b"\n", b""):
            break
        name, _, value = line.decode("latin-1").partition(":")
        headers[name.strip().lower()] = value.strip()
    n = int(headers.get("content-length") or 0)
    body = json.loads(await reader.readexactly(n)) if n else {}
    retry_after = headers.get("retry-after")
    return status, body, (float(retry_after) if retry_after else None)


def _request_bytes(path: str, body: dict, tenant: str) -> bytes:
    data = json.dumps(body).encode()
    return (f"POST {path} HTTP/1.1\r\nHost: bench\r\n"
            f"Content-Type: application/json\r\n"
            f"Content-Length: {len(data)}\r\nX-Tenant: {tenant}\r\n"
            f"\r\n").encode() + data


class _SessionConn:
    """One logical session's connection: keep-alive against the async
    tier, reconnect-per-request (with connect retries around the tiny
    listen backlog) against the threaded baseline."""

    def __init__(self, host: str, port: int, keep_alive: bool,
                 timeout: float):
        self.host = host
        self.port = port
        self.keep_alive = keep_alive
        self.timeout = timeout
        self.reader = self.writer = None
        self.connect_retries = 0

    async def _connect(self) -> None:
        delay = 0.005
        for _ in range(400):
            try:
                self.reader, self.writer = await asyncio.wait_for(
                    asyncio.open_connection(self.host, self.port),
                    timeout=self.timeout)
                return
            except (ConnectionError, OSError, asyncio.TimeoutError):
                self.connect_retries += 1
                await asyncio.sleep(delay)
                delay = min(delay * 1.5, 0.2)
        raise ConnectionError("could not connect after 400 attempts")

    async def request(self, path: str, body: dict,
                      tenant: str) -> tuple[int, dict, float | None]:
        if self.reader is None:
            await self._connect()
        try:
            self.writer.write(_request_bytes(path, body, tenant))
            await self.writer.drain()
            out = await asyncio.wait_for(_read_response(self.reader),
                                         timeout=self.timeout)
        except (ConnectionError, asyncio.IncompleteReadError,
                asyncio.TimeoutError):
            # stale keep-alive or dropped conn: one clean reconnect retry
            await self.close()
            await self._connect()
            self.writer.write(_request_bytes(path, body, tenant))
            await self.writer.drain()
            out = await asyncio.wait_for(_read_response(self.reader),
                                         timeout=self.timeout)
        if not self.keep_alive:
            await self.close()
        return out

    async def close(self) -> None:
        if self.writer is not None:
            try:
                self.writer.close()
            except Exception:       # noqa: BLE001 — teardown best-effort
                pass
        self.reader = self.writer = None


# -- the zipfian session driver --------------------------------------------

class LoadStats:
    def __init__(self):
        self.latencies: list = []
        self.completed = 0
        self.shed_429 = 0
        self.errors = 0
        self.connect_retries = 0


async def _drive_session(host, port, keep_alive, plan, stats: LoadStats,
                         timeout: float):
    """One logical session: a few requests (one-shots, or a /v1 session
    open + pages) drawn from the zipfian plan."""
    conn = _SessionConn(host, port, keep_alive, timeout)
    try:
        for kind, tenant, body in plan:
            t0 = time.perf_counter()
            try:
                status, out, retry_after = await conn.request(
                    "/v1/query" if kind != "page" else "/v1/page",
                    body, tenant)
            except Exception:       # noqa: BLE001 — load gen keeps going
                stats.errors += 1
                continue
            dt = time.perf_counter() - t0
            if status == 200:
                stats.completed += 1
                stats.latencies.append(dt)
                if kind == "open" and out.get("cursor"):
                    # chain one page onto the open (pages in the plan
                    # carry a placeholder cursor until the open lands)
                    for sub in plan:
                        if sub[0] == "page" and sub[2].get("cursor") is None:
                            sub[2]["cursor"] = out["cursor"]
                            break
            elif status == 429:
                stats.shed_429 += 1
                await asyncio.sleep(min(retry_after or 0.02, 0.1))
            else:
                stats.errors += 1
    finally:
        stats.connect_retries += conn.connect_retries
        await conn.close()


def _build_plans(n_sessions, tenants, zipf_s, pages, rng):
    """→ per-session request plans: zipfian tenant + template choice,
    every third session paginates instead of one-shotting."""
    sqls = _templates()
    t_probs = _zipf_probs(tenants, zipf_s)
    q_probs = _zipf_probs(len(sqls), zipf_s)
    plans = []
    for i in range(n_sessions):
        tenant = f"tenant-{rng.choice(tenants, p=t_probs)}"
        plan = []
        if i % 3 == 0:
            sql = sqls[rng.choice(len(sqls), p=q_probs)]
            plan.append(["open", tenant,
                         {"sql": sql, "session": True, "page_size": 3}])
            for _ in range(pages):
                plan.append(["page", tenant, {"cursor": None}])
        else:
            for _ in range(1 + pages):
                sql = sqls[rng.choice(len(sqls), p=q_probs)]
                plan.append(["oneshot", tenant, {"sql": sql}])
        plans.append(plan)
    return plans


async def _run_load(host, port, keep_alive, plans, timeout) -> tuple:
    stats = LoadStats()
    t0 = time.perf_counter()
    await asyncio.gather(*[
        _drive_session(host, port, keep_alive, plan, stats, timeout)
        for plan in plans])
    wall = time.perf_counter() - t0
    return stats, wall


def _summarize(stats: LoadStats, wall: float) -> dict:
    lat = np.sort(np.asarray(stats.latencies or [0.0]))
    total = stats.completed + stats.shed_429 + stats.errors
    return {
        "wall_s": wall,
        "completed": stats.completed,
        "shed_429": stats.shed_429,
        "errors": stats.errors,
        "connect_retries": stats.connect_retries,
        "qps": stats.completed / max(wall, 1e-9),
        "shed_rate": stats.shed_429 / max(total, 1),
        "p50_ms": float(lat[int(0.50 * (len(lat) - 1))]) * 1e3,
        "p99_ms": float(lat[int(0.99 * (len(lat) - 1))]) * 1e3,
    }


def _row(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


# -- the two server phases --------------------------------------------------

def _make_service(n_masks, size):
    from repro.service import MaskSearchService
    from repro.service.server import _synthetic_store
    store, rois = _synthetic_store(n_masks, size)
    return MaskSearchService(store, provided_rois=rois)


def bench_threaded(args, plans, record):
    from repro.service import make_server
    service = _make_service(args.n_masks, args.size)
    httpd = make_server(service, "127.0.0.1", 0)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    host, port = httpd.server_address[:2]
    try:
        stats, wall = asyncio.run(
            _run_load(host, port, False, plans, args.timeout))
    finally:
        httpd.shutdown()
        service.close()
    summ = _summarize(stats, wall)
    _row("concurrency_threaded", wall,
         f"qps={summ['qps']:.0f};p99={summ['p99_ms']:.1f}ms;"
         f"connect_retries={summ['connect_retries']}")
    record["threaded"] = summ
    return summ


def bench_async_tier(args, plans, record):
    from repro.service.asyncserver import serve_in_thread
    service = _make_service(args.n_masks, args.size)
    handle = serve_in_thread(
        service, tenant_rate=args.tenant_rate, tenant_burst=args.tenant_burst,
        queue_depth=args.queue_depth, batch_max=args.batch_max,
        max_connections=max(2 * len(plans), 64))
    try:
        stats, wall = asyncio.run(
            _run_load(handle.tier.host, handle.tier.port, True, plans,
                      args.timeout))
        sched = service.scheduler.stats
        tier = handle.tier.stats
        fusion = {
            "fused_passes": sched.fused_passes,
            "cross_tenant_passes": sched.cross_tenant_passes,
            "cross_tenant_jobs": sched.cross_tenant_jobs,
            "mean_fused_tenant_width": (
                sched.fused_tenant_width
                / max(sched.fused_passes + sched.pair_passes, 1)),
            "batches": tier.batches,
            "batched_requests": tier.batched_requests,
            "admitted": handle.tier.admission.stats.admitted,
        }
    finally:
        handle.stop()
        service.close()
    summ = _summarize(stats, wall)
    summ["fusion"] = fusion
    _row("concurrency_async_tier", wall,
         f"qps={summ['qps']:.0f};p99={summ['p99_ms']:.1f}ms;"
         f"shed={summ['shed_429']};"
         f"xtenant_passes={fusion['cross_tenant_passes']}")
    record["async_tier"] = summ
    return summ


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=1200,
                    help="concurrent zipfian sessions per server phase")
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--zipf", type=float, default=1.1,
                    help="zipf exponent for tenant/template popularity")
    ap.add_argument("--pages", type=int, default=2,
                    help="follow-up requests per session")
    ap.add_argument("--n-masks", type=int, default=200)
    ap.add_argument("--size", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--timeout", type=float, default=120.0)
    ap.add_argument("--tenant-rate", type=float, default=400.0)
    ap.add_argument("--tenant-burst", type=float, default=60.0)
    ap.add_argument("--queue-depth", type=int, default=2048)
    ap.add_argument("--batch-max", type=int, default=32)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke scale: 80 sessions, small store")
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    if args.tiny:
        args.sessions = 80
        args.tenants = 4
        args.pages = 1
        args.n_masks = 120
        args.tenant_burst = 10.0
        args.tenant_rate = 200.0

    print("name,us_per_call,derived")
    rng = np.random.default_rng(args.seed)
    record = {"config": {
        "sessions": args.sessions, "tenants": args.tenants,
        "zipf": args.zipf, "pages": args.pages, "n_masks": args.n_masks,
        "size": args.size, "tenant_rate": args.tenant_rate,
        "tenant_burst": args.tenant_burst,
    }}

    plans = _build_plans(args.sessions, args.tenants, args.zipf,
                         args.pages, rng)
    # independent (identically distributed) plans per phase so session
    # cursors never leak across servers
    plans_async = _build_plans(args.sessions, args.tenants, args.zipf,
                               args.pages, rng)

    threaded = bench_threaded(args, plans, record)
    tier = bench_async_tier(args, plans_async, record)

    record["qps_ratio"] = tier["qps"] / max(threaded["qps"], 1e-9)
    record["p99_ratio"] = threaded["p99_ms"] / max(tier["p99_ms"], 1e-9)
    _row("concurrency_ratios", 0.0,
         f"qps_ratio={record['qps_ratio']:.2f};"
         f"p99_ratio={record['p99_ratio']:.2f};"
         f"shed_rate={tier['shed_rate']:.3f}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
        print(f"# wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
