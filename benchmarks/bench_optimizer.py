"""CHI pyramid + cost-based filter ordering benchmark (DESIGN.md §13).

Prints ``name,us_per_call,derived`` CSV rows (harness contract) and, with
``--json PATH``, writes a machine-readable record (``BENCH_optimizer.json``).

Workload: a skewed-selectivity conjunction over full-image ROIs at
grid=16 — one conjunct rejects almost nothing, the other rejects almost
everything.  The cost-based optimizer evaluates the selective conjunct
first and decides nearly every candidate at the 4x4 pyramid tier, touching
a fraction of the index bytes the classic single-grid pass reads.

Measured:
  * optimizer.bytes_per_decided_ratio — index bytes per bounds-decided
    candidate, classic single-grid vs pyramid ladder.  Headline; the
    acceptance bar is >= 3x and CI gates it (seed-deterministic).
  * optimizer.reorder.latency_ratio   — filter-phase latency without vs
    with conjunct reordering (pyramid on for both).  Reported, not gated.

Bit-identity of (ids, decided counts) between classic plan-order
evaluation and the optimized ladder is asserted in-bench on the host and
device backends.

    PYTHONPATH=src python benchmarks/bench_optimizer.py \
        --json BENCH_optimizer.json
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def _row(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def _setup(n_masks: int, size: int):
    from repro.core import CHIConfig, MaskStore
    from repro.core.store import MASK_META_DTYPE

    rng = np.random.default_rng(17)
    masks = rng.random((n_masks, size, size), dtype=np.float32)
    n_low = n_masks // 2
    n_hot = max(n_masks // 20, 1)
    masks[:n_low] *= 0.3                        # half the store: low-valued
    masks[n_low:n_low + n_hot] = (              # 5%: clearly hot
        0.5 + 0.5 * masks[n_low:n_low + n_hot])
    meta = np.zeros(n_masks, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(n_masks)
    meta["image_id"] = np.arange(n_masks)
    meta["mask_type"] = np.arange(n_masks) % 3 + 1
    # 0.2 and 0.8 (the query thresholds) sit on CHI value edges, so the
    # aligned full-image ROI is answered exactly at every pyramid tier
    cfg = CHIConfig(grid=16, num_bins=16, height=size, width=size,
                    thresholds=tuple(round(0.1 + 0.05 * i, 2)
                                     for i in range(15)))
    return MaskStore.create_memory(masks, meta, cfg)


def _skewed_plan(size: int):
    from repro.core.exprs import CP, And, Cmp
    from repro.core.plan import LogicalPlan

    area = size * size
    full = (0, 0, size, size)
    inf = float("inf")
    # plan order puts the weak conjunct first; the optimizer must flip it.
    # weak accepts ~everything; strong rejects all but the hot 5% (uniform
    # masks have ~0.2*area above 0.8 — a clear margin below 0.25*area).
    # CHI value edges are float32-quantized, so query at the float32 edge
    # value for exact (lb == ub) aligned bounds.
    lo, hi = float(np.float32(0.2)), float(np.float32(0.8))
    weak = Cmp(CP(full, lo, inf), ">", 0.01 * area)
    strong = Cmp(CP(full, hi, inf), ">", 0.25 * area)
    return LogicalPlan(predicate=And(weak, strong))


def _run(store, plan, repeats, backend=None, pyramid=True, reorder=True):
    from repro.core import opt
    from repro.core.plan import run_plan

    with opt.configure(pyramid=pyramid, reorder=reorder):
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            ids, stats = run_plan(store, plan, backend=backend)
            best = min(best, time.perf_counter() - t0)
    return ids, stats, best


def bench_optimizer(store, size, repeats, record):
    plan = _skewed_plan(size)
    legs = {}
    for name, kw in (
        ("classic", dict(pyramid=False, reorder=False)),
        ("ladder", dict(pyramid=True, reorder=True)),
        ("ladder_unordered", dict(pyramid=True, reorder=False)),
    ):
        ids, stats, t = _run(store, plan, repeats, **kw)
        decided = max(int(stats.n_decided_by_bounds), 1)
        legs[name] = {"ids": list(map(int, ids)),
                      "chi_bytes": int(stats.chi_bytes),
                      "n_decided_by_bounds": int(stats.n_decided_by_bounds),
                      "n_verified": int(stats.n_verified),
                      "bytes_per_decided": stats.chi_bytes / decided,
                      "filter_latency_s": t}
        _row(f"optimizer_{name}", t,
             f"chi_bytes={stats.chi_bytes};decided="
             f"{stats.n_decided_by_bounds};hits={len(ids)}")
    # the optimized ladder must be bit-identical to plan-order evaluation
    assert legs["ladder"]["ids"] == legs["classic"]["ids"]
    assert legs["ladder_unordered"]["ids"] == legs["classic"]["ids"]
    assert (legs["ladder"]["n_decided_by_bounds"]
            == legs["classic"]["n_decided_by_bounds"])
    ids_dev, stats_dev, t_dev = _run(store, plan, 1, backend="device")
    assert list(map(int, ids_dev)) == legs["classic"]["ids"], \
        "device ladder diverged from host plan-order evaluation"
    _row("optimizer_ladder_device", t_dev,
         f"chi_bytes={stats_dev.chi_bytes}")

    ratio = (legs["classic"]["bytes_per_decided"]
             / max(legs["ladder"]["bytes_per_decided"], 1e-9))
    reorder_ratio = (legs["ladder_unordered"]["filter_latency_s"]
                     / max(legs["ladder"]["filter_latency_s"], 1e-9))
    _row("optimizer_summary", legs["ladder"]["filter_latency_s"],
         f"bytes_per_decided_ratio={ratio:.2f}x;"
         f"reorder_latency_ratio={reorder_ratio:.2f}x")
    record["optimizer"] = {
        "workload": "skewed-selectivity conjunction, full-image ROIs, "
                    "grid=16",
        "classic": {k: v for k, v in legs["classic"].items() if k != "ids"},
        "ladder": {k: v for k, v in legs["ladder"].items() if k != "ids"},
        "ladder_unordered": {k: v for k, v in legs["ladder_unordered"].items()
                             if k != "ids"},
        "bytes_per_decided_ratio": ratio,
        "reorder": {
            "with_s": legs["ladder"]["filter_latency_s"],
            "without_s": legs["ladder_unordered"]["filter_latency_s"],
            "latency_ratio": reorder_ratio,
        },
        "device": {"filter_latency_s": t_dev,
                   "chi_bytes": int(stats_dev.chi_bytes)},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-masks", type=int, default=2000)
    ap.add_argument("--size", type=int, default=128)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--json", default=None,
                    help="also write a JSON record to this path")
    args = ap.parse_args()

    import jax

    print("name,us_per_call,derived")
    record = {"config": {"n_masks": args.n_masks, "size": args.size,
                         "repeats": args.repeats,
                         "jax_backend": jax.default_backend(),
                         "device_count": jax.device_count()}}
    t0 = time.perf_counter()
    store = _setup(args.n_masks, args.size)
    _row("db_ingest_total", time.perf_counter() - t0,
         f"n_masks={args.n_masks};size={args.size}")
    bench_optimizer(store, args.size, args.repeats, record)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
        print(f"# wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
