"""Ingest-path benchmark — incremental CHI maintenance vs full rebuild.

The paper's motivating workflows regenerate masks between queries (models
retrain, saliency maps refresh), so the index must absorb deltas without
re-indexing the database.  This benchmark appends a fixed-size delta to
databases of growing size and compares:

  * ``ingest_incr_bN``  — ``MaskStore.append``: CHI tables built for the
                          delta only, attached as a new chunk (O(delta)).
  * ``ingest_full_bN``  — the frozen-store alternative: rebuild the whole
                          CHI with ``build_chi_np`` over base+delta (O(N)).

Prints ``name,us_per_call,derived`` CSV rows (harness contract) and, with
``--json PATH``, writes a machine-readable record (``BENCH_ingest.json``).
The headline: incremental append cost is proportional to the delta, so its
speedup over the full rebuild *grows with database size*.

    PYTHONPATH=src python benchmarks/bench_ingest.py --json BENCH_ingest.json
    PYTHONPATH=src python benchmarks/bench_ingest.py \
        --sizes 96,192 --delta 16 --size 32        # tiny CI smoke mode
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np


def _row(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def _make_db(n: int, size: int, seed: int):
    from repro.core.store import MASK_META_DTYPE
    from repro.data.masks import object_boxes, saliency_masks

    boxes = object_boxes(n, size, size, seed=seed + 1)
    masks, _ = saliency_masks(n, size, size, seed=seed,
                              attacked_fraction=0.2, boxes=boxes)
    meta = np.zeros(n, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(n)
    meta["image_id"] = np.arange(n) // 2
    meta["mask_type"] = np.arange(n) % 2 + 1
    return np.asarray(masks, np.float32), meta


def bench_size(n_base: int, delta: int, size: int, repeats: int, record: list):
    from repro.core import CHIConfig, MaskStore, build_chi_np

    cfg = CHIConfig(grid=16, num_bins=16, height=size, width=size)
    base_masks, base_meta = _make_db(n_base + delta, size, seed=n_base % 97)
    new_masks = base_masks[n_base:]
    store = MaskStore.create_memory(base_masks[:n_base],
                                    base_meta[:n_base], cfg)

    def fresh_meta(k):
        m = base_meta[n_base:].copy()
        m["mask_id"] += 10_000_000 * (k + 1)  # fresh ids per delta
        return m

    # warmup append absorbs the one-time amortized buffer growth, then
    # measure steady-state appends (the model-iteration loop's cost)
    store.append(new_masks, fresh_meta(0))
    t_incr = []
    for i in range(repeats):
        t0 = time.perf_counter()
        store.append(new_masks, fresh_meta(i + 1))
        t_incr.append(time.perf_counter() - t0)
    t_incr_s = float(np.median(t_incr))

    t_full = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        full = build_chi_np(base_masks, cfg)
        t_full.append(time.perf_counter() - t0)
    t_full_s = float(np.median(t_full))

    # the incremental chunks must equal a from-scratch rebuild
    chi_equal = bool(np.array_equal(store.chi_host()[:n_base + delta], full))
    assert chi_equal, "incremental CHI diverged from full rebuild"

    speedup = t_full_s / max(t_incr_s, 1e-12)
    _row(f"ingest_incr_b{n_base}", t_incr_s,
         f"delta={delta};chunks={len(store.chi_chunks)}")
    _row(f"ingest_full_b{n_base}", t_full_s,
         f"n={n_base + delta};speedup={speedup:.1f}x")
    record.append({
        "n_base": n_base, "delta": delta, "mask_size": size,
        "t_incremental_s": t_incr_s, "t_full_rebuild_s": t_full_s,
        "speedup": speedup, "chi_equal": chi_equal,
    })


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="512,2048",
                    help="comma-separated base database sizes")
    ap.add_argument("--delta", type=int, default=64,
                    help="masks appended per ingest")
    ap.add_argument("--size", type=int, default=128, help="mask side length")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--json", default=None,
                    help="also write a JSON record to this path")
    args = ap.parse_args()

    import jax

    sizes = [int(s) for s in args.sizes.split(",")]
    print("name,us_per_call,derived")
    results: list = []
    for n_base in sizes:
        bench_size(n_base, args.delta, args.size, args.repeats, results)

    speedups = [r["speedup"] for r in results]
    growing = all(b >= a for a, b in zip(speedups, speedups[1:]))
    _row("ingest_speedup_trend", 0.0,
         f"speedups={'/'.join(f'{s:.1f}x' for s in speedups)};"
         f"growing={growing}")
    record = {
        "config": {"sizes": sizes, "delta": args.delta,
                   "mask_size": args.size, "repeats": args.repeats,
                   "jax_backend": jax.default_backend(),
                   "device_count": jax.device_count()},
        "results": results,
        "speedup_growing_with_size": growing,
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
        print(f"# wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
