"""CI bench-regression gate: compare a tiny-mode bench run against the
committed ``BENCH_*.json`` baseline.

Committed baselines carry two records: the full-scale measurement (the
headline numbers) and a ``"tiny"`` section produced with the exact flags
the CI ``bench-smoke`` job uses — so the gate compares apples to apples.
The gated metrics are the **pruned-vs-naive bytes ratios**: they are
seed-deterministic (mask data, bounds and verification order are all
seeded), so a drop means a real pruning/accounting regression, not CI
noise.  Latency ratios ride along in the uploaded artifact but are not
gated (shared CI runners make wall time a coin flip).

A metric fails when it regresses by more than ``--max-regression``:
``current < baseline / max_regression``.

    python benchmarks/check_regression.py \
        --baseline BENCH_pair.json --current /tmp/bench_pair.json \
        --metrics pair_iou_topk.bytes_ratio,pair_filter.bytes_ratio
"""

from __future__ import annotations

import argparse
import json
import sys


def lookup(record: dict, dotted: str):
    node = record
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_*.json (its 'tiny' section is "
                         "used when present)")
    ap.add_argument("--current", required=True,
                    help="JSON produced by the tiny-mode CI run")
    ap.add_argument("--metrics", required=True,
                    help="comma-separated dotted paths, e.g. "
                         "pair_iou_topk.bytes_ratio")
    ap.add_argument("--max-regression", type=float, default=2.5,
                    help="fail when current < baseline / this factor")
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    baseline = baseline.get("tiny", baseline)
    with open(args.current) as f:
        current = json.load(f)

    failures = []
    for metric in args.metrics.split(","):
        metric = metric.strip()
        base = lookup(baseline, metric)
        cur = lookup(current, metric)
        if base is None:
            print(f"SKIP {metric}: not in baseline ({args.baseline})")
            continue
        if cur is None:
            failures.append(f"{metric}: missing from current run")
            continue
        floor = float(base) / args.max_regression
        status = "FAIL" if float(cur) < floor else "ok"
        print(f"{status:4s} {metric}: current={float(cur):.3f} "
              f"baseline={float(base):.3f} floor={floor:.3f}")
        if status == "FAIL":
            failures.append(
                f"{metric}: {float(cur):.3f} < {floor:.3f} "
                f"(baseline {float(base):.3f} / {args.max_regression}x)")
    if failures:
        print("\nbench regression gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("bench regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
