"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract).

Tables reproduced (demo paper §4 + the full paper's workload experiment):
  * bench_filter_query   — §4 Scenario 1: Filter query, MaskSearch vs
                           full-scan.  Derived: measured speedup, modeled-EBS
                           speedup (paper's disk provisioning), %masks loaded.
  * bench_topk_query     — §4 Scenarios 1+2: Top-K (ASC normalized ROI
                           count; DESC dispersion).
  * bench_agg_iou        — §4 Scenario 3: IoU aggregation (GROUP BY image).
  * bench_multi_query    — full-paper multi-query workload: shared bounds
                           pass + shared verification loads.
  * bench_chi_build      — index-construction throughput (ingest path).
  * bench_cp_kernels     — verification-kernel microbench.

DB defaults are container-sized (5 000 masks @128²); pass --full for the
paper's 22 275 masks.  Modeled-EBS numbers use the paper's own provisioning
(125 MiB/s, 3000 IOPS) so the headline ~100× reproduces independent of this
machine's page cache.
"""

from __future__ import annotations

import argparse
import os
import shutil
import tempfile
import time

import numpy as np

import jax
import jax.numpy as jnp


def _setup(n_masks: int, size: int, tmpdir: str):
    from repro.core import CHIConfig, MaskStore
    from repro.core.store import MASK_META_DTYPE
    from repro.data.masks import object_boxes, saliency_masks

    rois = object_boxes(n_masks, size, size)
    masks, attacked = saliency_masks(n_masks, size, size, seed=7,
                                     attacked_fraction=0.2, boxes=rois,
                                     in_box_fraction=0.9)
    meta = np.zeros(n_masks, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(n_masks)
    meta["image_id"] = np.arange(n_masks) // 2     # 2 mask types per image
    meta["mask_type"] = np.arange(n_masks) % 2 + 1
    # Thresholds on 0.05 multiples + one at 1.0: the workload's value
    # ranges (0.2, 0.6), (0.8, 1.0) align exactly, so the value dimension of
    # every bound is tight (the paper picks Θ to match the workload, §2).
    # Masks live in [0,1), so the 1.0 edge counts every pixel.
    thetas = tuple(round(0.05 * i, 2) for i in range(1, 20)) + (1.0,)
    cfg = CHIConfig(grid=16, num_bins=21, height=size, width=size,
                    thresholds=thetas)
    store = MaskStore.create_disk(os.path.join(tmpdir, "db"), masks, meta, cfg)
    return store, rois, masks, attacked


def _row(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def _timed(fn, repeats: int = 5):
    fn()                                   # warmup (jit compiles)
    times = []
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times)), out


def bench_filter_query(store, rois, args):
    from repro.core import CP, RoiArea, filter_query
    from repro.core.exprs import BinOp
    expr = BinOp("/", CP("provided", 0.8, 1.0), RoiArea("provided"))
    thr = 0.05

    def run_indexed():
        store.io.reset()
        return filter_query(store, expr, "<", thr, provided_rois=rois)

    def run_scan():
        store.io.reset()
        return filter_query(store, expr, "<", thr, provided_rois=rois,
                            use_index=False)

    t_idx, (ids_i, st_i) = _timed(run_indexed, args.repeats)
    io_idx = store.io.modeled_ebs_time_s
    t_scan, (ids_s, st_s) = _timed(run_scan, args.repeats)
    io_scan = store.io.modeled_ebs_time_s
    assert set(ids_i) == set(ids_s), "index answer != full scan"
    _row("filter_masksearch", t_idx,
         f"loaded={st_i.load_fraction:.3%};modeled_ebs_s={io_idx:.2f}")
    _row("filter_fullscan", t_scan,
         f"loaded=100%;modeled_ebs_s={io_scan:.2f}")
    _row("filter_speedup", 0.0,
         f"measured={t_scan / max(t_idx, 1e-9):.1f}x;"
         f"modeled_ebs={io_scan / max(io_idx, 1e-9):.1f}x")


def bench_topk_query(store, rois, args):
    from repro.core import CP, RoiArea, topk_query
    from repro.core.exprs import BinOp
    expr1 = BinOp("/", CP("provided", 0.8, 1.0), RoiArea("provided"))
    expr2 = CP(None, 0.2, 0.6)

    for name, expr, desc in (("topk_s1_asc", expr1, False),
                             ("topk_s2_desc", expr2, True)):
        def run_idx(expr=expr, desc=desc):
            store.io.reset()
            return topk_query(store, expr, 25, desc=desc, provided_rois=rois)

        def run_scan(expr=expr, desc=desc):
            store.io.reset()
            return topk_query(store, expr, 25, desc=desc, provided_rois=rois,
                              use_index=False)

        t_idx, (ids_i, sc_i, st_i) = _timed(run_idx, args.repeats)
        io_idx = store.io.modeled_ebs_time_s
        t_scan, (ids_s, sc_s, _) = _timed(run_scan, args.repeats)
        io_scan = store.io.modeled_ebs_time_s
        assert np.allclose(np.sort(sc_i), np.sort(sc_s)), f"{name} mismatch"
        _row(f"{name}_masksearch", t_idx,
             f"loaded={st_i.load_fraction:.3%};modeled_ebs_s={io_idx:.2f}")
        _row(f"{name}_fullscan", t_scan, f"modeled_ebs_s={io_scan:.2f}")
        _row(f"{name}_speedup", 0.0,
             f"measured={t_scan / max(t_idx, 1e-9):.1f}x;"
             f"modeled_ebs={io_scan / max(io_idx, 1e-9):.1f}x")


def bench_agg_iou(store, rois, args):
    from repro.core import queries

    def run_idx():
        store.io.reset()
        return queries.run(queries.SCENARIO3_IOU, store)

    def run_scan():
        store.io.reset()
        return queries.run(queries.SCENARIO3_IOU, store, use_index=False)

    t_idx, ((ids_i, sc_i), st_i) = _timed(run_idx, max(args.repeats // 2, 1))
    io_idx = store.io.modeled_ebs_time_s
    t_scan, ((ids_s, sc_s), _) = _timed(run_scan, 1)
    io_scan = store.io.modeled_ebs_time_s
    assert np.allclose(np.sort(sc_i), np.sort(sc_s), atol=1e-9)
    _row("agg_iou_masksearch", t_idx,
         f"loaded={st_i.load_fraction:.3%};modeled_ebs_s={io_idx:.2f}")
    _row("agg_iou_fullscan", t_scan, f"modeled_ebs_s={io_scan:.2f}")
    _row("agg_iou_speedup", 0.0,
         f"measured={t_scan / max(t_idx, 1e-9):.1f}x;"
         f"modeled_ebs={io_scan / max(io_idx, 1e-9):.1f}x")


def bench_multi_query(store, rois, args):
    """Workload of 10 related queries (5 filter + 5 top-k) — one bounds
    pass per query over the in-memory CHI + shared verification loads."""
    from repro.core.multiquery import run_workload
    sqls = []
    for t in (0.02, 0.04, 0.06, 0.08, 0.10):
        sqls.append("SELECT mask_id FROM MasksDatabaseView WHERE "
                    f"CP(mask, roi, (0.8, 1.0)) / AREA(roi) < {t};")
    for lv in (0.15, 0.2, 0.25, 0.3, 0.35):
        sqls.append("SELECT mask_id FROM MasksDatabaseView ORDER BY "
                    f"CP(mask, full_img, ({lv}, {lv + 0.4})) DESC LIMIT 25;")

    def run_shared():
        store.io.reset()
        return run_workload(store, sqls, provided_rois=rois, share_loads=True)

    def run_unshared():
        store.io.reset()
        return run_workload(store, sqls, provided_rois=rois,
                            share_loads=False)

    def run_scan():
        store.io.reset()
        return run_workload(store, sqls, provided_rois=rois, use_index=False,
                            share_loads=False)

    t_sh, (_, ws_sh) = _timed(run_shared, max(args.repeats // 2, 1))
    io_sh = store.io.modeled_ebs_time_s
    t_un, (_, ws_un) = _timed(run_unshared, max(args.repeats // 2, 1))
    t_scan, (_, ws_scan) = _timed(run_scan, 1)
    io_scan = store.io.modeled_ebs_time_s
    _row("workload10_masksearch_shared", t_sh,
         f"files={ws_sh.files_loaded};modeled_ebs_s={io_sh:.2f}")
    _row("workload10_masksearch_unshared", t_un,
         f"files={ws_un.files_loaded}")
    _row("workload10_fullscan", t_scan,
         f"files={ws_scan.files_loaded};modeled_ebs_s={io_scan:.2f}")
    _row("workload10_speedup", 0.0,
         f"measured={t_scan / max(t_sh, 1e-9):.1f}x;"
         f"share_gain={t_un / max(t_sh, 1e-9):.2f}x;"
         f"modeled_ebs={io_scan / max(io_sh, 1e-9):.1f}x")


def bench_chi_build(store, masks, args):
    from repro.core.chi import build_chi
    from repro.kernels.ops import chi_cell_hist
    cfg = store.cfg
    sub = jnp.asarray(masks[:256])
    edges = jnp.asarray(cfg.interior_edges)

    build_jnp = lambda: jax.block_until_ready(build_chi(sub, cfg))
    t_jnp, _ = _timed(build_jnp, 3)
    kern = lambda: jax.block_until_ready(
        chi_cell_hist(sub, edges, cfg.grid, use_pallas=True, interpret=True))
    t_kern, _ = _timed(kern, 1)
    mb = sub.nbytes / 1e6
    _row("chi_build_jnp_256", t_jnp, f"MB_per_s={mb / t_jnp:.0f}")
    _row("chi_build_pallas_interp_256", t_kern,
         "correctness-path;TPU perf is the BlockSpec design")
    _row("chi_index_overhead", 0.0,
         f"index_bytes_frac="
         f"{cfg.index_bytes(len(store)) / cfg.mask_bytes(len(store)):.3%}")


def bench_cp_kernels(store, masks, args):
    from repro.kernels import ops
    sub = jnp.asarray(masks[:1024])
    rois = jnp.tile(jnp.asarray([[8, 8, store.cfg.height - 8,
                                  store.cfg.width - 8]], jnp.int32),
                    (sub.shape[0], 1))
    f = lambda: jax.block_until_ready(ops.cp_count(sub, rois, 0.25, 0.75))
    t, _ = _timed(f, args.repeats)
    _row("cp_count_1024", t, f"us_per_mask={t * 1e6 / sub.shape[0]:.2f}")
    qrois = jnp.broadcast_to(rois[None], (8,) + rois.shape)
    lvs = jnp.linspace(0.1, 0.8, 8)
    uvs = lvs + 0.15
    g = lambda: jax.block_until_ready(
        ops.cp_count_multi(sub, qrois, lvs, uvs, use_pallas=False))
    t8, _ = _timed(g, args.repeats)
    _row("cp_count_multi_q8_1024", t8,
         f"per_query_amortized={t8 / 8 / max(t, 1e-9):.2f}x_single")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-masks", type=int, default=5000)
    ap.add_argument("--size", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale DB: 22275 masks")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--keep-db", default=None)
    args = ap.parse_args()
    if args.full:
        args.n_masks = 22275

    print("name,us_per_call,derived")
    tmpdir = args.keep_db or tempfile.mkdtemp(prefix="masksearch_bench_")
    try:
        t0 = time.perf_counter()
        store, rois, masks, _ = _setup(args.n_masks, args.size, tmpdir)
        _row("db_ingest_total", time.perf_counter() - t0,
             f"n={args.n_masks};size={args.size}")
        bench_filter_query(store, rois, args)
        bench_topk_query(store, rois, args)
        bench_agg_iou(store, rois, args)
        bench_multi_query(store, rois, args)
        bench_chi_build(store, masks, args)
        bench_cp_kernels(store, masks, args)
    finally:
        if not args.keep_db:
            shutil.rmtree(tmpdir, ignore_errors=True)


if __name__ == "__main__":
    main()
