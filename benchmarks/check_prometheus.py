"""Stdlib-only Prometheus text-exposition checker for the CI smoke leg.

Validates the output of ``GET /metrics`` without depending on a Prometheus
client library:

  * every sample line parses as ``name[{labels}] value``;
  * every sample belongs to a family announced by a ``# TYPE`` line, and the
    family's samples match its type (``counter``/``gauge`` are plain samples;
    ``histogram`` families expose ``_bucket``/``_sum``/``_count`` series);
  * histogram buckets are cumulative-monotone in ``le`` order, end with a
    ``+Inf`` bucket, and the ``+Inf`` count equals the ``_count`` sample;
  * counters are non-negative.

``--require NAME`` (repeatable) additionally asserts that a family is
present — the CI leg uses it to pin the families the observability layer
promises.

    curl -s localhost:8080/metrics | python benchmarks/check_prometheus.py \
        --require masksearch_queries_total
"""

from __future__ import annotations

import argparse
import re
import sys

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r" (?P<value>[^ ]+)$")
_LABEL_RE = re.compile(r'^[a-zA-Z_][a-zA-Z0-9_]*="[^"\\]*"$')


def _family_of(name: str, typed: dict) -> str | None:
    """Map a sample name to its announced family (histograms expose
    ``<fam>_bucket``/``_sum``/``_count`` under family ``<fam>``)."""
    if name in typed:
        return name
    for suffix in ("_bucket", "_sum", "_count"):
        if name.endswith(suffix) and name[: -len(suffix)] in typed:
            return name[: -len(suffix)]
    return None


def check(text: str, required: list[str]) -> list[str]:
    errors: list[str] = []
    typed: dict[str, str] = {}
    helped: set[str] = set()
    # histogram buckets keyed by (family, non-le labels) -> [(le, count)]
    buckets: dict[tuple, list[tuple[float, float]]] = {}
    counts: dict[tuple, float] = {}
    sums: set[tuple] = set()

    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            helped.add(line.split(" ", 3)[2])
            continue
        if line.startswith("# TYPE "):
            parts = line.split(" ")
            if len(parts) != 4 or parts[3] not in ("counter", "gauge",
                                                   "histogram"):
                errors.append(f"line {lineno}: malformed TYPE line: {line!r}")
                continue
            if parts[2] in typed:
                errors.append(f"line {lineno}: duplicate TYPE for {parts[2]}")
            typed[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue

        m = _SAMPLE_RE.match(line)
        if not m:
            errors.append(f"line {lineno}: unparsable sample: {line!r}")
            continue
        name, raw_labels, raw_value = (m.group("name"), m.group("labels"),
                                       m.group("value"))
        try:
            value = float(raw_value)
        except ValueError:
            errors.append(f"line {lineno}: non-numeric value {raw_value!r}")
            continue
        labels = {}
        for pair in (raw_labels.split(",") if raw_labels else []):
            if not _LABEL_RE.match(pair):
                errors.append(f"line {lineno}: malformed label {pair!r}")
                break
            k, v = pair.split("=", 1)
            labels[k] = v.strip('"')

        fam = _family_of(name, typed)
        if fam is None:
            errors.append(f"line {lineno}: sample {name!r} has no TYPE line")
            continue
        ftype = typed[fam]
        key = (fam, tuple(sorted((k, v) for k, v in labels.items()
                                 if k != "le")))
        if ftype == "histogram":
            if name.endswith("_bucket"):
                if "le" not in labels:
                    errors.append(f"line {lineno}: bucket without le label")
                    continue
                le = (float("inf") if labels["le"] == "+Inf"
                      else float(labels["le"]))
                buckets.setdefault(key, []).append((le, value))
            elif name.endswith("_count"):
                counts[key] = value
            elif name.endswith("_sum"):
                sums.add(key)
            else:
                errors.append(f"line {lineno}: plain sample {name!r} in "
                              f"histogram family {fam!r}")
        else:
            if name != fam:
                errors.append(f"line {lineno}: suffixed sample {name!r} in "
                              f"{ftype} family {fam!r}")
            if ftype == "counter" and value < 0:
                errors.append(f"line {lineno}: negative counter {name!r}")

    for fam in typed:
        if fam not in helped:
            errors.append(f"family {fam!r}: TYPE without HELP")
    for key, series in buckets.items():
        fam = key[0]
        les = [le for le, _ in series]
        vals = [v for _, v in series]
        if les != sorted(les):
            errors.append(f"{fam}{dict(key[1])}: buckets out of le order")
        if vals != sorted(vals):
            errors.append(f"{fam}{dict(key[1])}: bucket counts not "
                          f"cumulative-monotone: {vals}")
        if not les or les[-1] != float("inf"):
            errors.append(f"{fam}{dict(key[1])}: missing +Inf bucket")
        elif key in counts and counts[key] != vals[-1]:
            errors.append(f"{fam}{dict(key[1])}: _count {counts[key]} != "
                          f"+Inf bucket {vals[-1]}")
        if key not in counts:
            errors.append(f"{fam}{dict(key[1])}: missing _count")
        if key not in sums:
            errors.append(f"{fam}{dict(key[1])}: missing _sum")

    for want in required:
        if want not in typed:
            errors.append(f"required family {want!r} absent from exposition")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("path", nargs="?", default="-",
                    help="exposition file, or '-' for stdin (default)")
    ap.add_argument("--require", action="append", default=[],
                    help="family name that must be present (repeatable)")
    args = ap.parse_args()

    if args.path == "-":
        text = sys.stdin.read()
    else:
        with open(args.path) as f:
            text = f.read()

    errors = check(text, args.require)
    families = text.count("# TYPE ")
    samples = sum(1 for ln in text.splitlines()
                  if ln.strip() and not ln.startswith("#"))
    if errors:
        print(f"prometheus check FAILED ({families} families, "
              f"{samples} samples):", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    print(f"prometheus check ok: {families} families, {samples} samples")
    return 0


if __name__ == "__main__":
    sys.exit(main())
