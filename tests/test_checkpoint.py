"""Checkpoint/restart + fault-tolerance tests (deliverable: large-scale
runnability).  Determinism: save→restore→train ≡ uninterrupted train."""

import os

import jax
import numpy as np
import pytest

from repro.configs import load_smoke
from repro.data.pipeline import SyntheticLMData
from repro.models import build_model
from repro.train import checkpoint as ckpt
from repro.train.fault import PreemptionGuard
from repro.train.optimizer import OptConfig
from repro.train.train_loop import init_train_state, make_train_step


def _setup(microbatches=1):
    cfg = load_smoke("granite_3_2b")
    model = build_model(cfg)
    opt_cfg = OptConfig(warmup_steps=2, total_steps=20)
    params, axes, opt_state = init_train_state(model, jax.random.PRNGKey(0),
                                               opt_cfg)
    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      microbatches=microbatches))
    data = SyntheticLMData(cfg, seq_len=16, global_batch=4)
    return model, params, opt_state, step_fn, data


def _tree_equal(a, b):
    return all(np.array_equal(np.asarray(x, np.float32),
                              np.asarray(y, np.float32))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_save_restore_exact_resume(tmp_path):
    model, params, opt_state, step_fn, data = _setup()
    # uninterrupted: 6 steps
    p_ref, o_ref = params, opt_state
    for s in range(6):
        p_ref, o_ref, _ = step_fn(p_ref, o_ref, data.batch_at(s))

    # interrupted at step 3
    p, o = params, opt_state
    for s in range(3):
        p, o, _ = step_fn(p, o, data.batch_at(s))
    ckpt.save(str(tmp_path), 3, {"params": p, "opt": o})
    del p, o

    state, step = ckpt.restore_latest(
        str(tmp_path), {"params": params, "opt": opt_state})
    assert step == 3
    p, o = state["params"], state["opt"]
    for s in range(3, 6):
        p, o, _ = step_fn(p, o, data.batch_at(s))
    assert _tree_equal(p, p_ref), "resume diverged from uninterrupted run"


def test_crash_mid_write_ignored(tmp_path):
    model, params, opt_state, step_fn, data = _setup()
    ckpt.save(str(tmp_path), 1, {"params": params})
    # simulate a crash: a half-written .tmp dir for step 2
    os.makedirs(tmp_path / "step_00000002.tmp")
    with open(tmp_path / "step_00000002.tmp" / "leaf_00000.npy", "wb") as f:
        f.write(b"garbage")
    state, step = ckpt.restore_latest(str(tmp_path), {"params": params})
    assert step == 1  # the committed one


def test_keep_prunes_old(tmp_path):
    model, params, opt_state, step_fn, data = _setup()
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), s, {"params": params}, keep=2)
    names = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert names == ["step_00000003", "step_00000004"]
    assert ckpt.latest_step(str(tmp_path)) == 4


def test_structure_mismatch_rejected(tmp_path):
    model, params, opt_state, step_fn, data = _setup()
    ckpt.save(str(tmp_path), 1, {"params": params})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), 1, {"params": params, "extra": params})


def test_preemption_guard_checkpoints_and_stops(tmp_path):
    model, params, opt_state, step_fn, data = _setup()
    guard = PreemptionGuard(signals=())
    p, o = params, opt_state
    saved_at = None
    for s in range(10):
        if s == 4:
            guard.trigger()           # simulated SIGTERM
        p, o, _ = step_fn(p, o, data.batch_at(s))
        if guard.should_stop:
            ckpt.save(str(tmp_path), s, {"params": p, "opt": o})
            saved_at = s
            break
    assert saved_at == 4
    _, step = ckpt.restore_latest(str(tmp_path), {"params": p, "opt": o})
    assert step == 4


def test_elastic_restore_across_device_counts(tmp_path):
    """Checkpoints are device-layout-free: a state saved from this process
    restores under a different fake device count (subprocess with 8 devs)."""
    import subprocess
    import sys
    model, params, opt_state, step_fn, data = _setup()
    ckpt.save(str(tmp_path), 7, {"params": params})
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {os.path.abspath("src")!r})
import jax, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import load_smoke
from repro.models import build_model
from repro.train import checkpoint as ckpt
from repro.launch.mesh import make_local_mesh
from repro.launch import sharding as sh

mesh = make_local_mesh((8,), ("data",))
model = build_model(load_smoke("granite_3_2b"))
params, axes = model.init(jax.random.PRNGKey(0))
shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
shards = sh.param_sharding_tree(mesh, shapes, axes)
state, step = ckpt.restore_latest({str(tmp_path)!r}, {{"params": params}},
                                  shardings={{"params": shards}})
assert step == 7
leaf = jax.tree.leaves(state["params"])[0]
assert len(leaf.sharding.device_set) >= 1
print("ELASTIC_OK", len(jax.devices()))
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300)
    assert "ELASTIC_OK 8" in out.stdout, out.stderr[-2000:]


def test_microbatched_step_matches_single(tmp_path):
    """Gradient accumulation is loss-equivalent to the unaccumulated step."""
    cfg = load_smoke("granite_3_2b")
    import dataclasses
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    opt_cfg = OptConfig(warmup_steps=0, total_steps=10)
    params, _, opt = init_train_state(model, jax.random.PRNGKey(0), opt_cfg)
    data = SyntheticLMData(cfg, seq_len=16, global_batch=8)
    batch = data.batch_at(0)
    s1 = make_train_step(model, opt_cfg, microbatches=1)
    s4 = make_train_step(model, opt_cfg, microbatches=4)
    p1, o1, m1 = jax.jit(s1)(params, opt, batch)
    p4, o4, m4 = jax.jit(s4)(params, opt, batch)
    l1 = jax.tree.leaves(p1)
    l4 = jax.tree.leaves(p4)
    # losses agree to f32 roundoff; grads differ only by summation order
    # (measured ~1e-4 relative), so params after one Adam step may differ by
    # O(lr)·O(rel-err) — use a tolerance reflecting that, not exactness.
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    err = max(float(np.max(np.abs(np.asarray(a, np.float32) -
                                  np.asarray(b, np.float32))))
              for a, b in zip(l1, l4))
    assert err < 5e-3, f"accumulated step diverges: {err}"
