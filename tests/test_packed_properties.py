"""Bitpacked binary-mask tier (DESIGN.md §12): pack/unpack round-trips at
ragged widths, popcount kernel (Pallas interpret) ≡ jnp reference ≡ numpy
oracle, fused bounds+verify megakernel semantics (CHI passthrough + one
launch per verification batch), and the headline acceptance — a packed
store answers plans bit-identically to the float store while loading ≥8×
fewer bytes.  Seeded sweeps run everywhere; hypothesis variants (guarded,
the container may lack it) widen the shape/range space."""

import numpy as np
import pytest

from repro.core import CHIConfig, MaskStore
from repro.core.engine import TopKRun
from repro.core.exprs import CP
from repro.core.packing import (WORD_BITS, pack_masks, packed_row_nbytes,
                                unpack_masks, validate_binary, words_for)
from repro.core.plan import LogicalPlan, run_plan
from repro.core.store import MASK_META_DTYPE
from repro.data.masks import object_boxes, saliency_masks
from repro.kernels import ops as kops
from repro.kernels import popcount as pk
from repro.obs import REGISTRY

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                # pragma: no cover
    HAVE_HYPOTHESIS = False

# ragged widths on purpose: every W % 32 class the span masks must handle
WIDTHS = (1, 31, 32, 33, 37, 64, 100)
RANGES = ((0.2, 0.6), (0.0, 1.0), (-1.0, 2.0), (0.5, 1.5), (0.7, 0.8),
          (0.0, 0.5), (1.0, 1.0))


def _binary(shape, seed=0, p=0.4):
    rng = np.random.default_rng(seed)
    return (rng.random(shape) < p).astype(np.float32)


def _rois(b, h, w, seed=1):
    rng = np.random.default_rng(seed)
    r = np.sort(rng.integers(0, h + 1, (b, 2)), axis=1)
    c = np.sort(rng.integers(0, w + 1, (b, 2)), axis=1)
    return np.stack([r[:, 0], c[:, 0], r[:, 1], c[:, 1]], 1).astype(np.int32)


def _oracle_cp(masks, rois, lv, uv):
    """Numpy ground truth: #pixels with lv <= value < uv inside the ROI."""
    out = np.zeros(len(masks), np.int64)
    for i, (m, (r0, c0, r1, c1)) in enumerate(zip(masks, rois)):
        win = m[r0:r1, c0:c1]
        out[i] = np.count_nonzero((win >= lv) & (win < uv))
    return out


def _launches(kernel):
    snap = REGISTRY.snapshot().get("masksearch_kernel_launches_total", {})
    return snap.get(f"kernel={kernel}", 0.0)


# ---------------------------------------------------------------------------
# packing: round-trip identity + the zero-tail invariant
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("w", WIDTHS)
def test_pack_unpack_roundtrip(w):
    masks = _binary((4, 9, w), seed=w)
    packed = pack_masks(masks)
    assert packed.shape == (4, 9, words_for(w))
    assert packed.dtype == np.uint32
    np.testing.assert_array_equal(unpack_masks(packed, w), masks)
    assert packed_row_nbytes(9, w) == 9 * words_for(w) * 4


@pytest.mark.parametrize("w", WIDTHS)
def test_tail_bits_past_width_are_zero(w):
    packed = pack_masks(np.ones((3, 5, w), np.float32))
    tail = words_for(w) * WORD_BITS - w
    if tail:
        garbage = packed[..., -1] >> np.uint32(WORD_BITS - tail)
        np.testing.assert_array_equal(garbage, 0)
    # all-ones masks popcount to exactly w per row
    bits = np.unpackbits(packed.view(np.uint8), bitorder="little")
    assert bits.sum() == 3 * 5 * w


def test_validate_binary_rejects_grayscale():
    validate_binary(np.array([[0.0, 1.0], [1.0, 0.0]]))
    with pytest.raises(ValueError, match="binary"):
        validate_binary(np.array([0.0, 0.5, 1.0]))


# ---------------------------------------------------------------------------
# kernel parity: Pallas interpret ≡ jnp reference ≡ numpy oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("w", (31, 37, 64))
@pytest.mark.parametrize("lv,uv", RANGES)
def test_cp_count_packed_matches_oracle_and_float(w, lv, uv):
    masks = _binary((5, 16, w), seed=3 * w)
    packed = pack_masks(masks)
    rois = _rois(5, 16, w, seed=w)
    want = _oracle_cp(masks, rois, lv, uv)
    got_ref = np.asarray(kops.cp_count_packed(packed, rois, lv, uv,
                                              use_pallas=False))
    got_pl = np.asarray(kops.cp_count_packed(packed, rois, lv, uv,
                                             use_pallas=True, interpret=True))
    got_float = np.asarray(kops.cp_count(masks, rois, lv, uv,
                                         use_pallas=False))
    np.testing.assert_array_equal(got_ref, want)
    np.testing.assert_array_equal(got_pl, want)
    np.testing.assert_array_equal(got_float, want)


@pytest.mark.parametrize("q", (1, 3))
def test_cp_count_multi_packed_matches_single(q):
    w = 37
    masks = _binary((6, 16, w), seed=9)
    packed = pack_masks(masks)
    rois = np.stack([_rois(6, 16, w, seed=20 + i) for i in range(q)])
    lvs = np.asarray([RANGES[i % len(RANGES)][0] for i in range(q)],
                     np.float32)
    uvs = np.asarray([max(RANGES[i % len(RANGES)]) for i in range(q)],
                     np.float32)
    got = np.asarray(kops.cp_count_multi_packed(packed, rois, lvs, uvs,
                                                use_pallas=True,
                                                interpret=True))
    assert got.shape == (q, 6)
    for i in range(q):
        np.testing.assert_array_equal(
            got[i], _oracle_cp(masks, rois[i], lvs[i], uvs[i]))


@pytest.mark.parametrize("thresh", (0.5, -0.5, 1.5))
def test_mask_agg_packed_matches_float(thresh):
    n, s, h, w = 4, 3, 16, 37
    grp = _binary((n, s, h, w), seed=13)
    packed = pack_masks(grp)
    rois = _rois(n, h, w, seed=14)
    gi, gu = kops.mask_agg_counts_packed(packed, rois, thresh,
                                         use_pallas=True, interpret=True)
    wi, wu = kops.mask_agg_counts(grp, rois, thresh, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(gu), np.asarray(wu))


@pytest.mark.parametrize("ta,tb", ((0.5, 0.5), (-1.0, 0.5), (0.5, 2.0)))
def test_pair_counts_packed_matches_float(ta, tb):
    b, h, w = 5, 16, 37
    ma, mb = _binary((b, h, w), seed=17), _binary((b, h, w), seed=18)
    rois = _rois(b, h, w, seed=19)
    got = kops.pair_counts_packed(pack_masks(ma), pack_masks(mb), rois,
                                  ta, tb, use_pallas=True, interpret=True)
    want = kops.pair_counts(ma, mb, rois, ta, tb, use_pallas=False)
    for g, f in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(f))


# ---------------------------------------------------------------------------
# fused bounds+verify megakernel
# ---------------------------------------------------------------------------


def test_fused_verify_passthrough_and_count():
    """Decided entries pass their CHI lower bound through verbatim (even a
    deliberately wrong one — proof nothing recounts them); undecided
    entries get the exact packed count."""
    q, b, h, w = 3, 6, 16, 37
    masks = _binary((b, h, w), seed=23)
    packed = pack_masks(masks)
    rois = np.stack([_rois(b, h, w, seed=30 + i) for i in range(q)])
    lvs = np.asarray([0.2, 0.5, 0.0], np.float32)
    uvs = np.asarray([0.6, 1.5, 1.0], np.float32)
    rng = np.random.default_rng(31)
    decided = (rng.random((q, b)) < 0.5).astype(np.int32)
    lb = rng.integers(0, 1000, (q, b)).astype(np.int32)  # sentinel values
    for kw in ({"use_pallas": False},
               {"use_pallas": True, "interpret": True}):
        got = np.asarray(kops.fused_bounds_verify(
            packed, rois, lvs, uvs, decided, lb, **kw))
        for i in range(q):
            exact = _oracle_cp(masks, rois[i], lvs[i], uvs[i])
            want = np.where(decided[i] > 0, lb[i], exact)
            np.testing.assert_array_equal(got[i], want)


def test_fused_verify_pallas_matches_ref():
    q, b, h, w = 2, 4, 8, 64
    packed = pack_masks(_binary((b, h, w), seed=37))
    rois = np.stack([_rois(b, h, w, seed=40 + i) for i in range(q)])
    lvs = np.asarray([0.2, 0.7], np.float32)
    uvs = np.asarray([0.6, 1.2], np.float32)
    decided = np.asarray([[1, 0, 1, 0], [0, 0, 1, 1]], np.int32)
    lb = np.asarray([[7, 0, 9, 0], [0, 0, 3, 4]], np.int32)
    pl = pk.fused_verify_packed_pallas(packed, rois, lvs, uvs, decided, lb,
                                       interpret=True)
    rf = pk.fused_verify_packed_ref(packed, rois, lvs, uvs, decided, lb)
    np.testing.assert_array_equal(np.asarray(pl), np.asarray(rf))


# ---------------------------------------------------------------------------
# end-to-end acceptance: bytes ratio + one launch per verification batch
# ---------------------------------------------------------------------------

B, H, W = 24, 32, 32


def _stores():
    boxes = object_boxes(B, H, W, seed=2)
    m, _ = saliency_masks(B, H, W, seed=1, boxes=boxes)
    masks = (m > 0.5).astype(np.float32)
    meta = np.zeros(B, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(B)
    meta["image_id"] = np.arange(B) // 2
    meta["mask_type"] = np.arange(B) % 2 + 1
    cfg = CHIConfig(grid=4, num_bins=8, height=H, width=W)
    fstore = MaskStore.create_memory(masks, meta, cfg)
    pstore = MaskStore.create_memory(masks, meta.copy(), cfg, packed=True)
    return fstore, pstore, masks


def test_packed_store_equivalent_and_bytes_ratio():
    fstore, pstore, _ = _stores()
    # grid-misaligned ROI so CHI bounds leave a residue to verify
    plan = LogicalPlan(order_by=CP((3, 5, 29, 31), 0.5, 1.5), k=8)
    (fids, fscores), fstats = run_plan(fstore, plan, verify_batch=5)
    (pids, pscores), pstats = run_plan(pstore, plan, verify_batch=5)
    np.testing.assert_array_equal(fids, pids)
    np.testing.assert_array_equal(fscores, pscores)
    assert fstats.n_verified == pstats.n_verified
    # identical candidates verified, 1-bit rows: ≥8× fewer bytes (ISSUE 8
    # acceptance; exactly 32× here since W % 32 == 0)
    assert fstats.bytes_loaded > 0
    assert fstats.bytes_loaded >= 8 * pstats.bytes_loaded


def test_megakernel_one_launch_per_verify_batch():
    _, pstore, _ = _stores()
    run = TopKRun(pstore, CP((3, 5, 29, 31), 0.5, 1.5), verify_batch=4)
    run.target(8)
    before = _launches("fused_bounds_verify")
    n_batches = 0
    while not run.finished():
        batch = run.take_batch()
        if not len(batch):
            break
        run.self_verify(batch)
        n_batches += 1
    assert n_batches >= 2          # the scenario actually batches
    assert _launches("fused_bounds_verify") - before == n_batches


def test_explain_analyze_reports_packed_source():
    from repro.obs.explain import explain_analyze

    fstore, pstore, _ = _stores()
    plan = LogicalPlan(order_by=CP((3, 5, 29, 31), 0.5, 1.5), k=5)
    for store, want in ((fstore, False), (pstore, True)):
        rep = explain_analyze(store, plan, verify_batch=5)
        src = {c["op"]: c for c in rep["tree"]["children"]}["Source"]
        assert src["packed"] is want


def test_packed_store_rejects_nonbinary_ingest():
    boxes = object_boxes(4, H, W, seed=5)
    gray, _ = saliency_masks(4, H, W, seed=6, boxes=boxes)
    meta = np.zeros(4, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(4)
    cfg = CHIConfig(grid=4, num_bins=8, height=H, width=W)
    with pytest.raises(ValueError, match="binary"):
        MaskStore.create_memory(gray, meta, cfg, packed=True)


# ---------------------------------------------------------------------------
# hypothesis sweeps (skipped where hypothesis is unavailable)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(deadline=None, max_examples=30,
              suppress_health_check=[HealthCheck.too_slow])
    @given(h=st.integers(1, 12), w=st.integers(1, 80),
           seed=st.integers(0, 2**16), p=st.floats(0.0, 1.0))
    def test_hyp_pack_roundtrip(h, w, seed, p):
        masks = _binary((2, h, w), seed=seed, p=p)
        np.testing.assert_array_equal(unpack_masks(pack_masks(masks), w),
                                      masks)

    @settings(deadline=None, max_examples=20,
              suppress_health_check=[HealthCheck.too_slow])
    @given(w=st.integers(1, 70), seed=st.integers(0, 2**16),
           lv=st.floats(-1.0, 2.0), span=st.floats(0.0, 2.0))
    def test_hyp_cp_packed_matches_oracle(w, seed, lv, span):
        uv = lv + span
        masks = _binary((3, 8, w), seed=seed)
        rois = _rois(3, 8, w, seed=seed + 1)
        got = np.asarray(kops.cp_count_packed(
            pack_masks(masks), rois, lv, uv,
            use_pallas=True, interpret=True))
        np.testing.assert_array_equal(got, _oracle_cp(masks, rois, lv, uv))
