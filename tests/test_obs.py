"""Observability suite (DESIGN.md §10): span tracing, EXPLAIN ANALYZE, the
metrics registry, and the service's /metrics + /trace surfaces.

Key invariants:
  * the span-tree *structure* (names, nesting, candidate/verified counts) is
    identical across host/device/mesh for CP rankings, dual-mask rankings,
    and aggregations — instrumentation lives in the backend-agnostic
    drivers, so this holds by construction and is asserted here;
  * with tracing disabled no Span is ever allocated
    (``Tracer.spans_started`` stays 0 — a counter assertion, not a timing);
  * EXPLAIN ANALYZE returns per-operator candidates / decided-by-bounds /
    verified / bytes / timings on every backend and for every plan kind;
  * the Prometheus exposition is well-formed and the Chrome trace export
    round-trips through json.
"""

import json

import numpy as np
import pytest

from repro.core import CHIConfig, MaskStore, queries
from repro.core.plan import run_plan
from repro.core.store import MASK_META_DTYPE
from repro.data.masks import object_boxes, saliency_masks
from repro.obs import GLOBAL_TRACER, Tracer, chrome_trace
from repro.obs import trace as trace_mod
from repro.obs.explain import explain_analyze, explain_plan
from repro.obs.metrics import MetricsRegistry, REGISTRY

B, H, W = 24, 32, 32
BACKENDS = ("host", "device", "mesh")

CP_SQL = ("SELECT mask_id FROM V "
          "ORDER BY CP(mask, roi, (0.8, 1.0)) / AREA(roi) ASC LIMIT 10;")
PAIR_SQL = ("SELECT image_id FROM V "
            "ORDER BY IOU(saliency, attention, 0.6, 0.6) ASC LIMIT 6;")
AGG_SQL = "SELECT SCALAR_AGG(AVG, CP(mask, full_img, (0.2, 0.6))) FROM V;"
FILTERED_SQL = ("SELECT mask_id FROM V "
                "WHERE CP(mask, full_img, (0.2, 0.6)) > 50 "
                "ORDER BY CP(mask, full_img, (0.2, 0.6)) DESC LIMIT 8;")


@pytest.fixture(scope="module")
def db():
    rois = object_boxes(B, H, W, seed=5)
    masks, _ = saliency_masks(B, H, W, seed=4, attacked_fraction=0.25,
                              boxes=rois)
    meta = np.zeros(B, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(B)
    meta["image_id"] = np.arange(B) // 2
    meta["mask_type"] = np.arange(B) % 2 + 1   # pairs: (1, 2) per image
    cfg = CHIConfig(grid=4, num_bins=8, height=H, width=W)
    return MaskStore.create_memory(masks, meta, cfg), rois


# -- tracer mechanics --------------------------------------------------------


def test_disabled_tracer_allocates_no_spans(db):
    store, rois = db
    before = GLOBAL_TRACER.spans_started
    queries.run(CP_SQL, store, provided_rois=rois)
    assert GLOBAL_TRACER.spans_started == before
    assert trace_mod.span("anything") is trace_mod.NOOP_SPAN


def test_span_tree_nesting_and_ring_buffer():
    t = Tracer(enabled=True)
    with t.activate():
        with t.query_span(label="q") as root:
            with trace_mod.span("bounds") as sp:
                sp.set(candidates=7)
            with trace_mod.span("verify.round") as sp:
                sp.set(batch=3)
    assert [c.name for c in root.children] == ["bounds", "verify.round"]
    qid = root.attrs["query_id"]
    assert t.get_trace(qid) is root
    assert t.last_trace() is root
    assert t.spans_started == 3
    # ring-buffer bound
    t2 = Tracer(enabled=True, max_traces=2)
    with t2.activate():
        for _ in range(4):
            with t2.query_span():
                pass
    assert len(t2.trace_ids()) == 2


def test_trace_exports_round_trip():
    t = Tracer(enabled=True)
    with t.activate():
        with t.query_span(label="export") as root:
            with trace_mod.span("bounds") as sp:
                sp.set(candidates=np.int64(5), chi_bytes=np.int32(640))
    d = json.loads(json.dumps(root.to_dict()))
    assert d["name"] == "query" and d["children"][0]["name"] == "bounds"
    ch = json.loads(json.dumps(chrome_trace(root)))
    assert {e["name"] for e in ch["traceEvents"]} == {"query", "bounds"}
    assert all(e["ph"] == "X" for e in ch["traceEvents"])


# -- backend-invariant span structure ---------------------------------------


def _trace_structure(store, sql, rois, backend):
    plan = queries.parse(sql).plan
    t = Tracer(enabled=True)
    rep = explain_analyze(store, plan, provided_rois=rois, backend=backend,
                          verify_batch=5, tracer=t)
    return t.last_trace().structure(), rep


@pytest.mark.parametrize("sql", [CP_SQL, PAIR_SQL, AGG_SQL, FILTERED_SQL],
                         ids=["cp", "pair", "agg", "filtered_topk"])
def test_span_structure_identical_across_backends(db, sql):
    store, rois = db
    shapes = {}
    reports = {}
    for backend in BACKENDS:
        shapes[backend], reports[backend] = \
            _trace_structure(store, sql, rois, backend)
    assert shapes["device"] == shapes["host"]
    assert shapes["mesh"] == shapes["host"]
    # ...and the annotated per-operator counts agree too
    s0 = reports["host"]["tree"]["stats"]
    for backend in ("device", "mesh"):
        s = reports[backend]["tree"]["stats"]
        for key in ("candidates", "decided_by_bounds", "verified", "rounds"):
            assert s[key] == s0[key], (sql, backend, key)


# -- EXPLAIN [ANALYZE] -------------------------------------------------------


def test_explain_grammar_prefix():
    q = queries.parse("EXPLAIN ANALYZE " + CP_SQL)
    assert q.explain == "analyze" and q.kind == "topk"
    assert queries.parse("EXPLAIN " + CP_SQL).explain == "plan"
    assert queries.parse(CP_SQL).explain is None


def test_explain_plan_is_not_executed(db):
    store, _ = db
    io0 = store.io.bytes_read
    rep = queries.parse("EXPLAIN " + CP_SQL).run(store)
    assert rep["analyzed"] is False
    assert store.io.bytes_read == io0
    ops = [c["op"] for c in rep["tree"]["children"]]
    assert ops == ["CHIBounds", "Source"]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("sql", [CP_SQL, PAIR_SQL, FILTERED_SQL],
                         ids=["cp", "pair", "filtered_topk"])
def test_explain_analyze_operator_stats(db, sql, backend):
    store, rois = db
    plan = queries.parse(sql).plan
    rep = explain_analyze(store, plan, provided_rois=rois, backend=backend,
                          verify_batch=5)
    assert rep["analyzed"] is True and rep["backend"] == backend
    root = rep["tree"]
    stats = root["stats"]
    for key in ("candidates", "decided_by_bounds", "verified", "rounds",
                "bytes_loaded", "bytes_saved", "bound_time_s",
                "verify_time_s"):
        assert key in stats, key
    assert stats["candidates"] > 0
    # pure rankings decide every candidate by bounds or verification;
    # filtered rankings may retire predicate-rejected rows without either
    decided = stats["decided_by_bounds"] + stats["verified"]
    if "WHERE" in sql:
        assert 0 < decided <= stats["candidates"]
    else:
        assert decided == stats["candidates"]
    ops = {c["op"]: c for c in root["children"]}
    assert "Verify" in ops and "CHIBounds" in ops and "Source" in ops
    assert len(ops["Verify"]["rounds"]) == stats["rounds"]
    assert sum(r["bytes_loaded"] for r in ops["Verify"]["rounds"]) \
        == stats["bytes_loaded"]
    for row in ops["CHIBounds"]["exprs"]:
        assert row["candidates"] == stats["candidates"]
        assert row["chi_bytes"] > 0
    if "WHERE" in sql:
        leaves = ops["Filter"]["leaves"]
        assert leaves and all(
            leaf["accepted_by_bounds"] + leaf["rejected_by_bounds"]
            + leaf["undecided"] == stats["candidates"] for leaf in leaves)
    # the whole report is JSON (the HTTP layer serves it verbatim)
    json.loads(json.dumps(rep))
    # ...and matches the plain execution result
    result, _ = run_plan(store, plan, provided_rois=rois, verify_batch=5,
                         backend=backend)
    assert rep["n_results"] == len(result[0])


def test_explain_analyze_scalar_agg(db):
    store, rois = db
    plan = queries.parse(AGG_SQL).plan
    rep = explain_analyze(store, plan, provided_rois=rois)
    (value, _) = run_plan(store, plan, provided_rois=rois)
    assert rep["value"] == pytest.approx(value)
    assert rep["tree"]["op"] == "Aggregate"


def test_explain_analyze_restores_tracer_state(db):
    store, rois = db
    t = Tracer(enabled=False)
    explain_analyze(store, queries.parse(CP_SQL).plan, provided_rois=rois,
                    tracer=t)
    assert t.enabled is False          # forced on only for the query
    assert t.last_trace() is not None  # ...but the trace was retained


def test_explain_plan_render_smoke():
    rep = explain_plan(queries.parse(FILTERED_SQL).plan)
    assert "TopK" in rep["text"] and "Filter" in rep["text"]


# -- metrics registry --------------------------------------------------------


def _parse_prometheus(text):
    """Tiny exposition-format check: returns {metric: value} for plain
    samples and validates histogram bucket monotonicity."""
    samples = {}
    typed = {}
    for line in text.splitlines():
        if not line:
            continue
        if line.startswith("# TYPE "):
            _, _, name, mtype = line.split(" ")
            typed[name] = mtype
            continue
        if line.startswith("#"):
            continue
        name_labels, value = line.rsplit(" ", 1)
        float(value)                      # must parse
        samples[name_labels] = float(value)
    return samples, typed


def test_registry_counter_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("t_total", "help", ("kind",))
    c.labels(kind="a").inc()
    c.labels(kind="a").inc(2)
    g = reg.gauge("t_gauge", "help")
    g.set(4.5)
    h = reg.histogram("t_seconds", "help", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    samples, typed = _parse_prometheus(reg.prometheus_text())
    assert samples['t_total{kind="a"}'] == 3
    assert samples["t_gauge"] == 4.5
    assert samples['t_seconds_bucket{le="0.1"}'] == 1
    assert samples['t_seconds_bucket{le="1"}'] == 2
    assert samples['t_seconds_bucket{le="+Inf"}'] == 3
    assert samples["t_seconds_count"] == 3
    assert samples["t_seconds_sum"] == pytest.approx(5.55)
    assert typed == {"t_total": "counter", "t_gauge": "gauge",
                     "t_seconds": "histogram"}
    summ = h.labels().summary()
    assert summ["count"] == 3 and 0.0 < summ["p50"] <= 1.0
    # idempotent re-registration; type mismatch rejected
    assert reg.counter("t_total") is c
    with pytest.raises(ValueError):
        reg.gauge("t_total")


def test_registry_collectors_reflect_dataclasses():
    import dataclasses as dc

    from repro.obs.metrics import dataclass_sampler

    @dc.dataclass
    class S:
        reads: int = 3
        frac: float = 0.5
        name: str = "x"       # non-numeric: skipped

    reg = MetricsRegistry()
    reg.register_collector(dataclass_sampler("t_s", "counter", "h",
                                             lambda: S()))
    samples, _ = _parse_prometheus(reg.prometheus_text())
    assert samples == {"t_s_reads": 3.0, "t_s_frac": 0.5}


def test_kernel_launch_metrics_populated(db):
    store, rois = db
    queries.run(CP_SQL, store, provided_rois=rois)
    samples, _ = _parse_prometheus(REGISTRY.prometheus_text())
    launches = {k: v for k, v in samples.items()
                if k.startswith("masksearch_kernel_launches_total")}
    assert any(v > 0 for v in launches.values()), launches
    assert any(k.startswith("masksearch_backend_resolutions_total")
               for k in samples)
