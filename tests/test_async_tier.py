"""Async serving tier: admission control, fair queueing, shedding,
streaming, and cross-tenant fused verification (DESIGN.md §14)."""

import json
import socket
import threading
import urllib.error
import urllib.request

import pytest

from repro.service import MaskSearchService
from repro.service.admission import (AdmissionController, FairQueue,
                                     TokenBucket)
from repro.service.asyncserver import serve_in_thread
from repro.service.errors import OverloadedError, RateLimitedError
from repro.service.server import _synthetic_store

TOPK_SQL = ("SELECT mask_id FROM MasksDatabaseView ORDER BY "
            "CP(mask, full_img, (0.2, 0.6)) DESC LIMIT {n};")
FILTER_SQL = ("SELECT mask_id FROM MasksDatabaseView WHERE "
              "CP(mask, full_img, (0.3, 0.7)) > {t};")


# -- admission primitives ---------------------------------------------------

def test_token_bucket_grant_and_refill():
    b = TokenBucket(rate=1.0, burst=2.0)
    assert b.try_take(0.0) == 0.0
    assert b.try_take(0.0) == 0.0
    wait = b.try_take(0.0)                 # empty: full token outstanding
    assert wait == pytest.approx(1.0)
    assert b.try_take(0.5) > 0.0           # half refilled: still short
    assert b.try_take(1.6) == 0.0          # refilled past one token
    b2 = TokenBucket(rate=10.0, burst=1.0)
    b2.try_take(0.0)
    assert b2.try_take(100.0) == 0.0       # refill clamps at burst


def test_fair_queue_depth_bound_and_force():
    q = FairQueue(depth=2)
    assert q.push("a", 1) and q.push("a", 2)
    assert not q.push("a", 3)              # at depth: shed
    assert q.push("a", 3, force=True)      # continuation work is exempt
    assert q.depth_of("a") == 3 and len(q) == 3


def test_fair_queue_drr_is_weighted_fair():
    q = FairQueue(depth=100, weights={"heavy": 2.0})
    for i in range(30):
        q.push("heavy", f"h{i}")
        q.push("light", f"l{i}")
    batch = q.pop_batch(18)
    heavy = sum(1 for t, _ in batch if t == "heavy")
    light = len(batch) - heavy
    # weight 2:1 → heavy drains ~2x light, and light is never starved
    assert heavy == pytest.approx(2 * light, abs=2)
    assert light >= 5
    # draining the rest empties both queues exactly
    rest = q.pop_batch(10_000)
    assert len(rest) == 60 - len(batch) and len(q) == 0


def test_fair_queue_single_tenant_fifo_order():
    q = FairQueue(depth=10)
    for i in range(5):
        q.push("t", i)
    assert [item for _, item in q.pop_batch(5)] == [0, 1, 2, 3, 4]


def test_admission_controller_sheds_with_retry_after():
    clk = [0.0]
    ac = AdmissionController(rate=1.0, burst=2.0, depth=1,
                             clock=lambda: clk[0])
    ac.admit("t", "job1")
    with pytest.raises(OverloadedError) as over:   # queue (depth 1) full
        ac.admit("t", "job2")
    assert over.value.retry_after > 0
    assert ac.queue.pop_batch(10) == [("t", "job1")]
    ac.admit("t", "job2")                  # burst token 2 of 2
    assert ac.queue.pop_batch(10) == [("t", "job2")]
    with pytest.raises(RateLimitedError) as rate:  # bucket empty
        ac.admit("t", "job3")
    assert rate.value.retry_after == pytest.approx(1.0)
    clk[0] = 1.0                           # one token refilled
    ac.admit("t", "job3")
    assert ac.stats.admitted == 3
    assert ac.stats.shed_queue_full == 1
    assert ac.stats.shed_rate_limited == 1


# -- the HTTP tier ----------------------------------------------------------

@pytest.fixture(scope="module")
def tier():
    store, rois = _synthetic_store(60, 32)
    service = MaskSearchService(store, provided_rois=rois)
    handle = serve_in_thread(service, tenant_rate=10_000, tenant_burst=10_000)
    yield service, handle
    handle.stop()
    service.close()


def _raw(base, method, path, body=None, tenant=None):
    data = json.dumps(body).encode() if body is not None else None
    headers = {"Content-Type": "application/json"} if data else {}
    if tenant:
        headers["X-Tenant"] = tenant
    req = urllib.request.Request(base + path, data=data, method=method,
                                 headers=headers)
    try:
        with urllib.request.urlopen(req, timeout=30) as resp:
            return resp.status, json.loads(resp.read()), resp.headers
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read()), e.headers


def test_tier_serves_both_namespaces(tier):
    service, handle = tier
    base = handle.base_url
    code, out, _ = _raw(base, "POST", "/v1/query",
                        {"sql": TOPK_SQL.format(n=5)})
    assert code == 200 and [it for it in out["ids"]]
    code, legacy, _ = _raw(base, "POST", "/query",
                           {"sql": TOPK_SQL.format(n=5)})
    assert code == 200 and legacy["ids"] == out["ids"]
    code, out, _ = _raw(base, "GET", "/v1/healthz")
    assert (code, out) == (200, {"ok": True})
    code, out, _ = _raw(base, "GET", "/v1/stats")
    assert code == 200 and "epoch" in out
    code, err, _ = _raw(base, "POST", "/v1/nope", {})
    assert code == 404 and err["error"]["code"] == "not_found"
    code, err, _ = _raw(base, "POST", "/query", {})
    assert code == 400 and isinstance(err["error"], str)   # legacy flat


def test_quota_shed_is_clean_429_with_retry_after():
    store, rois = _synthetic_store(40, 32)
    service = MaskSearchService(store, provided_rois=rois)
    handle = serve_in_thread(service, tenant_rate=0.001, tenant_burst=1)
    try:
        base = handle.base_url
        sql = TOPK_SQL.format(n=3)
        code, _, _ = _raw(base, "POST", "/v1/query", {"sql": sql},
                          tenant="greedy")
        assert code == 200                 # burst token
        code, err, headers = _raw(base, "POST", "/v1/query", {"sql": sql},
                                  tenant="greedy")
        assert code == 429
        assert err["error"]["code"] == "rate_limited"
        assert err["error"]["retry_after"] > 0
        assert int(headers["Retry-After"]) >= 1
        # quota is per tenant: another tenant still gets through
        code, _, _ = _raw(base, "POST", "/v1/query", {"sql": sql},
                          tenant="patient")
        assert code == 200
        # mutations are charged through the same buckets
        code, err, _ = _raw(base, "POST", "/v1/delete",
                            {"mask_ids": [0]}, tenant="greedy")
        assert code == 429 and err["error"]["code"] == "rate_limited"
        assert handle.tier.admission.stats.shed_rate_limited >= 2
    finally:
        handle.stop()
        service.close()


def test_connection_limit_sheds_overloaded():
    store, rois = _synthetic_store(20, 32)
    service = MaskSearchService(store, provided_rois=rois)
    handle = serve_in_thread(service, max_connections=1)
    try:
        host, port = handle.tier.host, handle.tier.port
        squatter = socket.create_connection((host, port), timeout=10)
        try:
            deadline = 50
            while handle.tier.stats.connections_open < 1 and deadline:
                threading.Event().wait(0.01)
                deadline -= 1
            code, err, headers = _raw(handle.base_url, "GET", "/v1/healthz")
            assert code == 429
            assert err["error"]["code"] == "overloaded"
            assert "Retry-After" in headers
            assert handle.tier.stats.shed_connections >= 1
        finally:
            squatter.close()
    finally:
        handle.stop()
        service.close()


def test_streaming_session_matches_oneshot(tier):
    service, handle = tier
    from repro.service import ServiceClient
    c = ServiceClient(handle.base_url)
    oneshot = c.query(TOPK_SQL.format(n=12))
    pages = list(c.stream_query(TOPK_SQL.format(n=12), page_size=5))
    assert len(pages) >= 2
    assert pages[-1]["exhausted"] and pages[-1]["cursor"] is None
    streamed = [it["id"] for p in pages for it in p["items"]]
    # the stream pages through the full ranking; its prefix is the one-shot
    assert streamed[:len(oneshot["ids"])] == oneshot["ids"]
    assert handle.tier.stats.stream_pages >= len(pages)
    # streams drop their session on completion
    assert len(service.sessions) == 0


def test_cross_tenant_fusion_in_one_batch(tier):
    """The tentpole acceptance: queries from different tenants in one
    admitted batch merge into the same fused verification passes."""
    service, handle = tier
    before = service.scheduler.stats.cross_tenant_passes
    items = [{"op": "query", "sql": TOPK_SQL.format(n=3 + i),
              "tenant": f"tenant-{i % 3}"} for i in range(6)]
    results = service.execute_many(items)
    assert all(status == "ok" for status, _ in results)
    stats = service.scheduler.stats
    assert stats.cross_tenant_passes > before
    assert stats.cross_tenant_jobs >= 2
    assert stats.fused_tenant_width >= 3
    text = service.metrics_text()
    assert "masksearch_scheduler_cross_tenant_passes" in text
    assert "repro_async_tier_batches" in text
    assert "repro_admission_admitted" in text


def test_cross_tenant_fusion_over_http(tier):
    """Concurrent volleys from distinct tenants through the wire reach the
    batch dispatcher and fuse; retried volleys absorb scheduling jitter."""
    service, handle = tier
    base = handle.base_url
    before = service.scheduler.stats.cross_tenant_passes
    for attempt in range(8):
        barrier = threading.Barrier(6)
        codes: list = []

        def fire(i):
            barrier.wait()
            code, _, _ = _raw(base, "POST", "/v1/query",
                              {"sql": FILTER_SQL.format(t=120 + i)},
                              tenant=f"t{i}")
            codes.append(code)
        threads = [threading.Thread(target=fire, args=(i,))
                   for i in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert codes and all(c == 200 for c in codes)
        if service.scheduler.stats.cross_tenant_passes > before:
            break
    assert service.scheduler.stats.cross_tenant_passes > before, \
        "no cross-tenant fused pass in 8 concurrent volleys"
    assert handle.tier.stats.batches > 0


def test_execute_many_isolates_per_item_faults(tier):
    service, _ = tier
    results = service.execute_many([
        {"op": "query", "sql": TOPK_SQL.format(n=3)},
        {"op": "query", "sql": "SELEC nope"},
        {"op": "page", "session_id": "never-created"},
    ])
    assert results[0][0] == "ok"
    assert results[1][0] == "error" and isinstance(results[1][1], Exception)
    assert results[2][0] == "error"
    assert isinstance(results[2][1], KeyError)    # NotFoundError subclass


def test_tier_sessions_and_mutations(tier):
    service, handle = tier
    base = handle.base_url
    code, out, _ = _raw(base, "POST", "/v1/query",
                        {"sql": TOPK_SQL.format(n=6), "session": True,
                         "page_size": 2})
    assert code == 200 and out["cursor"].startswith("c1.")
    code, page, _ = _raw(base, "POST", "/v1/page", {"cursor": out["cursor"]})
    assert code == 200 and page["offset"] == 2
    size = service.store.cfg.height
    code, ing, _ = _raw(base, "POST", "/v1/ingest",
                        {"masks": [[[0.5] * size] * size],
                         "mask_ids": [8200], "image_ids": [8200]})
    assert code == 200 and ing["applied"]["appended"] == 1
    # append-only ingest keeps the pinned snapshot serveable: paging
    # continues (200) or — if the engine cannot serve it — is a clean
    # 409 stale_epoch envelope, never a 500
    code, out, _ = _raw(base, "POST", "/v1/page",
                        {"cursor": page["cursor"]})
    assert code in (200, 409)
    if code == 409:
        assert out["error"]["code"] == "stale_epoch"
    code, dele, _ = _raw(base, "POST", "/v1/delete", {"mask_ids": [8200]})
    assert code == 200 and dele["applied"]["deleted"] == 1
