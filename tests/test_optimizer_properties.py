"""Property-based tests (hypothesis) for the CHI pyramid + cost-based
filter optimizer (DESIGN.md §13):

  1. Tier nesting: every coarse-tier [lb, ub] contains the finer tier's
     interval and the exact CP value, for arbitrary masks/ROIs/ranges
     (including float32 bin-edge values the nextafter32 mapping handles).
  2. Optimizer-ordering equivalence: any conjunct order, with the ladder
     on or off, yields bit-identical filter verdicts.
  3. Pyramid round-trip: tier tables survive disk persistence and
     append/update/delete as exact tier slices of the finest table.

The deterministic seeded twins of these properties live in
tests/test_optimizer.py and always run; this sweep needs the dev extra.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (dev extra)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import chi, opt
from repro.core.chi import CHIConfig, tier_slice
from repro.core.exprs import CP, And, Cmp, MaskEvalContext
from repro.core.plan import LogicalPlan, run_plan
from repro.core.store import MASK_META_DTYPE, MaskStore


def _meta(b):
    meta = np.zeros(b, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(b)
    meta["image_id"] = np.arange(b)
    meta["mask_type"] = np.arange(b) % 3 + 1
    return meta


def _mask_batch(seed, b, h, w, style):
    rng = np.random.default_rng(seed)
    if style == 0:
        m = rng.random((b, h, w), dtype=np.float32)
    elif style == 1:
        m = (rng.random((b, h, w)) > 0.5).astype(np.float32) * 0.999
    else:               # constant bin-edge values, one ulp apart
        base = np.float32(rng.choice([0.25, 0.5, 0.75]))
        m = np.full((b, h, w), base, np.float32)
        m[::2] = np.nextafter(base, np.float32(1.0))
        m[1::4] = np.nextafter(base, np.float32(0.0))
    return m


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    style=st.integers(0, 2),
    hw=st.tuples(st.integers(16, 48), st.integers(16, 48)),
    roi=st.tuples(st.floats(0, 1), st.floats(0, 1), st.floats(0, 1),
                  st.floats(0, 1)),
    vrange=st.tuples(st.floats(0, 1), st.floats(0, 1)),
)
def test_tier_intervals_nest_and_contain_exact(seed, style, hw, roi, vrange):
    h, w = hw
    b = 6
    masks = _mask_batch(seed, b, h, w, style)
    cfg = CHIConfig(grid=16, num_bins=4, height=h, width=w)
    store = MaskStore.create_memory(masks, _meta(b), cfg)
    r0 = int(roi[0] * h); r1 = int(roi[2] * h)
    c0 = int(roi[1] * w); c1 = int(roi[3] * w)
    r0, r1 = min(r0, r1), max(r0, r1)
    c0, c1 = min(c0, c1), max(c0, c1)
    lv, uv = sorted(vrange)
    expr = CP((r0, c0, r1, c1), lv, uv)
    sub = masks[:, r0:r1, c0:c1]
    exact = ((sub >= lv) & (sub < uv)).sum(axis=(1, 2)).astype(np.float64)
    tiers = cfg.tier_grids
    prev = None
    for g in tiers:
        ctx = MaskEvalContext(store, np.arange(b))
        ctx.tier = None if g == tiers[-1] else g
        lb, ub = ctx.bounds(expr)
        assert np.all(lb <= exact) and np.all(exact <= ub), (g, lv, uv)
        if prev is not None:
            assert np.all(prev[0] <= lb) and np.all(ub <= prev[1]), g
        prev = (lb, ub)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    backend=st.sampled_from(["host", "device", "mesh"]),
    packed=st.booleans(),
    t_lo=st.floats(0.0, 0.4),
    t_hi=st.floats(0.5, 1.0),
    swap=st.booleans(),
)
def test_any_conjunct_order_bit_identical(seed, backend, packed,
                                          t_lo, t_hi, swap):
    b, h, w = 30, 32, 32
    rng = np.random.default_rng(seed)
    if packed:
        masks = (rng.random((b, h, w)) < 0.4).astype(np.float32)
        lo_rng, hi_rng = (0.5, 1.5), (0.5, 1.5)
    else:
        masks = rng.random((b, h, w), dtype=np.float32)
        masks[: b // 2] *= 0.3
        lo_rng, hi_rng = (0.2, float("inf")), (0.8, float("inf"))
    cfg = CHIConfig(grid=8, num_bins=8, height=h, width=w)
    store = MaskStore.create_memory(masks, _meta(b), cfg, packed=packed)
    area = h * w
    ca = Cmp(CP((0, 0, h, w), *lo_rng), ">", t_lo * area)
    cb = Cmp(CP((0, 0, h, w), *hi_rng), ">", t_hi * area)
    pred = And(cb, ca) if swap else And(ca, cb)
    plan = LogicalPlan(predicate=pred)
    with opt.configure(pyramid=False, reorder=False):
        ids_classic, st_c = run_plan(store, plan, backend=backend)
    with opt.configure(pyramid=True, reorder=True):
        ids_ladder, st_o = run_plan(store, plan, backend=backend)
    np.testing.assert_array_equal(ids_classic, ids_ladder)
    assert st_c.n_decided_by_bounds == st_o.n_decided_by_bounds


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    grid=st.sampled_from([8, 16]),
    n_append=st.integers(1, 4),
    n_delete=st.integers(0, 3),
)
def test_pyramid_roundtrip_disk_and_mutation(tmp_path_factory, seed, grid,
                                             n_append, n_delete):
    b, h, w = 10, 32, 32
    rng = np.random.default_rng(seed)
    root = tmp_path_factory.mktemp("pyr")
    cfg = CHIConfig(grid=grid, num_bins=4, height=h, width=w)
    store = MaskStore.create_disk(
        root / "db", rng.random((b, h, w)).astype(np.float32), _meta(b), cfg)
    store = MaskStore.open_disk(root / "db")

    def check(st_):
        finest = st_.chi_host()
        for g in st_.cfg.tier_grids[:-1]:
            np.testing.assert_array_equal(
                st_.chi_tier_host(g), tier_slice(finest, st_.cfg.grid, g))

    check(store)
    emeta = _meta(n_append)
    emeta["mask_id"] += b
    emeta["image_id"] += b
    store.append(rng.random((n_append, h, w)).astype(np.float32), emeta)
    check(store)
    store.update([0], rng.random((1, h, w)).astype(np.float32))
    check(store)
    if n_delete:
        store.delete(list(range(1, 1 + n_delete)))
        check(store)
