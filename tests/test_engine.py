"""Unit tests: engine semantics, SQL front-end, store tiers, multi-query."""

import numpy as np
import pytest

from repro.core import (CHIConfig, CP, MaskStore, engine, queries)
from repro.core.exprs import AggCP, BinOp, RoiArea
from repro.core.store import MASK_META_DTYPE
from repro.data.masks import object_boxes, saliency_masks

B, H, W = 60, 64, 64


@pytest.fixture(scope="module")
def db(tmp_path_factory):
    root = tmp_path_factory.mktemp("maskdb")
    rois = object_boxes(B, H, W, seed=2)
    masks, attacked = saliency_masks(B, H, W, seed=1, attacked_fraction=0.25,
                                     boxes=rois)
    meta = np.zeros(B, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(B) + 1000
    meta["image_id"] = np.arange(B) // 2
    meta["mask_type"] = np.arange(B) % 2 + 1
    cfg = CHIConfig(grid=8, num_bins=16, height=H, width=W)
    store = MaskStore.create_disk(str(root), masks, meta, cfg)
    return store, rois, masks, attacked


def test_disk_roundtrip_and_reopen(db, tmp_path):
    store, rois, masks, _ = db
    reopened = MaskStore.open_disk(store.root)
    assert len(reopened) == B
    got = reopened.load(np.array([0, 5, 17]))
    np.testing.assert_array_equal(got, masks[[0, 5, 17]])
    assert reopened.io.files_read == 3
    assert reopened.io.bytes_read == 3 * H * W * 4
    assert reopened.io.modeled_ebs_time_s > 0


def test_filter_verification_reduces_io(db):
    store, rois, _, _ = db
    expr = BinOp("/", CP("provided", 0.8, 1.0), RoiArea("provided"))
    store.io.reset()
    ids, stats = engine.filter_query(store, expr, "<", 0.02,
                                     provided_rois=rois)
    assert stats.n_verified < stats.n_candidates  # the index pruned loads
    # partial ROI-row loads: strictly fewer bytes than full-mask verification
    assert 0 < stats.bytes_loaded < stats.n_verified * H * W * 4
    ids_scan, _ = engine.filter_query(store, expr, "<", 0.02,
                                      provided_rois=rois, use_index=False)
    assert set(ids) == set(ids_scan)


def test_topk_early_termination(db):
    store, rois, _, _ = db
    expr = BinOp("/", CP("provided", 0.8, 1.0), RoiArea("provided"))
    ids, scores, stats = engine.topk_query(store, expr, 5, desc=False,
                                           provided_rois=rois, verify_batch=8)
    assert len(ids) == 5
    assert np.all(np.diff(scores) >= 0)           # ascending
    assert stats.n_verified < stats.n_candidates
    _, scores_s, _ = engine.topk_query(store, expr, 5, desc=False,
                                       provided_rois=rois, use_index=False)
    np.testing.assert_allclose(scores, scores_s)


def test_scenario2_dispersion_finds_attacked(db):
    store, rois, _, attacked = db
    # ~12 of 60 masks are attacked; the dispersion ranking should put
    # attacked masks strictly on top (perfect separation on this data).
    (ids, scores), stats = queries.run(
        "SELECT mask_id FROM MasksDatabaseView ORDER BY "
        "CP(mask, full_img, (0.2, 0.6)) DESC LIMIT 10;", store)
    pos = store.positions_of(ids)
    hit = attacked[pos].mean()
    assert hit >= 0.9, f"dispersion query precision {hit}"


def test_scenario3_iou_groups(db):
    store, rois, masks, _ = db
    (img_ids, scores), stats = queries.run(queries.SCENARIO3_IOU, store)
    assert len(img_ids) == 25
    assert np.all(scores[:-1] <= scores[1:] + 1e-12)
    # brute-force check the winner
    im = img_ids[0]
    members = masks[store.meta["image_id"] == im] > 0.8
    inter = np.logical_and.reduce(members).sum()
    union = np.logical_or.reduce(members).sum()
    want = inter / union if union else 0.0
    assert abs(scores[0] - want) < 1e-9


def test_mask_type_predicate(db):
    store, _, _, _ = db
    q = queries.parse("SELECT mask_id FROM MasksDatabaseView WHERE "
                      "mask_type IN (1) AND CP(mask, full_img, (0.0, 1.0)) "
                      f"> {H * W - 1};")
    ids, _ = q.run(store)
    types = store.meta["mask_type"][store.positions_of(ids)]
    assert np.all(types == 1)
    assert len(ids) == B // 2  # full-range CP == area for every mask


def test_multiquery_shares_loads(db):
    from repro.core.multiquery import run_workload
    store, rois, _, _ = db
    sqls = ["SELECT mask_id FROM MasksDatabaseView ORDER BY "
            f"CP(mask, full_img, ({lv}, {lv + 0.3})) DESC LIMIT 10;"
            for lv in (0.2, 0.25, 0.3)]
    store.io.reset()
    _, ws = run_workload(store, sqls, provided_rois=rois, share_loads=True)
    shared_files = ws.files_loaded
    store.io.reset()
    _, ws2 = run_workload(store, sqls, provided_rois=rois, share_loads=False)
    assert shared_files <= ws2.files_loaded


def test_sql_parser_errors():
    with pytest.raises(SyntaxError):
        queries.parse("SELECT nothing FROM MasksDatabaseView;")
    with pytest.raises(SyntaxError):
        queries.parse("SELECT mask_id FROM V WHERE CP(mask, roi) < 5;")
    q = queries.parse("SELECT mask_id FROM V WHERE "
                      "CP(mask, (1, 2, 30, 40), (0.5, 1.0)) >= 10;")
    assert q.op == ">=" and q.threshold == 10


def test_ragged_groups_record_dropped_masks():
    """Grouped evaluation needs rectangular (n_groups, size) blocks, so
    ragged image groups are truncated to the smallest group size.  That
    used to be silent data loss; it must now be surfaced in
    ExecStats.n_dropped_masks (indexed and full-scan paths alike)."""
    b, h, w = 11, 32, 32
    masks = saliency_masks(b, h, w, seed=6)[0]
    meta = np.zeros(b, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(b)
    # images of 3, 2, 2, and 4 masks → size 2, with 1 + 2 = 3 dropped
    meta["image_id"] = np.array([0, 0, 0, 1, 1, 2, 2, 3, 3, 3, 3])
    cfg = CHIConfig(grid=4, num_bins=8, height=h, width=w)
    store = MaskStore.create_memory(masks, meta, cfg)

    expr = AggCP("union", 0.8, None)
    _, _, stats = engine.topk_query(store, expr, 3, group_by_image=True)
    assert stats.n_dropped_masks == 3
    assert stats.n_candidates == 4                    # 4 image groups
    _, _, stats_scan = engine.topk_query(store, expr, 3,
                                         group_by_image=True,
                                         use_index=False)
    assert stats_scan.n_dropped_masks == 3

    # even groups drop nothing
    even = np.zeros(6, MASK_META_DTYPE)
    even["mask_id"] = np.arange(6)
    even["image_id"] = np.arange(6) // 2
    store2 = MaskStore.create_memory(masks[:6], even, cfg)
    _, _, stats2 = engine.topk_query(store2, expr, 2, group_by_image=True)
    assert stats2.n_dropped_masks == 0
    # and per-mask (ungrouped) runs never report drops
    ids, fstats = engine.filter_query(store, CP(None, 0.0, 1.0), ">", -1.0)
    assert fstats.n_dropped_masks == 0


def test_execution_detail_bounds_histogram(db):
    """The GUI's 'Execution Detail' bound distribution, as library data."""
    from repro.core.exprs import MaskEvalContext
    store, rois, _, _ = db
    ctx = MaskEvalContext(store, np.arange(len(store)), rois)
    lb, ub = ctx.bounds(CP("provided", 0.8, 1.0))
    assert np.all(lb <= ub)
    assert (ub - lb).max() > 0  # something undecided → histogram non-trivial
