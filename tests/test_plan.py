"""Plan-IR tests: predicate trees, filtered top-k, the unified Run API, and
the compat shim — including the PR's acceptance query.

Key invariants:
  * the ISSUE acceptance query parses, prunes through the predicate tree
    (``n_verified < n_candidates``), and matches the full-scan baseline;
  * randomized predicate-tree plans always agree with ``use_index=False``
    (and three-valued bounds decisions are individually sound);
  * legacy ``Query.run`` results are bit-identical to the engine functions
    they used to call directly;
  * SCALAR_AGG over an empty candidate set returns NaN, never raises.
"""

import numpy as np
import pytest

from repro.core import CHIConfig, MaskStore, engine, queries
from repro.core.engine import (FilteredTopKRun, FilterRun, MinMaxAggRun,
                               ScalarAggRun, TopKRun)
from repro.core.exprs import (And, BinOp, Cmp, CP, MaskEvalContext,
                              Not, Or, RoiArea, TypeIn)
from repro.core.plan import LogicalPlan, compile_plan, run_plan, \
    simplify_predicate
from repro.core.store import MASK_META_DTYPE
from repro.data.masks import object_boxes, saliency_masks

B, H, W = 48, 192, 192

ACCEPTANCE_SQL = (
    "SELECT mask_id FROM MasksDatabaseView "
    "WHERE CP(mask, roi, (0.8, 1.0)) > 500 "
    "AND NOT CP(mask, full_img, (0.2, 0.6)) < 100 "
    "ORDER BY CP(mask, full_img, (0.2, 0.6)) DESC LIMIT 25")


@pytest.fixture(scope="module")
def db():
    rois = object_boxes(B, H, W, seed=2)
    masks, _ = saliency_masks(B, H, W, seed=1, attacked_fraction=0.3,
                              boxes=rois, in_box_fraction=0.8)
    meta = np.zeros(B, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(B) + 1000
    meta["image_id"] = np.arange(B) // 2
    meta["mask_type"] = np.arange(B) % 2 + 1
    cfg = CHIConfig(grid=8, num_bins=16, height=H, width=W)
    return MaskStore.create_memory(masks, meta, cfg), rois


@pytest.fixture(scope="module")
def small_db():
    b, h, w = 24, 32, 32
    rois = object_boxes(b, h, w, seed=5)
    masks, _ = saliency_masks(b, h, w, seed=4, attacked_fraction=0.25,
                              boxes=rois)
    meta = np.zeros(b, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(b)
    meta["image_id"] = np.arange(b) // 2
    meta["mask_type"] = np.arange(b) % 3 + 1
    cfg = CHIConfig(grid=4, num_bins=8, height=h, width=w)
    return MaskStore.create_memory(masks, meta, cfg), rois


# -- the acceptance query ----------------------------------------------------


def test_acceptance_query_parses_prunes_and_matches_baseline(db):
    store, rois = db
    q = queries.parse(ACCEPTANCE_SQL)
    assert q.kind == "filtered_topk" and q.k == 25 and q.desc

    (ids, scores), stats = q.run(store, provided_rois=rois, verify_batch=8)
    assert len(ids) > 0
    assert stats.n_verified < stats.n_candidates  # predicate-tree pruning
    (ids0, scores0), stats0 = q.run(store, provided_rois=rois,
                                    use_index=False)
    assert list(ids) == list(ids0)
    np.testing.assert_allclose(scores, scores0)
    assert stats0.n_verified == stats0.n_candidates == B


def test_existing_flat_callers_unchanged(db):
    """`queries.run()` keeps its one-shot signature and result shapes."""
    store, rois = db
    (ids, scores), stats = queries.run(queries.SCENARIO2_TOPK, store)
    assert len(ids) == 25 and len(scores) == 25
    ids_f, stats_f = queries.run(
        "SELECT mask_id FROM V WHERE CP(mask, full_img, (0.2, 0.6)) "
        "> 300;", store)
    assert stats_f.n_candidates == B
    value, _ = queries.run(
        "SELECT SCALAR_AGG(AVG, CP(mask, full_img, (0.5, 1.0))) FROM V;",
        store)
    assert np.isfinite(value)


# -- compat shim: bit-identical to the engine functions ----------------------


def test_query_run_filter_bit_identical(db):
    store, rois = db
    sql = ("SELECT mask_id FROM MasksDatabaseView WHERE "
           "CP(mask, roi, (0.8, 1.0)) / AREA(roi) < 0.05;")
    q = queries.parse(sql)
    ids_q, _ = q.run(store, provided_rois=rois)
    expr = BinOp("/", CP("provided", 0.8, 1.0), RoiArea("provided"))
    ids_e, _ = engine.filter_query(store, expr, "<", 0.05,
                                   provided_rois=rois)
    np.testing.assert_array_equal(ids_q, ids_e)


def test_query_run_topk_bit_identical(db):
    store, rois = db
    q = queries.parse(queries.SCENARIO2_TOPK)
    (ids_q, scores_q), _ = q.run(store)
    ids_e, scores_e, _ = engine.topk_query(store, CP(None, 0.2, 0.6), 25,
                                           desc=True)
    np.testing.assert_array_equal(ids_q, ids_e)
    np.testing.assert_array_equal(scores_q, scores_e)


@pytest.mark.parametrize("agg", ["SUM", "AVG", "MIN", "MAX"])
def test_query_run_scalar_agg_bit_identical(db, agg):
    store, _ = db
    q = queries.parse(f"SELECT SCALAR_AGG({agg}, "
                      "CP(mask, full_img, (0.4, 0.8))) FROM V;")
    value_q, _ = q.run(store)
    value_e, _ = engine.scalar_agg(store, CP(None, 0.4, 0.8), agg)
    assert value_q == value_e


def test_query_field_mutation_seen_at_run_time(db):
    """Pre-redesign callers mutate the flat fields after parse() and re-run;
    the shim must rebuild the plan from the current fields."""
    store, _ = db
    q = queries.parse("SELECT mask_id FROM V WHERE "
                      "CP(mask, full_img, (0.2, 0.6)) > 100;")
    q.threshold = 2000.0
    ids, _ = q.run(store)
    ids_e, _ = engine.filter_query(store, CP(None, 0.2, 0.6), ">", 2000.0)
    np.testing.assert_array_equal(ids, ids_e)

    q2 = queries.parse(queries.SCENARIO2_TOPK)
    q2.k = 7
    q2.desc = False
    (ids2, scores2), _ = q2.run(store)
    ids_e2, scores_e2, _ = engine.topk_query(store, CP(None, 0.2, 0.6), 7,
                                             desc=False)
    np.testing.assert_array_equal(ids2, ids_e2)
    np.testing.assert_array_equal(scores2, scores_e2)


def test_query_run_forwards_positions(db):
    """Pre-redesign Query.run forwarded positions= to the engine."""
    store, _ = db
    rows = np.arange(0, B, 3)
    q = queries.parse("SELECT mask_id FROM V WHERE "
                      "CP(mask, full_img, (0.2, 0.6)) > 300;")
    ids, stats = q.run(store, positions=rows)
    ids_e, _ = engine.filter_query(store, CP(None, 0.2, 0.6), ">", 300.0,
                                   positions=rows)
    np.testing.assert_array_equal(ids, ids_e)
    assert stats.n_candidates == len(rows)


def test_programmatic_image_id_plan_groups(db):
    """select="image_id" implies grouping even without group_by_image —
    a hand-built plan must not silently return mask ids."""
    store, _ = db
    from repro.core.exprs import AggCP
    plan = LogicalPlan(select="image_id", order_by=AggCP("union", 0.8, None),
                       k=5)
    assert plan.grouped
    (ids, scores), _ = run_plan(store, plan)
    image_ids = set(int(x) for x in np.unique(store.meta["image_id"]))
    assert set(int(x) for x in ids) <= image_ids
    (ids0, scores0), _ = run_plan(store, plan, use_index=False)
    assert list(ids) == list(ids0)
    np.testing.assert_allclose(scores, scores0)


def test_hand_built_query_derives_plan(db):
    """Legacy code paths that construct Query records directly still run."""
    store, _ = db
    q = queries.Query(kind="topk", select="mask_id", expr=CP(None, 0.2, 0.6),
                      k=5, desc=True)
    (ids, scores), _ = q.run(store)
    ids_e, scores_e, _ = engine.topk_query(store, CP(None, 0.2, 0.6), 5)
    np.testing.assert_array_equal(ids, ids_e)


# -- the unified Run API -----------------------------------------------------


def test_compile_plan_kinds(db):
    store, rois = db
    pred = Cmp(CP(None, 0.2, 0.6), ">", 300.0)
    rank = CP(None, 0.5, 1.0)
    cases = [
        (LogicalPlan(predicate=pred), FilterRun),
        (LogicalPlan(order_by=rank, k=5), TopKRun),
        (LogicalPlan(predicate=pred, order_by=rank, k=5), FilteredTopKRun),
        (LogicalPlan(agg="AVG", agg_expr=rank), ScalarAggRun),
        (LogicalPlan(agg="MAX", agg_expr=rank), MinMaxAggRun),
    ]
    for plan, run_cls in cases:
        run = compile_plan(store, plan, provided_rois=rois)
        assert isinstance(run, run_cls), plan.kind
        # the uniform surface
        run.target(plan.k)
        while not run.finished():
            batch = run.take_batch()
            if not len(batch):
                break
            run.self_verify(batch)
        run.result()


def test_shared_expression_keeps_partial_row_loads(db):
    """Filtering and ranking by the *same* expression is one distinct CP
    term — the ROI-row partial-load optimization must stay enabled, and
    self-verification must evaluate the shared term once per batch."""
    store, rois = db
    expr = CP(None, 0.2, 0.6)
    run = FilteredTopKRun(store, Cmp(expr, ">", 100.0), expr, desc=True,
                          verify_batch=8)
    assert run.ctx.partial_rows
    run.ensure(5)
    ids, scores = run.result()
    ids0, scores0, _ = engine.filtered_topk_query(
        store, Cmp(expr, ">", 100.0), expr, 5, desc=True, use_index=False)
    assert list(ids) == list(ids0)
    np.testing.assert_allclose(scores, scores0)


def test_min_max_respects_grouping(small_db):
    """compile_plan must not drop group_by_image for MIN/MAX (it groups the
    candidate set exactly like SUM/AVG does)."""
    store, _ = small_db
    from repro.core.exprs import AggCP
    expr = AggCP("union", 0.8, None)
    plan = LogicalPlan(agg="MAX", agg_expr=expr, group_by_image=True)
    run = compile_plan(store, plan)
    assert run.n == len(np.unique(store.meta["image_id"]))
    run.ensure(1)
    value = run.result()
    value_e, _ = engine.scalar_agg(store, expr, "MAX")
    assert value == value_e


def test_filtered_topk_resumable_target_growth(db):
    """target(k) can grow: pagination over a filtered ranking equals the
    one-shot larger LIMIT (same contract TopKRun has)."""
    store, rois = db
    pred = Cmp(CP("provided", 0.8, 1.0), ">", 200.0)
    rank = CP(None, 0.2, 0.6)
    run = FilteredTopKRun(store, pred, rank, desc=True, provided_rois=rois,
                          verify_batch=4)
    run.ensure(3)
    first3 = run.result()
    run.ensure(9)
    ids9, scores9 = run.result()
    ids_one, scores_one, _ = engine.filtered_topk_query(
        store, pred, rank, 9, desc=True, provided_rois=rois)
    assert list(ids9) == list(ids_one)
    np.testing.assert_allclose(scores9, scores_one)
    assert list(first3[0]) == list(ids9[:3])


def test_simplify_predicate_extracts_type_conjuncts():
    cp = Cmp(CP(None, 0.0, 0.5), ">", 1.0)
    types, residue = simplify_predicate(
        And(TypeIn((1, 2)), And(cp, TypeIn((2, 3)))))
    assert types == (2,)
    assert residue == cp
    types2, residue2 = simplify_predicate(Or(TypeIn((1,)), cp))
    assert types2 is None and isinstance(residue2, Or)


def test_type_in_below_not_executes(small_db):
    store, _ = small_db
    q = queries.parse("SELECT mask_id FROM V WHERE "
                      "NOT mask_type IN (1) AND "
                      "CP(mask, full_img, (0.0, 1.0)) >= 0;")
    ids, _ = q.run(store)
    types = store.meta["mask_type"][store.positions_of(ids)]
    assert len(ids) > 0 and np.all(types != 1)


# -- empty candidate sets ----------------------------------------------------


@pytest.mark.parametrize("agg,want_nan", [("MIN", True), ("MAX", True),
                                          ("AVG", True), ("SUM", False)])
def test_scalar_agg_empty_candidate_set(small_db, agg, want_nan):
    store, _ = small_db
    value, stats = engine.scalar_agg(store, CP(None, 0.2, 0.6), agg,
                                     mask_types=(99,))
    assert stats.n_candidates == 0
    if want_nan:
        assert np.isnan(value)
    else:
        assert value == 0.0
    # and through SQL, where it used to IndexError
    q = queries.parse(f"SELECT SCALAR_AGG({agg}, "
                      "CP(mask, full_img, (0.2, 0.6))) FROM V "
                      "WHERE mask_type IN (99);")
    value_q, _ = q.run(store)
    assert (np.isnan(value_q) if want_nan else value_q == 0.0)


def test_filtered_topk_empty_result(small_db):
    store, rois = small_db
    q = queries.parse(
        "SELECT mask_id FROM V WHERE CP(mask, full_img, (0.0, 1.0)) < -1 "
        "ORDER BY CP(mask, full_img, (0.2, 0.6)) DESC LIMIT 5;")
    (ids, scores), stats = q.run(store, provided_rois=rois)
    assert len(ids) == 0 and len(scores) == 0


# -- randomized plan equivalence (numpy fallback; hypothesis version in
#    test_plan_properties.py) -------------------------------------------------


def _random_expr(rng):
    ranges = [(0.0, 0.3), (0.2, 0.6), (0.5, 1.0), (0.8, 1.0)]
    rois = [None, "provided", (4, 4, 28, 28)]
    lv, uv = ranges[rng.integers(len(ranges))]
    roi = rois[rng.integers(len(rois))]
    base = CP(roi, lv, uv)
    if rng.random() < 0.3:
        return BinOp("/", base, RoiArea(roi))
    if rng.random() < 0.3:
        lv2, uv2 = ranges[rng.integers(len(ranges))]
        op = "+-*"[rng.integers(3)]
        return BinOp(op, base, CP(rois[rng.integers(len(rois))], lv2, uv2))
    return base


def _random_pred(rng, depth=0):
    if depth < 2 and rng.random() < 0.55:
        kind = rng.integers(3)
        if kind == 0:
            return And(_random_pred(rng, depth + 1),
                       _random_pred(rng, depth + 1))
        if kind == 1:
            return Or(_random_pred(rng, depth + 1),
                      _random_pred(rng, depth + 1))
        return Not(_random_pred(rng, depth + 1))
    expr = _random_expr(rng)
    op = ("<", "<=", ">", ">=")[rng.integers(4)]
    threshold = float(rng.choice([0.0, 0.02, 10.0, 100.0, 400.0]))
    return Cmp(expr, op, threshold)


def test_random_predicate_plans_match_baseline(small_db):
    store, rois = small_db
    rng = np.random.default_rng(0)
    for trial in range(20):
        pred = _random_pred(rng)
        # three-valued bounds decisions are individually sound
        ctx = MaskEvalContext(store, np.arange(len(store)), rois,
                              partial_rows=False)
        accept, reject = pred.decide(ctx.bounds, ctx)
        exact = pred.exact(ctx, np.arange(len(store)))
        assert np.all(exact[accept]), f"trial {trial}: accept unsound"
        assert not np.any(exact[reject]), f"trial {trial}: reject unsound"
        assert not np.any(accept & reject), f"trial {trial}: contradiction"
        # full plan equals the full-scan baseline
        plan = LogicalPlan(predicate=pred)
        ids, _ = run_plan(store, plan, provided_rois=rois, verify_batch=5)
        ids0, _ = run_plan(store, plan, provided_rois=rois, use_index=False)
        assert sorted(ids) == sorted(ids0), f"trial {trial}"


def test_random_filtered_topk_plans_match_baseline(small_db):
    store, rois = small_db
    rng = np.random.default_rng(1)
    for trial in range(12):
        pred = _random_pred(rng)
        rank = _random_expr(rng)
        desc = bool(rng.integers(2))
        plan = LogicalPlan(predicate=pred, order_by=rank, k=5, desc=desc)
        (ids, scores), _ = run_plan(store, plan, provided_rois=rois,
                                    verify_batch=3)
        (ids0, scores0), _ = run_plan(store, plan, provided_rois=rois,
                                      use_index=False)
        assert list(ids) == list(ids0), f"trial {trial}"
        np.testing.assert_allclose(scores, scores0, err_msg=f"trial {trial}")
