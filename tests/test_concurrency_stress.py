"""Lock-instrumented concurrency stress: the HTTP service under
concurrent queries + ingest/delete, with REPRO_LOCK_CHECK=1 teeth
(repro/lockcheck.py), plus self-checks that the teeth actually bite."""

import json
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro import lockcheck

TOPK_SQL = ("SELECT mask_id FROM MasksDatabaseView ORDER BY "
            "CP(mask, full_img, (0.2, 0.6)) DESC LIMIT 5;")
FILTER_SQL = ("SELECT mask_id FROM MasksDatabaseView WHERE "
              "CP(mask, full_img, (0.3, 0.7)) > 150;")


@pytest.fixture()
def lock_checked(monkeypatch):
    monkeypatch.setenv("REPRO_LOCK_CHECK", "1")
    lockcheck.reset_diagnostics()
    yield
    lockcheck.reset_diagnostics()


def _service(n=80, size=32):
    from repro.service import MaskSearchService, make_server
    from repro.service.server import _synthetic_store
    store, rois = _synthetic_store(n, size)
    service = MaskSearchService(store, provided_rois=rois)
    httpd = make_server(service, "127.0.0.1", 0)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    host, port = httpd.server_address[:2]
    return service, httpd, store, f"http://{host}:{port}"


def _post(base, path, body):
    req = urllib.request.Request(
        base + path, data=json.dumps(body).encode(),
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=30) as resp:
            return resp.status
    except urllib.error.HTTPError as e:
        return e.code


def _get(base, path):
    try:
        with urllib.request.urlopen(base + path, timeout=30) as resp:
            return resp.status
    except urllib.error.HTTPError as e:
        return e.code


def test_http_stress_under_lock_check(lock_checked):
    """Concurrent queries, sessions, metrics scrapes, ingest, delete —
    every response must be a handled status (no 500s: a 500 here is a
    race or a LockCheckError escaping a handler)."""
    service, httpd, store, base = _service()
    size = store.cfg.height
    codes: list[tuple[str, int]] = []
    codes_lock = threading.Lock()
    stop = threading.Event()

    def note(tag, code):
        with codes_lock:
            codes.append((tag, code))

    def query_loop():
        for i in range(10):
            note("query", _post(base, "/query",
                                {"sql": TOPK_SQL if i % 2 else FILTER_SQL}))
            note("stats", _get(base, "/stats"))

    def session_loop():
        for _ in range(4):
            req = urllib.request.Request(
                base + "/query",
                data=json.dumps({"sql": TOPK_SQL, "session": True,
                                 "page_size": 2}).encode(),
                headers={"Content-Type": "application/json"})
            try:
                with urllib.request.urlopen(req, timeout=30) as resp:
                    sid = json.loads(resp.read()).get("session")
                note("session", resp.status)
            except urllib.error.HTTPError as e:
                note("session", e.code)
                continue
            if sid:
                # paging may 409 once a mutation outpaces the pinned epoch
                note("page", _get(base, f"/session/{sid}/page?k=2"))

    def ingest_loop():
        rng = np.random.default_rng(7)
        for i in range(6):
            masks = rng.random((2, size, size), np.float32)
            note("ingest", _post(base, "/ingest", {
                "masks": masks.tolist(),
                "mask_ids": [10_000 + 2 * i, 10_001 + 2 * i]}))

    def delete_loop():
        for i in range(4):
            note("delete", _post(base, "/delete", {"mask_ids": [i]}))

    def metrics_loop():
        while not stop.is_set():
            note("metrics", _get(base, "/metrics"))
            stop.wait(0.01)

    threads = ([threading.Thread(target=query_loop) for _ in range(4)]
               + [threading.Thread(target=session_loop) for _ in range(2)]
               + [threading.Thread(target=ingest_loop),
                  threading.Thread(target=delete_loop)])
    scraper = threading.Thread(target=metrics_loop)
    scraper.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
        assert not t.is_alive(), "stress worker hung"
    stop.set()
    scraper.join(timeout=30)
    httpd.shutdown()
    service.close()

    bad = [(tag, c) for tag, c in codes if c not in (200, 404, 409)]
    assert not bad, f"unhandled responses under stress: {bad}"
    assert sum(1 for tag, c in codes if tag == "query" and c == 200) > 0
    assert sum(1 for tag, c in codes if tag == "ingest" and c == 200) > 0
    # the instrumented locks saw real contention and stayed acyclic
    edges = lockcheck.order_edges()
    assert any("service" in k for k in edges), edges


def test_async_tier_stress_under_lock_check(lock_checked):
    """The async tier under concurrent multi-tenant load with the
    instrumented locks on: mixed queries, sessions, mutations, pages,
    scrapes.  Every response must be a handled status — 200, 404/409
    (expected session faults), or a *clean* 429 shed carrying the /v1
    envelope with retry_after.  A 500 is a race escaping a handler."""
    from repro.service import MaskSearchService
    from repro.service.asyncserver import serve_in_thread
    from repro.service.server import _synthetic_store
    store, rois = _synthetic_store(80, 32)
    service = MaskSearchService(store, provided_rois=rois)
    handle = serve_in_thread(service, tenant_rate=50.0, tenant_burst=20,
                             queue_depth=64, batch_max=16)
    base = handle.base_url
    size = store.cfg.height
    codes: list[tuple[str, int]] = []
    codes_lock = threading.Lock()
    shed_envelopes: list[dict] = []

    def note(tag, code):
        with codes_lock:
            codes.append((tag, code))

    def call(tag, method, path, body=None, tenant="default"):
        data = json.dumps(body).encode() if body is not None else None
        headers = {"Content-Type": "application/json"} if data else {}
        headers["X-Tenant"] = tenant
        req = urllib.request.Request(base + path, data=data, method=method,
                                     headers=headers)
        try:
            with urllib.request.urlopen(req, timeout=30) as resp:
                note(tag, resp.status)
                return json.loads(resp.read())
        except urllib.error.HTTPError as e:
            note(tag, e.code)
            if e.code == 429:
                env = json.loads(e.read())
                with codes_lock:
                    shed_envelopes.append(env)
            return None

    def query_loop(tenant):
        for i in range(8):
            call("query", "POST", "/v1/query",
                 {"sql": TOPK_SQL if i % 2 else FILTER_SQL}, tenant=tenant)

    def session_loop(tenant):
        for _ in range(3):
            out = call("session", "POST", "/v1/query",
                       {"sql": TOPK_SQL, "session": True, "page_size": 2},
                       tenant=tenant)
            if out and out.get("cursor"):
                call("page", "POST", "/v1/page", {"cursor": out["cursor"]},
                     tenant=tenant)

    def ingest_loop():
        rng = np.random.default_rng(11)
        for i in range(5):
            call("ingest", "POST", "/v1/ingest",
                 {"masks": rng.random((2, size, size), np.float32).tolist(),
                  "mask_ids": [20_000 + 2 * i, 20_001 + 2 * i]},
                 tenant="writer")

    def delete_loop():
        for i in range(4):
            call("delete", "POST", "/v1/delete", {"mask_ids": [i]},
                 tenant="writer")

    def greedy_loop():
        # hammers one tenant far past its bucket to force clean sheds
        for _ in range(60):
            call("greedy", "POST", "/v1/query", {"sql": TOPK_SQL},
                 tenant="greedy")

    def metrics_loop():
        for _ in range(10):
            call("metrics", "GET", "/v1/healthz")

    threads = ([threading.Thread(target=query_loop, args=(f"t{i}",))
                for i in range(4)]
               + [threading.Thread(target=session_loop, args=(f"t{i}",))
                  for i in range(2)]
               + [threading.Thread(target=ingest_loop),
                  threading.Thread(target=delete_loop),
                  threading.Thread(target=greedy_loop),
                  threading.Thread(target=metrics_loop)])
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120)
        assert not t.is_alive(), "async stress worker hung"
    handle.stop()
    service.close()

    bad = [(tag, c) for tag, c in codes if c not in (200, 404, 409, 429)]
    assert not bad, f"unhandled responses under async stress: {bad}"
    assert sum(1 for tag, c in codes if tag == "query" and c == 200) > 0
    assert sum(1 for tag, c in codes if tag == "ingest" and c == 200) > 0
    # the greedy tenant was shed with well-formed /v1 envelopes...
    assert shed_envelopes, "greedy tenant was never rate-limited"
    for env in shed_envelopes:
        err = env["error"]
        assert err["code"] in ("rate_limited", "overloaded")
        assert err["retry_after"] > 0
    # ...while polite tenants kept a healthy success rate (fair isolation)
    polite_ok = sum(1 for tag, c in codes if tag == "query" and c == 200)
    assert polite_ok >= 16, f"polite tenants starved: {polite_ok}"
    # the instrumented locks saw the executor pool's contention, acyclic
    edges = lockcheck.order_edges()
    assert any("service" in k for k in edges), edges


def test_lock_check_detects_injected_unlocked_write(lock_checked):
    """ISSUE 7 acceptance: a deliberately-injected unlocked write to the
    service's shared counter dict raises LockCheckError."""
    from repro.service import MaskSearchService
    from repro.service.server import _synthetic_store
    store, rois = _synthetic_store(16, 16)
    service = MaskSearchService(store, provided_rois=rois)
    with pytest.raises(lockcheck.LockCheckError):
        service._counts["total"] = 999      # write without the lock
    with service._lock:
        service._counts["total"] += 1       # locked write is fine
    service.close()


def test_release_by_non_owner_raises(lock_checked):
    lock = lockcheck.make_lock("t.nonowner")
    lock.acquire()
    err: list = []

    def rogue():
        try:
            lock.release()
        except lockcheck.LockCheckError as e:
            err.append(e)
    t = threading.Thread(target=rogue)
    t.start()
    t.join()
    assert err, "release from a non-owner thread must raise"
    lock.release()


def test_non_reentrant_self_deadlock_raises(lock_checked):
    lock = lockcheck.make_lock("t.selfdead")
    with lock:
        with pytest.raises(lockcheck.LockCheckError):
            lock.acquire()


def test_rlock_reentry_allowed(lock_checked):
    lock = lockcheck.make_rlock("t.reentrant")
    with lock:
        with lock:
            lock.assert_held()
    assert not lock.locked()


def test_lock_order_cycle_detected(lock_checked):
    a = lockcheck.make_lock("t.order.a")
    b = lockcheck.make_lock("t.order.b")
    with a:
        with b:       # records a -> b
            pass
    with b:
        with pytest.raises(lockcheck.LockCheckError):
            a.acquire()   # b -> a closes the cycle


def test_hold_time_recorded(lock_checked):
    lock = lockcheck.make_lock("t.hold")
    with lock:
        pass
    assert lockcheck.hold_stats().get("t.hold", -1.0) >= 0.0


def test_disabled_mode_is_plain_threading(monkeypatch):
    monkeypatch.delenv("REPRO_LOCK_CHECK", raising=False)
    lock = lockcheck.make_lock("t.plain")
    assert isinstance(lock, type(threading.Lock()))
    d = lockcheck.guard_dict({"x": 1}, lock)
    d["x"] = 2                 # plain dict: no guard, no error
    assert type(d) is dict
