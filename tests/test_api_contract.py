"""/v1 API contract tests (DESIGN.md §14).

Every ``/v1`` response is validated against a hand-rolled JSON schema
(no external jsonschema dependency — a ~40-line structural validator
covers the subset we need: type, required, properties, items, enum,
nullable).  The legacy unversioned routes are checked for *byte-level*
equivalence with their historical payloads: same service, same request,
the shim must return exactly what the pre-/v1 server returned, since
``/v1`` payloads are reshapings of those dicts.

Also here: the ``_guard`` regression — a genuine ``KeyError`` escaping a
handler must surface as 500 (a server fault), not masquerade as 404; only
``NotFoundError`` maps to 404.
"""

import json
import threading
import urllib.error
import urllib.request

import pytest

from repro.service import MaskSearchService, ServiceClient, ServiceError, \
    make_server
from repro.service.errors import NotFoundError, error_envelope
from repro.service.routes import decode_cursor, encode_cursor
from repro.service.server import _synthetic_store

TOPK_SQL = ("SELECT mask_id FROM MasksDatabaseView ORDER BY "
            "CP(mask, full_img, (0.2, 0.6)) DESC LIMIT 6;")
FILTER_SQL = ("SELECT mask_id FROM MasksDatabaseView WHERE "
              "CP(mask, full_img, (0.3, 0.7)) > 150;")
AGG_SQL = ("SELECT SCALAR_AGG(AVG, CP(mask, full_img, (0.3, 0.7))) "
           "FROM MasksDatabaseView;")


# -- minimal structural JSON-schema validator -------------------------------

_TYPES = {"object": dict, "array": list, "string": str, "boolean": bool,
          "number": (int, float), "integer": int, "null": type(None)}


def check_schema(value, schema, path="$"):
    """Assert ``value`` matches ``schema`` (subset of JSON Schema)."""
    if schema.get("nullable") and value is None:
        return
    t = schema.get("type")
    if t is not None:
        expected = _TYPES[t]
        ok = isinstance(value, expected)
        if t == "number" and isinstance(value, bool):
            ok = False
        if t == "integer" and isinstance(value, bool):
            ok = False
        assert ok, f"{path}: expected {t}, got {type(value).__name__} " \
                   f"({value!r})"
    if "enum" in schema:
        assert value in schema["enum"], \
            f"{path}: {value!r} not in {schema['enum']}"
    if isinstance(value, dict):
        for key in schema.get("required", ()):
            assert key in value, f"{path}: missing required key {key!r} " \
                                 f"(have {sorted(value)})"
        for key, sub in schema.get("properties", {}).items():
            if key in value:
                check_schema(value[key], sub, f"{path}.{key}")
    if isinstance(value, list) and "items" in schema:
        for i, item in enumerate(value):
            check_schema(item, schema["items"], f"{path}[{i}]")


ERROR_SCHEMA = {
    "type": "object", "required": ["error"],
    "properties": {"error": {
        "type": "object", "required": ["code", "type", "message"],
        "properties": {
            "code": {"type": "string",
                     "enum": ["bad_request", "bad_cursor", "not_found",
                              "stale_epoch", "rate_limited", "overloaded",
                              "internal"]},
            "type": {"type": "string"},
            "message": {"type": "string"},
            "retry_after": {"type": "number"},
        }}}}

PAGE_SCHEMA = {
    "type": "object",
    "required": ["kind", "items", "cursor", "exhausted", "offset",
                 "served", "total_candidates", "stats", "cache_hit"],
    "properties": {
        "kind": {"type": "string", "enum": ["topk", "filtered_topk"]},
        "items": {"type": "array",
                  "items": {"type": "object", "required": ["id", "score"],
                            "properties": {"id": {"type": "integer"},
                                           "score": {"type": "number"}}}},
        "cursor": {"type": "string", "nullable": True},
        "exhausted": {"type": "boolean"},
        "offset": {"type": "integer"},
        "served": {"type": "integer"},
        "total_candidates": {"type": "integer"},
        "cache_hit": {"type": "boolean"},
    }}

ONESHOT_SCHEMA = {
    "type": "object", "required": ["kind", "stats", "cache_hit"],
    "properties": {"kind": {"type": "string"},
                   "cache_hit": {"type": "boolean"}}}

INGEST_SCHEMA = {
    "type": "object",
    "required": ["epoch", "applied", "n_masks", "mask_ids",
                 "evicted_cache_entries"],
    "properties": {
        "epoch": {"type": "integer"},
        "applied": {"type": "object", "required": ["appended", "updated"],
                    "properties": {"appended": {"type": "integer"},
                                   "updated": {"type": "integer"}}},
        "n_masks": {"type": "integer"},
        "mask_ids": {"type": "array", "items": {"type": "integer"}},
        "evicted_cache_entries": {"type": "integer"},
    }}

DELETE_SCHEMA = {
    "type": "object",
    "required": ["epoch", "applied", "n_masks", "evicted_cache_entries"],
    "properties": {
        "epoch": {"type": "integer"},
        "applied": {"type": "object", "required": ["deleted"],
                    "properties": {"deleted": {"type": "integer"}}},
    }}


# -- fixtures ---------------------------------------------------------------

@pytest.fixture(scope="module")
def served():
    store, rois = _synthetic_store(60, 32)
    service = MaskSearchService(store, provided_rois=rois)
    httpd = make_server(service, "127.0.0.1", 0)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    host, port = httpd.server_address[:2]
    yield service, f"http://{host}:{port}"
    httpd.shutdown()
    service.close()


def _raw(base, method, path, body=None):
    """→ (status, parsed json) with no client-side shaping."""
    data = json.dumps(body).encode() if body is not None else None
    req = urllib.request.Request(
        base + path, data=data, method=method,
        headers={"Content-Type": "application/json"} if data else {})
    try:
        with urllib.request.urlopen(req, timeout=30) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


# -- cursor round-trip ------------------------------------------------------

def test_cursor_roundtrip():
    cur = encode_cursor("s17-abcd", 25)
    assert cur.startswith("c1.")
    assert "=" not in cur                       # unpadded
    assert decode_cursor(cur) == "s17-abcd"
    assert decode_cursor("bare-legacy-sid") == "bare-legacy-sid"
    from repro.service.errors import BadCursorError
    with pytest.raises(BadCursorError):
        decode_cursor("c1.!!!not-base64!!!")
    with pytest.raises(BadCursorError):
        decode_cursor("")


# -- /v1 response schemas ---------------------------------------------------

def test_v1_query_oneshot_schema(served):
    _, base = served
    code, out = _raw(base, "POST", "/v1/query", {"sql": TOPK_SQL})
    assert code == 200
    check_schema(out, ONESHOT_SCHEMA)
    assert "ids" in out and "scores" in out


def test_v1_session_paging_schema_and_cursor_chain(served):
    _, base = served
    code, out = _raw(base, "POST", "/v1/query",
                     {"sql": TOPK_SQL, "session": True, "page_size": 2})
    assert code == 200
    check_schema(out, PAGE_SCHEMA)
    assert out["cursor"] is not None and out["cursor"].startswith("c1.")
    seen = [it["id"] for it in out["items"]]
    cursor = out["cursor"]
    for _ in range(40):                      # page to exhaustion via cursors
        code, out = _raw(base, "POST", "/v1/page", {"cursor": cursor})
        assert code == 200
        check_schema(out, PAGE_SCHEMA)
        seen += [it["id"] for it in out["items"]]
        if out["exhausted"]:
            assert out["cursor"] is None     # terminal page: no cursor
            break
        cursor = out["cursor"]
    else:
        pytest.fail("session never exhausted")
    assert len(seen) == len(set(seen)), "pages overlapped"


def test_v1_workload_schema(served):
    _, base = served
    code, out = _raw(base, "POST", "/v1/workload",
                     {"sqls": [TOPK_SQL, FILTER_SQL, AGG_SQL]})
    assert code == 200
    check_schema(out, {"type": "object", "required": ["items"],
                       "properties": {"items": {"type": "array"}}})
    assert len(out["items"]) == 3
    for item in out["items"]:
        check_schema(item, ONESHOT_SCHEMA)


def test_v1_mutation_envelopes(served):
    service, base = served
    size = service.store.cfg.height
    masks = [[[0.5] * size] * size for _ in range(2)]
    code, out = _raw(base, "POST", "/v1/ingest",
                     {"masks": masks, "mask_ids": [7000, 7001],
                      "image_ids": [7000, 7001]})
    assert code == 200
    check_schema(out, INGEST_SCHEMA)
    assert out["applied"]["appended"] == 2
    code, out = _raw(base, "POST", "/v1/delete", {"mask_ids": [7000, 7001]})
    assert code == 200
    check_schema(out, DELETE_SCHEMA)
    assert out["applied"]["deleted"] == 2


def test_v1_error_envelopes(served):
    _, base = served
    # bad_request: missing sql
    code, out = _raw(base, "POST", "/v1/query", {})
    assert code == 400
    check_schema(out, ERROR_SCHEMA)
    assert out["error"]["code"] == "bad_request"
    # bad_request: SQL syntax error
    code, out = _raw(base, "POST", "/v1/query", {"sql": "SELEC nope"})
    assert code == 400
    check_schema(out, ERROR_SCHEMA)
    assert out["error"]["code"] == "bad_request"
    # bad_cursor
    code, out = _raw(base, "POST", "/v1/page", {"cursor": "c1.@@@"})
    assert code == 400
    check_schema(out, ERROR_SCHEMA)
    assert out["error"]["code"] == "bad_cursor"
    # not_found: unknown session (bare sid accepted, then 404)
    code, out = _raw(base, "POST", "/v1/page", {"cursor": "never-created"})
    assert code == 404
    check_schema(out, ERROR_SCHEMA)
    assert out["error"]["code"] == "not_found"
    # not_found: unknown route
    code, out = _raw(base, "POST", "/v1/nope", {})
    assert code == 404
    check_schema(out, ERROR_SCHEMA)


def test_v1_session_drop(served):
    _, base = served
    _, out = _raw(base, "POST", "/v1/query",
                  {"sql": TOPK_SQL, "session": True, "page_size": 2})
    code, dropped = _raw(base, "POST", "/v1/session/drop",
                         {"cursor": out["cursor"]})
    assert code == 200 and dropped == {"dropped": True}
    code, dropped = _raw(base, "POST", "/v1/session/drop",
                         {"cursor": out["cursor"]})
    assert dropped == {"dropped": False}     # idempotent, not an error


def test_v1_observability_routes(served):
    _, base = served
    assert _raw(base, "GET", "/v1/healthz")[1] == {"ok": True}
    code, stats = _raw(base, "GET", "/v1/stats")
    assert code == 200 and "epoch" in stats
    code, out = _raw(base, "POST", "/v1/query",
                     {"sql": "EXPLAIN ANALYZE " + TOPK_SQL})
    assert code == 200 and out.get("explain")
    code, trace = _raw(base, "GET", "/v1/trace/last")
    assert code == 200 and trace.get("name") == "query"


# -- legacy shim equivalence ------------------------------------------------

def test_legacy_routes_byte_identical_to_history(served):
    """The unversioned routes keep serving the historical payload shapes:
    every field the pre-/v1 server returned, with the same values (modulo
    per-query stats/ids), and none of the /v1 envelope keys."""
    _, base = served
    code, legacy = _raw(base, "POST", "/query", {"sql": TOPK_SQL})
    assert code == 200
    for key in ("kind", "ids", "scores", "stats", "cache_hit"):
        assert key in legacy
    assert "items" not in legacy and "applied" not in legacy

    code, legacy = _raw(base, "POST", "/query",
                        {"sql": TOPK_SQL, "session": True, "page_size": 3})
    assert code == 200
    for key in ("session", "page", "served", "exhausted"):
        assert key in legacy
    assert "cursor" not in legacy
    sid = legacy["session"]
    assert not sid.startswith("c1.")         # legacy route: bare sid
    code, page = _raw(base, "GET", f"/session/{sid}/page?k=3")
    assert code == 200 and page["page"]["offset"] == 3

    # /v1 serves the same content, reshaped
    code, v1 = _raw(base, "POST", "/v1/query",
                    {"sql": TOPK_SQL, "session": True, "page_size": 3})
    assert [it["id"] for it in v1["items"]] == legacy["page"]["ids"]
    assert [it["score"] for it in v1["items"]] == legacy["page"]["scores"]

    size = 32
    masks = [[[0.25] * size] * size]
    code, legacy = _raw(base, "POST", "/ingest",
                        {"masks": masks, "mask_ids": [7100],
                         "image_ids": [7100]})
    assert code == 200
    for key in ("epoch", "appended", "updated", "n_masks"):
        assert key in legacy
    assert "applied" not in legacy           # flat historical counters
    code, legacy = _raw(base, "POST", "/delete", {"mask_ids": [7100]})
    assert code == 200 and "deleted" in legacy and "applied" not in legacy

    # legacy errors keep the flat {"error": "<str>"} body
    code, err = _raw(base, "POST", "/query", {})
    assert code == 400 and isinstance(err["error"], str)


def test_client_speaks_v1_but_returns_legacy_shapes(served):
    _, base = served
    c = ServiceClient(base)
    r = c.query(TOPK_SQL, session=True, page_size=2)
    assert r["session"].startswith("c1.")    # cursor rides the session field
    r2 = c.next_page(r["session"])
    assert r2["page"]["offset"] == 2
    assert c.drop_session(r2["session"] or r["session"])["dropped"]
    with pytest.raises(ServiceError) as err:
        c.query("SELEC nope")
    assert err.value.code == 400             # int HTTP status (historical)
    assert err.value.error_code == "bad_request"
    assert err.value.error_type


# -- the _guard KeyError regression ----------------------------------------

def test_genuine_keyerror_is_500_not_404(served):
    """A bare KeyError escaping a handler is a server fault → 500 with an
    ``internal`` envelope; only NotFoundError maps to 404."""
    service, base = served
    original = service.next_page

    def boom(*a, **kw):
        raise KeyError("some internal dict key")
    service.next_page = boom
    try:
        code, out = _raw(base, "POST", "/v1/page",
                         {"cursor": "whatever-sid"})
        assert code == 500
        check_schema(out, ERROR_SCHEMA)
        assert out["error"]["code"] == "internal"
        assert out["error"]["type"] == "KeyError"
        # legacy route: same status, flat error body
        code, out = _raw(base, "GET", "/session/whatever-sid/page")
        assert code == 500 and isinstance(out["error"], str)
    finally:
        service.next_page = original


def test_notfounderror_maps_to_404():
    status, env, retry = error_envelope(NotFoundError("nope"))
    assert (status, env["error"]["code"]) == (404, "not_found")
    assert str(NotFoundError("bare message")) == "bare message"
    status, env, _ = error_envelope(KeyError("k"))
    assert (status, env["error"]["code"]) == (500, "internal")
