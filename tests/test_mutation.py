"""Mutable mask database (DESIGN.md §8): epoch-versioned append/update/
delete, incremental CHI maintenance, snapshot consistency for resumable
runs, and epoch-keyed cache invalidation across every cache tier
(planner result/bounds caches, sessions, the shared-load cache)."""

import threading

import numpy as np
import pytest

from repro.core import (CHIConfig, MaskStore, StaleRunError, build_chi_np)
from repro.core.engine import TopKRun
from repro.core.exprs import CP, Cmp
from repro.core.plan import LogicalPlan, run_plan
from repro.core.store import MASK_META_DTYPE
from repro.data.masks import object_boxes, saliency_masks
from repro.service import MaskSearchService
from repro.service.planner import LRUCache

B, H, W = 18, 32, 32
CFG = CHIConfig(grid=4, num_bins=8, height=H, width=W)

TOPK_SQL = ("SELECT mask_id FROM MasksDatabaseView ORDER BY "
            "CP(mask, full_img, (0.2, 0.6)) DESC LIMIT {k};")


def _data(n, seed=0, id_base=0):
    boxes = object_boxes(n, H, W, seed=seed + 1)
    masks, _ = saliency_masks(n, H, W, seed=seed, attacked_fraction=0.3,
                              boxes=boxes)
    meta = np.zeros(n, MASK_META_DTYPE)
    meta["mask_id"] = id_base + np.arange(n)
    meta["image_id"] = (id_base + np.arange(n)) // 2
    meta["mask_type"] = np.arange(n) % 3 + 1
    return np.asarray(masks, np.float32), meta


def _mk_memory(n=B, seed=0):
    masks, meta = _data(n, seed=seed)
    return MaskStore.create_memory(masks, meta, CFG), masks


# ---------------------------------------------------------------------------
# store-level mutation semantics
# ---------------------------------------------------------------------------


def test_append_indexes_only_the_delta():
    store, masks = _mk_memory()
    new_masks, new_meta = _data(6, seed=7, id_base=1000)
    chunks_before = len(store.chi_chunks)
    epoch = store.append(new_masks, new_meta)
    assert epoch == store.epoch == 1
    assert len(store) == B + 6
    # the delta landed as its own chunk; nothing existing was rebuilt
    assert len(store.chi_chunks) == chunks_before + 1
    assert len(store.chi_chunks[-1]) == 6
    all_masks = np.concatenate([masks, new_masks])
    np.testing.assert_array_equal(store.chi_host(),
                                  build_chi_np(all_masks, CFG))
    # duplicate / colliding ids refuse
    with pytest.raises(ValueError):
        store.append(new_masks[:1], new_meta[:1])


def test_update_patches_chi_rows_in_place():
    store, masks = _mk_memory()
    new = np.clip(masks[[2, 5, 11]] * 0.4 + 0.1, 0, 1)
    epoch = store.update([2, 5, 11], new)
    assert epoch == 1
    ref = masks.copy()
    ref[[2, 5, 11]] = new
    np.testing.assert_array_equal(store.chi_host(), build_chi_np(ref, CFG))
    np.testing.assert_array_equal(store.resident_masks()[[2, 5, 11]], new)
    with pytest.raises(KeyError):
        store.update([9999], new[:1])


def test_delete_compacts_and_keeps_ids_stable():
    store, masks = _mk_memory()
    epoch = store.delete([0, 7, 17])
    assert epoch == 1 and len(store) == B - 3
    keep = np.ones(B, bool)
    keep[[0, 7, 17]] = False
    np.testing.assert_array_equal(store.mask_ids, np.arange(B)[keep])
    np.testing.assert_array_equal(store.chi_host(),
                                  build_chi_np(masks[keep], CFG))
    # positions renumber; lookups by id still resolve
    assert store.positions_of([1])[0] == 0


def test_random_mutation_sequence_matches_rebuild():
    """After any interleaving of append/update/delete, the chunked CHI must
    equal a from-scratch build and queries must match a fresh store."""
    rng = np.random.default_rng(42)
    store, masks = _mk_memory()
    current = masks.copy()
    ids = list(range(B))
    next_id = 1000
    for step in range(8):
        op = rng.integers(3)
        if op == 0:                                        # append
            n = int(rng.integers(1, 4))
            add, meta = _data(n, seed=100 + step, id_base=next_id)
            next_id += n
            store.append(add, meta)
            current = np.concatenate([current, add])
            ids.extend(meta["mask_id"])
        elif op == 1 and len(ids):                          # update
            n = int(rng.integers(1, min(4, len(ids)) + 1))
            sel = rng.choice(len(ids), size=n, replace=False)
            upd_ids = [ids[i] for i in sel]
            new = np.clip(rng.random((n, H, W)).astype(np.float32), 0, 1)
            store.update(upd_ids, new)
            current[sel] = new
        elif len(ids) > 4:                                  # delete
            n = int(rng.integers(1, 3))
            sel = np.sort(rng.choice(len(ids), size=n, replace=False))[::-1]
            del_ids = [ids[i] for i in sel]
            store.delete(del_ids)
            keep = np.ones(len(ids), bool)
            keep[sel] = False
            current = current[keep]
            ids = [m for i, m in enumerate(ids) if keep[i]]
        np.testing.assert_array_equal(store.chi_host(),
                                      build_chi_np(current, CFG))
        np.testing.assert_array_equal(store.resident_masks(), current)
        # query equivalence against a freshly built store
        meta = np.zeros(len(ids), MASK_META_DTYPE)
        meta["mask_id"] = ids
        fresh = MaskStore.create_memory(current, meta, CFG)
        plan = LogicalPlan(order_by=CP(None, 0.2, 0.6),
                           k=min(5, max(len(ids), 1)))
        (got_ids, got_scores), _ = run_plan(store, plan)
        (ref_ids, ref_scores), _ = run_plan(fresh, plan)
        np.testing.assert_array_equal(got_ids, ref_ids)
        np.testing.assert_array_equal(got_scores, ref_scores)


# ---------------------------------------------------------------------------
# disk-tier persistence round-trips (satellite)
# ---------------------------------------------------------------------------


def test_disk_roundtrip_preserves_config_meta_chi_epoch(tmp_path):
    masks, meta = _data(10, seed=3)
    root = str(tmp_path / "db")
    store = MaskStore.create_disk(root, masks, meta, CFG)
    assert store.epoch == 0

    add_masks, add_meta = _data(4, seed=9, id_base=500)
    store.append(add_masks, add_meta)
    new = np.clip(masks[[1, 3]] * 0.2, 0, 1)
    store.update([1, 3], new)
    assert store.epoch == 2

    current = np.concatenate([masks, add_masks])
    current[[1, 3]] = new

    re = MaskStore.open_disk(root)
    assert re.epoch == 2
    assert re.cfg == CFG
    np.testing.assert_array_equal(re.meta, store.meta)
    assert len(re.chi_chunks) == len(store.chi_chunks)
    np.testing.assert_array_equal(re.chi_host(), build_chi_np(current, CFG))
    np.testing.assert_array_equal(re.load_all(), current)

    # delete compacts the chunk files and persists too
    re.delete([500, 501])
    re2 = MaskStore.open_disk(root)
    assert re2.epoch == 3
    assert len(re2.chi_chunks) == 1
    keep = np.ones(14, bool)
    keep[[10, 11]] = False
    np.testing.assert_array_equal(re2.chi_host(),
                                  build_chi_np(current[keep], CFG))
    np.testing.assert_array_equal(re2.load_all(), current[keep])


# ---------------------------------------------------------------------------
# snapshot consistency for resumable runs
# ---------------------------------------------------------------------------


def _partial_run(store, **kw):
    run = TopKRun(store, CP(None, 0.2, 0.6), verify_batch=2, **kw)
    run.target(6)
    batch = run.take_batch()
    if len(batch):
        run.self_verify(batch)
    return run


def test_memory_run_finishes_on_snapshot_after_update():
    store, masks = _mk_memory()
    reference = TopKRun(store, CP(None, 0.2, 0.6), verify_batch=2)
    reference.ensure(6)
    run = _partial_run(store)
    # rewrite bytes the run still needs — the run's pinned view must win
    store.update(list(range(B)),
                 np.clip(masks[::-1].copy() * 0.5, 0, 1))
    assert not run.fresh() and run.resumable()
    run.ensure(6)
    got, ref = run.result(), reference.result()
    np.testing.assert_array_equal(got[0], ref[0])
    np.testing.assert_array_equal(got[1], ref[1])


@pytest.mark.parametrize("backend", ["device", "mesh"])
def test_stale_run_on_refreshed_backend_raises(backend):
    store, masks = _mk_memory()
    run = _partial_run(store, backend=backend)
    store.update([0], np.clip(masks[:1] * 0.5, 0, 1))
    assert not run.resumable()
    with pytest.raises(StaleRunError):
        run.ensure(6)


def test_disk_run_staleness_tracks_dirty_ids(tmp_path):
    masks, meta = _data(12, seed=3)
    root = str(tmp_path / "db")
    store = MaskStore.create_disk(root, masks, meta, CFG)

    # run restricted to the first half; dirty the second half → untouched
    run = _partial_run(store, positions=np.arange(6))
    store.update([10, 11], np.clip(masks[[10, 11]] * 0.5, 0, 1))
    assert run.resumable()
    run.ensure(6)                                     # finishes cleanly

    # second run: dirty a mask still pending → clean StaleRunError
    run2 = _partial_run(store, positions=np.arange(6))
    rest = run2.pending[run2.cursor:]
    if not len(rest):
        pytest.skip("bounds decided everything; nothing pending")
    dirty_pos = int(run2.ctx.positions[rest[0]])
    dirty_id = int(store.meta["mask_id"][dirty_pos])
    store.update([dirty_id], np.clip(masks[[dirty_pos]] * 0.5, 0, 1))
    assert not run2.resumable()
    with pytest.raises(StaleRunError):
        run2.ensure(6)


# ---------------------------------------------------------------------------
# shared-load cache: invalidation, bound + eviction (satellite)
# ---------------------------------------------------------------------------


def test_shared_cache_invalidates_updated_positions():
    store, masks = _mk_memory()
    store.enable_cache()
    store.load(np.array([0, 1, 2]))
    new = np.clip(masks[[1]] * 0.25, 0, 1)
    store.update([1], new)
    assert store.cache_stats.invalidations == 1
    out = store.load(np.array([0, 1, 2]))
    np.testing.assert_array_equal(out[1], new[0])     # fresh bytes, not cache
    np.testing.assert_array_equal(out, store.resident_masks()[:3])


def test_shared_cache_capacity_bound_and_eviction():
    store, masks = _mk_memory()
    row_bytes = H * W * 4
    assert store.enable_cache(capacity_bytes=4 * row_bytes)
    store.load(np.arange(4))                          # fills the capacity
    store.load(np.arange(4, 8))                       # 4 misses → 4 evictions
    assert store.cache_stats.evictions == 4
    assert store._cache_used <= 4
    # correctness under eviction churn
    for lo in (0, 4, 2, 6):
        out = store.load(np.arange(lo, lo + 4))
        np.testing.assert_array_equal(out, masks[lo:lo + 4])


def test_shared_cache_remaps_across_append_and_delete():
    store, masks = _mk_memory()
    store.enable_cache()
    store.load(np.arange(6))
    add_masks, add_meta = _data(3, seed=5, id_base=700)
    store.append(add_masks, add_meta)
    assert len(store._cache_map) == len(store)
    out = store.load(np.array([B, B + 1]))            # the appended rows
    np.testing.assert_array_equal(out, add_masks[:2])

    hits_before = store.cache_stats.hits
    store.delete([0, 2])                              # renumber positions
    out = store.load(store.positions_of([1, 3, 4]))
    np.testing.assert_array_equal(out, masks[[1, 3, 4]])
    # surviving rows still count as hits — the bytes never re-read
    assert store.cache_stats.hits > hits_before


# ---------------------------------------------------------------------------
# service: no pre-epoch cache entry is ever served
# ---------------------------------------------------------------------------


def test_service_result_and_bounds_caches_roll_with_epoch():
    store, masks = _mk_memory()
    svc = MaskSearchService(store)
    sql = TOPK_SQL.format(k=5)
    out1 = svc.query(sql)
    assert svc.query(sql)["cache_hit"]

    # refined query hits the bounds cache within one epoch
    refined = ("SELECT mask_id FROM MasksDatabaseView WHERE "
               "CP(mask, full_img, (0.2, 0.6)) > {};")
    svc.query(refined.format(50))
    hits0 = svc.planner.bounds_cache.info.hits
    svc.query(refined.format(80))
    assert svc.planner.bounds_cache.info.hits == hits0 + 1

    # mutation: every pre-epoch entry becomes unreachable
    r = svc.ingest(np.clip(masks[:3][:, ::-1] * 0.7, 0, 1),
                   mask_ids=[0, 1, 2], on_conflict="update")
    assert r["updated"] == 3 and svc.store.epoch == 1
    info = svc.planner.bounds_cache.info
    hits1, misses1 = info.hits, info.misses
    svc.query(refined.format(90))                     # only epoch-0 entries
    assert info.hits == hits1 and info.misses == misses1 + 1
    out2 = svc.query(sql)
    assert not out2["cache_hit"]

    # the recomputed result matches a from-scratch database
    fresh = MaskStore.create_memory(store.resident_masks(),
                                    store.meta.copy(), CFG)
    ref = MaskSearchService(fresh).query(sql)
    assert out2["ids"] == ref["ids"] and out2["scores"] == ref["scores"]
    svc.close()


def test_session_pages_stay_on_pinned_epoch():
    store, masks = _mk_memory()
    svc = MaskSearchService(store)
    sql = TOPK_SQL.format(k=9)
    full = svc.query(sql)                              # pre-mutation truth
    page = svc.query(sql, session=True, page_size=3)
    sid = page["session"]
    got = list(page["page"]["ids"])
    svc.ingest(np.clip(masks[:4] * 0.1, 0, 1), mask_ids=[0, 1, 2, 3],
               on_conflict="update")
    for _ in range(2):
        nxt = svc.next_page(sid)
        got.extend(nxt["page"]["ids"])
    assert got == full["ids"]                          # snapshot-consistent

    # fused multi-session paging reports staleness per session instead of
    # silently mixing epochs (device-resident backends can't snapshot)
    out = svc.next_pages({sid: None})
    assert "page" in out[sid] or out[sid].get("stale")
    svc.close()


def test_failed_batch_is_not_dropped_on_stale_error(tmp_path):
    """A StaleRunError mid-batch must leave the batch pending: a retried
    ensure() raises again rather than finishing with the lost batch's
    candidates silently missing (regression: take_batch used to commit
    the cursor before verification succeeded)."""
    masks, meta = _data(12, seed=3)
    root = str(tmp_path / "db")
    store = MaskStore.create_disk(root, masks, meta, CFG)
    run = _partial_run(store)
    rest = run.pending[run.cursor:]
    if not len(rest):
        pytest.skip("bounds decided everything; nothing pending")
    dirty_pos = int(run.ctx.positions[rest[0]])
    store.update([int(store.meta["mask_id"][dirty_pos])],
                 np.clip(masks[[dirty_pos]] * 0.5, 0, 1))
    n_verified = run.stats.n_verified
    for _ in range(2):                                 # retries keep failing
        with pytest.raises(StaleRunError):
            run.ensure(6)
        assert run.stats.n_verified == n_verified
    assert not run.resumable()                         # never "finishes"


def test_append_capacity_survives_update_and_delete():
    """update/delete replace the mask buffer copy-on-write but keep its
    spare capacity, so the model-iteration loop (update → append → …)
    pays O(delta) appends, not an O(B) regrow each time."""
    store, masks = _mk_memory()
    add_masks, add_meta = _data(4, seed=6, id_base=400)
    store.append(add_masks, add_meta)                  # grows capacity ≥ 2B
    cap = len(store._masks_buf)
    assert cap > len(store)
    store.update([0, 1], np.clip(masks[:2] * 0.5, 0, 1))
    assert len(store._masks_buf) == cap                # capacity retained
    buf = store._masks_buf
    more_masks, more_meta = _data(3, seed=7, id_base=500)
    store.append(more_masks, more_meta)
    assert store._masks_buf is buf                     # no regrow needed
    store.delete([400, 401])
    assert len(store._masks_buf) == cap


def test_service_delete_reports_unique_count():
    store, _ = _mk_memory()
    svc = MaskSearchService(store)
    out = svc.delete([3, 3, 5])
    assert out["deleted"] == 2 and out["n_masks"] == B - 2
    svc.close()


def test_finished_device_session_pages_after_mutation():
    """A device-backend run with no verification work left is resumable
    after a mutation — its results are run-local (regression: the stale
    precheck used to reject it before checking finished())."""
    store, masks = _mk_memory()
    run = TopKRun(store, CP(None, 0.2, 0.6), verify_batch=len(store),
                  backend="device")
    run.ensure(6)                                     # everything verified
    svc_like_ids, _ = run.result()
    store.append(*_data(2, seed=8, id_base=900))
    assert not run.fresh() and run.resumable()
    run.ensure(6)                                     # no-op, no raise
    got_ids, _ = run.result()
    np.testing.assert_array_equal(got_ids, svc_like_ids)


def test_ingest_update_applies_supplied_metadata():
    """on_conflict='update' must apply caller-supplied meta fields to the
    existing rows (omitted fields keep their values) — a retrained
    model's masks re-ingest under a new model_id."""
    store, masks = _mk_memory()
    svc = MaskSearchService(store)
    before = store.meta[store.positions_of([1, 2])].copy()
    svc.ingest(np.clip(masks[[1, 2]] * 0.5, 0, 1), mask_ids=[1, 2],
               model_ids=7, on_conflict="update")
    after = store.meta[store.positions_of([1, 2])]
    assert list(after["model_id"]) == [7, 7]
    np.testing.assert_array_equal(after["image_id"], before["image_id"])
    np.testing.assert_array_equal(after["mask_type"], before["mask_type"])
    # bytes-only upsert leaves metadata untouched
    svc.ingest(np.clip(masks[[1]] * 0.25, 0, 1), mask_ids=[1],
               on_conflict="update")
    assert store.meta[store.positions_of([1])[0]]["model_id"] == 7
    svc.close()


def test_service_ingest_append_and_delete():
    store, _ = _mk_memory()
    svc = MaskSearchService(store)
    r = svc.ingest(np.zeros((2, H, W), np.float32), image_ids=[90, 90])
    assert r["appended"] == 2 and r["n_masks"] == B + 2
    assert r["mask_ids"] == [B, B + 1]                 # auto-assigned
    with pytest.raises(ValueError):
        svc.ingest(np.zeros((1, H, W)), mask_ids=[0])  # on_conflict=error
    d = svc.delete([B, B + 1])
    assert d["n_masks"] == B and d["epoch"] == 2
    assert svc.stats()["epoch"] == 2
    svc.close()


# ---------------------------------------------------------------------------
# planner LRU thread-safety (satellite)
# ---------------------------------------------------------------------------


def test_lru_cache_concurrent_access():
    cache = LRUCache(32)
    errors = []

    def worker(seed):
        rng = np.random.default_rng(seed)
        try:
            for _ in range(3000):
                k = f"k{int(rng.integers(100))}"
                if rng.random() < 0.5:
                    cache.put(k, rng.integers(1000))
                else:
                    cache.get(k)
        except Exception as e:                          # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(cache) <= 32
    assert cache.info.size == len(cache)


# ---------------------------------------------------------------------------
# bitpacked binary-mask tier: mutation + persistence (DESIGN.md §12)
# ---------------------------------------------------------------------------


def _binary_data(n, seed=0, id_base=0):
    masks, meta = _data(n, seed=seed, id_base=id_base)
    return (masks > 0.5).astype(np.float32), meta


def test_packed_mutation_sequence_matches_float_rebuild():
    """append/update/delete on a packed store: the chunked CHI always equals
    a from-scratch float build, the packed words always unpack to the
    current masks, and queries match a fresh float store bit-for-bit."""
    from repro.core.packing import unpack_masks

    masks, meta = _binary_data(B)
    store = MaskStore.create_memory(masks, meta, CFG, packed=True)
    assert store.packed and store.row_nbytes == H * ((W + 31) // 32) * 4
    current = masks.copy()
    ids = list(range(B))
    next_id = 1000
    rng = np.random.default_rng(7)
    for step in range(6):
        op = rng.integers(3)
        if op == 0:                                        # append
            add, ameta = _binary_data(2, seed=50 + step, id_base=next_id)
            next_id += 2
            store.append(add, ameta)
            current = np.concatenate([current, add])
            ids.extend(ameta["mask_id"])
        elif op == 1:                                      # update
            sel = rng.choice(len(ids), size=2, replace=False)
            new = (rng.random((2, H, W)) < 0.5).astype(np.float32)
            store.update([ids[i] for i in sel], new)
            current[sel] = new
        elif len(ids) > 4:                                 # delete
            sel = np.sort(rng.choice(len(ids), size=2, replace=False))[::-1]
            store.delete([ids[i] for i in sel])
            keep = np.ones(len(ids), bool)
            keep[sel] = False
            current = current[keep]
            ids = [m for i, m in enumerate(ids) if keep[i]]
        np.testing.assert_array_equal(store.chi_host(),
                                      build_chi_np(current, CFG))
        np.testing.assert_array_equal(
            unpack_masks(store.resident_masks(), W), current)
        fmeta = np.zeros(len(ids), MASK_META_DTYPE)
        fmeta["mask_id"] = ids
        fresh = MaskStore.create_memory(current, fmeta, CFG)
        plan = LogicalPlan(order_by=CP(None, 0.5, 1.5),
                           k=min(5, max(len(ids), 1)))
        (got_ids, got_scores), _ = run_plan(store, plan)
        (ref_ids, ref_scores), _ = run_plan(fresh, plan)
        np.testing.assert_array_equal(got_ids, ref_ids)
        np.testing.assert_array_equal(got_scores, ref_scores)
    # the binary contract survives mutation: grayscale bytes refuse
    with pytest.raises(ValueError, match="binary"):
        store.update([ids[0]], np.full((1, H, W), 0.5, np.float32))
    with pytest.raises(ValueError, match="binary"):
        store.append(np.full((1, H, W), 0.25, np.float32),
                     _binary_data(1, id_base=9000)[1])


def test_packed_disk_roundtrip_preserves_flag_and_words(tmp_path):
    from repro.core.packing import unpack_masks

    masks, meta = _binary_data(10, seed=3)
    root = str(tmp_path / "pdb")
    store = MaskStore.create_disk(root, masks, meta, CFG, packed=True)
    add_masks, add_meta = _binary_data(4, seed=9, id_base=500)
    store.append(add_masks, add_meta)
    new = (np.arange(H * W).reshape(H, W) % 3 == 0)[None].astype(np.float32)
    store.update([1], new)
    current = np.concatenate([masks, add_masks])
    current[1] = new[0]

    re = MaskStore.open_disk(root)
    assert re.packed and re.epoch == 2 and re.cfg == CFG
    assert re.row_nbytes == store.row_nbytes
    np.testing.assert_array_equal(unpack_masks(re.load_all(), W), current)
    np.testing.assert_array_equal(re.chi_host(), build_chi_np(current, CFG))
    # metered IO is packed bytes: one row load costs row_nbytes, not H*W*4
    io0 = re.io.bytes_read
    re.load(np.array([0]))
    assert re.io.bytes_read - io0 == re.row_nbytes < H * W * 4


def test_stale_run_error_surfaces_as_conflict():
    """A filter predicate whose residue needs rewritten disk bytes reports
    StaleRunError (never silently mixes epochs) through run_plan too."""
    store, masks = _mk_memory()
    run = _partial_run(store, backend="device")
    store.delete([0])
    with pytest.raises(StaleRunError):
        run.ensure(6)
    # but a fresh plan over the mutated store is fine on every backend
    plan = LogicalPlan(predicate=Cmp(CP(None, 0.2, 0.6), ">", 100.0))
    for backend in ("host", "device", "mesh"):
        run_plan(store, plan, backend=backend)
