"""Stats-accounting consistency (satellites of the observability PR).

Two families of invariants:

* **No drifted fields** — every stats dataclass's ``reset()`` restores every
  field to its default and ``as_dict()`` exposes every field.  Asserted by
  reflection over ``dataclasses.fields``, so a field added tomorrow cannot
  silently drift out of either method.
* **Exact byte attribution** — the sum of per-run ``bytes_loaded`` equals
  the store's metered ``io.bytes_read`` delta on both the one-shot path and
  the fused scheduler path (largest-remainder apportionment, no truncation
  drift), and cache-served bytes count once globally
  (``cache_stats.bytes_saved``) while being attributed per run as
  ``bytes_saved``.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import CHIConfig, MaskStore, queries
from repro.core.engine import ExecStats
from repro.core.plan import compile_plan, run_plan
from repro.core.store import MASK_META_DTYPE, CacheStats, IOStats
from repro.data.masks import object_boxes, saliency_masks
from repro.service.planner import CacheInfo
from repro.service.scheduler import FusedScheduler, SchedulerStats, _apportion

B, H, W = 30, 32, 32

STATS_CLASSES = [ExecStats, IOStats, CacheStats, SchedulerStats, CacheInfo]


@pytest.fixture()
def db():
    rois = object_boxes(B, H, W, seed=7)
    masks, _ = saliency_masks(B, H, W, seed=6, attacked_fraction=0.3,
                              boxes=rois)
    meta = np.zeros(B, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(B)
    meta["image_id"] = np.arange(B) // 2
    meta["mask_type"] = np.arange(B) % 2 + 1
    cfg = CHIConfig(grid=4, num_bins=8, height=H, width=W)
    return MaskStore.create_memory(masks, meta, cfg), rois


# -- reflection drift tests --------------------------------------------------


def _poke(obj):
    """Set every numeric field to a distinctive nonzero value."""
    for i, f in enumerate(dataclasses.fields(obj)):
        cur = getattr(obj, f.name)
        if isinstance(cur, bool) or not isinstance(cur, (int, float)):
            continue
        setattr(obj, f.name, type(cur)(i + 7))
    return obj


@pytest.mark.parametrize("cls", STATS_CLASSES,
                         ids=[c.__name__ for c in STATS_CLASSES])
def test_as_dict_exposes_every_field(cls):
    obj = _poke(cls())
    d = obj.as_dict()
    for f in dataclasses.fields(obj):
        assert f.name in d, f"{cls.__name__}.as_dict() drifted: {f.name}"
        assert d[f.name] == getattr(obj, f.name)


@pytest.mark.parametrize("cls", [c for c in STATS_CLASSES
                                 if hasattr(c, "reset")],
                         ids=[c.__name__ for c in STATS_CLASSES
                              if hasattr(c, "reset")])
def test_reset_restores_every_field(cls):
    obj = _poke(cls())
    obj.reset()
    fresh = cls()
    for f in dataclasses.fields(obj):
        assert getattr(obj, f.name) == getattr(fresh, f.name), \
            f"{cls.__name__}.reset() drifted: {f.name}"


def test_iostats_merge_covers_every_field():
    a, b = _poke(IOStats()), _poke(IOStats())
    want = {f.name: getattr(a, f.name) + getattr(b, f.name)
            for f in dataclasses.fields(a)}
    a.merge(b)
    for name, v in want.items():
        assert getattr(a, name) == v, f"IOStats.merge() drifted: {name}"


# -- exact apportionment -----------------------------------------------------


@pytest.mark.parametrize("total,weights", [
    (100, [1, 1, 1]),          # the old int(total*share) truncation case
    (7, [3, 2, 2]),
    (1, [5, 5]),
    (0, [1, 2]),
    (999983, [17, 3, 250, 1]),
    (10, [0, 0]),              # degenerate: no weight
])
def test_apportion_sums_exactly(total, weights):
    shares = _apportion(total, weights)
    assert len(shares) == len(weights)
    assert all(s >= 0 for s in shares)
    if sum(weights) > 0 and total > 0:
        assert sum(shares) == total
    else:
        assert shares == [0] * len(weights)


# -- byte cross-checks -------------------------------------------------------


def test_one_shot_bytes_match_store_meter(db):
    store, rois = db
    io0 = store.io.bytes_read
    _, stats = run_plan(store, queries.parse(
        "SELECT mask_id FROM V ORDER BY CP(mask, roi, (0.8, 1.0)) "
        "ASC LIMIT 10;").plan, provided_rois=rois, verify_batch=4)
    assert stats.bytes_loaded == store.io.bytes_read - io0
    assert stats.bytes_saved == 0      # no cache in play


def test_scheduler_bytes_partition_store_meter(db):
    """Fused rounds: per-run bytes_loaded must sum to exactly the metered
    delta, and per-run bytes_saved to exactly the cache's bytes_saved
    delta — cache-served bytes never double-count as loads."""
    store, rois = db
    sqls = [
        "SELECT mask_id FROM V ORDER BY CP(mask, roi, (0.8, 1.0)) "
        "ASC LIMIT 7;",
        "SELECT mask_id FROM V ORDER BY CP(mask, full_img, (0.2, 0.6)) "
        "DESC LIMIT 9;",
        "SELECT mask_id FROM V WHERE CP(mask, full_img, (0.5, 1.0)) > 10;",
    ]
    runs = [compile_plan(store, queries.parse(s).plan, provided_rois=rois,
                         verify_batch=4) for s in sqls]
    for run, s in zip(runs, sqls):
        run.target(queries.parse(s).plan.k)
    io0 = store.io.bytes_read
    saved0 = store.cache_stats.bytes_saved
    sched = FusedScheduler(store)
    sched.drive(runs)
    loaded = sum(r.stats.bytes_loaded for r in runs)
    saved = sum(r.stats.bytes_saved for r in runs)
    assert loaded == store.io.bytes_read - io0
    assert saved == store.cache_stats.bytes_saved - saved0
    assert sched.stats.fused_bytes_loaded <= loaded   # fused subset of total


def test_self_verify_attributes_cache_savings(db):
    """Two identical runs behind the shared-load cache: the second run's
    loads are served from cache — metered once globally, attributed to the
    run as bytes_saved."""
    store, rois = db
    plan = queries.parse("SELECT mask_id FROM V "
                         "ORDER BY CP(mask, roi, (0.8, 1.0)) ASC "
                         "LIMIT 10;").plan
    owns = store.enable_cache()
    try:
        _, first = run_plan(store, plan, provided_rois=rois, verify_batch=4)
        io0 = store.io.bytes_read
        _, second = run_plan(store, plan, provided_rois=rois, verify_batch=4)
        assert second.bytes_loaded == store.io.bytes_read - io0
        assert second.bytes_saved > 0
        # everything the second run touched was already cached
        assert second.bytes_loaded == 0
    finally:
        if owns:
            store.clear_cache()


def test_execstats_as_dict_reports_load_fraction():
    s = ExecStats(n_candidates=10, n_verified=4)
    d = s.as_dict()
    assert d["load_fraction"] == pytest.approx(0.4)
