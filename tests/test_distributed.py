"""Multi-device tests for the distributed query engine + sharded training.

The main pytest session must see 1 device (dry-run isolation), so these run
in subprocesses that set XLA_FLAGS=--xla_force_host_platform_device_count=8
before importing jax.
"""

import os
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(code: str, timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_distributed_query_engine():
    _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import chi, cp, distributed as dist
from repro.data.masks import saliency_masks

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
N, H, W = 64, 64, 64
cfg = chi.CHIConfig(grid=8, num_bins=8, height=H, width=W)
masks = saliency_masks(N, H, W, seed=3)[0]
tables = chi.build_chi_np(masks, cfg)
rois = np.tile([8, 8, 56, 56], (N, 1)).astype(np.int32)
eng = dist.DistributedEngine(mesh, cfg)
t_sh = jax.device_put(jnp.asarray(tables), dist.row_sharding(mesh, 4))
r_sh = jax.device_put(jnp.asarray(rois), dist.row_sharding(mesh, 2))
lv, uv, T = 0.5, 1.0, 200
accept, undecided, counts = eng.filter_bounds(t_sh, r_sh, lv, uv, "<", T)
exact = np.array([cp.cp_exact_np(m, rois[0], lv, uv) for m in masks])
acc, und = np.asarray(accept), np.asarray(undecided)
assert np.all(exact[acc] < T)
assert np.all(exact[~(acc | und)] >= T)
assert int(counts[1]) < N, "bounds must decide something on blobby masks"

vals, ids, tau, surv = eng.topk_candidates(t_sh, r_sh, lv, uv, k=5)
top5 = set(np.argsort(-exact, kind="stable")[:5])
assert top5.issubset(set(np.nonzero(np.asarray(surv))[0]))
assert np.asarray(surv).sum() < N, "top-k pruning must drop candidates"

m_sh = jax.device_put(jnp.asarray(masks), dist.row_sharding(mesh, 3))
got = np.asarray(eng.verify(m_sh, r_sh, lv, uv))
assert np.array_equal(got, exact)
print("DIST_ENGINE_OK", int(counts[1]), int(np.asarray(surv).sum()))
""")


def test_sharded_train_step_matches_single_device():
    _run("""
import numpy as np, jax, jax.numpy as jnp, dataclasses
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import load_smoke
from repro.models import build_model
from repro.launch import sharding as sh
from repro.launch.mesh import make_local_mesh
from repro.data.pipeline import SyntheticLMData
from repro.train.optimizer import OptConfig
from repro.train.train_loop import init_train_state, make_train_step

cfg = dataclasses.replace(load_smoke("granite_3_2b"), dtype="float32")
model = build_model(cfg)
opt_cfg = OptConfig(warmup_steps=0, total_steps=10)
params, axes, opt = init_train_state(model, jax.random.PRNGKey(0), opt_cfg)
data = SyntheticLMData(cfg, seq_len=16, global_batch=8)
batch = data.batch_at(0)

# single device reference
step = make_train_step(model, opt_cfg)
p_ref, _, m_ref = jax.jit(step)(params, opt, batch)

# 2x4 mesh with full sharding rules
mesh = make_local_mesh((2, 4), ("data", "model"))
sh.install_activation_rules(mesh)
shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
pshard = sh.param_sharding_tree(mesh, shapes, axes)
p_dev = jax.tree.map(jax.device_put, params, pshard)
o_dev = jax.device_put(opt)
b_dev = jax.tree.map(
    lambda x: jax.device_put(np.asarray(x), NamedSharding(mesh, P("data"))), batch)
step_sh = make_train_step(model, opt_cfg, param_shardings=pshard)
p_sh, _, m_sh = jax.jit(step_sh)(p_dev, o_dev, b_dev)
# losses agree to f32 roundoff; sharded reductions reorder float adds and
# Adam's normalization amplifies that for near-zero grads — so params match
# to ~1e-3, not bitwise (measured: loss delta 1.3e-5, param delta 5e-4).
assert abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 1e-3
err = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
          for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)))
assert err < 5e-3, f"sharded step diverges from single-device: {err}"
print("SHARDED_TRAIN_OK", float(m_sh["loss"]))
""")


def test_decode_with_seq_sharded_cache():
    _run("""
import numpy as np, jax, jax.numpy as jnp, dataclasses
from repro.configs import load_smoke
from repro.models import build_model
from repro.launch import sharding as sh
from repro.launch.mesh import make_local_mesh

cfg = dataclasses.replace(load_smoke("granite_3_2b"), dtype="float32")
model = build_model(cfg)
params, axes = model.init(jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)

cache = model.init_cache(2, 16)
logits_ref, cache_ref = model.prefill(params, {"tokens": tokens}, cache)
logits_ref2, _ = model.decode_step(params, cache_ref, tokens[:, -1:],
                                   jnp.int32(8))

mesh = make_local_mesh((1, 8), ("data", "model"))
sh.install_activation_rules(mesh)
cache_shapes = jax.eval_shape(lambda: model.init_cache(2, 16))
cshard = sh.cache_sharding_tree(mesh, cache_shapes)
cache_sh = jax.tree.map(lambda s, d: jax.device_put(jnp.zeros(s.shape, s.dtype), d),
                        cache_shapes, cshard)
logits_p, cache_sh = jax.jit(model.prefill)(params, {"tokens": tokens}, cache_sh)
logits_d, _ = jax.jit(model.decode_step)(params, cache_sh, tokens[:, -1:],
                                         jnp.int32(8))
np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_ref),
                           rtol=1e-4, atol=1e-4)
np.testing.assert_allclose(np.asarray(logits_d), np.asarray(logits_ref2),
                           rtol=1e-4, atol=1e-4)
print("SP_DECODE_OK")
""")
