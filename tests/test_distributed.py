"""Multi-device tests for the distributed query engine + sharded training.

The main pytest session must see 1 device (dry-run isolation), so these run
in subprocesses that set XLA_FLAGS=--xla_force_host_platform_device_count=8
before importing jax.
"""

import os
import subprocess
import sys


SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(code: str, timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_distributed_query_engine():
    _run("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import chi, cp, distributed as dist
from repro.data.masks import saliency_masks

mesh = dist.make_mesh((2, 4), ("data", "model"))
N, H, W = 64, 64, 64
cfg = chi.CHIConfig(grid=8, num_bins=8, height=H, width=W)
masks = saliency_masks(N, H, W, seed=3)[0]
tables = chi.build_chi_np(masks, cfg)
rois = np.tile([8, 8, 56, 56], (N, 1)).astype(np.int32)
eng = dist.DistributedEngine(mesh, cfg)
t_sh = jax.device_put(jnp.asarray(tables), dist.row_sharding(mesh, 4))
r_sh = jax.device_put(jnp.asarray(rois), dist.row_sharding(mesh, 2))
lv, uv, T = 0.5, 1.0, 200
accept, undecided, counts = eng.filter_bounds(t_sh, r_sh, lv, uv, "<", T)
exact = np.array([cp.cp_exact_np(m, rois[0], lv, uv) for m in masks])
acc, und = np.asarray(accept), np.asarray(undecided)
assert np.all(exact[acc] < T)
assert np.all(exact[~(acc | und)] >= T)
assert int(counts[1]) < N, "bounds must decide something on blobby masks"

vals, ids, tau, surv = eng.topk_candidates(t_sh, r_sh, lv, uv, k=5)
top5 = set(np.argsort(-exact, kind="stable")[:5])
assert top5.issubset(set(np.nonzero(np.asarray(surv))[0]))
assert np.asarray(surv).sum() < N, "top-k pruning must drop candidates"

m_sh = jax.device_put(jnp.asarray(masks), dist.row_sharding(mesh, 3))
got = np.asarray(eng.verify(m_sh, r_sh, lv, uv))
assert np.array_equal(got, exact)
print("DIST_ENGINE_OK", int(counts[1]), int(np.asarray(surv).sum()))
""")


def test_mesh_backend_multi_device_matches_host():
    """run_plan(backend="mesh") over a real 8-device mesh returns the host
    backend's exact ids/scores and n_verified — including a candidate count
    that does NOT divide the device count (exercises the padding path)."""
    _run("""
import numpy as np, jax
from repro.core import CHIConfig, MaskStore
from repro.core.backend import MeshBackend
from repro.core.distributed import make_mesh
from repro.core.exprs import AggCP, BinOp, Cmp, CP, RoiArea
from repro.core.plan import LogicalPlan, run_plan
from repro.core.store import MASK_META_DTYPE
from repro.data.masks import object_boxes, saliency_masks

B, H, W = 52, 64, 64          # 52 % 8 != 0 -> padding exercised
rois = object_boxes(B, H, W, seed=2)
masks, _ = saliency_masks(B, H, W, seed=1, attacked_fraction=0.25, boxes=rois)
meta = np.zeros(B, MASK_META_DTYPE)
meta["mask_id"] = np.arange(B) + 100
meta["image_id"] = np.arange(B) // 2
meta["mask_type"] = np.arange(B) % 2 + 1
cfg = CHIConfig(grid=8, num_bins=8, height=H, width=W)
store = MaskStore.create_memory(masks, meta, cfg)
be = MeshBackend(store, make_mesh((8,), ("data",)))

plans = [
    LogicalPlan(predicate=Cmp(CP(None, 0.5, 1.0), ">", 500.0)),
    LogicalPlan(order_by=CP(None, 0.2, 0.6), k=7),
    LogicalPlan(predicate=Cmp(CP("provided", 0.8, 1.0), ">", 50.0),
                order_by=BinOp("/", CP(None, 0.2, 0.6), RoiArea(None)),
                k=5, desc=False),
    LogicalPlan(agg="MAX", agg_expr=CP(None, 0.4, 0.8)),
    LogicalPlan(select="image_id", order_by=AggCP("union", 0.8, None), k=5),
]
for plan in plans:
    got, st = run_plan(store, plan, provided_rois=rois, verify_batch=8,
                       backend=be)
    want, st0 = run_plan(store, plan, provided_rois=rois, verify_batch=8,
                         backend="host")
    if isinstance(want, tuple):
        assert list(got[0]) == list(want[0]), plan.kind
        np.testing.assert_allclose(got[1], want[1])
    elif isinstance(want, float):
        assert got == want, plan.kind
    else:
        assert list(got) == list(want), plan.kind
    assert st.n_verified == st0.n_verified, plan.kind
print("MESH_BACKEND_OK")
""")


def test_sharded_train_step_matches_single_device():
    _run("""
import numpy as np, jax, jax.numpy as jnp, dataclasses
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import load_smoke
from repro.models import build_model
from repro.launch import sharding as sh
from repro.launch.mesh import make_local_mesh
from repro.data.pipeline import SyntheticLMData
from repro.train.optimizer import OptConfig
from repro.train.train_loop import init_train_state, make_train_step

cfg = dataclasses.replace(load_smoke("granite_3_2b"), dtype="float32")
model = build_model(cfg)
opt_cfg = OptConfig(warmup_steps=0, total_steps=10)
params, axes, opt = init_train_state(model, jax.random.PRNGKey(0), opt_cfg)
data = SyntheticLMData(cfg, seq_len=16, global_batch=8)
batch = data.batch_at(0)

# single device reference
step = make_train_step(model, opt_cfg)
p_ref, _, m_ref = jax.jit(step)(params, opt, batch)

# 2x4 mesh with full sharding rules
mesh = make_local_mesh((2, 4), ("data", "model"))
sh.install_activation_rules(mesh)
shapes = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
pshard = sh.param_sharding_tree(mesh, shapes, axes)
p_dev = jax.tree.map(jax.device_put, params, pshard)
o_dev = jax.device_put(opt)
b_dev = jax.tree.map(
    lambda x: jax.device_put(np.asarray(x), NamedSharding(mesh, P("data"))), batch)
step_sh = make_train_step(model, opt_cfg, param_shardings=pshard)
p_sh, _, m_sh = jax.jit(step_sh)(p_dev, o_dev, b_dev)
# losses agree to f32 roundoff; sharded reductions reorder float adds and
# Adam's normalization amplifies that for near-zero grads — so params match
# to ~1e-3, not bitwise (measured: loss delta 1.3e-5, param delta 5e-4).
assert abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 1e-3
err = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
          for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)))
assert err < 5e-3, f"sharded step diverges from single-device: {err}"
print("SHARDED_TRAIN_OK", float(m_sh["loss"]))
""")


def test_decode_with_seq_sharded_cache():
    _run("""
import numpy as np, jax, jax.numpy as jnp, dataclasses
from repro.configs import load_smoke
from repro.models import build_model
from repro.launch import sharding as sh
from repro.launch.mesh import make_local_mesh

cfg = dataclasses.replace(load_smoke("granite_3_2b"), dtype="float32")
model = build_model(cfg)
params, axes = model.init(jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)

cache = model.init_cache(2, 16)
logits_ref, cache_ref = model.prefill(params, {"tokens": tokens}, cache)
logits_ref2, _ = model.decode_step(params, cache_ref, tokens[:, -1:],
                                   jnp.int32(8))

mesh = make_local_mesh((1, 8), ("data", "model"))
sh.install_activation_rules(mesh)
cache_shapes = jax.eval_shape(lambda: model.init_cache(2, 16))
cshard = sh.cache_sharding_tree(mesh, cache_shapes)
cache_sh = jax.tree.map(lambda s, d: jax.device_put(jnp.zeros(s.shape, s.dtype), d),
                        cache_shapes, cshard)
logits_p, cache_sh = jax.jit(model.prefill)(params, {"tokens": tokens}, cache_sh)
logits_d, _ = jax.jit(model.decode_step)(params, cache_sh, tokens[:, -1:],
                                         jnp.int32(8))
np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_ref),
                           rtol=1e-4, atol=1e-4)
np.testing.assert_allclose(np.asarray(logits_d), np.asarray(logits_ref2),
                           rtol=1e-4, atol=1e-4)
print("SP_DECODE_OK")
""")
