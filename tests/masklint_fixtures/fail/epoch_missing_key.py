"""MUST TRIGGER epoch-discipline: key constructions without an epoch."""


def lookup(planner, plan, roi_sig, backend):
    payload = planner.cached_result(plan, roi_sig, backend)  # no epoch
    if payload is None:
        planner.store_result(plan, roi_sig, {"ids": []}, backend)
    return payload
