"""MUST TRIGGER stats-drift: hand-listed reset/merge and an as_dict
that omits a field — all three drift when a field is added."""
import dataclasses


@dataclasses.dataclass
class ScanStats:
    rows: int = 0
    bytes_read: int = 0

    def reset(self):
        self.rows = 0
        self.bytes_read = 0

    def merge(self, other):
        self.rows += other.rows
        self.bytes_read += other.bytes_read

    def as_dict(self):
        return {"rows": self.rows}
