"""MUST TRIGGER epoch-snapshot: reaching around the snapshot into
private store state."""


def plan_loads(store):
    if store._cache_map is not None:  # private reach-around
        return "cached"
    return "direct"
