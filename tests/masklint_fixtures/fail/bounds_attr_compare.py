"""MUST TRIGGER bounds-soundness: attribute-carried bounds compared
directly."""


def prune(candidates, tau):
    return [c for c in candidates if c.cp_ub >= tau]  # raw ub compare
