"""MUST TRIGGER epoch-discipline: a hardcoded epoch literal pins the
cache to one store generation forever."""
from repro.service.planner import bounds_key, result_key


def keys(expr, plan, roi_sig):
    rk = result_key(plan, roi_sig, "host", 0)            # literal epoch
    bk = bounds_key(expr, plan, roi_sig, "host", epoch=7)  # literal epoch
    return rk, bk
