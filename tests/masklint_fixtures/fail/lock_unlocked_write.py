"""MUST TRIGGER lock-discipline: unlocked write in a lock-owning class."""
import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0

    def bump(self):
        self.value += 1  # write outside `with self._lock`
