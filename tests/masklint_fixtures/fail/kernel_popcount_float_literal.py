"""MUST TRIGGER popcount-no-float: range semantics (float threshold
compare) routed into the popcount kernel body instead of the wrapper's
int32 flags."""
import jax.numpy as jnp


def _bad_range_popcount_kernel(f_ref, mask_ref, out_ref):
    ones = jnp.sum(mask_ref[0] & jnp.uint32(1))
    out_ref[0] += jnp.where(f_ref[0] > 0.5, ones, 0)   # float literal
