"""MUST TRIGGER kernel-constraints: Python control flow on traced
values inside the kernel body."""


def gate_kernel(x_ref, o_ref):
    if x_ref[0, 0] > 0:          # traced value in Python `if`
        o_ref[...] = x_ref[...]
    while x_ref[0, 0] > 0:       # and a traced `while`
        break
