"""MUST TRIGGER bounds-edge: ad-hoc threshold-to-bin mapping over CHI
edges (drops the nextafter32 strict-threshold bump)."""
import numpy as np


def bin_of(cfg, threshold):
    return int(np.searchsorted(cfg.edges, threshold))
