"""MUST TRIGGER kernel-constraints: float64 math and host callbacks
inside the kernel body."""
import jax.numpy as jnp


def acc_kernel(x_ref, o_ref):
    acc = x_ref[...].astype(jnp.float64)   # no f64 on TPU Pallas
    print("acc", acc)                       # host callback stalls the pipe
    o_ref[...] = acc
