"""MUST TRIGGER lock-order: opposite nesting of two locks via nested
`with` statements."""
import threading


class Outer:
    def __init__(self):
        self._lock = threading.Lock()
        self.inner = Inner(self)

    def forward(self):
        with self._lock:
            with self.inner._lock:
                pass


class Inner:
    def __init__(self, outer):
        self._lock = threading.Lock()
        self.outer = Outer()

    def backward(self):
        with self._lock:
            with self.outer._lock:
                pass
