"""MUST TRIGGER lock-discipline: the second write escapes the locked
region, and a private helper is called from an unlocked site."""
import threading


class Ledger:
    def __init__(self):
        self._lock = threading.Lock()
        self.entries = {}
        self.total = 0

    def add(self, key, amount):
        with self._lock:
            self.entries[key] = amount
        self.total += amount  # fell out of the with-block

    def audit(self):
        self._rebuild()  # unlocked call site -> helper not in closure

    def _rebuild(self):
        self.total = sum(self.entries.values())
