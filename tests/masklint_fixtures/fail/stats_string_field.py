"""MUST TRIGGER stats-drift: a non-numeric field silently vanishes from
the reflection samplers, and a default-less field breaks reset()."""
import dataclasses


@dataclasses.dataclass
class IngestStats:
    source: str            # not int/float -> dropped from /metrics
    rows: int = 0
    wall_s: float = 0.0
