"""MUST TRIGGER lock-order: A and B take each other's locks while
holding their own."""
import threading


class Alpha:
    def __init__(self):
        self._lock = threading.Lock()
        self.beta = Beta(self)

    def poke(self):
        with self._lock:
            self.beta.poke_back()


class Beta:
    def __init__(self, alpha):
        self._lock = threading.Lock()
        self.alpha = Alpha()

    def poke_back(self):
        with self._lock:
            self.alpha.poke()
