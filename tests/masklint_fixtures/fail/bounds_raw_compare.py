"""MUST TRIGGER bounds-soundness: raw comparisons standing in for the
three-valued decision."""


def accepted_ids(ids, lb, ub, threshold):
    keep = ub > threshold      # "possible" used as "certain"
    sure = lb >= threshold
    return ids[keep], ids[sure]
