"""MUST TRIGGER bounds-edge: searchsorted over a local edges array."""
import numpy as np


def k_for(edges, t):
    return edges.searchsorted(np.float32(t))
