"""MUST TRIGGER kernel-constraints: index_map arity != grid rank."""
import functools

import jax
import jax.experimental.pallas as pl
import jax.numpy as jnp


def scale_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def launch(x, bh):
    b, h = x.shape
    return pl.pallas_call(
        functools.partial(scale_kernel),
        grid=(b, h // bh),
        in_specs=[pl.BlockSpec((1, bh), lambda i: (i, 0))],   # 1 arg, rank 2
        out_specs=pl.BlockSpec((1, bh), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
    )(x)
