"""MUST TRIGGER epoch-snapshot: a run object reading raw arrays through
its pinned snapshot's private state."""


class Run:
    def __init__(self, store):
        self.snap = store.snapshot()

    def raw_rows(self, positions):
        return self.snap._masks[positions]  # bypasses load()/staleness
