"""MUST TRIGGER popcount-no-float: unpacking words to float lanes inside
a popcount kernel body (re-pays the 32x HBM traffic the packed tier
removes)."""
import jax.numpy as jnp


def _bad_cp_popcount_kernel(roi_ref, lv_ref, mask_ref, out_ref):
    m = mask_ref[0].astype(jnp.float32)            # unpacked float load
    out_ref[0] += jnp.sum((m >= lv_ref[0]).astype(jnp.int32))
