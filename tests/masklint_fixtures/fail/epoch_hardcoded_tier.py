"""MUST TRIGGER epoch-discipline: a hardcoded tier literal pins one
pyramid rung — bounds from a different tier alias under the same key."""
from repro.service.planner import bounds_key


def key_for(expr, plan, roi_sig, store):
    return bounds_key(expr, plan, roi_sig, "host",
                      epoch=store.epoch, tier=8)  # literal tier
