"""MUST TRIGGER epoch-discipline: bounds_key without a tier — the tier=0
default binds and a coarse CHI-pyramid interval answers refined requests."""
from repro.service.planner import bounds_key


def key_for(expr, plan, roi_sig, store):
    return bounds_key(expr, plan, roi_sig, "host",
                      epoch=store.epoch)  # no tier
