"""NEAR MISS, must stay clean: integer-only popcount kernel body; the
float range math lives in the wrapper (outside the traced body), which is
exactly the intended split."""
import jax.numpy as jnp


def _popcount32(x):
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def _ok_cp_popcount_kernel(f1_ref, f0_ref, mask_ref, out_ref):
    ones = jnp.sum(_popcount32(mask_ref[0]))
    out_ref[0] += f1_ref[0] * ones + f0_ref[0] * (32 - ones)


def launch_flags(lv, uv):
    # float compares are fine OUT HERE: the wrapper collapses [lv, uv) on
    # binary values to two int32 flags before tracing the kernel.
    lv = jnp.asarray(lv, jnp.float32)
    uv = jnp.asarray(uv, jnp.float32)
    f1 = ((lv <= 1.0) & (1.0 < uv)).astype(jnp.int32)
    f0 = ((lv <= 0.0) & (0.0 < uv)).astype(jnp.int32)
    return f1, f0
