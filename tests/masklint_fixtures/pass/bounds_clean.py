"""MUST STAY CLEAN: bound decisions via cmp_decide; searchsorted over
non-edge arrays is ordinary numpy."""
import numpy as np

from repro.core.exprs import cmp_decide


def split(op, lb, ub, threshold, positions, all_pos):
    accept, reject = cmp_decide(op, lb, ub, threshold)
    slots = np.searchsorted(all_pos, positions)   # positions, not edges
    return accept, reject, slots
