"""MUST STAY CLEAN: bounds_key threads both the live epoch and the tier
the bounds pass actually ran at."""
from repro.service.planner import bounds_key


def key_for(expr, plan, roi_sig, store, tier):
    return bounds_key(expr, plan, roi_sig, "host",
                      epoch=store.epoch, tier=tier)
