"""MUST STAY CLEAN: numeric defaulted fields, reflection reset/merge,
asdict-based export — the ExecStats/IOStats shape."""
import dataclasses


@dataclasses.dataclass
class ProbeStats:
    rows: int = 0
    bytes_read: int = 0
    wall_s: float = 0.0

    def reset(self):
        for f in dataclasses.fields(self):
            setattr(self, f.name, f.default)

    def merge(self, other):
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))

    def as_dict(self):
        return dataclasses.asdict(self)
