"""MUST STAY CLEAN: arity-correct index maps, static range unroll,
f32 accumulation — the shape of the real kernels."""
import functools

import jax
import jax.experimental.pallas as pl
import jax.numpy as jnp


def sum_kernel(x_ref, o_ref, *, nb):
    acc = jnp.zeros_like(o_ref)
    for k in range(nb):               # static unroll via partial kwarg
        acc = acc + x_ref[k]
    o_ref[...] = acc.astype(jnp.float32)


def launch(x, bh):
    b, h = x.shape
    grid = (b, h // bh)
    return pl.pallas_call(
        functools.partial(sum_kernel, nb=4),
        grid=grid,
        in_specs=[pl.BlockSpec((1, bh), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, bh), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
    )(x)
