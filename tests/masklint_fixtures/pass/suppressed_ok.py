"""MUST STAY CLEAN: a reviewed inline suppression with a reason."""


def bucket_of(value, buckets):
    for i, ub in enumerate(buckets):
        if value <= ub:  # masklint: ignore[bounds-soundness] -- histogram bucket edge, not a CHI bound
            return i
    return len(buckets)
