"""MUST STAY CLEAN: keys thread the epoch; store access stays public."""


def lookup(planner, plan, roi_sig, backend, store):
    payload = planner.cached_result(plan, roi_sig, backend,
                                    epoch=store.epoch)
    if payload is None:
        planner.store_result(plan, roi_sig, {"ids": []}, backend,
                             store.epoch)
    snap = store.snapshot()
    if snap.cache_enabled and snap.can_serve([0, 1]):
        return snap.load([0, 1])
    return payload
