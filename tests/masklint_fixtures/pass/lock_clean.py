"""MUST STAY CLEAN: writes under the lock, construction-time writes,
and a private helper called only from locked regions."""
import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0
        self.history = []

    def bump(self, amount):
        with self._lock:
            self.value += amount
            self._note(amount)

    def reset(self):
        with self._lock:
            self.value = 0
            self.history = []

    def _note(self, amount):
        # only ever called under the lock (closure rule)
        self.history.append(amount)
        self.value = max(self.value, 0)

    def read(self):
        return self.value   # unlocked *read*: tolerated by design
