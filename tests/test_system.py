"""End-to-end behaviour test: the full ML-workflow loop from the demo —
train a small model → harvest attention masks into the store → query →
augment → retrain step (Scenario 1, compressed)."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import load_smoke
from repro.core import CHIConfig, MaskStore, queries, saliency
from repro.core.store import MASK_META_DTYPE
from repro.data.pipeline import SyntheticLMData
from repro.models import build_model
from repro.train.optimizer import OptConfig
from repro.train.train_loop import init_train_state, make_train_step


def test_full_workflow_loop():
    cfg = load_smoke("granite_3_2b")
    model = build_model(cfg)
    opt_cfg = OptConfig(learning_rate=1e-3, warmup_steps=2, total_steps=30)
    params, axes, opt = init_train_state(model, jax.random.PRNGKey(0), opt_cfg)
    step = jax.jit(make_train_step(model, opt_cfg))
    data = SyntheticLMData(cfg, seq_len=32, global_batch=8)

    # 1. train a few steps
    losses = []
    for s in range(8):
        params, opt, metrics = step(params, opt, data.batch_at(s))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], "training must reduce loss"

    # 2. harvest attention masks into a MaskSearch store
    batch = data.batch_at(100)
    maps = model.attention_maps(params, batch)        # (B, H, S, S)
    masks = saliency.normalize01(jnp.mean(maps, axis=1))
    masks = np.asarray(masks, np.float32)
    n, h, w = masks.shape
    meta = np.zeros(n, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(n)
    meta["image_id"] = np.arange(n)
    chi_cfg = CHIConfig(grid=8, num_bins=8, height=h, width=w)
    store = MaskStore.create_memory(masks, meta, chi_cfg)

    # 3. query: which examples have the least diagonal-band attention?
    (ids, scores), stats = queries.run(
        "SELECT mask_id FROM MasksDatabaseView ORDER BY "
        "CP(mask, full_img, (0.5, 1.0)) ASC LIMIT 4;", store)
    assert len(ids) == 4
    assert stats.n_candidates == n

    # 4. augment the selected rows and take another train step
    from repro.core.augment import mix_augmented
    sel = np.isin(meta["mask_id"], ids)
    new_tokens = mix_augmented(jax.random.PRNGKey(7),
                               jnp.asarray(batch["tokens"]),
                               jnp.asarray(sel), cfg.vocab_size)
    batch2 = dict(batch, tokens=np.asarray(new_tokens))
    params, opt, metrics = step(params, opt, batch2)
    assert np.isfinite(float(metrics["loss"]))
