"""masklint (repro.analysis) — fixture corpus, suppression semantics,
CLI surface, and the meta-test that the committed repo is clean."""

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import all_rules, run_paths

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "masklint_fixtures"

# filename prefix -> the rule its findings must include
_EXPECTED_RULE = {
    "lock_order": "lock-order",
    "lock": "lock-discipline",
    "epoch_missing": "epoch-discipline",
    "epoch_hardcoded": "epoch-discipline",
    "epoch": "epoch-snapshot",
    "bounds_edge": "bounds-edge",
    "bounds": "bounds-soundness",
    "kernel_popcount": "popcount-no-float",
    "kernel": "kernel-constraints",
    "stats": "stats-drift",
}


def _expected_rule(name: str) -> str:
    for prefix in sorted(_EXPECTED_RULE, key=len, reverse=True):
        if name.startswith(prefix):
            return _EXPECTED_RULE[prefix]
    raise AssertionError(f"fixture {name} matches no expected-rule prefix")


def _run(paths, **kw):
    kw.setdefault("suppressions_path", str(REPO / "masklint-suppressions.json"))
    return run_paths([str(p) for p in paths], root=str(REPO), **kw)


FAIL_FIXTURES = sorted((FIXTURES / "fail").glob("*.py"))
PASS_FIXTURES = sorted((FIXTURES / "pass").glob("*.py"))


def test_corpus_present_and_balanced():
    """ISSUE 7 acceptance: >=2 trigger and >=1 near-miss fixture per
    rule family (lock, epoch, bounds, kernel, stats)."""
    fams = ("lock", "epoch", "bounds", "kernel", "stats")
    for fam in fams:
        triggers = [p for p in FAIL_FIXTURES if p.name.startswith(fam)]
        clean = [p for p in PASS_FIXTURES if p.name.startswith(fam)]
        assert len(triggers) >= 2, f"{fam}: need >=2 must-fail fixtures"
        assert len(clean) >= 1, f"{fam}: need >=1 near-miss fixture"


@pytest.mark.parametrize("path", FAIL_FIXTURES, ids=lambda p: p.stem)
def test_fail_fixture_triggers_its_rule(path):
    result = _run([path])
    assert result.findings, f"{path.name} produced no findings"
    rules = {f.rule for f in result.findings}
    assert _expected_rule(path.name) in rules, \
        f"{path.name}: expected {_expected_rule(path.name)}, got {rules}"


@pytest.mark.parametrize("path", PASS_FIXTURES, ids=lambda p: p.stem)
def test_pass_fixture_stays_clean(path):
    result = _run([path])
    assert not result.findings, \
        f"{path.name}: {[f.format() for f in result.findings]}"


def test_repo_as_committed_is_clean():
    """The CI gate: `python -m repro.analysis` exits 0 at the repo root."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--format", "json"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120)
    payload = json.loads(proc.stdout)
    assert proc.returncode == 0, payload
    assert payload["ok"] and not payload["findings"], payload
    assert payload["files_scanned"] > 50


def test_cli_explain_and_list():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    listing = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--list"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=60)
    assert listing.returncode == 0
    names = {line.split()[0] for line in listing.stdout.splitlines()}
    assert {"lock-discipline", "lock-order", "epoch-discipline",
            "epoch-snapshot", "bounds-soundness", "bounds-edge",
            "kernel-constraints", "popcount-no-float",
            "stats-drift"} <= names
    for rule in sorted(names):
        doc = subprocess.run(
            [sys.executable, "-m", "repro.analysis", "--explain", rule],
            cwd=REPO, env=env, capture_output=True, text=True, timeout=60)
        assert doc.returncode == 0 and "Invariant" in doc.stdout, rule
    bad = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--explain", "nope"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=60)
    assert bad.returncode == 2


def test_every_rule_documented():
    for name, cls in all_rules().items():
        assert cls.summary, name
        assert "Invariant" in cls.doc and "Violation" in cls.doc, name


def test_inline_suppression_requires_reason(tmp_path):
    src = FIXTURES / "fail" / "bounds_raw_compare.py"
    text = src.read_text()
    # a bare ignore (no reason) must NOT suppress
    bare = text.replace("keep = ub > threshold      ",
                        "keep = (ub > threshold)  # masklint: ignore[all]")
    f1 = tmp_path / "bare.py"
    f1.write_text(bare)
    r1 = _run([f1])
    assert any(f.rule == "bounds-soundness" and "reason" in f.message
               for f in r1.findings)
    # with a reason it suppresses
    withreason = text.replace(
        "keep = ub > threshold      ",
        "keep = (ub > threshold)  # masklint: ignore[all] -- test reason")
    f2 = tmp_path / "reasoned.py"
    f2.write_text(withreason)
    r2 = _run([f2])
    # the `keep` line is suppressed; the fixture's other raw compare
    # (`sure = lb >= threshold`) still fires
    kept_lines = [f.line for f in r2.findings
                  if f.rule == "bounds-soundness"]
    assert len(kept_lines) == 1 and r2.n_suppressed >= 1


def test_suppression_file_entries(tmp_path):
    target = FIXTURES / "fail" / "epoch_private_reach.py"
    rel = target.relative_to(REPO).as_posix()
    sup = tmp_path / "sup.json"
    sup.write_text(json.dumps({"suppressions": [
        {"rule": "epoch-snapshot", "path": rel, "reason": "test entry"}]}))
    r = _run([target], suppressions_path=str(sup))
    assert not r.findings and r.n_suppressed >= 1
    # entries without a reason are themselves findings
    sup.write_text(json.dumps({"suppressions": [
        {"rule": "epoch-snapshot", "path": rel}]}))
    r2 = _run([target], suppressions_path=str(sup))
    assert any(f.rule == "suppression-file" for f in r2.findings)


def test_shipped_suppression_file_is_empty():
    data = json.loads((REPO / "masklint-suppressions.json").read_text())
    assert data == {"suppressions": []}


def test_parse_error_is_reported(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def f(:\n")
    r = _run([bad])
    assert any(f.rule == "parse-error" for f in r.findings)


def test_rule_subset_selection(tmp_path):
    r = _run([FIXTURES / "fail" / "lock_unlocked_write.py"],
             rule_names=["stats-drift"])
    assert not r.findings     # lock rule not selected
    with pytest.raises(KeyError):
        _run([FIXTURES], rule_names=["no-such-rule"])
