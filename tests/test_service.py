"""Service-layer tests: sessions, plan/result caching, fused verification,
and the HTTP/JSON API — the acceptance contract of the serving subsystem.

Key invariants:
  * pagination over n pages ≡ one-shot ``LIMIT n·k`` (ids AND scores);
  * a warm result-cache hit performs zero mask loads;
  * concurrent fused verification loads strictly fewer bytes than running
    the same queries serially without sharing;
  * the HTTP front is a faithful translation of the service API.
"""

import threading

import numpy as np
import pytest

from repro.core import CHIConfig, MaskStore, engine, queries
from repro.core.store import MASK_META_DTYPE
from repro.data.masks import object_boxes, saliency_masks
from repro.service import MaskSearchService, ServiceClient, make_server

B, H, W = 60, 64, 64

TOPK_SQL = ("SELECT mask_id FROM MasksDatabaseView ORDER BY "
            "CP(mask, full_img, (0.2, 0.6)) DESC LIMIT 5;")


@pytest.fixture(scope="module")
def db(tmp_path_factory):
    root = tmp_path_factory.mktemp("servicedb")
    rois = object_boxes(B, H, W, seed=2)
    masks, _ = saliency_masks(B, H, W, seed=1, attacked_fraction=0.25,
                              boxes=rois)
    meta = np.zeros(B, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(B) + 1000
    meta["image_id"] = np.arange(B) // 2
    meta["mask_type"] = np.arange(B) % 2 + 1
    cfg = CHIConfig(grid=8, num_bins=16, height=H, width=W)
    MaskStore.create_disk(str(root), masks, meta, cfg)
    return str(root), rois


def _fresh_service(root, rois=None, **kw):
    return MaskSearchService(MaskStore.open_disk(root), provided_rois=rois,
                             **kw)


def test_session_pagination_matches_oneshot(db):
    root, rois = db
    svc = _fresh_service(root, verify_batch=8)
    first = svc.query(TOPK_SQL, session=True, page_size=5)
    pages = [first["page"]]
    for _ in range(3):
        pages.append(svc.next_page(first["session"])["page"])
    paged_ids = sum((p["ids"] for p in pages), [])
    paged_scores = sum((p["scores"] for p in pages), [])
    assert [p["offset"] for p in pages] == [0, 5, 10, 15]

    store = MaskStore.open_disk(root)
    plan = queries.parse(TOPK_SQL)
    ids, scores, _ = engine.topk_query(store, plan.expr, 20, desc=plan.desc)
    assert paged_ids == [int(x) for x in ids]
    np.testing.assert_allclose(paged_scores, scores)


def test_pagination_matches_oneshot_with_tied_scores():
    """CP scores are integer counts, so boundary ties are the norm; the
    deterministic tie-break (by candidate order) must make paginated and
    one-shot runs agree even when the k-th rank is heavily tied."""
    b, h, w = 40, 32, 32
    # only 4 distinct mask patterns → massively tied scores
    base = saliency_masks(4, h, w, seed=9)[0]
    masks = base[np.arange(b) % 4]
    meta = np.zeros(b, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(b)
    meta["image_id"] = np.arange(b)
    cfg = CHIConfig(grid=4, num_bins=8, height=h, width=w)
    store = MaskStore.create_memory(masks, meta, cfg)
    svc = MaskSearchService(store, verify_batch=4)

    sql = ("SELECT mask_id FROM MasksDatabaseView ORDER BY "
           "CP(mask, full_img, (0.3, 0.7)) DESC LIMIT 5;")
    first = svc.query(sql, session=True, page_size=5)
    pages = [first["page"]]
    for _ in range(3):
        pages.append(svc.next_page(first["session"])["page"])
    paged_ids = sum((p["ids"] for p in pages), [])

    store2 = MaskStore.create_memory(masks, meta, cfg)
    plan = queries.parse(sql)
    ids, scores, _ = engine.topk_query(store2, plan.expr, 20, desc=True)
    assert paged_ids == [int(x) for x in ids]
    assert len(set(paged_ids)) == 20                 # no dup/drop across pages


def test_pagination_is_incremental_not_rerun(db):
    root, _ = db
    svc = _fresh_service(root, verify_batch=8)
    first = svc.query(TOPK_SQL, session=True, page_size=5)
    verified_p1 = first["stats"]["n_verified"]
    page2 = svc.next_page(first["session"])
    # the second page resumes the frontier: strictly fewer new verifications
    # than re-running a LIMIT 10 query from scratch
    store = MaskStore.open_disk(root)
    plan = queries.parse(TOPK_SQL)
    _, _, full = engine.topk_query(store, plan.expr, 10, desc=True)
    assert page2["stats"]["n_verified"] - verified_p1 < full.n_verified


def test_warm_result_cache_zero_mask_loads(db):
    root, _ = db
    svc = _fresh_service(root)
    cold = svc.query(TOPK_SQL)
    assert not cold["cache_hit"]
    io_before = svc.store.io.bytes_read
    warm = svc.query(TOPK_SQL)
    assert warm["cache_hit"]
    assert warm["stats"]["bytes_loaded"] == 0
    assert svc.store.io.bytes_read == io_before      # zero mask loads
    assert warm["ids"] == cold["ids"]
    np.testing.assert_allclose(warm["scores"], cold["scores"])
    # caller mutation must not poison the cache
    warm["ids"].reverse()
    cold["ids"].clear()
    again = svc.query(TOPK_SQL)
    assert again["cache_hit"] and again["ids"] == [int(x) for x in
                                                   np.asarray(warm["ids"])[::-1]]


def test_bounds_cache_reused_across_thresholds(db):
    root, _ = db
    svc = _fresh_service(root)
    base = "SELECT mask_id FROM MasksDatabaseView WHERE " \
           "CP(mask, full_img, (0.2, 0.6)) > {};"
    svc.query(base.format(500))
    assert svc.planner.bounds_cache.info.misses == 1
    out = svc.query(base.format(800))
    assert svc.planner.bounds_cache.info.hits >= 1   # refined query: free pass

    store = MaskStore.open_disk(root)
    plan = queries.parse(base.format(800))
    ids_ref, _ = engine.filter_query(store, plan.expr, plan.op,
                                     plan.threshold)
    assert sorted(out["ids"]) == sorted(int(x) for x in ids_ref)


def test_fused_batch_loads_fewer_bytes_than_serial(db):
    root, _ = db
    sqls = ["SELECT mask_id FROM MasksDatabaseView ORDER BY "
            f"CP(mask, full_img, ({lv}, {lv + 0.4})) DESC LIMIT 15;"
            for lv in (0.2, 0.25, 0.3)]

    svc = _fresh_service(root, verify_batch=8)
    io0 = svc.store.io.bytes_read
    fused = svc.submit_batch(sqls)
    fused_bytes = svc.store.io.bytes_read - io0
    assert svc.scheduler.stats.fused_passes > 0
    assert svc.store.cache_stats.bytes_saved > 0     # residues overlapped

    serial_store = MaskStore.open_disk(root)         # no sharing at all
    io0 = serial_store.io.bytes_read
    serial = [queries.parse(s).run(serial_store) for s in sqls]
    serial_bytes = serial_store.io.bytes_read - io0

    assert fused_bytes < serial_bytes
    for got, ((ids, scores), _) in zip(fused, serial):
        assert got["ids"] == [int(x) for x in ids]
        np.testing.assert_allclose(got["scores"], scores)


def test_concurrent_session_pages_fused(db):
    root, _ = db
    svc = _fresh_service(root, verify_batch=8)
    sids = []
    for lv in (0.2, 0.25):
        r = svc.query("SELECT mask_id FROM MasksDatabaseView ORDER BY "
                      f"CP(mask, full_img, ({lv}, {lv + 0.4})) DESC LIMIT 5;",
                      session=True, page_size=5)
        sids.append(r["session"])
    passes0 = svc.scheduler.stats.fused_passes
    pages = svc.next_pages({sid: None for sid in sids})
    assert set(pages) == set(sids)
    for sid in sids:
        assert pages[sid]["page"]["offset"] == 5
        assert len(pages[sid]["page"]["ids"]) == 5
    assert svc.scheduler.stats.fused_passes >= passes0


def test_filter_and_scalar_through_service(db):
    root, rois = db
    svc = _fresh_service(root, rois)
    fsql = ("SELECT mask_id FROM MasksDatabaseView WHERE "
            "CP(mask, roi, (0.8, 1.0)) / AREA(roi) < 0.05;")
    got = svc.query(fsql)
    store = MaskStore.open_disk(root)
    plan = queries.parse(fsql)
    want, _ = engine.filter_query(store, plan.expr, plan.op, plan.threshold,
                                  provided_rois=rois)
    assert sorted(got["ids"]) == sorted(int(x) for x in want)

    ssql = ("SELECT SCALAR_AGG(AVG, CP(mask, full_img, (0.5, 1.0))) "
            "FROM MasksDatabaseView;")
    got = svc.query(ssql)
    want_v, _ = engine.scalar_agg(store, queries.parse(ssql).expr, "AVG")
    assert abs(got["value"] - want_v) < 1e-9
    # scalar results are result-cached too
    warm = svc.query(ssql)
    assert warm["cache_hit"] and warm["value"] == got["value"]


FILTERED_TOPK_SQL = (
    "SELECT mask_id FROM MasksDatabaseView WHERE "
    "CP(mask, full_img, (0.5, 1.0)) > 200 "
    "ORDER BY CP(mask, full_img, (0.2, 0.6)) DESC LIMIT 5;")


def test_filtered_topk_session_pagination(db):
    """A predicate-filtered ranking paginates exactly like a plain one."""
    root, _ = db
    svc = _fresh_service(root, verify_batch=8)
    first = svc.query(FILTERED_TOPK_SQL, session=True, page_size=5)
    pages = [first["page"]]
    for _ in range(2):
        pages.append(svc.next_page(first["session"])["page"])
    paged_ids = sum((p["ids"] for p in pages), [])
    paged_scores = sum((p["scores"] for p in pages), [])

    import dataclasses

    store = MaskStore.open_disk(root)
    plan = queries.parse(FILTERED_TOPK_SQL).plan
    from repro.core.plan import run_plan
    (ids, scores), _ = run_plan(store, dataclasses.replace(plan, k=15))
    assert paged_ids == [int(x) for x in ids]
    np.testing.assert_allclose(paged_scores, scores)


def test_filtered_topk_fuses_in_batch(db):
    """Filtered rankings and scalar aggs ride the same fused passes."""
    root, _ = db
    svc = _fresh_service(root, verify_batch=8)
    sqls = [FILTERED_TOPK_SQL,
            FILTERED_TOPK_SQL.replace("0.2", "0.25"),
            "SELECT SCALAR_AGG(AVG, CP(mask, full_img, (0.3, 0.7))) "
            "FROM MasksDatabaseView;"]
    out = svc.submit_batch(sqls)
    assert svc.scheduler.stats.fused_passes > 0

    store = MaskStore.open_disk(root)
    for got, sql in zip(out, sqls):
        plan = queries.parse(sql)
        if got["kind"] == "scalar_agg":
            want, _ = plan.run(store)
            assert abs(got["value"] - want) < 1e-9
        else:
            (ids, scores), _ = plan.run(store)
            assert got["ids"] == [int(x) for x in ids]
            np.testing.assert_allclose(got["scores"], scores)


def test_service_honors_query_field_mutation(db):
    """A parsed Query whose flat fields were tweaked after parse() must
    execute (and cache) the mutated plan, exactly like Query.run."""
    root, _ = db
    svc = _fresh_service(root)
    q = queries.parse("SELECT mask_id FROM MasksDatabaseView WHERE "
                      "CP(mask, full_img, (0.2, 0.6)) > 500;")
    q.threshold = 900.0
    got = svc.query(q)
    store = MaskStore.open_disk(root)
    want, _ = engine.filter_query(store, queries.parse(
        "SELECT mask_id FROM MasksDatabaseView WHERE "
        "CP(mask, full_img, (0.2, 0.6)) > 900;").predicate)
    assert sorted(got["ids"]) == sorted(int(x) for x in want)


def test_empty_scalar_agg_serves_json_null(db):
    """NaN (empty candidate set) must reach HTTP clients as null, not the
    invalid-JSON literal NaN."""
    import json

    root, _ = db
    svc = _fresh_service(root)
    out = svc.query("SELECT SCALAR_AGG(AVG, CP(mask, full_img, (0.2, 0.6))) "
                    "FROM MasksDatabaseView WHERE mask_type IN (7);")
    assert out["value"] is None
    json.loads(json.dumps(out, allow_nan=False))     # strict round-trip


def test_filtered_session_exhausts_when_predicate_starves(db):
    """A filtered ranking whose predicate matches fewer rows than requested
    must report exhausted instead of serving endless empty pages."""
    root, _ = db
    svc = _fresh_service(root, verify_batch=8)
    sql = ("SELECT mask_id FROM MasksDatabaseView WHERE "
           "CP(mask, full_img, (0.99, 1.0)) > 100000 "    # impossible: > area
           "ORDER BY CP(mask, full_img, (0.2, 0.6)) DESC LIMIT 5;")
    first = svc.query(sql, session=True, page_size=5)
    assert first["page"]["ids"] == []
    assert first["exhausted"]
    again = svc.next_page(first["session"])
    assert again["page"]["ids"] == [] and again["exhausted"]
    # and a partially-starved predicate delivers its rows then exhausts
    store = MaskStore.open_disk(root)
    probe = queries.parse("SELECT mask_id FROM MasksDatabaseView WHERE "
                          "CP(mask, full_img, (0.5, 1.0)) > 900;")
    n_match = len(probe.run(store)[0])
    assert 0 < n_match < B
    sql2 = ("SELECT mask_id FROM MasksDatabaseView WHERE "
            "CP(mask, full_img, (0.5, 1.0)) > 900 "
            "ORDER BY CP(mask, full_img, (0.2, 0.6)) DESC LIMIT "
            f"{n_match + 3};")
    page = svc.query(sql2, session=True, page_size=n_match + 3)
    assert len(page["page"]["ids"]) == n_match
    assert page["exhausted"]


def test_bounds_cache_shared_across_plan_shapes(db):
    """A CP expression's bounds entry is shared between the plans that use
    it — a filter, a refined filter, and a filtered ranking all hit it."""
    root, _ = db
    svc = _fresh_service(root)
    svc.query("SELECT mask_id FROM MasksDatabaseView WHERE "
              "CP(mask, full_img, (0.2, 0.6)) > 500;")
    misses0 = svc.planner.bounds_cache.info.misses
    svc.query("SELECT mask_id FROM MasksDatabaseView WHERE "
              "CP(mask, full_img, (0.2, 0.6)) > 800 "
              "AND CP(mask, full_img, (0.5, 1.0)) > 10;")
    # the (0.2, 0.6) expression came from cache; only (0.5, 1.0) missed
    assert svc.planner.bounds_cache.info.hits >= 1
    assert svc.planner.bounds_cache.info.misses == misses0 + 1


def test_group_query_through_batch_fallback(db):
    root, _ = db
    svc = _fresh_service(root, verify_batch=8)
    out = svc.submit_batch([queries.SCENARIO3_IOU])
    store = MaskStore.open_disk(root)
    (ids, scores), _ = queries.run(queries.SCENARIO3_IOU, store)
    assert out[0]["ids"] == [int(x) for x in ids]
    np.testing.assert_allclose(out[0]["scores"], scores)
    assert svc.scheduler.stats.fallback_batches > 0  # MASK_AGG can't fuse


@pytest.mark.parametrize("backend", ["device", "mesh"])
def test_service_on_alternate_backends(db, backend):
    """One service per backend: identical answers to the host service, for
    one-shot queries, fused batches, and session pagination — and the
    device backend's verification loads nothing from the metered store
    (the bytes live resident in HBM)."""
    root, rois = db
    host = _fresh_service(root, rois, verify_batch=8)
    alt = _fresh_service(root, rois, verify_batch=8, backend=backend)
    assert alt.stats()["backend"] == backend

    want = host.query(FILTERED_TOPK_SQL)
    io0 = alt.store.io.bytes_read
    got = alt.query(FILTERED_TOPK_SQL)
    assert got["ids"] == want["ids"]
    np.testing.assert_allclose(got["scores"], want["scores"])
    assert got["stats"]["n_verified"] == want["stats"]["n_verified"]
    if backend == "device":
        # resident-tier verification: zero metered query-path bytes
        assert alt.store.io.bytes_read == io0

    sqls = [TOPK_SQL, TOPK_SQL.replace("0.2", "0.25")]
    for w, g in zip(host.submit_batch(sqls), alt.submit_batch(sqls)):
        assert g["ids"] == w["ids"]
    assert alt.scheduler.stats.fused_passes > 0

    sess_h = host.query(TOPK_SQL, session=True, page_size=5)
    sess_a = alt.query(TOPK_SQL, session=True, page_size=5)
    assert sess_a["page"]["ids"] == sess_h["page"]["ids"]
    page_h = host.next_page(sess_h["session"])
    page_a = alt.next_page(sess_a["session"])
    assert page_a["page"]["ids"] == page_h["page"]["ids"]
    host.close()
    alt.close()


def test_session_errors(db):
    root, _ = db
    svc = _fresh_service(root)
    with pytest.raises(ValueError):
        svc.query("SELECT SCALAR_AGG(AVG, CP(mask, full_img, (0.5, 1.0))) "
                  "FROM V;", session=True)
    with pytest.raises(KeyError):
        svc.next_page("no-such-session")
    r = svc.query(TOPK_SQL, session=True)
    assert svc.drop_session(r["session"])
    with pytest.raises(KeyError):
        svc.next_page(r["session"])


def test_http_roundtrip(db):
    root, _ = db
    svc = _fresh_service(root, verify_batch=8)
    httpd = make_server(svc, "127.0.0.1", 0)
    thread = threading.Thread(target=httpd.serve_forever, daemon=True)
    thread.start()
    try:
        host, port = httpd.server_address[:2]
        client = ServiceClient(f"http://{host}:{port}")
        assert client.healthz()["ok"]

        one = client.query(TOPK_SQL)
        assert one["kind"] == "topk" and len(one["ids"]) == 5

        sess = client.query(TOPK_SQL, session=True, page_size=5)
        page2 = client.next_page(sess["session"], k=5)
        assert page2["page"]["offset"] == 5
        assert client.drop_session(sess["session"])["dropped"]

        batch = client.workload([TOPK_SQL, TOPK_SQL.replace("0.2", "0.25")])
        assert len(batch) == 2 and batch[0]["cache_hit"]  # one-shot above

        stats = client.stats()
        assert stats["queries"]["total"] >= 4
        assert "shared_cache" in stats and "result_cache" in stats

        from repro.service import ServiceError
        with pytest.raises(ServiceError) as err:
            client.query("SELECT nonsense FROM V;")
        assert err.value.code == 400
        with pytest.raises(ServiceError) as err:
            client.next_page("missing")
        assert err.value.code == 404
    finally:
        httpd.shutdown()
        httpd.server_close()
        thread.join(timeout=5)
        svc.close()
