"""Property tests (hypothesis): three-valued predicate-tree bounds decisions
agree with the ``use_index=False`` full-scan baseline on random plans.

Soundness being checked, for every randomly generated predicate tree:

  * ``decide`` never contradicts itself (accept ∧ reject = ∅);
  * accept ⇒ the exact predicate holds, reject ⇒ it cannot hold;
  * executing the plan through the index (with bounds pruning through the
    whole boolean tree) returns exactly the baseline's rows, and filtered
    top-k returns the baseline's ids *and* scores in order.

The numpy-seeded fallback versions of these checks (runnable without
hypothesis) live in test_plan.py.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed in this environment")

from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from repro.core import CHIConfig, MaskStore  # noqa: E402
from repro.core.exprs import (And, BinOp, Cmp, CP, MaskEvalContext,  # noqa: E402
                              Not, Or, RoiArea)
from repro.core.plan import LogicalPlan, run_plan  # noqa: E402
from repro.core.store import MASK_META_DTYPE  # noqa: E402
from repro.data.masks import object_boxes, saliency_masks  # noqa: E402

B, H, W = 20, 32, 32

_STORE = {}


def _db():
    """Module-lazy store (hypothesis re-enters the test many times)."""
    if "store" not in _STORE:
        rois = object_boxes(B, H, W, seed=5)
        masks, _ = saliency_masks(B, H, W, seed=4, attacked_fraction=0.25,
                                  boxes=rois)
        meta = np.zeros(B, MASK_META_DTYPE)
        meta["mask_id"] = np.arange(B)
        meta["image_id"] = np.arange(B) // 2
        meta["mask_type"] = np.arange(B) % 2 + 1
        cfg = CHIConfig(grid=4, num_bins=8, height=H, width=W)
        _STORE["store"] = MaskStore.create_memory(masks, meta, cfg)
        _STORE["rois"] = rois
    return _STORE["store"], _STORE["rois"]


_ranges = st.sampled_from([(0.0, 0.3), (0.2, 0.6), (0.5, 1.0), (0.8, 1.0)])
_rois = st.sampled_from([None, "provided", (4, 4, 28, 28)])


@st.composite
def _exprs(draw):
    lv, uv = draw(_ranges)
    roi = draw(_rois)
    base = CP(roi, lv, uv)
    shape = draw(st.integers(0, 3))
    if shape == 1:
        return BinOp("/", base, RoiArea(roi))
    if shape == 2:
        lv2, uv2 = draw(_ranges)
        return BinOp(draw(st.sampled_from("+-*")), base,
                     CP(draw(_rois), lv2, uv2))
    return base


@st.composite
def _cmps(draw):
    return Cmp(draw(_exprs()), draw(st.sampled_from(["<", "<=", ">", ">="])),
               draw(st.sampled_from([0.0, 0.02, 10.0, 100.0, 400.0])))


_preds = st.recursive(
    _cmps(),
    lambda children: st.one_of(
        st.builds(And, children, children),
        st.builds(Or, children, children),
        st.builds(Not, children),
    ),
    max_leaves=4,
)

_SETTINGS = settings(max_examples=30, deadline=None,
                     suppress_health_check=[HealthCheck.too_slow])


@_SETTINGS
@given(pred=_preds)
def test_three_valued_decisions_sound(pred):
    store, rois = _db()
    ctx = MaskEvalContext(store, np.arange(len(store)), rois,
                          partial_rows=False)
    accept, reject = pred.decide(ctx.bounds, ctx)
    assert not np.any(accept & reject)
    exact = pred.exact(ctx, np.arange(len(store)))
    assert np.all(exact[accept])
    assert not np.any(exact[reject])


@_SETTINGS
@given(pred=_preds)
def test_random_filter_plan_matches_full_scan(pred):
    store, rois = _db()
    plan = LogicalPlan(predicate=pred)
    ids, stats = run_plan(store, plan, provided_rois=rois, verify_batch=5)
    ids0, _ = run_plan(store, plan, provided_rois=rois, use_index=False)
    assert sorted(ids) == sorted(ids0)
    assert stats.n_verified + stats.n_decided_by_bounds == stats.n_candidates


@_SETTINGS
@given(pred=_preds, rank=_exprs(), desc=st.booleans(),
       k=st.integers(1, B + 2))
def test_random_filtered_topk_matches_full_scan(pred, rank, desc, k):
    store, rois = _db()
    plan = LogicalPlan(predicate=pred, order_by=rank, k=k, desc=desc)
    (ids, scores), _ = run_plan(store, plan, provided_rois=rois,
                                verify_batch=3)
    (ids0, scores0), _ = run_plan(store, plan, provided_rois=rois,
                                  use_index=False)
    assert list(ids) == list(ids0)
    np.testing.assert_allclose(scores, scores0)
