"""Per-architecture smoke tests (reduced configs, CPU).

For every assigned arch: one forward/loss, one SGD-free grad step, one
prefill + two decode steps.  Asserts output shapes and finiteness — the
full configs are exercised only through the dry-run (no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, load_smoke
from repro.models import build_model

B, S = 2, 32


def _batch(cfg, rng):
    kt, kp, ka = jax.random.split(rng, 3)
    if cfg.is_encoder_decoder:
        dec = min(S, cfg.max_decode_len)
        return {
            "audio_feats": jax.random.normal(ka, (B, S, cfg.d_model),
                                             jnp.float32),
            "tokens": jax.random.randint(kt, (B, dec), 0, cfg.vocab_size),
            "labels": jax.random.randint(kp, (B, dec), 0, cfg.vocab_size),
        }
    batch = {
        "tokens": jax.random.randint(kt, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(kp, (B, S), 0, cfg.vocab_size),
    }
    if cfg.num_patches:
        batch["patches"] = jax.random.normal(
            ka, (B, cfg.num_patches, cfg.d_model), jnp.float32)
    if cfg.mtp_depth:
        batch["labels_mtp"] = jax.random.randint(kp, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad(arch):
    cfg = load_smoke(arch)
    model = build_model(cfg)
    params, axes = model.init(jax.random.PRNGKey(0))
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(loss) > 0

    grads = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(params, batch)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode(arch):
    cfg = load_smoke(arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    if cfg.is_encoder_decoder:
        cache = model.init_cache(B, enc_len=S)
        prompt = {"audio_feats": batch["audio_feats"],
                  "tokens": batch["tokens"][:, :8]}
        logits, cache = jax.jit(model.prefill)(params, prompt, cache)
        pos0 = 8
    else:
        max_len = S + 8
        cache = model.init_cache(B, max_len)
        prompt = {k: (v[:, :8] if k == "tokens" else v)
                  for k, v in batch.items() if k in ("tokens", "patches")}
        logits, cache = jax.jit(model.prefill)(params, prompt, cache)
        pos0 = 8 + (cfg.num_patches or 0)

    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    step = jax.jit(model.decode_step)
    token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    for i in range(2):
        logits, cache = step(params, cache, token, jnp.int32(pos0 + i))
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert np.all(np.isfinite(np.asarray(logits, np.float32))), \
            f"{arch}: decode step {i} not finite"
        token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)


def test_prefill_decode_consistency_dense():
    """Decode logits must match teacher-forced forward logits (granite).
    Run in f32: this test checks cache logic, not bf16 noise."""
    import dataclasses
    cfg = dataclasses.replace(load_smoke("granite_3_2b"), dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, 12), 0,
                                cfg.vocab_size)
    full_logits, _ = model.logits(params, {"tokens": tokens})

    cache = model.init_cache(B, 16)
    logits_p, cache = model.prefill(params, {"tokens": tokens[:, :8]}, cache)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0], np.float32),
        np.asarray(full_logits[:, 7], np.float32), rtol=2e-2, atol=2e-2)
    # decode positions 8..11 must reproduce teacher forcing
    for pos in range(8, 12):
        logits_d, cache = model.decode_step(
            params, cache, tokens[:, pos:pos + 1], jnp.int32(pos))
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32),
            np.asarray(full_logits[:, pos], np.float32), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["mamba2_13b", "recurrentgemma_2b"])
def test_prefill_decode_consistency_recurrent(arch):
    """SSM/RG-LRU decode must continue the prefill state correctly.
    Run in f32: this test checks recurrence logic, not bf16 noise."""
    import dataclasses
    cfg = dataclasses.replace(load_smoke(arch), dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, 12), 0,
                                cfg.vocab_size)
    full_logits, _ = model.logits(params, {"tokens": tokens})
    cache = model.init_cache(B, 16)
    logits_p, cache = model.prefill(params, {"tokens": tokens[:, :8]}, cache)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0], np.float32),
        np.asarray(full_logits[:, 7], np.float32), rtol=5e-2, atol=5e-2)
    for pos in range(8, 12):
        logits_d, cache = model.decode_step(
            params, cache, tokens[:, pos:pos + 1], jnp.int32(pos))
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32),
            np.asarray(full_logits[:, pos], np.float32), rtol=5e-2, atol=5e-2)


def test_attention_maps_for_masksearch():
    cfg = load_smoke("granite_3_2b")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    maps = model.attention_maps(params, batch)
    assert maps.shape == (B, cfg.num_heads, S, S)
    rows = np.asarray(maps, np.float32).sum(-1)
    np.testing.assert_allclose(rows, 1.0, atol=1e-3)


def test_exact_configs_are_assigned_geometry():
    """Spot-check the full configs against the assignment table."""
    from repro.configs import load_arch
    v3 = load_arch("deepseek_v3_671b")
    assert (v3.num_layers, v3.d_model, v3.num_heads) == (61, 7168, 128)
    assert (v3.num_experts, v3.top_k, v3.vocab_size) == (256, 8, 129280)
    g = load_arch("gemma3_27b")
    assert g.pattern_layers.count("global") == 10       # 10 whole 5L+1G groups
    assert g.pattern_layers.count("local") == 52        # 50 in groups + 2 tail
    assert g.num_layers == 62 and g.vocab_size == 262144
    m = load_arch("mamba2_13b")
    assert m.ssm_state == 128 and m.num_layers == 48 and m.d_ff == 0
    w = load_arch("whisper_large_v3")
    assert w.is_encoder_decoder and w.d_model == 1280 and w.vocab_size == 51866
