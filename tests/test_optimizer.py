"""CHI pyramid + cost-based filter ordering (core/opt.py, DESIGN.md §13):
tier nesting, disk/mutation round-trips, bit-identity of the refinement
ladder across backends and representations, tier-aware cache keys, and the
EXPLAIN/metrics surfaces."""

import numpy as np
import pytest

from repro.core import exprs as E
from repro.core import opt
from repro.core.chi import CHIConfig, tier_slice
from repro.core.exprs import CP, And, Cmp, MaskEvalContext, Or, TypeIn
from repro.core.plan import LogicalPlan, run_plan
from repro.core.store import MASK_META_DTYPE, MaskStore
from repro.obs.explain import explain_analyze
from repro.obs.metrics import get_registry
from repro.service.planner import bounds_key

H = W = 64
INF = float("inf")


def _meta(b):
    meta = np.zeros(b, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(b)
    meta["image_id"] = np.arange(b)
    meta["mask_type"] = np.arange(b) % 3 + 1
    return meta


def _masks(b, seed=0, skew=True):
    rng = np.random.default_rng(seed)
    m = rng.random((b, H, W)).astype(np.float32)
    if skew:
        m[: b // 2] *= 0.3          # half the store is low-valued
    return m


@pytest.fixture(scope="module")
def store16():
    b = 48
    masks = _masks(b, seed=1)
    # bin-edge masks: constant at each threshold and one float32 ulp around
    # it — the exact values the nextafter32 query-edge mapping must bound
    for i, t in enumerate((0.2, 0.5, 0.8)):
        masks[i] = np.float32(t)
        masks[i + 3] = np.nextafter(np.float32(t), np.float32(np.inf))
        masks[i + 6] = np.nextafter(np.float32(t), np.float32(-np.inf))
    cfg = CHIConfig(grid=16, num_bins=4, height=H, width=W,
                    thresholds=(0.2, 0.5, 0.8))
    return MaskStore.create_memory(masks, _meta(b), cfg)


def _exact_cp(masks, roi, lv, uv):
    r0, c0, r1, c1 = roi
    sub = masks[:, r0:r1, c0:c1]
    return ((sub >= lv) & (sub < uv)).sum(axis=(1, 2)).astype(np.float64)


def test_tier_nesting_contains_fine_and_exact(store16):
    """Every coarse-tier [lb, ub] contains the finer tier's interval and
    the exact CP value — the soundness-by-construction ladder invariant."""
    store = store16
    tiers = store.cfg.tier_grids
    assert tiers == (4, 8, 16)
    pos = np.arange(len(store))
    masks = store.load(pos)
    for roi in [(0, 0, H, W), (3, 5, 61, 59), (17, 2, 40, 33)]:
        for lv, uv in [(0.2, INF), (0.5, INF), (0.8, INF), (0.2, 0.5)]:
            expr = CP(roi, lv, uv)
            exact = _exact_cp(masks, roi, lv, uv)
            prev = None
            for g in tiers:
                ctx = MaskEvalContext(store, pos)
                ctx.tier = None if g == tiers[-1] else g
                lb, ub = ctx.bounds(expr)
                assert np.all(lb <= exact) and np.all(exact <= ub), \
                    (roi, lv, uv, g)
                if prev is not None:
                    plb, pub = prev
                    assert np.all(plb <= lb) and np.all(ub <= pub), \
                        f"tier {g} not nested in coarser interval"
                prev = (lb, ub)


def test_pyramid_tables_are_exact_tier_slices(store16):
    finest = store16.chi_host()
    for g in store16.cfg.tier_grids[:-1]:
        np.testing.assert_array_equal(
            store16.chi_tier_host(g),
            tier_slice(finest, store16.cfg.grid, g))


def test_pyramid_roundtrip_disk_and_mutation(tmp_path):
    b = 24
    cfg = CHIConfig(grid=8, num_bins=8, height=H, width=W)
    store = MaskStore.create_disk(tmp_path / "db", _masks(b, seed=3),
                                  _meta(b), cfg)
    store = MaskStore.open_disk(tmp_path / "db")

    def check(st):
        finest = st.chi_host()
        for g in st.cfg.tier_grids[:-1]:
            np.testing.assert_array_equal(
                st.chi_tier_host(g), tier_slice(finest, st.cfg.grid, g))

    check(store)
    extra = _masks(4, seed=4)
    emeta = _meta(4)
    emeta["mask_id"] += b
    emeta["image_id"] += b
    store.append(extra, emeta)
    check(store)
    store.update([1, 2], _masks(2, seed=5))
    check(store)
    store.delete([0, 5, b + 1])
    check(store)


def _skewed_pred():
    # conjunct 0: barely selective; conjunct 1: rejects nearly everything
    return And(Cmp(CP((0, 0, H, W), 0.2, INF), ">", 20.0),
               Cmp(CP((0, 0, H, W), 0.8, INF), ">", 790.0))


def _reassoc(pred):
    assert isinstance(pred, And)
    return And(pred.right, pred.left)


@pytest.mark.parametrize("backend", ["host", "device", "mesh"])
def test_ladder_bit_identity_across_backends(backend):
    b = 60
    cfg = CHIConfig(grid=8, num_bins=8, height=H, width=W)
    store = MaskStore.create_memory(_masks(b, seed=7), _meta(b), cfg)
    pred = And(_skewed_pred(), TypeIn((1, 2)))
    plan = LogicalPlan(predicate=pred)
    with opt.configure(pyramid=False, reorder=False):
        ids_classic, st_classic = run_plan(store, plan, backend=backend)
    with opt.configure(pyramid=True, reorder=True):
        ids_ladder, st_ladder = run_plan(store, plan, backend=backend)
        ids_re, _ = run_plan(
            store, LogicalPlan(predicate=_reassoc(pred)), backend=backend)
    np.testing.assert_array_equal(ids_classic, ids_ladder)
    np.testing.assert_array_equal(sorted(ids_classic), sorted(ids_re))
    assert st_classic.n_decided_by_bounds == st_ladder.n_decided_by_bounds
    assert st_classic.n_verified == st_ladder.n_verified
    assert st_ladder.chi_bytes <= st_classic.chi_bytes


def test_ladder_bit_identity_packed():
    b = 40
    rng = np.random.default_rng(11)
    masks = (rng.random((b, H, W)) < 0.4).astype(np.float32)
    masks[: b // 3] = 0.0                      # skew: a third is empty
    cfg = CHIConfig(grid=8, num_bins=8, height=H, width=W)
    meta = _meta(b)
    fstore = MaskStore.create_memory(masks, meta, cfg)
    pstore = MaskStore.create_memory(masks, meta.copy(), cfg, packed=True)
    pred = And(Cmp(CP((0, 0, H, W), 0.5, 1.5), ">", 10.0),
               Cmp(CP((8, 8, 56, 56), 0.5, 1.5), ">", 1200.0))
    plan = LogicalPlan(predicate=pred)
    with opt.configure(pyramid=False, reorder=False):
        ids_f, _ = run_plan(fstore, plan)
    with opt.configure(pyramid=True, reorder=True):
        ids_fo, _ = run_plan(fstore, plan)
        ids_po, _ = run_plan(pstore, plan)
    np.testing.assert_array_equal(ids_f, ids_fo)
    np.testing.assert_array_equal(ids_f, ids_po)


def test_filtered_topk_identity_under_optimizer():
    b = 60
    cfg = CHIConfig(grid=8, num_bins=8, height=H, width=W)
    store = MaskStore.create_memory(_masks(b, seed=9), _meta(b), cfg)
    plan = LogicalPlan(predicate=_skewed_pred(),
                       order_by=CP((0, 0, H, W), 0.5, INF), k=7)
    with opt.configure(pyramid=False, reorder=False):
        (ids_c, sc_c), st_c = run_plan(store, plan)
    with opt.configure(pyramid=True, reorder=True):
        (ids_o, sc_o), st_o = run_plan(store, plan)
    np.testing.assert_array_equal(ids_c, ids_o)
    np.testing.assert_array_equal(sc_c, sc_o)
    assert st_c.n_verified == st_o.n_verified


def test_or_and_not_predicates_identical_under_optimizer():
    b = 48
    cfg = CHIConfig(grid=8, num_bins=8, height=H, width=W)
    store = MaskStore.create_memory(_masks(b, seed=13), _meta(b), cfg)
    preds = [
        Or(Cmp(CP((0, 0, H, W), 0.8, INF), ">", 790.0),
           Cmp(CP((0, 0, H, W), 0.2, INF), "<", 900.0)),
        And(E.Not(Cmp(CP((0, 0, H, W), 0.8, INF), ">", 790.0)),
            Cmp(CP((0, 0, H, W), 0.2, INF), ">", 20.0)),
    ]
    for pred in preds:
        plan = LogicalPlan(predicate=pred)
        with opt.configure(pyramid=False, reorder=False):
            ids_c, _ = run_plan(store, plan)
        with opt.configure(pyramid=True, reorder=True):
            ids_o, _ = run_plan(store, plan)
        np.testing.assert_array_equal(ids_c, ids_o)


def test_bounds_key_carries_tier_and_trailing_epoch():
    expr = CP((0, 0, H, W), 0.5, INF)
    plan = LogicalPlan(predicate=Cmp(expr, ">", 1.0))
    k4 = bounds_key(expr, plan, "none", "host", epoch=3, tier=4)
    k16 = bounds_key(expr, plan, "none", "host", epoch=3, tier=16)
    assert k4 != k16
    assert "|t4|" in k4 and "|t16|" in k16
    # the epoch must stay the trailing component (evict_dead_epochs
    # parses it off the end)
    assert k4.rsplit("|", 1)[-1] == "e3"
    assert k16.rsplit("|", 1)[-1] == "e3"


def test_explain_reports_ladder_and_order(store16):
    pred = And(Cmp(CP((0, 0, H, W), 0.5, INF), ">", 3500.0),
               Cmp(CP((0, 0, H, W), 0.2, INF), ">", 20.0))
    rep = explain_analyze(store16, LogicalPlan(predicate=pred))
    filt = next(c for c in rep["tree"]["children"] if c["op"] == "Filter")
    assert filt["tier_grids"] == [4, 8, 16]
    assert sorted(filt["order"]) == [0, 1]
    assert all("start_tier" in leaf for leaf in filt["leaves"])
    evaluated = [leaf for leaf in filt["leaves"] if leaf["evaluated"]]
    assert evaluated and all("actual_reject" in leaf for leaf in evaluated)
    chib = next(c for c in rep["tree"]["children"] if c["op"] == "CHIBounds")
    tier_rows = [r for r in chib["exprs"] if "tier" in r]
    assert tier_rows and all(r["chi_bytes"] > 0 for r in tier_rows)
    assert rep["stats"]["chi_bytes"] > 0
    assert "start_tier" in rep["text"]


def test_selectivity_error_histogram_observed(store16):
    fam = get_registry().histogram(
        "masksearch_selectivity_abs_error",
        "|estimated - actual| per-conjunct rejection-rate error")

    def count():
        return sum(child.count for _, child in fam.samples())

    before = count()
    run_plan(store16, LogicalPlan(predicate=_skewed_pred()))
    assert count() > before


def test_configure_restores_flags():
    assert opt.PYRAMID and opt.REORDER
    with opt.configure(pyramid=False, reorder=False):
        assert not opt.PYRAMID and not opt.REORDER
    assert opt.PYRAMID and opt.REORDER
