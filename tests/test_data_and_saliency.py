"""Data pipeline, saliency extraction, and augmentation tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import load_smoke
from repro.core import augment, saliency
from repro.data.masks import object_boxes, saliency_masks
from repro.data.pipeline import AugmentedData, PrefetchIterator, SyntheticLMData
from repro.models import build_model


def test_pipeline_deterministic_and_host_sharded():
    cfg = load_smoke("granite_3_2b")
    d1 = SyntheticLMData(cfg, 16, 8, seed=3)
    d2 = SyntheticLMData(cfg, 16, 8, seed=3)
    np.testing.assert_array_equal(d1.batch_at(5)["tokens"],
                                  d2.batch_at(5)["tokens"])
    # host sharding: two hosts see different rows, together a full batch
    h0 = SyntheticLMData(cfg, 16, 8, seed=3, host_index=0, host_count=2)
    h1 = SyntheticLMData(cfg, 16, 8, seed=3, host_index=1, host_count=2)
    assert h0.batch_at(0)["tokens"].shape[0] == 4
    assert not np.array_equal(h0.batch_at(0)["tokens"],
                              h1.batch_at(0)["tokens"])


def test_prefetch_iterator():
    cfg = load_smoke("granite_3_2b")
    data = SyntheticLMData(cfg, 8, 4)
    it = PrefetchIterator(iter([data.batch_at(i) for i in range(5)]), depth=2)
    batches = list(it)
    assert len(batches) == 5
    np.testing.assert_array_equal(batches[2]["tokens"],
                                  data.batch_at(2)["tokens"])


def test_attention_rollout_properties():
    L, B, Hh, S = 3, 2, 4, 16
    attn = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(0), (L, B, Hh, S, S)), axis=-1)
    roll = saliency.attention_rollout(attn)
    assert roll.shape == (B, S, S)
    r = np.asarray(roll)
    assert r.min() >= 0.0 and r.max() < 1.0


def test_input_saliency_and_grid():
    cfg = load_smoke("granite_3_2b")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                cfg.vocab_size)
    emb = jnp.take(params["embedding"], tokens, axis=0)

    def loss_fn(p, batch, embeddings):
        # recompute loss with injected embeddings via a linear probe
        return jnp.sum(embeddings ** 2) * 1e-3  # simple differentiable probe

    scores = saliency.input_saliency(
        loss_fn, params, {"embeddings": emb, "tokens": tokens})
    assert scores.shape == (2, 32)
    grid = saliency.tokens_to_grid(scores, 8, 8)
    assert grid.shape == (2, 8, 8)
    up = saliency.resize_mask(grid, 16, 16)
    assert up.shape == (2, 16, 16)


def test_randomize_outside_roi():
    imgs, _ = saliency_masks(4, 32, 32, seed=0)
    rois = object_boxes(4, 32, 32, seed=1)
    out = augment.randomize_outside_roi(jax.random.PRNGKey(0),
                                        jnp.asarray(imgs), jnp.asarray(rois))
    out = np.asarray(out)
    for i in range(4):
        r0, c0, r1, c1 = rois[i]
        np.testing.assert_array_equal(out[i, r0:r1, c0:c1],
                                      imgs[i, r0:r1, c0:c1])
        outside = np.ones((32, 32), bool)
        outside[r0:r1, c0:c1] = False
        assert not np.allclose(out[i][outside], imgs[i][outside])


def test_augmented_data_mixes():
    cfg = load_smoke("granite_3_2b")
    base = SyntheticLMData(cfg, 16, 8, seed=4)
    ad = AugmentedData(base)
    plain = ad.batch_at(0)["tokens"].copy()
    aug_batch = {"tokens": np.zeros((4, 16), np.int32),
                 "labels": np.zeros((4, 16), np.int32)}
    ad.add_augmented(aug_batch)
    mixed = ad.batch_at(0)["tokens"]
    assert np.array_equal(mixed[:4], np.zeros((4, 16), np.int32))
    assert np.array_equal(mixed[4:], plain[4:])


def test_expert_utilization_map():
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(2), (2, 64, 8)), axis=-1)
    m = saliency.expert_utilization_map(probs, 32, 32)
    assert m.shape == (2, 32, 32)
    assert float(m.max()) < 1.0
