"""Backend-equivalence suite: Host, Device, and Mesh backends must return
identical ids/scores and identical ``n_verified`` accounting for any plan
the IR can express (the ExecBackend contract, DESIGN.md §7).

Seeded-numpy randomized plans here; the hypothesis version lives in
``test_backend_properties.py``.  The mesh backend runs over a 1-device
local mesh in-process (the 8-device variant is
``test_distributed.py::test_mesh_backend_multi_device_matches_host``).
"""

import numpy as np
import pytest

from repro.core import CHIConfig, MaskStore
from repro.core.backend import (DeviceBackend, HostBackend, MeshBackend,
                                get_backend, host_backend)
from repro.core.exprs import (AggCP, And, BinOp, Cmp, CP, MaskEvalContext,
                              Not, Or, RoiArea)
from repro.core.plan import LogicalPlan, run_plan
from repro.core.store import MASK_META_DTYPE
from repro.data.masks import object_boxes, saliency_masks

B, H, W = 24, 32, 32
BACKENDS = ("host", "device", "mesh")


@pytest.fixture(scope="module")
def db():
    rois = object_boxes(B, H, W, seed=5)
    masks, _ = saliency_masks(B, H, W, seed=4, attacked_fraction=0.25,
                              boxes=rois)
    meta = np.zeros(B, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(B)
    meta["image_id"] = np.arange(B) // 2
    meta["mask_type"] = np.arange(B) % 3 + 1
    cfg = CHIConfig(grid=4, num_bins=8, height=H, width=W)
    return MaskStore.create_memory(masks, meta, cfg), rois


def _run_all(store, plan, rois, verify_batch=5):
    return {name: run_plan(store, plan, provided_rois=rois,
                           verify_batch=verify_batch, backend=name)
            for name in BACKENDS}


def _assert_equivalent(outs, label=""):
    payload0, stats0 = outs["host"]
    for name in ("device", "mesh"):
        payload, stats = outs[name]
        if isinstance(payload0, tuple):                 # (ids, scores)
            assert list(payload[0]) == list(payload0[0]), (label, name)
            np.testing.assert_allclose(payload[1], payload0[1],
                                       err_msg=f"{label}/{name}")
        elif isinstance(payload0, float):               # scalar agg
            both_nan = np.isnan(payload) and np.isnan(payload0)
            assert both_nan or payload == payload0, (label, name)
        else:                                           # filter ids
            assert list(payload) == list(payload0), (label, name)
        assert stats.n_verified == stats0.n_verified, (label, name)
        assert stats.n_decided_by_bounds == stats0.n_decided_by_bounds, \
            (label, name)
        assert stats.n_dropped_masks == stats0.n_dropped_masks, (label, name)


# -- randomized plan suite (seeded fallback) ---------------------------------


def _random_expr(rng):
    ranges = [(0.0, 0.3), (0.2, 0.6), (0.5, 1.0), (0.8, 1.0)]
    rois = [None, "provided", (4, 4, 28, 28)]
    lv, uv = ranges[rng.integers(len(ranges))]
    roi = rois[rng.integers(len(rois))]
    base = CP(roi, lv, uv)
    if rng.random() < 0.3:
        return BinOp("/", base, RoiArea(roi))
    if rng.random() < 0.3:
        lv2, uv2 = ranges[rng.integers(len(ranges))]
        op = "+-*"[rng.integers(3)]
        return BinOp(op, base, CP(rois[rng.integers(len(rois))], lv2, uv2))
    return base


def _random_pred(rng, depth=0):
    if depth < 2 and rng.random() < 0.5:
        kind = rng.integers(3)
        if kind == 0:
            return And(_random_pred(rng, depth + 1),
                       _random_pred(rng, depth + 1))
        if kind == 1:
            return Or(_random_pred(rng, depth + 1),
                      _random_pred(rng, depth + 1))
        return Not(_random_pred(rng, depth + 1))
    expr = _random_expr(rng)
    op = ("<", "<=", ">", ">=")[rng.integers(4)]
    threshold = float(rng.choice([0.0, 0.02, 10.0, 100.0, 400.0]))
    return Cmp(expr, op, threshold)


def test_random_filter_plans_equivalent(db):
    store, rois = db
    rng = np.random.default_rng(10)
    for trial in range(12):
        plan = LogicalPlan(predicate=_random_pred(rng))
        _assert_equivalent(_run_all(store, plan, rois), f"filter{trial}")


def test_random_ranking_plans_equivalent(db):
    store, rois = db
    rng = np.random.default_rng(11)
    for trial in range(10):
        plan = LogicalPlan(order_by=_random_expr(rng),
                           k=int(rng.integers(1, B + 2)),
                           desc=bool(rng.integers(2)))
        _assert_equivalent(_run_all(store, plan, rois), f"topk{trial}")


def test_random_filtered_topk_plans_equivalent(db):
    store, rois = db
    rng = np.random.default_rng(12)
    for trial in range(10):
        plan = LogicalPlan(predicate=_random_pred(rng),
                           order_by=_random_expr(rng),
                           k=int(rng.integers(1, 9)),
                           desc=bool(rng.integers(2)))
        _assert_equivalent(_run_all(store, plan, rois), f"ftopk{trial}")


@pytest.mark.parametrize("agg", ["SUM", "AVG", "MIN", "MAX"])
def test_scalar_agg_plans_equivalent(db, agg):
    store, rois = db
    plan = LogicalPlan(agg=agg, agg_expr=BinOp("/", CP("provided", 0.8, 1.0),
                                               RoiArea("provided")))
    _assert_equivalent(_run_all(store, plan, rois), agg)
    empty = LogicalPlan(agg=agg, agg_expr=CP(None, 0.2, 0.6),
                        mask_types=(99,))
    _assert_equivalent(_run_all(store, empty, rois), f"{agg}-empty")


@pytest.mark.parametrize("agg", ["intersect", "union"])
def test_group_plans_equivalent(db, agg):
    store, rois = db
    plan = LogicalPlan(select="image_id", order_by=AggCP(agg, 0.8, None), k=6)
    _assert_equivalent(_run_all(store, plan, rois), f"group-{agg}")
    iou = LogicalPlan(select="image_id",
                      order_by=BinOp("/", AggCP("intersect", 0.8, None),
                                     AggCP("union", 0.8, None)),
                      k=6, desc=False)
    _assert_equivalent(_run_all(store, iou, rois), "group-iou")


# -- mutation sequences (epoch-versioned store, DESIGN.md §8) ----------------


def test_backends_equivalent_across_mutation_sequence():
    """After any interleaving of append/update/delete, host/device/mesh
    must return bit-identical ids/scores and the chunked CHI must equal a
    from-scratch rebuild — the backends' resident copies refresh per epoch
    via their sync() hook."""
    from repro.core.chi import build_chi_np

    n0, extra = 16, 8
    all_rois = object_boxes(n0 + 2 * extra, H, W, seed=21)
    all_masks, _ = saliency_masks(n0 + 2 * extra, H, W, seed=20,
                                  attacked_fraction=0.3, boxes=all_rois)
    meta = np.zeros(n0 + 2 * extra, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(n0 + 2 * extra)
    meta["image_id"] = np.arange(n0 + 2 * extra) // 2
    meta["mask_type"] = np.arange(n0 + 2 * extra) % 3 + 1
    cfg = CHIConfig(grid=4, num_bins=8, height=H, width=W)
    store = MaskStore.create_memory(all_masks[:n0], meta[:n0], cfg)
    # Mirror keyed by mask_id: appends happen in id order and deletes keep
    # relative order, so store rows == sorted active ids throughout.
    by_id = np.asarray(all_masks, np.float32).copy()
    active = np.zeros(n0 + 2 * extra, bool)
    active[:n0] = True

    rng = np.random.default_rng(33)
    plans = [
        LogicalPlan(order_by=CP(None, 0.2, 0.6), k=5),
        LogicalPlan(predicate=Cmp(CP((4, 4, 28, 28), 0.5, 1.0), ">", 40.0),
                    order_by=BinOp("/", CP("provided", 0.5, 1.0),
                                   RoiArea("provided")), k=4),
    ]

    def check():
        np.testing.assert_array_equal(store.mask_ids, np.nonzero(active)[0])
        np.testing.assert_array_equal(store.chi_host(),
                                      build_chi_np(by_id[active], cfg))
        for plan in plans:
            _assert_equivalent(_run_all(store, plan, all_rois[active]),
                               repr(plan))

    # append the first extra block
    store.append(all_masks[n0:n0 + extra], meta[n0:n0 + extra])
    active[n0:n0 + extra] = True
    check()
    # update a few rows in place
    upd = rng.choice(np.nonzero(active)[0], size=3, replace=False)
    new = np.clip(rng.random((3, H, W)).astype(np.float32), 0, 1)
    store.update(upd, new)
    by_id[upd] = new
    check()
    # delete a few, then append the second block
    dele = rng.choice(np.nonzero(active)[0], size=2, replace=False)
    store.delete(dele)
    active[dele] = False
    check()
    store.append(all_masks[n0 + extra:], meta[n0 + extra:])
    active[n0 + extra:] = True
    check()


# -- the physical primitives in isolation ------------------------------------


def test_cp_bounds_bit_identical_across_backends(db):
    """CP-leaf bounds are *integers* from the same CHI math (host resolve vs
    device_resolve) — they must agree exactly, not approximately."""
    store, rois = db
    rng = np.random.default_rng(13)
    ctx = MaskEvalContext(store, np.arange(len(store)), rois)
    backends = [get_backend(store, n) for n in BACKENDS]
    for trial in range(15):
        expr = _random_expr(rng)
        ref_lb, ref_ub = backends[0].bounds(ctx, expr)
        for be in backends[1:]:
            lb, ub = be.bounds(ctx, expr)
            np.testing.assert_array_equal(lb, ref_lb, err_msg=f"{trial}")
            np.testing.assert_array_equal(ub, ref_ub, err_msg=f"{trial}")
    # the unbounded-above CP leaf (uv=inf, MASK_AGG member bounds)
    inf_cp = CP(None, 0.8, float("inf"))
    ref = backends[0].bounds(ctx, inf_cp)
    for be in backends[1:]:
        got = be.bounds(ctx, inf_cp)
        np.testing.assert_array_equal(got[0], ref[0])
        np.testing.assert_array_equal(got[1], ref[1])


def test_verify_counts_identical_across_backends(db):
    store, rois = db
    ctx = MaskEvalContext(store, np.arange(len(store)), rois)
    terms = {CP(None, 0.2, 0.6), CP("provided", 0.8, 1.0),
             CP((4, 4, 28, 28), 0.0, 0.3)}
    batch = np.arange(0, B, 2)
    ref = host_backend().verify_counts(ctx, batch, terms)
    for name in ("device", "mesh"):
        ctx2 = MaskEvalContext(store, np.arange(len(store)), rois)
        got = get_backend(store, name).verify_counts(ctx2, batch, terms)
        for t in terms:
            np.testing.assert_array_equal(got[t], ref[t], err_msg=name)


@pytest.mark.parametrize("desc", [True, False])
def test_topk_frontier_exact_under_f32_collisions(db, desc):
    """Scores closer than one float32 ulp collapse in the device/mesh
    collectives; τ must still be resolved at float64 so the frontier is
    bit-identical to the host's np.partition path (regression: the f32
    tie-class pick used to over-prune)."""
    store, _ = db
    base = np.array([1.0, 1.0 + 1e-10, 1.0 + 2e-10, 0.5, 2.0])
    lb = base if desc else base - 1e-11
    ub = base + 1e-11 if desc else base
    definite = np.ones(len(base), bool)
    possible = np.ones(len(base), bool)
    for k in range(1, len(base) + 1):
        want = host_backend().topk_candidates(lb, ub, k, desc, definite,
                                              possible)
        for name in ("device", "mesh"):
            got = get_backend(store, name).topk_candidates(
                lb, ub, k, desc, definite, possible)
            np.testing.assert_array_equal(got, want, err_msg=f"{name} k={k}")
    # and with a mixed definite/possible pattern inside the tie class
    definite2 = np.array([True, False, True, True, True])
    possible2 = np.array([True, True, True, False, True])
    for k in (1, 2, 3):
        want = host_backend().topk_candidates(lb, ub, k, desc, definite2,
                                              possible2)
        for name in ("device", "mesh"):
            got = get_backend(store, name).topk_candidates(
                lb, ub, k, desc, definite2, possible2)
            np.testing.assert_array_equal(got, want, err_msg=f"{name} k={k}")


def test_get_backend_resolution(db):
    store, _ = db
    assert isinstance(get_backend(store, None), HostBackend)
    assert get_backend(store, "host") is get_backend(store)
    dev = get_backend(store, "device")
    assert isinstance(dev, DeviceBackend)
    assert get_backend(store, "device") is dev          # cached per store
    mesh = get_backend(store, "mesh")
    assert isinstance(mesh, MeshBackend)
    assert get_backend(store, mesh) is mesh             # instances pass through
    with pytest.raises(ValueError):
        get_backend(store, "gpu-cluster")


def test_mesh_reaches_distributed_steps(db):
    """Acceptance: core/distributed.py's step functions are the mesh
    backend's physical layer — reachable from run_plan(backend="mesh")."""
    store, rois = db
    be = get_backend(store, "mesh")
    from repro.core import distributed as dist
    assert be._verify_step is not None
    calls = []
    original = be._verify_step

    def spying(*a, **kw):
        calls.append(1)
        return original(*a, **kw)

    be._verify_step = spying
    try:
        plan = LogicalPlan(order_by=CP(None, 0.2, 0.6), k=5)
        run_plan(store, plan, provided_rois=rois, verify_batch=4,
                 backend="mesh")
    finally:
        be._verify_step = original
    assert calls, "mesh execution must verify through distributed steps"
    assert dist.make_verify_step is not None


# -- bitpacked binary-mask tier (DESIGN.md §12) ------------------------------


@pytest.fixture(scope="module")
def packed_db():
    """The same binary masks twice: a float store and a packed store.
    Equality across the pair AND across backends pins the packed tier to
    the float tier's exact semantics."""
    rois = object_boxes(B, H, W, seed=5)
    m, _ = saliency_masks(B, H, W, seed=4, attacked_fraction=0.25, boxes=rois)
    masks = (m > 0.5).astype(np.float32)
    meta = np.zeros(B, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(B)
    meta["image_id"] = np.arange(B) // 2
    meta["mask_type"] = np.arange(B) % 3 + 1
    cfg = CHIConfig(grid=4, num_bins=8, height=H, width=W)
    fstore = MaskStore.create_memory(masks, meta, cfg)
    pstore = MaskStore.create_memory(masks, meta.copy(), cfg, packed=True)
    return fstore, pstore, rois


def test_packed_plans_equivalent_across_backends_and_to_float(packed_db):
    fstore, pstore, rois = packed_db
    rng = np.random.default_rng(14)
    plans = [LogicalPlan(predicate=_random_pred(rng)) for _ in range(4)]
    plans += [LogicalPlan(order_by=_random_expr(rng),
                          k=int(rng.integers(1, B + 2)),
                          desc=bool(rng.integers(2))) for _ in range(4)]
    plans += [
        # binary-meaningful ranges: (0.5, 1.5) selects the set bits
        LogicalPlan(predicate=Cmp(CP((4, 4, 28, 28), 0.5, 1.5), ">", 40.0),
                    order_by=BinOp("/", CP("provided", 0.5, 1.5),
                                   RoiArea("provided")), k=4),
        LogicalPlan(agg="SUM", agg_expr=CP(None, 0.5, 1.5)),
        LogicalPlan(agg="MAX", agg_expr=CP("provided", 0.5, 1.5)),
        LogicalPlan(select="image_id", order_by=AggCP("intersect", 0.5, None),
                    k=6),
        LogicalPlan(select="image_id",
                    order_by=BinOp("/", AggCP("intersect", 0.5, None),
                                   AggCP("union", 0.5, None)),
                    k=6, desc=False),
    ]
    for i, plan in enumerate(plans):
        fouts = _run_all(fstore, plan, rois)
        pouts = _run_all(pstore, plan, rois)
        # packed host ≡ device ≡ mesh
        _assert_equivalent(pouts, f"packed{i}")
        # and the packed pair ≡ the float store (transitively: all six runs)
        _assert_equivalent({"host": fouts["host"], "device": pouts["host"],
                            "mesh": pouts["mesh"]}, f"packed-vs-float{i}")


def test_packed_mesh_uses_fused_verify_step(packed_db):
    """The mesh backend's packed verification goes through the fused
    bounds+verify distributed step — one sharded launch per batch."""
    _, pstore, rois = packed_db
    be = get_backend(pstore, "mesh")
    assert be._packed and be._fused_verify_step is not None
    calls = []
    original = be._fused_verify_step

    def spying(*a, **kw):
        calls.append(1)
        return original(*a, **kw)

    be._fused_verify_step = spying
    try:
        plan = LogicalPlan(order_by=CP((3, 5, 29, 31), 0.5, 1.5), k=5)
        _, stats = run_plan(pstore, plan, provided_rois=rois, verify_batch=4,
                            backend="mesh")
    finally:
        be._fused_verify_step = original
    assert stats.n_verified > 0
    assert len(calls) == stats.n_rounds
