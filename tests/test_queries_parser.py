"""Parser edge-case tests for the SQL-ish front-end (core/queries.py)."""

import numpy as np
import pytest

from repro.core import CHIConfig, MaskStore, engine, queries
from repro.core.exprs import AggCP, BinOp, CP, Const, RoiArea
from repro.core.store import MASK_META_DTYPE
from repro.data.masks import saliency_masks


@pytest.fixture(scope="module")
def small_store():
    b, h, w = 16, 32, 32
    masks = saliency_masks(b, h, w, seed=3)[0]
    meta = np.zeros(b, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(b)
    meta["image_id"] = np.arange(b) // 2
    meta["mask_type"] = np.arange(b) % 2 + 1
    cfg = CHIConfig(grid=4, num_bins=8, height=h, width=w)
    return MaskStore.create_memory(masks, meta, cfg)


# -- ORDER BY aliases --------------------------------------------------------

def test_order_by_alias_resolves_to_expression():
    q = queries.parse(
        "SELECT image_id, CP(intersect(mask > 0.8), full_img, (0.5, 2.0)) "
        "/ CP(union(mask > 0.8), full_img, (0.5, 2.0)) AS iou "
        "FROM V GROUP BY image_id ORDER BY iou ASC LIMIT 7;")
    assert q.kind == "topk" and q.k == 7 and q.desc is False
    assert q.group_by_image
    assert isinstance(q.expr, BinOp) and q.expr.op == "/"
    assert isinstance(q.expr.left, AggCP) and q.expr.left.agg == "intersect"
    assert isinstance(q.expr.right, AggCP) and q.expr.right.agg == "union"


def test_order_by_inline_expression_and_desc_default():
    q = queries.parse("SELECT mask_id FROM V "
                      "ORDER BY CP(mask, full_img, (0.2, 0.6)) LIMIT 3;")
    assert q.kind == "topk" and q.desc is True      # DESC is the default
    q = queries.parse("SELECT mask_id FROM V "
                      "ORDER BY CP(mask, full_img, (0.2, 0.6)) DESC LIMIT 3;")
    assert q.desc is True


# -- WHERE: mask_type IN + AND chains ---------------------------------------

def test_mask_type_in_with_predicate_and_chain(small_store):
    q = queries.parse(
        "SELECT mask_id FROM V WHERE mask_type IN (1, 2) AND "
        "CP(mask, full_img, (0.0, 1.0)) >= 0;")
    assert q.mask_types == (1, 2)
    assert q.op == ">=" and q.threshold == 0
    ids, _ = q.run(small_store)
    assert len(ids) == len(small_store)             # trivially-true predicate

    # order-independent: predicate first, mask_type second
    q2 = queries.parse(
        "SELECT mask_id FROM V WHERE CP(mask, full_img, (0.0, 1.0)) >= 0 "
        "AND mask_type IN (2);")
    assert q2.mask_types == (2,)
    ids2, _ = q2.run(small_store)
    types = small_store.meta["mask_type"][small_store.positions_of(ids2)]
    assert np.all(types == 2)


def test_multiple_cp_predicates_combine(small_store):
    """Formerly a documented hard rejection; now an And tree in the IR."""
    from repro.core.exprs import And, Cmp
    q = queries.parse("SELECT mask_id FROM V WHERE "
                      "CP(mask, full_img, (0.0, 0.5)) > 1 AND "
                      "CP(mask, full_img, (0.5, 1.0)) > 1;")
    assert q.kind == "filter"
    assert isinstance(q.predicate, And)
    assert isinstance(q.predicate.left, Cmp)
    assert isinstance(q.predicate.right, Cmp)
    ids, stats = q.run(small_store)
    ids_scan, _ = q.run(small_store, use_index=False)
    assert set(int(x) for x in ids) == set(int(x) for x in ids_scan)


def test_cp_predicate_composes_with_order_by(small_store):
    """Formerly a documented hard rejection; now a filtered_topk plan."""
    q = queries.parse(
        "SELECT mask_id FROM V WHERE CP(mask, full_img, (0.5, 1.0)) > 100 "
        "ORDER BY CP(mask, full_img, (0.0, 0.5)) DESC LIMIT 5;")
    assert q.kind == "filtered_topk" and q.k == 5
    (ids, scores), _ = q.run(small_store)
    (ids0, scores0), _ = q.run(small_store, use_index=False)
    assert list(ids) == list(ids0)
    np.testing.assert_allclose(scores, scores0)


def test_or_not_and_parens(small_store):
    from repro.core.exprs import Cmp, Not, Or
    q = queries.parse(
        "SELECT mask_id FROM V WHERE CP(mask, full_img, (0.0, 0.5)) > 1e2 "
        "OR NOT (CP(mask, full_img, (0.5, 1.0)) >= -5 "
        "AND mask_type IN (1));")
    assert q.kind == "filter"
    assert isinstance(q.predicate, Or)
    assert isinstance(q.predicate.right, Not)
    ids, _ = q.run(small_store)
    ids_scan, _ = q.run(small_store, use_index=False)
    assert set(int(x) for x in ids) == set(int(x) for x in ids_scan)
    # parenthesized arithmetic still parses as an expression comparison
    q2 = queries.parse("SELECT mask_id FROM V WHERE "
                       "(CP(mask, full_img, (0.0, 0.5)) + 3) > 5;")
    assert isinstance(q2.predicate, Cmp)


def test_unary_minus_and_scientific_notation(small_store):
    from repro.core.exprs import BinOp, Const
    q = queries.parse("SELECT mask_id FROM V WHERE "
                      "-1 * CP(mask, full_img, (0.0, 0.5)) < 1e4;")
    assert isinstance(q.expr, BinOp) and q.expr.op == "*"
    assert isinstance(q.expr.left, Const) and q.expr.left.value == -1.0
    assert q.threshold == 1e4
    ids, _ = q.run(small_store)
    assert len(ids) == len(small_store)      # -CP is always < 1e4
    q2 = queries.parse("SELECT mask_id FROM V WHERE "
                       "CP(mask, full_img, (0.0, 1.0)) >= -2.5e-1;")
    assert q2.threshold == -0.25


# -- literal ROI rectangles --------------------------------------------------

def test_literal_roi_rectangle(small_store):
    q = queries.parse("SELECT mask_id FROM V WHERE "
                      "CP(mask, (4, 4, 28, 28), (0.5, 1.0)) >= 0;")
    assert isinstance(q.expr, CP) and q.expr.roi == (4, 4, 28, 28)
    ids_q, _ = q.run(small_store)
    ids_e, _ = engine.filter_query(small_store, CP((4, 4, 28, 28), 0.5, 1.0),
                                   ">=", 0)
    assert set(ids_q) == set(ids_e)


def test_roi_area_and_arithmetic():
    q = queries.parse("SELECT mask_id FROM V WHERE "
                      "CP(mask, roi, (0.8, 1.0)) / AREA(roi) "
                      "+ 0.5 * CP(mask, roi, (0.0, 0.2)) < 10;")
    assert isinstance(q.expr, BinOp) and q.expr.op == "+"
    assert isinstance(q.expr.left.right, RoiArea)
    assert isinstance(q.expr.right.left, Const)
    assert q.expr.right.left.value == 0.5


# -- SCALAR_AGG forms --------------------------------------------------------

@pytest.mark.parametrize("agg", ["SUM", "AVG", "MIN", "MAX"])
def test_scalar_agg_forms(small_store, agg):
    q = queries.parse(f"SELECT SCALAR_AGG({agg}, "
                      "CP(mask, full_img, (0.4, 0.8))) FROM V;")
    assert q.kind == "scalar_agg" and q.agg == agg
    value, _ = q.run(small_store)
    want, _ = engine.scalar_agg(small_store, CP(None, 0.4, 0.8), agg)
    assert abs(value - want) < 1e-9


def test_scalar_agg_case_insensitive():
    q = queries.parse("SELECT SCALAR_AGG(avg, "
                      "CP(mask, full_img, (0.0, 1.0))) FROM V;")
    assert q.agg == "AVG"


# -- malformed queries -------------------------------------------------------

@pytest.mark.parametrize("sql", [
    "SELECT mask_id FROM V ORDER BY CP(mask, full_img, (0.2, 0.6));",  # no LIMIT
    "SELECT mask_id FROM V;",                       # filter without predicate
    "SELECT mask_id FROM V WHERE CP(mask, roi) < 5;",      # CP arity
    "SELECT mask_id FROM V WHERE CP(mask, roi, (0.5, 1.0)) = 5;",  # bad op
    "SELECT mask_id FROM V WHERE CP(mask, bogus, (0.5, 1.0)) < 5;",  # bad ROI
    "SELECT nothing FROM V;",                       # bad select column
    "SELECT mask_id FROM V WHERE mask_type IN 1;",  # IN without parens
    "SELECT mask_id FROM V GROUP BY mask_id;",      # can only group by image
    "SELECT",                                       # truncated
    # boolean-grammar malformations
    "SELECT mask_id FROM V WHERE CP(mask, full_img, (0.5, 1.0)) > 100 AND;",
    "SELECT mask_id FROM V WHERE NOT;",             # NOT without operand
    "SELECT mask_id FROM V WHERE (CP(mask, full_img, (0.5, 1.0)) > 1;",
    "SELECT mask_id FROM V WHERE CP(mask, full_img, (0.5, 1.0)) > 1 "
    "LIMIT 5;",                                     # trailing tokens
    # negative LIMIT: unary-minus literals must not leak into k
    "SELECT mask_id FROM V ORDER BY CP(mask, full_img, (0.2, 0.6)) "
    "DESC LIMIT -5;",
    # grouped ranking cannot mix in per-mask CP terms
    "SELECT image_id FROM V WHERE CP(mask, full_img, (0.5, 1.0)) > 10 "
    "GROUP BY image_id ORDER BY "
    "CP(union(mask > 0.5), full_img, (0.0, 1.0)) DESC LIMIT 3;",
    "SELECT mask_id FROM V WHERE ",                 # ends where expr expected
    "SELECT mask_id FROM V ORDER BY ",              # ends where expr expected
    "SELECT mask_id FROM V WHERE CP(",              # ends inside CP(
])
def test_malformed_queries_raise_syntaxerror(sql):
    with pytest.raises(SyntaxError):
        queries.parse(sql)


def test_image_id_select_implies_grouping():
    q = queries.parse("SELECT image_id FROM V ORDER BY "
                      "CP(mask, full_img, (0.2, 0.6)) DESC LIMIT 4;")
    assert q.group_by_image
