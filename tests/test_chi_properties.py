"""Property-based tests (hypothesis) for the system's core invariants:

  1. CHI bounds are sound for arbitrary masks/ROIs/value ranges.
  2. Aligned queries are answered exactly (lower == upper).
  3. Engine results ≡ brute-force full scan for all query classes.
  4. Interval arithmetic on expressions preserves soundness.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis (dev extra)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import chi, cp
from repro.core.exprs import CP, BinOp, RoiArea


def _mask_batch(seed, b, h, w, style):
    rng = np.random.default_rng(seed)
    if style == 0:      # uniform noise
        return rng.random((b, h, w), dtype=np.float32)
    if style == 1:      # blobby (spatially coherent)
        from repro.data.masks import saliency_masks
        return saliency_masks(b, h, w, seed=seed)[0]
    if style == 2:      # near-binary
        return (rng.random((b, h, w)) > 0.5).astype(np.float32) * 0.999
    return np.zeros((b, h, w), np.float32)  # constant


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    style=st.integers(0, 3),
    grid=st.sampled_from([2, 4, 8]),
    nb=st.sampled_from([2, 4, 16]),
    hw=st.tuples(st.integers(8, 48), st.integers(8, 48)),
    roi=st.tuples(st.floats(0, 1), st.floats(0, 1), st.floats(0, 1),
                  st.floats(0, 1)),
    vrange=st.tuples(st.floats(0, 1), st.floats(0, 1)),
)
def test_bounds_sound(seed, style, grid, nb, hw, roi, vrange):
    h, w = hw
    b = 4
    masks = _mask_batch(seed, b, h, w, style)
    cfg = chi.CHIConfig(grid=grid, num_bins=nb, height=h, width=w)
    table = chi.build_chi_np(masks, cfg)
    r0 = int(roi[0] * h); r1 = int(roi[2] * h)
    c0 = int(roi[1] * w); c1 = int(roi[3] * w)
    r0, r1 = min(r0, r1), max(r0, r1)
    c0, c1 = min(c0, c1), max(c0, c1)
    lv, uv = sorted(vrange)
    rois = np.tile([r0, c0, r1, c1], (b, 1))
    lb, ub = chi.chi_bounds(np.asarray(table), cfg, rois, lv, uv)
    lb, ub = np.asarray(lb), np.asarray(ub)
    exact = np.array([cp.cp_exact_np(m, (r0, c0, r1, c1), lv, uv)
                      for m in masks])
    assert np.all(lb <= exact), (lb, exact)
    assert np.all(exact <= ub), (exact, ub)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    grid=st.sampled_from([2, 4, 8]),
    nb=st.sampled_from([4, 8]),
    cells=st.tuples(st.integers(0, 8), st.integers(0, 8), st.integers(0, 8),
                    st.integers(0, 8)),
    bins=st.tuples(st.integers(0, 8), st.integers(0, 8)),
)
def test_aligned_queries_exact(seed, grid, nb, cells, bins):
    h = w = 32
    masks = _mask_batch(seed, 3, h, w, 1)
    cfg = chi.CHIConfig(grid=grid, num_bins=nb, height=h, width=w)
    table = chi.build_chi_np(masks, cfg)
    rb, cb, edges = cfg.row_bounds, cfg.col_bounds, cfg.edges
    i0, i1 = sorted((cells[0] % (grid + 1), cells[1] % (grid + 1)))
    j0, j1 = sorted((cells[2] % (grid + 1), cells[3] % (grid + 1)))
    k0, k1 = sorted((1 + bins[0] % (nb - 1), 1 + bins[1] % (nb - 1)))
    roi = (int(rb[i0]), int(cb[j0]), int(rb[i1]), int(cb[j1]))
    lv, uv = float(edges[k0]), float(edges[k1])
    rois = np.tile(roi, (3, 1))
    lb, ub = chi.chi_bounds(np.asarray(table), cfg, rois, lv, uv)
    assert np.array_equal(np.asarray(lb), np.asarray(ub))
    exact = np.array([cp.cp_exact_np(m, roi, lv, uv) for m in masks])
    assert np.array_equal(np.asarray(lb), exact)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    op=st.sampled_from(["<", "<=", ">", ">="]),
    frac=st.floats(0.0, 1.0),
    expr_kind=st.integers(0, 2),
)
def test_filter_matches_full_scan(seed, op, frac, expr_kind):
    from repro.core import engine, store
    from repro.data.masks import object_boxes, saliency_masks
    b, h, w = 24, 32, 32
    masks = saliency_masks(b, h, w, seed=seed)[0]
    rois = object_boxes(b, h, w, seed=seed + 1)
    meta = np.zeros(b, store.MASK_META_DTYPE)
    meta["mask_id"] = np.arange(b)
    meta["image_id"] = np.arange(b)
    cfg = chi.CHIConfig(grid=4, num_bins=8, height=h, width=w)
    st_ = store.MaskStore.create_memory(masks, meta, cfg)
    exprs = [CP("provided", 0.6, 1.0),
             BinOp("/", CP("provided", 0.6, 1.0), RoiArea("provided")),
             BinOp("+", CP(None, 0.0, 0.3), CP(None, 0.7, 1.0))]
    expr = exprs[expr_kind]
    tmax = (h * w) if expr_kind != 1 else 1.0
    thr = frac * tmax
    ids_i, _ = engine.filter_query(st_, expr, op, thr, provided_rois=rois)
    ids_s, _ = engine.filter_query(st_, expr, op, thr, provided_rois=rois,
                                   use_index=False)
    assert set(ids_i) == set(ids_s)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 20),
       desc=st.booleans())
def test_topk_matches_full_scan(seed, k, desc):
    from repro.core import engine, store
    from repro.data.masks import saliency_masks
    b, h, w = 30, 32, 32
    masks = saliency_masks(b, h, w, seed=seed)[0]
    meta = np.zeros(b, store.MASK_META_DTYPE)
    meta["mask_id"] = np.arange(b)
    meta["image_id"] = np.arange(b)
    cfg = chi.CHIConfig(grid=4, num_bins=8, height=h, width=w)
    st_ = store.MaskStore.create_memory(masks, meta, cfg)
    expr = CP(None, 0.5, 0.9)
    _, sc_i, _ = engine.topk_query(st_, expr, k, desc=desc, verify_batch=7)
    _, sc_s, _ = engine.topk_query(st_, expr, k, desc=desc, use_index=False)
    np.testing.assert_allclose(np.sort(sc_i), np.sort(sc_s))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       agg=st.sampled_from(["SUM", "AVG", "MIN", "MAX"]))
def test_scalar_agg_matches_full_scan(seed, agg):
    from repro.core import engine, store
    from repro.data.masks import saliency_masks
    b, h, w = 16, 32, 32
    masks = saliency_masks(b, h, w, seed=seed)[0]
    meta = np.zeros(b, store.MASK_META_DTYPE)
    meta["mask_id"] = np.arange(b)
    meta["image_id"] = np.arange(b)
    cfg = chi.CHIConfig(grid=4, num_bins=8, height=h, width=w)
    st_ = store.MaskStore.create_memory(masks, meta, cfg)
    expr = CP(None, 0.4, 0.8)
    v_i, _ = engine.scalar_agg(st_, expr, agg)
    v_s, _ = engine.scalar_agg(st_, expr, agg, use_index=False)
    assert abs(v_i - v_s) < 1e-6 * max(abs(v_s), 1)
