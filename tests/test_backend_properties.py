"""Property tests (hypothesis): the three execution backends agree on
randomly generated plans — identical ids/scores and identical
``n_verified`` accounting.  The seeded-numpy fallback of this suite lives
in ``test_backend_equivalence.py``.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed in this environment")

from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from repro.core import CHIConfig, MaskStore  # noqa: E402
from repro.core.exprs import (And, BinOp, Cmp, CP, Not, Or,  # noqa: E402
                              RoiArea)
from repro.core.plan import LogicalPlan, run_plan  # noqa: E402
from repro.core.store import MASK_META_DTYPE  # noqa: E402
from repro.data.masks import object_boxes, saliency_masks  # noqa: E402

B, H, W = 20, 32, 32
BACKENDS = ("host", "device", "mesh")

_STORE = {}


def _db():
    """Module-lazy store (hypothesis re-enters the test many times); the
    device/mesh backends stay cached on the store across examples."""
    if "store" not in _STORE:
        rois = object_boxes(B, H, W, seed=5)
        masks, _ = saliency_masks(B, H, W, seed=4, attacked_fraction=0.25,
                                  boxes=rois)
        meta = np.zeros(B, MASK_META_DTYPE)
        meta["mask_id"] = np.arange(B)
        meta["image_id"] = np.arange(B) // 2
        meta["mask_type"] = np.arange(B) % 2 + 1
        cfg = CHIConfig(grid=4, num_bins=8, height=H, width=W)
        _STORE["store"] = MaskStore.create_memory(masks, meta, cfg)
        _STORE["rois"] = rois
    return _STORE["store"], _STORE["rois"]


_ranges = st.sampled_from([(0.0, 0.3), (0.2, 0.6), (0.5, 1.0), (0.8, 1.0)])
_rois = st.sampled_from([None, "provided", (4, 4, 28, 28)])


@st.composite
def _exprs(draw):
    lv, uv = draw(_ranges)
    roi = draw(_rois)
    base = CP(roi, lv, uv)
    shape = draw(st.integers(0, 3))
    if shape == 1:
        return BinOp("/", base, RoiArea(roi))
    if shape == 2:
        lv2, uv2 = draw(_ranges)
        return BinOp(draw(st.sampled_from("+-*")), base,
                     CP(draw(_rois), lv2, uv2))
    return base


@st.composite
def _cmps(draw):
    return Cmp(draw(_exprs()), draw(st.sampled_from(["<", "<=", ">", ">="])),
               draw(st.sampled_from([0.0, 0.02, 10.0, 100.0, 400.0])))


_preds = st.recursive(
    _cmps(),
    lambda children: st.one_of(
        st.builds(And, children, children),
        st.builds(Or, children, children),
        st.builds(Not, children),
    ),
    max_leaves=4,
)

_SETTINGS = settings(max_examples=25, deadline=None,
                     suppress_health_check=[HealthCheck.too_slow])


def _assert_backends_agree(plan):
    store, rois = _db()
    outs = {name: run_plan(store, plan, provided_rois=rois, verify_batch=4,
                           backend=name) for name in BACKENDS}
    payload0, stats0 = outs["host"]
    for name in ("device", "mesh"):
        payload, stats = outs[name]
        if isinstance(payload0, tuple):
            assert list(payload[0]) == list(payload0[0]), name
            np.testing.assert_allclose(payload[1], payload0[1])
        else:
            assert list(payload) == list(payload0), name
        assert stats.n_verified == stats0.n_verified, name
        assert stats.n_decided_by_bounds == stats0.n_decided_by_bounds, name


@_SETTINGS
@given(pred=_preds)
def test_filter_backends_agree(pred):
    _assert_backends_agree(LogicalPlan(predicate=pred))


@_SETTINGS
@given(rank=_exprs(), desc=st.booleans(), k=st.integers(1, B + 2))
def test_ranking_backends_agree(rank, desc, k):
    _assert_backends_agree(LogicalPlan(order_by=rank, k=k, desc=desc))


@_SETTINGS
@given(pred=_preds, rank=_exprs(), desc=st.booleans(),
       k=st.integers(1, B + 2))
def test_filtered_topk_backends_agree(pred, rank, desc, k):
    _assert_backends_agree(
        LogicalPlan(predicate=pred, order_by=rank, k=k, desc=desc))


# -- mutation sequences (epoch-versioned store) ------------------------------

_ops = st.lists(
    st.one_of(
        st.tuples(st.just("append"), st.integers(1, 3), st.integers(0, 9)),
        st.tuples(st.just("update"), st.integers(1, 3), st.integers(0, 9)),
        st.tuples(st.just("delete"), st.integers(1, 2), st.integers(0, 9)),
    ),
    min_size=1, max_size=5)


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=_ops)
def test_mutation_sequences_preserve_index_and_results(ops):
    """Any interleaving of append/update/delete leaves the chunked CHI
    equal to a from-scratch ``build_chi_np`` and query results equal to a
    freshly built store over the same bytes."""
    from repro.core.chi import build_chi_np

    rng = np.random.default_rng(7)
    masks0, _ = saliency_masks(12, H, W, seed=2, attacked_fraction=0.3,
                               boxes=object_boxes(12, H, W, seed=3))
    meta0 = np.zeros(12, MASK_META_DTYPE)
    meta0["mask_id"] = np.arange(12)
    cfg = CHIConfig(grid=4, num_bins=8, height=H, width=W)
    store = MaskStore.create_memory(masks0, meta0, cfg)
    current = np.asarray(masks0, np.float32).copy()
    ids = list(range(12))
    next_id = 100
    for kind, n, seed in ops:
        if kind == "append":
            add = rng.random((n, H, W)).astype(np.float32)
            meta = np.zeros(n, MASK_META_DTYPE)
            meta["mask_id"] = next_id + np.arange(n)
            next_id += n
            store.append(add, meta)
            current = np.concatenate([current, add])
            ids.extend(meta["mask_id"])
        elif kind == "update":
            sel = (np.arange(n) * (seed + 1)) % len(ids)
            sel = np.unique(sel)
            new = rng.random((len(sel), H, W)).astype(np.float32)
            store.update([ids[i] for i in sel], new)
            current[sel] = new
        else:
            if len(ids) <= 3:
                continue
            sel = np.unique((np.arange(n) * (seed + 1)) % len(ids))
            store.delete([ids[i] for i in sel])
            keep = np.ones(len(ids), bool)
            keep[sel] = False
            current = current[keep]
            ids = [m for i, m in enumerate(ids) if keep[i]]
    np.testing.assert_array_equal(store.chi_host(),
                                  build_chi_np(current, cfg))
    meta = np.zeros(len(ids), MASK_META_DTYPE)
    meta["mask_id"] = ids
    fresh = MaskStore.create_memory(current, meta, cfg)
    plan = LogicalPlan(order_by=CP(None, 0.2, 0.6), k=min(5, len(ids)))
    (got_ids, got_scores), _ = run_plan(store, plan)
    (ref_ids, ref_scores), _ = run_plan(fresh, plan)
    np.testing.assert_array_equal(got_ids, ref_ids)
    np.testing.assert_array_equal(got_scores, ref_scores)
