"""Dual-mask (pair) operator tests — DESIGN.md §9's acceptance contract.

Seeded tests run everywhere; the hypothesis-decorated variants (guarded, so
this file still runs where hypothesis is absent) sweep random thresholds,
ROIs and plan shapes.  Key invariants:

  * the pair kernel (Pallas interpret) ≡ the jnp reference ≡ a numpy oracle;
  * cell-decomposed pair bounds always contain the exact pairwise count and
    never exceed the area-level combination-rule envelope;
  * host / device / mesh return bit-identical pair top-k ids AND scores,
    with identical verification accounting;
  * every indexed pair plan ≡ the decode-all-pairs naive scan;
  * pair queries flow through the SQL grammar, the service (sessions,
    result cache, fused batches) and the mutation/epoch machinery.
"""

import numpy as np
import pytest

from repro.core import CHIConfig, MaskStore, queries
from repro.core.engine import _make_context
from repro.core.exprs import (Cmp, CP, PairTerm, pair_iou, pair_stat_bounds)
from repro.core.plan import LogicalPlan, run_plan
from repro.core.store import MASK_META_DTYPE
from repro.data.masks import object_boxes, saliency_masks
from repro.kernels import ops as kops
from repro.kernels import ref as kref

try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                # pragma: no cover
    HAVE_HYPOTHESIS = False

N_IMG, H, W = 30, 32, 32
BACKENDS = ("host", "device", "mesh")

_STORE = {}


def _db():
    """Module-lazy store: per image a (saliency, attention) pair, with a
    planted misaligned minority (off-object attention)."""
    if "store" not in _STORE:
        rng = np.random.default_rng(8)
        boxes = object_boxes(N_IMG, H, W, seed=4)
        model, _ = saliency_masks(N_IMG, H, W, seed=5, boxes=boxes,
                                  in_box_fraction=1.0)
        off, _ = saliency_masks(N_IMG, H, W, seed=7, boxes=None)
        mis = rng.random(N_IMG) < 0.3
        human = np.where(mis[:, None, None], off,
                         np.clip(0.9 * model, 0.0, 1.0 - 1e-6))
        masks = np.stack([model, human], axis=1).reshape(-1, H, W)
        n = len(masks)
        meta = np.zeros(n, MASK_META_DTYPE)
        meta["mask_id"] = np.arange(n)
        meta["image_id"] = np.arange(n) // 2
        meta["mask_type"] = np.arange(n) % 2 + 1
        cfg = CHIConfig(grid=4, num_bins=8, height=H, width=W)
        _STORE["store"] = MaskStore.create_memory(masks, meta, cfg)
        _STORE["rois"] = np.repeat(boxes, 2, axis=0)
    return _STORE["store"], _STORE["rois"]


# ---------------------------------------------------------------------------
# Kernel
# ---------------------------------------------------------------------------


def test_pair_kernel_matches_reference_and_oracle():
    rng = np.random.default_rng(0)
    a = rng.random((7, H, W)).astype(np.float32)
    b = rng.random((7, H, W)).astype(np.float32)
    rois = np.array([[0, 0, H, W], [4, 4, 28, 28], [0, 0, 16, W],
                     [8, 0, H, 16], [2, 3, 5, 7], [0, 0, 0, 0],
                     [30, 30, H, W]], np.int32)
    ta, tb = np.float32(0.55), np.float32(0.3)
    ref = [np.asarray(x) for x in kref.pair_counts_ref(a, b, rois, ta, tb)]
    pal = [np.asarray(x) for x in kops.pair_counts(
        a, b, rois, ta, tb, use_pallas=True, interpret=True)]
    jnp_path = [np.asarray(x) for x in kops.pair_counts(
        a, b, rois, ta, tb, use_pallas=False)]
    ba, bb = a > ta, b > tb
    for i, (r0, c0, r1, c1) in enumerate(rois):
        wa, wb = ba[i, r0:r1, c0:c1], bb[i, r0:r1, c0:c1]
        assert ref[0][i] == np.sum(wa & wb)
        assert ref[1][i] == np.sum(wa | wb)
        assert ref[2][i] == np.sum(wa & ~wb)
    for r, p, j in zip(ref, pal, jnp_path):
        np.testing.assert_array_equal(r, p)
        np.testing.assert_array_equal(r, j)


# ---------------------------------------------------------------------------
# Bounds soundness
# ---------------------------------------------------------------------------


def _check_bounds_sound(term, rois):
    store, _ = _db()
    ctx, ids, _ = _make_context(store, [term], False, None, None, rois)
    lb, ub = ctx.bounds(term)
    exact = ctx.exact(term, np.arange(len(ids)))
    assert np.all(lb <= exact), (term, (lb - exact).max())
    assert np.all(exact <= ub), (term, (exact - ub).max())
    # the cell decomposition must stay inside the area-level envelope
    area = np.asarray(
        ctx.pair_rois(term.roi), np.int64)
    area = np.maximum(area[:, 2] - area[:, 0], 0) * \
        np.maximum(area[:, 3] - area[:, 1], 0)
    glb, gub = pair_stat_bounds(term.stat, np.zeros(len(ids)), area,
                                np.zeros(len(ids)), area,
                                area.astype(np.float64))
    assert np.all(lb >= glb) and np.all(ub <= gub)


@pytest.mark.parametrize("stat", ["inter", "union", "diff"])
@pytest.mark.parametrize("roi", [None, "provided", (5, 3, 29, 27)])
@pytest.mark.parametrize("ta,tb", [(0.3, 0.6), (0.5, 0.5), (0.8, 0.2)])
def test_pair_bounds_contain_exact(stat, roi, ta, tb):
    _, rois = _db()
    _check_bounds_sound(PairTerm(stat, 1, 2, ta, tb, roi), rois)


def test_pair_bounds_sound_at_bin_edges():
    """Thresholds exactly on CHI bin edges and mask values exactly at the
    threshold — the measure-zero case the nextafter resolution covers."""
    edge = 0.5   # an interior edge of the 8-bin config
    rng = np.random.default_rng(1)
    masks = rng.choice(np.float32([0.25, edge, 0.75]),
                       size=(8, H, W)).astype(np.float32)
    meta = np.zeros(8, MASK_META_DTYPE)
    meta["mask_id"] = np.arange(8)
    meta["image_id"] = np.arange(8) // 2
    meta["mask_type"] = np.arange(8) % 2 + 1
    cfg = CHIConfig(grid=4, num_bins=8, height=H, width=W)
    store = MaskStore.create_memory(masks, meta, cfg)
    for stat in ("inter", "union", "diff"):
        term = PairTerm(stat, 1, 2, edge, edge, None)
        ctx, ids, _ = _make_context(store, [term], False, None, None, None)
        lb, ub = ctx.bounds(term)
        exact = ctx.exact(term, np.arange(len(ids)))
        assert np.all(lb <= exact) and np.all(exact <= ub), stat


# ---------------------------------------------------------------------------
# Backend equivalence + naive-scan equivalence
# ---------------------------------------------------------------------------


def _assert_backends_and_naive_agree(plan, rois=None):
    store, _ = _db()
    outs = {name: run_plan(store, plan, provided_rois=rois, verify_batch=4,
                           backend=name) for name in BACKENDS}
    payload0, stats0 = outs["host"]
    for name in ("device", "mesh"):
        payload, stats = outs[name]
        if isinstance(payload0, tuple):
            assert list(payload[0]) == list(payload0[0]), name
            np.testing.assert_array_equal(payload[1], payload0[1])
        elif isinstance(payload0, float):
            np.testing.assert_allclose(payload, payload0)
        else:
            assert list(payload) == list(payload0), name
        assert stats.n_verified == stats0.n_verified, name
        assert stats.n_decided_by_bounds == stats0.n_decided_by_bounds, name
    naive, _ = run_plan(store, plan, provided_rois=rois, use_index=False)
    if isinstance(payload0, tuple):
        assert list(naive[0]) == list(payload0[0])
        np.testing.assert_allclose(naive[1], payload0[1])
    elif isinstance(payload0, float):
        np.testing.assert_allclose(naive, payload0)
    else:
        assert list(naive) == list(payload0)


def test_pair_iou_topk_bit_identical_across_backends():
    _assert_backends_and_naive_agree(
        LogicalPlan(order_by=pair_iou(1, 2, 0.6, 0.6), k=5, desc=False))


def test_pair_filtered_topk_across_backends():
    _, rois = _db()
    plan = LogicalPlan(
        predicate=Cmp(PairTerm("diff", 1, 2, 0.5, 0.5, None), ">", 30.0),
        order_by=PairTerm("inter", 1, 2, 0.5, 0.5, "provided"),
        k=6, desc=True)
    _assert_backends_and_naive_agree(plan, rois=rois)


def test_pair_filter_and_scalar_agg_across_backends():
    _assert_backends_and_naive_agree(
        LogicalPlan(predicate=Cmp(PairTerm("union", 1, 2, 0.4, 0.4, None),
                                  "<", 400.0)))
    _assert_backends_and_naive_agree(
        LogicalPlan(agg="AVG", agg_expr=pair_iou(1, 2, 0.6, 0.6)))


def test_pair_candidates_are_role_matched_images():
    """Images missing one role never become candidates; extra masks per
    (image, role) are excluded deterministically and accounted."""
    store, _ = _db()
    rng = np.random.default_rng(2)
    extra = rng.random((3, H, W)).astype(np.float32)
    meta = np.zeros(3, MASK_META_DTYPE)
    meta["mask_id"] = 900 + np.arange(3)
    # image 500 exists only in role 1; image 0 gets a duplicate role-1 mask
    meta["image_id"] = [500, 500, 0]
    meta["mask_type"] = [1, 1, 1]
    masks = np.concatenate([np.asarray(store._masks), extra])
    allmeta = np.concatenate([store.meta, meta])
    cfg = store.cfg
    s2 = MaskStore.create_memory(masks, allmeta, cfg)
    term = PairTerm("inter", 1, 2, 0.5, 0.5, None)
    ctx, ids, n_dropped = _make_context(s2, [term], False, None, None, None)
    assert 500 not in ids
    assert len(ids) == N_IMG
    assert n_dropped == 3            # 2 partner-less + 1 duplicate
    # the duplicate (higher position) must not displace image 0's original
    assert ctx.pos_a[list(ids).index(0)] == 0


# ---------------------------------------------------------------------------
# Plan validation + SQL grammar
# ---------------------------------------------------------------------------


def test_pair_plan_validation():
    iou = pair_iou(1, 2, 0.5, 0.5)
    with pytest.raises(ValueError, match="single"):
        LogicalPlan(order_by=iou / PairTerm("inter", 1, 3, 0.5, 0.5, None),
                    k=5).validate()
    with pytest.raises(ValueError, match="cannot mix"):
        LogicalPlan(order_by=iou / CP(None, 0.2, 0.6), k=5).validate()
    with pytest.raises(ValueError, match="role"):
        LogicalPlan(order_by=iou, k=5, mask_types=(1, 2)).validate()
    from repro.core.exprs import TypeIn
    with pytest.raises(ValueError, match="role"):
        LogicalPlan(order_by=iou, k=5,
                    predicate=TypeIn((1,))).validate()
    # select normalizes for pure pair plans
    assert LogicalPlan(order_by=iou, k=5).select == "image_id"
    with pytest.raises(ValueError):
        PairTerm("bogus", 1, 2, 0.5, 0.5, None)


def test_engine_level_pair_calls_validate_like_plans():
    """Engine one-shots bypass LogicalPlan.validate; they must still raise
    the same clear errors instead of silently dropping restrictions."""
    from repro.core import engine
    store, _ = _db()
    term = PairTerm("inter", 1, 2, 0.5, 0.5, None)
    with pytest.raises(ValueError, match="role"):
        engine.filter_query(store, Cmp(term, ">", 10.0), mask_types=(1,))
    with pytest.raises(ValueError, match="cannot mix"):
        engine.topk_query(store, term + CP(None, 0.2, 0.6), 3)


def test_pair_sql_grammar_roundtrip():
    q = queries.parse(queries.SCENARIO6_DISCREPANCY)
    assert q.plan.paired and q.plan.kind == "topk" and not q.plan.desc
    assert q.plan.select == "image_id"
    roles = {t.role_a for t in q.plan.order_by.cp_terms()} | \
        {t.role_b for t in q.plan.order_by.cp_terms()}
    assert roles == {1, 2}

    q2 = queries.parse(
        "SELECT image_id FROM MasksDatabaseView "
        "WHERE PAIR_DIFF(1, 2, 0.6, 0.6) > 100 "
        "ORDER BY PAIR_INTER(saliency, attention, 0.6, 0.6, roi) ASC "
        "LIMIT 7;")
    assert q2.plan.kind == "filtered_topk" and q2.plan.paired
    term = q2.plan.order_by
    assert term.stat == "inter" and term.roi == "provided"

    with pytest.raises(SyntaxError):
        queries.parse("SELECT image_id FROM V ORDER BY "
                      "IOU(nonsense_role, attention, 0.5, 0.5) ASC LIMIT 5;")


def test_pair_sql_executes_like_programmatic_plan():
    store, rois = _db()
    (ids_sql, scores_sql), _ = queries.run(
        queries.SCENARIO6_DISCREPANCY.replace("LIMIT 25", "LIMIT 5"),
        store)
    plan = LogicalPlan(order_by=pair_iou(1, 2, 0.6, 0.6), k=5, desc=False)
    (ids_pl, scores_pl), _ = run_plan(store, plan)
    assert list(ids_sql) == list(ids_pl)
    np.testing.assert_array_equal(scores_sql, scores_pl)


# ---------------------------------------------------------------------------
# Service integration: sessions, fused batches, epochs
# ---------------------------------------------------------------------------


def _fresh_service(**kw):
    from repro.service import MaskSearchService
    store, rois = _db()
    # fresh memory store per service so epochs/caches don't leak across tests
    s = MaskStore.create_memory(np.asarray(store._masks).copy(),
                                store.meta.copy(), store.cfg)
    return MaskSearchService(s, provided_rois=rois, **kw)


PAIR_SQL = ("SELECT image_id FROM MasksDatabaseView "
            "ORDER BY IOU(saliency, attention, 0.6, 0.6) ASC LIMIT 6;")


def test_pair_session_pagination_matches_oneshot():
    svc = _fresh_service(verify_batch=4)
    one = svc.query(PAIR_SQL)
    page = svc.query(PAIR_SQL, session=True, page_size=3)
    paged = list(page["page"]["ids"])
    paged += list(svc.next_page(page["session"])["page"]["ids"])
    assert paged == one["ids"]
    svc.close()


def test_pair_queries_fuse_in_batches():
    svc = _fresh_service(verify_batch=4)
    sqls = [PAIR_SQL,
            "SELECT image_id FROM MasksDatabaseView "
            "WHERE PAIR_DIFF(saliency, attention, 0.5, 0.5) > 20 "
            "ORDER BY PAIR_DIFF(saliency, attention, 0.5, 0.5) DESC "
            "LIMIT 6;"]
    fused = svc.submit_batch(sqls)
    assert svc.scheduler.stats.pair_passes > 0
    solo = _fresh_service(verify_batch=4)
    for sql, payload in zip(sqls, fused):
        expect = solo.query(sql)
        assert payload["ids"] == expect["ids"]
        np.testing.assert_allclose(payload["scores"], expect["scores"])
    svc.close()
    solo.close()


def test_pair_results_epoch_keyed_and_planner_evicts():
    svc = _fresh_service()
    out = svc.query(PAIR_SQL)
    assert svc.query(PAIR_SQL)["cache_hit"]
    n_cached = len(svc.planner.result_cache)
    assert n_cached > 0
    rng = np.random.default_rng(0)
    r = svc.ingest(rng.random((2, H, W)).astype(np.float32),
                   mask_ids=[5000, 5001], image_ids=[2500, 2500],
                   mask_types=[1, 2])
    # the mutation swept the dead generation out of both LRUs
    assert r["evicted_cache_entries"] > 0
    assert len(svc.planner.result_cache) == 0
    assert svc.planner.result_cache.info.invalidations > 0
    out2 = svc.query(PAIR_SQL)
    assert not out2["cache_hit"]
    assert out2["stats"]["n_candidates"] == out["stats"]["n_candidates"] + 1
    svc.close()


# ---------------------------------------------------------------------------
# Hypothesis sweeps (skipped cleanly where hypothesis is not installed)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    _SETTINGS = settings(max_examples=25, deadline=None,
                         suppress_health_check=[HealthCheck.too_slow])
    _stats = st.sampled_from(["inter", "union", "diff"])
    _threshs = st.floats(0.05, 0.95)
    _rois = st.sampled_from([None, "provided", (4, 4, 28, 28),
                             (0, 0, 16, 32), (7, 3, 9, 30)])

    @st.composite
    def _terms(draw):
        return PairTerm(draw(_stats), 1, 2, draw(_threshs), draw(_threshs),
                        draw(_rois))

    @_SETTINGS
    @given(term=_terms())
    def test_pair_bounds_always_contain_exact(term):
        _, rois = _db()
        _check_bounds_sound(term, rois)

    @st.composite
    def _pair_exprs(draw):
        base = draw(_terms())
        shape = draw(st.integers(0, 2))
        if shape == 1:
            t2 = PairTerm("union" if base.stat != "union" else "inter",
                          1, 2, base.ta, base.tb, base.roi)
            return base / t2
        if shape == 2:
            return base - draw(_terms())
        return base

    @_SETTINGS
    @given(rank=_pair_exprs(), desc=st.booleans(),
           k=st.integers(1, N_IMG + 2))
    def test_pair_rankings_backends_agree(rank, desc, k):
        _, rois = _db()
        _assert_backends_and_naive_agree(
            LogicalPlan(order_by=rank, k=k, desc=desc), rois=rois)

    @_SETTINGS
    @given(term=_terms(), op=st.sampled_from(["<", "<=", ">", ">="]),
           thr=st.sampled_from([0.0, 10.0, 60.0, 300.0, 900.0]))
    def test_pair_filters_backends_agree(term, op, thr):
        _, rois = _db()
        _assert_backends_and_naive_agree(
            LogicalPlan(predicate=Cmp(term, op, thr)), rois=rois)
