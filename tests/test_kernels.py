"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes and dtypes.  Counts are integers → exact equality."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.chi_build import chi_cell_hist_pallas
from repro.kernels.cp_count import cp_count_multi_pallas, cp_count_pallas
from repro.kernels.mask_agg import mask_agg_counts_pallas

SHAPES = [(3, 64, 64), (2, 128, 256), (5, 96, 160), (1, 256, 256), (4, 32, 512)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _random(shape, dtype, seed=0):
    rng = np.random.default_rng(seed)
    m = rng.random(shape, dtype=np.float32)
    return jnp.asarray(m, dtype)


def _random_rois(b, h, w, seed=1):
    rng = np.random.default_rng(seed)
    r = np.sort(rng.integers(0, h + 1, (b, 2)), axis=1)
    c = np.sort(rng.integers(0, w + 1, (b, 2)), axis=1)
    return jnp.asarray(np.stack([r[:, 0], c[:, 0], r[:, 1], c[:, 1]], 1),
                       jnp.int32)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_cp_count_matches_ref(shape, dtype):
    b, h, w = shape
    masks = _random(shape, dtype)
    rois = _random_rois(b, h, w)
    got = cp_count_pallas(masks, rois, 0.25, 0.8, interpret=True)
    want = ref.cp_count_ref(masks, rois, jnp.asarray(0.25, dtype),
                            jnp.asarray(0.8, dtype))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", SHAPES[:3])
def test_cp_count_full_roi_and_extremes(shape):
    b, h, w = shape
    masks = _random(shape, jnp.float32, seed=7)
    rois = jnp.tile(jnp.asarray([[0, 0, h, w]], jnp.int32), (b, 1))
    got = cp_count_pallas(masks, rois, 0.0, 1.0, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), h * w)
    # empty ROI and empty range
    empty = jnp.tile(jnp.asarray([[5, 5, 5, w]], jnp.int32), (b, 1))
    got0 = cp_count_pallas(masks, empty, 0.0, 1.0, interpret=True)
    np.testing.assert_array_equal(np.asarray(got0), 0)
    got1 = cp_count_pallas(masks, rois, 0.5, 0.5, interpret=True)
    np.testing.assert_array_equal(np.asarray(got1), 0)


@pytest.mark.parametrize("q", [1, 3, 8])
@pytest.mark.parametrize("shape", SHAPES[:3])
def test_cp_count_multi_matches_ref(q, shape):
    b, h, w = shape
    masks = _random(shape, jnp.float32, seed=3)
    rng = np.random.default_rng(4)
    rois = jnp.stack([_random_rois(b, h, w, seed=10 + i) for i in range(q)])
    bounds = np.sort(rng.random((q, 2)), axis=1)
    lvs = jnp.asarray(bounds[:, 0], jnp.float32)
    uvs = jnp.asarray(bounds[:, 1], jnp.float32)
    got = cp_count_multi_pallas(masks, rois, lvs, uvs, interpret=True)
    want = ref.cp_count_multi_ref(masks, rois, lvs, uvs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape,grid", [((2, 64, 64), 8), ((3, 128, 256), 16),
                                        ((1, 256, 256), 16), ((2, 96, 96), 4)])
@pytest.mark.parametrize("nb", [4, 16])
def test_chi_cell_hist_matches_ref(shape, grid, nb):
    masks = _random(shape, jnp.float32, seed=5)
    edges = jnp.asarray(np.arange(1, nb) / nb, jnp.float32)
    got = chi_cell_hist_pallas(masks, edges, grid, interpret=True)
    want = ref.chi_cell_hist_ref(masks, edges, grid)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # total count conserved
    assert int(np.asarray(got).sum()) == int(np.prod(shape))


def test_chi_cell_hist_matches_core_chi():
    """Kernel output, prefix-summed, must equal the CHI built by core.chi."""
    from repro.core import chi as chi_lib
    b, h, w, g, nb = 2, 64, 96, 8, 8
    masks = _random((b, h, w), jnp.float32, seed=11)
    cfg = chi_lib.CHIConfig(grid=g, num_bins=nb, height=h, width=w)
    hist = chi_cell_hist_pallas(masks, jnp.asarray(cfg.interior_edges), g,
                                interpret=True)
    table = chi_lib.histograms_to_table(hist)
    want = chi_lib.build_chi_np(np.asarray(masks, np.float32), cfg)
    np.testing.assert_array_equal(np.asarray(table), want)


@pytest.mark.parametrize("s", [2, 3, 5])
@pytest.mark.parametrize("shape", [(4, 64, 64), (2, 128, 128)])
def test_mask_agg_matches_ref(s, shape):
    n, h, w = shape
    masks = _random((n, s, h, w), jnp.float32, seed=8)
    rois = _random_rois(n, h, w, seed=9)
    gi, gu = mask_agg_counts_pallas(masks, rois, 0.6, interpret=True)
    wi, wu = ref.mask_agg_counts_ref(masks, rois, 0.6)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_array_equal(np.asarray(gu), np.asarray(wu))


def test_ops_wrappers_fallback_cpu():
    """On CPU the ops layer uses the reference path and still agrees with the
    forced-interpret Pallas path.  ``use_pallas=False`` is explicit so the
    reference side survives REPRO_FORCE_PALLAS_INTERPRET=1 (which only
    overrides default dispatch) and the comparison stays meaningful."""
    b, h, w = 3, 64, 64
    masks = _random((b, h, w), jnp.float32, seed=12)
    rois = _random_rois(b, h, w, seed=13)
    a = ops.cp_count(masks, rois, 0.2, 0.9, use_pallas=False)
    bb = ops.cp_count(masks, rois, 0.2, 0.9, use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))
    iou = ops.mask_agg_iou(masks.reshape(1, b, h, w),
                           jnp.asarray([[0, 0, h, w]], jnp.int32), 0.5)
    assert 0.0 <= float(iou[0]) <= 1.0
