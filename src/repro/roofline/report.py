"""Render the dry-run result JSONs into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.roofline.report [--dir dryrun_results]

Produces two markdown tables on stdout:
  §Dry-run  — compile status + bytes/device + collective schedule, both
              meshes, every cell;
  §Roofline — the three per-chip time terms, dominant bottleneck,
              MODEL_FLOPS/HLO_FLOPs useful ratio (single-pod cells).
"""

from __future__ import annotations

import argparse
import glob
import json
import os


def load_results(dir_: str, mesh: str) -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(dir_, mesh, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def _fmt_bytes(b) -> str:
    return f"{b / 1e9:.2f}"


def dryrun_table(results: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | status | peak GB/dev | fits 16G | "
        "collectives (AG/AR/RS/A2A/CP) | compile s |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"SKIP ({r['reason'][:60]}…) | – | – | – | – |")
            continue
        if r["status"] == "failed":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"**FAIL** {r['error'][:60]} | – | – | – | – |")
            continue
        cost = r.get("linearized_cost") or r.get("scanned_cost") or r.get("cost")
        cc = cost["coll_counts"] if cost else {}
        colls = "/".join(str(int(cc.get(k, 0))) for k in
                         ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute"))
        mem = r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{_fmt_bytes(mem['peak_estimate_bytes'])} | "
            f"{'✔' if r.get('fits_16g') else '✘'} | {colls} | "
            f"{r.get('compile_s', 0):.0f} |")
    return "\n".join(lines)


def roofline_table(results: list[dict]) -> str:
    lines = [
        "| arch | shape | compute ms | memory ms | collective ms | dominant |"
        " MODEL_TFLOPs | useful ratio | roofline fraction |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        roof = r.get("roofline")
        if not roof or r["status"] != "ok":
            continue
        bound = max(roof["compute_s"], roof["memory_s"], roof["collective_s"])
        # roofline fraction: useful model FLOPs per chip-second at the pace
        # the dominant term allows, vs peak
        n_chips = r.get("n_chips", 256)
        if roof["model_flops"] > 0 and bound > 0:
            frac = (roof["model_flops"] / n_chips / bound) / 197e12
        else:
            frac = 0.0
        # 1g/2g deltas can go ~0⁻ for decode cells (per-layer cost ≈ fused-op
        # noise); clamp for display
        comp = max(roof['compute_s'], 0.0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {comp * 1e3:.2f} | "
            f"{roof['memory_s'] * 1e3:.2f} | {roof['collective_s'] * 1e3:.2f} | "
            f"{roof['dominant']} | {roof['model_flops'] / 1e12:.0f} | "
            f"{max(roof['useful_ratio'], 0.0):.2f} | {frac:.1%} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="dryrun_results")
    args = ap.parse_args()

    single = load_results(args.dir, "single")
    multi = load_results(args.dir, "multi")
    print("## §Dry-run (single-pod 16x16 = 256 chips)\n")
    print(dryrun_table(single))
    if multi:
        print("\n## §Dry-run (multi-pod 2x16x16 = 512 chips)\n")
        print(dryrun_table(multi))
    print("\n## §Roofline (single-pod, per-chip terms; "
          "1g/2g linearization)\n")
    print(roofline_table(single))
    n_ok = sum(r["status"] == "ok" for r in single)
    n_skip = sum(r["status"] == "skipped" for r in single)
    n_fail = sum(r["status"] == "failed" for r in single)
    print(f"\nsingle-pod: {n_ok} ok / {n_skip} skip / {n_fail} fail")
    if multi:
        n_ok = sum(r["status"] == "ok" for r in multi)
        n_skip = sum(r["status"] == "skipped" for r in multi)
        n_fail = sum(r["status"] == "failed" for r in multi)
        print(f"multi-pod:  {n_ok} ok / {n_skip} skip / {n_fail} fail")


if __name__ == "__main__":
    main()
