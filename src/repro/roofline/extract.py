"""Roofline-term extraction from compiled XLA artifacts (no real hardware).

Per (arch × shape × mesh) cell we derive three per-chip time terms
(TPU v5e constants from launch/mesh.py):

    compute    = HLO_FLOPs_per_device / 197e12
    memory     = HLO_bytes_per_device / 819e9
    collective = collective_bytes_per_device / 50e9

``cost_analysis()`` is per-device post-SPMD (verified empirically —
tools/probes); collective bytes are parsed from the partitioned HLO: we sum
the *output* shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction (output size ≈ bytes crossing
the links per device for ring algorithms, the standard approximation).

**Scan correction**: XLA counts a while-loop body once.  Layer stacks are
scanned, so cells are costed from 1-group and 2-group *unrolled* compiles:

    cost(L groups) = cost(1) + (L − 1) · (cost(2) − cost(1))

This is exact for homogeneous stacks (every group identical) and is the
documented methodology in EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import dataclasses
import re

from ..launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-reduce.5 = f32[256,1024]{1,0} all-reduce(%x), replica_groups=...
_INSTR_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    if not dims:
        return nbytes
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n * nbytes


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes per collective kind from (partitioned) HLO text.
    ``-done`` halves of async pairs are skipped (counted at ``-start``)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for m in _INSTR_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if "-done(" in m.group(0):
            continue
        out[kind] += _shape_bytes(dtype, dims)
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


@dataclasses.dataclass
class CellCost:
    """Per-device costs for one compiled step."""

    flops: float
    bytes_accessed: float
    coll_bytes: float
    coll_counts: dict

    @classmethod
    def from_compiled(cls, compiled) -> "CellCost":
        ca = compiled.cost_analysis()
        coll = collective_bytes(compiled.as_text())
        return cls(flops=float(ca.get("flops", 0.0)),
                   bytes_accessed=float(ca.get("bytes accessed", 0.0)),
                   coll_bytes=float(coll["total_bytes"]),
                   coll_counts=coll["counts"])

    def linearize(self, other: "CellCost", groups: int) -> "CellCost":
        """self = 1-group cost, other = 2-group cost → full-stack cost."""
        d = max(groups - 1, 0)
        return CellCost(
            flops=self.flops + d * (other.flops - self.flops),
            bytes_accessed=self.bytes_accessed + d * (other.bytes_accessed -
                                                      self.bytes_accessed),
            coll_bytes=self.coll_bytes + d * (other.coll_bytes -
                                              self.coll_bytes),
            coll_counts={k: self.coll_counts.get(k, 0) + d * (
                other.coll_counts.get(k, 0) - self.coll_counts.get(k, 0))
                for k in _COLLECTIVES},
        )


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float          # 6·N_active·D analytic
    hlo_flops_global: float
    useful_ratio: float

    @classmethod
    def from_cost(cls, cost: CellCost, n_chips: int,
                  model_flops: float) -> "Roofline":
        compute = cost.flops / PEAK_FLOPS_BF16
        memory = cost.bytes_accessed / HBM_BW
        coll = cost.coll_bytes / ICI_BW
        terms = {"compute": compute, "memory": memory, "collective": coll}
        dominant = max(terms, key=terms.get)
        hlo_global = cost.flops * n_chips
        return cls(compute_s=compute, memory_s=memory, collective_s=coll,
                   dominant=dominant, model_flops=model_flops,
                   hlo_flops_global=hlo_global,
                   useful_ratio=(model_flops / hlo_global
                                 if hlo_global > 0 else 0.0))

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def active_params(cfg) -> float:
    """Parameter count that each token touches (MoE: top-k + shared only)."""
    d = cfg.d_model
    n = 0.0
    # embeddings (tied or not, the matmul cost counts once at the head)
    n += cfg.vocab_size * d
    kinds = cfg.pattern_layers
    for kind in kinds:
        if kind in ("global", "local"):
            if cfg.attention == "mla":
                n += d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.num_heads * (
                    cfg.qk_nope_dim + cfg.qk_rope_dim)
                n += d * cfg.kv_lora_rank + d * cfg.qk_rope_dim
                n += cfg.kv_lora_rank * cfg.num_heads * (
                    cfg.qk_nope_dim + cfg.v_head_dim)
                n += cfg.num_heads * cfg.v_head_dim * d
            else:
                n += d * cfg.num_heads * cfg.head_dim * 2  # wq, wo
                n += d * cfg.num_kv_heads * cfg.head_dim * 2
        elif kind == "rglru":
            w = cfg.lru_width or d
            n += d * w * 2 + w * w * 2 + w * d
        elif kind == "ssm":
            d_inner = cfg.ssm_expand * d
            nh = cfg.ssm_heads or d_inner // cfg.ssm_head_dim
            proj = 2 * d_inner + 2 * cfg.ssm_state + nh
            n += d * proj + d_inner * d
    # FFN: dense layers full; MoE layers top-k routed + shared
    moe_layers = (len(kinds) - cfg.first_k_dense) if cfg.num_experts else 0
    dense_layers = len(kinds) - moe_layers
    if cfg.attention != "none":  # ssm blocks have no separate FFN
        n += dense_layers * 3 * d * cfg.d_ff if cfg.d_ff else 0
    if cfg.num_experts:
        per_expert = 3 * d * cfg.moe_d_ff
        n += moe_layers * (cfg.top_k + cfg.num_shared_experts) * per_expert
    if cfg.is_encoder_decoder:
        # decoder cross-attn on top of the enc+dec self stacks
        n += cfg.dec_layers * d * cfg.num_heads * cfg.head_dim * 4
    return float(n)


def model_flops_for(cfg, shape_kind: str, seq_len: int,
                    global_batch: int) -> float:
    """6·N_active·D(tokens); decode processes 1 token per sequence;
    train pays 3× the forward (fwd+bwd)."""
    n_active = active_params(cfg)
    if shape_kind == "train":
        tokens = global_batch * seq_len
        return 6.0 * n_active * tokens
    if shape_kind == "prefill":
        tokens = global_batch * seq_len
        return 2.0 * n_active * tokens
    tokens = global_batch * 1
    return 2.0 * n_active * tokens
