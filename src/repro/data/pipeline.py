"""Data pipeline: deterministic synthetic LM batches + mask-harvest hooks.

Production shape: host-sharded loading (each host materializes only its
``global_batch / num_hosts`` rows), bounded background prefetch (straggler
mitigation: input hiccups don't stall the collective until the buffer
drains), and an augmentation side-channel that Scenario 1 feeds query
results back into.

Synthetic text is Zipf-distributed token ids with a fixed per-step PRNG
(seed ⊕ step) — restart-reproducible, which the checkpoint tests rely on.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import numpy as np


class SyntheticLMData:
    """Deterministic synthetic batches for a ModelConfig."""

    def __init__(self, cfg, seq_len: int, global_batch: int, *, seed: int = 0,
                 host_index: int = 0, host_count: int = 1):
        assert global_batch % host_count == 0
        self.cfg = cfg
        self.seq_len = seq_len
        self.local_batch = global_batch // host_count
        self.seed = seed
        self.host_index = host_index

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) ^ (self.host_index << 20))
        cfg = self.cfg
        b, s = self.local_batch, self.seq_len
        # Zipf-ish marginals over the vocab
        z = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64)
        tokens_full = (z - 1) % cfg.vocab_size
        batch = {
            "tokens": tokens_full[:, :-1].astype(np.int32),
            "labels": tokens_full[:, 1:].astype(np.int32),
        }
        if cfg.is_encoder_decoder:
            dec = min(s, cfg.max_decode_len)
            batch = {
                "audio_feats": rng.standard_normal(
                    (b, s, cfg.d_model), dtype=np.float32),
                "tokens": batch["tokens"][:, :dec],
                "labels": batch["labels"][:, :dec],
            }
        elif cfg.num_patches:
            batch["patches"] = rng.standard_normal(
                (b, cfg.num_patches, cfg.d_model), dtype=np.float32)
        if cfg.mtp_depth:
            mtp = np.full_like(batch["labels"], -1)
            mtp[:, :-1] = tokens_full[:, 2:]
            batch["labels_mtp"] = mtp
        return batch

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchIterator:
    """Bounded background prefetch (depth N) over any batch iterator."""

    _SENTINEL = object()

    def __init__(self, source, depth: int = 2,
                 transform: Optional[Callable] = None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._transform = transform
        self._stop = threading.Event()

        def work():
            try:
                for item in source:
                    if self._stop.is_set():
                        return
                    if transform is not None:
                        item = transform(item)
                    self._q.put(item)
            finally:
                self._q.put(self._SENTINEL)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._SENTINEL:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


class AugmentedData:
    """Wraps a base source and mixes in query-selected augmented examples —
    the Scenario-1 feedback loop (core/augment.py produces the examples)."""

    def __init__(self, base: SyntheticLMData):
        self.base = base
        self._extra: list[dict] = []

    def add_augmented(self, batch: dict) -> None:
        self._extra.append(batch)

    def batch_at(self, step: int) -> dict:
        batch = self.base.batch_at(step)
        if self._extra:
            aug = self._extra[step % len(self._extra)]
            n = min(len(aug["tokens"]), len(batch["tokens"]) // 2)
            if n:
                for key in ("tokens", "labels"):
                    if key in aug:
                        batch[key] = batch[key].copy()
                        batch[key][:n] = aug[key][:n]
        return batch
