"""Synthetic saliency-mask generator for benchmarks/examples.

Real model-saliency maps (the paper's iWildCam Grad-CAM masks) are smooth,
blobby, spatially coherent fields — which is exactly why CHI prunes well on
them (a mask that is hot in one region is provably cold elsewhere).  This
generator reproduces those statistics: a few Gaussian bumps (the "object"
focus) over a low-level smooth background, normalized to [0, 1).

``attacked=True`` masks get extra diffuse mid-value noise — the Scenario-2
adversarial signature (dispersed attention) that CP(·, full, (0.2, 0.6))
queries single out.
"""

from __future__ import annotations

import numpy as np


def saliency_masks(n: int, height: int = 128, width: int = 128, *,
                   seed: int = 0, n_blobs=(1, 4),
                   attacked_fraction: float = 0.0,
                   boxes: np.ndarray | None = None,
                   in_box_fraction: float = 0.9
                   ) -> tuple[np.ndarray, np.ndarray]:
    """→ (masks (n, H, W) float32 in [0,1), attacked (n,) bool).

    With ``boxes`` given (the per-image object boxes), the dominant blob is
    centered *inside* the box for ``in_box_fraction`` of masks — a model
    that mostly attends to the object, with a minority of
    spurious-correlation cases attending to background.  That is the
    distribution the paper's Scenario-1 queries hunt through, and what
    gives the filter-verification framework its pruning power.
    """
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:height, 0:width].astype(np.float32)
    masks = np.empty((n, height, width), np.float32)
    attacked = rng.random(n) < attacked_fraction
    for i in range(n):
        k = rng.integers(n_blobs[0], n_blobs[1] + 1)
        field = rng.uniform(0.0, 0.15) * np.ones((height, width), np.float32)
        in_box = boxes is not None and rng.random() < in_box_fraction
        for j in range(k):
            if in_box and j == 0:        # dominant blob inside the object box
                r0, c0, r1, c1 = boxes[i]
                cy = rng.uniform(r0 + 0.25 * (r1 - r0), r1 - 0.25 * (r1 - r0))
                cx = rng.uniform(c0 + 0.25 * (c1 - c0), c1 - 0.25 * (c1 - c0))
                sy = rng.uniform(0.15, 0.35) * (r1 - r0)
                sx = rng.uniform(0.15, 0.35) * (c1 - c0)
                amp = rng.uniform(0.9, 1.2)
            else:
                cy = rng.uniform(0.15, 0.85) * height
                cx = rng.uniform(0.15, 0.85) * width
                sy = rng.uniform(0.05, 0.25) * height
                sx = rng.uniform(0.05, 0.25) * width
                amp = rng.uniform(0.3, 0.7) if in_box else rng.uniform(0.5, 1.0)
            field += amp * np.exp(-(((yy - cy) / sy) ** 2 +
                                    ((xx - cx) / sx) ** 2))
        if attacked[i]:
            # diffuse mid-value noise over the whole image (S2 signature)
            field = 0.45 * field + rng.uniform(0.25, 0.5) * \
                np.abs(np.sin(yy / rng.uniform(3, 9)) *
                       np.cos(xx / rng.uniform(3, 9)))
        lo, hi = field.min(), field.max()
        masks[i] = (field - lo) / max(hi - lo, 1e-9) * (1.0 - 1e-6)
    return masks, attacked


def object_boxes(n: int, height: int, width: int, *, seed: int = 1) -> np.ndarray:
    """Random object bounding boxes (the YOLO-box stand-in), (n, 4) int32."""
    rng = np.random.default_rng(seed)
    h = rng.integers(height // 4, height // 2, n)
    w = rng.integers(width // 4, width // 2, n)
    r0 = rng.integers(0, height - h, n)
    c0 = rng.integers(0, width - w, n)
    return np.stack([r0, c0, r0 + h, c0 + w], axis=1).astype(np.int32)
