"""Instrumented-lock mode: runtime teeth for the lock-discipline contract.

The static side of the contract lives in ``repro.analysis`` (masklint's
``lock-discipline`` / ``lock-order`` rules); this module is the dynamic
side.  With ``REPRO_LOCK_CHECK=1`` in the environment, every lock built
through :func:`make_lock` / :func:`make_rlock` is replaced by an
instrumented wrapper that turns silent races into loud failures:

* **owner tracking** — releasing a lock from a thread that does not hold
  it raises :class:`LockCheckError` (plain ``threading.Lock`` permits it);
* **ordering** — every *nested* acquisition records a directed edge
  ``outer → inner`` in a process-global lock-order graph, and an
  acquisition that would close a cycle (a latent deadlock: two threads
  taking the same pair of locks in opposite orders) raises immediately,
  even when the interleaving that would actually deadlock never happens
  in the test run;
* **hold-time accounting** — the longest time each named lock was held is
  recorded (:func:`hold_stats`); setting ``REPRO_LOCK_MAX_HOLD_S`` turns
  a budget overrun into an error.

With the variable unset (the default, and the production path) the
factories return plain ``threading.Lock()`` / ``threading.RLock()`` —
zero overhead, zero behaviour change.

:func:`guard_dict` extends the teeth to shared *containers*: it wraps a
dict so every mutation asserts that a given instrumented lock is held by
the calling thread.  Reads stay unguarded on purpose — the service's
``/metrics`` scrape reads counters without the service lock by design
(torn reads of monotonic counters are tolerated; torn *writes* are not).
"""

from __future__ import annotations

import os
import threading
import time

__all__ = [
    "LockCheckError", "enabled", "make_lock", "make_rlock", "guard_dict",
    "order_edges", "hold_stats", "reset_diagnostics",
]


class LockCheckError(AssertionError):
    """A violation of the lock discipline detected at runtime."""


def enabled() -> bool:
    """Whether instrumented-lock mode is on (``REPRO_LOCK_CHECK`` set to
    anything but empty/``0``).  Read at lock-construction time."""
    return os.environ.get("REPRO_LOCK_CHECK", "") not in ("", "0")


# -- process-global diagnostics ------------------------------------------------

_DIAG_LOCK = threading.Lock()
_ORDER_EDGES: dict[str, dict[str, str]] = {}   # outer -> {inner: site label}
_MAX_HOLD_S: dict[str, float] = {}             # name -> longest hold seconds
_HELD = threading.local()                      # per-thread stack of lock names


def _held_stack() -> list[str]:
    stack = getattr(_HELD, "stack", None)
    if stack is None:
        stack = _HELD.stack = []
    return stack


def _find_path(src: str, dst: str) -> list[str] | None:
    """A path src → … → dst in the order graph (DFS), or None."""
    seen = {src}
    trail = [(src, [src])]
    while trail:
        node, path = trail.pop()
        if node == dst:
            return path
        for nxt in _ORDER_EDGES.get(node, ()):
            if nxt not in seen:
                seen.add(nxt)
                trail.append((nxt, path + [nxt]))
    return None


def _record_edge(outer: str, inner: str) -> None:
    """Record outer→inner; raise if the reverse direction is reachable
    (the pair of locks has now been taken in both orders somewhere)."""
    with _DIAG_LOCK:
        edges = _ORDER_EDGES.setdefault(outer, {})
        if inner in edges:
            return
        back = _find_path(inner, outer)
        if back is not None:
            raise LockCheckError(
                f"lock-order cycle: acquiring {inner!r} while holding "
                f"{outer!r}, but the graph already has "
                f"{' -> '.join(back)} — two threads taking these locks "
                f"in opposite orders can deadlock")
        edges[inner] = f"held {outer!r}"


def _record_hold(name: str, held_s: float) -> None:
    with _DIAG_LOCK:
        if held_s > _MAX_HOLD_S.get(name, 0.0):
            _MAX_HOLD_S[name] = held_s


def order_edges() -> dict[str, list[str]]:
    """The observed lock-order graph (outer name → inner names)."""
    with _DIAG_LOCK:
        return {k: sorted(v) for k, v in _ORDER_EDGES.items()}


def hold_stats() -> dict[str, float]:
    """Longest observed hold time per lock name, in seconds."""
    with _DIAG_LOCK:
        return dict(_MAX_HOLD_S)


def reset_diagnostics() -> None:
    """Clear the global order graph and hold stats (test isolation)."""
    with _DIAG_LOCK:
        _ORDER_EDGES.clear()
        _MAX_HOLD_S.clear()


# -- the instrumented wrappers -------------------------------------------------

class _InstrumentedBase:
    """Common owner/ordering/hold-time machinery over an inner lock.

    The inner primitive does the real blocking; all bookkeeping happens
    on the owning thread around it, so attributes like ``_owner`` are
    only written by whichever thread holds the inner lock (plus the
    pre-acquire checks, which read racily but fail toward detection)."""

    _reentrant = False

    def __init__(self, name: str):
        self.name = name
        self._inner = (threading.RLock() if self._reentrant
                       else threading.Lock())
        self._owner: int | None = None
        self._depth = 0
        self._acquired_at = 0.0
        budget = os.environ.get("REPRO_LOCK_MAX_HOLD_S", "")
        self._hold_budget_s = float(budget) if budget else 0.0

    # -- core protocol ---------------------------------------------------
    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        me = threading.get_ident()
        reacquire = self._owner == me
        if reacquire and not self._reentrant:
            raise LockCheckError(
                f"lock {self.name!r}: non-reentrant re-acquire by the "
                f"owning thread (self-deadlock)")
        stack = _held_stack()
        if stack and not reacquire and self.name not in stack:
            _record_edge(stack[-1], self.name)
        got = self._inner.acquire(blocking, timeout)
        if not got:
            return False
        if self._depth == 0:
            self._owner = me
            self._acquired_at = time.perf_counter()
        self._depth += 1
        stack.append(self.name)
        return True

    def release(self) -> None:
        me = threading.get_ident()
        if self._owner != me:
            raise LockCheckError(
                f"lock {self.name!r}: released by thread {me} but "
                f"held by {self._owner!r}")
        self._depth -= 1
        if self._depth == 0:
            held_s = time.perf_counter() - self._acquired_at
            _record_hold(self.name, held_s)
            self._owner = None
            if self._hold_budget_s and held_s > self._hold_budget_s:
                self._inner.release()
                self._pop_held()
                raise LockCheckError(
                    f"lock {self.name!r}: held {held_s:.3f}s, over the "
                    f"REPRO_LOCK_MAX_HOLD_S={self._hold_budget_s} budget")
        self._pop_held()
        self._inner.release()

    def _pop_held(self) -> None:
        stack = _held_stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] == self.name:
                del stack[i]
                return

    # -- conveniences ----------------------------------------------------
    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.release()
        return False

    def locked(self) -> bool:
        return self._owner is not None

    def held_by_me(self) -> bool:
        return self._owner == threading.get_ident()

    def assert_held(self) -> None:
        """Raise unless the calling thread currently owns this lock."""
        if self._owner != threading.get_ident():
            raise LockCheckError(
                f"lock {self.name!r}: required to be held by the calling "
                f"thread but owner is {self._owner!r}")

    def __repr__(self) -> str:
        state = f"held depth={self._depth}" if self._owner else "unlocked"
        return f"<{type(self).__name__} {self.name!r} {state}>"


class InstrumentedLock(_InstrumentedBase):
    _reentrant = False


class InstrumentedRLock(_InstrumentedBase):
    _reentrant = True


def make_lock(name: str):
    """A ``threading.Lock`` — instrumented when ``REPRO_LOCK_CHECK=1``."""
    return InstrumentedLock(name) if enabled() else threading.Lock()


def make_rlock(name: str):
    """A ``threading.RLock`` — instrumented when ``REPRO_LOCK_CHECK=1``."""
    return InstrumentedRLock(name) if enabled() else threading.RLock()


# -- guarded containers --------------------------------------------------------

class GuardedDict(dict):
    """A dict whose *mutations* assert the guarding lock is held.

    Reads are deliberately unguarded (see module docs).  Only built when
    instrumented-lock mode is on — :func:`guard_dict` returns the plain
    mapping otherwise, so the production path has no indirection."""

    def __init__(self, mapping, lock):
        super().__init__(mapping)
        self._lc_lock = lock

    def _check(self) -> None:
        self._lc_lock.assert_held()

    def __setitem__(self, key, value):
        self._check()
        super().__setitem__(key, value)

    def __delitem__(self, key):
        self._check()
        super().__delitem__(key)

    def pop(self, *a):
        self._check()
        return super().pop(*a)

    def popitem(self):
        self._check()
        return super().popitem()

    def clear(self):
        self._check()
        super().clear()

    def update(self, *a, **kw):
        self._check()
        super().update(*a, **kw)

    def setdefault(self, key, default=None):
        self._check()
        return super().setdefault(key, default)


def guard_dict(mapping: dict, lock) -> dict:
    """Wrap ``mapping`` so mutations assert ``lock`` is held — when the
    lock is instrumented; otherwise return ``mapping`` unchanged."""
    if isinstance(lock, _InstrumentedBase):
        return GuardedDict(mapping, lock)
    return mapping
