"""MaskStore — the tiered, epoch-versioned mask database behind
``MasksDatabaseView``.

The paper's schema::

    MasksDatabaseView(mask_id, image_id, model_id, mask_type, mask REAL[][])

Metadata + the CHI table are small and always memory/HBM-resident; mask
*bytes* live in a configurable tier:

* ``disk``   — one ``.npy`` file per mask (the paper's file-per-mask layout on
               EBS; this is the tier whose I/O the index avoids).  All reads
               are metered: real wall time + a modeled EBS-gp3 time
               (125 MB/s throughput, 3000 IOPS) so benchmarks can report the
               paper's own I/O model independent of the container's page
               cache.
* ``memory`` — a host ndarray (the "hot" tier; also what a TPU host RAM tier
               looks like).
* ``device`` — a jnp array (HBM-resident, used by the distributed shard_map
               engine and the dry-run).

The engine only sees :meth:`load` / :meth:`load_all`, so tiers are
interchangeable.

Mutability (the full paper's in-place index maintenance, DESIGN.md §8):
the store is a *database*, not a frozen snapshot.  :meth:`append`,
:meth:`update` and :meth:`delete` mutate it under a monotonically
increasing :attr:`epoch`.  CHI maintenance is incremental — the index is a
**chunked** list of prefix-sum tables, one chunk per ingest batch, so an
append builds tables only for the delta and never re-copies the existing
``(B, G+1, G+1, NB+1)`` tensor.  Readers pin an epoch through
:meth:`snapshot`; memory-resident tiers serve pinned readers forever
(mutations are copy-on-write at the array level), the disk tier serves
them until one of *their* mask_ids is overwritten, after which resuming
raises :class:`StaleRunError`.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from . import packing
from .chi import CHIConfig, build_chi_delta, build_chi_np, tier_slice

# Paper's EBS gp3 provisioning (§4): 125 MiB/s, 3000 IOPS.
EBS_THROUGHPUT_BYTES_S = 125 * 1024 * 1024
EBS_IOPS = 3000.0
EBS_IO_CHUNK = 256 * 1024  # gp3 accounting chunk for large sequential reads

# Shared-load cache default bound (satellite: the cache must not grow
# without limit across a long-lived service).
DEFAULT_CACHE_BYTES = 256 * 1024 * 1024

# Compact the chunked CHI once appends fragment it this far — keeps the
# cross-chunk gather and the full-table concat O(few chunks).
_CHI_MAX_CHUNKS = 64

# Mutations older than this fall off the dirty log; snapshot readers pinned
# before the log's floor are conservatively treated as stale (disk tier).
_DIRTY_LOG_MAX = 256


class StaleRunError(RuntimeError):
    """A reader pinned to an earlier store epoch needs data the store can
    no longer serve consistently (its bytes were overwritten, or its
    backend's device residency was refreshed past the pinned epoch)."""


@dataclasses.dataclass
class IOStats:
    """Disk-tier accounting — the quantity MaskSearch's index minimizes."""

    files_read: int = 0
    bytes_read: int = 0
    wall_time_s: float = 0.0

    @property
    def modeled_ebs_time_s(self) -> float:
        """Time under the paper's EBS model: throughput-bound transfer plus
        per-request IOPS cost (each file ≥1 I/O, 256 KiB accounting chunks)."""
        ios = self.files_read + self.bytes_read // EBS_IO_CHUNK
        return self.bytes_read / EBS_THROUGHPUT_BYTES_S + ios / EBS_IOPS

    # Reflection, not field lists: a counter added to the dataclass can
    # never silently drift out of merge/reset (tests/test_stats_consistency
    # asserts this for every stats dataclass).
    def merge(self, other: "IOStats") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other,
                                                                  f.name))

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["modeled_ebs_time_s"] = self.modeled_ebs_time_s
        return d

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, f.default)


@dataclasses.dataclass
class CacheStats:
    """Shared-load cache accounting (cross-query / cross-session sharing).

    ``bytes_saved`` is the disk I/O that cache hits avoided — the quantity
    the service's fused verification maximizes across in-flight sessions.
    ``evictions`` counts rows displaced by the capacity bound;
    ``invalidations`` counts rows dropped because :meth:`MaskStore.update`
    rewrote their bytes (epoch maintenance, not capacity pressure)."""

    hits: int = 0
    misses: int = 0
    bytes_saved: int = 0
    evictions: int = 0
    invalidations: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["hit_rate"] = self.hit_rate
        return d

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, f.default)


MASK_META_DTYPE = np.dtype([
    ("mask_id", np.int64),
    ("image_id", np.int64),
    ("model_id", np.int32),
    ("mask_type", np.int32),
])


def _positions_of(meta: np.ndarray, mask_ids) -> np.ndarray:
    """Row positions for the given mask_ids against a meta array."""
    ids = np.atleast_1d(np.asarray(mask_ids, dtype=np.int64))
    order = np.argsort(meta["mask_id"], kind="stable")
    sorted_ids = meta["mask_id"][order]
    pos = np.clip(np.searchsorted(sorted_ids, ids), 0,
                  max(len(sorted_ids) - 1, 0))
    if len(sorted_ids) == 0 or np.any(sorted_ids[pos] != ids):
        raise KeyError("unknown mask_id in lookup")
    return order[pos]


def _select(meta: np.ndarray, conds: dict) -> np.ndarray:
    keep = np.ones(len(meta), dtype=bool)
    for col, val in conds.items():
        vals = np.atleast_1d(np.asarray(val))
        keep &= np.isin(meta[col], vals)
    return np.nonzero(keep)[0]


def _load_row_spans(cfg: CHIConfig, io: IOStats, meta: np.ndarray, masks,
                    path_of, positions: np.ndarray, spans: np.ndarray,
                    row_width: int | None = None, dtype=np.float32):
    """Shared partial-row load loop (live store + epoch-pinned snapshot):
    read only each mask's ROI row span — from the resident array when one
    exists, else by npy memmap slice — metering rows read plus a 4 KiB
    header/page floor per file under the EBS model's granularity.

    ``row_width``/``dtype`` describe the stored representation of one mask
    row (``cfg.width`` float32 on the float tier, ``words_for(width)``
    uint32 on the packed tier) so metered bytes match what the tier
    actually moves."""
    positions = np.asarray(positions, dtype=np.int64)
    spans = np.asarray(spans, dtype=np.int64)
    heights = np.maximum(spans[:, 1] - spans[:, 0], 0)
    max_span = max(int(heights.max()) if len(heights) else 0, 1)
    if row_width is None:
        row_width = cfg.width
    buf = np.zeros((len(positions), max_span, row_width), dtype)
    t0 = time.perf_counter()
    nbytes = 0
    for i, p in enumerate(positions):
        r0, r1 = int(spans[i, 0]), int(spans[i, 1])
        if r1 <= r0:
            continue
        if masks is not None:
            rows = np.asarray(masks)[p, r0:r1]
        else:
            mm = np.load(path_of(meta["mask_id"][p]), mmap_mode="r")
            rows = np.asarray(mm[r0:r1])
        buf[i, : r1 - r0] = rows
        nbytes += rows.nbytes + 4096     # + header/page floor
    io.wall_time_s += time.perf_counter() - t0
    io.files_read += len(positions)
    io.bytes_read += nbytes
    return buf, heights.astype(np.int32)


class MaskStore:
    """A partition of the mask database (one shard in the distributed case)."""

    def __init__(self, cfg: CHIConfig, meta: np.ndarray, *, tier: str,
                 root: str | None = None, masks: np.ndarray | None = None,
                 chi_table: np.ndarray | None = None,
                 chi_chunks: list | None = None, epoch: int = 0,
                 packed: bool = False):
        if meta.dtype != MASK_META_DTYPE:
            raise ValueError("meta must use MASK_META_DTYPE")
        self.cfg = cfg
        self.meta = meta
        self.tier = tier
        self.root = root
        # Bitpacked binary tier (DESIGN.md §12): mask rows live as
        # little-endian uint32 words, 1 bit/pixel.  `masks` (and every
        # load/resident/device surface) then carries (…, H, words) uint32.
        self.packed = bool(packed)
        self.words = packing.words_for(cfg.width)
        self._masks = masks
        # Spare-capacity buffer behind self._masks (memory tier): appends
        # write into the tail so existing epoch views never move.
        self._masks_buf = masks
        self.io = IOStats()
        # Epoch versioning: every mutation bumps `epoch`; the dirty log
        # records which mask_ids each bump touched so disk-tier snapshot
        # readers can tell whether *their* bytes moved.
        self.epoch = int(epoch)
        self._dirty_log: list[tuple[int, np.ndarray | None]] = []
        self._dirty_floor = int(epoch)
        # Resident copies + per-store execution backends (core/backend.py):
        # device/mesh backends pin mask bytes once and refresh per epoch.
        self._resident: np.ndarray | None = None
        self._device_masks = None
        self._backend_cache: dict = {}
        # Cross-query shared-load cache (bounded; see enable_cache).
        self._cache_map: np.ndarray | None = None
        self._cache_arr: np.ndarray | None = None
        self._cache_pos: np.ndarray | None = None
        self._cache_used = 0
        self._cache_clock = 0
        self._cache_cap = 0
        self.cache_stats = CacheStats()
        # CHI: a chunked list of host prefix-sum tables (one chunk per
        # ingest batch) + lazily materialized host-concat / device caches.
        if chi_table is not None and chi_chunks is not None:
            raise ValueError("pass chi_table or chi_chunks, not both")
        if chi_chunks is not None:
            self._chi_chunks = [np.asarray(c, np.int32) for c in chi_chunks]
        elif chi_table is not None:
            self._chi_chunks = [np.asarray(chi_table, np.int32)]
        elif masks is not None:
            if self.packed:
                # CHI is built from pixel values; packed constructors
                # (create_memory/create_disk) index the float input before
                # packing and pass the table in.
                raise ValueError("packed stores need a prebuilt CHI table")
            self._chi_chunks = [build_chi_np(np.asarray(masks), cfg)]
        else:
            self._chi_chunks = None
        self._chi_cat: np.ndarray | None = None     # host full-table cache
        self._chi_dev = None                        # device full-table cache
        # Pyramid tiers (DESIGN.md §13): coarse tables are exact strided
        # subsamples of the finest chunks, materialized lazily per tier and
        # then maintained incrementally across mutations — never persisted
        # (disk round-trips re-derive them from the chunked layout).
        self._chi_tier_host: dict[int, np.ndarray] = {}
        self._chi_tier_dev: dict = {}
        self._chi_stats: np.ndarray | None = None   # corner value CDF cache
        self._chunk_files: list[str] | None = None  # disk tier persistence

    # -- construction ------------------------------------------------------

    @classmethod
    def create_memory(cls, masks: np.ndarray, meta: np.ndarray, cfg: CHIConfig,
                      chi_table: np.ndarray | None = None,
                      packed: bool = False) -> "MaskStore":
        """``packed=True`` declares the mask type binary at ingest: values
        are validated to be exactly {0, 1}, indexed from the float input,
        then stored 1 bit/pixel (DESIGN.md §12)."""
        masks = np.asarray(masks)
        if packed:
            packing.validate_binary(masks)
            if chi_table is None:
                chi_table = build_chi_np(np.asarray(masks, np.float32), cfg)
            masks = packing.pack_masks(masks)
        return cls(cfg, meta, tier="memory", masks=masks,
                   chi_table=chi_table, packed=packed)

    @classmethod
    def create_disk(cls, root: str, masks: np.ndarray, meta: np.ndarray,
                    cfg: CHIConfig, chi_table: np.ndarray | None = None,
                    packed: bool = False) -> "MaskStore":
        """Ingest: write one .npy per mask + persist CHI and metadata.
        With ``packed=True`` the per-mask files hold uint32 words (the CHI
        is still built from the float input before packing)."""
        os.makedirs(os.path.join(root, "masks"), exist_ok=True)
        masks = np.asarray(masks, dtype=np.float32)
        if chi_table is None:
            chi_table = build_chi_np(masks, cfg)
        if packed:
            packing.validate_binary(masks)
            masks = packing.pack_masks(masks)
        for row, m in zip(meta, masks):
            np.save(os.path.join(root, "masks", f"{int(row['mask_id'])}.npy"), m)
        np.save(os.path.join(root, "chi.npy"), np.asarray(chi_table))
        np.save(os.path.join(root, "meta.npy"), meta)
        store = cls(cfg, meta, tier="disk", root=root, chi_table=chi_table,
                    packed=packed)
        store._chunk_files = ["chi.npy"]
        store._write_config()
        return store

    @classmethod
    def open_disk(cls, root: str) -> "MaskStore":
        with open(os.path.join(root, "config.json")) as f:
            raw = json.load(f)
        cfg = CHIConfig(grid=raw["grid"], num_bins=raw["num_bins"],
                        height=raw["height"], width=raw["width"],
                        thresholds=None if raw["thresholds"] is None
                        else tuple(raw["thresholds"]))
        meta = np.load(os.path.join(root, "meta.npy"))
        chunk_files = raw.get("chi_chunks", ["chi.npy"])
        chunks = [np.load(os.path.join(root, f)) for f in chunk_files]
        store = cls(cfg, meta, tier="disk", root=root, chi_chunks=chunks,
                    epoch=raw.get("epoch", 0),
                    packed=raw.get("packed", False))
        store._chunk_files = list(chunk_files)
        return store

    def _write_config(self) -> None:
        cfg = self.cfg
        with open(os.path.join(self.root, "config.json"), "w") as f:
            json.dump({
                "grid": cfg.grid, "num_bins": cfg.num_bins,
                "height": cfg.height, "width": cfg.width,
                "thresholds": None if cfg.thresholds is None
                else list(cfg.thresholds),
                "epoch": self.epoch,
                "chi_chunks": self._chunk_files,
                "packed": self.packed,
            }, f)

    def _mask_path(self, mask_id: int) -> str:
        return os.path.join(self.root, "masks", f"{int(mask_id)}.npy")

    # -- properties ---------------------------------------------------------

    def __len__(self) -> int:
        return len(self.meta)

    @property
    def row_shape(self) -> tuple:
        """Stored shape of one mask: (H, W) float or (H, words) packed."""
        if self.packed:
            return (self.cfg.height, self.words)
        return (self.cfg.height, self.cfg.width)

    @property
    def row_dtype(self):
        return np.uint32 if self.packed else np.float32

    @property
    def row_nbytes(self) -> int:
        """Bytes one stored mask actually occupies — what the shared-load
        cache budget and ``bytes_saved`` accounting are denominated in."""
        h, w = self.row_shape
        return h * w * np.dtype(self.row_dtype).itemsize

    @property
    def chi_table(self):
        """The full CHI table as one device array (cached; maintained
        incrementally across mutations once materialized)."""
        if self._chi_chunks is None:
            raise ValueError("store has no CHI table; ingest with an index")
        if self._chi_dev is None:
            self._chi_dev = jnp.asarray(self.chi_host())
        return self._chi_dev

    def chi_host(self, positions: np.ndarray | None = None) -> np.ndarray:
        """CHI rows as host numpy — the whole table (cached concat of the
        chunks) or a gather of specific row positions across chunks."""
        if self._chi_chunks is None:
            raise ValueError("store has no CHI table; ingest with an index")
        if positions is None:
            if self._chi_cat is None:
                self._chi_cat = (self._chi_chunks[0]
                                 if len(self._chi_chunks) == 1
                                 else np.concatenate(self._chi_chunks))
            return self._chi_cat
        positions = np.asarray(positions, dtype=np.int64)
        starts, cid = self._chunk_of(positions)
        out = np.empty((len(positions),) + self._chi_chunks[0].shape[1:],
                       np.int32)
        for c in np.unique(cid):
            sel = cid == c
            out[sel] = self._chi_chunks[c][positions[sel] - starts[c]]
        return out

    def _chunk_of(self, positions: np.ndarray):
        """Map row positions to their owning CHI chunk: returns
        ``(chunk_starts, chunk_index_per_position)``."""
        lens = np.array([len(c) for c in self._chi_chunks], dtype=np.int64)
        ends = np.cumsum(lens)
        return ends - lens, np.searchsorted(ends, positions, side="right")

    @property
    def chi_chunks(self) -> list | None:
        """The chunked CHI layout (read-only view for tests/benchmarks)."""
        return self._chi_chunks

    # -- pyramid tiers (DESIGN.md §13) ---------------------------------------

    def chi_tier_host(self, g: int) -> np.ndarray:
        """The tier-``g`` CHI table as host numpy — the finest tier is
        :meth:`chi_host` itself; coarser tiers are exact strided subsamples,
        materialized once and maintained incrementally across mutations."""
        if g == self.cfg.grid:
            return self.chi_host()
        tab = self._chi_tier_host.get(g)
        if tab is None:
            tab = tier_slice(self.chi_host(), self.cfg.grid, g)
            self._chi_tier_host[g] = tab
        return tab

    def chi_tier_table(self, g: int):
        """:meth:`chi_tier_host` pinned in device memory (cached per tier)."""
        if g == self.cfg.grid:
            return self.chi_table
        tab = self._chi_tier_dev.get(g)
        if tab is None:
            tab = jnp.asarray(self.chi_tier_host(g))
            self._chi_tier_dev[g] = tab
        return tab

    def chi_value_stats(self) -> np.ndarray:
        """(B, NB+1) whole-image value CDF per mask — the CHI's own corner
        plane ``table[:, -1, -1, :]`` (``stats[b, k]`` counts pixels with
        value < ``edges[k]``; the last entry is H·W).  This is the build-time
        index statistic the cost-based optimizer estimates selectivities
        from — no extra state, kept fresh incrementally like the tiers."""
        if self._chi_stats is None:
            self._chi_stats = np.ascontiguousarray(
                self.chi_host()[:, -1, -1, :])
        return self._chi_stats

    @property
    def mask_ids(self) -> np.ndarray:
        return self.meta["mask_id"]

    @property
    def cache_enabled(self) -> bool:
        """Whether the cross-query load cache is on — the public signal
        for planners choosing between cached whole-row loads and
        partial-row reads (see :meth:`enable_cache`)."""
        return self._cache_map is not None

    @property
    def backend_cache(self) -> dict:
        """Named :class:`ExecBackend` instances resident over this store
        (owned by ``core.backend.get_backend``, keyed by backend name)."""
        return self._backend_cache

    def positions_of(self, mask_ids: Sequence[int]) -> np.ndarray:
        """Row positions for the given mask_ids (metadata is host-side)."""
        return _positions_of(self.meta, mask_ids)

    def select(self, **conds) -> np.ndarray:
        """Row positions matching metadata equality/IN predicates, e.g.
        ``select(mask_type=(1, 2), image_id=7)`` — the relational WHERE over
        everything except the mask column."""
        return _select(self.meta, conds)

    # -- mutation (the epoch-versioned write path) ---------------------------

    def _bump(self, changed_ids: np.ndarray | None) -> int:
        """Advance the epoch, recording which mask_ids the mutation rewrote
        (None for pure appends — they dirty nothing a pinned reader owns)."""
        self.epoch += 1
        self._dirty_log.append(
            (self.epoch,
             None if changed_ids is None
             else np.asarray(changed_ids, np.int64)))
        if len(self._dirty_log) > _DIRTY_LOG_MAX:
            drop = len(self._dirty_log) - _DIRTY_LOG_MAX
            self._dirty_floor = self._dirty_log[drop - 1][0]
            del self._dirty_log[:drop]
        return self.epoch

    def ids_dirty_since(self, epoch: int, mask_ids: np.ndarray) -> bool:
        """Whether any of ``mask_ids`` was updated/deleted after ``epoch``
        (conservatively True when the dirty log no longer reaches back)."""
        if epoch >= self.epoch:
            return False
        if epoch < self._dirty_floor:
            return True
        ids = np.asarray(mask_ids, np.int64)
        for ep, changed in self._dirty_log:
            if ep <= epoch or changed is None:
                continue
            if np.isin(ids, changed).any():
                return True
        return False

    def snapshot(self) -> "StoreSnapshot":
        """A read-only view pinned at the current epoch (see module docs)."""
        return StoreSnapshot(self)

    def _check_mutable(self) -> None:
        if self.tier not in ("memory", "disk"):
            raise ValueError(f"tier {self.tier!r} does not support mutation")
        if self._chi_chunks is None:
            raise ValueError("store has no CHI index; cannot maintain it "
                             "incrementally")

    def _cow_masks_buf(self, rows: np.ndarray) -> np.ndarray:
        """Copy-on-write replacement buffer for the memory tier: a fresh
        allocation (pinned readers keep the old arrays) that retains the
        old buffer's spare capacity, so appends after an update/delete
        stay amortized O(delta)."""
        cap = max(len(self._masks_buf) if self._masks_buf is not None else 0,
                  len(rows))
        buf = np.empty((cap,) + rows.shape[1:], rows.dtype)
        buf[:len(rows)] = rows
        return buf

    def _append_memory_rows(self, masks: np.ndarray) -> None:
        """Write new rows into the spare capacity behind ``self._masks`` —
        existing epoch views keep aliasing the old prefix untouched."""
        n = len(self._masks)
        need = n + len(masks)
        buf = self._masks_buf
        if buf is None or need > len(buf):
            cap = max(need, 2 * n, 8)
            grown = np.empty((cap,) + self._masks.shape[1:],
                             self._masks.dtype)
            grown[:n] = self._masks
            buf = grown
        buf[n:need] = masks.astype(self._masks.dtype, copy=False)
        self._masks_buf = buf
        self._masks = buf[:need]

    def append(self, masks: np.ndarray, meta: np.ndarray) -> int:
        """Append new masks (+ metadata rows) and index them incrementally:
        CHI tables are built **only for the delta** and attached as a new
        chunk — O(len(masks)), never O(len(store)).  Returns the new epoch."""
        self._check_mutable()
        meta = np.asarray(meta)
        if meta.dtype != MASK_META_DTYPE:
            raise ValueError("meta must use MASK_META_DTYPE")
        masks = np.asarray(masks, np.float32)
        if masks.ndim == 2:
            masks = masks[None]
        if masks.shape[1:] != (self.cfg.height, self.cfg.width):
            raise ValueError(f"mask shape {masks.shape[1:]} != cfg "
                             f"{(self.cfg.height, self.cfg.width)}")
        if len(masks) != len(meta):
            raise ValueError("masks and meta length mismatch")
        if len(masks) == 0:
            return self.epoch
        new_ids = meta["mask_id"]
        if len(np.unique(new_ids)) != len(new_ids) or \
                np.isin(new_ids, self.meta["mask_id"]).any():
            raise ValueError("append mask_ids must be unique and not "
                             "already present (use update to replace)")
        if self.packed:
            packing.validate_binary(masks)
        chunk = build_chi_delta(masks, self.cfg)    # CHI from pixel values
        stored = packing.pack_masks(masks) if self.packed else masks
        # mask bytes
        if self.tier == "memory":
            self._append_memory_rows(stored)
        else:
            for row, m in zip(meta, stored):
                np.save(self._mask_path(row["mask_id"]), m)
        # resident / device mirrors: extend incrementally when materialized
        if self._resident is not None:
            if self.tier == "memory":
                self._resident = None        # re-derived as a cheap view
            else:
                self._resident = np.concatenate([self._resident, stored])
        if self._device_masks is not None:
            self._device_masks = jnp.concatenate(
                [self._device_masks,
                 jnp.asarray(stored, self._device_masks.dtype)])
        # CHI: new chunk; no existing rows are copied
        self._chi_chunks.append(chunk)
        if self._chi_dev is not None:
            self._chi_dev = jnp.concatenate(
                [self._chi_dev, jnp.asarray(chunk)])
        self._chi_cat = None
        # pyramid tiers / value stats: extend materialized caches with the
        # delta's slice — same O(delta) contract as the chunk itself
        for g, tab in list(self._chi_tier_host.items()):
            self._chi_tier_host[g] = np.concatenate(
                [tab, tier_slice(chunk, self.cfg.grid, g)])
        for g, tab in list(self._chi_tier_dev.items()):
            self._chi_tier_dev[g] = jnp.concatenate(
                [tab, jnp.asarray(tier_slice(chunk, self.cfg.grid, g))])
        if self._chi_stats is not None:
            self._chi_stats = np.concatenate(
                [self._chi_stats, np.ascontiguousarray(chunk[:, -1, -1, :])])
        # metadata + shared-load cache extension
        self.meta = np.concatenate([self.meta, meta])
        if self._cache_map is not None:
            self._cache_map = np.concatenate(
                [self._cache_map, np.full(len(meta), -1, np.int64)])
        self._bump(None)
        if self.tier == "disk":
            np.save(os.path.join(self.root, "meta.npy"), self.meta)
            fname = f"chi.{len(self._chunk_files)}.npy"
            np.save(os.path.join(self.root, fname), chunk)
            self._chunk_files.append(fname)
            self._write_config()
        if len(self._chi_chunks) > _CHI_MAX_CHUNKS:
            self.compact_chi()
        return self.epoch

    def update(self, mask_ids: Sequence[int], masks: np.ndarray,
               meta: np.ndarray | None = None) -> int:
        """Replace mask bytes for existing ids, rebuilding CHI rows only for
        the delta (patched into their owning chunks).  ``meta`` optionally
        replaces the metadata rows too (mask_ids must match).  Returns the
        new epoch.  Arrays visible to pinned readers are never written in
        place — memory-tier mask and meta updates are copy-on-write."""
        self._check_mutable()
        mask_ids = np.atleast_1d(np.asarray(mask_ids, np.int64))
        if len(np.unique(mask_ids)) != len(mask_ids):
            raise ValueError("update mask_ids must be unique")
        positions = self.positions_of(mask_ids)
        if meta is not None:
            meta = np.asarray(meta)
            if meta.dtype != MASK_META_DTYPE:
                raise ValueError("meta must use MASK_META_DTYPE")
            if len(meta) != len(mask_ids) or \
                    np.any(meta["mask_id"] != mask_ids):
                raise ValueError("update meta rows must match mask_ids")
        masks = np.asarray(masks, np.float32)
        if masks.ndim == 2:
            masks = masks[None]
        if masks.shape != (len(positions), self.cfg.height, self.cfg.width):
            raise ValueError(f"expected masks of shape "
                             f"{(len(positions), self.cfg.height, self.cfg.width)}, "
                             f"got {masks.shape}")
        if len(positions) == 0:
            return self.epoch
        if self.packed:
            packing.validate_binary(masks)
        new_rows = build_chi_delta(masks, self.cfg)
        stored = packing.pack_masks(masks) if self.packed else masks
        # patch CHI rows inside their owning chunks (copy-on-write per chunk)
        starts, cid = self._chunk_of(positions)
        touched_chunks = np.unique(cid)
        for c in touched_chunks:
            sel = cid == c
            patched = self._chi_chunks[c].copy()
            patched[positions[sel] - starts[c]] = new_rows[sel]
            self._chi_chunks[c] = patched
        self._chi_cat = None
        if self._chi_dev is not None:
            self._chi_dev = self._chi_dev.at[jnp.asarray(positions)].set(
                jnp.asarray(new_rows))
        # pyramid tiers / value stats: patch materialized caches in place
        # (copy-on-write, same as the owning chunks above)
        for g, tab in list(self._chi_tier_host.items()):
            patched_t = tab.copy()
            patched_t[positions] = tier_slice(new_rows, self.cfg.grid, g)
            self._chi_tier_host[g] = patched_t
        for g, tab in list(self._chi_tier_dev.items()):
            self._chi_tier_dev[g] = tab.at[jnp.asarray(positions)].set(
                jnp.asarray(tier_slice(new_rows, self.cfg.grid, g)))
        if self._chi_stats is not None:
            stats = self._chi_stats.copy()
            stats[positions] = new_rows[:, -1, -1, :]
            self._chi_stats = stats
        # mask bytes (copy-on-write for memory so pinned views stay intact;
        # the replacement buffer keeps the old spare capacity so the next
        # append stays O(delta))
        if self.tier == "memory":
            self._masks_buf = self._cow_masks_buf(self._masks)
            self._masks = self._masks_buf[:len(self.meta)]
            self._masks[positions] = stored.astype(self._masks.dtype,
                                                   copy=False)
            self._resident = None
        else:
            for mid, m in zip(mask_ids, stored):
                np.save(self._mask_path(mid), m)
            if self._resident is not None:
                res = self._resident.copy()
                res[positions] = stored
                self._resident = res
        if self._device_masks is not None:
            self._device_masks = self._device_masks.at[
                jnp.asarray(positions)].set(
                jnp.asarray(stored, self._device_masks.dtype))
        # shared-load cache: the bytes at these positions changed
        if self._cache_map is not None:
            rows = self._cache_map[positions]
            valid = rows >= 0
            if np.any(valid):
                self._cache_map[positions[valid]] = -1
                self._cache_pos[rows[valid]] = -1
                self.cache_stats.invalidations += int(np.count_nonzero(valid))
        if meta is not None:
            fresh_meta = self.meta.copy()
            fresh_meta[positions] = meta
            self.meta = fresh_meta
        self._bump(mask_ids)
        if self.tier == "disk":
            for c in touched_chunks:
                np.save(os.path.join(self.root, self._chunk_files[c]),
                        self._chi_chunks[c])
            if meta is not None:
                np.save(os.path.join(self.root, "meta.npy"), self.meta)
            self._write_config()
        return self.epoch

    def delete(self, mask_ids: Sequence[int]) -> int:
        """Remove masks; surviving rows keep their relative order (positions
        renumber, mask_ids are stable).  Compacts the CHI into one chunk.
        Returns the new epoch."""
        self._check_mutable()
        mask_ids = np.unique(np.atleast_1d(np.asarray(mask_ids, np.int64)))
        positions = self.positions_of(mask_ids)
        if len(positions) == 0:
            return self.epoch
        keep = np.ones(len(self.meta), dtype=bool)
        keep[positions] = False
        keep_idx = np.nonzero(keep)[0]
        # CHI: compact surviving rows into a single chunk
        self._chi_chunks = [np.ascontiguousarray(self.chi_host()[keep])]
        self._chi_cat = None
        if self._chi_dev is not None:
            self._chi_dev = self._chi_dev[jnp.asarray(keep_idx)]
        # pyramid tiers / value stats: gather survivors
        for g, tab in list(self._chi_tier_host.items()):
            self._chi_tier_host[g] = np.ascontiguousarray(tab[keep])
        for g, tab in list(self._chi_tier_dev.items()):
            self._chi_tier_dev[g] = tab[jnp.asarray(keep_idx)]
        if self._chi_stats is not None:
            self._chi_stats = np.ascontiguousarray(self._chi_stats[keep])
        # mask bytes
        if self.tier == "memory":
            self._masks_buf = self._cow_masks_buf(self._masks[keep])
            self._masks = self._masks_buf[:len(keep_idx)]
            self._resident = None
        else:
            for mid in mask_ids:
                try:
                    os.remove(self._mask_path(mid))
                except FileNotFoundError:
                    pass
            if self._resident is not None:
                self._resident = np.ascontiguousarray(self._resident[keep])
        if self._device_masks is not None:
            self._device_masks = self._device_masks[jnp.asarray(keep_idx)]
        # shared-load cache: remap surviving positions (cached bytes are
        # still valid — only the numbering moved)
        if self._cache_map is not None:
            newpos = np.cumsum(keep) - 1
            self._cache_map = self._cache_map[keep]
            slot_old = self._cache_pos[:self._cache_used]
            live = slot_old >= 0
            gone = live & ~keep[np.where(live, slot_old, 0)]
            self.cache_stats.invalidations += int(np.count_nonzero(gone))
            remapped = np.where(live & ~gone,
                                newpos[np.where(live, slot_old, 0)], -1)
            self._cache_pos[:self._cache_used] = remapped
        self.meta = self.meta[keep]
        self._bump(mask_ids)
        if self.tier == "disk":
            np.save(os.path.join(self.root, "meta.npy"), self.meta)
            for f in self._chunk_files[1:]:
                try:
                    os.remove(os.path.join(self.root, f))
                except FileNotFoundError:
                    pass
            self._chunk_files = ["chi.npy"]
            np.save(os.path.join(self.root, "chi.npy"), self._chi_chunks[0])
            self._write_config()
        return self.epoch

    def compact_chi(self) -> None:
        """Merge the chunked CHI into one chunk (bounds gather fan-out);
        called automatically once appends fragment past a threshold."""
        if self._chi_chunks is None or len(self._chi_chunks) <= 1:
            return
        self._chi_chunks = [self.chi_host().copy()]
        self._chi_cat = self._chi_chunks[0]
        if self.tier == "disk" and self._chunk_files is not None:
            for f in self._chunk_files:
                if f != "chi.npy":
                    try:
                        os.remove(os.path.join(self.root, f))
                    except FileNotFoundError:
                        pass
            self._chunk_files = ["chi.npy"]
            np.save(os.path.join(self.root, "chi.npy"), self._chi_chunks[0])
            self._write_config()

    # -- resident tiers (backend ingest, not the metered query path) ---------

    def resident_masks(self) -> np.ndarray:
        """All mask bytes as one host array (cached per epoch).

        This is the one-time *ingest* read the device and mesh backends pin
        their resident copy from — deliberately not metered through ``io``:
        the quantity MaskSearch's index minimizes is per-query verification
        I/O, and a resident tier pays its bytes once at load time.
        Mutations keep the copy fresh incrementally (appends concatenate,
        updates patch a copy, deletes compact)."""
        if self._resident is None:
            if self._masks is not None:
                self._resident = np.asarray(self._masks, self.row_dtype)
            else:
                out = np.empty((len(self.meta),) + self.row_shape,
                               self.row_dtype)
                for i in range(len(self.meta)):
                    out[i] = np.load(self._mask_path(self.meta["mask_id"][i]))
                self._resident = out
        return self._resident

    def device_masks(self):
        """:meth:`resident_masks` pinned in device memory (jnp, cached) —
        the HBM-resident tier the device backend verifies against.  Once
        materialized, mutations maintain it incrementally: appends
        ``device_put`` only the new rows, updates scatter the changed rows,
        deletes gather the survivors."""
        if self._device_masks is None:
            self._device_masks = jnp.asarray(self.resident_masks())
        return self._device_masks

    # -- mask-byte access (the metered path) --------------------------------

    def enable_cache(self, capacity_bytes: int | None = None) -> bool:
        """Turn on the cross-query load cache (hits are not metered — the
        bytes were already paid for by an earlier query in the workload).

        The cache is bounded: at most ``capacity_bytes`` (default 256 MiB)
        of mask rows stay resident; beyond that, rows are evicted FIFO and
        accounted in ``CacheStats.evictions``.

        Idempotent: returns True iff this call newly enabled the cache, so
        nested users (a workload running under the query service, which
        keeps a long-lived cross-session cache) don't clear an outer
        owner's cache on the way out."""
        if self._cache_map is not None:
            return False
        cap_bytes = DEFAULT_CACHE_BYTES if capacity_bytes is None \
            else int(capacity_bytes)
        # Capacity in *stored-representation* rows: a packed store's rows
        # are ~32× smaller, so the same byte budget holds ~32× more masks.
        self._cache_cap = max(cap_bytes // self.row_nbytes, 1)
        self._cache_map = np.full(len(self.meta), -1, dtype=np.int64)
        self._cache_arr = None
        self._cache_pos = np.full(self._cache_cap, -1, dtype=np.int64)
        self._cache_used = 0
        self._cache_clock = 0
        self.cache_stats.reset()
        return True

    def clear_cache(self) -> None:
        self._cache_map = None
        self._cache_arr = None
        self._cache_pos = None
        self._cache_used = 0
        self._cache_clock = 0
        self._cache_cap = 0

    def _read_files(self, mask_ids: np.ndarray) -> np.ndarray:
        """Metered disk-tier read of whole masks by id."""
        loaded = np.empty((len(mask_ids),) + self.row_shape,
                          dtype=self.row_dtype)
        t0 = time.perf_counter()
        nbytes = 0
        for i, mid in enumerate(mask_ids):
            arr = np.load(self._mask_path(mid))
            loaded[i] = arr
            nbytes += arr.nbytes
        self.io.wall_time_s += time.perf_counter() - t0
        self.io.files_read += len(mask_ids)
        self.io.bytes_read += nbytes
        return loaded

    def _read_tier(self, miss_pos: np.ndarray) -> np.ndarray:
        if self.tier in ("memory", "device"):
            loaded = np.asarray(self._masks)[miss_pos]
            self.io.files_read += len(miss_pos)
            self.io.bytes_read += int(loaded.nbytes)
            return loaded
        return self._read_files(self.meta["mask_id"][miss_pos])

    def _cache_insert(self, miss_pos: np.ndarray, loaded: np.ndarray) -> None:
        """Insert loaded rows, filling free capacity first, then FIFO-evicting
        (accounted in ``cache_stats.evictions``)."""
        cap = self._cache_cap
        if cap <= 0:
            return
        if len(miss_pos) > cap:
            drop = len(miss_pos) - cap
            miss_pos, loaded = miss_pos[drop:], loaded[drop:]
        n = len(miss_pos)
        free = cap - self._cache_used
        k = min(free, n)
        if k:
            need = self._cache_used + k
            arr = self._cache_arr
            if arr is None or need > len(arr):
                grow = min(cap, max(need, 2 * (len(arr) if arr is not None
                                               else 128)))
                grown = np.empty((grow,) + self.row_shape, self.row_dtype)
                if arr is not None:
                    grown[:self._cache_used] = arr[:self._cache_used]
                self._cache_arr = arr = grown
            base = self._cache_used
            arr[base:need] = loaded[:k]
            self._cache_pos[base:need] = miss_pos[:k]
            self._cache_map[miss_pos[:k]] = base + np.arange(k)
            self._cache_used = need
        if n > k:
            r = n - k
            slots = (self._cache_clock + np.arange(r)) % cap
            old = self._cache_pos[slots]
            valid = old >= 0
            vo = old[valid]
            still = self._cache_map[vo] == slots[valid]
            self._cache_map[vo[still]] = -1
            self.cache_stats.evictions += int(np.count_nonzero(valid))
            self._cache_arr[slots] = loaded[k:]
            self._cache_pos[slots] = miss_pos[k:]
            self._cache_map[miss_pos[k:]] = slots
            self._cache_clock = int((self._cache_clock + r) % cap)

    def load(self, positions: np.ndarray) -> np.ndarray:
        """Load mask bytes for the given row positions.  On the disk tier
        this is the I/O that the filter-verification framework minimizes."""
        positions = np.asarray(positions, dtype=np.int64)
        if self._cache_map is None:
            return self._read_tier(positions)
        rows = self._cache_map[positions]
        miss = rows < 0
        n_hit = int(np.count_nonzero(~miss))
        self.cache_stats.hits += n_hit
        # bytes_saved in *stored-representation* bytes — exact for float
        # and packed tiers alike (satellite: packed byte metering).
        self.cache_stats.bytes_saved += n_hit * self.row_nbytes
        if not np.any(miss):
            return self._cache_arr[rows]
        miss_pos = np.unique(positions[miss])
        self.cache_stats.misses += len(miss_pos)
        loaded = self._read_tier(miss_pos)
        out = np.empty((len(positions),) + self.row_shape, self.row_dtype)
        if n_hit:
            out[~miss] = self._cache_arr[rows[~miss]]
        out[miss] = loaded[np.searchsorted(miss_pos, positions[miss])]
        self._cache_insert(miss_pos, np.asarray(loaded, self.row_dtype))
        return out

    def load_all(self) -> np.ndarray:
        return self.load(np.arange(len(self)))

    def load_rows(self, positions: np.ndarray, spans: np.ndarray):
        """Partial verification loads (beyond-paper): read only the row span
        each mask's ROI needs, via npy memmap slicing — the disk pays for
        ROI rows, not the whole mask.

        Args:
          positions: (n,) row positions.
          spans: (n, 2) [row_start, row_end) per mask.
        Returns:
          (buf (n, max_span, row_width) in the stored representation —
           float32 pixel rows, or uint32 words on the packed tier; rows
           beyond a mask's span are 0 — and heights (n,) int32).
        Metered: bytes = rows actually read (+4 KiB header/IO floor per
        file under the EBS model's page granularity).
        """
        masks = self._masks if self.tier in ("memory", "device") else None
        return _load_row_spans(self.cfg, self.io, self.meta, masks,
                               self._mask_path, positions, spans,
                               row_width=self.row_shape[1],
                               dtype=self.row_dtype)


class StoreSnapshot:
    """Read-only view of a :class:`MaskStore` pinned at one epoch — the
    snapshot resumable runs hold (DESIGN.md §8).

    Delegation contract: while the store's epoch is unchanged every call is
    forwarded verbatim (shared-load cache, I/O metering, partial-row
    loads).  Once the store moves on:

    * memory-resident tiers keep serving — mutations are copy-on-write at
      the array level, so the pinned ``meta``/mask views are immutable;
    * the disk tier serves reads only while none of the *requested*
      mask_ids was updated or deleted since the pinned epoch, and raises
      :class:`StaleRunError` otherwise (mask files are rewritten in place);
    * the CHI table is construction-time state (bounds passes run at pin
      time), so :attr:`chi_table` refuses to serve a moved store.
    """

    def __init__(self, store: MaskStore):
        self._store = store
        self.epoch = store.epoch
        self.cfg = store.cfg
        self.tier = store.tier
        self.root = store.root
        self.meta = store.meta
        self._masks = store._masks
        # Representation is construction-time state — it never changes
        # across epochs, so the pinned values stay valid forever.
        self.packed = store.packed
        self.words = store.words
        self.row_shape = store.row_shape
        self.row_dtype = store.row_dtype
        self.row_nbytes = store.row_nbytes

    @property
    def fresh(self) -> bool:
        return self.epoch == self._store.epoch

    # -- metering / cache state shared with the live store ------------------
    @property
    def io(self) -> IOStats:
        return self._store.io

    @property
    def cache_stats(self) -> CacheStats:
        return self._store.cache_stats

    @property
    def _cache_map(self):
        # Stale readers must not consult the live cache: its position
        # numbering and contents track the *current* epoch.
        return self._store._cache_map if self.fresh else None

    @property
    def cache_enabled(self) -> bool:
        """Cross-query load cache visibility at the pinned epoch — False
        once the store moves on (the live cache's position numbering
        tracks the current epoch, so a stale reader must not plan
        around it)."""
        return self._cache_map is not None

    @property
    def backend_cache(self) -> dict:
        return self._store.backend_cache

    # -- pinned metadata surface --------------------------------------------
    def __len__(self) -> int:
        return len(self.meta)

    @property
    def mask_ids(self) -> np.ndarray:
        return self.meta["mask_id"]

    def positions_of(self, mask_ids: Sequence[int]) -> np.ndarray:
        return _positions_of(self.meta, mask_ids)

    def select(self, **conds) -> np.ndarray:
        return _select(self.meta, conds)

    @property
    def chi_table(self):
        if not self.fresh:
            raise StaleRunError(
                f"CHI bounds pinned at epoch {self.epoch} cannot be "
                f"recomputed: store moved to epoch {self._store.epoch}")
        return self._store.chi_table

    @property
    def chi_chunks(self) -> list | None:
        """Chunked CHI layout for observability byte accounting — None (not
        an error) once the store moves on; row *sizes* don't change across
        epochs but the freshness contract stays uniform with chi_table."""
        return self._store.chi_chunks if self.fresh else None

    def chi_host(self, positions: np.ndarray | None = None) -> np.ndarray:
        """Host CHI rows at the pinned epoch — same freshness contract as
        :attr:`chi_table` (bounds passes run at pin time)."""
        if not self.fresh:
            raise StaleRunError(
                f"CHI bounds pinned at epoch {self.epoch} cannot be "
                f"recomputed: store moved to epoch {self._store.epoch}")
        return self._store.chi_host(positions)

    def _require_fresh_index(self) -> MaskStore:
        if not self.fresh:
            raise StaleRunError(
                f"CHI bounds pinned at epoch {self.epoch} cannot be "
                f"recomputed: store moved to epoch {self._store.epoch}")
        return self._store

    def chi_tier_host(self, g: int) -> np.ndarray:
        """Pyramid tier at the pinned epoch — same freshness contract as
        :attr:`chi_table` (the refinement ladder runs at pin time)."""
        return self._require_fresh_index().chi_tier_host(g)

    def chi_tier_table(self, g: int):
        return self._require_fresh_index().chi_tier_table(g)

    def chi_value_stats(self) -> np.ndarray:
        """Build-time index statistics at the pinned epoch (cost model)."""
        return self._require_fresh_index().chi_value_stats()

    def snapshot(self) -> "StoreSnapshot":
        return self

    # -- byte reads at the pinned epoch -------------------------------------
    def _require_clean(self, positions: np.ndarray) -> np.ndarray:
        ids = self.meta["mask_id"][positions]
        if self._store.ids_dirty_since(self.epoch, ids):
            raise StaleRunError(
                f"run pinned at epoch {self.epoch} needs mask bytes that "
                f"were rewritten (store at epoch {self._store.epoch})")
        return ids

    def can_serve(self, positions: np.ndarray) -> bool:
        """Whether :meth:`load` for these positions would succeed — True
        while fresh or memory-resident; for the disk tier, while none of
        the positions' mask_ids moved since the pinned epoch."""
        if self.fresh or self._masks is not None:
            return True
        positions = np.asarray(positions, dtype=np.int64)
        ids = self.meta["mask_id"][positions]
        return not self._store.ids_dirty_since(self.epoch, ids)

    def load(self, positions: np.ndarray) -> np.ndarray:
        if self.fresh:
            return self._store.load(positions)
        positions = np.asarray(positions, dtype=np.int64)
        if self._masks is not None:
            loaded = np.asarray(self._masks)[positions]
            self.io.files_read += len(positions)
            self.io.bytes_read += int(loaded.nbytes)
            return loaded
        ids = self._require_clean(positions)
        return self._store._read_files(ids)

    def load_all(self) -> np.ndarray:
        return self.load(np.arange(len(self)))

    def load_rows(self, positions: np.ndarray, spans: np.ndarray):
        if self.fresh:
            return self._store.load_rows(positions, spans)
        positions = np.asarray(positions, dtype=np.int64)
        if self._masks is None:
            self._require_clean(positions)
        return _load_row_spans(self.cfg, self.io, self.meta, self._masks,
                               self._store._mask_path, positions, spans,
                               row_width=self.row_shape[1],
                               dtype=self.row_dtype)
