"""MaskStore — the tiered mask database behind ``MasksDatabaseView``.

The paper's schema::

    MasksDatabaseView(mask_id, image_id, model_id, mask_type, mask REAL[][])

Metadata + the CHI table are small and always memory/HBM-resident; mask
*bytes* live in a configurable tier:

* ``disk``   — one ``.npy`` file per mask (the paper's file-per-mask layout on
               EBS; this is the tier whose I/O the index avoids).  All reads
               are metered: real wall time + a modeled EBS-gp3 time
               (125 MB/s throughput, 3000 IOPS) so benchmarks can report the
               paper's own I/O model independent of the container's page
               cache.
* ``memory`` — a host ndarray (the "hot" tier; also what a TPU host RAM tier
               looks like).
* ``device`` — a jnp array (HBM-resident, used by the distributed shard_map
               engine and the dry-run).

The engine only sees :meth:`load` / :meth:`load_all`, so tiers are
interchangeable.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from .chi import CHIConfig, build_chi_np

# Paper's EBS gp3 provisioning (§4): 125 MiB/s, 3000 IOPS.
EBS_THROUGHPUT_BYTES_S = 125 * 1024 * 1024
EBS_IOPS = 3000.0
EBS_IO_CHUNK = 256 * 1024  # gp3 accounting chunk for large sequential reads


@dataclasses.dataclass
class IOStats:
    """Disk-tier accounting — the quantity MaskSearch's index minimizes."""

    files_read: int = 0
    bytes_read: int = 0
    wall_time_s: float = 0.0

    @property
    def modeled_ebs_time_s(self) -> float:
        """Time under the paper's EBS model: throughput-bound transfer plus
        per-request IOPS cost (each file ≥1 I/O, 256 KiB accounting chunks)."""
        ios = self.files_read + self.bytes_read // EBS_IO_CHUNK
        return self.bytes_read / EBS_THROUGHPUT_BYTES_S + ios / EBS_IOPS

    def merge(self, other: "IOStats") -> None:
        self.files_read += other.files_read
        self.bytes_read += other.bytes_read
        self.wall_time_s += other.wall_time_s

    def reset(self) -> None:
        self.files_read = 0
        self.bytes_read = 0
        self.wall_time_s = 0.0


@dataclasses.dataclass
class CacheStats:
    """Shared-load cache accounting (cross-query / cross-session sharing).

    ``bytes_saved`` is the disk I/O that cache hits avoided — the quantity
    the service's fused verification maximizes across in-flight sessions."""

    hits: int = 0
    misses: int = 0
    bytes_saved: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def reset(self) -> None:
        self.hits = 0
        self.misses = 0
        self.bytes_saved = 0


MASK_META_DTYPE = np.dtype([
    ("mask_id", np.int64),
    ("image_id", np.int64),
    ("model_id", np.int32),
    ("mask_type", np.int32),
])


class MaskStore:
    """A partition of the mask database (one shard in the distributed case)."""

    def __init__(self, cfg: CHIConfig, meta: np.ndarray, *, tier: str,
                 root: str | None = None, masks: np.ndarray | None = None,
                 chi_table: np.ndarray | None = None):
        if meta.dtype != MASK_META_DTYPE:
            raise ValueError("meta must use MASK_META_DTYPE")
        self.cfg = cfg
        self.meta = meta
        self.tier = tier
        self.root = root
        self._masks = masks
        self.io = IOStats()
        # Resident copies + per-store execution backends (core/backend.py):
        # device/mesh backends pin mask bytes once and reuse them across runs.
        self._resident: np.ndarray | None = None
        self._device_masks = None
        self._backend_cache: dict = {}
        # Optional cross-query load cache (multi-query workloads share
        # verification I/O — the full paper's workload optimization).
        # Array-based: _cache_map[pos] = row into _cache_rows, -1 = miss.
        self._cache_map: np.ndarray | None = None
        self._cache_rows: list[np.ndarray] | None = None
        self.cache_stats = CacheStats()
        if chi_table is None and masks is not None:
            chi_table = build_chi_np(np.asarray(masks), cfg)
        self._chi = jnp.asarray(chi_table) if chi_table is not None else None

    # -- construction ------------------------------------------------------

    @classmethod
    def create_memory(cls, masks: np.ndarray, meta: np.ndarray, cfg: CHIConfig,
                      chi_table: np.ndarray | None = None) -> "MaskStore":
        return cls(cfg, meta, tier="memory", masks=np.asarray(masks),
                   chi_table=chi_table)

    @classmethod
    def create_disk(cls, root: str, masks: np.ndarray, meta: np.ndarray,
                    cfg: CHIConfig, chi_table: np.ndarray | None = None
                    ) -> "MaskStore":
        """Ingest: write one .npy per mask + persist CHI and metadata."""
        os.makedirs(os.path.join(root, "masks"), exist_ok=True)
        masks = np.asarray(masks, dtype=np.float32)
        for row, m in zip(meta, masks):
            np.save(os.path.join(root, "masks", f"{int(row['mask_id'])}.npy"), m)
        if chi_table is None:
            chi_table = build_chi_np(masks, cfg)
        np.save(os.path.join(root, "chi.npy"), np.asarray(chi_table))
        np.save(os.path.join(root, "meta.npy"), meta)
        with open(os.path.join(root, "config.json"), "w") as f:
            json.dump({
                "grid": cfg.grid, "num_bins": cfg.num_bins,
                "height": cfg.height, "width": cfg.width,
                "thresholds": None if cfg.thresholds is None else list(cfg.thresholds),
            }, f)
        return cls(cfg, meta, tier="disk", root=root, chi_table=chi_table)

    @classmethod
    def open_disk(cls, root: str) -> "MaskStore":
        with open(os.path.join(root, "config.json")) as f:
            raw = json.load(f)
        cfg = CHIConfig(grid=raw["grid"], num_bins=raw["num_bins"],
                        height=raw["height"], width=raw["width"],
                        thresholds=None if raw["thresholds"] is None
                        else tuple(raw["thresholds"]))
        meta = np.load(os.path.join(root, "meta.npy"))
        chi = np.load(os.path.join(root, "chi.npy"))
        return cls(cfg, meta, tier="disk", root=root, chi_table=chi)

    # -- properties ---------------------------------------------------------

    def __len__(self) -> int:
        return len(self.meta)

    @property
    def chi_table(self):
        if self._chi is None:
            raise ValueError("store has no CHI table; ingest with an index")
        return self._chi

    @property
    def mask_ids(self) -> np.ndarray:
        return self.meta["mask_id"]

    def positions_of(self, mask_ids: Sequence[int]) -> np.ndarray:
        """Row positions for the given mask_ids (metadata is host-side)."""
        order = np.argsort(self.meta["mask_id"], kind="stable")
        sorted_ids = self.meta["mask_id"][order]
        pos = np.searchsorted(sorted_ids, mask_ids)
        if np.any(sorted_ids[pos] != np.asarray(mask_ids)):
            raise KeyError("unknown mask_id in lookup")
        return order[pos]

    def select(self, **conds) -> np.ndarray:
        """Row positions matching metadata equality/IN predicates, e.g.
        ``select(mask_type=(1, 2), image_id=7)`` — the relational WHERE over
        everything except the mask column."""
        keep = np.ones(len(self.meta), dtype=bool)
        for col, val in conds.items():
            vals = np.atleast_1d(np.asarray(val))
            keep &= np.isin(self.meta[col], vals)
        return np.nonzero(keep)[0]

    # -- resident tiers (backend ingest, not the metered query path) ---------

    def resident_masks(self) -> np.ndarray:
        """All mask bytes as one host array (cached).

        This is the one-time *ingest* read the device and mesh backends pin
        their resident copy from — deliberately not metered through ``io``:
        the quantity MaskSearch's index minimizes is per-query verification
        I/O, and a resident tier pays its bytes once at load time."""
        if self._resident is None:
            if self._masks is not None:
                self._resident = np.asarray(self._masks, np.float32)
            else:
                out = np.empty((len(self.meta), self.cfg.height,
                                self.cfg.width), np.float32)
                for i in range(len(self.meta)):
                    path = os.path.join(
                        self.root, "masks",
                        f"{int(self.meta['mask_id'][i])}.npy")
                    out[i] = np.load(path)
                self._resident = out
        return self._resident

    def device_masks(self):
        """:meth:`resident_masks` pinned in device memory (jnp, cached) —
        the HBM-resident tier the device backend verifies against."""
        if self._device_masks is None:
            self._device_masks = jnp.asarray(self.resident_masks())
        return self._device_masks

    # -- mask-byte access (the metered path) --------------------------------

    def enable_cache(self) -> bool:
        """Turn on the cross-query load cache (hits are not metered — the
        bytes were already paid for by an earlier query in the workload).

        Idempotent: returns True iff this call newly enabled the cache, so
        nested users (a workload running under the query service, which
        keeps a long-lived cross-session cache) don't clear an outer
        owner's cache on the way out."""
        if self._cache_map is not None:
            return False
        self._cache_map = np.full(len(self.meta), -1, dtype=np.int64)
        self._cache_rows = [None, 0]        # [rows array, used count]
        self.cache_stats.reset()
        return True

    def clear_cache(self) -> None:
        self._cache_map = None
        self._cache_rows = None

    def _read_tier(self, miss_pos: np.ndarray) -> np.ndarray:
        if self.tier in ("memory", "device"):
            loaded = np.asarray(self._masks)[miss_pos]
            self.io.files_read += len(miss_pos)
            self.io.bytes_read += int(loaded.nbytes)
            return loaded
        loaded = np.empty((len(miss_pos), self.cfg.height, self.cfg.width),
                          dtype=np.float32)
        t0 = time.perf_counter()
        nbytes = 0
        for i, p in enumerate(miss_pos):
            path = os.path.join(self.root, "masks",
                                f"{int(self.meta['mask_id'][p])}.npy")
            arr = np.load(path)
            loaded[i] = arr
            nbytes += arr.nbytes
        self.io.wall_time_s += time.perf_counter() - t0
        self.io.files_read += len(miss_pos)
        self.io.bytes_read += nbytes
        return loaded

    def load(self, positions: np.ndarray) -> np.ndarray:
        """Load mask bytes for the given row positions.  On the disk tier
        this is the I/O that the filter-verification framework minimizes."""
        positions = np.asarray(positions, dtype=np.int64)
        if self._cache_map is None:
            return self._read_tier(positions)
        rows = self._cache_map[positions]
        miss = rows < 0
        n_hit = int(np.count_nonzero(~miss))
        itemsize = (self._masks.dtype.itemsize if self._masks is not None
                    else 4)                      # disk tier stores float32
        self.cache_stats.hits += n_hit
        self.cache_stats.bytes_saved += (
            n_hit * self.cfg.height * self.cfg.width * itemsize)
        if np.any(miss):
            miss_pos = np.unique(positions[miss])
            self.cache_stats.misses += len(miss_pos)
            loaded = self._read_tier(miss_pos)
            base = self._cache_rows[1]
            arr = self._cache_rows[0]
            need = base + len(miss_pos)
            if arr is None or need > len(arr):
                cap = max(need, 2 * (len(arr) if arr is not None else 256))
                grown = np.empty((cap, self.cfg.height, self.cfg.width),
                                 np.float32)
                if arr is not None:
                    grown[:base] = arr[:base]
                arr = grown
            arr[base:need] = loaded
            self._cache_rows = [arr, need]
            self._cache_map[miss_pos] = base + np.arange(len(miss_pos))
            rows = self._cache_map[positions]
        return self._cache_rows[0][rows]

    def load_all(self) -> np.ndarray:
        return self.load(np.arange(len(self)))

    def load_rows(self, positions: np.ndarray, spans: np.ndarray):
        """Partial verification loads (beyond-paper): read only the row span
        each mask's ROI needs, via npy memmap slicing — the disk pays for
        ROI rows, not the whole mask.

        Args:
          positions: (n,) row positions.
          spans: (n, 2) [row_start, row_end) per mask.
        Returns:
          (buf (n, max_span, W) float32 — rows beyond a mask's span are 0,
           heights (n,) int32).
        Metered: bytes = rows actually read (+4 KiB header/IO floor per
        file under the EBS model's page granularity).
        """
        positions = np.asarray(positions, dtype=np.int64)
        spans = np.asarray(spans, dtype=np.int64)
        heights = np.maximum(spans[:, 1] - spans[:, 0], 0)
        max_span = max(int(heights.max()) if len(heights) else 0, 1)
        buf = np.zeros((len(positions), max_span, self.cfg.width), np.float32)
        t0 = time.perf_counter()
        nbytes = 0
        for i, p in enumerate(positions):
            r0, r1 = int(spans[i, 0]), int(spans[i, 1])
            if r1 <= r0:
                continue
            if self.tier in ("memory", "device"):
                rows = np.asarray(self._masks)[p, r0:r1]
            else:
                path = os.path.join(self.root, "masks",
                                    f"{int(self.meta['mask_id'][p])}.npy")
                mm = np.load(path, mmap_mode="r")
                rows = np.asarray(mm[r0:r1])
            buf[i, : r1 - r0] = rows
            nbytes += rows.nbytes + 4096     # + header/page floor
        self.io.wall_time_s += time.perf_counter() - t0
        self.io.files_read += len(positions)
        self.io.bytes_read += nbytes
        return buf, heights.astype(np.int32)
