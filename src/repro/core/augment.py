"""Scenario-1 dataset augmentation (demo §4, Step 3).

After a Top-K/Filter query retrieves images where the model attends outside
the object bounding box, the demo's "Start Augment" button randomizes pixels
*outside* the ROI (keeping labels) so the retrained model cannot rely on
background correlations.  This is that button, as a library call wired into
the data pipeline (see examples/scenario1_debugging.py for the full
train → query → augment → retrain loop).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .cp import _roi_mask

Array = jax.Array


def randomize_outside_roi(rng: jax.Array, images: Array, rois: Array) -> Array:
    """Replace pixels outside each image's ROI with uniform noise.

    Args:
      rng: PRNG key.
      images: (B, H, W) or (B, H, W, C) floats in [0, 1].
      rois: (B, 4) half-open rectangles (the object boxes).
    Returns:
      Augmented images, same shape/dtype.
    """
    chan = images.ndim == 4
    h, w = images.shape[1:3]
    inside = _roi_mask(rois, h, w)
    if chan:
        inside = inside[..., None]
    noise = jax.random.uniform(rng, images.shape, dtype=images.dtype)
    return jnp.where(inside, images, noise)


def mix_augmented(rng: jax.Array, tokens: Array, selected: Array,
                  vocab_size: int) -> Array:
    """LM analogue: re-randomize the *non-salient* positions of selected
    sequences (selected: (B,) bool; positions outside the per-example salient
    span get fresh random tokens).  Used by the scenario-1 example when the
    "images" are token grids."""
    noise = jax.random.randint(rng, tokens.shape, 0, vocab_size, tokens.dtype)
    return jnp.where(selected[:, None], noise, tokens)
