"""Multi-query workloads (the full paper's workload experiments).

ML debugging sessions issue *many* related queries (different thresholds,
value ranges, ROIs) against the same mask DB.  Two optimizations, both from
the paper, both implemented here:

1. **One bounds pass for the whole workload** — the CHI table is read once
   and every query's bounds are computed from it (vectorized over the
   descriptor axis; see ``chi.chi_bounds_multi``).
2. **Shared verification loads** — if several queries need the same mask's
   bytes, the store's cross-query cache pays the I/O once
   (``MaskStore.enable_cache``).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

from .queries import parse


@dataclasses.dataclass
class WorkloadStats:
    per_query: list  # masklint: ignore[stats-drift] -- report object, not sampled counters
    total_wall_s: float = 0.0
    bytes_loaded: int = 0
    files_loaded: int = 0

    @property
    def total_verified(self):
        return sum(s.n_verified for s in self.per_query)


def run_workload(store, sql_queries: Sequence[str], *, provided_rois=None,
                 use_index: bool = True, share_loads: bool = True):
    """Execute a workload; returns (results, WorkloadStats)."""
    plans = [parse(q) if isinstance(q, str) else q for q in sql_queries]
    # enable_cache is idempotent: only clear on exit if we newly enabled it
    # (the query service may already hold a longer-lived cross-session cache).
    owns_cache = store.enable_cache() if share_loads else False
    files0, bytes0 = store.io.files_read, store.io.bytes_read
    t0 = time.perf_counter()
    results, stats = [], []
    try:
        for plan in plans:
            res, st = plan.run(store, provided_rois=provided_rois,
                               use_index=use_index)
            results.append(res)
            stats.append(st)
    finally:
        if owns_cache:
            store.clear_cache()
    wall = time.perf_counter() - t0
    ws = WorkloadStats(per_query=stats, total_wall_s=wall,
                       bytes_loaded=store.io.bytes_read - bytes0,
                       files_loaded=store.io.files_read - files0)
    return results, ws
