"""Mask generation — the "masks come from models" half of the workflow.

The demo's masks are model saliency maps (Grad-CAM-style) and object-detector
boxes.  Our mask sources, per architecture family (DESIGN.md §7):

  * **attention rollout** for transformer LMs — per-layer attention maps
    multiplied through the residual stream (Abnar & Zuidema), giving a
    (S × S) float mask per example;
  * **last-layer attention maps** (cheaper; per-head or head-averaged);
  * **input-gradient saliency** for any differentiable model (the only
    option for attention-free Mamba-2) — |∂loss/∂embedding| reduced over
    features, reshaped to a 2-D grid;
  * **cross-attention maps** for enc-dec (whisper): (dec_len × enc_len);
  * **expert-utilization maps** for MoE: (tokens × experts) routing heat map.

Every source normalizes into the paper's data model: 2-D float arrays in
[0, 1), ready for CHI ingest.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def normalize01(mask: Array, axis=(-2, -1)) -> Array:
    """Affinely map each mask to [0, 1) (per-mask min/max, ε-shrunk so the
    max stays strictly below 1 — the paper's value domain)."""
    lo = jnp.min(mask, axis=axis, keepdims=True)
    hi = jnp.max(mask, axis=axis, keepdims=True)
    out = (mask - lo) / jnp.maximum(hi - lo, 1e-12)
    return out * (1.0 - 1e-6)


def attention_rollout(attn: Array) -> Array:
    """Attention rollout over a layer stack.

    Args:
      attn: (L, B, heads, S, S) post-softmax attention.
    Returns:
      (B, S, S) rollout masks in [0, 1).
    """
    a = jnp.mean(attn, axis=2)                       # head-average: (L, B, S, S)
    s = a.shape[-1]
    eye = jnp.eye(s, dtype=a.dtype)
    a = 0.5 * a + 0.5 * eye                          # residual connection
    a = a / jnp.sum(a, axis=-1, keepdims=True)

    def step(carry, layer):
        return layer @ carry, None

    out, _ = jax.lax.scan(step, jnp.broadcast_to(eye, a.shape[1:]), a)
    return normalize01(out)


def last_layer_attention(attn_last: Array) -> Array:
    """(B, heads, S, S) → (B, S, S) head-averaged map in [0, 1)."""
    return normalize01(jnp.mean(attn_last, axis=1))


def input_saliency(loss_fn, params, batch) -> Array:
    """|∂loss/∂embeddings| saliency (works for every arch incl. Mamba-2).

    ``loss_fn(params, batch, embeddings) -> scalar`` where ``embeddings`` is
    the (B, S, D) input-embedding tensor the model consumes.  Returns
    (B, S) per-token scores in [0, 1).
    """
    def wrt_embeddings(emb):
        return loss_fn(params, batch, emb)

    emb = batch["embeddings"]
    g = jax.grad(wrt_embeddings)(emb)
    scores = jnp.linalg.norm(g, axis=-1)             # (B, S)
    return normalize01(scores, axis=(-1,))


def tokens_to_grid(scores: Array, height: int, width: int) -> Array:
    """Arrange (B, S) per-token scores into (B, height, width) masks.

    Tokens fill the grid row-major; short sequences pad with 0, long ones
    average-pool.  This is the canonical "LM tokens as a 2-D mask" layout
    the query engine indexes.
    """
    b, s = scores.shape
    cells = height * width
    if s >= cells:
        # average-pool s → cells
        pad = (-s) % cells
        x = jnp.pad(scores, ((0, 0), (0, pad)))
        x = x.reshape(b, cells, -1).mean(-1)
    else:
        x = jnp.pad(scores, ((0, 0), (0, cells - s)))
    return x.reshape(b, height, width)


def resize_mask(mask: Array, height: int, width: int) -> Array:
    """Bilinear-resize arbitrary 2-D maps (e.g. cross-attention (T×S)) onto
    the store's canonical (H, W)."""
    b = mask.shape[0]
    return jax.image.resize(mask, (b, height, width), method="bilinear")


def expert_utilization_map(router_probs: Array, height: int, width: int) -> Array:
    """MoE routing heat map: (B, S, E) router probabilities → per-example
    (H, W) mask (tokens × experts resized).  A MaskSearch client unique to
    MoE archs: 'find batches whose expert load is most skewed' is a CP query
    over these masks."""
    return normalize01(resize_mask(router_probs, height, width))
