"""The composable query-plan IR: logical plans + one physical Run API.

A :class:`LogicalPlan` is the canonical description of any front-end query —
source (mask-type restriction, optional grouping) → boolean predicate tree
(:mod:`.exprs` ``Pred``) → ranking or scalar aggregation.  The SQL parser
(:mod:`.queries`) compiles text to this IR; programmatic callers build it
directly; the service canonicalizes it into cache keys.

:func:`compile_plan` lowers a logical plan to exactly one physical run
object from :mod:`.engine` — :class:`~.engine.FilterRun`,
:class:`~.engine.TopKRun`, :class:`~.engine.FilteredTopKRun`,
:class:`~.engine.ScalarAggRun`, :class:`~.engine.MinMaxAggRun`, or their
dual-mask (pair) siblings :class:`~.engine.PairFilterRun` /
:class:`~.engine.PairTopKRun` / :class:`~.engine.PairFilteredTopKRun`
when the expressions contain pair terms (DESIGN.md §9) — all of
which present the uniform ``target / take_batch / apply_exact / finished /
result`` interface, so sessions, the fused scheduler, and any future
operator (pagination over filters, joins, distributed sharding) drive them
identically.

:func:`run_plan` is the one-shot driver, including the ``use_index=False``
full-scan baseline every plan kind can be checked against.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from ..obs import trace as _trace
from . import engine
from .exprs import (And, BinOp, Cmp, CP, Node, Not, Or, PairTerm, Pred,
                    RoiArea, TypeIn, is_group_expr, is_pair_expr,
                    pair_roles_of)

_KINDS = ("filter", "topk", "filtered_topk", "scalar_agg")


@dataclasses.dataclass(frozen=True)
class LogicalPlan:
    """source → predicate → rank/aggregate, as one immutable record.

    Exactly one of the three output shapes is active:

    * ``order_by`` set → a ranking (``topk``; ``filtered_topk`` when a
      predicate is also present);
    * ``agg`` set → a scalar aggregation over ``agg_expr``;
    * neither → a filter (``predicate`` required).
    """

    select: str = "mask_id"               # "mask_id" | "image_id"
    predicate: Optional[Pred] = None      # boolean predicate tree
    mask_types: Optional[tuple] = None    # source-level type restriction
    order_by: Optional[Node] = None       # ranking expression
    k: Optional[int] = None
    desc: bool = True
    agg: Optional[str] = None             # SUM | AVG | MIN | MAX
    agg_expr: Optional[Node] = None
    group_by_image: bool = False

    def __post_init__(self):
        # Normalize so semantically identical plans share one signature()
        # (and thus one service cache entry): aggregate names are
        # case-insensitive, and ranking fields are dead without ORDER BY.
        if self.agg is not None:
            object.__setattr__(self, "agg", self.agg.upper())
        if self.order_by is None:
            object.__setattr__(self, "k", None)
            object.__setattr__(self, "desc", True)
        # Pair (dual-mask) plans evaluate per image and return image ids;
        # normalize the default select so programmatic plans behave like
        # parsed ones.
        if self.select == "mask_id" and self.paired:
            object.__setattr__(self, "select", "image_id")

    @property
    def kind(self) -> str:
        if self.agg is not None:
            return "scalar_agg"
        if self.order_by is not None:
            return "filtered_topk" if self.predicate is not None else "topk"
        return "filter"

    def exprs(self) -> list:
        """Every distinct value expression the plan evaluates."""
        out: list = []
        if self.predicate is not None:
            out.extend(self.predicate.value_exprs())
        for e in (self.order_by, self.agg_expr):
            if e is not None and e not in out:
                out.append(e)
        return out

    @property
    def paired(self) -> bool:
        """Whether this is a dual-mask (pair) plan: any expression contains
        a :class:`~repro.core.exprs.PairTerm`.  Pair plans evaluate per
        image over (role_a, role_b) mask pairs."""
        return any(is_pair_expr(e) for e in self.exprs())

    @property
    def grouped(self) -> bool:
        """Whether execution evaluates per image group rather than per mask.
        ``select="image_id"`` implies grouping (as in the SQL front-end),
        so programmatically built plans behave like parsed ones.  Pair
        plans are their own unit (per-image *role pairs*, not groups)."""
        if self.paired:
            return False
        return (self.group_by_image or self.select == "image_id" or
                any(is_group_expr(e) for e in self.exprs()))

    def validate(self) -> "LogicalPlan":
        kind = self.kind
        if kind == "filter" and self.predicate is None:
            raise ValueError("filter plan needs a predicate")
        if kind in ("topk", "filtered_topk"):
            if self.k is None:
                raise ValueError("ranking plan needs k (LIMIT)")
            if self.k < 1:
                raise ValueError(f"LIMIT must be a positive integer, "
                                 f"got {self.k}")
        if self.paired:
            pair_roles_of(self.exprs())   # raises on mixed role pairings
            mixed = [t for e in self.exprs() for t in e.cp_terms()
                     if not isinstance(t, PairTerm)]
            if mixed:
                # AREA(roi) stays legal (normalized discrepancies); any
                # other counted term is a unit mismatch.
                raise ValueError(
                    "a dual-mask (pair) plan cannot mix in per-mask CP or "
                    "MASK_AGG terms; every count must be a pair stat "
                    f"(offending: {mixed[0]!r})")
            if self.mask_types is not None or (
                    self.predicate is not None and
                    _has_type_leaf(self.predicate)):
                raise ValueError(
                    "pair plans select their masks by role (the two "
                    "mask_types named in the pair terms); drop the "
                    "mask_type IN (...) restriction")
            if self.select != "image_id":
                raise ValueError("pair plans evaluate per image; "
                                 "SELECT image_id")
        if any(is_group_expr(e) for e in self.exprs()):
            bad = [e for e in self.exprs() if _has_per_mask_leaf(e)]
            if bad:
                raise ValueError(
                    "a MASK_AGG (grouped) plan cannot mix in per-mask "
                    "CP/AREA terms; use CP(intersect|union(mask > t), ...) "
                    f"expressions throughout (offending: {bad[0]!r})")
        if kind == "scalar_agg":
            if self.agg_expr is None:
                raise ValueError("scalar_agg plan needs agg_expr")
            if self.agg.upper() not in ("SUM", "AVG", "MIN", "MAX"):
                raise ValueError(f"unknown aggregate {self.agg!r}")
        if self.select not in ("mask_id", "image_id"):
            raise ValueError(f"can only SELECT mask_id/image_id, "
                             f"got {self.select!r}")
        if self.grouped and self.predicate is not None and \
                _has_type_leaf(self.predicate):
            raise ValueError("mask_type IN below AND/OR/NOT cannot appear in "
                             "a grouped (MASK_AGG / GROUP BY) plan; use it as "
                             "a top-level conjunct instead")
        return self

    def signature(self) -> str:
        """Deterministic canonical form (frozen-dataclass reprs are stable
        and include every field) — the service's cache-key input."""
        return "|".join([
            self.kind, self.select, repr(self.predicate), repr(self.order_by),
            str(self.k), str(self.desc), str(self.agg), repr(self.agg_expr),
            str(None if self.mask_types is None
                else tuple(sorted(self.mask_types))),
            str(self.grouped),
        ])


def _has_per_mask_leaf(node: Node) -> bool:
    """True if the expression contains a leaf only evaluable per mask
    (a plain CP or an AREA term) — invalid inside a grouped plan."""
    if isinstance(node, (CP, RoiArea)):
        return True
    if isinstance(node, BinOp):
        return _has_per_mask_leaf(node.left) or _has_per_mask_leaf(node.right)
    return False


def _has_type_leaf(pred: Pred) -> bool:
    if isinstance(pred, TypeIn):
        return True
    if isinstance(pred, (And, Or)):
        return _has_type_leaf(pred.left) or _has_type_leaf(pred.right)
    if isinstance(pred, Not):
        return _has_type_leaf(pred.child)
    return False


def simplify_predicate(pred: Optional[Pred]):
    """Split source-level ``mask_type IN`` conjuncts out of a predicate tree.

    Returns ``(mask_types, residue)``: every :class:`TypeIn` reachable
    through top-level ``And`` nodes becomes a candidate-set restriction
    (intersected if repeated) — pruning the source *before* the bounds pass,
    exactly like the flat front-end did — and the remaining conjuncts are
    reassembled (left-associated, original order) as the residue predicate.
    ``TypeIn`` below ``Or``/``Not`` stays in the tree and is decided as an
    ordinary (never-unknown) leaf.
    """
    if pred is None:
        return None, None
    conjuncts: list = []

    def _flatten(p: Pred) -> None:
        if isinstance(p, And):
            _flatten(p.left)
            _flatten(p.right)
        else:
            conjuncts.append(p)

    _flatten(pred)
    mask_types: Optional[tuple] = None
    rest: list = []
    for c in conjuncts:
        if isinstance(c, TypeIn):
            if mask_types is None:
                mask_types = tuple(c.types)
            else:
                mask_types = tuple(t for t in mask_types if t in c.types)
        else:
            rest.append(c)
    residue: Optional[Pred] = None
    for c in rest:
        residue = c if residue is None else And(residue, c)
    return mask_types, residue


# ---------------------------------------------------------------------------
# Physical compilation
# ---------------------------------------------------------------------------


def compile_plan(store, plan: LogicalPlan, *, provided_rois=None,
                 verify_batch: int = 256, bounds_hook=None, positions=None,
                 bounds=None, backend=None):
    """Lower a logical plan to its resumable physical run.

    ``bounds_hook`` (``get(expr)``/``put(expr, lb, ub)``) lets the caller —
    the service planner — cache per-expression CHI bounds across runs.
    ``positions`` restricts the candidate set to explicit store rows;
    ``bounds`` is the legacy precomputed ``(lb, ub)`` pair for a
    single-expression filter/top-k plan.  ``backend`` selects the physical
    execution layer (``None``/``"host"``, ``"device"``, ``"mesh"``, or an
    :class:`repro.core.backend.ExecBackend` instance); every backend
    returns identical results.
    """
    plan.validate()
    common = dict(mask_types=plan.mask_types,
                  group_by_image=plan.grouped,
                  provided_rois=provided_rois, verify_batch=verify_batch,
                  bounds_hook=bounds_hook, positions=positions,
                  backend=backend)
    kind = plan.kind
    if bounds is not None and not (
            kind == "topk" or
            (kind == "filter" and isinstance(plan.predicate, Cmp))):
        raise ValueError(
            "bounds= applies only to single-expression filter/top-k plans; "
            "use bounds_hook to cache per-expression bounds for "
            f"{kind!r} plans")
    paired = plan.paired
    # Run construction is the plan/compile phase: context build + the full
    # CHI bounds pass (per-expression ``bounds`` spans nest inside).
    with _trace.span("plan.compile") as sp:
        run = _lower(store, plan, kind, paired, bounds, common)
        sp.set(kind=kind, candidates=run.n)
    return run


def _lower(store, plan, kind, paired, bounds, common):
    if kind == "filter":
        cls = engine.PairFilterRun if paired else engine.FilterRun
        return cls(store, plan.predicate, bounds=bounds, **common)
    if kind == "topk":
        cls = engine.PairTopKRun if paired else engine.TopKRun
        return cls(store, plan.order_by, desc=plan.desc, bounds=bounds,
                   **common)
    if kind == "filtered_topk":
        cls = engine.PairFilteredTopKRun if paired else engine.FilteredTopKRun
        return cls(store, plan.predicate, plan.order_by, desc=plan.desc,
                   **common)
    agg = plan.agg.upper()
    if agg in ("MIN", "MAX"):
        return engine.MinMaxAggRun(store, plan.agg_expr, agg, **common)
    return engine.ScalarAggRun(store, plan.agg_expr, agg, **common)


def run_plan(store, plan: LogicalPlan, *, provided_rois=None,
             use_index: bool = True, verify_batch: Optional[int] = None,
             bounds_hook=None, positions=None, bounds=None, backend=None):
    """One-shot execution of a logical plan → ``(payload, stats)``.

    Payload shapes match the legacy front-end exactly: ``filter`` → ids,
    ``topk``/``filtered_topk`` → ``(ids, scores)``, ``scalar_agg`` → float.
    ``use_index=False`` is the full-scan baseline for every plan kind (it
    always runs on the host — it exists to check the backends against).

    ``verify_batch`` defaults per kind: rankings (and MIN/MAX, which share
    their early-termination loop) verify in 256-candidate rounds; filters
    and SUM/AVG have no early exit, so a one-shot run verifies the whole
    residue in a single pass.  Resumable/service callers pick their own.

    ``backend`` selects the physical layer — ``run_plan(plan,
    backend="mesh")`` executes the same plan over the sharded step
    functions of :mod:`repro.core.distributed`.
    """
    plan.validate()
    kind = plan.kind
    if not use_index:
        return _run_scan(store, plan, provided_rois, positions)
    if verify_batch is None:
        ranked = kind in ("topk", "filtered_topk") or (
            kind == "scalar_agg" and plan.agg.upper() in ("MIN", "MAX"))
        verify_batch = 256 if ranked else max(len(store), 1)
    run = compile_plan(store, plan, provided_rois=provided_rois,
                       verify_batch=verify_batch, bounds_hook=bounds_hook,
                       positions=positions, bounds=bounds, backend=backend)
    run.ensure(plan.k)
    if kind in ("topk", "filtered_topk"):
        ids, scores = run.result()
        return (ids, scores), run.stats
    return run.result(), run.stats


def _run_scan(store, plan: LogicalPlan, provided_rois, positions=None):
    """The ``use_index=False`` baseline: exact evaluation of everything."""
    kind = plan.kind
    common = dict(mask_types=plan.mask_types,
                  group_by_image=plan.grouped,
                  provided_rois=provided_rois, use_index=False,
                  positions=positions)
    if kind == "filter":
        return engine.filter_query(store, plan.predicate, **common)
    if kind == "topk":
        ids, scores, stats = engine.topk_query(
            store, plan.order_by, plan.k, desc=plan.desc, **common)
        return (ids, scores), stats
    if kind == "filtered_topk":
        ids, scores, stats = engine.filtered_topk_query(
            store, plan.predicate, plan.order_by, plan.k, desc=plan.desc,
            **common)
        return (ids, scores), stats
    common.pop("group_by_image")
    return engine.scalar_agg(store, plan.agg_expr, plan.agg, **common)


__all__ = ["LogicalPlan", "compile_plan", "run_plan", "simplify_predicate"]
