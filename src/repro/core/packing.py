"""Bitpacked binary-mask representation (1 bit/pixel, uint32 words).

Binary mask types (segmentation outputs, thresholded detections) carry one
bit of information per pixel but the float tier moves them as float32 —
32× the bytes on a bandwidth-bound query class.  A *packed* store keeps
each mask row as ``ceil(W / 32)`` little-endian uint32 words: bit ``i`` of
word ``k`` is pixel column ``k * 32 + i``.  Tail bits past ``W`` in the
last word are always zero — an invariant established here at pack time and
relied on by every popcount kernel (kernels/popcount.py), which therefore
never needs the width: ROI column spans are clipped to ``W`` upstream
(``cp.normalize_rois``) and the stored words carry no garbage past it.

Packing is lossless only for binary inputs, so ingest validates values are
exactly {0.0, 1.0}; CP semantics on the packed tier reduce to an exact
integer decomposition (see kernels/popcount.py) that is bit-identical to
the float kernels on the same data.
"""

from __future__ import annotations

import numpy as np

__all__ = ["words_for", "packed_row_nbytes", "validate_binary",
           "pack_masks", "unpack_masks"]

WORD_BITS = 32


def words_for(width: int) -> int:
    """uint32 words per mask row of ``width`` pixel columns."""
    return (int(width) + WORD_BITS - 1) // WORD_BITS


def packed_row_nbytes(height: int, width: int) -> int:
    """Bytes of one packed mask: ``H × ceil(W/32)`` uint32 words."""
    return int(height) * words_for(width) * 4


def validate_binary(masks: np.ndarray) -> None:
    """Raise ValueError unless every value is exactly 0.0 or 1.0."""
    arr = np.asarray(masks)
    if arr.size and not np.logical_or(arr == 0, arr == 1).all():
        bad = arr[np.logical_and(arr != 0, arr != 1)].flat[0]
        raise ValueError(
            f"packed stores hold binary masks only: found value {bad!r} "
            f"outside {{0, 1}} — threshold the masks before ingest")


def pack_masks(masks: np.ndarray) -> np.ndarray:
    """``(..., W)`` binary → ``(..., words)`` uint32, LSB-first.

    Nonzero pixels become set bits; tail bits beyond ``W`` in the last
    word are zero.  Works on any leading shape (whole batches, row spans).
    """
    arr = np.asarray(masks)
    w = arr.shape[-1]
    words = words_for(w)
    bits = arr != 0
    pad = words * WORD_BITS - w
    if pad:
        bits = np.concatenate(
            [bits, np.zeros(bits.shape[:-1] + (pad,), bool)], axis=-1)
    packed = np.packbits(bits, axis=-1, bitorder="little")
    packed = np.ascontiguousarray(packed).view("<u4")
    return packed.astype(np.uint32, copy=False)


def unpack_masks(packed: np.ndarray, width: int,
                 dtype=np.float32) -> np.ndarray:
    """``(..., words)`` uint32 → ``(..., width)`` of ``dtype`` in {0, 1}."""
    arr = np.ascontiguousarray(np.asarray(packed), dtype="<u4")
    bits = np.unpackbits(arr.view(np.uint8), axis=-1, bitorder="little")
    return bits[..., :int(width)].astype(dtype)
