"""The filter–verification execution framework (paper §2).

Every query runs in two phases:

1. **Filter** — CHI-derived bounds are computed for every candidate (no mask
   bytes touched).  Candidates whose bounds already decide the predicate are
   accepted/pruned outright; bound-coincident candidates (``lb == ub``) have
   *known exact scores* for free.
2. **Verification** — only the undecided residue is loaded from the mask
   tier and evaluated exactly.  For Top-K, verification proceeds in rounds of
   ``verify_batch`` ordered by most-promising bound, and stops as soon as the
   running k-th-best exact score dominates every unverified candidate's bound
   (the paper's incremental-threshold pruning, recast as fixed-size device
   batches — see DESIGN.md §3 on why batches instead of a per-mask heap).

All functions return :class:`ExecStats` telling exactly how much I/O the
index avoided — the quantity behind the paper's 100× claim.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from .exprs import (GroupEvalContext, MaskEvalContext, Node, is_group_expr)


@dataclasses.dataclass
class ExecStats:
    n_candidates: int = 0
    n_decided_by_bounds: int = 0      # accepted or pruned without loading
    n_verified: int = 0               # masks actually loaded + scanned
    n_rounds: int = 0                 # top-k verification rounds
    bytes_loaded: int = 0
    bound_time_s: float = 0.0
    verify_time_s: float = 0.0

    @property
    def load_fraction(self) -> float:
        return self.n_verified / max(self.n_candidates, 1)


_OPS = {
    "<":  (lambda ub, t: ub < t,  lambda lb, t: lb >= t),
    "<=": (lambda ub, t: ub <= t, lambda lb, t: lb > t),
    ">":  (lambda lb, t: lb > t,  lambda ub, t: ub <= t),
    ">=": (lambda lb, t: lb >= t, lambda ub, t: ub < t),
}


def _accept_reject(op: str, lb, ub, threshold: float):
    """Sound bound decisions: accept iff the predicate must hold, reject iff
    it cannot hold, for exact ∈ [lb, ub]."""
    if op in ("<", "<="):
        acc_fn, rej_fn = _OPS[op]
        return acc_fn(ub, threshold), rej_fn(lb, threshold)
    acc_fn, rej_fn = _OPS[op]
    return acc_fn(lb, threshold), rej_fn(ub, threshold)


def _make_context(store, expr: Node, positions, group_by_image: bool,
                  mask_types, provided_rois, partial_rows: bool = True):
    """Build the evaluation context + the id array that results refer to."""
    if is_group_expr(expr) or group_by_image:
        sel = (store.select(mask_type=mask_types) if mask_types is not None
               else np.arange(len(store)))
        if positions is not None:
            sel = np.intersect1d(sel, positions)
        img = store.meta["image_id"][sel]
        order = np.argsort(img, kind="stable")
        sel, img = sel[order], img[order]
        uniq, starts, counts = np.unique(img, return_index=True,
                                         return_counts=True)
        size = counts.min()
        if counts.max() != size:
            # ragged groups: keep the first `size` per image (deterministic)
            keep = np.concatenate(
                [sel[s:s + size] for s in starts])
            groups = keep.reshape(-1, size)
        else:
            groups = sel.reshape(-1, size)
        ctx = GroupEvalContext(store, groups, uniq, provided_rois)
        return ctx, uniq
    if positions is None:
        positions = (store.select(mask_type=mask_types)
                     if mask_types is not None else np.arange(len(store)))
    ctx = MaskEvalContext(store, positions, provided_rois,
                          partial_rows=partial_rows)
    return ctx, store.meta["mask_id"][positions]


def _exact_for(ctx, expr, idx):
    if isinstance(ctx, GroupEvalContext):
        return ctx.exact(expr, idx)
    return ctx.exact(expr, idx)


# ---------------------------------------------------------------------------
# Filter query
# ---------------------------------------------------------------------------


class _VerifyRun:
    """Shared machinery of resumable verification runs (DESIGN.md §3).

    Construction runs the bounds pass (or reuses a cached ``bounds=(lb,
    ub)`` pair from the service planner).  Subclasses fill ``pending``
    (candidate indices in verification-priority order) and implement
    :meth:`finished` and :meth:`_apply`.  Verification is then driven
    either self-contained (:meth:`_drain`) or externally by the service
    scheduler, which pairs :meth:`take_batch` with :meth:`apply_exact`
    to fuse batches from many concurrent runs into one kernel pass.
    """

    def __init__(self, store, expr: Node, *,
                 positions: Optional[np.ndarray] = None, mask_types=None,
                 group_by_image: bool = False,
                 provided_rois: Optional[np.ndarray] = None,
                 verify_batch: int = 256, bounds=None):
        self.store = store
        self.expr = expr
        self.verify_batch = max(int(verify_batch), 1)
        self.ctx, self.ids = _make_context(store, expr, positions,
                                           group_by_image, mask_types,
                                           provided_rois)
        self.stats = ExecStats(n_candidates=len(self.ids))
        t0 = time.perf_counter()
        if bounds is None:
            lb, ub = self.ctx.bounds(expr)
        else:
            lb, ub = bounds
        self.stats.bound_time_s = time.perf_counter() - t0
        self.lb = np.asarray(lb, np.float64)
        self.ub = np.asarray(ub, np.float64)
        self.pending = np.empty(0, dtype=np.int64)
        self.cursor = 0

    @property
    def n(self) -> int:
        return len(self.ids)

    def finished(self) -> bool:
        raise NotImplementedError

    def _apply(self, batch: np.ndarray, values: np.ndarray) -> None:
        raise NotImplementedError

    def take_batch(self) -> np.ndarray:
        """Pop the next pending chunk; caller must ``apply_exact`` it."""
        batch = self.pending[self.cursor:self.cursor + self.verify_batch]
        self.cursor += len(batch)
        return batch

    def apply_exact(self, batch: np.ndarray, values: np.ndarray) -> None:
        self._apply(batch, values)
        self.stats.n_verified += len(batch)
        self.stats.n_rounds += 1

    def self_verify(self, batch: np.ndarray) -> None:
        io0 = self.store.io.bytes_read
        t0 = time.perf_counter()
        self.apply_exact(batch, _exact_for(self.ctx, self.expr, batch))
        self.stats.verify_time_s += time.perf_counter() - t0
        self.stats.bytes_loaded += self.store.io.bytes_read - io0

    def _drain(self) -> None:
        while not self.finished():
            batch = self.take_batch()
            if not len(batch):
                break
            self.self_verify(batch)


class FilterRun(_VerifyRun):
    """Resumable verification state for a filter query: the undecided
    residue is verified in chunks until exhausted."""

    def __init__(self, store, expr: Node, op: str, threshold: float, *,
                 positions: Optional[np.ndarray] = None, mask_types=None,
                 group_by_image: bool = False,
                 provided_rois: Optional[np.ndarray] = None,
                 verify_batch: int = 256, bounds=None):
        if op not in _OPS:
            raise ValueError(f"bad comparison {op!r}")
        self.op = op
        self.threshold = threshold
        super().__init__(store, expr, positions=positions,
                         mask_types=mask_types, group_by_image=group_by_image,
                         provided_rois=provided_rois,
                         verify_batch=verify_batch, bounds=bounds)
        accept, reject = _accept_reject(op, self.lb, self.ub, threshold)
        self.accept = np.asarray(accept).copy()
        self.pending = np.nonzero(~(accept | reject))[0]
        self.stats.n_decided_by_bounds = self.n - len(self.pending)

    def finished(self) -> bool:
        return self.cursor >= len(self.pending)

    def _apply(self, batch: np.ndarray, values: np.ndarray) -> None:
        self.accept[batch] = _cmp(self.op, values, self.threshold)

    def ensure(self) -> None:
        self._drain()

    def result(self) -> np.ndarray:
        return self.ids[self.accept]


def filter_query(store, expr: Node, op: str, threshold: float, *,
                 positions: Optional[np.ndarray] = None,
                 mask_types=None, group_by_image: bool = False,
                 provided_rois: Optional[np.ndarray] = None,
                 use_index: bool = True, bounds=None):
    """``SELECT {mask_id|image_id} WHERE expr op threshold``.

    Returns ``(ids, stats)``.  ``use_index=False`` is the full-scan baseline
    (the paper's "without MaskSearch").  ``bounds`` optionally supplies a
    precomputed ``(lb, ub)`` pair (the service's bounds cache).
    """
    if not use_index:
        ctx, ids = _make_context(store, expr, positions, group_by_image,
                                 mask_types, provided_rois,
                                 partial_rows=False)
        n = len(ids)
        stats = ExecStats(n_candidates=n)
        io_before = store.io.bytes_read
        t0 = time.perf_counter()
        exact = _exact_for(ctx, expr, np.arange(n))
        keep = _cmp(op, exact, threshold)
        stats.n_verified = n
        stats.verify_time_s = time.perf_counter() - t0
        stats.bytes_loaded = store.io.bytes_read - io_before
        return ids[keep], stats

    run = FilterRun(store, expr, op, threshold, positions=positions,
                    mask_types=mask_types, group_by_image=group_by_image,
                    provided_rois=provided_rois,
                    verify_batch=max(len(store), 1), bounds=bounds)
    run.ensure()
    return run.result(), run.stats


def _cmp(op, values, threshold):
    import operator
    return {"<": operator.lt, "<=": operator.le,
            ">": operator.gt, ">=": operator.ge}[op](values, threshold)


# ---------------------------------------------------------------------------
# Top-K query
# ---------------------------------------------------------------------------


class TopKRun(_VerifyRun):
    """Resumable top-k verification state (the batched loop of §3, DESIGN.md).

    Construction runs the bounds pass only; verification is then driven
    either by :meth:`ensure` (the one-shot ``topk_query`` path) or
    externally, one :meth:`take_batch`/:meth:`apply_exact` round at a time
    (the service's sessions and fused scheduler).  The finality target ``k``
    can *grow* between rounds — :meth:`target` re-derives the static pruning
    frontier from the cached bounds, so a GUI's "next 25" costs only the
    extra verification batches, never a fresh bounds pass.
    """

    def __init__(self, store, expr: Node, *, desc: bool = True,
                 positions: Optional[np.ndarray] = None, mask_types=None,
                 group_by_image: bool = False,
                 provided_rois: Optional[np.ndarray] = None,
                 verify_batch: int = 256, bounds=None):
        self.desc = desc
        super().__init__(store, expr, positions=positions,
                         mask_types=mask_types, group_by_image=group_by_image,
                         provided_rois=provided_rois,
                         verify_batch=verify_batch, bounds=bounds)
        # Scores: exact where bounds coincide, else pending verification.
        self.scores = np.where(self.lb == self.ub, self.lb, np.nan)
        self.known = ~np.isnan(self.scores)
        self._known0 = self.known.copy()
        self.k = 0
        self.alive = np.zeros(self.n, dtype=bool)

    def target(self, k: int) -> int:
        """Set/raise the finality target to ``k`` (clamped to n) and
        re-derive the static pruning frontier.  Idempotent for equal k."""
        k = min(int(k), self.n)
        if k == self.k:
            return k
        self.k = k
        n = self.n
        if n == 0 or k <= 0:
            self.alive = np.zeros(n, dtype=bool)
            self.pending = np.empty(0, dtype=np.int64)
            self.cursor = 0
            return k
        # Static pruning: a candidate can make top-k only if its optimistic
        # bound beats the k-th best pessimistic bound.
        if self.desc:
            tau = np.partition(self.lb, -k)[-k]
            self.alive = self.ub >= tau
        else:
            tau = np.partition(self.ub, k - 1)[k - 1]
            self.alive = self.lb <= tau
        self.stats.n_decided_by_bounds = int(
            n - np.count_nonzero(self.alive & ~self._known0))
        pending = np.nonzero(self.alive & ~self.known)[0]
        # verify most-promising first
        key = self.ub[pending] if self.desc else self.lb[pending]
        self.pending = pending[np.argsort(-key if self.desc else key,
                                          kind="stable")]
        self.cursor = 0
        return k

    def finished(self) -> bool:
        """True iff the current top-``k`` can no longer change."""
        have = np.nonzero(self.known & self.alive)[0]
        if len(have) >= self.k > 0:
            vals = self.scores[have]
            kth = (np.partition(vals, -self.k)[-self.k] if self.desc
                   else np.partition(vals, self.k - 1)[self.k - 1])
            rest = self.pending[self.cursor:]
            if len(rest) == 0:
                return True
            best_possible = (self.ub[rest].max() if self.desc
                             else self.lb[rest].min())
            # strict domination → no unverified candidate can displace top-k
            return ((self.desc and best_possible < kth) or
                    (not self.desc and best_possible > kth))
        return self.cursor >= len(self.pending)

    def _apply(self, batch: np.ndarray, values: np.ndarray) -> None:
        self.scores[batch] = values
        self.known[batch] = True

    def ensure(self, k: Optional[int] = None) -> None:
        """Drive verification until the top-``k`` is final."""
        if k is not None:
            self.target(k)
        self._drain()

    def result(self, k: Optional[int] = None):
        """(ids, scores) of the current top-``k`` — call after :meth:`ensure`
        (or after the scheduler reports :meth:`finished`).  Ties break by
        candidate order, so paginated and one-shot runs agree exactly."""
        k = self.k if k is None else min(int(k), self.n)
        final = np.nonzero(self.known)[0]
        if len(final) == 0 or k <= 0:
            return self.ids[:0], self.scores[:0]
        vals = self.scores[final]
        order = final[_topk_order(vals, min(k, len(final)), self.desc)]
        return self.ids[order], self.scores[order]


def topk_query(store, expr: Node, k: int, *, desc: bool = True,
               positions: Optional[np.ndarray] = None,
               mask_types=None, group_by_image: bool = False,
               provided_rois: Optional[np.ndarray] = None,
               use_index: bool = True, verify_batch: int = 256,
               bounds=None):
    """``SELECT ... ORDER BY expr {DESC|ASC} LIMIT k`` → (ids, scores, stats)."""
    if not use_index:
        ctx, ids = _make_context(store, expr, positions, group_by_image,
                                 mask_types, provided_rois)
        n = len(ids)
        k = min(k, n)
        stats = ExecStats(n_candidates=n)
        io_before = store.io.bytes_read
        t0 = time.perf_counter()
        exact = _exact_for(ctx, expr, np.arange(n))
        order = _topk_order(exact, k, desc)
        stats.n_verified = n
        stats.verify_time_s = time.perf_counter() - t0
        stats.bytes_loaded = store.io.bytes_read - io_before
        return ids[order], exact[order], stats

    run = TopKRun(store, expr, desc=desc, positions=positions,
                  mask_types=mask_types, group_by_image=group_by_image,
                  provided_rois=provided_rois, verify_batch=verify_batch,
                  bounds=bounds)
    run.ensure(k)
    ids, scores = run.result()
    return ids, scores, run.stats


def _topk_order(values, k, desc):
    """Indices of the top-k, fully deterministic: ties break by ascending
    candidate position.  CP scores are integer pixel counts, so boundary
    ties are the norm — argpartition's arbitrary pick among equals would
    let a paginated run (whose known-set grows between pages) select a
    different tied candidate than a one-shot run."""
    v = -values if desc else values
    order = np.lexsort((np.arange(len(v)), v))  # primary v, then index
    return order[:k]


# ---------------------------------------------------------------------------
# Scalar aggregation
# ---------------------------------------------------------------------------


def scalar_agg(store, expr: Node, agg: str, *,
               positions: Optional[np.ndarray] = None, mask_types=None,
               provided_rois: Optional[np.ndarray] = None,
               use_index: bool = True):
    """``SELECT SCALAR_AGG(expr)`` with agg ∈ {SUM, AVG, MIN, MAX}.

    MIN/MAX reuse the top-k pruning machinery (k=1).  SUM/AVG verify only
    bound-undecided masks.  Returns ``(value, stats)``.
    """
    agg = agg.upper()
    if agg in ("MIN", "MAX"):
        ids, scores, stats = topk_query(
            store, expr, 1, desc=(agg == "MAX"), positions=positions,
            mask_types=mask_types, provided_rois=provided_rois,
            use_index=use_index)
        return float(scores[0]), stats

    ctx, ids = _make_context(store, expr, positions, False, mask_types,
                             provided_rois, partial_rows=use_index)
    n = len(ids)
    stats = ExecStats(n_candidates=n)
    io_before = store.io.bytes_read
    if not use_index:
        exact = _exact_for(ctx, expr, np.arange(n))
        stats.n_verified = n
    else:
        t0 = time.perf_counter()
        lb, ub = ctx.bounds(expr)
        stats.bound_time_s = time.perf_counter() - t0
        exact = lb.astype(np.float64)
        undecided = np.nonzero(lb != ub)[0]
        stats.n_decided_by_bounds = n - len(undecided)
        if len(undecided):
            t0 = time.perf_counter()
            exact[undecided] = _exact_for(ctx, expr, undecided)
            stats.verify_time_s = time.perf_counter() - t0
        stats.n_verified = len(undecided)
    stats.bytes_loaded = store.io.bytes_read - io_before
    value = float(exact.sum()) if agg == "SUM" else float(exact.mean())
    return value, stats
