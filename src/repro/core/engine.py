"""The filter–verification execution framework (paper §2).

Every query runs in two phases:

1. **Filter** — CHI-derived bounds are computed for every candidate (no mask
   bytes touched).  Candidates whose bounds already decide the predicate are
   accepted/pruned outright; bound-coincident candidates (``lb == ub``) have
   *known exact scores* for free.  Boolean predicate trees prune through
   three-valued logic (:meth:`repro.core.exprs.Pred.decide`): a conjunction
   rejects as soon as one conjunct must fail, a disjunction accepts as soon
   as one disjunct must hold.
2. **Verification** — only the undecided residue is loaded from the mask
   tier and evaluated exactly.  For Top-K, verification proceeds in rounds of
   ``verify_batch`` ordered by most-promising bound, and stops as soon as the
   running k-th-best exact score dominates every unverified candidate's bound
   (the paper's incremental-threshold pruning, recast as fixed-size device
   batches — see DESIGN.md §3 on why batches instead of a per-mask heap).

Physical execution is uniform: every run object — :class:`FilterRun`,
:class:`TopKRun`, :class:`FilteredTopKRun`, :class:`ScalarAggRun`,
:class:`MinMaxAggRun`, and the dual-mask :class:`PairFilterRun` /
:class:`PairTopKRun` / :class:`PairFilteredTopKRun` (DESIGN.md §9) —
presents ``target / take_batch / apply_exact /
finished / result`` (DESIGN.md §6), so sessions resume any of them and the
service scheduler fuses their verification batches without knowing which
operator it is driving.  The runs themselves are backend-agnostic drivers:
every physical operation (bounds, exact counts, the ranking frontier,
MASK_AGG counts) goes through an :class:`repro.core.backend.ExecBackend`
— host NumPy, single-device resident HBM, or the ``shard_map`` mesh —
selected per run (DESIGN.md §7).

All runs expose :class:`ExecStats` telling exactly how much I/O the index
avoided — the quantity behind the paper's 100× claim.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from ..obs import trace as _trace
from . import opt as opt_lib
from .backend import get_backend
from .exprs import (Cmp, CP, GroupEvalContext, MaskEvalContext, Node,
                    PairEvalContext, PairTerm, Pred, eval_with_counts,
                    is_group_expr, pair_roles_of, tier_context)
from .store import StaleRunError


@dataclasses.dataclass
class ExecStats:
    n_candidates: int = 0
    n_decided_by_bounds: int = 0      # accepted or pruned without loading
    n_verified: int = 0               # masks actually loaded + scanned
    n_rounds: int = 0                 # top-k verification rounds
    n_dropped_masks: int = 0          # ragged-group members excluded from
                                      # GROUP BY (see _make_context)
    bytes_loaded: int = 0             # store bytes metered for this run
    bytes_saved: int = 0              # served from the shared-load cache
    chi_bytes: int = 0                # index bytes the bounds passes touched
    bound_time_s: float = 0.0
    verify_time_s: float = 0.0

    @property
    def load_fraction(self) -> float:
        return self.n_verified / max(self.n_candidates, 1)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["load_fraction"] = self.load_fraction
        return d

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, f.default)


def _chi_row_nbytes(ctx, tier: Optional[int] = None) -> int:
    """Bytes of CHI table one candidate's bounds pass touches (pair
    candidates touch both roles' rows); at pyramid tier ``tier`` the row is
    the (g+1)²·(NB+1) strided subsample.  Best-effort: 0 when the store
    doesn't expose its chunked CHI layout."""
    chunks = getattr(ctx.store, "chi_chunks", None)
    if not chunks:
        return 0
    row = chunks[0]
    if tier is None:
        per = int(np.prod(row.shape[1:])) * row.dtype.itemsize
    else:
        per = (tier + 1) * (tier + 1) * row.shape[-1] * row.dtype.itemsize
    return per * (2 if isinstance(ctx, PairEvalContext) else 1)


def _make_context(store, exprs, group_by_image: bool, positions, mask_types,
                  provided_rois, partial_rows: bool = True, backend=None):
    """Build the evaluation context + the id array that results refer to.

    The unit of evaluation comes from the expressions: pair terms →
    :class:`PairEvalContext` over per-image (role_a, role_b) mask rows;
    MASK_AGG terms (or explicit grouping) → :class:`GroupEvalContext`;
    otherwise :class:`MaskEvalContext` per mask.

    Returns ``(ctx, ids, n_dropped)`` — ``n_dropped`` counts masks excluded
    from ragged image groups (grouped evaluation needs one rectangular
    ``(n_groups, size)`` block, so images with more masks than the smallest
    group keep only their first ``size``; the caller surfaces the count in
    ``ExecStats.n_dropped_masks`` instead of losing it silently).  For pair
    contexts it counts role-A/role-B masks excluded from evaluation —
    duplicates beyond the first per (image, role) plus masks whose image
    lacks the partner role.
    """
    exprs = tuple(exprs)
    roles = pair_roles_of(exprs)
    if roles is not None:
        # Engine-level callers bypass LogicalPlan.validate — enforce the
        # same invariants here so they get clear errors, not silently
        # dropped restrictions or a TypeError deep in bounds().
        mixed = [t for e in exprs for t in e.cp_terms()
                 if not isinstance(t, PairTerm)]
        if mixed:
            raise ValueError(
                "a dual-mask (pair) query cannot mix in per-mask CP or "
                f"MASK_AGG terms (offending: {mixed[0]!r})")
        if mask_types is not None:
            raise ValueError(
                "pair queries select their masks by role (the two "
                "mask_types named in the pair terms); drop mask_types")
        return _make_pair_context(store, roles, positions, provided_rois,
                                  backend)
    grouped = _grouped_for(exprs, group_by_image)
    if grouped:
        sel = (store.select(mask_type=mask_types) if mask_types is not None
               else np.arange(len(store)))
        if positions is not None:
            sel = np.intersect1d(sel, positions)
        img = store.meta["image_id"][sel]
        order = np.argsort(img, kind="stable")
        sel, img = sel[order], img[order]
        uniq, starts, counts = np.unique(img, return_index=True,
                                         return_counts=True)
        n_dropped = 0
        if len(counts):
            size = counts.min()
            if counts.max() != size:
                # ragged groups: keep the first `size` per image
                # (deterministic); the rest are *dropped from evaluation*
                # and accounted in ExecStats.n_dropped_masks.
                n_dropped = int(counts.sum() - size * len(counts))
                keep = np.concatenate(
                    [sel[s:s + size] for s in starts])
                groups = keep.reshape(-1, size)
            else:
                groups = sel.reshape(-1, size)
        else:
            groups = sel.reshape(0, 1)
        ctx = GroupEvalContext(store, groups, uniq, provided_rois)
        ctx.backend = backend
        return ctx, uniq, n_dropped
    if positions is None:
        positions = (store.select(mask_type=mask_types)
                     if mask_types is not None else np.arange(len(store)))
    ctx = MaskEvalContext(store, positions, provided_rois,
                          partial_rows=partial_rows)
    ctx.backend = backend
    return ctx, store.meta["mask_id"][positions], 0


def _make_pair_context(store, roles, positions, provided_rois, backend):
    """Per-image pairing: for each image present in **both** roles, pair
    its first role-A mask with its first role-B mask (ascending store
    position — deterministic across runs and backends)."""
    sel_a = store.select(mask_type=roles[0])
    sel_b = store.select(mask_type=roles[1])
    if positions is not None:
        positions = np.asarray(positions)
        sel_a = np.intersect1d(sel_a, positions)
        sel_b = np.intersect1d(sel_b, positions)
    uniq_a, first_a = np.unique(store.meta["image_id"][sel_a],
                                return_index=True)
    uniq_b, first_b = np.unique(store.meta["image_id"][sel_b],
                                return_index=True)
    common, ia, ib = np.intersect1d(uniq_a, uniq_b, return_indices=True)
    pos_a = sel_a[first_a[ia]]
    pos_b = sel_b[first_b[ib]]
    n_dropped = int(len(sel_a) + len(sel_b) - 2 * len(common))
    ctx = PairEvalContext(store, pos_a, pos_b, common, roles, provided_rois)
    ctx.backend = backend
    return ctx, common, n_dropped


def _grouped_for(exprs, group_by_image: bool) -> bool:
    return group_by_image or any(is_group_expr(e) for e in exprs)


# ---------------------------------------------------------------------------
# The uniform resumable run
# ---------------------------------------------------------------------------


class _VerifyRun:
    """Shared machinery of resumable verification runs (DESIGN.md §3/§6).

    Construction runs the bounds pass — per distinct value expression,
    through an optional ``bounds_hook`` (``get(expr) -> (lb, ub) | None``,
    ``put(expr, lb, ub)``) such as the service planner's bounds cache.
    Subclasses fill ``pending`` (candidate indices in verification-priority
    order) and implement :meth:`finished`, :meth:`_apply` and
    :meth:`result`.  Verification is then driven either self-contained
    (:meth:`ensure`) or externally by the service scheduler, which pairs
    :meth:`take_batch` with :meth:`apply_exact` to fuse batches from many
    concurrent runs into one kernel pass; :meth:`cp_terms` and
    :meth:`fused_values` are the fusion contract.
    """

    def __init__(self, store, exprs, *,
                 positions: Optional[np.ndarray] = None, mask_types=None,
                 group_by_image: bool = False,
                 provided_rois: Optional[np.ndarray] = None,
                 verify_batch: int = 256, bounds_hook=None, backend=None):
        self.store = store
        self.exprs = tuple(exprs)
        self.verify_batch = max(int(verify_batch), 1)
        self.backend = get_backend(store, backend)
        # Snapshot consistency (DESIGN.md §8): the run pins the epoch it was
        # planned at and evaluates against an epoch-pinned store view, so a
        # mutation mid-run either lets the run finish on retained data
        # (memory tiers; untouched disk ids) or raises a clean
        # StaleRunError — never a silent mix of old and new bytes.
        self.epoch = getattr(store, "epoch", 0)
        snap = store.snapshot() if hasattr(store, "snapshot") else store
        self.ctx, self.ids, n_dropped = _make_context(
            snap, self.exprs, group_by_image, positions, mask_types,
            provided_rois, backend=self.backend)
        if (isinstance(self.ctx, MaskEvalContext) and
                len({t for e in self.exprs for t in e.cp_terms()}) > 1):
            # ROI-row partial loads only pay off for a single distinct CP
            # term; a multi-term run shares one full-mask load instead.
            self.ctx.partial_rows = False
        self.stats = ExecStats(n_candidates=len(self.ids),
                               n_dropped_masks=n_dropped)
        self._bounds_hook = bounds_hook
        self._bounds_memo: dict = {}
        # Filled by _decide_pred when the cost-based optimizer ran: conjunct
        # order, per-conjunct tier ladders, estimated vs. actual selectivity
        # (surfaced by EXPLAIN ANALYZE).
        self.opt_report: Optional[dict] = None
        self.pending = np.empty(0, dtype=np.int64)
        self.cursor = 0

    @property
    def n(self) -> int:
        return len(self.ids)

    # -- bounds ------------------------------------------------------------
    def expr_bounds(self, expr: Node):
        """(lb, ub) float64 arrays for ``expr`` over all candidates, memoized
        per run and (optionally) cached across runs by the bounds hook."""
        if expr in self._bounds_memo:
            return self._bounds_memo[expr]
        t0 = time.perf_counter()
        finest = self.ctx.cfg.grid
        with _trace.span("bounds") as sp:
            cached = (self._bounds_hook.get(expr, tier=finest)
                      if self._bounds_hook else None)
            if cached is not None:
                lb, ub = cached
            else:
                lb, ub = self.backend.bounds(self.ctx, expr)
                lb = np.asarray(lb, np.float64)
                ub = np.asarray(ub, np.float64)
                if self._bounds_hook is not None:
                    self._bounds_hook.put(expr, lb, ub, tier=finest)
            nbytes = (0 if cached is not None
                      else self.n * _chi_row_nbytes(self.ctx))
            sp.set(expr=repr(expr), candidates=self.n,
                   cached=cached is not None, chi_bytes=nbytes)
        self.stats.chi_bytes += nbytes
        self.stats.bound_time_s += time.perf_counter() - t0
        self._bounds_memo[expr] = (lb, ub)
        return lb, ub

    # -- the uniform drive interface --------------------------------------
    def target(self, k: Optional[int] = None) -> Optional[int]:
        """Set/raise the finality target (top-k runs); no-op elsewhere, so
        callers can drive any run kind uniformly."""
        return k

    def finished(self) -> bool:
        raise NotImplementedError

    def result(self):
        raise NotImplementedError

    def cp_terms(self) -> list:
        """All CP terms this run's verification evaluates (fusion input)."""
        return [t for e in self.exprs for t in e.cp_terms()]

    def exact_values(self, batch: np.ndarray):
        """Self-contained exact evaluation of one batch (loads mask bytes)."""
        raise NotImplementedError

    def _self_counts(self, batch: np.ndarray):
        """Per-term exact counts for ``batch``, evaluated **once per
        distinct term** by the run's backend (a predicate and a ranking
        sharing an expression share its loads/kernel rows even in
        self-verification), or None when the run isn't a pure per-mask CP
        or pure pair-term evaluation."""
        terms = set(self.cp_terms())
        if isinstance(self.ctx, PairEvalContext):
            if terms and all(isinstance(t, PairTerm) for t in terms):
                return self.backend.pair_verify_counts(self.ctx, batch, terms)
            return None
        if not isinstance(self.ctx, MaskEvalContext):
            return None
        if terms and all(isinstance(t, CP) for t in terms):
            if getattr(self.store, "packed", False):
                # Packed tier: the bounds+verify megakernel answers every
                # term of the batch in ONE launch, passing CHI-decided
                # entries through from the run's memoized bounds (a term
                # whose expression-level bounds were never memoized is just
                # treated as undecided — no extra bounds pass).
                return self.backend.fused_verify_counts(
                    self.ctx, batch, terms, self._bounds_memo.get)
            return self.backend.verify_counts(self.ctx, batch, terms)
        return None

    def fused_values(self, batch: np.ndarray, counts: dict):
        """Exact evaluation when every CP term's count was precomputed by a
        fused multi-query kernel pass (``counts``: CP node → array aligned
        with ``batch``)."""
        raise NotImplementedError

    def _apply(self, batch: np.ndarray, values) -> None:
        raise NotImplementedError

    def fresh(self) -> bool:
        """Whether the store is still at the epoch this run was planned at."""
        return self.epoch == getattr(self.store, "epoch", 0)

    def resumable(self) -> bool:
        """Whether the run can still be driven to completion: fresh, already
        finished (no store access needed — results are run-local), or its
        epoch-pinned snapshot can serve every remaining verification load
        (host backend only — device/mesh residency tracks the live epoch)."""
        if self.fresh():
            return True
        rest = self.pending[self.cursor:]
        if not len(rest) or self.finished():
            return True
        if self.backend.name != "host":
            return False
        snap = self.ctx.store
        if not hasattr(snap, "can_serve"):
            return True
        if isinstance(self.ctx, MaskEvalContext):
            positions = self.ctx.positions[rest]
        elif isinstance(self.ctx, PairEvalContext):
            positions = np.concatenate([self.ctx.pos_a[rest],
                                        self.ctx.pos_b[rest]])
        else:
            positions = self.ctx.groups[rest].reshape(-1)
        return snap.can_serve(positions)

    def take_batch(self) -> np.ndarray:
        """Peek the next pending chunk; caller must ``apply_exact`` it —
        the cursor advances only when the batch's exact values are applied,
        so a verification failure (e.g. a :class:`StaleRunError` from the
        snapshot load) leaves the batch pending instead of silently
        dropping its candidates from the result.

        A stale run (the store mutated since planning) can only resume on
        the host backend, whose loads go through the run's epoch-pinned
        snapshot; device/mesh residency has been refreshed past the pinned
        epoch, so resuming there would silently mix old bounds with new
        bytes — raise instead."""
        if (self.cursor < len(self.pending) and not self.fresh()
                and self.backend.name != "host"):
            raise StaleRunError(
                f"run pinned at epoch {self.epoch} cannot resume on "
                f"backend {self.backend.name!r}: store moved to epoch "
                f"{self.store.epoch} and its resident masks were refreshed")
        return self.pending[self.cursor:self.cursor + self.verify_batch]

    def apply_exact(self, batch: np.ndarray, values) -> None:
        self._apply(batch, values)
        self.cursor += len(batch)
        self.stats.n_verified += len(batch)
        self.stats.n_rounds += 1

    def self_verify(self, batch: np.ndarray) -> None:
        cache = self.store.cache_stats
        io0 = self.store.io.bytes_read
        saved0, hits0 = cache.bytes_saved, cache.hits
        t0 = time.perf_counter()
        with _trace.span("verify.round") as sp:
            self.apply_exact(batch, self.exact_values(batch))
            sp.set(batch=len(batch),
                   bytes_loaded=self.store.io.bytes_read - io0,
                   bytes_saved=cache.bytes_saved - saved0,
                   cache_hits=cache.hits - hits0)
        self.stats.verify_time_s += time.perf_counter() - t0
        self.stats.bytes_loaded += self.store.io.bytes_read - io0
        self.stats.bytes_saved += cache.bytes_saved - saved0

    def _drain(self) -> None:
        while not self.finished():
            batch = self.take_batch()
            if not len(batch):
                break
            self.self_verify(batch)

    def ensure(self, k: Optional[int] = None) -> None:
        """Drive verification to completion (optionally raising the target)."""
        if k is not None:
            self.target(k)
        self._drain()


def _as_pred(expr_or_pred, op, threshold) -> Pred:
    if isinstance(expr_or_pred, Pred):
        if op is not None or threshold is not None:
            raise ValueError("op/threshold are implied by a predicate tree")
        return expr_or_pred
    return Cmp(expr_or_pred, op, threshold)


def _ladder_bounds_of(run, sub, g: int, finest: int):
    """The ``bounds_of`` callable for one ladder rung: the run's backend
    over the tier subcontext, traced as ``bounds.tier`` spans (distinct
    from the classic full-pass ``bounds`` spans, whose candidate/byte
    attributes describe the whole candidate set)."""

    def bounds_of(expr):
        t0 = time.perf_counter()
        with _trace.span("bounds.tier") as sp:
            lb, ub = run.backend.bounds(sub, expr)
            lb = np.asarray(lb, np.float64)
            ub = np.asarray(ub, np.float64)
            nbytes = len(sub.positions) * _chi_row_nbytes(sub, g)
            sp.set(expr=repr(expr), tier=g, candidates=len(sub.positions),
                   chi_bytes=nbytes)
        run.stats.chi_bytes += nbytes
        run.stats.bound_time_s += time.perf_counter() - t0
        return lb, ub

    return bounds_of


def _decide_pred(run, pred: Pred, shared_exprs=()):
    """Three-valued WHERE decision, through the cost-based optimizer when
    it applies (``core/opt.py``, DESIGN.md §13): conjuncts are evaluated
    cheapest-and-most-selective first, each starting at its chosen CHI
    pyramid tier and refining only the still-undecided candidates downward.

    The final (accept, reject) verdicts are bit-identical to the classic
    plan-order decide at the finest grid: coarse bounds contain fine bounds
    so coarse decisions are monotone, the finest rung re-evaluates every
    still-undecided candidate with exactly the classic bounds, and a
    candidate skipped because an earlier conjunct rejected it is rejected
    under any conjunct order.  The service's bounds-cache path keeps the
    classic decide so its finest-tier entries stay shared across refined
    queries.  Sets ``run.opt_report`` when the optimizer ran.
    """
    ctx = run.ctx
    plans = None
    if run._bounds_hook is None:
        plans = opt_lib.plan_filter(pred, ctx, shared_exprs=shared_exprs,
                                    memo_exprs=run._bounds_memo)
    if plans is None:
        accept, reject = pred.decide(run.expr_bounds, ctx)
        return np.asarray(accept), np.asarray(reject)
    tiers = ctx.cfg.tier_grids
    finest = tiers[-1]
    n = run.n
    accept = np.ones(n, dtype=bool)
    reject = np.zeros(n, dtype=bool)
    report = []
    for plan in plans:
        c = plan.pred
        live = np.nonzero(~reject)[0]
        a_c = np.zeros(n, dtype=bool)
        r_c = np.zeros(n, dtype=bool)
        tier_rows = []
        if plan.classic:
            a, r = c.decide(run.expr_bounds, ctx)
            a_c |= np.asarray(a, bool)
            r_c |= np.asarray(r, bool)
            evaluated = n
            rejected = int(r_c.sum())
        else:
            undecided = live
            for g in tiers[tiers.index(plan.start_tier):]:
                if not len(undecided):
                    break
                sub = tier_context(ctx, undecided,
                                   None if g == finest else g)
                a, r = c.decide(_ladder_bounds_of(run, sub, g, finest), sub)
                a = np.asarray(a, bool)
                r = np.asarray(r, bool)
                a_c[undecided[a]] = True
                r_c[undecided[r]] = True
                tier_rows.append({"grid": int(g),
                                  "candidates": int(len(undecided)),
                                  "accepted": int(a.sum()),
                                  "rejected": int(r.sum())})
                undecided = undecided[~(a | r)]
            evaluated = len(live)
            rejected = int(r_c[live].sum())
        actual_reject = rejected / evaluated if evaluated else None
        if plan.est_reject is not None and evaluated:
            opt_lib.observe_selectivity_error(
                abs(plan.est_reject - actual_reject))
        report.append({
            "pred": repr(c), "plan_index": plan.index,
            "start_tier": int(plan.start_tier), "classic": plan.classic,
            "est_reject": plan.est_reject, "actual_reject": actual_reject,
            "evaluated": evaluated, "tiers": tier_rows,
        })
        accept &= a_c
        reject |= r_c
    run.opt_report = {"order": [p.index for p in plans],
                      "reordered": [p.index for p in plans] !=
                      sorted(p.index for p in plans),
                      "tier_grids": [int(g) for g in tiers],
                      "conjuncts": report}
    return accept, reject


class FilterRun(_VerifyRun):
    """Resumable verification state for a filter query — a boolean predicate
    tree (or the legacy ``expr op threshold`` triple) whose bound-undecided
    residue is verified in chunks until exhausted."""

    def __init__(self, store, expr_or_pred, op: Optional[str] = None,
                 threshold: Optional[float] = None, *,
                 positions: Optional[np.ndarray] = None, mask_types=None,
                 group_by_image: bool = False,
                 provided_rois: Optional[np.ndarray] = None,
                 verify_batch: int = 256, bounds=None, bounds_hook=None,
                 backend=None):
        self.pred = _as_pred(expr_or_pred, op, threshold)
        # legacy surface for single-comparison plans
        if isinstance(self.pred, Cmp):
            self.expr = self.pred.expr
            self.op = self.pred.op
            self.threshold = self.pred.threshold
        else:
            self.expr, self.op, self.threshold = None, None, None
        super().__init__(store, self.pred.value_exprs(), positions=positions,
                         mask_types=mask_types, group_by_image=group_by_image,
                         provided_rois=provided_rois,
                         verify_batch=verify_batch, bounds_hook=bounds_hook,
                         backend=backend)
        if bounds is not None and self.expr is not None:
            self._bounds_memo[self.expr] = tuple(
                np.asarray(b, np.float64) for b in bounds)
        accept, reject = _decide_pred(self, self.pred)
        self.accept = np.asarray(accept).copy()
        self.pending = np.nonzero(~(accept | reject))[0]
        self.stats.n_decided_by_bounds = self.n - len(self.pending)

    def finished(self) -> bool:
        return self.cursor >= len(self.pending)

    def exact_values(self, batch):
        counts = self._self_counts(batch)
        if counts is not None:
            return self.fused_values(batch, counts)
        return self.pred.exact(self.ctx, batch)

    def fused_values(self, batch, counts):
        return self.pred.exact_with_counts(self.ctx, batch, counts)

    def _apply(self, batch: np.ndarray, values) -> None:
        self.accept[batch] = values

    def result(self) -> np.ndarray:
        return self.ids[self.accept]


def filter_query(store, expr_or_pred, op: Optional[str] = None,
                 threshold: Optional[float] = None, *,
                 positions: Optional[np.ndarray] = None,
                 mask_types=None, group_by_image: bool = False,
                 provided_rois: Optional[np.ndarray] = None,
                 use_index: bool = True, bounds=None, backend=None):
    """``SELECT {mask_id|image_id} WHERE predicate``.

    The predicate is either a :class:`repro.core.exprs.Pred` tree or the
    legacy ``expr, op, threshold`` triple.  Returns ``(ids, stats)``.
    ``use_index=False`` is the full-scan baseline (the paper's "without
    MaskSearch").  ``bounds`` optionally supplies a precomputed ``(lb, ub)``
    pair for a single-comparison predicate (legacy service surface).
    """
    pred = _as_pred(expr_or_pred, op, threshold)
    if not use_index:
        ctx, ids, n_dropped = _make_context(store, pred.value_exprs(),
                                            group_by_image, positions,
                                            mask_types, provided_rois,
                                            partial_rows=False)
        n = len(ids)
        stats = ExecStats(n_candidates=n, n_dropped_masks=n_dropped)
        io_before = store.io.bytes_read
        t0 = time.perf_counter()
        keep = pred.exact(ctx, np.arange(n))
        stats.n_verified = n
        stats.verify_time_s = time.perf_counter() - t0
        stats.bytes_loaded = store.io.bytes_read - io_before
        return ids[keep], stats

    run = FilterRun(store, pred, positions=positions,
                    mask_types=mask_types, group_by_image=group_by_image,
                    provided_rois=provided_rois,
                    verify_batch=max(len(store), 1), bounds=bounds,
                    backend=backend)
    run.ensure()
    return run.result(), run.stats


# ---------------------------------------------------------------------------
# Top-K query
# ---------------------------------------------------------------------------


class TopKRun(_VerifyRun):
    """Resumable top-k verification state (the batched loop of §3, DESIGN.md).

    Construction runs the bounds pass only; verification is then driven
    either by :meth:`ensure` (the one-shot ``topk_query`` path) or
    externally, one :meth:`take_batch`/:meth:`apply_exact` round at a time
    (the service's sessions and fused scheduler).  The finality target ``k``
    can *grow* between rounds — :meth:`target` re-derives the static pruning
    frontier from the cached bounds, so a GUI's "next 25" costs only the
    extra verification batches, never a fresh bounds pass.

    The frontier is written once, predicate-aware: a plain top-k is the
    trivial case where every candidate is known to qualify (``p_true`` all
    set); :class:`FilteredTopKRun` re-derives ``p_true``/``p_false`` from a
    predicate tree and shares every line of the pruning machinery.
    """

    def __init__(self, store, expr: Node, *, desc: bool = True,
                 positions: Optional[np.ndarray] = None, mask_types=None,
                 group_by_image: bool = False,
                 provided_rois: Optional[np.ndarray] = None,
                 verify_batch: int = 256, bounds=None, bounds_hook=None,
                 backend=None, _pred_exprs=()):
        self.desc = desc
        self.expr = expr
        super().__init__(store, list(_pred_exprs) + [expr],
                         positions=positions,
                         mask_types=mask_types, group_by_image=group_by_image,
                         provided_rois=provided_rois,
                         verify_batch=verify_batch, bounds_hook=bounds_hook,
                         backend=backend)
        if bounds is not None:
            self._bounds_memo[expr] = tuple(
                np.asarray(b, np.float64) for b in bounds)
        self._init_qualification()
        self.lb, self.ub = self.expr_bounds(expr)
        # Scores: exact where bounds coincide, else pending verification.
        self.scores = np.where(self.lb == self.ub, self.lb, np.nan)
        self.known = ~np.isnan(self.scores)
        self._resolved0 = self._resolved().copy()
        self.k = 0
        self.alive = np.zeros(self.n, dtype=bool)

    def _init_qualification(self) -> None:
        """Plain top-k: every candidate trivially satisfies the (absent)
        predicate.  Overridden by FilteredTopKRun."""
        self.p_true = np.ones(self.n, dtype=bool)
        self.p_false = np.zeros(self.n, dtype=bool)
        self.p_known = np.ones(self.n, dtype=bool)

    def _resolved(self) -> np.ndarray:
        """Candidates needing no verification: predicate known-false, or
        predicate known (true) with an exact score."""
        return self.p_false | (self.p_known & self.known)

    def target(self, k: Optional[int] = None) -> int:
        """Set/raise the finality target to ``k`` (clamped to n) and
        re-derive the static pruning frontier.  Idempotent for equal k."""
        if k is None:
            return self.k
        k = min(int(k), self.n)
        if k == self.k:
            return k
        self.k = k
        n = self.n
        if n == 0 or k <= 0:
            self.alive = np.zeros(n, dtype=bool)
            self.pending = np.empty(0, dtype=np.int64)
            self.cursor = 0
            return k
        # Static pruning: a candidate can make top-k only if its optimistic
        # bound beats the k-th best pessimistic bound among candidates that
        # *definitely* qualify — so no possibly-qualifying candidate is
        # pruned on an assumption about another's unverified predicate.
        # The frontier selection itself is a backend primitive (host
        # np.partition; device/mesh lax.top_k + all_gather).
        possible = ~self.p_false
        self.alive = self.backend.topk_candidates(self.lb, self.ub, k,
                                                  self.desc, self.p_true,
                                                  possible)
        self.stats.n_decided_by_bounds = int(
            n - np.count_nonzero(self.alive & ~self._resolved0))
        pending = np.nonzero(self.alive & ~self._resolved())[0]
        # verify most-promising first
        key = self.ub[pending] if self.desc else self.lb[pending]
        self.pending = pending[np.argsort(-key if self.desc else key,
                                          kind="stable")]
        self.cursor = 0
        return k

    def finished(self) -> bool:
        """True iff the current top-``k`` can no longer change."""
        have = np.nonzero(self.p_true & self.known & self.alive)[0]
        if len(have) >= self.k > 0:
            vals = self.scores[have]
            kth = (np.partition(vals, -self.k)[-self.k] if self.desc
                   else np.partition(vals, self.k - 1)[self.k - 1])
            rest = self.pending[self.cursor:]
            if len(rest) == 0:
                return True
            best_possible = (self.ub[rest].max() if self.desc
                             else self.lb[rest].min())
            # strict domination → no unverified candidate can displace top-k
            return ((self.desc and best_possible < kth) or
                    (not self.desc and best_possible > kth))
        return self.cursor >= len(self.pending)

    def exact_values(self, batch):
        counts = self._self_counts(batch)
        if counts is not None:
            return self.fused_values(batch, counts)
        return self.ctx.exact(self.expr, batch)

    def fused_values(self, batch, counts):
        return eval_with_counts(self.ctx, self.expr, batch, counts)

    def _apply(self, batch: np.ndarray, values) -> None:
        self.scores[batch] = values
        self.known[batch] = True

    def result(self, k: Optional[int] = None):
        """(ids, scores) of the current top-``k`` — call after :meth:`ensure`
        (or after the scheduler reports :meth:`finished`).  Ties break by
        candidate order, so paginated and one-shot runs agree exactly."""
        k = self.k if k is None else min(int(k), self.n)
        final = np.nonzero(self.p_true & self.known)[0]
        if len(final) == 0 or k <= 0:
            return self.ids[:0], self.scores[:0]
        vals = self.scores[final]
        order = final[_topk_order(vals, min(k, len(final)), self.desc)]
        return self.ids[order], self.scores[order]


def topk_query(store, expr: Node, k: int, *, desc: bool = True,
               positions: Optional[np.ndarray] = None,
               mask_types=None, group_by_image: bool = False,
               provided_rois: Optional[np.ndarray] = None,
               use_index: bool = True, verify_batch: int = 256,
               bounds=None, backend=None):
    """``SELECT ... ORDER BY expr {DESC|ASC} LIMIT k`` → (ids, scores, stats)."""
    if not use_index:
        ctx, ids, n_dropped = _make_context(store, [expr], group_by_image,
                                            positions, mask_types,
                                            provided_rois)
        n = len(ids)
        k = min(k, n)
        stats = ExecStats(n_candidates=n, n_dropped_masks=n_dropped)
        io_before = store.io.bytes_read
        t0 = time.perf_counter()
        exact = ctx.exact(expr, np.arange(n))
        order = _topk_order(exact, k, desc)
        stats.n_verified = n
        stats.verify_time_s = time.perf_counter() - t0
        stats.bytes_loaded = store.io.bytes_read - io_before
        return ids[order], exact[order], stats

    run = TopKRun(store, expr, desc=desc, positions=positions,
                  mask_types=mask_types, group_by_image=group_by_image,
                  provided_rois=provided_rois, verify_batch=verify_batch,
                  bounds=bounds, backend=backend)
    run.ensure(k)
    ids, scores = run.result()
    return ids, scores, run.stats


def _topk_order(values, k, desc):
    """Indices of the top-k, fully deterministic: ties break by ascending
    candidate position.  CP scores are integer pixel counts, so boundary
    ties are the norm — argpartition's arbitrary pick among equals would
    let a paginated run (whose known-set grows between pages) select a
    different tied candidate than a one-shot run."""
    v = -values if desc else values
    order = np.lexsort((np.arange(len(v)), v))  # primary v, then index
    return order[:k]


# ---------------------------------------------------------------------------
# Filtered Top-K: predicate residue feeds the ranking frontier
# ---------------------------------------------------------------------------


class FilteredTopKRun(TopKRun):
    """``WHERE predicate ORDER BY expr LIMIT k`` as one filter–verification
    run (the query class the flat front-end refused outright).

    The three-valued predicate decision and the ranking bounds come from the
    same CHI pass: bound-rejected candidates leave the ranking frontier
    immediately, bound-accepted ones rank on their score bounds, and the
    *unknown* residue stays in the frontier optimistically (it might satisfy
    the predicate with its optimistic score).  One verification batch
    resolves both the predicate truth and the exact score — every CP term of
    both trees is answered from one load of the mask bytes (and one fused
    kernel row set when the scheduler drives this run).

    All pruning machinery is inherited: the base frontier is already
    predicate-aware (``p_true``/``p_false``/``p_known``), with τ drawn only
    from *definitely*-qualifying candidates, so no possibly-qualifying
    candidate is pruned on an assumption about another candidate's
    unverified predicate.  This class only re-derives the qualification
    masks from the predicate tree and verifies (predicate, score) pairs.
    """

    def __init__(self, store, pred: Pred, expr: Node, *, desc: bool = True,
                 positions: Optional[np.ndarray] = None, mask_types=None,
                 group_by_image: bool = False,
                 provided_rois: Optional[np.ndarray] = None,
                 verify_batch: int = 256, bounds_hook=None, backend=None):
        self.pred = pred
        super().__init__(store, expr, desc=desc, positions=positions,
                         mask_types=mask_types, group_by_image=group_by_image,
                         provided_rois=provided_rois,
                         verify_batch=verify_batch, bounds_hook=bounds_hook,
                         backend=backend, _pred_exprs=pred.value_exprs())

    def _init_qualification(self) -> None:
        # The ranking expression is "shared": a conjunct over it decides
        # from the run's full finest bounds so the pass stays memoized for
        # the ranking frontier instead of re-running per ladder rung.
        accept, reject = _decide_pred(self, self.pred,
                                      shared_exprs=(self.expr,))
        self.p_true = np.asarray(accept).copy()
        self.p_false = np.asarray(reject).copy()
        self.p_known = self.p_true | self.p_false

    def exact_values(self, batch):
        counts = self._self_counts(batch)
        if counts is not None:
            return self.fused_values(batch, counts)
        return (self.pred.exact(self.ctx, batch),
                self.ctx.exact(self.expr, batch))

    def fused_values(self, batch, counts):
        return (self.pred.exact_with_counts(self.ctx, batch, counts),
                eval_with_counts(self.ctx, self.expr, batch, counts))

    def _apply(self, batch: np.ndarray, values) -> None:
        pred_vals, score_vals = values
        pred_vals = np.asarray(pred_vals, bool)
        self.p_true[batch] = pred_vals
        self.p_false[batch] = ~pred_vals
        self.p_known[batch] = True
        self.scores[batch] = score_vals
        self.known[batch] = True


def filtered_topk_query(store, pred: Pred, expr: Node, k: int, *,
                        desc: bool = True,
                        positions: Optional[np.ndarray] = None,
                        mask_types=None, group_by_image: bool = False,
                        provided_rois: Optional[np.ndarray] = None,
                        use_index: bool = True, verify_batch: int = 256,
                        backend=None):
    """``WHERE predicate ORDER BY expr LIMIT k`` → (ids, scores, stats)."""
    if not use_index:
        ctx, ids, n_dropped = _make_context(store,
                                            list(pred.value_exprs()) + [expr],
                                            group_by_image, positions,
                                            mask_types, provided_rois,
                                            partial_rows=False)
        n = len(ids)
        stats = ExecStats(n_candidates=n, n_dropped_masks=n_dropped)
        io_before = store.io.bytes_read
        t0 = time.perf_counter()
        keep = np.nonzero(pred.exact(ctx, np.arange(n)))[0]
        exact = ctx.exact(expr, keep)
        sub = _topk_order(exact, min(k, len(keep)), desc)
        stats.n_verified = n
        stats.verify_time_s = time.perf_counter() - t0
        stats.bytes_loaded = store.io.bytes_read - io_before
        return ids[keep[sub]], exact[sub], stats

    run = FilteredTopKRun(store, pred, expr, desc=desc, positions=positions,
                          mask_types=mask_types, group_by_image=group_by_image,
                          provided_rois=provided_rois,
                          verify_batch=verify_batch, backend=backend)
    run.ensure(k)
    ids, scores = run.result()
    return ids, scores, run.stats


# ---------------------------------------------------------------------------
# Dual-mask (pair) runs — the paper's discrepancy queries as plan operators
# ---------------------------------------------------------------------------


class _PairRunMixin:
    """Shared surface of the dual-mask physical operators (DESIGN.md §9).

    All frontier machinery is inherited unchanged — a pair run is the same
    filter–verification drive over a :class:`PairEvalContext` whose
    candidates are per-image (role_a, role_b) mask pairs: bounds combine
    the two roles' CHI passes (:func:`repro.core.exprs.pair_stat_bounds`),
    verification answers every pair term of the plan from one fused
    dual-mask kernel pass per batch (``ExecBackend.pair_verify_counts``),
    and results refer to **image ids**.  The pruning win is squared
    relative to single-mask plans: skipping a pair skips the bytes of
    *two* masks.
    """

    @property
    def roles(self) -> tuple:
        """The (role_a, role_b) mask-type pair this run evaluates."""
        return self.ctx.roles

    def _check_pair_ctx(self) -> None:
        if not isinstance(self.ctx, PairEvalContext):
            raise ValueError(
                "pair run compiled without pair terms — use the plain "
                "FilterRun/TopKRun classes (or compile_plan) instead")


class PairFilterRun(_PairRunMixin, FilterRun):
    """``SELECT image_id WHERE <pair predicate>`` — e.g. images whose
    saliency∖attention difference count exceeds a threshold."""

    def __init__(self, store, expr_or_pred, *args, **kw):
        super().__init__(store, expr_or_pred, *args, **kw)
        self._check_pair_ctx()


class PairTopKRun(_PairRunMixin, TopKRun):
    """``SELECT image_id ORDER BY <pair expr> LIMIT k`` — e.g. the paper's
    saliency-vs-attention discrepancy ranking ``ORDER BY IOU(a, b, t, t)
    ASC LIMIT 25``."""

    def __init__(self, store, expr, **kw):
        super().__init__(store, expr, **kw)
        self._check_pair_ctx()


class PairFilteredTopKRun(_PairRunMixin, FilteredTopKRun):
    """Pair predicate + pair ranking in one run: the predicate truth and
    the exact score of one image resolve from a single load of its two
    masks."""

    def __init__(self, store, pred, expr, **kw):
        super().__init__(store, pred, expr, **kw)
        self._check_pair_ctx()


# ---------------------------------------------------------------------------
# Scalar aggregation
# ---------------------------------------------------------------------------


class ScalarAggRun(_VerifyRun):
    """Resumable SUM/AVG: bound-coincident candidates are exact for free;
    only the undecided residue verifies.  ``result()`` is the scalar."""

    def __init__(self, store, expr: Node, agg: str, *,
                 positions: Optional[np.ndarray] = None, mask_types=None,
                 group_by_image: bool = False,
                 provided_rois: Optional[np.ndarray] = None,
                 verify_batch: int = 256, bounds_hook=None, backend=None):
        agg = agg.upper()
        if agg not in ("SUM", "AVG"):
            raise ValueError(f"ScalarAggRun handles SUM/AVG, got {agg!r}")
        self.agg = agg
        self.expr = expr
        super().__init__(store, [expr], positions=positions,
                         mask_types=mask_types, group_by_image=group_by_image,
                         provided_rois=provided_rois,
                         verify_batch=verify_batch, bounds_hook=bounds_hook,
                         backend=backend)
        lb, ub = self.expr_bounds(expr)
        self.values = lb.astype(np.float64)   # astype copies; safe to mutate
        self.pending = np.nonzero(lb != ub)[0]
        self.stats.n_decided_by_bounds = self.n - len(self.pending)

    def finished(self) -> bool:
        return self.cursor >= len(self.pending)

    def exact_values(self, batch):
        counts = self._self_counts(batch)
        if counts is not None:
            return self.fused_values(batch, counts)
        return self.ctx.exact(self.expr, batch)

    def fused_values(self, batch, counts):
        return eval_with_counts(self.ctx, self.expr, batch, counts)

    def _apply(self, batch: np.ndarray, values) -> None:
        self.values[batch] = values

    def result(self) -> float:
        if self.agg == "SUM":
            return float(self.values.sum())
        return float(self.values.mean()) if self.n else float("nan")


class MinMaxAggRun(TopKRun):
    """MIN/MAX through the top-k pruning machinery (k = 1); ``result()`` is
    the scalar (NaN on an empty candidate set, matching SUM/AVG's clean
    empty-set behavior)."""

    def __init__(self, store, expr: Node, agg: str, **kw):
        agg = agg.upper()
        if agg not in ("MIN", "MAX"):
            raise ValueError(f"MinMaxAggRun handles MIN/MAX, got {agg!r}")
        self.agg = agg
        super().__init__(store, expr, desc=(agg == "MAX"), **kw)
        TopKRun.target(self, 1)

    def target(self, k: Optional[int] = None) -> int:
        return self.k  # the finality target is always 1

    def result(self) -> float:
        _, scores = TopKRun.result(self, 1)
        return float(scores[0]) if len(scores) else float("nan")


def scalar_agg(store, expr: Node, agg: str, *,
               positions: Optional[np.ndarray] = None, mask_types=None,
               provided_rois: Optional[np.ndarray] = None,
               use_index: bool = True, backend=None):
    """``SELECT SCALAR_AGG(expr)`` with agg ∈ {SUM, AVG, MIN, MAX}.

    MIN/MAX reuse the top-k pruning machinery (k=1).  SUM/AVG verify only
    bound-undecided masks.  Returns ``(value, stats)``.  An empty candidate
    set (e.g. ``mask_type IN (...)`` matching nothing) yields NaN for
    AVG/MIN/MAX and 0.0 for SUM, never an exception.
    """
    agg = agg.upper()
    common = dict(positions=positions, mask_types=mask_types,
                  provided_rois=provided_rois)
    if not use_index:
        if agg in ("MIN", "MAX"):
            _, scores, stats = topk_query(store, expr, 1,
                                          desc=(agg == "MAX"),
                                          use_index=False, **common)
            value = float(scores[0]) if len(scores) else float("nan")
            return value, stats
        ctx, ids, n_dropped = _make_context(store, [expr], False, positions,
                                            mask_types, provided_rois,
                                            partial_rows=False)
        n = len(ids)
        stats = ExecStats(n_candidates=n, n_dropped_masks=n_dropped)
        io_before = store.io.bytes_read
        exact = ctx.exact(expr, np.arange(n)) if n else np.empty(0)
        stats.n_verified = n
        stats.bytes_loaded = store.io.bytes_read - io_before
        if agg == "SUM":
            value = float(exact.sum())
        else:
            value = float(exact.mean()) if n else float("nan")
        return value, stats

    if agg in ("MIN", "MAX"):
        run = MinMaxAggRun(store, expr, agg, backend=backend, **common)
    else:
        run = ScalarAggRun(store, expr, agg, backend=backend,
                           verify_batch=max(len(store), 1), **common)
    run.ensure()
    return run.result(), run.stats
