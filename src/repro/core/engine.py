"""The filter–verification execution framework (paper §2).

Every query runs in two phases:

1. **Filter** — CHI-derived bounds are computed for every candidate (no mask
   bytes touched).  Candidates whose bounds already decide the predicate are
   accepted/pruned outright; bound-coincident candidates (``lb == ub``) have
   *known exact scores* for free.
2. **Verification** — only the undecided residue is loaded from the mask
   tier and evaluated exactly.  For Top-K, verification proceeds in rounds of
   ``verify_batch`` ordered by most-promising bound, and stops as soon as the
   running k-th-best exact score dominates every unverified candidate's bound
   (the paper's incremental-threshold pruning, recast as fixed-size device
   batches — see DESIGN.md §3 on why batches instead of a per-mask heap).

All functions return :class:`ExecStats` telling exactly how much I/O the
index avoided — the quantity behind the paper's 100× claim.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from .exprs import (GroupEvalContext, MaskEvalContext, Node, is_group_expr)


@dataclasses.dataclass
class ExecStats:
    n_candidates: int = 0
    n_decided_by_bounds: int = 0      # accepted or pruned without loading
    n_verified: int = 0               # masks actually loaded + scanned
    n_rounds: int = 0                 # top-k verification rounds
    bytes_loaded: int = 0
    bound_time_s: float = 0.0
    verify_time_s: float = 0.0

    @property
    def load_fraction(self) -> float:
        return self.n_verified / max(self.n_candidates, 1)


_OPS = {
    "<":  (lambda ub, t: ub < t,  lambda lb, t: lb >= t),
    "<=": (lambda ub, t: ub <= t, lambda lb, t: lb > t),
    ">":  (lambda lb, t: lb > t,  lambda ub, t: ub <= t),
    ">=": (lambda lb, t: lb >= t, lambda ub, t: ub < t),
}


def _accept_reject(op: str, lb, ub, threshold: float):
    """Sound bound decisions: accept iff the predicate must hold, reject iff
    it cannot hold, for exact ∈ [lb, ub]."""
    if op in ("<", "<="):
        acc_fn, rej_fn = _OPS[op]
        return acc_fn(ub, threshold), rej_fn(lb, threshold)
    acc_fn, rej_fn = _OPS[op]
    return acc_fn(lb, threshold), rej_fn(ub, threshold)


def _make_context(store, expr: Node, positions, group_by_image: bool,
                  mask_types, provided_rois, partial_rows: bool = True):
    """Build the evaluation context + the id array that results refer to."""
    if is_group_expr(expr) or group_by_image:
        sel = (store.select(mask_type=mask_types) if mask_types is not None
               else np.arange(len(store)))
        if positions is not None:
            sel = np.intersect1d(sel, positions)
        img = store.meta["image_id"][sel]
        order = np.argsort(img, kind="stable")
        sel, img = sel[order], img[order]
        uniq, starts, counts = np.unique(img, return_index=True,
                                         return_counts=True)
        size = counts.min()
        if counts.max() != size:
            # ragged groups: keep the first `size` per image (deterministic)
            keep = np.concatenate(
                [sel[s:s + size] for s in starts])
            groups = keep.reshape(-1, size)
        else:
            groups = sel.reshape(-1, size)
        ctx = GroupEvalContext(store, groups, uniq, provided_rois)
        return ctx, uniq
    if positions is None:
        positions = (store.select(mask_type=mask_types)
                     if mask_types is not None else np.arange(len(store)))
    ctx = MaskEvalContext(store, positions, provided_rois,
                          partial_rows=partial_rows)
    return ctx, store.meta["mask_id"][positions]


def _exact_for(ctx, expr, idx):
    if isinstance(ctx, GroupEvalContext):
        return ctx.exact(expr, idx)
    return ctx.exact(expr, idx)


# ---------------------------------------------------------------------------
# Filter query
# ---------------------------------------------------------------------------


def filter_query(store, expr: Node, op: str, threshold: float, *,
                 positions: Optional[np.ndarray] = None,
                 mask_types=None, group_by_image: bool = False,
                 provided_rois: Optional[np.ndarray] = None,
                 use_index: bool = True):
    """``SELECT {mask_id|image_id} WHERE expr op threshold``.

    Returns ``(ids, stats)``.  ``use_index=False`` is the full-scan baseline
    (the paper's "without MaskSearch").
    """
    ctx, ids = _make_context(store, expr, positions, group_by_image,
                             mask_types, provided_rois,
                             partial_rows=use_index)
    n = len(ids)
    stats = ExecStats(n_candidates=n)
    io_before = store.io.bytes_read

    if not use_index:
        t0 = time.perf_counter()
        exact = _exact_for(ctx, expr, np.arange(n))
        keep = _cmp(op, exact, threshold)
        stats.n_verified = n
        stats.verify_time_s = time.perf_counter() - t0
        stats.bytes_loaded = store.io.bytes_read - io_before
        return ids[keep], stats

    t0 = time.perf_counter()
    lb, ub = ctx.bounds(expr)
    accept, reject = _accept_reject(op, lb, ub, threshold)
    stats.bound_time_s = time.perf_counter() - t0
    undecided = np.nonzero(~(accept | reject))[0]
    stats.n_decided_by_bounds = n - len(undecided)

    t0 = time.perf_counter()
    if len(undecided):
        exact = _exact_for(ctx, expr, undecided)
        accept = accept.copy()
        accept[undecided] = _cmp(op, exact, threshold)
    stats.n_verified = len(undecided)
    stats.verify_time_s = time.perf_counter() - t0
    stats.bytes_loaded = store.io.bytes_read - io_before
    return ids[accept], stats


def _cmp(op, values, threshold):
    import operator
    return {"<": operator.lt, "<=": operator.le,
            ">": operator.gt, ">=": operator.ge}[op](values, threshold)


# ---------------------------------------------------------------------------
# Top-K query
# ---------------------------------------------------------------------------


def topk_query(store, expr: Node, k: int, *, desc: bool = True,
               positions: Optional[np.ndarray] = None,
               mask_types=None, group_by_image: bool = False,
               provided_rois: Optional[np.ndarray] = None,
               use_index: bool = True, verify_batch: int = 256):
    """``SELECT ... ORDER BY expr {DESC|ASC} LIMIT k`` → (ids, scores, stats)."""
    ctx, ids = _make_context(store, expr, positions, group_by_image,
                             mask_types, provided_rois)
    n = len(ids)
    k = min(k, n)
    stats = ExecStats(n_candidates=n)
    io_before = store.io.bytes_read

    if not use_index:
        t0 = time.perf_counter()
        exact = _exact_for(ctx, expr, np.arange(n))
        order = _topk_order(exact, k, desc)
        stats.n_verified = n
        stats.verify_time_s = time.perf_counter() - t0
        stats.bytes_loaded = store.io.bytes_read - io_before
        return ids[order], exact[order], stats

    t0 = time.perf_counter()
    lb, ub = ctx.bounds(expr)
    stats.bound_time_s = time.perf_counter() - t0

    # Scores: exact where bounds coincide, else pending verification.
    scores = np.where(lb == ub, lb, np.nan)
    known = ~np.isnan(scores)

    # Static pruning: a candidate can make top-k only if its optimistic bound
    # beats the k-th best pessimistic bound.
    if desc:
        tau = np.partition(lb, -k)[-k] if n >= k else -np.inf
        alive = ub >= tau
    else:
        tau = np.partition(ub, k - 1)[k - 1] if n >= k else np.inf
        alive = lb <= tau
    stats.n_decided_by_bounds = int(n - np.count_nonzero(alive & ~known))

    pending = np.nonzero(alive & ~known)[0]
    # verify most-promising first
    key = ub[pending] if desc else lb[pending]
    pending = pending[np.argsort(-key if desc else key, kind="stable")]

    t0 = time.perf_counter()
    cursor = 0
    while True:
        have = np.nonzero(known & alive)[0]
        if len(have) >= k:
            vals = scores[have]
            kth = (np.partition(vals, -k)[-k] if desc
                   else np.partition(vals, k - 1)[k - 1])
            rest = pending[cursor:]
            if len(rest) == 0:
                break
            best_possible = ub[rest].max() if desc else lb[rest].min()
            # strict domination → no unverified candidate can displace top-k
            if (desc and best_possible < kth) or (not desc and best_possible > kth):
                break
        elif cursor >= len(pending):
            break
        batch = pending[cursor:cursor + verify_batch]
        if len(batch) == 0:
            break
        exact = _exact_for(ctx, expr, batch)
        scores[batch] = exact
        known[batch] = True
        cursor += len(batch)
        stats.n_rounds += 1
        stats.n_verified += len(batch)
    stats.verify_time_s = time.perf_counter() - t0
    stats.bytes_loaded = store.io.bytes_read - io_before

    final = np.nonzero(known)[0]
    vals = scores[final]
    order = final[_topk_order(vals, k, desc)]
    return ids[order], scores[order], stats


def _topk_order(values, k, desc):
    v = -values if desc else values
    part = np.argpartition(v, min(k, len(v)) - 1)[:k]
    return part[np.argsort(v[part], kind="stable")]


# ---------------------------------------------------------------------------
# Scalar aggregation
# ---------------------------------------------------------------------------


def scalar_agg(store, expr: Node, agg: str, *,
               positions: Optional[np.ndarray] = None, mask_types=None,
               provided_rois: Optional[np.ndarray] = None,
               use_index: bool = True):
    """``SELECT SCALAR_AGG(expr)`` with agg ∈ {SUM, AVG, MIN, MAX}.

    MIN/MAX reuse the top-k pruning machinery (k=1).  SUM/AVG verify only
    bound-undecided masks.  Returns ``(value, stats)``.
    """
    agg = agg.upper()
    if agg in ("MIN", "MAX"):
        ids, scores, stats = topk_query(
            store, expr, 1, desc=(agg == "MAX"), positions=positions,
            mask_types=mask_types, provided_rois=provided_rois,
            use_index=use_index)
        return float(scores[0]), stats

    ctx, ids = _make_context(store, expr, positions, False, mask_types,
                             provided_rois, partial_rows=use_index)
    n = len(ids)
    stats = ExecStats(n_candidates=n)
    io_before = store.io.bytes_read
    if not use_index:
        exact = _exact_for(ctx, expr, np.arange(n))
        stats.n_verified = n
    else:
        t0 = time.perf_counter()
        lb, ub = ctx.bounds(expr)
        stats.bound_time_s = time.perf_counter() - t0
        exact = lb.astype(np.float64)
        undecided = np.nonzero(lb != ub)[0]
        stats.n_decided_by_bounds = n - len(undecided)
        if len(undecided):
            t0 = time.perf_counter()
            exact[undecided] = _exact_for(ctx, expr, undecided)
            stats.verify_time_s = time.perf_counter() - t0
        stats.n_verified = len(undecided)
    stats.bytes_loaded = store.io.bytes_read - io_before
    value = float(exact.sum()) if agg == "SUM" else float(exact.mean())
    return value, stats
