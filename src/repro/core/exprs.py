"""Query expressions over CP terms, with sound interval (bounds) semantics.

The paper lets users "use multiple CP functions and apply arithmetic
operations in queries" — e.g. Scenario 1 normalizes a CP by the ROI area and
Scenario 3 ranks by ``CP(intersect(...))/CP(union(...))`` (IoU).  This module
gives those expressions two evaluation modes:

* ``bounds``  — interval arithmetic over CHI-derived (lower, upper) bounds;
                never touches mask bytes.  Soundness: the exact value always
                lies inside the returned interval.
* ``exact``   — evaluation against loaded mask bytes (the verification path).

Two unit kinds exist:

* per-**mask** expressions (Filter/Top-K/scalar-agg) built from :class:`CP`;
* per-**group** expressions (the paper's MASK_AGG, GROUP BY image_id) built
  from :class:`AggCP` over the masks of one image — intersection / union of
  thresholded member masks, with bounds derived purely from member CP bounds:

      intersect:  ub = min_i ub_i,  lb = max(0, Σ lb_i − (n−1)·|roi|)
      union:      lb = max_i lb_i,  ub = min(|roi|, Σ ub_i)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from . import chi as chi_lib
from . import cp as cp_lib

_INF = np.float64(np.inf)


def _as_rois(roi, positions: np.ndarray, store_rois: Optional[np.ndarray],
             cfg) -> np.ndarray:
    """Resolve a term's ROI spec to an ``(n, 4)`` array for these rows.

    ``roi`` is ``None`` (full mask), a 4-tuple constant rectangle, or the
    string ``"provided"`` meaning per-mask ROIs supplied by the caller
    (the paper's mask-dependent ROIs, e.g. YOLO boxes keyed by image).
    """
    n = len(positions)
    if roi is None:
        return cp_lib.normalize_rois(None, n, cfg.height, cfg.width)
    if isinstance(roi, str) and roi == "provided":
        if store_rois is None:
            raise ValueError("query uses provided ROIs but none were given")
        return cp_lib.normalize_rois(store_rois[positions], n, cfg.height, cfg.width)
    return cp_lib.normalize_rois(np.asarray(roi), n, cfg.height, cfg.width)


class Node:
    """Expression tree base."""

    def __truediv__(self, other):
        return BinOp("/", self, _wrap(other))

    def __mul__(self, other):
        return BinOp("*", self, _wrap(other))

    def __add__(self, other):
        return BinOp("+", self, _wrap(other))

    def __sub__(self, other):
        return BinOp("-", self, _wrap(other))

    def cp_terms(self):
        return []


def _wrap(x):
    return x if isinstance(x, Node) else Const(float(x))


@dataclasses.dataclass(frozen=True)
class Const(Node):
    value: float

    def cp_terms(self):
        return []


@dataclasses.dataclass(frozen=True)
class CP(Node):
    """CP(mask, roi, (lv, uv)) — the paper's primitive."""

    roi: object  # None | (r0,c0,r1,c1) | "provided"
    lv: float
    uv: float

    def cp_terms(self):
        return [self]


@dataclasses.dataclass(frozen=True)
class RoiArea(Node):
    """Pixel area of the term's ROI — for normalized CPs (Scenario 1)."""

    roi: object

    def cp_terms(self):
        return []


@dataclasses.dataclass(frozen=True)
class AggCP(Node):
    """CP(MASK_AGG(mask > thresh), roi, (lv, uv)) over one image's masks.

    ``agg`` ∈ {"intersect", "union"}.  The aggregated mask is binary, so the
    counted pixels are those where the intersection/union holds; ``lv/uv``
    are implied (count of 1s) and kept for API symmetry.
    """

    agg: str
    thresh: float
    roi: object

    def cp_terms(self):
        return [self]


@dataclasses.dataclass(frozen=True)
class PairTerm(Node):
    """A per-image function of **two** mask roles (DESIGN.md §9).

    Roles are mask_types: for each image the plan pairs its first role-A
    mask with its first role-B mask, thresholds them (``> ta`` / ``> tb``)
    and counts, inside the pair's ROI, the pixels of

        ``stat="inter"`` — A∩B,   ``stat="union"`` — A∪B,
        ``stat="diff"``  — A∖B  (|B∖A| is the same term with roles swapped).

    IoU and every other pair statistic are expression trees over these
    three counts (see :func:`pair_iou`), so interval arithmetic, the
    guarded division and fused verification all come for free.  Bounds
    derive from each role's CHI tables alone (no mask bytes) — the sound
    combination rules over thresholded-count bounds (lo_X, hi_X) of a
    region of area ``|R|``:

        inter:  max(0, lo_A + lo_B − |R|) ≤ · ≤ min(hi_A, hi_B)
        union:  max(lo_A, lo_B)           ≤ · ≤ min(|R|, hi_A + hi_B)
        diff:   max(0, lo_A − hi_B)       ≤ · ≤ min(hi_A, |R| − lo_B)

    (diff = A ∩ Bᶜ with Bᶜ's count in [|R|−hi_B, |R|−lo_B]) — applied
    **per CHI cell** and summed (:func:`pair_cell_bounds`), which is
    always at least as tight as applying them to the whole ROI and is
    what makes spatial-discrepancy pruning work at all.
    """

    stat: str     # "inter" | "union" | "diff"
    role_a: int   # mask_type of role A (e.g. 1 = model saliency)
    role_b: int   # mask_type of role B (e.g. 2 = human attention)
    ta: float     # threshold for A (binary A = mask_A > ta)
    tb: float     # threshold for B
    roi: object = None   # None | (r0,c0,r1,c1) | "provided"

    def __post_init__(self):
        if self.stat not in ("inter", "union", "diff"):
            raise ValueError(f"unknown pair stat {self.stat!r}")

    def cp_terms(self):
        return [self]


def pair_iou(role_a: int, role_b: int, ta: float, tb: float,
             roi=None) -> Node:
    """``IOU(role_a, role_b, ta, tb)`` as an expression tree: the ratio of
    the pair's intersection and union counts.  Both terms share one
    (ta, tb, roi) pair spec, so verification answers them from a single
    fused kernel pass over the two masks."""
    return BinOp("/", PairTerm("inter", role_a, role_b, ta, tb, roi),
                 PairTerm("union", role_a, role_b, ta, tb, roi))


def pair_stat_bounds(stat: str, a_lb, a_ub, b_lb, b_ub, area):
    """Sound (lb, ub) for one pair stat from *aggregate* thresholded-count
    bounds over one region (see :class:`PairTerm`).  This is the area-level
    combination rule; execution uses its cell-decomposed refinement
    (:func:`pair_cell_bounds`), which applies these same formulas per CHI
    cell and is therefore always at least as tight — kept as the
    documented algebra and the property-test envelope."""
    if stat == "inter":
        return (np.maximum(0.0, a_lb + b_lb - area),
                np.minimum(a_ub, b_ub))
    if stat == "union":
        return (np.maximum(a_lb, b_lb),
                np.minimum(area, a_ub + b_ub))
    if stat == "diff":
        return (np.maximum(0.0, a_lb - b_ub),
                np.minimum(a_ub, area - b_lb))
    raise ValueError(f"unknown pair stat {stat!r}")


def _threshold_ks(cfg, thresh: float) -> tuple[int, int]:
    """CHI value-edge indices (inner, outer) for the strict ``> thresh``
    count.  ``[nextafter32(t), ∞)`` contains exactly the float32 values
    strictly above ``t``, so the resulting bounds are sound — and tight —
    for the comparison the pair kernel evaluates (no measure-zero
    unsoundness when a threshold coincides with a bin edge)."""
    lv = float(np.nextafter(np.float32(thresh), np.float32(np.inf)))
    edges = cfg.edges
    k_in = int(np.clip(np.searchsorted(edges, lv, side="left"),
                       0, cfg.num_bins))
    k_out = int(np.clip(np.searchsorted(edges, lv, side="right") - 1,
                        0, cfg.num_bins))
    return k_in, k_out


def _cell_counts(tables: np.ndarray, k: int) -> np.ndarray:
    """Per-cell counts of pixels with value ≥ edges[k], from the CHI
    prefix-sum rows: (n, G+1, G+1, NB+1) → (n, G, G) int64."""
    p = tables[..., -1].astype(np.int64) - tables[..., k].astype(np.int64)
    return p[:, 1:, 1:] - p[:, :-1, 1:] - p[:, 1:, :-1] + p[:, :-1, :-1]


def pair_cell_bounds(cfg, stat: str, lo_a, hi_a, lo_b, hi_b,
                     rois: np.ndarray):
    """Cell-decomposed sound (lb, ub) for one pair stat (DESIGN.md §9).

    ``lo_X``/``hi_X``: (n, G, G) per-cell lower/upper counts of role X's
    thresholded pixels (from :func:`_cell_counts` at the inner/outer value
    edge).  The pair stat is summed cell by cell — e.g. for the difference
    A∖B, a cell where the model is provably hot (``lo_a``) and the human
    provably cold (``hi_b``) contributes ``lo_a − hi_b`` to the lower
    bound — which captures the *spatial* disjointness discrepancy queries
    rank by; the area-level rule (:func:`pair_stat_bounds`) cannot (its
    lower bounds collapse to 0 for full-image regions).  Each cell's
    contribution applies the area-level algebra to that cell, restricted
    to its overlap with the ROI: partial-overlap cells contribute 0 to
    lower bounds and an overlap-clamped upper, so arbitrary pixel ROIs
    stay sound.  By convexity the cell sum dominates the area-level rule,
    so only this path runs in execution.
    """
    rb = np.asarray(cfg.row_bounds, np.int64)
    cb = np.asarray(cfg.col_bounds, np.int64)
    r0, c0 = rois[:, 0][:, None], rois[:, 1][:, None]
    r1, c1 = rois[:, 2][:, None], rois[:, 3][:, None]
    ov_r = np.clip(np.minimum(r1, rb[None, 1:]) -
                   np.maximum(r0, rb[None, :-1]), 0, None)     # (n, G)
    ov_c = np.clip(np.minimum(c1, cb[None, 1:]) -
                   np.maximum(c0, cb[None, :-1]), 0, None)
    full_r = (rb[None, :-1] >= r0) & (rb[None, 1:] <= r1)
    full_c = (cb[None, :-1] >= c0) & (cb[None, 1:] <= c1)
    overlap = ov_r[:, :, None] * ov_c[:, None, :]              # |cell ∩ R|
    full = full_r[:, :, None] & full_c[:, None, :]             # cell ⊆ R
    cell_area = ((rb[1:] - rb[:-1])[None, :, None] *
                 (cb[1:] - cb[:-1])[None, None, :])
    if stat == "inter":
        lb = np.where(full, np.maximum(0, lo_a + lo_b - cell_area), 0)
        ub = np.minimum(np.minimum(hi_a, hi_b), overlap)
    elif stat == "union":
        lb = np.where(full, np.maximum(lo_a, lo_b), 0)
        ub = np.minimum(overlap, hi_a + hi_b)
    elif stat == "diff":
        lb = np.where(full, np.maximum(0, lo_a - hi_b), 0)
        ub = np.where(full,
                      np.minimum(np.minimum(hi_a, overlap),
                                 cell_area - lo_b),
                      np.minimum(hi_a, overlap))
    else:
        raise ValueError(f"unknown pair stat {stat!r}")
    return (lb.sum(axis=(1, 2)).astype(np.float64),
            ub.sum(axis=(1, 2)).astype(np.float64))


def cell_counts_jnp(tables, k):
    """Device mirror of :func:`_cell_counts` (same corner-difference math,
    int32 — per-cell counts are ≤ H·W so int32 is exact).  ``k`` may be a
    traced scalar, so the value-edge gather stays inside one jit."""
    last = tables.shape[-1] - 1
    p = jnp.take(tables, last, axis=-1) - jnp.take(tables, k, axis=-1)
    return p[:, 1:, 1:] - p[:, :-1, 1:] - p[:, 1:, :-1] + p[:, :-1, :-1]


def pair_cell_bounds_jnp(stat: str, lo_a, hi_a, lo_b, hi_b, rois,
                         row_bounds, col_bounds):
    """Device mirror of :func:`pair_cell_bounds` — identical per-cell
    formulas in int32 (cell sums are bounded by the ROI area < 2³¹, so the
    int32 device result converts to float64 bit-identically to the host
    path).  ``stat`` is trace-static; boundary arrays come in as runtime
    operands so one compilation serves every tier."""
    rb = row_bounds.astype(jnp.int32)
    cb = col_bounds.astype(jnp.int32)
    rois = rois.astype(jnp.int32)
    r0, c0 = rois[:, 0][:, None], rois[:, 1][:, None]
    r1, c1 = rois[:, 2][:, None], rois[:, 3][:, None]
    ov_r = jnp.clip(jnp.minimum(r1, rb[None, 1:]) -
                    jnp.maximum(r0, rb[None, :-1]), 0, None)
    ov_c = jnp.clip(jnp.minimum(c1, cb[None, 1:]) -
                    jnp.maximum(c0, cb[None, :-1]), 0, None)
    full_r = (rb[None, :-1] >= r0) & (rb[None, 1:] <= r1)
    full_c = (cb[None, :-1] >= c0) & (cb[None, 1:] <= c1)
    overlap = ov_r[:, :, None] * ov_c[:, None, :]
    full = full_r[:, :, None] & full_c[:, None, :]
    cell_area = ((rb[1:] - rb[:-1])[None, :, None] *
                 (cb[1:] - cb[:-1])[None, None, :])
    zero = jnp.zeros((), jnp.int32)
    if stat == "inter":
        lb = jnp.where(full, jnp.maximum(0, lo_a + lo_b - cell_area), zero)
        ub = jnp.minimum(jnp.minimum(hi_a, hi_b), overlap)
    elif stat == "union":
        lb = jnp.where(full, jnp.maximum(lo_a, lo_b), zero)
        ub = jnp.minimum(overlap, hi_a + hi_b)
    elif stat == "diff":
        lb = jnp.where(full, jnp.maximum(0, lo_a - hi_b), zero)
        ub = jnp.where(full,
                       jnp.minimum(jnp.minimum(hi_a, overlap),
                                   cell_area - lo_b),
                       jnp.minimum(hi_a, overlap))
    else:
        raise ValueError(f"unknown pair stat {stat!r}")
    return lb.sum(axis=(1, 2)), ub.sum(axis=(1, 2))


@dataclasses.dataclass(frozen=True)
class BinOp(Node):
    op: str
    left: Node
    right: Node

    def cp_terms(self):
        return self.left.cp_terms() + self.right.cp_terms()


# ---------------------------------------------------------------------------
# Comparison semantics (shared by predicates and the engine's filter path)
# ---------------------------------------------------------------------------

_CMP_EXACT = {
    "<": np.less, "<=": np.less_equal,
    ">": np.greater, ">=": np.greater_equal,
}


def cmp_exact(op: str, values, threshold):
    """Exact truth of ``values op threshold`` (vectorized)."""
    return _CMP_EXACT[op](values, threshold)


def cmp_decide(op: str, lb, ub, threshold):
    """Sound three-valued decision of ``exact op threshold`` from bounds.

    Returns ``(accept, reject)`` boolean arrays: *accept* iff the comparison
    must hold for every exact ∈ [lb, ub], *reject* iff it cannot hold;
    neither → unknown (verification required).
    """
    if op == "<":
        return ub < threshold, lb >= threshold
    if op == "<=":
        return ub <= threshold, lb > threshold
    if op == ">":
        return lb > threshold, ub <= threshold
    if op == ">=":
        return lb >= threshold, ub < threshold
    raise ValueError(f"bad comparison {op!r}")


# ---------------------------------------------------------------------------
# Boolean predicate trees (the query-plan IR's WHERE clause)
# ---------------------------------------------------------------------------


class Pred:
    """Boolean predicate tree over value expressions.

    Two evaluation modes mirror :class:`Node`'s:

    * :meth:`decide` — **three-valued** bounds evaluation.  Each subtree maps
      its children's (accept, reject) pairs to its own, so conjunctions and
      disjunctions of CP predicates still prune from CHI bounds alone:

          Cmp:  sound interval comparison (``cmp_decide``)
          And:  accept = a₁ ∧ a₂,  reject = r₁ ∨ r₂
          Or:   accept = a₁ ∨ a₂,  reject = r₁ ∧ r₂
          Not:  accept = r,        reject = a

      Soundness invariant: accept ⇒ exact-true, reject ⇒ exact-false, for
      every assignment of exact values inside the children's bounds.
    * :meth:`exact` / :meth:`exact_with_counts` — truth against loaded mask
      bytes (the verification path / the scheduler's fused-counts path).
    """

    def __and__(self, other):
        return And(self, other)

    def __or__(self, other):
        return Or(self, other)

    def __invert__(self):
        return Not(self)

    def value_exprs(self) -> list:
        """Distinct value expressions (Cmp left-hand sides) in tree order."""
        out: list = []
        for e in self._value_exprs():
            if e not in out:
                out.append(e)
        return out

    def _value_exprs(self):
        return []

    def cp_terms(self) -> list:
        return [t for e in self._value_exprs() for t in e.cp_terms()]

    def decide(self, bounds_of, ctx):
        """(accept, reject) bool arrays; ``bounds_of(expr) -> (lb, ub)``."""
        raise NotImplementedError

    def exact(self, ctx, idx: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def exact_with_counts(self, ctx, idx: np.ndarray, counts: dict) -> np.ndarray:
        """Exact truth when every CP term's count is precomputed (fused)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Cmp(Pred):
    """Leaf comparison ``expr op threshold`` with op ∈ {<, <=, >, >=}."""

    expr: Node
    op: str
    threshold: float

    def __post_init__(self):
        if self.op not in _CMP_EXACT:
            raise ValueError(f"bad comparison {self.op!r}")

    def _value_exprs(self):
        return [self.expr]

    def decide(self, bounds_of, ctx):
        lb, ub = bounds_of(self.expr)
        return cmp_decide(self.op, lb, ub, self.threshold)

    def exact(self, ctx, idx):
        return cmp_exact(self.op, ctx.exact(self.expr, idx), self.threshold)

    def exact_with_counts(self, ctx, idx, counts):
        vals = eval_with_counts(ctx, self.expr, idx, counts)
        return cmp_exact(self.op, vals, self.threshold)


@dataclasses.dataclass(frozen=True)
class TypeIn(Pred):
    """``mask_type IN (...)`` as a composable leaf (never unknown)."""

    types: tuple

    def decide(self, bounds_of, ctx):
        m = self._match(ctx, None)
        return m, ~m

    def _match(self, ctx, idx):
        if not isinstance(ctx, MaskEvalContext):
            raise TypeError("mask_type IN is a per-mask predicate; it cannot "
                            "appear in a grouped (MASK_AGG) query")
        if idx is None:
            idx = np.arange(len(ctx.positions))
        types = ctx.store.meta["mask_type"][ctx.positions[idx]]
        return np.isin(types, np.asarray(self.types))

    def exact(self, ctx, idx):
        return self._match(ctx, idx)

    def exact_with_counts(self, ctx, idx, counts):
        return self._match(ctx, idx)


@dataclasses.dataclass(frozen=True)
class And(Pred):
    left: Pred
    right: Pred

    def _value_exprs(self):
        return self.left._value_exprs() + self.right._value_exprs()

    def decide(self, bounds_of, ctx):
        la, lr = self.left.decide(bounds_of, ctx)
        ra, rr = self.right.decide(bounds_of, ctx)
        return la & ra, lr | rr

    def exact(self, ctx, idx):
        return self.left.exact(ctx, idx) & self.right.exact(ctx, idx)

    def exact_with_counts(self, ctx, idx, counts):
        return (self.left.exact_with_counts(ctx, idx, counts) &
                self.right.exact_with_counts(ctx, idx, counts))


@dataclasses.dataclass(frozen=True)
class Or(Pred):
    left: Pred
    right: Pred

    def _value_exprs(self):
        return self.left._value_exprs() + self.right._value_exprs()

    def decide(self, bounds_of, ctx):
        la, lr = self.left.decide(bounds_of, ctx)
        ra, rr = self.right.decide(bounds_of, ctx)
        return la | ra, lr & rr

    def exact(self, ctx, idx):
        return self.left.exact(ctx, idx) | self.right.exact(ctx, idx)

    def exact_with_counts(self, ctx, idx, counts):
        return (self.left.exact_with_counts(ctx, idx, counts) |
                self.right.exact_with_counts(ctx, idx, counts))


@dataclasses.dataclass(frozen=True)
class Not(Pred):
    child: Pred

    def _value_exprs(self):
        return self.child._value_exprs()

    def decide(self, bounds_of, ctx):
        a, r = self.child.decide(bounds_of, ctx)
        return r, a

    def exact(self, ctx, idx):
        return ~self.child.exact(ctx, idx)

    def exact_with_counts(self, ctx, idx, counts):
        return ~self.child.exact_with_counts(ctx, idx, counts)


def is_group_pred(pred: Pred) -> bool:
    return any(isinstance(t, AggCP) for t in pred.cp_terms())


# ---------------------------------------------------------------------------
# Interval arithmetic
# ---------------------------------------------------------------------------


def _interval_binop(op, llb, lub, rlb, rub):
    if op == "+":
        return llb + rlb, lub + rub
    if op == "-":
        return llb - rub, lub - rlb
    if op == "*":
        cands = np.stack([llb * rlb, llb * rub, lub * rlb, lub * rub])
        return cands.min(0), cands.max(0)
    if op == "/":
        # CP counts are >= 0; we only support non-negative denominators
        # (true for all paper queries).  den lb == 0 → upper bound +inf.
        with np.errstate(divide="ignore", invalid="ignore"):
            lb = np.where(rub > 0, llb / rub, 0.0)
            ub = np.where(rlb > 0, lub / rlb, np.where(lub > 0, _INF, 0.0))
        return lb, ub
    raise ValueError(f"unknown op {op}")


def _exact_binop(op: str, l, r):
    """Exact arithmetic over evaluated subtrees — one implementation of the
    guarded division (0/0 → 0) for every evaluation context."""
    if op == "/":
        with np.errstate(divide="ignore", invalid="ignore"):
            return np.where(r != 0, l / np.where(r == 0, 1, r), 0.0)
    return {"+": np.add, "-": np.subtract, "*": np.multiply}[op](l, r)


# ---------------------------------------------------------------------------
# Per-mask evaluation
# ---------------------------------------------------------------------------


class MaskEvalContext:
    """Binds an expression to a store partition + candidate row positions.

    ``partial_rows``: verification for single-CP expressions loads only each
    mask's ROI row-span (store.load_rows) — a beyond-paper I/O optimization;
    disabled automatically when the expression needs full masks or the
    store's cross-query cache is active (full masks are what's shared).
    """

    def __init__(self, store, positions: np.ndarray,
                 provided_rois: Optional[np.ndarray] = None,
                 partial_rows: bool = True):
        self.store = store
        self.cfg = store.cfg
        self.positions = np.asarray(positions, dtype=np.int64)
        self.provided_rois = provided_rois
        self.partial_rows = partial_rows
        # Optional ExecBackend (core/backend.py) routing physical leaves;
        # None → the host paths below (set by engine._make_context).
        self.backend = None
        # Pyramid bound tier (DESIGN.md §13): None → the finest grid.  Set
        # on ladder subcontexts by the optimizer so every backend's CP-leaf
        # primitive reads the matching coarse CHI tier.
        self.tier: Optional[int] = None
        self._loaded: Optional[np.ndarray] = None  # aligned with positions
        self._rows: list = []
        self._rows_used = 0

    def resolve_rois(self, roi, store_positions: np.ndarray) -> np.ndarray:
        """Public ROI resolution for arbitrary store row positions — used by
        the service scheduler to build fused cp_count_multi descriptor rows."""
        return _as_rois(roi, store_positions, self.provided_rois, self.cfg)

    # bytes ----------------------------------------------------------------
    def masks_for(self, idx: np.ndarray) -> np.ndarray:
        """Load (and cache) mask bytes for candidate indices ``idx``."""
        if self._loaded is None:
            self._loaded = np.full((len(self.positions),), -1, dtype=np.int64)
        missing = idx[self._loaded[idx] < 0]
        if len(missing):
            new = self.store.load(self.positions[missing])
            self._loaded[missing] = self._rows_used + np.arange(len(missing))
            self._rows.append(new)             # amortized growth (no O(n²))
            self._rows_used += len(missing)
        if len(self._rows) > 1:
            self._rows = [np.concatenate(self._rows, axis=0)]
        return self._rows[0][self._loaded[idx]]

    def _can_partial(self, node) -> bool:
        return (self.partial_rows and self._loaded is None and
                not self.store.cache_enabled and
                len(node.cp_terms()) <= 1)

    # bounds -----------------------------------------------------------------
    def bounds(self, node: Node, cp_leaf=None):
        """(lb, ub) float64 arrays over all candidate positions.

        ``cp_leaf(ctx, cp_node) -> (lb, ub)`` optionally overrides the
        CP-leaf bounds primitive (an execution backend's device/mesh CHI
        pass); the interval arithmetic over the tree stays shared, so every
        backend prunes with identical semantics."""
        n = len(self.positions)
        if isinstance(node, Const):
            v = np.full(n, node.value)
            return v.copy(), v.copy()
        if isinstance(node, RoiArea):
            rois = _as_rois(node.roi, self.positions, self.provided_rois, self.cfg)
            a = cp_lib.roi_area(rois).astype(np.float64)
            return a.copy(), a.copy()
        if isinstance(node, CP):
            if cp_leaf is not None:
                return cp_leaf(self, node)
            return self._chi_cp_bounds(node)
        if isinstance(node, BinOp):
            llb, lub = self.bounds(node.left, cp_leaf)
            rlb, rub = self.bounds(node.right, cp_leaf)
            return _interval_binop(node.op, llb, lub, rlb, rub)
        raise TypeError(f"node {node} not valid in a per-mask expression")

    def _chi_cp_bounds(self, node: CP):
        """Host CP-leaf bounds: CHI gather over the store's index at this
        context's bound tier (the finest grid unless a refinement-ladder
        subcontext pinned a coarser one)."""
        rois = _as_rois(node.roi, self.positions, self.provided_rois, self.cfg)
        g = self.tier
        if g is None or g == self.cfg.grid:
            cfg, table = self.cfg, self.store.chi_table
        else:
            cfg, table = self.cfg.for_grid(g), self.store.chi_tier_table(g)
        table = table[jnp.asarray(self.positions)]
        lb, ub = chi_lib.chi_bounds(table, cfg, rois, node.lv, node.uv)
        return np.asarray(lb, np.float64), np.asarray(ub, np.float64)

    # exact ------------------------------------------------------------------
    def exact(self, node: Node, idx: np.ndarray) -> np.ndarray:
        """Exact value for candidate indices ``idx`` (loads mask bytes)."""
        self._use_partial = self._can_partial(node)
        return self._exact_node(node, idx)

    def _cp_partial(self, node: CP, idx: np.ndarray) -> np.ndarray:
        """Exact CP reading only each mask's ROI row span from disk."""
        rois = _as_rois(node.roi, self.positions[idx], self.provided_rois,
                        self.cfg)
        spans = rois[:, [0, 2]]
        buf, heights = self.store.load_rows(self.positions[idx], spans)
        local = np.stack([np.zeros(len(idx), np.int64), rois[:, 1],
                          heights.astype(np.int64), rois[:, 3]], axis=1)
        if getattr(self.store, "packed", False):
            # buf rows are uint32 words; column coords are unchanged (the
            # packed layout is per-row, so a row span packs identically).
            counts = kops.cp_count_packed(
                jnp.asarray(buf), jnp.asarray(local, jnp.int32),
                jnp.asarray(node.lv, jnp.float32),
                jnp.asarray(min(node.uv, 3.4e38), jnp.float32))
        else:
            counts = kops.cp_count(
                jnp.asarray(buf), jnp.asarray(local, jnp.int32),
                jnp.asarray(node.lv, buf.dtype),
                jnp.asarray(min(node.uv, 3.4e38), buf.dtype))
        return np.asarray(counts, np.float64)

    def _eval_tree(self, node: Node, idx: np.ndarray, cp_eval) -> np.ndarray:
        """Shared exact-evaluation walker.  CP leaves delegate to ``cp_eval``
        (loading + kernel here; precomputed fused counts in the scheduler),
        so both paths share one set of expression semantics — notably the
        guarded division."""
        if isinstance(node, Const):
            return np.full(len(idx), node.value)
        if isinstance(node, RoiArea):
            rois = _as_rois(node.roi, self.positions[idx], self.provided_rois,
                            self.cfg)
            return cp_lib.roi_area(rois).astype(np.float64)
        if isinstance(node, CP):
            return cp_eval(node, idx)
        if isinstance(node, BinOp):
            return _exact_binop(node.op,
                                self._eval_tree(node.left, idx, cp_eval),
                                self._eval_tree(node.right, idx, cp_eval))
        raise TypeError(f"node {node} not valid in a per-mask expression")

    def _cp_exact(self, node: CP, idx: np.ndarray) -> np.ndarray:
        if self._use_partial:
            return self._cp_partial(node, idx)
        masks = self.masks_for(idx)
        rois = _as_rois(node.roi, self.positions[idx], self.provided_rois,
                        self.cfg)
        # verification hot path → Pallas cp_count on TPU, jnp ref on CPU
        if getattr(self.store, "packed", False):
            counts = kops.cp_count_packed(
                jnp.asarray(masks), jnp.asarray(rois),
                jnp.asarray(node.lv, jnp.float32),
                jnp.asarray(min(node.uv, 3.4e38), jnp.float32))
        else:
            counts = kops.cp_count(
                jnp.asarray(masks), jnp.asarray(rois),
                jnp.asarray(node.lv, masks.dtype),
                jnp.asarray(min(node.uv, 3.4e38), masks.dtype))
        return np.asarray(counts, np.float64)

    def _exact_node(self, node: Node, idx: np.ndarray) -> np.ndarray:
        return self._eval_tree(node, idx, self._cp_exact)


def eval_with_counts(ctx: "MaskEvalContext", node: Node, idx: np.ndarray,
                     counts: dict) -> np.ndarray:
    """Exact per-mask expression value when every CP term's count was already
    computed by a fused multi-query kernel pass (the service scheduler's
    ``cp_count_multi`` route).  ``counts`` maps CP nodes (hashable frozen
    dataclasses) to ``(len(idx),)`` count arrays; everything else runs
    through the same walker as self-verification."""
    return ctx._eval_tree(node, idx,
                          lambda n, i: np.asarray(counts[n], np.float64))


def tier_context(ctx: "MaskEvalContext", idx: np.ndarray,
                 tier: Optional[int]) -> "MaskEvalContext":
    """A shallow subcontext over candidate indices ``idx`` of ``ctx`` with
    the bound tier pinned — what the refinement ladder hands each rung's
    bounds pass.  ``provided_rois`` stays whole-store-indexed (ROIs resolve
    by store position), the backend rides along, and ``tier=None`` means
    the finest grid, so a final rung is bit-identical to the classic path."""
    sub = MaskEvalContext(ctx.store, ctx.positions[np.asarray(idx)],
                          ctx.provided_rois, partial_rows=ctx.partial_rows)
    sub.backend = ctx.backend
    sub.tier = tier
    return sub


# ---------------------------------------------------------------------------
# Per-group (MASK_AGG) evaluation
# ---------------------------------------------------------------------------


class GroupEvalContext:
    """Binds an AggCP expression to image groups.

    ``group_positions``: (n_groups, group_size) row positions — one image's
    masks per row (the paper's ``GROUP BY image_id`` with
    ``mask_type IN (...)``).
    """

    def __init__(self, store, group_positions: np.ndarray,
                 image_ids: np.ndarray,
                 provided_rois: Optional[np.ndarray] = None):
        self.store = store
        self.cfg = store.cfg
        self.groups = np.asarray(group_positions, dtype=np.int64)
        self.image_ids = np.asarray(image_ids)
        self.provided_rois = provided_rois
        self._ctx = MaskEvalContext(store, self.groups.reshape(-1), provided_rois)
        # Optional ExecBackend routing MASK_AGG verification (None → host).
        self.backend = None

    def resolve_group_rois(self, roi, gidx: np.ndarray) -> np.ndarray:
        """Per-group ROI resolution (one ROI per image group — members
        share it), for backends building fused mask_agg kernel rows."""
        return _as_rois(roi, self.groups[np.asarray(gidx), 0],
                        self.provided_rois, self.cfg)

    def _member_bounds(self, node: AggCP, cp_leaf=None):
        """Per-member CP bounds for the thresholded mask (value > thresh)."""
        member = CP(node.roi, node.thresh, float("inf"))
        lb, ub = self._ctx.bounds(member, cp_leaf)
        g, s = self.groups.shape
        return lb.reshape(g, s), ub.reshape(g, s)

    def _areas(self, node: AggCP):
        rois = _as_rois(node.roi, self.groups[:, 0], self.provided_rois, self.cfg)
        return cp_lib.roi_area(rois).astype(np.float64)

    def bounds(self, node: Node, cp_leaf=None):
        if isinstance(node, Const):
            v = np.full(len(self.groups), node.value)
            return v.copy(), v.copy()
        if isinstance(node, AggCP):
            mlb, mub = self._member_bounds(node, cp_leaf)
            area = self._areas(node)
            n = self.groups.shape[1]
            if node.agg == "intersect":
                ub = mub.min(axis=1)
                lb = np.maximum(0.0, mlb.sum(axis=1) - (n - 1) * area)
            elif node.agg == "union":
                lb = mlb.max(axis=1)
                ub = np.minimum(area, mub.sum(axis=1))
            else:
                raise ValueError(f"unknown agg {node.agg}")
            return lb.astype(np.float64), ub.astype(np.float64)
        if isinstance(node, BinOp):
            llb, lub = self.bounds(node.left, cp_leaf)
            rlb, rub = self.bounds(node.right, cp_leaf)
            return _interval_binop(node.op, llb, lub, rlb, rub)
        raise TypeError(f"node {node} not valid in a group expression")

    def exact(self, node: Node, gidx: np.ndarray) -> np.ndarray:
        if isinstance(node, Const):
            return np.full(len(gidx), node.value)
        if isinstance(node, AggCP):
            backend = self.backend
            if backend is None:
                from .backend import host_backend
                backend = host_backend()
            return backend.mask_agg_counts(self, node, gidx)
        if isinstance(node, BinOp):
            return _exact_binop(node.op, self.exact(node.left, gidx),
                                self.exact(node.right, gidx))
        raise TypeError(f"node {node} not valid in a group expression")


def is_group_expr(node: Node) -> bool:
    return any(isinstance(t, AggCP) for t in node.cp_terms())


# ---------------------------------------------------------------------------
# Per-pair (dual-mask) evaluation
# ---------------------------------------------------------------------------


class PairEvalContext:
    """Binds pair expressions to per-image (role_a, role_b) mask rows.

    ``pos_a``/``pos_b`` are aligned ``(n,)`` store row positions — image i's
    role-A and role-B masks.  The pair's ROI resolves from the **role-A
    row** (``"provided"`` per-mask boxes, a constant rectangle, or the full
    mask) and applies to both roles, so intersection/union/difference are
    counted over one region per image.

    Pair bounds combine both roles' CHI rows cell-by-cell.  The host path
    gathers the rows and runs :func:`pair_cell_bounds` in numpy; the
    device/mesh backends run the identical math jit'd over their resident
    CHI (:func:`pair_cell_bounds_jnp` via ``pair_leaf``).  Cell counts and
    sums are integral either way, so the three backends share one pruning
    semantics bit for bit; verification (the dual-mask kernel pass) is
    backend-physical as before.
    """

    def __init__(self, store, pos_a: np.ndarray, pos_b: np.ndarray,
                 image_ids: np.ndarray, roles: tuple,
                 provided_rois: Optional[np.ndarray] = None):
        self.store = store
        self.cfg = store.cfg
        self.pos_a = np.asarray(pos_a, dtype=np.int64)
        self.pos_b = np.asarray(pos_b, dtype=np.int64)
        self.image_ids = np.asarray(image_ids)
        self.roles = tuple(roles)
        self.provided_rois = provided_rois
        # Optional ExecBackend routing pair verification (None → host).
        self.backend = None
        self._cells_memo: dict = {}    # (role, thresh) → (lo, hi) cells

    def resolve_pair_rois(self, roi, pos_a_rows: np.ndarray) -> np.ndarray:
        """Per-pair ROI resolution at explicit role-A store rows — used by
        the service scheduler to build fused pair-pass descriptor rows."""
        return _as_rois(roi, pos_a_rows, self.provided_rois, self.cfg)

    def pair_rois(self, roi, idx: Optional[np.ndarray] = None) -> np.ndarray:
        pos = self.pos_a if idx is None else self.pos_a[np.asarray(idx)]
        return _as_rois(roi, pos, self.provided_rois, self.cfg)

    def _role_tables(self, which: str) -> np.ndarray:
        """One role's CHI rows as host numpy.  Deliberately *not* memoized:
        sessions hold their run (and thus this context) alive across
        pages, and only the much smaller per-cell counts are needed after
        the bounds pass — retaining full (n, G+1, G+1, NB+1) row copies
        per role would multiply the store's CHI footprint per open
        session."""
        pos = self.pos_a if which == "a" else self.pos_b
        store = self.store
        if hasattr(store, "chi_host"):
            return store.chi_host(pos)
        return np.asarray(store.chi_table)[pos]

    def _role_cells(self, which: str, thresh: float):
        """(lo, hi) per-cell thresholded counts for one role, memoized per
        (role, threshold) — IoU's inter and union terms share them."""
        key = (which, float(thresh))
        if key not in self._cells_memo:
            k_in, k_out = _threshold_ks(self.cfg, thresh)
            tables = self._role_tables(which)
            self._cells_memo[key] = (_cell_counts(tables, k_in),
                                     _cell_counts(tables, k_out))
        return self._cells_memo[key]

    def bounds(self, node: Node, cp_leaf=None, pair_leaf=None):
        """(lb, ub) float64 over all candidate pairs.  ``cp_leaf`` is part
        of the shared context signature but unused.  ``pair_leaf(pctx,
        term) -> (lb, ub)`` optionally overrides the PairTerm cell-combine
        primitive — the device/mesh backends run the same cell math jit'd
        over their resident CHI (:func:`pair_cell_bounds_jnp`), so the pair
        filter phase leaves the host while pruning stays bit-identical; the
        host path below gathers both roles' CHI rows and combines them
        cell-by-cell in numpy."""
        n = len(self.pos_a)
        if isinstance(node, Const):
            v = np.full(n, node.value)
            return v.copy(), v.copy()
        if isinstance(node, RoiArea):
            a = cp_lib.roi_area(self.pair_rois(node.roi)).astype(np.float64)
            return a.copy(), a.copy()
        if isinstance(node, PairTerm):
            if pair_leaf is not None:
                return pair_leaf(self, node)
            lo_a, hi_a = self._role_cells("a", node.ta)
            lo_b, hi_b = self._role_cells("b", node.tb)
            return pair_cell_bounds(self.cfg, node.stat, lo_a, hi_a,
                                    lo_b, hi_b, self.pair_rois(node.roi))
        if isinstance(node, BinOp):
            llb, lub = self.bounds(node.left, cp_leaf, pair_leaf)
            rlb, rub = self.bounds(node.right, cp_leaf, pair_leaf)
            return _interval_binop(node.op, llb, lub, rlb, rub)
        raise TypeError(f"node {node} not valid in a pair expression")

    def _eval_tree(self, node: Node, idx: np.ndarray, leaf_eval) -> np.ndarray:
        """Shared exact-evaluation walker (the pair analogue of
        :meth:`MaskEvalContext._eval_tree`): PairTerm leaves delegate to
        ``leaf_eval`` — precomputed counts when the scheduler fuses, a
        backend pair pass in self-verification."""
        if isinstance(node, Const):
            return np.full(len(idx), node.value)
        if isinstance(node, RoiArea):
            return cp_lib.roi_area(self.pair_rois(node.roi, idx)).astype(
                np.float64)
        if isinstance(node, PairTerm):
            return leaf_eval(node, idx)
        if isinstance(node, BinOp):
            return _exact_binop(node.op,
                                self._eval_tree(node.left, idx, leaf_eval),
                                self._eval_tree(node.right, idx, leaf_eval))
        raise TypeError(f"node {node} not valid in a pair expression")

    def exact(self, node: Node, idx: np.ndarray) -> np.ndarray:
        """Exact value for candidate indices ``idx`` — every distinct pair
        spec in the node is answered by one fused dual-mask kernel pass."""
        idx = np.asarray(idx)
        if len(idx) == 0:
            return np.empty(0, np.float64)
        terms = {t for t in node.cp_terms() if isinstance(t, PairTerm)}
        backend = self.backend
        if backend is None:
            from .backend import host_backend
            backend = host_backend()
        counts = backend.pair_verify_counts(self, idx, terms)
        return self._eval_tree(node, idx,
                               lambda t, i: np.asarray(counts[t], np.float64))


def is_pair_expr(node: Node) -> bool:
    return any(isinstance(t, PairTerm) for t in node.cp_terms())


def pair_roles_of(exprs) -> Optional[tuple]:
    """The single (role_a, role_b) mask-type pair the expressions use, or
    ``None`` when they contain no pair terms.  One plan evaluates against
    one role pairing; mixing pairings raises."""
    roles = {(t.role_a, t.role_b) for e in exprs for t in e.cp_terms()
             if isinstance(t, PairTerm)}
    if not roles:
        return None
    if len(roles) > 1:
        raise ValueError("all pair terms in one plan must share a single "
                         f"(role_a, role_b) mask-type pair, got "
                         f"{sorted(roles)}")
    return roles.pop()
