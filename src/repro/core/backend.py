"""Pluggable execution backends — one physical filter–verification layer.

The engine's run objects (:mod:`.engine`) are *drivers*: they own the
frontier bookkeeping (what is decided, what is pending, when a ranking is
final) but delegate every physical operation to an :class:`ExecBackend`,
the way SeeSaw routes one interactive query API over interchangeable
vector backends.  Four primitives cover every plan the IR can express:

* ``bounds(ctx, expr)``            — CHI-derived (lb, ub) for every
                                     candidate of a value expression (the
                                     filter phase; no mask bytes touched).
* ``verify_counts(ctx, batch, terms)`` — exact per-CP-term pixel counts for
                                     one verification batch (the
                                     verification phase).
* ``topk_candidates(lb, ub, k, …)`` — the ranking frontier: which
                                     candidates can still reach the top-k.
* ``mask_agg_counts(gctx, node, gidx)`` — fused thresholded
                                     intersection/union counts for MASK_AGG
                                     group verification.

plus ``fused_counts`` — the service scheduler's cross-query
``cp_count_multi`` pass, run on whichever backend owns the store — and
the dual-mask pair primitives (DESIGN.md §9): ``fused_pair_counts``
(Q pair descriptors over a batch of per-image mask pairs → (Q, 3, B)
inter/union/diff counts) with the shared driver ``pair_verify_counts``
(pair bounds stay host-side: the cell decomposition needs per-cell CHI
counts, and sharing that code path keeps pruning bit-identical).

Three implementations:

* :class:`HostBackend`   — the NumPy/``MaskEvalContext`` paths extracted
                           from the engine, behavior-preserving (partial
                           ROI-row loads, shared-load cache, I/O metering).
* :class:`DeviceBackend` — the store's mask bytes and CHI table pinned
                           resident in device memory; bounds *and*
                           verification are jit-compiled over the Pallas
                           kernels, so the filter phase leaves the host.
* :class:`MeshBackend`   — :mod:`.distributed`'s step functions over
                           ``shard_map``: rows shard over every mesh axis,
                           the top-k frontier is one ``all_gather``
                           collective, and verification/MASK_AGG batches
                           run sharded.

Equivalence contract (property-tested in
``tests/test_backend_equivalence.py``): all three backends return
identical ids/scores and identical ``n_verified`` accounting for any plan.
Bounds interval arithmetic stays on the host in float64 for every backend
(only the CP leaf differs, and it is integral), and the device/mesh top-k
collectives return the τ *row id* rather than a float32 τ value, so the
frontier comparison happens at full host precision everywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from ..obs.metrics import REGISTRY as _REG
from .distributed import (_bounds_from_corners, device_resolve,
                          make_chi_bounds_step, make_cp_multi_packed_step,
                          make_cp_multi_step, make_fused_verify_step,
                          make_mask_agg_packed_step, make_mask_agg_step,
                          make_mesh, make_pair_cells_step,
                          make_pair_counts_packed_step,
                          make_pair_counts_step, make_topk_select_step,
                          make_verify_packed_step, make_verify_step,
                          value_ks)
from .exprs import _threshold_ks, cell_counts_jnp, pair_cell_bounds_jnp

F32_MAX = 3.4e38  # finite stand-in for +inf in float32 kernel compares
_F32_MAX = F32_MAX

_BACKEND_RESOLUTIONS = _REG.counter(
    "masksearch_backend_resolutions_total",
    "get_backend() resolutions by resolved backend", ("backend",))
_BACKEND_BUILDS = _REG.counter(
    "masksearch_backend_constructions_total",
    "Named backend instances constructed (the resident mask/CHI upload "
    "happens here)", ("backend",))
_BACKEND_SYNCS = _REG.counter(
    "masksearch_backend_syncs_total",
    "Epoch re-pins of resident backend state after store mutations",
    ("backend",))


def spec_arrays(specs, dtype=np.float32):
    """Stack fused-pass descriptors ``(rois, lv, uv)`` into kernel inputs,
    clamping +inf upper values to the float32-safe ceiling — the one
    canonical layout shared by every backend and the service scheduler."""
    rois_q = np.stack([s[0] for s in specs]).astype(np.int32)
    lvs = np.asarray([s[1] for s in specs], dtype)
    uvs = np.asarray([min(s[2], F32_MAX) for s in specs], dtype)
    return rois_q, lvs, uvs


def is_packed(store) -> bool:
    """Whether a store serves the bitpacked binary-mask tier (DESIGN.md §12).

    ``getattr`` so snapshots, stores predating the tier, and test doubles
    all read as float."""
    return bool(getattr(store, "packed", False))


def chi_verdicts(terms, batch: np.ndarray, bounds_of):
    """Assemble the megakernel's CHI-verdict inputs from memoized bounds.

    ``bounds_of(term) -> (lb, ub) | None`` is a *memo-only* getter: a term
    whose filter-phase bounds were never computed returns None and is simply
    treated as undecided everywhere — always correct, never an extra bounds
    pass.  Returns ``decided`` (Q, B) int32 0/1 and ``lb`` (Q, B) int32
    aligned with ``terms`` × ``batch``."""
    q, b = len(terms), len(batch)
    decided = np.zeros((q, b), np.int32)
    lb_out = np.zeros((q, b), np.int32)
    for i, t in enumerate(terms):
        bnd = bounds_of(t) if bounds_of is not None else None
        if bnd is None:
            continue
        tlb = np.asarray(bnd[0])[batch]
        tub = np.asarray(bnd[1])[batch]
        eq = tlb == tub
        decided[i] = eq
        lb_out[i] = np.where(eq, tlb, 0)
    return decided, lb_out


class ExecBackend:
    """Protocol for the physical layer under the engine's run drivers."""

    name = "abstract"

    def sync(self) -> None:
        """Refresh any store-resident state (pinned masks, CHI tables) to
        the store's current epoch.  Called by :func:`get_backend` on every
        resolution, so a backend instance cached across mutations never
        serves pre-epoch residency.  Host is stateless — no-op."""

    def bounds(self, ctx, expr):
        """(lb, ub) float64 arrays over ``ctx``'s candidates for ``expr``."""
        raise NotImplementedError

    def verify_counts(self, ctx, batch: np.ndarray, terms) -> dict:
        """Exact counts for one verification batch: CP term → float64
        array aligned with ``batch`` (candidate indices into ``ctx``)."""
        raise NotImplementedError

    def topk_candidates(self, lb, ub, k: int, desc: bool,
                        definite: np.ndarray,
                        possible: np.ndarray) -> np.ndarray:
        """The static pruning frontier: candidates whose optimistic bound
        beats the k-th best pessimistic bound among ``definite``
        (definitely-qualifying) candidates.  Returns an ``alive`` bool
        array ⊆ ``possible``; when fewer than k are definite nothing can
        be pruned and ``possible`` is returned unchanged."""
        raise NotImplementedError

    def mask_agg_counts(self, gctx, node, gidx: np.ndarray) -> np.ndarray:
        """Exact MASK_AGG counts (thresholded intersect/union inside the
        ROI) for group indices ``gidx`` of a :class:`GroupEvalContext`."""
        raise NotImplementedError

    def fused_verify_counts(self, ctx, batch: np.ndarray, terms,
                            bounds_of=None) -> dict:
        """The bounds+verify megakernel route (packed stores, DESIGN.md
        §12): one launch answers *every* CP descriptor of a verification
        batch — CHI-decided (term, mask) entries (memoized lb == ub) pass
        their bound straight through, the undecided remainder is counted
        from the packed words.  ``bounds_of(term) -> (lb, ub) | None`` is a
        memo-only getter over the run's filter-phase bounds; None →
        undecided (always correct).  Float stores fall back to the classic
        per-term :meth:`verify_counts` path, so drivers can call this
        unconditionally."""
        terms = list(terms)
        if not is_packed(getattr(ctx, "store", None)):
            return self.verify_counts(ctx, batch, terms)
        batch = np.asarray(batch)
        pos = ctx.positions[batch]
        rois_q, lvs, uvs = spec_arrays(
            [(ctx.resolve_rois(t.roi, pos), t.lv, t.uv) for t in terms])
        decided, lb = chi_verdicts(terms, batch, bounds_of)
        counts = self._fused_verify_batch(ctx, batch, pos, rois_q, lvs, uvs,
                                          decided, lb)
        return {t: np.asarray(counts[i], np.float64)
                for i, t in enumerate(terms)}

    def _fused_verify_batch(self, ctx, batch, pos, rois_q, lvs, uvs,
                            decided, lb) -> np.ndarray:
        """Physical megakernel dispatch: packed batch rows + assembled
        descriptors/verdicts → (Q, B) int32 exact counts."""
        raise NotImplementedError

    def fused_counts(self, store, positions: np.ndarray,
                     specs) -> np.ndarray:
        """The scheduler's fused pass: Q ``(rois, lv, uv)`` descriptors
        over the masks at ``positions`` → (Q, B) counts from one pass
        over the bytes."""
        raise NotImplementedError

    PAIR_STAT_ROW = {"inter": 0, "union": 1, "diff": 2}

    def fused_pair_counts(self, store, pos_a: np.ndarray, pos_b: np.ndarray,
                          specs) -> np.ndarray:
        """Dual-mask pass: Q ``(rois, ta, tb)`` descriptors over the
        per-image mask pairs ``(pos_a[i], pos_b[i])`` → (Q, 3, B) counts —
        rows indexed by :attr:`PAIR_STAT_ROW` (inter / union / diff=|A∖B|).
        Each pair's bytes are touched once per descriptor batch; all three
        stats come from that one pass (DESIGN.md §9)."""
        raise NotImplementedError

    def pair_verify_counts(self, pctx, batch: np.ndarray, terms) -> dict:
        """Exact pair-term counts for one verification batch: pair term →
        float64 array aligned with ``batch`` (candidate indices into
        ``pctx``).  Terms sharing a (ta, tb, roi) pair spec — e.g. IoU's
        intersection and union — are answered by a single fused kernel
        pass.  Shared driver; the physical pass is
        :meth:`fused_pair_counts`."""
        terms = list(terms)
        batch = np.asarray(batch)
        spec_ix: dict = {}
        specs: list = []
        for t in terms:
            key = (t.ta, t.tb, t.roi)
            if key not in spec_ix:
                spec_ix[key] = len(specs)
                specs.append((pctx.pair_rois(t.roi, batch), t.ta, t.tb))
        counts = self.fused_pair_counts(pctx.store, pctx.pos_a[batch],
                                        pctx.pos_b[batch], specs)
        return {t: np.asarray(counts[spec_ix[(t.ta, t.tb, t.roi)],
                                     self.PAIR_STAT_ROW[t.stat]], np.float64)
                for t in terms}


# ---------------------------------------------------------------------------
# Host — the extracted NumPy / MaskEvalContext physical layer
# ---------------------------------------------------------------------------


class HostBackend(ExecBackend):
    """The original physical layer: bounds through the store's CHI gather,
    verification through metered ``store.load`` (partial ROI-row loads,
    shared-load cache) + the ``cp_count`` kernel, frontiers in NumPy."""

    name = "host"

    def bounds(self, ctx, expr):
        return ctx.bounds(expr)

    def verify_counts(self, ctx, batch, terms):
        # One ctx.exact per *distinct* term: masks_for caches the load, so
        # a predicate and a ranking sharing an expression share its bytes.
        return {t: ctx.exact(t, batch) for t in terms}

    def topk_candidates(self, lb, ub, k, desc, definite, possible):
        if desc:
            dvals = lb[definite]
            if len(dvals) >= k:
                tau = np.partition(dvals, -k)[-k]
                return possible & (ub >= tau)
            return possible.copy()
        dvals = ub[definite]
        if len(dvals) >= k:
            tau = np.partition(dvals, k - 1)[k - 1]
            return possible & (lb <= tau)
        return possible.copy()

    def mask_agg_counts(self, gctx, node, gidx):
        gidx = np.asarray(gidx)
        s = gctx.groups.shape[1]
        flat_idx = (gidx[:, None] * s + np.arange(s)[None, :]).reshape(-1)
        masks = gctx._ctx.masks_for(flat_idx)
        # row shape is (H, W) float or (H, words) packed — keep it as-is
        masks = masks.reshape((len(gidx), s) + masks.shape[1:])
        rois = gctx.resolve_group_rois(node.roi, gidx)
        # fused threshold+agg+count → Pallas mask_agg kernel on TPU
        if is_packed(gctx._ctx.store):
            inter, union = kops.mask_agg_counts_packed(
                jnp.asarray(masks), jnp.asarray(rois),
                jnp.asarray(node.thresh, jnp.float32))
        else:
            inter, union = kops.mask_agg_counts(
                jnp.asarray(masks), jnp.asarray(rois),
                jnp.asarray(node.thresh, masks.dtype))
        counts = inter if node.agg == "intersect" else union
        return np.asarray(counts, np.float64)

    def fused_counts(self, store, positions, specs):
        masks = store.load(positions)
        if is_packed(store):
            rois_q, lvs, uvs = spec_arrays(specs)
            return np.asarray(kops.cp_count_multi_packed(
                jnp.asarray(masks), jnp.asarray(rois_q),
                jnp.asarray(lvs), jnp.asarray(uvs)))
        rois_q, lvs, uvs = spec_arrays(specs, masks.dtype)
        return np.asarray(kops.cp_count_multi(
            jnp.asarray(masks), jnp.asarray(rois_q),
            jnp.asarray(lvs), jnp.asarray(uvs)))

    def fused_pair_counts(self, store, pos_a, pos_b, specs):
        # One metered load of the *union* of both roles' rows — a mask
        # shared by several pairs (or both roles) pays its bytes once.
        pos_a, pos_b = np.asarray(pos_a), np.asarray(pos_b)
        upos = np.unique(np.concatenate([pos_a, pos_b]))
        loaded = store.load(upos)
        a = jnp.asarray(loaded[np.searchsorted(upos, pos_a)])
        b = jnp.asarray(loaded[np.searchsorted(upos, pos_b)])
        packed = is_packed(store)
        kernel = kops.pair_counts_packed if packed else kops.pair_counts
        tdt = jnp.float32 if packed else a.dtype
        out = np.empty((len(specs), 3, len(pos_a)), np.int64)
        for qi, (rois, ta, tb) in enumerate(specs):
            trio = kernel(a, b, jnp.asarray(rois, jnp.int32),
                          jnp.asarray(ta, tdt), jnp.asarray(tb, tdt))
            for row, counts in enumerate(trio):
                out[qi, row] = np.asarray(counts)
        return out

    def _fused_verify_batch(self, ctx, batch, pos, rois_q, lvs, uvs,
                            decided, lb):
        # masks_for meters the load (in packed bytes) and shares rows with
        # any other term touching the same candidates.
        masks = ctx.masks_for(batch)
        return np.asarray(kops.fused_bounds_verify(
            jnp.asarray(masks), jnp.asarray(rois_q), jnp.asarray(lvs),
            jnp.asarray(uvs), jnp.asarray(decided), jnp.asarray(lb)))


# ---------------------------------------------------------------------------
# Device — single device, masks + CHI pinned resident in HBM
# ---------------------------------------------------------------------------


@jax.jit
def _device_cp_bounds(tables, pos, rois, rb, cb, ks):
    """CP-leaf bounds with the candidate gather, corner resolution and
    8-corner lookup all on device (the filter phase leaving the host).
    The tier is implicit in the operands — ``device_resolve`` derives the
    grid from ``rb``'s length — so one compilation serves each tier shape."""
    corners, area = device_resolve(rois, rb, cb)
    return _bounds_from_corners(tables[pos], corners, area,
                                ks[0], ks[1], ks[2], ks[3])


@functools.partial(jax.jit, static_argnames=("stat",))
def _device_pair_cells(tables, pos_a, pos_b, ks, rois, rb, cb, stat):
    """Pair-term cell-combine with both role gathers, the per-cell
    thresholded counts and the cell algebra all on device — the pair
    filter phase leaving the host like the CP leaf (DESIGN.md §13).
    ``ks`` holds [ka_in, ka_out, kb_in, kb_out] value-edge indices."""
    tab_a = tables[pos_a]
    tab_b = tables[pos_b]
    lo_a = cell_counts_jnp(tab_a, ks[0])
    hi_a = cell_counts_jnp(tab_a, ks[1])
    lo_b = cell_counts_jnp(tab_b, ks[2])
    hi_b = cell_counts_jnp(tab_b, ks[3])
    return pair_cell_bounds_jnp(stat, lo_a, hi_a, lo_b, hi_b, rois, rb, cb)


@jax.jit
def _device_multi_counts(masks, pos, rois_q, lvs, uvs):
    """Gather a verification batch from the resident mask array and answer
    Q CP descriptors in one fused kernel pass."""
    return kops.cp_count_multi(masks[pos], rois_q, lvs, uvs)


@functools.partial(jax.jit, static_argnames=("k",))
def _device_kth_index(pes, definite, k):
    masked = jnp.where(definite, pes, -jnp.inf)
    return jax.lax.top_k(masked, k)[1][k - 1]


@functools.partial(jax.jit, static_argnames=("s",))
def _device_group_counts(masks, flat_pos, rois, thresh, s):
    grp = masks[flat_pos]
    n = flat_pos.shape[0] // s
    grp = grp.reshape(n, s, masks.shape[1], masks.shape[2])
    return kops.mask_agg_counts(grp, rois, thresh)


@jax.jit
def _device_multi_counts_packed(packed, pos, rois_q, lvs, uvs):
    """Packed-tier sibling of :func:`_device_multi_counts`."""
    return kops.cp_count_multi_packed(packed[pos], rois_q, lvs, uvs)


@functools.partial(jax.jit, static_argnames=("s",))
def _device_group_counts_packed(packed, flat_pos, rois, thresh, s):
    grp = packed[flat_pos]
    n = flat_pos.shape[0] // s
    grp = grp.reshape(n, s, packed.shape[1], packed.shape[2])
    return kops.mask_agg_counts_packed(grp, rois, thresh)


@jax.jit
def _device_fused_verify(packed, pos, rois_q, lvs, uvs, decided, lb):
    """Gather a verification batch from the resident packed words and run
    the bounds+verify megakernel — one launch for the whole batch."""
    return kops.fused_bounds_verify(packed[pos], rois_q, lvs, uvs,
                                    decided, lb)


class _KthValueMixin:
    """Shared τ finalization: the device/mesh collectives select over
    *float32* scores and return the k-th best row's id; τ itself is then
    re-derived on the host in float64, so the frontier is bit-identical to
    HostBackend's ``np.partition`` path.

    The float32 cast is order-preserving but not injective: scores closer
    than one f32 ulp collapse into a tie class, and the collective's pick
    within that class is arbitrary — reading its float64 value directly
    could yield a τ *larger* than the true k-th value and over-prune.  So
    when the selected row's f32 score is shared, the exact τ is resolved
    from the (tiny) tie class at float64: it is the m-th largest member,
    where m = k − (#definite scores strictly above the class)."""

    def _alive_from_index(self, lb, ub, k, desc, definite, possible,
                          pes32, tau_idx):
        pes64 = lb if desc else -ub
        if tau_idx >= len(pes64):   # τ fell on a padded −inf row: no pruning
            return possible.copy()
        # Read τ's class through the same masked view the collective ranked
        # (non-definite rows are −inf there), not the raw score array.
        tau32 = pes32[tau_idx] if definite[tau_idx] else np.float32(-np.inf)
        tie = definite & (pes32 == tau32)
        n_tie = int(np.count_nonzero(tie))
        if n_tie == 0:              # masked −inf pick outside definite
            return possible.copy()
        if n_tie == 1:
            tau = pes64[np.nonzero(tie)[0][0]]
        else:
            m = k - int(np.count_nonzero(definite & (pes32 > tau32)))
            vals = pes64[tie]
            tau = np.partition(vals, len(vals) - m)[len(vals) - m]
        if desc:
            return possible & (ub >= tau)
        return possible & (lb <= -tau)


class DeviceBackend(_KthValueMixin, ExecBackend):
    """Mask bytes + CHI table pinned in device memory; bounds and
    verification jit-compiled over the Pallas kernels."""

    name = "device"

    def __init__(self, store):
        self.store = store
        self.cfg = store.cfg
        self._packed = is_packed(store)   # resident array is uint32 words
        self._masks = store.device_masks()
        self._tables = store.chi_table
        self._epoch = getattr(store, "epoch", 0)
        self._rb = jnp.asarray(self.cfg.row_bounds, jnp.int32)
        self._cb = jnp.asarray(self.cfg.col_bounds, jnp.int32)
        self._tier_bnds: dict = {}   # tier grid → (row_bounds, col_bounds)

    def sync(self):
        """Re-pin the resident mask/CHI arrays after a store mutation.  The
        store maintains its device caches incrementally (appends
        ``device_put`` only the new chunk, updates scatter, deletes
        gather), so this is a reference refresh, not a re-upload."""
        if self._epoch == getattr(self.store, "epoch", 0):
            return
        self._masks = self.store.device_masks()
        self._tables = self.store.chi_table
        self._epoch = self.store.epoch
        _BACKEND_SYNCS.labels(backend=self.name).inc()

    def bounds(self, ctx, expr):
        if hasattr(ctx, "pair_rois"):
            return ctx.bounds(expr, pair_leaf=self._pair_cells)
        return ctx.bounds(expr, cp_leaf=self._cp_bounds)

    def _tier_bounds(self, g: int):
        pair = self._tier_bnds.get(g)
        if pair is None:
            tcfg = self.cfg.for_grid(g)
            pair = (jnp.asarray(tcfg.row_bounds, jnp.int32),
                    jnp.asarray(tcfg.col_bounds, jnp.int32))
            self._tier_bnds[g] = pair
        return pair

    def _cp_bounds(self, mctx, node):
        rois = mctx.resolve_rois(node.roi, mctx.positions)
        g = getattr(mctx, "tier", None)
        if g is None or g == self.cfg.grid:
            cfg, tables, rb, cb = self.cfg, self._tables, self._rb, self._cb
        else:
            # coarse ladder rung: the store's device-resident tier table
            # (maintained incrementally across mutations) + tier boundaries
            cfg = self.cfg.for_grid(g)
            tables = self.store.chi_tier_table(g)
            rb, cb = self._tier_bounds(g)
        ks = value_ks(cfg, node.lv, node.uv)
        lb, ub = _device_cp_bounds(
            tables, jnp.asarray(mctx.positions),
            jnp.asarray(rois, jnp.int32), rb, cb,
            jnp.asarray(ks))
        return np.asarray(lb, np.float64), np.asarray(ub, np.float64)

    def _pair_cells(self, pctx, node):
        rois = pctx.pair_rois(node.roi)
        ka = _threshold_ks(self.cfg, node.ta)
        kb = _threshold_ks(self.cfg, node.tb)
        lb, ub = _device_pair_cells(
            self._tables, jnp.asarray(pctx.pos_a), jnp.asarray(pctx.pos_b),
            jnp.asarray(np.array([ka[0], ka[1], kb[0], kb[1]], np.int32)),
            jnp.asarray(rois, jnp.int32), self._rb, self._cb,
            stat=node.stat)
        return np.asarray(lb, np.float64), np.asarray(ub, np.float64)

    def verify_counts(self, ctx, batch, terms):
        terms = list(terms)
        pos = ctx.positions[batch]
        rois_q, lvs, uvs = spec_arrays(
            [(ctx.resolve_rois(t.roi, pos), t.lv, t.uv) for t in terms])
        multi = (_device_multi_counts_packed if self._packed
                 else _device_multi_counts)
        counts = np.asarray(multi(
            self._masks, jnp.asarray(pos), jnp.asarray(rois_q),
            jnp.asarray(lvs), jnp.asarray(uvs)))
        return {t: counts[i].astype(np.float64)
                for i, t in enumerate(terms)}

    def _fused_verify_batch(self, ctx, batch, pos, rois_q, lvs, uvs,
                            decided, lb):
        return np.asarray(_device_fused_verify(
            self._masks, jnp.asarray(np.asarray(pos)), jnp.asarray(rois_q),
            jnp.asarray(lvs), jnp.asarray(uvs), jnp.asarray(decided),
            jnp.asarray(lb)))

    def topk_candidates(self, lb, ub, k, desc, definite, possible):
        if k <= 0 or int(np.count_nonzero(definite)) < k:
            return possible.copy()
        pes32 = (lb if desc else -ub).astype(np.float32)
        tau_idx = int(_device_kth_index(jnp.asarray(pes32),
                                        jnp.asarray(definite), k))
        return self._alive_from_index(lb, ub, k, desc, definite, possible,
                                      pes32, tau_idx)

    def mask_agg_counts(self, gctx, node, gidx):
        gidx = np.asarray(gidx)
        s = gctx.groups.shape[1]
        flat = gctx.groups[gidx].reshape(-1)
        rois = gctx.resolve_group_rois(node.roi, gidx)
        if self._packed:
            inter, union = _device_group_counts_packed(
                self._masks, jnp.asarray(flat), jnp.asarray(rois, jnp.int32),
                jnp.asarray(node.thresh, jnp.float32), s=int(s))
        else:
            inter, union = _device_group_counts(
                self._masks, jnp.asarray(flat), jnp.asarray(rois, jnp.int32),
                jnp.asarray(node.thresh, self._masks.dtype), s=int(s))
        counts = inter if node.agg == "intersect" else union
        return np.asarray(counts, np.float64)

    def fused_counts(self, store, positions, specs):
        rois_q, lvs, uvs = spec_arrays(specs)
        multi = (_device_multi_counts_packed if self._packed
                 else _device_multi_counts)
        return np.asarray(multi(
            self._masks, jnp.asarray(np.asarray(positions)),
            jnp.asarray(rois_q), jnp.asarray(lvs), jnp.asarray(uvs)))

    def fused_pair_counts(self, store, pos_a, pos_b, specs):
        # Both roles are resident (the store's one HBM mask array); gather
        # each role ONCE and answer every descriptor against the gathered
        # batch — zero metered bytes, 2 gathers regardless of Q.
        a = self._masks[jnp.asarray(np.asarray(pos_a))]
        b = self._masks[jnp.asarray(np.asarray(pos_b))]
        kernel = kops.pair_counts_packed if self._packed else kops.pair_counts
        tdt = jnp.float32 if self._packed else a.dtype
        out = np.empty((len(specs), 3, len(pos_a)), np.int64)
        for qi, (rois, ta, tb) in enumerate(specs):
            trio = kernel(
                a, b, jnp.asarray(np.asarray(rois), jnp.int32),
                jnp.asarray(ta, tdt), jnp.asarray(tb, tdt))
            for row, counts in enumerate(trio):
                out[qi, row] = np.asarray(counts)
        return out


# ---------------------------------------------------------------------------
# Mesh — distributed.py's step functions over shard_map
# ---------------------------------------------------------------------------


class MeshBackend(_KthValueMixin, ExecBackend):
    """The query engine sharded over a device mesh: every physical
    primitive is one of :mod:`.distributed`'s step functions, rows sharded
    over the flattened mesh.  Candidate sets are padded to a device-count
    multiple (padded rows carry −inf/False sentinels and are sliced off)."""

    name = "mesh"

    def __init__(self, store, mesh=None):
        self.store = store
        self.cfg = store.cfg
        if mesh is None:
            mesh = make_mesh((len(jax.devices()),), ("data",))
        self.mesh = mesh
        self.n_dev = int(mesh.devices.size)
        self._masks = store.resident_masks()
        self._tables_np = (store.chi_host() if hasattr(store, "chi_host")
                           else np.asarray(store.chi_table))
        self._epoch = getattr(store, "epoch", 0)
        self._rb = jnp.asarray(self.cfg.row_bounds, jnp.int32)
        self._cb = jnp.asarray(self.cfg.col_bounds, jnp.int32)
        self._bounds_step = make_chi_bounds_step(mesh)
        self._packed = is_packed(store)
        # Packed steps share the float steps' call signatures and shardings
        # (words axis for pixel-column axis), so every call site below is
        # representation-agnostic once the right step is pinned here.
        if self._packed:
            self._verify_step = make_verify_packed_step(mesh)
            self._agg_step = make_mask_agg_packed_step(mesh)
            self._multi_step = make_cp_multi_packed_step(mesh)
            self._pair_step = make_pair_counts_packed_step(mesh)
            self._fused_verify_step = make_fused_verify_step(mesh)
        else:
            self._verify_step = make_verify_step(mesh)
            self._agg_step = make_mask_agg_step(mesh)
            self._multi_step = make_cp_multi_step(mesh)
            self._pair_step = make_pair_counts_step(mesh)
            self._fused_verify_step = None
        self._select_steps: dict = {}
        self._pair_cells_steps: dict = {}   # pair stat → sharded cells step
        self._tier_bnds: dict = {}          # tier grid → (row_b, col_b)

    def sync(self):
        """Re-pin the host-resident mask/CHI arrays after a store mutation.
        The store maintains ``resident_masks`` incrementally, so memory-tier
        refreshes are a view swap; shards are re-padded lazily per step
        (the mesh has no persistent sharded residency to patch)."""
        if self._epoch == getattr(self.store, "epoch", 0):
            return
        self._masks = self.store.resident_masks()
        self._tables_np = self.store.chi_host()
        self._epoch = self.store.epoch
        _BACKEND_SYNCS.labels(backend=self.name).inc()

    def _pad(self, arr, fill=0):
        """Pad the leading dim to a positive device-count multiple."""
        n = len(arr)
        r = (-n) % self.n_dev if n else self.n_dev
        if r == 0:
            return arr, n
        pad = np.full((r,) + arr.shape[1:], fill, arr.dtype)
        return np.concatenate([arr, pad]), n

    def bounds(self, ctx, expr):
        if hasattr(ctx, "pair_rois"):
            return ctx.bounds(expr, pair_leaf=self._pair_cells)
        return ctx.bounds(expr, cp_leaf=self._cp_bounds)

    def _tier_bounds(self, g: int):
        pair = self._tier_bnds.get(g)
        if pair is None:
            tcfg = self.cfg.for_grid(g)
            pair = (jnp.asarray(tcfg.row_bounds, jnp.int32),
                    jnp.asarray(tcfg.col_bounds, jnp.int32))
            self._tier_bnds[g] = pair
        return pair

    def _cp_bounds(self, mctx, node):
        pos = np.asarray(mctx.positions)
        rois = mctx.resolve_rois(node.roi, pos).astype(np.int32)
        g = getattr(mctx, "tier", None)
        if g is None or g == self.cfg.grid:
            cfg, tables, rb, cb = self.cfg, self._tables_np, self._rb, self._cb
        else:
            # coarse ladder rung: the store's host tier cache (maintained
            # incrementally across mutations) + the tier's grid boundaries
            cfg = self.cfg.for_grid(g)
            tables = self.store.chi_tier_host(g)
            rb, cb = self._tier_bounds(g)
        tab_p, n = self._pad(tables[pos])
        rois_p, _ = self._pad(rois)
        ks = value_ks(cfg, node.lv, node.uv)
        lb, ub = self._bounds_step(tab_p, rois_p, rb, cb,
                                   jnp.asarray(ks))
        return (np.asarray(lb)[:n].astype(np.float64),
                np.asarray(ub)[:n].astype(np.float64))

    def _pair_cells(self, pctx, node):
        step = self._pair_cells_steps.get(node.stat)
        if step is None:
            step = make_pair_cells_step(self.mesh, node.stat)
            self._pair_cells_steps[node.stat] = step
        pos_a = np.asarray(pctx.pos_a)
        pos_b = np.asarray(pctx.pos_b)
        rois = np.asarray(pctx.pair_rois(node.roi), np.int32)
        tab_a_p, n = self._pad(self._tables_np[pos_a])
        tab_b_p, _ = self._pad(self._tables_np[pos_b])
        rois_p, _ = self._pad(rois)
        ka = _threshold_ks(self.cfg, node.ta)
        kb = _threshold_ks(self.cfg, node.tb)
        ks = jnp.asarray(np.array([ka[0], ka[1], kb[0], kb[1]], np.int32))
        lb, ub = step(tab_a_p, tab_b_p, rois_p, ks, self._rb, self._cb)
        return (np.asarray(lb)[:n].astype(np.float64),
                np.asarray(ub)[:n].astype(np.float64))

    def verify_counts(self, ctx, batch, terms):
        terms = list(terms)
        pos = ctx.positions[batch]
        masks_p, n = self._pad(self._masks[pos])
        if len(terms) == 1:
            # single descriptor → the plain sharded verify step
            t = terms[0]
            rois_p, _ = self._pad(
                ctx.resolve_rois(t.roi, pos).astype(np.int32))
            counts = self._verify_step(masks_p, rois_p,
                                       jnp.float32(t.lv),
                                       jnp.float32(min(t.uv, _F32_MAX)))
            return {t: np.asarray(counts)[:n].astype(np.float64)}
        # several distinct terms (predicate + ranking) → one fused pass
        # over the sharded batch, exactly like the scheduler's route
        rois_q, lvs, uvs = spec_arrays(
            [(self._pad(ctx.resolve_rois(t.roi, pos).astype(np.int32))[0],
              t.lv, t.uv) for t in terms])
        counts = np.asarray(self._multi_step(masks_p, rois_q, lvs, uvs))
        return {t: counts[i, :n].astype(np.float64)
                for i, t in enumerate(terms)}

    def _fused_verify_batch(self, ctx, batch, pos, rois_q, lvs, uvs,
                            decided, lb):
        masks_p, n = self._pad(self._masks[pos])
        pad = len(masks_p) - n
        if pad:
            # padded rows: empty ROI (zero area) + undecided → count 0
            rois_q = np.pad(rois_q, ((0, 0), (0, pad), (0, 0)))
            decided = np.pad(decided, ((0, 0), (0, pad)))
            lb = np.pad(lb, ((0, 0), (0, pad)))
        counts = self._fused_verify_step(masks_p, rois_q, lvs, uvs,
                                         decided, lb)
        return np.asarray(counts)[:, :n]

    def topk_candidates(self, lb, ub, k, desc, definite, possible):
        if k <= 0 or int(np.count_nonzero(definite)) < k:
            return possible.copy()
        pes32 = (lb if desc else -ub).astype(np.float32)
        pes_p, n = self._pad(pes32, fill=np.float32(-np.inf))
        def_p, _ = self._pad(np.asarray(definite, bool), fill=False)
        step = self._select_steps.get(k)
        if step is None:
            step = self._select_steps[k] = make_topk_select_step(self.mesh, k)
        ids = np.arange(len(pes_p), dtype=np.int32)
        tau_idx = int(step(pes_p, def_p, ids))
        return self._alive_from_index(lb, ub, k, desc, definite, possible,
                                      pes32, tau_idx)

    def mask_agg_counts(self, gctx, node, gidx):
        gidx = np.asarray(gidx)
        s = gctx.groups.shape[1]
        grp = self._masks[gctx.groups[gidx].reshape(-1)]
        # row shape is (H, W) float or (H, words) packed
        grp = grp.reshape((len(gidx), s) + self._masks.shape[1:])
        rois = gctx.resolve_group_rois(node.roi, gidx).astype(np.int32)
        grp_p, n = self._pad(grp)
        rois_p, _ = self._pad(rois)
        tdt = jnp.float32 if self._packed else grp.dtype
        inter, union = self._agg_step(grp_p, rois_p,
                                      jnp.asarray(node.thresh, tdt))
        counts = inter if node.agg == "intersect" else union
        return np.asarray(counts)[:n].astype(np.float64)

    def fused_counts(self, store, positions, specs):
        masks_p, n = self._pad(self._masks[np.asarray(positions)])
        rois_q, lvs, uvs = spec_arrays(
            [(self._pad(np.asarray(sp[0], np.int32))[0], sp[1], sp[2])
             for sp in specs])
        counts = self._multi_step(masks_p, rois_q, lvs, uvs)
        return np.asarray(counts)[:, :n]

    def fused_pair_counts(self, store, pos_a, pos_b, specs):
        # Pair rows shard together: the i-th pair's A and B tiles land on
        # the same device, so the fused kernel needs no collective.
        a_p, n = self._pad(self._masks[np.asarray(pos_a)])
        b_p, _ = self._pad(self._masks[np.asarray(pos_b)])
        out = np.empty((len(specs), 3, n), np.int64)
        for qi, (rois, ta, tb) in enumerate(specs):
            rois_p, _ = self._pad(np.asarray(rois, np.int32))
            trio = self._pair_step(a_p, b_p, rois_p, jnp.float32(ta),
                                   jnp.float32(tb))
            for row, counts in enumerate(trio):
                out[qi, row] = np.asarray(counts)[:n]
        return out


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------

_HOST = HostBackend()
_NAMED = {"device": DeviceBackend, "mesh": MeshBackend}


def host_backend() -> HostBackend:
    """The stateless host backend singleton (the default everywhere)."""
    return _HOST


def get_backend(store, backend=None) -> ExecBackend:
    """Resolve a backend spec against a store.

    ``backend`` is ``None``/``"host"`` (default), a backend *name*
    (``"device"``/``"mesh"`` — instances are cached per store, so the
    resident mask/CHI upload happens once), or an :class:`ExecBackend`
    instance (e.g. a :class:`MeshBackend` built over an explicit mesh).
    """
    if backend is None or backend == "host":
        _BACKEND_RESOLUTIONS.labels(backend="host").inc()
        return _HOST
    if isinstance(backend, ExecBackend):
        backend.sync()
        _BACKEND_RESOLUTIONS.labels(backend=backend.name).inc()
        return backend
    cls = _NAMED.get(backend)
    if cls is None:
        raise ValueError(f"unknown backend {backend!r}; expected one of "
                         f"{['host'] + sorted(_NAMED)} or an ExecBackend")
    cache = store.backend_cache
    if backend not in cache:
        cache[backend] = cls(store)
        _BACKEND_BUILDS.labels(backend=backend).inc()
    else:
        cache[backend].sync()
    _BACKEND_RESOLUTIONS.labels(backend=backend).inc()
    return cache[backend]


__all__ = ["ExecBackend", "HostBackend", "DeviceBackend", "MeshBackend",
           "F32_MAX", "chi_verdicts", "get_backend", "host_backend",
           "is_packed", "spec_arrays"]
