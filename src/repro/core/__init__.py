"""MaskSearch core — the paper's contribution as a composable JAX module.

Public surface:
  * :mod:`repro.core.cp`      — the CP primitive (exact paths).
  * :mod:`repro.core.chi`     — Cumulative Histogram Index build + bounds.
  * :mod:`repro.core.store`   — tiered MasksDatabaseView storage.
  * :mod:`repro.core.exprs`   — CP expressions with interval semantics.
  * :mod:`repro.core.engine`  — filter–verification execution framework.
  * :mod:`repro.core.backend` — pluggable execution backends (host /
    device / mesh) under one physical protocol.
  * :mod:`repro.core.queries` — SQL-ish front-end (demo "Query Command").
  * :mod:`repro.core.distributed` — shard_map multi-device query engine
    (the mesh backend's step functions).
  * :mod:`repro.core.saliency`/:mod:`repro.core.augment` — the ML-workflow
    integration (mask harvesting + Scenario-1 augmentation).
"""

from .backend import (DeviceBackend, ExecBackend, HostBackend,  # noqa: F401
                      MeshBackend, get_backend)
from .chi import (CHIConfig, build_chi, build_chi_delta,  # noqa: F401
                  build_chi_np, chi_bounds)
from .engine import (ExecStats, FilteredTopKRun, FilterRun,  # noqa: F401
                     MinMaxAggRun, PairFilteredTopKRun, PairFilterRun,
                     PairTopKRun, ScalarAggRun, TopKRun,
                     filter_query, filtered_topk_query, scalar_agg,
                     topk_query)
from .cp import cp_exact, cp_exact_np, full_roi  # noqa: F401
from .exprs import (CP, AggCP, And, BinOp, Cmp, Const, Not, Or,  # noqa: F401
                    PairTerm, Pred, RoiArea, TypeIn, pair_iou)
from .plan import LogicalPlan, compile_plan, run_plan  # noqa: F401
from .queries import parse, parse_plan, run  # noqa: F401
from .store import (MASK_META_DTYPE, IOStats, MaskStore,  # noqa: F401
                    StaleRunError, StoreSnapshot)
