"""Cost-based filter optimization over the CHI pyramid (DESIGN.md §13).

The filter phase of every query decides candidates from index bytes alone;
this module decides *which* index bytes.  Two independent switches:

* **pyramid** — each conjunct of the WHERE clause starts its bounds pass at
  a coarse CHI tier (the strided subsample the store materializes per
  :attr:`~repro.core.chi.CHIConfig.tier_grids`) and only still-undecided
  candidates refine downward.  Soundness is by construction — coarse
  bounds contain fine bounds (:func:`repro.core.chi.tier_slice`) — and the
  finest rung re-evaluates the residue with exactly the classic bounds, so
  the final three-valued verdicts are bit-identical to plan-order
  evaluation while most candidates are decided in a fraction of the index
  bytes.
* **reorder** — conjuncts are evaluated cheapest-and-most-selective first
  instead of plan order.  Because ``And`` verdicts combine commutatively
  (accept = all accept, reject = any reject) any order yields the same
  final verdicts; a selective conjunct up front shrinks the candidate set
  every later conjunct (and the verification residue) pays for.

The selectivity estimates come from index statistics that already exist:
the CHI corner row ``table[:, -1, -1, :]`` is each mask's whole-image
value CDF (:meth:`~repro.core.store.MaskStore.chi_value_stats`), so a CP
leaf's value is estimated as the bin-midpoint CDF fraction times its ROI
area — no mask bytes, no extra build pass.  Tier choice additionally uses
the per-tier spatial alignment slack
(:func:`repro.core.chi.tier_alignment_fracs`): a predicate whose estimated
margin from its threshold is large relative to a tier's slack is predicted
to be decided there, and the start tier minimizes predicted total index
bytes down the ladder.  Estimate error is exported as the
``masksearch_selectivity_abs_error`` histogram on ``/metrics``.

The engine consumes :func:`plan_filter` (see
:func:`repro.core.engine._decide_pred`); :func:`configure` scopes either
switch for tests and benchmarks.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

import numpy as np

from ..obs.metrics import get_registry
from . import chi as chi_lib
from .exprs import (And, BinOp, Cmp, Const, CP, MaskEvalContext, Not, Or,
                    Pred, RoiArea, TypeIn)

__all__ = ["configure", "plan_filter", "flatten_and", "ConjunctPlan",
           "estimate_values", "observe_selectivity_error"]

#: Module switches — both on by default; scope overrides with configure().
PYRAMID = True
REORDER = True

#: Neutral reject estimate for conjuncts the mini-interpreter cannot see
#: through (unsupported node kinds): no reorder preference, coarsest start.
NEUTRAL_REJECT = 0.5

_SELECTIVITY_ERROR = get_registry().histogram(
    "masksearch_selectivity_abs_error",
    "Absolute error of the optimizer's per-conjunct selectivity estimate "
    "(estimated vs. actual bound-rejected fraction of evaluated candidates)",
    buckets=(0.01, 0.02, 0.05, 0.1, 0.2, 0.3, 0.5, 1.0))
# materialize the unlabeled child so /metrics exports the (empty) family
# before the first ladder run — scrapers see the series exists
_SELECTIVITY_ERROR.labels()


def observe_selectivity_error(err: float) -> None:
    _SELECTIVITY_ERROR.observe(float(err))


@contextlib.contextmanager
def configure(pyramid: Optional[bool] = None, reorder: Optional[bool] = None):
    """Scope the optimizer switches (None leaves a switch untouched)::

        with opt.configure(pyramid=False, reorder=False):
            ...   # classic fixed plan-order, single-grid bounds
    """
    global PYRAMID, REORDER
    prev = (PYRAMID, REORDER)
    if pyramid is not None:
        PYRAMID = bool(pyramid)
    if reorder is not None:
        REORDER = bool(reorder)
    try:
        yield
    finally:
        PYRAMID, REORDER = prev


def flatten_and(pred: Pred) -> list:
    """Top-level conjuncts of a predicate tree, in plan order."""
    if isinstance(pred, And):
        return flatten_and(pred.left) + flatten_and(pred.right)
    return [pred]


@dataclasses.dataclass
class ConjunctPlan:
    """One conjunct's optimizer decision (also the EXPLAIN report row)."""

    index: int                    # position in the original plan order
    pred: Pred
    start_tier: int               # coarsest ladder rung to evaluate first
    cost: float                   # relative bounds-pass cost (CHI passes)
    est_reject: Optional[float]   # estimated bound-rejected fraction
    est_accept: Optional[float]
    classic: bool = False         # decide via the run's full finest bounds
                                  # (expression shared with the ranking, or
                                  # bounds already memoized on the run)


# ---------------------------------------------------------------------------
# Selectivity estimation (index statistics only — no mask bytes)
# ---------------------------------------------------------------------------


def _cdf_fraction(stats: np.ndarray, cfg, lv: float, uv: float):
    """Per-mask (inner, outer) fraction of pixels with value in [lv, uv),
    from the whole-image CDF rows (``chi_value_stats``) at the same four
    clipped value edges the bounds pass resolves to."""
    kl_in, ku_in, kl_out, ku_out = chi_lib.value_ks4(cfg, lv, uv)
    total = np.maximum(stats[:, -1].astype(np.float64), 1.0)
    inner = np.maximum(stats[:, ku_in] - stats[:, kl_in], 0) / total
    outer = np.maximum(stats[:, ku_out] - stats[:, kl_out], 0) / total
    return inner, outer


def estimate_values(node, ctx: MaskEvalContext):
    """Per-mask point estimate of a value expression, or None when a node
    kind is outside the mini-interpreter (Const / CP / RoiArea / BinOp).

    A CP leaf estimates as the midpoint of its inner/outer CDF fractions
    times its ROI area — exact for full-image aligned queries, a uniform-
    spatial-density approximation otherwise.
    """
    if isinstance(node, Const):
        return np.full(len(ctx.positions), float(node.value))
    if isinstance(node, RoiArea):
        rois = ctx.resolve_rois(node.roi, ctx.positions)
        return _roi_areas(rois)
    if isinstance(node, CP):
        store = ctx.store
        if not hasattr(store, "chi_value_stats"):
            return None
        stats = store.chi_value_stats()[np.asarray(ctx.positions)]
        inner, outer = _cdf_fraction(stats, ctx.cfg, node.lv, node.uv)
        rois = ctx.resolve_rois(node.roi, ctx.positions)
        return 0.5 * (inner + outer) * _roi_areas(rois)
    if isinstance(node, BinOp):
        left = estimate_values(node.left, ctx)
        right = estimate_values(node.right, ctx)
        if left is None or right is None:
            return None
        if node.op == "+":
            return left + right
        if node.op == "-":
            return left - right
        if node.op == "*":
            return left * right
        if node.op == "/":
            with np.errstate(divide="ignore", invalid="ignore"):
                out = np.where(right != 0, left / np.where(right != 0,
                                                           right, 1.0), 0.0)
            return out
        return None
    return None


def _roi_areas(rois: np.ndarray) -> np.ndarray:
    rois = np.asarray(rois, np.int64)
    h = np.maximum(rois[:, 2] - rois[:, 0], 0)
    w = np.maximum(rois[:, 3] - rois[:, 1], 0)
    return (h * w).astype(np.float64)


def _cmp_margins(cmp: Cmp, values: np.ndarray) -> np.ndarray:
    """Normalized distance of each mask's estimated value from the
    comparison threshold — the cushion that must exceed the bounds'
    relative slack for a tier to decide the mask."""
    t = float(cmp.threshold)
    scale = np.maximum(np.maximum(np.abs(values), abs(t)), 1.0)
    return np.abs(values - t) / scale


def _estimate_pred(pred: Pred, ctx: MaskEvalContext):
    """(est_accept, est_reject, margins) for one conjunct subtree.

    Fractions are in [0, 1]; margins is the per-mask normalized threshold
    cushion (the minimum over Cmp leaves for composite subtrees — a mask
    is undecided if *any* leaf is).  None components mean "no estimate".
    """
    if isinstance(pred, Cmp):
        values = estimate_values(pred.expr, ctx)
        if values is None or not len(values):
            return None, None, None
        sat = np.asarray(
            {"<": values < pred.threshold, "<=": values <= pred.threshold,
             ">": values > pred.threshold,
             ">=": values >= pred.threshold}[pred.op])
        acc = float(sat.mean())
        return acc, 1.0 - acc, _cmp_margins(pred, values)
    if isinstance(pred, TypeIn):
        # Metadata-exact: no CHI involved, never unknown.
        types = ctx.store.meta["mask_type"][np.asarray(ctx.positions)]
        acc = float(np.isin(types, np.asarray(pred.types)).mean()) \
            if len(types) else 0.0
        return acc, 1.0 - acc, None
    if isinstance(pred, Not):
        a, r, m = _estimate_pred(pred.child, ctx)
        return r, a, m
    if isinstance(pred, (And, Or)):
        la, lr, lm = _estimate_pred(pred.left, ctx)
        ra, rr, rm = _estimate_pred(pred.right, ctx)
        if la is None or ra is None:
            return None, None, None
        margins = (lm if rm is None else rm if lm is None
                   else np.minimum(lm, rm))
        if isinstance(pred, And):
            return la * ra, 1.0 - (1.0 - lr) * (1.0 - rr), margins
        return 1.0 - (1.0 - la) * (1.0 - ra), lr * rr, margins
    return None, None, None


# ---------------------------------------------------------------------------
# Tier choice (predicted index bytes down the ladder)
# ---------------------------------------------------------------------------


def _tier_slacks(pred: Pred, ctx: MaskEvalContext, tiers) -> dict:
    """Per-tier relative bounds slack for one conjunct: the worst CP
    leaf's spatial misalignment at that tier plus its (tier-independent)
    value-bin slack.  A mask whose estimated threshold margin exceeds the
    slack is predicted to be decided at that tier."""
    slacks = {g: 0.0 for g in tiers}
    for term in pred.cp_terms():
        if not isinstance(term, CP):
            return {g: np.inf for g in tiers}   # no model → never decided
        rois = ctx.resolve_rois(term.roi, ctx.positions)
        v_slack = 0.0
        store = ctx.store
        if hasattr(store, "chi_value_stats"):
            stats = store.chi_value_stats()[np.asarray(ctx.positions)]
            inner, outer = _cdf_fraction(stats, ctx.cfg, term.lv, term.uv)
            v_slack = float(np.mean(outer - inner)) if len(inner) else 0.0
        for g in tiers:
            inner_f, outer_f = chi_lib.tier_alignment_fracs(ctx.cfg, g, rois)
            s_slack = float(np.mean(outer_f - inner_f)) if len(inner_f) \
                else 0.0
            slacks[g] = max(slacks[g], s_slack + v_slack)
    return slacks


def _tier_row_bytes(cfg, g: int) -> int:
    return (g + 1) * (g + 1) * (cfg.num_bins + 1) * 4


def _choose_start_tier(pred: Pred, ctx: MaskEvalContext, tiers,
                       margins) -> int:
    """Ladder start minimizing predicted index bytes: starting coarse pays
    extra cheap rungs for the undecided residue; starting fine pays the
    full-resolution row for every candidate.  Ties break to the coarser
    start (deterministic).  No margins → start coarsest: the whole coarse
    ladder costs a fraction of one finest pass, so the downside is bounded
    while the upside is most candidates deciding early."""
    if margins is None or not len(margins):
        return tiers[0]
    slacks = _tier_slacks(pred, ctx, tiers)
    best, best_cost = tiers[-1], None
    for i, start in enumerate(tiers):
        cost, undecided = 0.0, 1.0
        for g in tiers[i:]:
            cost += undecided * _tier_row_bytes(ctx.cfg, g)
            undecided = float(np.mean(margins < slacks[g]))
        if best_cost is None or cost < best_cost:
            best, best_cost = start, cost
    return best


# ---------------------------------------------------------------------------
# The filter plan
# ---------------------------------------------------------------------------


def plan_filter(pred: Pred, ctx, shared_exprs=(), memo_exprs=()) -> \
        Optional[list]:
    """Optimizer decisions for one WHERE clause, in evaluation order, or
    None when the optimizer does not apply (switches off, non-per-mask
    context, or a single-tier pyramid) and the engine should run the
    classic plan-order decide.

    Conjuncts whose value expressions are shared with the ranking
    expression (or already memoized on the run) are marked ``classic``:
    they decide from the run's full finest bounds so the shared pass is
    computed once and stays memoized for the ranking frontier.
    """
    if not (PYRAMID or REORDER):
        return None
    if not isinstance(ctx, MaskEvalContext) or getattr(ctx, "tier", None):
        return None
    tiers = ctx.cfg.tier_grids
    if len(tiers) < 2:
        return None
    conjuncts = flatten_and(pred)
    shared = set(shared_exprs) | set(memo_exprs)
    plans = []
    for i, c in enumerate(conjuncts):
        est_accept, est_reject, margins = _estimate_pred(c, ctx)
        exprs = c.value_exprs()
        classic = any(e in shared for e in exprs)
        # TypeIn-only conjuncts touch metadata, not CHI — near-free.
        cost = float(max(len(exprs), 1)) if exprs else 0.25
        if classic or not PYRAMID:
            start = tiers[-1]
        else:
            start = _choose_start_tier(c, ctx, tiers, margins)
        plans.append(ConjunctPlan(index=i, pred=c, start_tier=start,
                                  cost=cost, est_reject=est_reject,
                                  est_accept=est_accept, classic=classic))
    if REORDER:
        plans.sort(key=lambda p: (-(p.est_reject if p.est_reject is not None
                                    else NEUTRAL_REJECT) / p.cost, p.index))
    return plans
