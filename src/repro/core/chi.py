"""CHI — the Cumulative Histogram Index (the paper's core contribution).

For every mask, pixel values are discretized against an ordered threshold set
Θ and the spatial domain is cut into a ``G×G`` grid.  CHI stores cumulative
pixel counts for every (spatial-prefix, threshold-prefix) key.  We lay the
same information out as a dense 3-D prefix-sum tensor per mask::

    table[b, i, j, k] = #{ pixels p of mask b :
                           p.row < row_bounds[i],
                           p.col < col_bounds[j],
                           p.value < edges[k] }

with ``table.shape == (B, G+1, G+1, NB+1)`` — an O(1) 8-corner gather answers
the count of any *aligned* (cell-rectangle × threshold-range), and arbitrary
queries get sound upper/lower bounds by sandwiching the ROI between the
largest inscribed and smallest covering aligned boxes (same for the value
range).  This dense layout is the TPU-friendly equivalent of the paper's
key-value CHI: contiguous, gather-vectorizable across the whole mask batch.

Soundness invariants (property-tested in ``tests/test_chi.py``):
  * ``lower(b) <= CP_exact(b) <= upper(b)`` always;
  * aligned queries are answered exactly (``lower == upper``).

Value-edge sentinels: interior thresholds live in ``(0, 1)``; edge 0 is −inf
and edge NB is +inf so the index stays sound even for masks containing
values outside ``[0, 1)`` (e.g. exactly 1.0 for binarized masks).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CHIConfig:
    """Static index parameters (shared by every mask in a store partition)."""

    grid: int = 16           # G — spatial cells per side
    num_bins: int = 16       # NB — value bins
    height: int = 256        # mask height in pixels
    width: int = 256         # mask width in pixels
    # Interior value thresholds (len NB-1).  None → uniform in (0, 1).
    thresholds: tuple[float, ...] | None = None

    @property
    def row_bounds(self) -> np.ndarray:
        g = self.grid
        return np.array([(i * self.height) // g for i in range(g + 1)], dtype=np.int64)

    @property
    def col_bounds(self) -> np.ndarray:
        g = self.grid
        return np.array([(j * self.width) // g for j in range(g + 1)], dtype=np.int64)

    @property
    def interior_edges(self) -> np.ndarray:
        """The NB-1 interior thresholds (finite, sorted)."""
        if self.thresholds is not None:
            t = np.asarray(self.thresholds, dtype=np.float32)
            if t.shape != (self.num_bins - 1,):
                raise ValueError(
                    f"need {self.num_bins - 1} interior thresholds, got {t.shape}")
            if np.any(np.diff(t) <= 0):
                raise ValueError("thresholds must be strictly increasing")
            return t
        nb = self.num_bins
        return (np.arange(1, nb, dtype=np.float32)) / np.float32(nb)

    @property
    def edges(self) -> np.ndarray:
        """(NB+1,) value edges with ±inf sentinels."""
        return np.concatenate(
            [[-np.inf], self.interior_edges.astype(np.float64), [np.inf]])

    def table_shape(self, batch: int) -> tuple[int, int, int, int]:
        return (batch, self.grid + 1, self.grid + 1, self.num_bins + 1)

    def index_bytes(self, batch: int) -> int:
        return int(np.prod(self.table_shape(batch))) * 4

    def mask_bytes(self, batch: int) -> int:
        return batch * self.height * self.width * 4

    @property
    def tier_grids(self) -> tuple[int, ...]:
        """Pyramid tiers, coarsest first, finest == ``grid`` (DESIGN.md §13).

        Each coarser tier halves the grid while it stays even and >= 4, so
        every coarse boundary is also a fine boundary (``(i*H)//g`` with
        ``g | grid`` is a subset of the fine boundary set) and the coarse
        table is an exact strided subsample of the fine one — no extra
        persisted state, nesting sound by construction.  A grid that cannot
        halve (odd, or already 4) is a single-tier pyramid, which disables
        the refinement ladder entirely.
        """
        g, tiers = self.grid, [self.grid]
        while g % 2 == 0 and g // 2 >= 4:
            g //= 2
            tiers.append(g)
        return tuple(reversed(tiers))

    def for_grid(self, g: int) -> "CHIConfig":
        """The same index geometry at tier ``g`` (value bins unchanged)."""
        if g == self.grid:
            return self
        if self.grid % g:
            raise ValueError(f"tier grid {g} does not divide grid {self.grid}")
        return dataclasses.replace(self, grid=g)


# ---------------------------------------------------------------------------
# Index construction
# ---------------------------------------------------------------------------


def cell_histograms(masks: Array, cfg: CHIConfig) -> Array:
    """(B, G, G, NB) int32 per-cell per-bin pixel counts — pure-jnp reference.

    The Pallas ``chi_build`` kernel computes the same tensor in one tiled pass;
    this is its oracle and the fallback path.
    """
    b, h, w = masks.shape
    if (h, w) != (cfg.height, cfg.width):
        raise ValueError(f"mask shape {(h, w)} != cfg {(cfg.height, cfg.width)}")
    g, nb = cfg.grid, cfg.num_bins
    interior = jnp.asarray(cfg.interior_edges, dtype=masks.dtype)
    # bin id per pixel in [0, NB): #(interior edges <= value)
    bins = jnp.sum(masks[..., None] >= interior, axis=-1).astype(jnp.int32)
    rb = np.asarray(cfg.row_bounds)
    cb = np.asarray(cfg.col_bounds)
    # cell id per pixel (boundaries may be ragged when G ∤ H)
    row_cell = np.searchsorted(rb, np.arange(h), side="right") - 1
    col_cell = np.searchsorted(cb, np.arange(w), side="right") - 1
    row_cell = jnp.asarray(np.clip(row_cell, 0, g - 1), dtype=jnp.int32)
    col_cell = jnp.asarray(np.clip(col_cell, 0, g - 1), dtype=jnp.int32)
    flat_key = (row_cell[:, None] * g + col_cell[None, :])[None, :, :] * nb + bins
    counts = jax.vmap(
        lambda k: jnp.zeros((g * g * nb,), jnp.int32).at[k.reshape(-1)].add(1)
    )(flat_key)
    return counts.reshape(b, g, g, nb)


def histograms_to_table(cell_hist: Array) -> Array:
    """Convert (B, G, G, NB) cell counts into the (B, G+1, G+1, NB+1) CHI
    prefix-sum table via three cumulative sums + zero padding."""
    c = jnp.cumsum(cell_hist, axis=1)
    c = jnp.cumsum(c, axis=2)
    c = jnp.cumsum(c, axis=3)
    c = jnp.pad(c, ((0, 0), (1, 0), (1, 0), (1, 0)))
    return c.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("cfg",))
def build_chi(masks: Array, cfg: CHIConfig) -> Array:
    """Build the CHI table for a batch of masks (pure-jnp path)."""
    return histograms_to_table(cell_histograms(masks, cfg))


def build_chi_delta(masks: np.ndarray, cfg: CHIConfig) -> np.ndarray:
    """CHI table rows for a *delta* batch — the incremental-ingest primitive
    behind :meth:`repro.core.store.MaskStore.append`/``update``.

    Cost is O(len(masks)), never O(database): the caller attaches the
    returned ``(delta, G+1, G+1, NB+1)`` rows as a new chunk (append) or
    patches them into existing chunks (update).  On accelerator backends
    (or under the forced-interpret CI leg) the histograms go through the
    Pallas ``chi_build`` kernel path; on plain CPU the NumPy oracle wins.
    """
    masks = np.asarray(masks, np.float32)
    if masks.ndim == 2:
        masks = masks[None]
    if len(masks) == 0:
        return np.zeros(cfg.table_shape(0), np.int32)
    from ..kernels import ops as kops
    # One dispatch policy with the kernel wrappers (ops._dispatch): the
    # jax path on accelerators or under the forced-interpret CI leg
    # (ops captures the flag at import), the NumPy oracle on plain CPU.
    if jax.default_backend() in ("tpu", "gpu") or kops._FORCE_INTERPRET:
        hist = kops.chi_cell_hist(jnp.asarray(masks),
                                  jnp.asarray(cfg.interior_edges),
                                  cfg.grid)
        return np.asarray(histograms_to_table(hist), np.int32)
    return build_chi_np(masks, cfg)


def build_chi_np(masks: np.ndarray, cfg: CHIConfig) -> np.ndarray:
    """Numpy oracle for :func:`build_chi` (used in tests + host-side ingest)."""
    b, h, w = masks.shape
    g, nb = cfg.grid, cfg.num_bins
    interior = cfg.interior_edges.astype(np.float64)
    bins = np.searchsorted(interior, masks.astype(np.float64), side="right")
    rb, cb = cfg.row_bounds, cfg.col_bounds
    row_cell = np.clip(np.searchsorted(rb, np.arange(h), side="right") - 1, 0, g - 1)
    col_cell = np.clip(np.searchsorted(cb, np.arange(w), side="right") - 1, 0, g - 1)
    out = np.zeros((b, g, g, nb), dtype=np.int64)
    flat = (row_cell[:, None] * g + col_cell[None, :])[None] * nb + bins
    for i in range(b):
        out[i] = np.bincount(flat[i].reshape(-1), minlength=g * g * nb).reshape(g, g, nb)
    tab = out.cumsum(axis=1).cumsum(axis=2).cumsum(axis=3)
    tab = np.pad(tab, ((0, 0), (1, 0), (1, 0), (1, 0)))
    return tab.astype(np.int32)


# ---------------------------------------------------------------------------
# Hierarchical pyramid tiers (DESIGN.md §13)
# ---------------------------------------------------------------------------


def tier_slice(table: np.ndarray, grid: int, g: int) -> np.ndarray:
    """The exact tier-``g`` CHI table, sliced out of the tier-``grid`` one.

    Because ``row_bounds[i] = (i*H)//g`` and ``g | grid``, every tier-``g``
    boundary equals the fine boundary at index ``i * (grid // g)`` —
    ``(i*(grid//g)*H)//grid == (i*H)//g`` exactly — so the coarse table is
    a strided subsample of the fine prefix tensor, not an approximation.
    Coarse-tier bounds therefore contain fine-tier bounds by construction.
    """
    if grid % g:
        raise ValueError(f"tier grid {g} does not divide grid {grid}")
    r = grid // g
    out = table[:, ::r, ::r, :]
    if isinstance(out, np.ndarray):
        out = np.ascontiguousarray(out)
    return out


def value_ks4(cfg: CHIConfig, lv: float, uv: float) -> tuple[int, int, int, int]:
    """The four clipped value-edge indices of :func:`resolve_query` —
    ``(kl_in, ku_in, kl_out, ku_out)`` — shared with the cost model so the
    searchsorted-on-edges logic stays in this module."""
    edges = cfg.edges
    nb = cfg.num_bins
    kl_in = int(np.clip(np.searchsorted(edges, lv, side="left"), 0, nb))
    ku_in = int(np.clip(np.searchsorted(edges, uv, side="right") - 1, 0, nb))
    kl_out = int(np.clip(np.searchsorted(edges, lv, side="right") - 1, 0, nb))
    ku_out = int(np.clip(np.searchsorted(edges, uv, side="left"), 0, nb))
    return kl_in, ku_in, kl_out, ku_out


def tier_alignment_fracs(cfg: CHIConfig, g: int, rois: np.ndarray):
    """Per-ROI (inner, outer) aligned-area fractions at tier ``g``.

    ``inner`` is the area of the largest tier-aligned box inscribed in the
    ROI and ``outer`` the smallest covering one, both divided by the ROI
    area — the spatial slack the cost model uses to predict how many
    candidates a tier can decide (inner == outer == 1 means the tier
    answers the ROI exactly).  Empty ROIs report (1, 1): they are always
    decided.  Same boundary math as :func:`resolve_query`, kept here so
    searchsorted over index geometry stays in this module.
    """
    tcfg = cfg.for_grid(g)
    rb, cb = tcfg.row_bounds, tcfg.col_bounds
    rois = np.asarray(rois, np.int64)
    r0, c0, r1, c1 = rois[:, 0], rois[:, 1], rois[:, 2], rois[:, 3]
    gi = tcfg.grid

    def _spans(bounds, lo, hi):
        il = np.clip(np.searchsorted(bounds, lo, side="left"), 0, gi)
        ih = np.clip(np.searchsorted(bounds, hi, side="right") - 1, 0, gi)
        ol = np.clip(np.searchsorted(bounds, lo, side="right") - 1, 0, gi)
        oh = np.clip(np.searchsorted(bounds, hi, side="left"), 0, gi)
        inner = np.maximum(bounds[ih] - bounds[il], 0)
        outer = np.maximum(bounds[oh] - bounds[ol], 0)
        return inner, outer

    in_h, out_h = _spans(rb, r0, r1)
    in_w, out_w = _spans(cb, c0, c1)
    area = np.maximum(r1 - r0, 0) * np.maximum(c1 - c0, 0)
    safe = np.maximum(area, 1).astype(np.float64)
    inner = np.where(area > 0, (in_h * in_w) / safe, 1.0)
    outer = np.where(area > 0, (out_h * out_w) / safe, 1.0)
    return inner, outer


# ---------------------------------------------------------------------------
# Aligned lookups and query bounds
# ---------------------------------------------------------------------------


def _lookup(table: Array, i0, i1, j0, j1, k0, k1) -> Array:
    """Exact count over aligned box [i0,i1)×[j0,j1) cells × [k0,k1) bins.

    All index args are (B,) int32 (or scalars broadcastable to it); the
    answer is an 8-corner inclusion–exclusion gather — O(1) per mask.
    """
    b = table.shape[0]
    bi = jnp.arange(b)

    def f(i, j, k):
        return table[bi, i, j, k]

    def plane(k):
        return f(i1, j1, k) - f(i0, j1, k) - f(i1, j0, k) + f(i0, j0, k)

    return plane(k1) - plane(k0)


@dataclasses.dataclass(frozen=True)
class AlignedQuery:
    """Host-side resolution of an arbitrary (roi, value-range) query against
    the index geometry: inscribed + covering aligned boxes."""

    # inner (inscribed) spatial box, cell indices
    il: np.ndarray; ih: np.ndarray; jl: np.ndarray; jh: np.ndarray
    # outer (covering) spatial box
    ol: np.ndarray; oh: np.ndarray; pl: np.ndarray; ph: np.ndarray
    # inner / outer value-bin ranges (scalars)
    kl_in: int; ku_in: int; kl_out: int; ku_out: int
    roi_area: np.ndarray  # (B,) pixel area, caps the upper bound
    aligned: np.ndarray   # (B,) bool — query exactly aligned to the index


def resolve_query(cfg: CHIConfig, rois: np.ndarray, lv: float, uv: float) -> AlignedQuery:
    """Map pixel-space ROIs + a value range onto index coordinates (host side;
    boundary arrays are tiny so numpy searchsorted is the right tool)."""
    rb, cb, edges = cfg.row_bounds, cfg.col_bounds, cfg.edges
    r0, c0, r1, c1 = rois[:, 0], rois[:, 1], rois[:, 2], rois[:, 3]
    # inner: smallest boundary >= start, largest boundary <= end
    il = np.searchsorted(rb, r0, side="left")
    ih = np.searchsorted(rb, r1, side="right") - 1
    jl = np.searchsorted(cb, c0, side="left")
    jh = np.searchsorted(cb, c1, side="right") - 1
    # outer: largest boundary <= start, smallest boundary >= end
    ol = np.searchsorted(rb, r0, side="right") - 1
    oh = np.searchsorted(rb, r1, side="left")
    pl = np.searchsorted(cb, c0, side="right") - 1
    ph = np.searchsorted(cb, c1, side="left")

    kl_in = int(np.searchsorted(edges, lv, side="left"))
    ku_in = int(np.searchsorted(edges, uv, side="right") - 1)
    kl_out = int(np.searchsorted(edges, lv, side="right") - 1)
    ku_out = int(np.searchsorted(edges, uv, side="left"))

    nbp1 = cfg.num_bins
    kl_in, ku_in = np.clip(kl_in, 0, nbp1), np.clip(ku_in, 0, nbp1)
    kl_out, ku_out = np.clip(kl_out, 0, nbp1), np.clip(ku_out, 0, nbp1)

    g = cfg.grid
    area = np.maximum(r1 - r0, 0) * np.maximum(c1 - c0, 0)
    spatial_aligned = (il == ol) & (ih == oh) & (jl == pl) & (jh == ph)
    value_aligned = (kl_in == kl_out) and (ku_in == ku_out)
    empty = area == 0
    return AlignedQuery(
        il=np.clip(il, 0, g), ih=np.clip(ih, 0, g),
        jl=np.clip(jl, 0, g), jh=np.clip(jh, 0, g),
        ol=np.clip(ol, 0, g), oh=np.clip(oh, 0, g),
        pl=np.clip(pl, 0, g), ph=np.clip(ph, 0, g),
        kl_in=int(kl_in), ku_in=int(ku_in),
        kl_out=int(kl_out), ku_out=int(ku_out),
        roi_area=area.astype(np.int64),
        aligned=(spatial_aligned & value_aligned) | empty,
    )


@functools.partial(jax.jit, static_argnames=("kl_in", "ku_in", "kl_out", "ku_out"))
def _bounds_device(table, il, ih, jl, jh, ol, oh, pl, ph, area,
                   kl_in: int, ku_in: int, kl_out: int, ku_out: int):
    inner_nonempty = (ih > il) & (jh > jl) & (ku_in > kl_in)
    lb_raw = _lookup(table, il, ih, jl, jh,
                     jnp.minimum(kl_in, ku_in), ku_in)
    lb = jnp.where(inner_nonempty, lb_raw, 0)
    outer_nonempty = (oh > ol) & (ph > pl) & (ku_out > kl_out)
    ub_raw = _lookup(table, ol, oh, pl, ph,
                     jnp.minimum(kl_out, ku_out), ku_out)
    ub = jnp.where(outer_nonempty, ub_raw, 0)
    ub = jnp.minimum(ub, area.astype(ub.dtype))
    lb = jnp.minimum(lb, ub)  # inner ⊆ outer, but guard rounding pathologies
    return lb.astype(jnp.int32), ub.astype(jnp.int32)


def chi_bounds(table: Array, cfg: CHIConfig, rois, lv: float, uv: float):
    """Sound (lower, upper) bounds on ``CP(mask, roi, [lv, uv))`` for every
    mask in the indexed batch — no mask bytes touched.

    Returns ``(lb, ub)`` int32 arrays of shape ``(B,)``.
    """
    b = table.shape[0]
    rois = np.asarray(rois, dtype=np.int64)
    if rois.ndim == 1:
        rois = np.tile(rois[None], (b, 1))
    q = resolve_query(cfg, rois, lv, uv)
    lb, ub = _bounds_device(
        table,
        jnp.asarray(q.il), jnp.asarray(q.ih), jnp.asarray(q.jl), jnp.asarray(q.jh),
        jnp.asarray(q.ol), jnp.asarray(q.oh), jnp.asarray(q.pl), jnp.asarray(q.ph),
        jnp.asarray(q.roi_area),
        kl_in=q.kl_in, ku_in=q.ku_in, kl_out=q.kl_out, ku_out=q.ku_out,
    )
    return lb, ub


def chi_bounds_multi(table: Array, cfg: CHIConfig,
                     rois_q: Sequence[np.ndarray],
                     ranges_q: Sequence[tuple[float, float]]):
    """Bounds for Q descriptors over the same indexed batch.

    One CHI read amortized over the whole workload: returns
    ``(lb, ub)`` of shape ``(Q, B)``.
    """
    lbs, ubs = [], []
    for rois, (lv, uv) in zip(rois_q, ranges_q):
        lb, ub = chi_bounds(table, cfg, rois, lv, uv)
        lbs.append(lb)
        ubs.append(ub)
    return jnp.stack(lbs), jnp.stack(ubs)


def decided_fraction(lb: np.ndarray, ub: np.ndarray) -> float:
    """Fraction of masks whose bounds already coincide (fully decided)."""
    lb, ub = np.asarray(lb), np.asarray(ub)
    return float(np.mean(lb == ub)) if lb.size else 1.0
