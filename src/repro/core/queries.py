"""SQL-ish query front-end for MaskSearch (the demo GUI's "Query Command").

Supports the paper's textual query classes verbatim, e.g.::

    SELECT mask_id FROM MasksDatabaseView
    WHERE CP(mask, roi, (0.8, 1.0)) < 5000;

    SELECT mask_id FROM MasksDatabaseView
    ORDER BY CP(mask, full_img, (0.2, 0.6)) DESC LIMIT 25;

    SELECT image_id,
           CP(intersect(mask > 0.8), roi, (0.5, 2.0))
         / CP(union(mask > 0.8), roi, (0.5, 2.0)) AS iou
    FROM MasksDatabaseView WHERE mask_type IN (1, 2)
    GROUP BY image_id ORDER BY iou ASC LIMIT 25;

    SELECT SCALAR_AGG(AVG, CP(mask, roi, (0.9, 1.0))) FROM MasksDatabaseView;

plus arithmetic over CP terms (including unary minus and scientific-notation
literals), ``AREA(roi)`` for normalized counts (Scenario 1), and **composable
WHERE clauses**: comparisons combine with ``AND`` / ``OR`` / ``NOT`` and
parentheses, and a predicate composes with ``ORDER BY … LIMIT`` — the
refinement shapes the demo GUI stacks up, e.g.::

    SELECT mask_id FROM MasksDatabaseView
    WHERE CP(mask, roi, (0.8, 1.0)) > 500
      AND NOT CP(mask, full_img, (0.2, 0.6)) < 100
    ORDER BY CP(mask, full_img, (0.2, 0.6)) DESC LIMIT 25;

plus **dual-mask (pair) queries** — the paper's saliency-vs-attention
discrepancy scenarios as first-class terms over per-image mask pairs::

    SELECT image_id FROM MasksDatabaseView
    ORDER BY IOU(saliency, attention, 0.6, 0.6) ASC LIMIT 25;

    SELECT image_id FROM MasksDatabaseView
    WHERE PAIR_DIFF(saliency, attention, 0.6, 0.6) > 1000
    ORDER BY PAIR_INTER(saliency, attention, 0.6, 0.6, roi) ASC LIMIT 25;

``roi`` refers to caller-provided per-mask rectangles (e.g. YOLO boxes);
``full_img`` is the whole mask; a literal ``(r0, c0, r1, c1)`` rectangle is
also accepted.  The parser builds expression trees from ``core.exprs`` and a
:class:`~repro.core.plan.LogicalPlan` executed through ``core.plan``;
:class:`Query` remains as a thin compatibility shim over the plan IR.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

from ..obs import trace as _trace
from . import plan as plan_lib
from .exprs import (AggCP, And, BinOp, Cmp, Const, CP, Node, Not, Or,
                    PairTerm, Pred, RoiArea, TypeIn, pair_iou)
from .plan import LogicalPlan

# Demo role-name convention (scenario 3/6 and the synthetic generators):
# mask_type 1 = model saliency, mask_type 2 = human attention.  The pair
# grammar accepts these names or integer mask_types directly.
PAIR_ROLES = {"saliency": 1, "attention": 2}

_PAIR_FNS = {"PAIR_INTER": "inter", "PAIR_UNION": "union",
             "PAIR_DIFF": "diff"}

_TOKEN_RE = re.compile(r"""
      (?P<num>(?:\d+\.\d*|\.\d+|\d+)(?:[eE][+-]?\d+)?|inf)
    | (?P<name>[A-Za-z_][A-Za-z_0-9]*)
    | (?P<op>[(),+\-*/<>=;]|<=|>=)
""", re.VERBOSE)

_CMP_OPS = ("<", "<=", ">", ">=")
_ARITH_OPS = ("+", "-", "*", "/")


def _tokenize(text: str):
    tokens = []
    i = 0
    text = text.strip()
    while i < len(text):
        if text[i].isspace():
            i += 1
            continue
        if text[i:i + 2] in ("<=", ">="):
            tokens.append(text[i:i + 2])
            i += 2
            continue
        m = _TOKEN_RE.match(text, i)
        if not m:
            raise SyntaxError(f"bad token at ...{text[i:i+20]!r}")
        tokens.append(m.group(0))
        i = m.end()
    return tokens


@dataclasses.dataclass
class Query:
    """A parsed query — a compatibility view over :class:`LogicalPlan`.

    The legacy flat fields (``kind``/``expr``/``op``/``threshold``/…) are
    kept for existing callers; ``plan`` is the composable IR that actually
    executes.  New code should use :func:`parse_plan` +
    :func:`repro.core.plan.run_plan` directly.
    """

    kind: str                      # "filter" | "topk" | "filtered_topk"
    select: str                    # "mask_id" | "image_id"   | "scalar_agg"
    expr: Optional[Node] = None
    op: Optional[str] = None
    threshold: Optional[float] = None
    k: Optional[int] = None
    desc: bool = True
    agg: Optional[str] = None
    mask_types: Optional[tuple] = None
    group_by_image: bool = False
    predicate: Optional[Pred] = None
    plan: Optional[LogicalPlan] = dataclasses.field(default=None, repr=False)
    # "plan" | "analyze" when the SQL carried an EXPLAIN [ANALYZE] prefix.
    # Deliberately outside _snapshot(): toggling it never invalidates the
    # compiled plan.
    explain: Optional[str] = None

    def __post_init__(self):
        if self.plan is None:
            self.plan = self._derive_plan()
        self._flat_sig = self._snapshot()

    def _snapshot(self):
        return (self.kind, self.select, self.expr, self.op, self.threshold,
                self.k, self.desc, self.agg, self.mask_types,
                self.group_by_image, self.predicate)

    def _derive_plan(self) -> LogicalPlan:
        """Rebuild the IR from legacy fields (hand-constructed Queries)."""
        pred = self.predicate
        if pred is None and self.op is not None and self.kind == "filter":
            pred = Cmp(self.expr, self.op, self.threshold)
        if self.kind == "scalar_agg":
            return LogicalPlan(select="mask_id", agg=self.agg,
                               agg_expr=self.expr,
                               mask_types=self.mask_types,
                               group_by_image=False)
        order = self.expr if self.kind in ("topk", "filtered_topk") else None
        return LogicalPlan(select=self.select, predicate=pred,
                           mask_types=self.mask_types, order_by=order,
                           k=self.k, desc=self.desc,
                           group_by_image=self.group_by_image)

    def sync_plan(self) -> LogicalPlan:
        """The executable plan, re-derived if the legacy flat fields were
        mutated since it was built.  The pre-redesign Query read its flat
        fields at call time, so parse-then-tweak callers (``q.threshold =
        …; q.run(…)``) must see their mutations; mutated comparison fields
        win over a predicate derived from the stale ones.  Every execution
        path (``run`` and the service) goes through here."""
        if self._snapshot() != self._flat_sig:
            old_predicate = self._flat_sig[-1]
            if (self.kind == "filter" and self.op is not None and
                    self.predicate == old_predicate):
                self.predicate = Cmp(self.expr, self.op, self.threshold)
            self.plan = self._derive_plan()
            self._flat_sig = self._snapshot()
        return self.plan

    def run(self, store, *, provided_rois=None, use_index: bool = True,
            **kw):
        """Execute against a MaskStore.  Result shapes are unchanged from
        the flat front-end: filter → ``(ids, stats)``, rankings →
        ``((ids, scores), stats)``, scalar agg → ``(value, stats)``.

        A query parsed from ``EXPLAIN <sql>`` returns the logical operator
        tree (not executed); ``EXPLAIN ANALYZE <sql>`` executes under a
        forced-on tracer and returns the annotated report dict (see
        :mod:`repro.obs.explain`)."""
        if self.explain is not None:
            from ..obs import explain as explain_mod
            if self.explain == "plan":
                return explain_mod.explain_plan(self.sync_plan())
            return explain_mod.explain_analyze(
                store, self.sync_plan(), provided_rois=provided_rois, **kw)
        return plan_lib.run_plan(store, self.sync_plan(),
                                 provided_rois=provided_rois,
                                 use_index=use_index, **kw)


def _legacy_query(plan: LogicalPlan, aliases=None) -> Query:
    """Flatten a plan into the compat record (shared fields mirrored)."""
    kind = plan.kind
    expr = None
    op = threshold = None
    if kind in ("topk", "filtered_topk"):
        expr = plan.order_by
    elif kind == "scalar_agg":
        expr = plan.agg_expr
    elif isinstance(plan.predicate, Cmp):
        expr = plan.predicate.expr
        op = plan.predicate.op
        threshold = plan.predicate.threshold
    q = Query(kind=kind, select=plan.select, expr=expr, op=op,
              threshold=threshold, k=plan.k, desc=plan.desc, agg=plan.agg,
              mask_types=plan.mask_types, group_by_image=plan.group_by_image,
              predicate=plan.predicate, plan=plan)
    q._aliases = aliases or {}
    return q


class _Parser:
    def __init__(self, tokens):
        self.toks = tokens
        self.i = 0

    # -- token helpers ----------------------------------------------------
    def peek(self, off: int = 0):
        j = self.i + off
        return self.toks[j] if j < len(self.toks) else None

    def next(self):
        tok = self.peek()
        if tok is None:
            raise SyntaxError("unexpected end of query")
        self.i += 1
        return tok

    def expect(self, want: str):
        tok = self.next()
        if tok.upper() != want.upper():
            raise SyntaxError(f"expected {want!r}, got {tok!r}")
        return tok

    def accept(self, want: str) -> bool:
        if self.peek() is not None and self.peek().upper() == want.upper():
            self.i += 1
            return True
        return False

    def number(self) -> float:
        tok = self.next()
        sign = 1.0
        if tok == "-":
            sign = -1.0
            tok = self.next()
        if tok == "inf":
            return sign * float("inf")
        try:
            return sign * float(tok)
        except ValueError as e:
            raise SyntaxError(f"expected number, got {tok!r}") from e

    # -- grammar -----------------------------------------------------------
    def parse(self) -> Query:
        self.expect("SELECT")
        select = "mask_id"
        agg = None
        agg_expr = None
        aliases = {}
        if (self.peek() or "").upper() == "SCALAR_AGG":
            self.next()
            self.expect("(")
            agg = self.next().upper()
            self.expect(",")
            agg_expr = self.expr()
            self.expect(")")
        else:
            select = self.next()
            if select not in ("mask_id", "image_id"):
                raise SyntaxError(
                    f"can only SELECT mask_id/image_id, got {select}")
            while self.accept(","):
                e = self.expr()
                self.expect("AS")
                aliases[self.next()] = e
        self.expect("FROM")
        self.next()  # view name, ignored

        mask_types = None
        predicate = None
        if self.accept("WHERE"):
            mask_types, predicate = plan_lib.simplify_predicate(
                self._pred_or())
        group_by_image = False
        if self.accept("GROUP"):
            self.expect("BY")
            self.expect("image_id")
            group_by_image = True
        order_by = None
        k = None
        desc = True
        if self.accept("ORDER"):
            self.expect("BY")
            nxt = self.peek()
            if nxt in aliases:
                self.next()
                order_by = aliases[nxt]
            else:
                order_by = self.expr()
            if self.accept("ASC"):
                desc = False
            else:
                self.accept("DESC")
            self.expect("LIMIT")
            k = int(self.number())
        self.accept(";")
        if self.peek() is not None:
            raise SyntaxError(f"trailing tokens at {self.peek()!r}")

        if agg is not None:
            if predicate is not None:
                raise SyntaxError(
                    "SCALAR_AGG supports only mask_type IN (...) in WHERE")
            if order_by is not None:
                raise SyntaxError("SCALAR_AGG cannot be ordered")
            plan = LogicalPlan(select="mask_id", agg=agg, agg_expr=agg_expr,
                               mask_types=mask_types)
        else:
            if select == "image_id":
                group_by_image = True
            if order_by is None and predicate is None:
                if mask_types is not None:
                    # pure source filter: every candidate of the type(s)
                    predicate = TypeIn(mask_types)
                else:
                    raise SyntaxError(
                        "filter query needs a predicate or ORDER BY")
            plan = LogicalPlan(select=select, predicate=predicate,
                               mask_types=mask_types, order_by=order_by,
                               k=k, desc=desc, group_by_image=group_by_image)
        try:
            plan.validate()
        except ValueError as e:
            raise SyntaxError(str(e)) from e
        return _legacy_query(plan, aliases)

    # predicate grammar:  or := and (OR and)* ;  and := unary (AND unary)* ;
    # unary := NOT unary | atom ;  atom := '(' or ')' | mask_type IN (...)
    #                                    | expr cmp_op number
    def _pred_or(self) -> Pred:
        node = self._pred_and()
        while self.accept("OR"):
            node = Or(node, self._pred_and())
        return node

    def _pred_and(self) -> Pred:
        node = self._pred_unary()
        while self.accept("AND"):
            node = And(node, self._pred_unary())
        return node

    def _pred_unary(self) -> Pred:
        if self.accept("NOT"):
            return Not(self._pred_unary())
        return self._pred_atom()

    def _pred_atom(self) -> Pred:
        tok = self.peek()
        if tok is None:
            raise SyntaxError("unexpected end of query (expected predicate)")
        if tok == "(":
            # Backtracking disambiguation: '(' may open a parenthesized
            # predicate or a parenthesized arithmetic expression.  Try the
            # predicate read; if it fails — or the closing paren is followed
            # by an operator, meaning the parens belonged to arithmetic —
            # rewind and parse a comparison instead.
            save = self.i
            try:
                self.next()
                node = self._pred_or()
                self.expect(")")
            except SyntaxError:
                self.i = save
            else:
                if (self.peek() or "") not in _CMP_OPS + _ARITH_OPS:
                    return node
                self.i = save
        if (tok or "").lower() == "mask_type":
            self.next()
            self.expect("IN")
            self.expect("(")
            types = [int(self.number())]
            while self.accept(","):
                types.append(int(self.number()))
            self.expect(")")
            return TypeIn(tuple(types))
        expr = self.expr()
        op = self.next()
        if op not in _CMP_OPS:
            raise SyntaxError(f"bad comparison {op!r}")
        return Cmp(expr, op, self.number())

    # expression grammar: expr := term (('+'|'-') term)*
    def expr(self) -> Node:
        node = self.term()
        while self.peek() in ("+", "-"):
            op = self.next()
            node = BinOp(op, node, self.term())
        return node

    def term(self) -> Node:
        node = self.factor()
        while self.peek() in ("*", "/"):
            op = self.next()
            node = BinOp(op, node, self.factor())
        return node

    def factor(self) -> Node:
        tok = self.peek()
        if tok is None:
            raise SyntaxError("unexpected end of query (expected expression)")
        if tok == "-":                      # unary minus
            self.next()
            operand = self.factor()
            if isinstance(operand, Const):
                return Const(-operand.value)
            return BinOp("-", Const(0.0), operand)
        if tok == "(":
            self.next()
            node = self.expr()
            self.expect(")")
            return node
        if tok.upper() == "CP":
            return self._cp()
        if tok.upper() == "IOU" or tok.upper() in _PAIR_FNS:
            return self._pair(tok.upper())
        if tok.upper() == "AREA":
            self.next()
            self.expect("(")
            roi = self._roi()
            self.expect(")")
            return RoiArea(roi)
        # number literal
        return Const(self.number())

    def _cp(self) -> Node:
        self.expect("CP")
        self.expect("(")
        tok = self.peek() or ""
        if tok.lower() in ("intersect", "union", "mask_agg"):
            agg = self.next().lower()
            self.expect("(")
            self.expect("mask")
            thresh = 0.5
            if self.accept(">"):
                thresh = self.number()
            self.expect(")")
            if agg == "mask_agg":
                agg = "intersect"  # MASK_AGG default: thresholded intersection
            self.expect(",")
            roi = self._roi()
            self.expect(",")
            lv, uv = self._range()
            self.expect(")")
            del lv, uv  # aggregated mask is binary; range implied
            return AggCP(agg, thresh, roi)
        self.expect("mask")
        self.expect(",")
        roi = self._roi()
        self.expect(",")
        lv, uv = self._range()
        self.expect(")")
        return CP(roi, lv, uv)

    def _role(self) -> int:
        """A pair role: a mask_type integer or a well-known role name."""
        tok = self.next()
        if tok.lower() in PAIR_ROLES:
            return PAIR_ROLES[tok.lower()]
        try:
            return int(tok)
        except ValueError as e:
            raise SyntaxError(
                f"bad mask role {tok!r}; expected a mask_type integer or "
                f"one of {sorted(PAIR_ROLES)}") from e

    def _pair(self, fn: str) -> Node:
        """Dual-mask terms (DESIGN.md §9)::

            IOU(role_a, role_b, ta, tb [, roi])
            PAIR_INTER | PAIR_UNION | PAIR_DIFF (role_a, role_b, ta, tb [, roi])

        Roles are mask_types (or the demo names saliency/attention); per
        image, role X's first mask is thresholded at ``> tX``.  ``roi``
        defaults to the full image; ``PAIR_DIFF(a, b, …)`` counts A∖B —
        swap the roles for B∖A.
        """
        self.next()
        self.expect("(")
        role_a = self._role()
        self.expect(",")
        role_b = self._role()
        self.expect(",")
        ta = self.number()
        self.expect(",")
        tb = self.number()
        roi = None
        if self.accept(","):
            roi = self._roi()
        self.expect(")")
        if fn == "IOU":
            return pair_iou(role_a, role_b, ta, tb, roi)
        return PairTerm(_PAIR_FNS[fn], role_a, role_b, ta, tb, roi)

    def _roi(self):
        tok = self.next()
        if tok.lower() == "roi":
            return "provided"
        if tok.lower() == "full_img":
            return None
        if tok == "(":
            vals = [self.number()]
            for _ in range(3):
                self.expect(",")
                vals.append(self.number())
            self.expect(")")
            return tuple(int(v) for v in vals)
        raise SyntaxError(f"bad ROI {tok!r}")

    def _range(self):
        self.expect("(")
        lv = self.number()
        self.expect(",")
        uv = self.number()
        self.expect(")")
        return lv, uv


def parse(sql: str) -> Query:
    """Parse a MaskSearch query string into an executable (compat) plan.

    A leading ``EXPLAIN [ANALYZE]`` is accepted in front of any query and
    recorded on :attr:`Query.explain` ("plan" / "analyze"); the rest of
    the statement parses exactly as it would alone."""
    with _trace.span("parse") as sp:
        tokens = _tokenize(sql)
        explain = None
        if tokens and tokens[0].upper() == "EXPLAIN":
            explain = "plan"
            tokens = tokens[1:]
            if tokens and tokens[0].upper() == "ANALYZE":
                explain = "analyze"
                tokens = tokens[1:]
        q = _Parser(tokens).parse()
        q.explain = explain
        sp.set(kind=q.kind, explain=explain or "")
    return q


def parse_plan(sql: str) -> LogicalPlan:
    """Parse straight to the composable IR (:class:`LogicalPlan`)."""
    return parse(sql).plan


def run(sql: str, store, **kw):
    """One-shot: parse + execute. Returns (result, stats)."""
    return parse(sql).run(store, **kw)


# Convenience used by examples: the paper's three scenario queries.
SCENARIO1_TOPK = (
    "SELECT mask_id FROM MasksDatabaseView "
    "ORDER BY CP(mask, roi, (0.8, 1.0)) / AREA(roi) ASC LIMIT 25;")
SCENARIO2_TOPK = (
    "SELECT mask_id FROM MasksDatabaseView "
    "ORDER BY CP(mask, full_img, (0.2, 0.6)) DESC LIMIT 25;")
SCENARIO3_IOU = (
    "SELECT image_id, CP(intersect(mask > 0.8), full_img, (0.5, 2.0)) "
    "/ CP(union(mask > 0.8), full_img, (0.5, 2.0)) AS iou "
    "FROM MasksDatabaseView WHERE mask_type IN (1, 2) "
    "GROUP BY image_id ORDER BY iou ASC LIMIT 25;")
SCENARIO6_DISCREPANCY = (
    "SELECT image_id FROM MasksDatabaseView "
    "ORDER BY IOU(saliency, attention, 0.6, 0.6) ASC LIMIT 25;")
