"""SQL-ish query front-end for MaskSearch (the demo GUI's "Query Command").

Supports the paper's textual query classes verbatim, e.g.::

    SELECT mask_id FROM MasksDatabaseView
    WHERE CP(mask, roi, (0.8, 1.0)) < 5000;

    SELECT mask_id FROM MasksDatabaseView
    ORDER BY CP(mask, full_img, (0.2, 0.6)) DESC LIMIT 25;

    SELECT image_id,
           CP(intersect(mask > 0.8), roi, (0.5, 2.0))
         / CP(union(mask > 0.8), roi, (0.5, 2.0)) AS iou
    FROM MasksDatabaseView WHERE mask_type IN (1, 2)
    GROUP BY image_id ORDER BY iou ASC LIMIT 25;

    SELECT SCALAR_AGG(AVG, CP(mask, roi, (0.9, 1.0))) FROM MasksDatabaseView;

plus arithmetic over CP terms and ``AREA(roi)`` for normalized counts
(Scenario 1).  ``roi`` refers to caller-provided per-mask rectangles (e.g.
YOLO boxes); ``full_img`` is the whole mask; a literal ``(r0, c0, r1, c1)``
rectangle is also accepted.  The parser builds the expression trees from
``core.exprs`` and a :class:`Query` plan executed by ``core.engine``.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

import numpy as np

from . import engine
from .exprs import CP, AggCP, BinOp, Const, Node, RoiArea

_TOKEN_RE = re.compile(r"""
      (?P<num>\d+\.\d*|\.\d+|\d+|inf)
    | (?P<name>[A-Za-z_][A-Za-z_0-9]*)
    | (?P<op>[(),+\-*/<>=;]|<=|>=)
""", re.VERBOSE)


def _tokenize(text: str):
    tokens = []
    i = 0
    text = text.strip()
    while i < len(text):
        if text[i].isspace():
            i += 1
            continue
        if text[i:i + 2] in ("<=", ">="):
            tokens.append(text[i:i + 2])
            i += 2
            continue
        m = _TOKEN_RE.match(text, i)
        if not m:
            raise SyntaxError(f"bad token at ...{text[i:i+20]!r}")
        tokens.append(m.group(0))
        i = m.end()
    return tokens


@dataclasses.dataclass
class Query:
    """A parsed + planned query, runnable against a MaskStore."""

    kind: str                      # "filter" | "topk" | "scalar_agg"
    select: str                    # "mask_id" | "image_id"
    expr: Optional[Node] = None
    op: Optional[str] = None
    threshold: Optional[float] = None
    k: Optional[int] = None
    desc: bool = True
    agg: Optional[str] = None
    mask_types: Optional[tuple] = None
    group_by_image: bool = False

    def run(self, store, *, provided_rois=None, use_index: bool = True,
            **kw):
        common = dict(mask_types=self.mask_types,
                      group_by_image=self.group_by_image,
                      provided_rois=provided_rois, use_index=use_index)
        if self.kind == "filter":
            return engine.filter_query(store, self.expr, self.op,
                                       self.threshold, **common, **kw)
        if self.kind == "topk":
            ids, scores, stats = engine.topk_query(
                store, self.expr, self.k, desc=self.desc, **common, **kw)
            return (ids, scores), stats
        if self.kind == "scalar_agg":
            common.pop("group_by_image")
            return engine.scalar_agg(store, self.expr, self.agg, **common, **kw)
        raise ValueError(self.kind)


class _Parser:
    def __init__(self, tokens):
        self.toks = tokens
        self.i = 0

    # -- token helpers ----------------------------------------------------
    def peek(self, off: int = 0):
        j = self.i + off
        return self.toks[j] if j < len(self.toks) else None

    def next(self):
        tok = self.peek()
        if tok is None:
            raise SyntaxError("unexpected end of query")
        self.i += 1
        return tok

    def expect(self, want: str):
        tok = self.next()
        if tok.upper() != want.upper():
            raise SyntaxError(f"expected {want!r}, got {tok!r}")
        return tok

    def accept(self, want: str) -> bool:
        if self.peek() is not None and self.peek().upper() == want.upper():
            self.i += 1
            return True
        return False

    def number(self) -> float:
        tok = self.next()
        if tok == "inf":
            return float("inf")
        try:
            return float(tok)
        except ValueError as e:
            raise SyntaxError(f"expected number, got {tok!r}") from e

    # -- grammar -----------------------------------------------------------
    def parse(self) -> Query:
        self.expect("SELECT")
        q = Query(kind="filter", select="mask_id")
        # select list — possibly SCALAR_AGG
        if (self.peek() or "").upper() == "SCALAR_AGG":
            self.next(); self.expect("(")
            q.agg = self.next().upper()
            self.expect(",")
            q.expr = self.expr()
            self.expect(")")
            q.kind = "scalar_agg"
        else:
            q.select = self.next()
            if q.select not in ("mask_id", "image_id"):
                raise SyntaxError(f"can only SELECT mask_id/image_id, got {q.select}")
            alias = {}
            while self.accept(","):
                e = self.expr()
                self.expect("AS")
                alias[self.next()] = e
            q._aliases = alias
        self.expect("FROM")
        self.next()  # view name, ignored
        # WHERE
        if self.accept("WHERE"):
            self._where(q)
        if self.accept("GROUP"):
            self.expect("BY")
            self.expect("image_id")
            q.group_by_image = True
        if self.accept("ORDER"):
            if q.expr is not None:
                # A CP WHERE predicate has no execution path under top-k;
                # refuse rather than silently rank the unfiltered set.
                raise SyntaxError(
                    "a CP WHERE predicate cannot be combined with ORDER BY "
                    "... LIMIT; only mask_type IN (...) filters compose "
                    "with rankings")
            self.expect("BY")
            nxt = self.peek()
            aliases = getattr(q, "_aliases", {})
            if nxt in aliases:
                self.next()
                order_expr = aliases[nxt]
            else:
                order_expr = self.expr()
            q.desc = True
            if self.accept("ASC"):
                q.desc = False
            else:
                self.accept("DESC")
            self.expect("LIMIT")
            q.k = int(self.number())
            q.kind = "topk"
            q.expr = order_expr
        self.accept(";")
        if q.kind == "filter" and q.expr is None:
            raise SyntaxError("filter query needs a CP predicate or ORDER BY")
        if q.select == "image_id":
            q.group_by_image = True
        return q

    def _where(self, q: Query):
        while True:
            if (self.peek() or "").lower() == "mask_type":
                self.next()
                self.expect("IN")
                self.expect("(")
                types = [int(self.number())]
                while self.accept(","):
                    types.append(int(self.number()))
                self.expect(")")
                q.mask_types = tuple(types)
            else:
                if q.expr is not None:
                    raise SyntaxError(
                        "multiple CP predicates in WHERE are not supported; "
                        "combine them into one expression")
                expr = self.expr()
                op = self.next()
                if op not in ("<", "<=", ">", ">="):
                    raise SyntaxError(f"bad comparison {op!r}")
                q.expr = expr
                q.op = op
                q.threshold = self.number()
            if not self.accept("AND"):
                break

    # expression grammar: expr := term (('+'|'-') term)*
    def expr(self) -> Node:
        node = self.term()
        while self.peek() in ("+", "-"):
            op = self.next()
            node = BinOp(op, node, self.term())
        return node

    def term(self) -> Node:
        node = self.factor()
        while self.peek() in ("*", "/"):
            op = self.next()
            node = BinOp(op, node, self.factor())
        return node

    def factor(self) -> Node:
        tok = self.peek()
        if tok is None:
            raise SyntaxError("unexpected end of query (expected expression)")
        if tok == "(":
            self.next()
            node = self.expr()
            self.expect(")")
            return node
        if tok.upper() == "CP":
            return self._cp()
        if tok.upper() == "AREA":
            self.next(); self.expect("(")
            roi = self._roi()
            self.expect(")")
            return RoiArea(roi)
        # number literal
        return Const(self.number())

    def _cp(self) -> Node:
        self.expect("CP"); self.expect("(")
        tok = self.peek() or ""
        if tok.lower() in ("intersect", "union", "mask_agg"):
            agg = self.next().lower()
            self.expect("(")
            self.expect("mask")
            thresh = 0.5
            if self.accept(">"):
                thresh = self.number()
            self.expect(")")
            if agg == "mask_agg":
                agg = "intersect"  # MASK_AGG default: thresholded intersection
            self.expect(",")
            roi = self._roi()
            self.expect(",")
            lv, uv = self._range()
            self.expect(")")
            del lv, uv  # aggregated mask is binary; range implied
            return AggCP(agg, thresh, roi)
        self.expect("mask")
        self.expect(",")
        roi = self._roi()
        self.expect(",")
        lv, uv = self._range()
        self.expect(")")
        return CP(roi, lv, uv)

    def _roi(self):
        tok = self.next()
        if tok.lower() == "roi":
            return "provided"
        if tok.lower() == "full_img":
            return None
        if tok == "(":
            vals = [self.number()]
            for _ in range(3):
                self.expect(",")
                vals.append(self.number())
            self.expect(")")
            return tuple(int(v) for v in vals)
        raise SyntaxError(f"bad ROI {tok!r}")

    def _range(self):
        self.expect("(")
        lv = self.number()
        self.expect(",")
        uv = self.number()
        self.expect(")")
        return lv, uv


def parse(sql: str) -> Query:
    """Parse a MaskSearch query string into an executable plan."""
    return _Parser(_tokenize(sql)).parse()


def run(sql: str, store, **kw):
    """One-shot: parse + execute. Returns (result, stats)."""
    return parse(sql).run(store, **kw)


# Convenience used by examples: the paper's three scenario queries.
SCENARIO1_TOPK = (
    "SELECT mask_id FROM MasksDatabaseView "
    "ORDER BY CP(mask, roi, (0.8, 1.0)) / AREA(roi) ASC LIMIT 25;")
SCENARIO2_TOPK = (
    "SELECT mask_id FROM MasksDatabaseView "
    "ORDER BY CP(mask, full_img, (0.2, 0.6)) DESC LIMIT 25;")
SCENARIO3_IOU = (
    "SELECT image_id, CP(intersect(mask > 0.8), full_img, (0.5, 2.0)) "
    "/ CP(union(mask > 0.8), full_img, (0.5, 2.0)) AS iou "
    "FROM MasksDatabaseView WHERE mask_type IN (1, 2) "
    "GROUP BY image_id ORDER BY iou ASC LIMIT 25;")
