"""Distributed MaskSearch — the query engine sharded over a TPU mesh.

The paper's prototype is single-node; this module is the beyond-paper
scale-out.  The mask DB (mask bytes + CHI tables + ROI table) is sharded
row-wise over every mesh axis (a DB of N masks becomes N/num_devices rows per
chip).  Four device-side *step* functions cover the engine's hot paths; each
is jit-compiled with explicit shardings and is what the multi-pod dry-run
lowers for the "masksearch" cells:

  * ``filter_bounds_step`` — CHI bounds + predicate verdicts for every local
    row.  Collective-free (embarrassingly parallel); one ``psum`` reports
    global accept/undecided counts.
  * ``verify_step``        — exact CP over a dense batch of survivor masks
    (the verification round; Pallas kernel on TPU).
  * ``topk_step``          — bound-driven distributed top-k: per-shard
    ``lax.top_k`` over upper bounds, ``all_gather`` of k candidates per
    shard, global threshold τ = k-th best lower bound, survivor flags.
  * ``iou_agg_step``       — fused thresholded intersection/union counts for
    group (MASK_AGG) queries.

Since the backend refactor these step functions are no longer a parallel
universe: :class:`repro.core.backend.MeshBackend` drives them from the
public query path (``run_plan(plan, backend="mesh")``) — the bounds step is
the CP leaf of every mesh bounds pass, ``verify_step`` answers verification
batches, ``topk_select_step`` is the ranking frontier's collective, and
``mask_agg_step`` serves MASK_AGG group verification.  The original
fused-verdict steps (``filter_bounds_step``/``topk_step``/``iou_agg_step``)
remain for the dry-run's lowered cells and the multi-device tests.

Device placement convention: rows are sharded over the flattened mesh
(``("pod","data","model")`` or ``("data","model")``); nothing is replicated
except the query descriptor scalars.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels import ops as kops
from . import chi as chi_lib
from . import cp as cp_lib
from .exprs import cell_counts_jnp, pair_cell_bounds_jnp

# shard_map moved out of jax.experimental (and check_rep became check_vma)
# across the jax versions this repo supports; resolve once here.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SHARD_MAP_KW = {"check_vma": False}
else:                                      # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_KW = {"check_rep": False}


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """Version-portable ``jax.make_mesh`` (``axis_types`` where supported)."""
    try:
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (TypeError, AttributeError):
        return jax.make_mesh(tuple(shape), tuple(axes))


def db_axes(mesh: Mesh) -> tuple[str, ...]:
    """All mesh axes — DB rows shard over the full device set."""
    return tuple(mesh.axis_names)


def row_sharding(mesh: Mesh, ndim: int) -> NamedSharding:
    return NamedSharding(mesh, P(db_axes(mesh), *([None] * (ndim - 1))))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Step functions (device-side hot paths)
# ---------------------------------------------------------------------------


def _bounds_from_corners(table, corners, area, kl_in, ku_in, kl_out, ku_out):
    """Same 8-corner math as chi._bounds_device, but with corner indices as
    device arrays (computed on device from boundary tables) so the whole
    bounds pass stays on-chip."""
    il, ih, jl, jh, ol, oh, pl, ph = [corners[:, i] for i in range(8)]
    inner_ok = (ih > il) & (jh > jl) & (ku_in > kl_in)
    lb = jnp.where(inner_ok,
                   chi_lib._lookup(table, il, ih, jl, jh,
                                   jnp.minimum(kl_in, ku_in), ku_in), 0)
    outer_ok = (oh > ol) & (ph > pl) & (ku_out > kl_out)
    ub = jnp.where(outer_ok,
                   chi_lib._lookup(table, ol, oh, pl, ph,
                                   jnp.minimum(kl_out, ku_out), ku_out), 0)
    ub = jnp.minimum(ub, area.astype(ub.dtype))
    lb = jnp.minimum(lb, ub)
    return lb.astype(jnp.int32), ub.astype(jnp.int32)


def device_resolve(rois, row_bounds, col_bounds):
    """Device-side resolve_query: map pixel ROIs onto grid corners.

    rois (N, 4) int32; boundary tables (G+1,) int32 (replicated — tiny).
    Returns corners (N, 8) int32 + area (N,).
    """
    r0, c0, r1, c1 = rois[:, 0], rois[:, 1], rois[:, 2], rois[:, 3]
    il = jnp.searchsorted(row_bounds, r0, side="left")
    ih = jnp.searchsorted(row_bounds, r1, side="right") - 1
    jl = jnp.searchsorted(col_bounds, c0, side="left")
    jh = jnp.searchsorted(col_bounds, c1, side="right") - 1
    ol = jnp.searchsorted(row_bounds, r0, side="right") - 1
    oh = jnp.searchsorted(row_bounds, r1, side="left")
    pl = jnp.searchsorted(col_bounds, c0, side="right") - 1
    ph = jnp.searchsorted(col_bounds, c1, side="left")
    g = row_bounds.shape[0] - 1
    corners = jnp.stack([il, ih, jl, jh, ol, oh, pl, ph], axis=1)
    corners = jnp.clip(corners, 0, g).astype(jnp.int32)
    area = (jnp.maximum(r1 - r0, 0) * jnp.maximum(c1 - c0, 0)).astype(jnp.int32)
    return corners, area


def make_filter_bounds_step(mesh: Mesh, op: str = "<"):
    """Build the jitted distributed bounds+verdict pass.

    Signature: (chi_tables (N,G+1,G+1,NB+1), rois (N,4), row_bounds, col_bounds,
                value_ks (4,) int32 [kl_in,ku_in,kl_out,ku_out], threshold ())
      → accept (N,) bool, undecided (N,) bool, counts (2,) int32 global.
    """
    axes = db_axes(mesh)

    def step(tables, rois, row_bounds, col_bounds, value_ks, threshold):
        corners, area = device_resolve(rois, row_bounds, col_bounds)
        kl_in, ku_in, kl_out, ku_out = (value_ks[0], value_ks[1],
                                        value_ks[2], value_ks[3])
        lb, ub = _bounds_from_corners(tables, corners, area,
                                      kl_in, ku_in, kl_out, ku_out)
        if op in ("<", "<="):
            accept = (ub < threshold) if op == "<" else (ub <= threshold)
            reject = (lb >= threshold) if op == "<" else (lb > threshold)
        else:
            accept = (lb > threshold) if op == ">" else (lb >= threshold)
            reject = (ub <= threshold) if op == ">" else (ub < threshold)
        undecided = ~(accept | reject)
        counts = jnp.stack([jnp.sum(accept.astype(jnp.int32)),
                            jnp.sum(undecided.astype(jnp.int32))])
        return accept, undecided, counts

    row = NamedSharding(mesh, P(axes))
    row2 = NamedSharding(mesh, P(axes, None))
    rep = replicated(mesh)
    return jax.jit(
        step,
        in_shardings=(NamedSharding(mesh, P(axes, None, None, None)),
                      row2, rep, rep, rep, rep),
        out_shardings=(row, row, rep),
    )


def make_verify_step(mesh: Mesh):
    """Exact CP over a dense survivor batch, rows sharded over all devices.

    Signature: (masks (V,H,W), rois (V,4), lv (), uv ()) → counts (V,) int32.
    On TPU this dispatches to the Pallas ``cp_count`` kernel; the jnp path is
    the portable fallback (identical semantics — see kernels/ops.py).
    """
    axes = db_axes(mesh)

    def step(masks, rois, lv, uv):
        return kops.cp_count(masks, rois, lv, uv)

    return jax.jit(
        step,
        in_shardings=(NamedSharding(mesh, P(axes, None, None)),
                      NamedSharding(mesh, P(axes, None)),
                      replicated(mesh), replicated(mesh)),
        out_shardings=NamedSharding(mesh, P(axes)),
    )


def make_topk_step(mesh: Mesh, k: int, desc: bool = True):
    """Bound-driven distributed top-k candidate selection (one shard_map).

    Per device: bounds → local top-k upper bounds (optimistic candidates) and
    local top-k lower bounds (pessimistic threshold contributors).  One
    ``all_gather`` each merges them; τ = k-th best gathered lower bound; every
    local row with ub ≥ τ survives to verification.

    Signature: (chi_tables, rois, row_bounds, col_bounds, value_ks)
      → (cand_vals (D*k,), cand_ids (D*k,), tau (), survivors (N,) bool)
    """
    axes = db_axes(mesh)
    n_dev = int(np.prod([mesh.shape[a] for a in axes]))

    def local(tables, rois, row_bounds, col_bounds, value_ks, base_ids):
        corners, area = device_resolve(rois, row_bounds, col_bounds)
        lb, ub = _bounds_from_corners(
            tables, corners, area,
            value_ks[0], value_ks[1], value_ks[2], value_ks[3])
        score_opt = ub if desc else -lb
        score_pes = lb if desc else -ub
        top_opt, idx_opt = jax.lax.top_k(score_opt, k)
        top_pes, _ = jax.lax.top_k(score_pes, k)
        gathered_opt = jax.lax.all_gather(top_opt, axes, tiled=True)
        gathered_ids = jax.lax.all_gather(base_ids[idx_opt], axes, tiled=True)
        gathered_pes = jax.lax.all_gather(top_pes, axes, tiled=True)
        # τ: k-th best pessimistic score globally
        tau = jax.lax.top_k(gathered_pes, k)[0][-1]
        survivors = score_opt >= tau
        return gathered_opt, gathered_ids, tau, survivors

    mapped = _shard_map(
        local, mesh=mesh,
        in_specs=(P(axes, None, None, None), P(axes, None), P(), P(), P(),
                  P(axes)),
        out_specs=(P(), P(), P(), P(axes)),
        **_SHARD_MAP_KW,
    )
    return jax.jit(mapped), n_dev * k


def value_ks(cfg: chi_lib.CHIConfig, lv: float, uv: float) -> np.ndarray:
    """Resolve a value range onto CHI bin edges as the 4-vector
    ``[kl_in, ku_in, kl_out, ku_out]`` (inner/outer threshold-prefix
    indices) — the host-side half of a device bounds pass.  Matches
    :func:`repro.core.chi.resolve_query`'s value resolution exactly."""
    edges = cfg.edges
    kl_in = np.searchsorted(edges, lv, side="left")
    ku_in = np.searchsorted(edges, uv, side="right") - 1
    kl_out = np.searchsorted(edges, lv, side="right") - 1
    ku_out = np.searchsorted(edges, uv, side="left")
    return np.clip(np.array([kl_in, ku_in, kl_out, ku_out], dtype=np.int32),
                   0, cfg.num_bins)


def make_chi_bounds_step(mesh: Mesh):
    """The CP-leaf bounds pass, sharded: CHI tables in, (lb, ub) out.

    Collective-free (each row's 8-corner gather is local); this is what the
    mesh backend runs once per distinct CP term of a plan — the generic
    analogue of ``filter_bounds_step``, which additionally folds in one
    comparison verdict.

    Signature: (chi_tables (N,G+1,G+1,NB+1), rois (N,4), row_bounds,
                col_bounds, value_ks (4,) int32) → lb (N,), ub (N,) int32.
    """
    axes = db_axes(mesh)

    def step(tables, rois, row_bounds, col_bounds, ks):
        corners, area = device_resolve(rois, row_bounds, col_bounds)
        return _bounds_from_corners(tables, corners, area,
                                    ks[0], ks[1], ks[2], ks[3])

    row = NamedSharding(mesh, P(axes))
    rep = replicated(mesh)
    return jax.jit(
        step,
        in_shardings=(NamedSharding(mesh, P(axes, None, None, None)),
                      NamedSharding(mesh, P(axes, None)), rep, rep, rep),
        out_shardings=(row, row),
    )


def make_topk_select_step(mesh: Mesh, k: int):
    """Distributed selection of the global k-th best pessimistic score.

    The collective at the heart of ``topk_step``, but over *precomputed*
    bounds scores instead of re-deriving them from CHI tables — so any
    ranking expression the plan IR can express (ratios, sums of CPs)
    shards.  Per device: mask non-definite rows to −inf, local top-k, one
    ``all_gather`` of (value, row-id) pairs, global top-k.  Returns the
    *row id* of the k-th best so the caller can read the threshold τ back
    at full host precision rather than float32.

    Signature: (pes (N,) f32, definite (N,) bool, base_ids (N,) int32)
      → () int32 row id of the global k-th best definite pessimistic score.
    """
    axes = db_axes(mesh)

    def local(pes, definite, base_ids):
        masked = jnp.where(definite, pes, -jnp.inf)
        kk = min(k, masked.shape[0])
        vals, idx = jax.lax.top_k(masked, kk)
        g_vals = jax.lax.all_gather(vals, axes, tiled=True)
        g_ids = jax.lax.all_gather(base_ids[idx], axes, tiled=True)
        order = jax.lax.top_k(g_vals, k)[1]
        return g_ids[order[k - 1]]

    mapped = _shard_map(local, mesh=mesh,
                        in_specs=(P(axes), P(axes), P(axes)),
                        out_specs=P(), **_SHARD_MAP_KW)
    return jax.jit(mapped)


def make_mask_agg_step(mesh: Mesh):
    """Fused thresholded intersection/union *counts* for MASK_AGG group
    verification, group rows sharded over all devices (the counts-level
    sibling of ``iou_agg_step``; on TPU dispatches to the Pallas
    ``mask_agg`` kernel).

    Signature: (group_masks (G,S,H,W), rois (G,4), thresh ())
      → (inter (G,), union (G,)) int32.
    """
    axes = db_axes(mesh)

    def step(group_masks, rois, thresh):
        return kops.mask_agg_counts(group_masks, rois, thresh)

    row = NamedSharding(mesh, P(axes))
    return jax.jit(
        step,
        in_shardings=(NamedSharding(mesh, P(axes, None, None, None)),
                      NamedSharding(mesh, P(axes, None)), replicated(mesh)),
        out_shardings=(row, row),
    )


def make_cp_multi_step(mesh: Mesh):
    """Fused multi-descriptor CP over one sharded mask batch — the service
    scheduler's cross-query verification pass on the mesh (Q descriptors
    answered from one pass over the sharded bytes).

    Signature: (masks (B,H,W), rois (Q,B,4), lvs (Q,), uvs (Q,))
      → counts (Q,B) int32.
    """
    axes = db_axes(mesh)

    def step(masks, rois, lvs, uvs):
        return kops.cp_count_multi(masks, rois, lvs, uvs)

    return jax.jit(
        step,
        in_shardings=(NamedSharding(mesh, P(axes, None, None)),
                      NamedSharding(mesh, P(None, axes, None)),
                      replicated(mesh), replicated(mesh)),
        out_shardings=NamedSharding(mesh, P(None, axes)),
    )


def make_pair_counts_step(mesh: Mesh):
    """Fused dual-mask pair counts, pair rows sharded over all devices —
    the mesh backend's verification pass for the discrepancy (pair) query
    class (DESIGN.md §9).  The i-th rows of ``masks_a`` and ``masks_b``
    are one image's role pair and shard to the same device, so the kernel
    runs collective-free; on TPU it dispatches to the Pallas
    ``pair_count`` kernel.

    Signature: (masks_a (B,H,W), masks_b (B,H,W), rois (B,4), ta (), tb ())
      → (inter (B,), union (B,), diff (B,)) int32.
    """
    axes = db_axes(mesh)

    def step(masks_a, masks_b, rois, ta, tb):
        return kops.pair_counts(masks_a, masks_b, rois, ta, tb)

    row = NamedSharding(mesh, P(axes))
    rep = replicated(mesh)
    return jax.jit(
        step,
        in_shardings=(NamedSharding(mesh, P(axes, None, None)),
                      NamedSharding(mesh, P(axes, None, None)),
                      NamedSharding(mesh, P(axes, None)), rep, rep),
        out_shardings=(row, row, row),
    )


def make_pair_cells_step(mesh: Mesh, stat: str):
    """The pair-term *bounds* pass on the mesh (DESIGN.md §13): the
    cell-decomposed sound combination of both roles' CHI rows
    (:func:`repro.core.exprs.pair_cell_bounds_jnp`), pair rows sharded
    over all devices.  Collective-free — each pair's cell math reads only
    its own two CHI rows — so, like the CP-leaf bounds step, the pair
    filter phase leaves the host entirely.  Padded rows (zero tables +
    zero ROIs) yield lb = ub = 0 and are sliced off by the caller.

    Signature: (tables_a (B,G+1,G+1,NB+1), tables_b (B,G+1,G+1,NB+1),
                rois (B,4), ks (4,) int32 [ka_in, ka_out, kb_in, kb_out],
                row_bounds (G+1,), col_bounds (G+1,))
      → (lb (B,), ub (B,)) int32.
    """
    axes = db_axes(mesh)

    def step(tables_a, tables_b, rois, ks, row_bounds, col_bounds):
        lo_a = cell_counts_jnp(tables_a, ks[0])
        hi_a = cell_counts_jnp(tables_a, ks[1])
        lo_b = cell_counts_jnp(tables_b, ks[2])
        hi_b = cell_counts_jnp(tables_b, ks[3])
        return pair_cell_bounds_jnp(stat, lo_a, hi_a, lo_b, hi_b,
                                    rois, row_bounds, col_bounds)

    row = NamedSharding(mesh, P(axes))
    rep = replicated(mesh)
    return jax.jit(
        step,
        in_shardings=(NamedSharding(mesh, P(axes, None, None, None)),
                      NamedSharding(mesh, P(axes, None, None, None)),
                      NamedSharding(mesh, P(axes, None)), rep, rep, rep),
        out_shardings=(row, row),
    )


# -- bitpacked binary-mask tier (DESIGN.md §12) -----------------------------
# Packed variants of the verification steps: identical shardings (the word
# axis replaces the pixel-column axis, rank for rank), kernel dispatch
# swapped for the popcount family.  Pair/agg thresholds are float32 — the
# packed wrappers derive integer flags from them; the uint32 words never
# meet a float lane.


def make_verify_packed_step(mesh: Mesh):
    """``make_verify_step`` over packed words.

    Signature: (packed (V,H,words) uint32, rois (V,4), lv (), uv ())
      → counts (V,) int32.
    """
    axes = db_axes(mesh)

    def step(packed, rois, lv, uv):
        return kops.cp_count_packed(packed, rois, lv, uv)

    return jax.jit(
        step,
        in_shardings=(NamedSharding(mesh, P(axes, None, None)),
                      NamedSharding(mesh, P(axes, None)),
                      replicated(mesh), replicated(mesh)),
        out_shardings=NamedSharding(mesh, P(axes)),
    )


def make_cp_multi_packed_step(mesh: Mesh):
    """``make_cp_multi_step`` over packed words.

    Signature: (packed (B,H,words), rois (Q,B,4), lvs (Q,), uvs (Q,))
      → counts (Q,B) int32.
    """
    axes = db_axes(mesh)

    def step(packed, rois, lvs, uvs):
        return kops.cp_count_multi_packed(packed, rois, lvs, uvs)

    return jax.jit(
        step,
        in_shardings=(NamedSharding(mesh, P(axes, None, None)),
                      NamedSharding(mesh, P(None, axes, None)),
                      replicated(mesh), replicated(mesh)),
        out_shardings=NamedSharding(mesh, P(None, axes)),
    )


def make_mask_agg_packed_step(mesh: Mesh):
    """``make_mask_agg_step`` over packed words.

    Signature: (group_packed (G,S,H,words), rois (G,4), thresh () f32)
      → (inter (G,), union (G,)) int32.
    """
    axes = db_axes(mesh)

    def step(group_packed, rois, thresh):
        return kops.mask_agg_counts_packed(group_packed, rois, thresh)

    row = NamedSharding(mesh, P(axes))
    return jax.jit(
        step,
        in_shardings=(NamedSharding(mesh, P(axes, None, None, None)),
                      NamedSharding(mesh, P(axes, None)), replicated(mesh)),
        out_shardings=(row, row),
    )


def make_pair_counts_packed_step(mesh: Mesh):
    """``make_pair_counts_step`` over packed words.

    Signature: (packed_a (B,H,words), packed_b (B,H,words), rois (B,4),
                ta () f32, tb () f32)
      → (inter (B,), union (B,), diff (B,)) int32.
    """
    axes = db_axes(mesh)

    def step(packed_a, packed_b, rois, ta, tb):
        return kops.pair_counts_packed(packed_a, packed_b, rois, ta, tb)

    row = NamedSharding(mesh, P(axes))
    rep = replicated(mesh)
    return jax.jit(
        step,
        in_shardings=(NamedSharding(mesh, P(axes, None, None)),
                      NamedSharding(mesh, P(axes, None, None)),
                      NamedSharding(mesh, P(axes, None)), rep, rep),
        out_shardings=(row, row, row),
    )


def make_fused_verify_step(mesh: Mesh):
    """The bounds+verify megakernel on the mesh: batch rows shard over all
    devices, the Q descriptor axis (rois/decided/lb) shards with them on
    the batch dimension, and every shard answers its rows collective-free
    in one launch.

    Signature: (packed (B,H,words), rois (Q,B,4), lvs (Q,), uvs (Q,),
                decided (Q,B) int32, lb (Q,B) int32)
      → counts (Q,B) int32.
    """
    axes = db_axes(mesh)

    def step(packed, rois, lvs, uvs, decided, lb):
        return kops.fused_bounds_verify(packed, rois, lvs, uvs, decided, lb)

    rep = replicated(mesh)
    qb = NamedSharding(mesh, P(None, axes))
    return jax.jit(
        step,
        in_shardings=(NamedSharding(mesh, P(axes, None, None)),
                      NamedSharding(mesh, P(None, axes, None)),
                      rep, rep, qb, qb),
        out_shardings=qb,
    )


def make_iou_agg_step(mesh: Mesh):
    """Fused group IoU: masks (Ngroups, n_types, H, W) → IoU scores.

    Signature: (group_masks, rois (Ngroups,4), thresh ()) → iou (Ngroups,) f32.
    On TPU dispatches to the Pallas ``mask_agg_iou`` kernel.
    """
    axes = db_axes(mesh)

    def step(group_masks, rois, thresh):
        binary = group_masks > thresh
        inter = jnp.all(binary, axis=1)
        union = jnp.any(binary, axis=1)
        h, w = group_masks.shape[-2:]
        inside = cp_lib._roi_mask(rois, h, w)
        inter_ct = jnp.sum(inter & inside, axis=(1, 2)).astype(jnp.float32)
        union_ct = jnp.sum(union & inside, axis=(1, 2)).astype(jnp.float32)
        return jnp.where(union_ct > 0, inter_ct / jnp.maximum(union_ct, 1), 0.0)

    return jax.jit(
        step,
        in_shardings=(NamedSharding(mesh, P(axes, None, None, None)),
                      NamedSharding(mesh, P(axes, None)),
                      replicated(mesh)),
        out_shardings=NamedSharding(mesh, P(axes)),
    )


# ---------------------------------------------------------------------------
# Host-side distributed query driver (runs the steps; used on real meshes and
# in the multi-device CPU tests)
# ---------------------------------------------------------------------------


class DistributedEngine:
    """Thin host orchestrator over the step functions for a sharded DB."""

    def __init__(self, mesh: Mesh, cfg: chi_lib.CHIConfig):
        self.mesh = mesh
        self.cfg = cfg
        self._filter_steps: dict[str, object] = {}
        self._verify = make_verify_step(mesh)
        self._topk_steps: dict[tuple, object] = {}

    def _value_ks(self, lv: float, uv: float) -> np.ndarray:
        return value_ks(self.cfg, lv, uv)

    def filter_bounds(self, tables, rois, lv, uv, op, threshold):
        if op not in self._filter_steps:
            self._filter_steps[op] = make_filter_bounds_step(self.mesh, op)
        rb = jnp.asarray(self.cfg.row_bounds, jnp.int32)
        cb = jnp.asarray(self.cfg.col_bounds, jnp.int32)
        return self._filter_steps[op](
            tables, jnp.asarray(rois, jnp.int32), rb, cb,
            jnp.asarray(self._value_ks(lv, uv)),
            jnp.asarray(threshold, jnp.int32))

    def verify(self, masks, rois, lv, uv):
        return self._verify(masks, jnp.asarray(rois, jnp.int32),
                            jnp.float32(lv), jnp.float32(uv))

    def topk_candidates(self, tables, rois, lv, uv, k, desc=True, ids=None):
        key = (k, desc)
        if key not in self._topk_steps:
            self._topk_steps[key] = make_topk_step(self.mesh, k, desc)[0]
        n = tables.shape[0]
        if ids is None:
            ids = jnp.arange(n, dtype=jnp.int32)
        rb = jnp.asarray(self.cfg.row_bounds, jnp.int32)
        cb = jnp.asarray(self.cfg.col_bounds, jnp.int32)
        return self._topk_steps[key](
            tables, jnp.asarray(rois, jnp.int32), rb, cb,
            jnp.asarray(self._value_ks(lv, uv)), ids)
