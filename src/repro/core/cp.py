"""CP — the paper's "Count Pixels" function.

``CP(mask, roi, (lv, uv))`` counts pixels of ``mask`` inside the rectangular
region-of-interest ``roi`` whose value falls in the half-open range
``[lv, uv)``.  This module holds the *exact* (non-indexed) implementations:

* :func:`cp_exact` — batched jnp implementation (the verification path of the
  filter-verification engine, and the full-scan baseline).
* :func:`cp_exact_np` — numpy oracle used by tests and the disk-tier scan.

ROI convention (used everywhere in this codebase):
    ``roi = (r0, c0, r1, c1)`` — half-open pixel rectangle
    ``rows r0 <= r < r1``, ``cols c0 <= c < c1``.
A ``None`` ROI means the full mask (the paper's ``full_img``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def full_roi(height: int, width: int) -> np.ndarray:
    """The ROI covering the whole mask (paper's ``full_img``)."""
    return np.array([0, 0, height, width], dtype=np.int32)


def normalize_rois(rois, batch: int, height: int, width: int) -> np.ndarray:
    """Broadcast/validate ROIs to an ``(B, 4)`` int32 array, clipped to bounds."""
    if rois is None:
        rois = np.tile(full_roi(height, width), (batch, 1))
    rois = np.asarray(rois, dtype=np.int32)
    if rois.ndim == 1:
        rois = np.tile(rois[None, :], (batch, 1))
    if rois.shape != (batch, 4):
        raise ValueError(f"rois must have shape ({batch}, 4), got {rois.shape}")
    out = rois.copy()
    out[:, 0] = np.clip(rois[:, 0], 0, height)
    out[:, 1] = np.clip(rois[:, 1], 0, width)
    out[:, 2] = np.clip(rois[:, 2], 0, height)
    out[:, 3] = np.clip(rois[:, 3], 0, width)
    return out


def roi_area(rois: np.ndarray) -> np.ndarray:
    """Pixel area of each half-open ROI rectangle; shape ``(B,)``."""
    rois = np.asarray(rois)
    h = np.maximum(rois[..., 2] - rois[..., 0], 0)
    w = np.maximum(rois[..., 3] - rois[..., 1], 0)
    return (h * w).astype(np.int64)


def _roi_mask(rois: Array, height: int, width: int) -> Array:
    """(B, H, W) bool — True inside each mask's ROI.  Built from iotas so it
    fuses with the compare+reduce instead of materializing per-mask maps."""
    rr = jax.lax.broadcasted_iota(jnp.int32, (1, height, width), 1)
    cc = jax.lax.broadcasted_iota(jnp.int32, (1, height, width), 2)
    r0 = rois[:, 0][:, None, None]
    c0 = rois[:, 1][:, None, None]
    r1 = rois[:, 2][:, None, None]
    c1 = rois[:, 3][:, None, None]
    return (rr >= r0) & (rr < r1) & (cc >= c0) & (cc < c1)


@functools.partial(jax.jit, static_argnames=())
def cp_exact(masks: Array, rois: Array, lv: Array, uv: Array) -> Array:
    """Exact CP for a batch.

    Args:
      masks: ``(B, H, W)`` float array, values in ``[0, 1)``.
      rois:  ``(B, 4)`` int32 half-open rectangles.
      lv/uv: scalars (or ``(B,)``) — half-open value range ``[lv, uv)``.

    Returns:
      ``(B,)`` int32 pixel counts.
    """
    b, h, w = masks.shape
    lv = jnp.asarray(lv)
    uv = jnp.asarray(uv)
    if lv.ndim == 1:
        lv = lv[:, None, None]
    if uv.ndim == 1:
        uv = uv[:, None, None]
    inside = _roi_mask(rois, h, w)
    in_range = (masks >= lv) & (masks < uv)
    return jnp.sum(inside & in_range, axis=(1, 2)).astype(jnp.int32)


def cp_exact_np(mask: np.ndarray, roi, lv: float, uv: float) -> int:
    """Pure-numpy oracle for a single mask (used by tests + disk full-scan)."""
    h, w = mask.shape
    if roi is None:
        roi = (0, 0, h, w)
    r0, c0, r1, c1 = (int(x) for x in roi)
    r0, r1 = max(r0, 0), min(r1, h)
    c0, c1 = max(c0, 0), min(c1, w)
    if r1 <= r0 or c1 <= c0:
        return 0
    window = mask[r0:r1, c0:c1]
    return int(np.count_nonzero((window >= lv) & (window < uv)))


@functools.partial(jax.jit, static_argnames=())
def cp_exact_multi(masks: Array, rois: Array, lvs: Array, uvs: Array) -> Array:
    """Exact CP for B masks × Q (roi, range) descriptors.

    Args:
      masks: ``(B, H, W)``.
      rois:  ``(Q, B, 4)`` or ``(Q, 4)`` (broadcast over masks).
      lvs/uvs: ``(Q,)``.

    Returns:
      ``(Q, B)`` int32 — one CP table per descriptor.  Used by the
      multi-query engine so one pass over the mask bytes serves every query
      in the workload (the paper's multi-query optimization).
    """
    if rois.ndim == 2:
        rois = jnp.broadcast_to(rois[:, None, :], (rois.shape[0], masks.shape[0], 4))

    def one(roi_q, lv_q, uv_q):
        return cp_exact(masks, roi_q, lv_q, uv_q)

    return jax.vmap(one)(rois, lvs, uvs)
