"""masklint — the repo's own static-analysis pass (DESIGN.md §11).

Run it as ``python -m repro.analysis``; see ``--list`` for the rule set
and ``--explain <rule>`` for the invariant each rule enforces.  The
package is pure-stdlib (``ast`` only): it never imports the code under
analysis, so it runs without jax/numpy installed.
"""

from .core import (Finding, ModuleCtx, Rule, RunResult, all_rules,
                   report_json, report_text, run_paths)

__all__ = ["Finding", "ModuleCtx", "Rule", "RunResult", "all_rules",
           "report_json", "report_text", "run_paths"]
