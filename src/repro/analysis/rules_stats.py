"""Stats-reflection drift: stats dataclasses must stay absorbable by the
obs/metrics.py reflection samplers and reset/merge machinery."""

from __future__ import annotations

import ast
import re

from .core import Finding, ModuleCtx, Rule, call_name, register

_STATS_NAME_RE = re.compile(r"(Stats|Info)$")
_NUMERIC = {"int", "float"}


def _is_dataclass(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        d = dec.func if isinstance(dec, ast.Call) else dec
        if (isinstance(d, ast.Name) and d.id == "dataclass") or \
                (isinstance(d, ast.Attribute) and d.attr == "dataclass"):
            return True
    return False


def _ann_name(ann: ast.AST) -> str:
    if isinstance(ann, ast.Name):
        return ann.id
    if isinstance(ann, ast.Attribute):
        return ann.attr
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        return ann.value
    return ast.unparse(ann)


def _uses_fields_reflection(fn: ast.FunctionDef) -> bool:
    return any(isinstance(n, ast.Call) and call_name(n) in
               ("fields", "asdict", "astuple", "replace")
               for n in ast.walk(fn))


@register
class StatsDriftRule(Rule):
    name = "stats-drift"
    summary = ("*Stats/*Info dataclass fields must stay visible to the "
               "metrics reflection samplers and reset/merge machinery")
    doc = """\
Invariant: every dataclass named *Stats or *Info keeps the shape the
reflection machinery relies on —

* every field is annotated `int` or `float` (obs/metrics.py's
  dataclass_sampler iterates dataclasses.fields and silently *skips*
  anything non-numeric, so a str/bool/list field simply vanishes from
  /metrics with no error);
* every field has a default (reset() restores `f.default` per field —
  a default-less field breaks reflection reset, and dataclass ordering);
* reset()/merge(), where present, iterate dataclasses.fields(...) (or
  asdict) instead of hand-listing attributes;
* as_dict(), where present, goes through asdict/fields, or its literal
  dict covers every declared field.

Why it holds: the observability PR deliberately built samplers, reset,
and merge on reflection so that adding a counter to ExecStats/IOStats/
CacheStats/SchedulerStats is a one-line change that automatically
appears in /metrics, EXPLAIN ANALYZE, and the phase summaries.  The
failure mode is *drift*: a hand-listed reset() keeps compiling after a
field is added, silently carrying the new counter across runs —
test_stats_consistency.py catches some of this at test time; this rule
catches all of it at lint time.

Violation examples:

    @dataclasses.dataclass
    class IngestStats:
        rows: int = 0
        source: str = ""          # vanishes from /metrics silently

    def reset(self):
        self.rows = 0             # next field added -> stale carry-over

Fix: keep stats dataclasses purely numeric (put labels/identity on the
metric family, not the stats object), give every field a default, and
write reset/merge as `for f in dataclasses.fields(self): ...`.
Non-stats dataclasses that merely end in ...Stats/...Info should be
renamed or suppressed with a reason.
"""

    def check_module(self, ctx: ModuleCtx) -> list[Finding]:
        findings: list[Finding] = []
        for cls in ast.walk(ctx.tree):
            if not (isinstance(cls, ast.ClassDef)
                    and _STATS_NAME_RE.search(cls.name)
                    and _is_dataclass(cls)):
                continue
            fields: list[str] = []
            for stmt in cls.body:
                if not (isinstance(stmt, ast.AnnAssign)
                        and isinstance(stmt.target, ast.Name)):
                    continue
                ann = _ann_name(stmt.annotation)
                if "ClassVar" in ast.unparse(stmt.annotation):
                    continue
                name = stmt.target.id
                fields.append(name)
                if ann not in _NUMERIC:
                    findings.append(ctx.finding(
                        self.name, stmt,
                        f"{cls.name}.{name} is annotated {ann!r} — "
                        f"dataclass_sampler only absorbs int/float "
                        f"fields; this one silently drops out of "
                        f"/metrics"))
                if stmt.value is None:
                    findings.append(ctx.finding(
                        self.name, stmt,
                        f"{cls.name}.{name} has no default — reflection "
                        f"reset() restores f.default per field and "
                        f"cannot handle default-less fields"))
            for meth in cls.body:
                if not isinstance(meth, ast.FunctionDef):
                    continue
                if meth.name in ("reset", "merge") \
                        and not _uses_fields_reflection(meth):
                    findings.append(ctx.finding(
                        self.name, meth,
                        f"{cls.name}.{meth.name} hand-lists attributes — "
                        f"iterate dataclasses.fields(self) so a new "
                        f"field cannot silently escape "
                        f"{meth.name}"))
                elif meth.name == "as_dict" \
                        and not _uses_fields_reflection(meth):
                    covered: set[str] = set()
                    for n in ast.walk(meth):
                        if isinstance(n, ast.Dict):
                            covered.update(
                                k.value for k in n.keys
                                if isinstance(k, ast.Constant)
                                and isinstance(k.value, str))
                    missing = [f for f in fields if f not in covered]
                    if missing:
                        findings.append(ctx.finding(
                            self.name, meth,
                            f"{cls.name}.as_dict omits field(s) "
                            f"{', '.join(missing)} — use "
                            f"dataclasses.asdict or cover every field"))
        return findings
