"""Epoch rules: cache keys must thread the epoch; no snapshot bypass."""

from __future__ import annotations

import ast

from .core import (Finding, ModuleCtx, Rule, call_name, mentions_identifier,
                   register)

# function name -> 0-based positional index where the epoch argument lands
# (matching the signatures in service/planner.py)
_KEYED_CALLS = {
    "result_key": 3,       # (plan_or_query, roi_sig, backend, epoch)
    "bounds_key": 4,       # (expr, plan_or_query, roi_sig, backend, epoch)
    "cached_result": 3,    # (plan_or_query, roi_sig, backend, epoch)
    "store_result": 4,     # (plan_or_query, roi_sig, payload, backend, epoch)
}

# calls whose keys also carry the CHI pyramid tier (keyword-only in the
# signature, so only the kwarg form exists)
_TIERED_CALLS = {"bounds_key"}


@register
class EpochDisciplineRule(Rule):
    name = "epoch-discipline"
    summary = ("planner cache-key constructions must thread the store "
               "epoch explicitly")
    doc = """\
Invariant: every call to the planner's key constructors and cache tiers —
result_key / bounds_key / cached_result / store_result — passes an epoch
argument whose expression actually derives from an epoch (store.epoch,
self._epoch, run.epoch, ...).  Omitting it silently binds the signature
default (epoch=0); hardcoding a literal pins one epoch forever.
Since the CHI-pyramid PR, bounds_key additionally carries the tier the
bounds were computed at: callers must pass ``tier=<variable>``.  Omitting
it binds tier=0, and hardcoding a literal pins one tier — either way a
coarse-tier interval (which soundly *contains* the fine one) can be
served for a refined request, silently widening bounds.

Why it holds: since the mutable-store PR, cache keys end in an `e<epoch>`
component and Planner.evict_dead_epochs sweeps keys from superseded
epochs.  A key built without the epoch aliases across mutations: a result
computed before an ingest/delete is served after it — the exact
wrong-answers-not-crashes failure mode the epoch machinery exists to
prevent (bounds refer to rows that moved; ids map to different masks).

Violation example:

    payload = planner.cached_result(plan, roi_sig, backend.name)
    #                               ^ no epoch: epoch=0 default binds,
    #                                 pre-mutation results leak forward

Fix: pass `epoch=self.store.epoch` (services) or thread the pinned run
epoch.  Calls that intentionally address a single immutable store can
suppress with `# masklint: ignore[epoch-discipline] -- <why>`.
"""

    def check_module(self, ctx: ModuleCtx) -> list[Finding]:
        findings: list[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            fname = call_name(node)
            if fname not in _KEYED_CALLS:
                continue
            pos = _KEYED_CALLS[fname]
            epoch_arg = next((kw.value for kw in node.keywords
                              if kw.arg == "epoch"), None)
            if epoch_arg is None and len(node.args) > pos:
                epoch_arg = node.args[pos]
            if epoch_arg is None:
                findings.append(ctx.finding(
                    self.name, node,
                    f"{fname}(...) without an epoch argument — the "
                    f"epoch=0 default binds and cached entries alias "
                    f"across store mutations"))
            elif isinstance(epoch_arg, ast.Constant):
                findings.append(ctx.finding(
                    self.name, node,
                    f"{fname}(...) hardcodes epoch={epoch_arg.value!r} — "
                    f"thread the live store/run epoch instead"))
            elif not mentions_identifier(epoch_arg, "epoch"):
                findings.append(ctx.finding(
                    self.name, node,
                    f"{fname}(...) epoch argument "
                    f"{ast.unparse(epoch_arg)!r} does not derive from an "
                    f"epoch — thread store.epoch or the pinned run epoch"))
            if fname in _TIERED_CALLS:
                tier_arg = next((kw.value for kw in node.keywords
                                 if kw.arg == "tier"), None)
                if tier_arg is None:
                    findings.append(ctx.finding(
                        self.name, node,
                        f"{fname}(...) without a tier argument — the "
                        f"tier=0 default binds and a coarse CHI-pyramid "
                        f"interval is served for a refined request"))
                elif isinstance(tier_arg, ast.Constant):
                    findings.append(ctx.finding(
                        self.name, node,
                        f"{fname}(...) hardcodes tier={tier_arg.value!r} — "
                        f"thread the tier the bounds pass actually ran at"))
        return findings


_STORE_NAMES = {"store", "snap", "snapshot", "st", "mask_store"}
_STORE_ATTRS = {"store", "_store", "snap", "_snap", "snapshot"}


def _is_store_expr(node: ast.AST) -> bool:
    if isinstance(node, ast.Name):
        return node.id in _STORE_NAMES or node.id.endswith("_store")
    if isinstance(node, ast.Attribute):
        return node.attr in _STORE_ATTRS
    return False


@register
class EpochSnapshotRule(Rule):
    name = "epoch-snapshot"
    summary = ("engine/run code may not reach around StoreSnapshot into "
               "private store state")
    doc = """\
Invariant: outside core/store.py, no code touches an underscore-private
attribute of a store or snapshot expression (`store._x`, `self.store._x`,
`snap._x`).  Everything the engine, backends, and service need is part of
the public surface (epoch, snapshot(), load/load_rows, chi_host/chi_table,
cache_enabled, backend_cache, ids_dirty_since, can_serve, ...).

Why it holds: StoreSnapshot is the consistency boundary for resumable
runs — it pins an epoch and mediates every read, refusing (StaleRunError)
or rerouting once the store moves on.  Private state like the load-cache
position map or CHI chunk buffers tracks the *current* epoch; reading it
through a pinned snapshot's back door returns rows renumbered by a
delete, which is a wrong answer, not an error.  PR 7 converted the two
historical reach-arounds (core/exprs.py reading `store._cache_map`,
core/backend.py reading `store._backend_cache`) into public properties
precisely so this rule can hold everywhere.

Violation example:

    if ctx.store._cache_map is not None:    # pre-PR-7 exprs.py
        ...

Fix: add/extend a public property on MaskStore *and* StoreSnapshot (so
the snapshot can apply its staleness contract), then use it.
"""

    def check_module(self, ctx: ModuleCtx) -> list[Finding]:
        if ctx.endswith("core/store.py"):
            return []
        findings: list[Finding] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Attribute) \
                    and node.attr.startswith("_") \
                    and not node.attr.startswith("__") \
                    and _is_store_expr(node.value):
                base = ast.unparse(node.value)
                findings.append(ctx.finding(
                    self.name, node,
                    f"private store state {base}.{node.attr} accessed "
                    f"outside core/store.py — go through the public "
                    f"MaskStore/StoreSnapshot surface so the snapshot "
                    f"staleness contract applies"))
        return findings
