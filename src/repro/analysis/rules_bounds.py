"""Bounds rules: three-valued comparisons stay in the sound combinators."""

from __future__ import annotations

import ast
import re

from .core import Finding, ModuleCtx, Rule, call_name, register

_BOUND_NAME_RE = re.compile(r"^(lb|ub)s?$|^(lb|ub)_|_(lb|ub)s?$")

# The vetted combinator implementations.  core/exprs.py owns cmp_decide
# and the interval arithmetic; core/backend.py and core/distributed.py
# carry the device/mesh mirrors of the same decisions (kept equivalent by
# the backend-equivalence test suite).
_BLESSED_SOUNDNESS = ("core/exprs.py", "core/backend.py",
                      "core/distributed.py")

# Modules allowed to binary-search CHI bin edges directly: the CHI
# builder, the combinator module (via _threshold_ks), and the mesh shards.
_BLESSED_EDGES = ("core/chi.py", "core/exprs.py", "core/distributed.py")


def _edgy(node: ast.AST) -> bool:
    """Whether any identifier in ``node`` smells like a bin-edge array."""
    return any(
        (isinstance(s, ast.Name) and "edge" in s.id.lower())
        or (isinstance(s, ast.Attribute) and "edge" in s.attr.lower())
        for s in ast.walk(node))


def _bound_ident(node: ast.AST) -> str | None:
    if isinstance(node, ast.Name) and _BOUND_NAME_RE.search(node.id):
        return node.id
    if isinstance(node, ast.Attribute) and _BOUND_NAME_RE.search(node.attr):
        return node.attr
    return None


@register
class BoundsSoundnessRule(Rule):
    name = "bounds-soundness"
    summary = ("CHI bound tuples are compared only via the sound "
               "combinators in core/exprs.py")
    doc = """\
Invariant: outside the combinator modules (core/exprs.py and its vetted
device/mesh mirrors in core/backend.py and core/distributed.py), no code
applies a raw `<" <= > >=` comparison to a CHI bound array (names like
lb/ub/lbs/ubs/cp_lb/ub_arr).  Predicate decisions over bounds go through
cmp_decide(op, lb, ub, threshold), which returns the three-valued
accept / reject / unknown split.

Why it holds: MaskSearch's correctness claim is that bounded filter-verify
returns exactly the naive scan's answer.  That rests on the bound
semantics: lb <= exact <= ub always.  A raw `ub > t` used as "accepted"
conflates *possible* with *certain* — masks whose exact value is below t
but whose upper bound clears it get accepted without verification.
cmp_decide also owns the strict-threshold edge case: `CP(...) > t` at a
CHI bin edge must bump the threshold by one float32 ulp
(np.nextafter, see _threshold_ks) before binning, or boundary-valued
masks flip between accept and unknown depending on bin alignment.

Violation example:

    accepted = ids[ub > t]                    # wrong: possible != certain

Correct:

    acc, rej = cmp_decide(op, lb, ub, t)      # unknown -> verify loop

Comparisons whose lb/ub names are *not* CHI bounds (histogram bucket
edges, address bounds) are suppressed inline with a reason, e.g.
`# masklint: ignore[bounds-soundness] -- histogram bucket edge`.
"""

    def check_module(self, ctx: ModuleCtx) -> list[Finding]:
        if ctx.endswith(*_BLESSED_SOUNDNESS):
            return []
        findings: list[Finding] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Compare):
                continue
            if not any(isinstance(op, (ast.Lt, ast.LtE, ast.Gt, ast.GtE))
                       for op in node.ops):
                continue
            operands = [node.left, *node.comparators]
            hit = next((n for n in map(_bound_ident, operands) if n), None)
            if hit:
                findings.append(ctx.finding(
                    self.name, node,
                    f"raw ordering comparison on bound-like value "
                    f"{hit!r} — decide predicates over CHI bounds via "
                    f"cmp_decide(op, lb, ub, t) in core/exprs.py (three-"
                    f"valued accept/reject/unknown, nextafter32 edge "
                    f"handling)"))
        return findings


@register
class BoundsEdgeRule(Rule):
    name = "bounds-edge"
    summary = ("CHI bin-edge thresholding happens only in the blessed "
               "helpers (nextafter32 strict-threshold semantics)")
    doc = """\
Invariant: binary-searching CHI bin edges (np.searchsorted over an
`edges` array) happens only in core/chi.py (index construction),
core/exprs.py (_threshold_ks), and core/distributed.py (the shard-local
mirror).  Everyone else passes thresholds to the combinators.

Why it holds: CHI histograms are cumulative counts over float32 pixel
bins.  Mapping a query threshold t to bin indices is where the strict
vs. non-strict distinction lives: for `> t` the threshold must be bumped
to np.nextafter(float32(t), +inf) *before* searchsorted, so pixels equal
to t land on the correct side of the cumulative count.  An ad-hoc
searchsorted(edges, t) elsewhere silently drops that ulp bump and the
bounds stop bracketing the exact value for thresholds sitting exactly on
a bin edge — exactly the inputs the demo UI produces (round numbers like
0.5 with power-of-two bin grids).

Violation example:

    k = np.searchsorted(cfg.edges, t)          # strictness-unaware

Correct: call through exprs bounds machinery (which uses _threshold_ks),
or extend _threshold_ks if a new site genuinely needs edge indices.
"""

    def check_module(self, ctx: ModuleCtx) -> list[Finding]:
        if ctx.endswith(*_BLESSED_EDGES):
            return []
        findings: list[Finding] = []
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and call_name(node) == "searchsorted"):
                continue
            operands = list(node.args)
            if isinstance(node.func, ast.Attribute):
                operands.append(node.func.value)   # edges.searchsorted(t)
            if any(_edgy(a) for a in operands):
                findings.append(ctx.finding(
                    self.name, node,
                    "searchsorted over CHI bin edges outside the blessed "
                    "helpers — threshold-to-bin mapping must go through "
                    "core/exprs._threshold_ks (float32 nextafter bump for "
                    "strict thresholds)"))
        return findings
