"""CLI for masklint: ``python -m repro.analysis [paths ...]``.

Exit codes: 0 clean, 1 findings, 2 usage error.
"""

from __future__ import annotations

import argparse
import sys

from .core import (SUPPRESSION_FILE, all_rules, report_json, report_text,
                   run_paths)

_DEFAULT_PATHS = ("src", "benchmarks", "examples")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="masklint: static analysis of the repo's correctness "
                    "contracts (lock/epoch/bounds/kernel/stats rules)")
    ap.add_argument("paths", nargs="*",
                    help=f"files or directories to scan (default: the "
                         f"{'/'.join(_DEFAULT_PATHS)} trees that exist "
                         f"under the current directory)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--rules", metavar="R1,R2",
                    help="comma-separated subset of rules to run")
    ap.add_argument("--suppressions", metavar="FILE",
                    help=f"suppression file (default: ./{SUPPRESSION_FILE})")
    ap.add_argument("--list", action="store_true",
                    help="list the registered rules and exit")
    ap.add_argument("--explain", metavar="RULE",
                    help="print a rule's invariant documentation and exit")
    args = ap.parse_args(argv)

    registry = all_rules()
    if args.list:
        width = max(len(n) for n in registry)
        for name in sorted(registry):
            print(f"{name:<{width}}  {registry[name].summary}")
        return 0
    if args.explain:
        cls = registry.get(args.explain)
        if cls is None:
            print(f"unknown rule {args.explain!r}; known: "
                  f"{', '.join(sorted(registry))}", file=sys.stderr)
            return 2
        print(f"{cls.name} — {cls.summary}\n")
        print(cls.doc)
        return 0

    import os
    paths = args.paths or [p for p in _DEFAULT_PATHS if os.path.isdir(p)]
    if not paths:
        print("no paths given and none of "
              f"{', '.join(_DEFAULT_PATHS)} exist here", file=sys.stderr)
        return 2
    rule_names = ([r.strip() for r in args.rules.split(",") if r.strip()]
                  if args.rules else None)
    try:
        result = run_paths(paths, rule_names=rule_names,
                           suppressions_path=args.suppressions)
    except KeyError as e:
        print(e.args[0], file=sys.stderr)
        return 2
    print(report_text(result) if args.format == "text"
          else report_json(result))
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
