"""Lock rules: unlocked shared-state writes, and lock-order cycles."""

from __future__ import annotations

import ast
import dataclasses

from .core import Finding, ModuleCtx, Rule, is_self_attr, register

_DUNDER_EXEMPT = {"__init__", "__post_init__", "__new__", "__del__",
                  "__getstate__", "__setstate__", "__reduce__"}


def _lock_classes(tree: ast.Module) -> dict[str, ast.ClassDef]:
    """Classes whose ``__init__`` assigns ``self._lock`` — the repo's
    marker for 'instances of me are shared across threads'."""
    out = {}
    for node in tree.body:
        if not isinstance(node, ast.ClassDef):
            continue
        for meth in node.body:
            if isinstance(meth, ast.FunctionDef) and meth.name == "__init__":
                for sub in ast.walk(meth):
                    if isinstance(sub, (ast.Assign, ast.AnnAssign)):
                        targets = (sub.targets
                                   if isinstance(sub, ast.Assign)
                                   else [sub.target])
                        if any(is_self_attr(t, "_lock") for t in targets):
                            out[node.name] = node
    return out


@dataclasses.dataclass
class _Write:
    method: str
    attr: str
    locked: bool
    node: ast.AST


@dataclasses.dataclass
class _CallSite:
    caller: str
    callee: str
    locked: bool


class _MethodScanner(ast.NodeVisitor):
    """Walk one method, tracking whether each statement is lexically
    inside ``with self._lock``; collect self-attribute writes and
    self-method calls."""

    def __init__(self, method_name: str):
        self.method = method_name
        self.locked = False
        self.writes: list[_Write] = []
        self.calls: list[_CallSite] = []

    def visit_With(self, node: ast.With) -> None:
        takes_lock = any(is_self_attr(item.context_expr, "_lock")
                         for item in node.items)
        for item in node.items:
            self.visit(item.context_expr)
        was = self.locked
        self.locked = was or takes_lock
        for stmt in node.body:
            self.visit(stmt)
        self.locked = was

    def _record_target(self, target: ast.AST, node: ast.AST) -> None:
        # self.x = ... / self.x[...] = ... / (a, self.x) = ...
        if isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._record_target(elt, node)
            return
        base = target
        if isinstance(base, ast.Subscript):
            base = base.value
        if is_self_attr(base) and base.attr != "_lock":
            self.writes.append(_Write(self.method, base.attr,
                                      self.locked, node))

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._record_target(t, node)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self._record_target(node.target, node)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._record_target(node.target, node)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        if is_self_attr(node.func):
            self.calls.append(_CallSite(self.method, node.func.attr,
                                        self.locked))
        self.generic_visit(node)


def _scan_class(cls: ast.ClassDef):
    writes: list[_Write] = []
    calls: list[_CallSite] = []
    method_names = set()
    for meth in cls.body:
        if not isinstance(meth, ast.FunctionDef):
            continue
        method_names.add(meth.name)
        scanner = _MethodScanner(meth.name)
        for stmt in meth.body:
            scanner.visit(stmt)
        writes.extend(scanner.writes)
        calls.extend(scanner.calls)
    return writes, calls, method_names


def _locked_closure(calls: list[_CallSite], method_names: set[str]) -> set[str]:
    """Private methods whose *every* intra-class call site is inside a
    locked region (directly, or via a caller already in the closure) —
    the service's ``_serve_page``-style helpers, which run under the
    public methods' lock without re-taking it."""
    sites: dict[str, list[_CallSite]] = {}
    for c in calls:
        if c.callee in method_names and c.callee.startswith("_") \
                and not c.callee.startswith("__"):
            sites.setdefault(c.callee, []).append(c)
    closed: set[str] = set()
    changed = True
    while changed:
        changed = False
        for name, cs in sites.items():
            if name in closed:
                continue
            if all(c.locked or c.caller in closed for c in cs):
                closed.add(name)
                changed = True
    return closed


@register
class LockDisciplineRule(Rule):
    name = "lock-discipline"
    summary = ("writes to shared state in lock-owning classes must happen "
               "under `with self._lock`")
    doc = """\
Invariant: in any class whose __init__ creates `self._lock`, every write
to instance state outside __init__ happens while the lock is held — either
lexically inside `with self._lock:`, or in a private helper whose every
intra-class call site is inside a locked region (the service's
`_serve_page` pattern: public methods take the RLock once, helpers run
under it).

Why it holds: the HTTP front (service/server.py) is a ThreadingHTTPServer,
so MaskSearchService methods, the planner's LRU caches, the metrics
registry, and the tracer all run concurrently.  An unlocked read of a
monotonic counter is a tolerated torn read (the /metrics scrape does this
by design); an unlocked *write* is a lost update or a torn compound
mutation — e.g. an LRU eviction racing an insert corrupts the cache's
size accounting silently.

Violation caught (PR 7 fixed this in obs/trace.py):

    class Tracer:
        def __init__(self):
            self._lock = threading.Lock()
            self.spans_started = 0
        def span(self, name):
            self.spans_started += 1      # <- unlocked read-modify-write

Fix: wrap the write in `with self._lock:`.  If the write is genuinely
single-threaded (construction-time, or documented reader-tolerated),
suppress with `# masklint: ignore[lock-discipline] -- <why>`.

Runtime counterpart: REPRO_LOCK_CHECK=1 (repro/lockcheck.py) promotes
the same contract to execution-time assertions (owner-checked release,
order-cycle detection, guarded dict mutation).
"""

    def check_module(self, ctx: ModuleCtx) -> list[Finding]:
        findings: list[Finding] = []
        for cls_name, cls in _lock_classes(ctx.tree).items():
            writes, calls, method_names = _scan_class(cls)
            closed = _locked_closure(calls, method_names)
            for w in writes:
                if w.method in _DUNDER_EXEMPT or w.locked \
                        or w.method in closed:
                    continue
                findings.append(ctx.finding(
                    self.name, w.node,
                    f"{cls_name}.{w.method} writes self.{w.attr} outside "
                    f"`with self._lock` ({cls_name} owns a lock; shared "
                    f"state must be written under it)"))
        return findings


@register
class LockOrderRule(Rule):
    name = "lock-order"
    summary = "the static lock-order graph across classes must be acyclic"
    doc = """\
Invariant: the directed graph "class A's locked regions reach into class
B, which owns its own lock" has no cycles.  Two threads taking the same
pair of locks in opposite orders is a deadlock waiting for the right
interleaving; with the service lock outermost and the planner-cache /
metrics / tracer locks strictly inner, the repo's graph is a tree.

How the edges are derived (a one-level static approximation): inside
`with self._lock:` of class A, a call `self.<attr>.<anything>(...)` —
where __init__ assigned `self.<attr> = B(...)` and B owns a `_lock` —
adds edge A → B; so does a nested `with self.<attr>._lock:`.  Cycles in
the resulting cross-module graph are reported on one edge of the cycle.

Violation example:

    class A:
        def __init__(self): self._lock = threading.Lock(); self.b = B(self)
        def f(self):
            with self._lock: self.b.g()      # A -> B
    class B:
        def __init__(self, a): self._lock = threading.Lock(); self.a = a
        def g(self):
            with self._lock: self.a.f()      # B -> A: cycle

Fix: establish a single order (take the outer lock first in both paths)
or drop work out of the locked region before calling across.  The runtime
check (REPRO_LOCK_CHECK=1) catches the dynamic version of the same bug,
including orders masklint's static approximation cannot see.
"""

    def __init__(self):
        # class -> {attr -> constructed-class-name}
        self._attr_types: dict[str, dict[str, str]] = {}
        self._lock_owners: set[str] = set()
        # (owner-class, attr, finding-stub)
        self._pending: list[tuple[str, str, Finding]] = []

    def check_module(self, ctx: ModuleCtx) -> list[Finding]:
        for cls_name, cls in _lock_classes(ctx.tree).items():
            self._lock_owners.add(cls_name)
            attr_types: dict[str, str] = {}
            for meth in cls.body:
                if isinstance(meth, ast.FunctionDef) \
                        and meth.name == "__init__":
                    for sub in ast.walk(meth):
                        if isinstance(sub, ast.Assign) \
                                and isinstance(sub.value, ast.Call):
                            fn = sub.value.func
                            ctor = fn.id if isinstance(fn, ast.Name) else \
                                (fn.attr if isinstance(fn, ast.Attribute)
                                 else "")
                            for t in sub.targets:
                                if is_self_attr(t) and ctor:
                                    attr_types[t.attr] = ctor
            self._attr_types[cls_name] = attr_types
            self._collect_edges(ctx, cls_name, cls)
        return []

    def _collect_edges(self, ctx: ModuleCtx, cls_name: str,
                       cls: ast.ClassDef) -> None:
        rule = self

        class V(ast.NodeVisitor):
            def __init__(self):
                self.locked = False

            def visit_With(self, node: ast.With) -> None:
                takes = any(is_self_attr(i.context_expr, "_lock")
                            for i in node.items)
                # nested `with self.<attr>._lock:` inside a locked region
                if self.locked:
                    for i in node.items:
                        e = i.context_expr
                        if isinstance(e, ast.Attribute) and e.attr == "_lock" \
                                and is_self_attr(e.value):
                            rule._pending.append(
                                (cls_name, e.value.attr,
                                 ctx.finding("lock-order", node, "")))
                was = self.locked
                self.locked = was or takes
                for stmt in node.body:
                    self.visit(stmt)
                self.locked = was

            def visit_Call(self, node: ast.Call) -> None:
                if self.locked and isinstance(node.func, ast.Attribute) \
                        and is_self_attr(node.func.value):
                    rule._pending.append(
                        (cls_name, node.func.value.attr,
                         ctx.finding("lock-order", node, "")))
                self.generic_visit(node)

        for meth in cls.body:
            if isinstance(meth, ast.FunctionDef):
                v = V()
                for stmt in meth.body:
                    v.visit(stmt)

    def finalize(self) -> list[Finding]:
        edges: dict[str, dict[str, Finding]] = {}
        for owner, attr, stub in self._pending:
            target = self._attr_types.get(owner, {}).get(attr)
            if target in self._lock_owners and target != owner:
                edges.setdefault(owner, {}).setdefault(target, stub)
        findings: list[Finding] = []
        reported: set[frozenset] = set()

        def dfs(node: str, path: list[str]) -> None:
            for nxt, stub in edges.get(node, {}).items():
                if nxt in path:
                    cycle = path[path.index(nxt):] + [nxt]
                    key = frozenset(cycle)
                    if key not in reported:
                        reported.add(key)
                        findings.append(dataclasses.replace(
                            stub, message=(
                                f"lock-order cycle "
                                f"{' -> '.join(cycle)}: these classes take "
                                f"each other's locks while holding their "
                                f"own — a deadlock under the right thread "
                                f"interleaving")))
                elif len(path) < 16:
                    dfs(nxt, path + [nxt])

        for start in sorted(edges):
            dfs(start, [start])
        return findings
