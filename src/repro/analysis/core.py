"""masklint core: the visitor framework, findings, suppression, reporters.

masklint is the repo's own static-analysis pass (``python -m
repro.analysis``).  Generic linters check style; this one checks the
*correctness contracts* the MaskSearch reproduction actually rests on —
lock discipline in the threaded service, epoch threading through cache
keys, bounds-soundness combinator usage, Pallas kernel constraints, and
stats-dataclass/reflection agreement (DESIGN.md §11 documents each
invariant).  Rules are pure ``ast`` passes: the analyzer imports nothing
from the analyzed code (no jax, no numpy), so it runs anywhere Python
runs and can never be broken by an import-time failure in the target.

Suppression, in order of review friction:

* inline — ``# masklint: ignore[rule-name] -- reason`` on the flagged
  line (the reason is mandatory; a bare ignore is itself a finding);
* repo-level — entries in ``masklint-suppressions.json`` (``{"rule",
  "path", "line"?, "reason"}``); the file ships empty and every entry
  is expected to carry a written justification.
"""

from __future__ import annotations

import ast
import dataclasses
import json
import os
import re

SUPPRESSION_FILE = "masklint-suppressions.json"

_INLINE_RE = re.compile(
    r"#\s*masklint:\s*ignore\[([a-zA-Z0-9_,\- ]+)\]"
    r"(?:\s*--\s*(?P<reason>\S.*))?")


@dataclasses.dataclass
class Finding:
    """One rule violation at one source location."""
    rule: str
    path: str          # repo-relative, '/'-separated
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: [{self.rule}] {self.message}"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class ModuleCtx:
    """Everything a rule sees for one source file."""

    def __init__(self, path: str, relpath: str, source: str):
        self.path = path
        self.relpath = relpath.replace(os.sep, "/")
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        return Finding(rule, self.relpath,
                       getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0) + 1, message)

    def endswith(self, *suffixes: str) -> bool:
        return self.relpath.endswith(suffixes)


class Rule:
    """Base class: subclass, set the metadata, implement check_module.

    ``check_module`` runs once per file; ``finalize`` runs once after all
    files, for rules that need cross-module state (the lock-order graph).
    """

    name: str = ""
    summary: str = ""       # one line, shown by --list
    doc: str = ""           # full invariant docs, shown by --explain

    def check_module(self, ctx: ModuleCtx) -> list[Finding]:
        return []

    def finalize(self) -> list[Finding]:
        return []


_REGISTRY: dict[str, type[Rule]] = {}


def register(cls: type[Rule]) -> type[Rule]:
    if not cls.name:
        raise ValueError(f"rule {cls.__name__} has no name")
    if cls.name in _REGISTRY:
        raise ValueError(f"duplicate rule name {cls.name!r}")
    _REGISTRY[cls.name] = cls
    return cls


def all_rules() -> dict[str, type[Rule]]:
    """Name → rule class, importing the rule modules on first use."""
    from . import (  # noqa: F401 — imported for their @register side effect
        rules_bounds, rules_epoch, rules_kernels, rules_locks, rules_stats,
    )
    return dict(_REGISTRY)


# -- shared AST helpers (used by several rule modules) -------------------------

def call_name(node: ast.Call) -> str:
    """The terminal name of a call target: f(...) → 'f', a.b.f(...) → 'f'."""
    fn = node.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return ""


def mentions_identifier(node: ast.AST, fragment: str) -> bool:
    """Whether any Name/Attribute identifier in ``node`` contains
    ``fragment`` (case-insensitive)."""
    frag = fragment.lower()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and frag in sub.id.lower():
            return True
        if isinstance(sub, ast.Attribute) and frag in sub.attr.lower():
            return True
    return False


def is_self_attr(node: ast.AST, attr: str | None = None) -> bool:
    """Whether ``node`` is ``self.<attr>`` (any attr when None)."""
    return (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
            and (attr is None or node.attr == attr))


# -- file discovery ------------------------------------------------------------

_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", ".hypothesis",
              ".ruff_cache", "node_modules"}


def iter_py_files(paths: list[str]) -> list[str]:
    out: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                out.append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames if d not in _SKIP_DIRS)
            out.extend(os.path.join(dirpath, f)
                       for f in sorted(filenames) if f.endswith(".py"))
    return out


# -- suppression ---------------------------------------------------------------

def load_suppression_file(path: str) -> tuple[list[dict], list[Finding]]:
    """Parse the repo-level suppression file → (entries, file-errors)."""
    if not os.path.exists(path):
        return [], []
    try:
        with open(path) as f:
            data = json.load(f)
        entries = data["suppressions"]
        assert isinstance(entries, list)
    except (json.JSONDecodeError, KeyError, AssertionError, TypeError) as e:
        return [], [Finding("suppression-file", path, 1, 1,
                            f"unreadable suppression file: {e}")]
    errors = []
    for i, ent in enumerate(entries):
        if not isinstance(ent, dict) or not ent.get("rule") \
                or not ent.get("path") or not str(ent.get("reason", "")).strip():
            errors.append(Finding(
                "suppression-file", path, 1, 1,
                f"suppression entry {i} must carry rule, path, and a "
                f"non-empty reason: {ent!r}"))
    return entries, errors


def _inline_suppressed(line_text: str, rule: str) -> tuple[bool, bool]:
    """(suppressed, has_reason) for an inline masklint comment."""
    m = _INLINE_RE.search(line_text)
    if not m:
        return False, True
    names = {n.strip() for n in m.group(1).split(",")}
    if rule not in names and "all" not in names:
        return False, True
    return True, bool(m.group("reason"))


def apply_suppressions(findings: list[Finding],
                       sources: dict[str, list[str]],
                       file_entries: list[dict]) -> tuple[list[Finding], int]:
    """Drop suppressed findings → (kept, n_suppressed).  An inline ignore
    without a ``-- reason`` suppresses nothing and is itself flagged."""
    kept: list[Finding] = []
    suppressed = 0
    for f in findings:
        lines = sources.get(f.path, [])
        text = lines[f.line - 1] if 0 < f.line <= len(lines) else ""
        inline, has_reason = _inline_suppressed(text, f.rule)
        if inline and not has_reason:
            kept.append(dataclasses.replace(
                f, message=f.message + "  [inline ignore present but has no "
                                       f"'-- reason'; reasons are mandatory]"))
            continue
        if inline:
            suppressed += 1
            continue
        if any(e.get("rule") in (f.rule, "all") and e.get("path") == f.path
               and ("line" not in e or int(e["line"]) == f.line)
               for e in file_entries):
            suppressed += 1
            continue
        kept.append(f)
    return kept, suppressed


# -- the runner ----------------------------------------------------------------

@dataclasses.dataclass
class RunResult:
    findings: list[Finding]
    n_files: int = 0
    n_suppressed: int = 0

    @property
    def ok(self) -> bool:
        return not self.findings


def run_paths(paths: list[str], rule_names: list[str] | None = None,
              suppressions_path: str | None = None,
              root: str | None = None) -> RunResult:
    """Run the (selected) rules over every ``*.py`` under ``paths``."""
    root = os.path.abspath(root or os.getcwd())
    registry = all_rules()
    names = rule_names or sorted(registry)
    unknown = [n for n in names if n not in registry]
    if unknown:
        raise KeyError(f"unknown rule(s): {', '.join(unknown)}; "
                       f"known: {', '.join(sorted(registry))}")
    rules = [registry[n]() for n in names]

    findings: list[Finding] = []
    sources: dict[str, list[str]] = {}
    files = iter_py_files(paths)
    for path in files:
        ap = os.path.abspath(path)
        rel = (os.path.relpath(ap, root) if ap.startswith(root + os.sep)
               else path).replace(os.sep, "/")
        try:
            with open(path, encoding="utf-8") as f:
                source = f.read()
            ctx = ModuleCtx(path, rel, source)
        except (SyntaxError, UnicodeDecodeError, OSError) as e:
            lineno = getattr(e, "lineno", 1) or 1
            findings.append(Finding("parse-error", rel, lineno, 1, str(e)))
            continue
        sources[rel] = ctx.lines
        for r in rules:
            findings.extend(r.check_module(ctx))
    for r in rules:
        findings.extend(r.finalize())

    sup_path = suppressions_path if suppressions_path is not None else \
        os.path.join(root, SUPPRESSION_FILE)
    entries, sup_errors = load_suppression_file(sup_path)
    kept, n_sup = apply_suppressions(findings, sources, entries)
    kept.extend(sup_errors)
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return RunResult(kept, n_files=len(files), n_suppressed=n_sup)


# -- reporters -----------------------------------------------------------------

def report_text(result: RunResult) -> str:
    out = [f.format() for f in result.findings]
    out.append(f"masklint: {len(result.findings)} finding(s), "
               f"{result.n_suppressed} suppressed, "
               f"{result.n_files} file(s) scanned")
    return "\n".join(out)


def report_json(result: RunResult) -> str:
    return json.dumps({
        "findings": [f.as_dict() for f in result.findings],
        "suppressed": result.n_suppressed,
        "files_scanned": result.n_files,
        "ok": result.ok,
    }, indent=2)
