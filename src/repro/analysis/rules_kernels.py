"""Pallas kernel constraints: grid/index-map arity, traced control flow,
dtype/host-callback bans inside kernel bodies."""

from __future__ import annotations

import ast

from .core import Finding, ModuleCtx, Rule, call_name, register

_HOST_CALLS = {"print", "io_callback", "pure_callback", "host_callback",
               "debug_callback", "breakpoint"}


def _first_kernel_ref(call: ast.Call) -> str | None:
    """The kernel function a pallas_call launches: a bare Name, or the
    first argument of functools.partial(Name, ...)."""
    if not call.args:
        return None
    a = call.args[0]
    if isinstance(a, ast.Name):
        return a.id
    if isinstance(a, ast.Call) and call_name(a) == "partial" and a.args \
            and isinstance(a.args[0], ast.Name):
        return a.args[0].id
    return None


def _grid_arity(call: ast.Call, local_tuples: dict[str, int]) -> int | None:
    for kw in call.keywords:
        if kw.arg == "grid":
            if isinstance(kw.value, ast.Tuple):
                return len(kw.value.elts)
            if isinstance(kw.value, ast.Name):
                return local_tuples.get(kw.value.id)
    return None


def _iter_blockspecs(call: ast.Call):
    """Every BlockSpec(...) call reachable from in_specs/out_specs/
    out_shape keyword values."""
    for kw in call.keywords:
        if kw.arg not in ("in_specs", "out_specs"):
            continue
        for sub in ast.walk(kw.value):
            if isinstance(sub, ast.Call) and call_name(sub) == "BlockSpec":
                yield sub


def _index_map_lambda(spec: ast.Call) -> ast.Lambda | None:
    for kw in spec.keywords:
        if kw.arg == "index_map" and isinstance(kw.value, ast.Lambda):
            return kw.value
    for a in spec.args:
        if isinstance(a, ast.Lambda):
            return a
    return None


@register
class KernelConstraintsRule(Rule):
    name = "kernel-constraints"
    summary = ("Pallas kernels: index-map arity == grid rank, no Python "
               "control flow / float64 / host callbacks in kernel bodies")
    doc = """\
Invariant, three parts, applied to any module that defines `*_kernel`
functions or issues pl.pallas_call:

1. Every BlockSpec index_map lambda takes exactly len(grid) parameters.
   Pallas hands the index map one program id per grid axis; an arity
   mismatch is a TypeError at trace time on TPU but can silently slip
   through on interpret-mode-only CI runs when the call path is not
   exercised.

2. Kernel bodies contain no Python `if`/`while`, and `for` only over
   range(...) with static bounds.  Kernel bodies run once at trace time:
   branching on a traced value raises ConcretizationTypeError at best;
   at worst a condition on a *static-looking* value bakes one branch into
   the compiled kernel.  Data-dependent selection uses @pl.when /
   jnp.where; static unrolling threads Python ints via functools.partial
   (how cp_count/mask_agg pass num-block counts).

3. No float64 and no host callbacks (print, io/pure/host/debug_callback)
   inside kernel bodies.  TPU Pallas has no f64 vector unit — jax silently
   downcasts under jax_enable_x64=False and *fails to lower* otherwise —
   and host callbacks stall the systolic pipeline (they are also
   unsupported inside Pallas kernels on TPU).  CHI count math is exact in
   int32; accumulate in float32.

Violation examples:

    pl.pallas_call(f, grid=(b, h // bh),
                   in_specs=[pl.BlockSpec((1, bh), lambda i: (i, 0))], ...)
    # index map takes 1 arg, grid has rank 2

    def cp_count_kernel(chi_ref, out_ref):
        if chi_ref[0, 0] > 0:        # traced value in Python `if`
            ...

Fix: match lambda arity to the grid; replace `if` with @pl.when or
jnp.where; keep accumulation in f32/int32.  Reference kernels:
src/repro/kernels/cp_count.py, mask_agg.py, pair_count.py, chi_build.py.
"""

    def check_module(self, ctx: ModuleCtx) -> list[Finding]:
        tree = ctx.tree
        kernel_names = {n.name for n in ast.walk(tree)
                        if isinstance(n, ast.FunctionDef)
                        and n.name.endswith("_kernel")}
        pallas_calls = [n for n in ast.walk(tree)
                        if isinstance(n, ast.Call)
                        and call_name(n) == "pallas_call"]
        if not kernel_names and not pallas_calls:
            return []
        findings: list[Finding] = []

        # local `grid = (a, b)` style assignments, for grid=grid resolution
        local_tuples: dict[str, int] = {}
        for n in ast.walk(tree):
            if isinstance(n, ast.Assign) and isinstance(n.value, ast.Tuple):
                for t in n.targets:
                    if isinstance(t, ast.Name):
                        local_tuples[t.id] = len(n.value.elts)

        for call in pallas_calls:
            ref = _first_kernel_ref(call)
            if ref:
                kernel_names.add(ref)
            arity = _grid_arity(call, local_tuples)
            if arity is None:
                continue
            for spec in _iter_blockspecs(call):
                lam = _index_map_lambda(spec)
                if lam is not None and len(lam.args.args) != arity:
                    findings.append(ctx.finding(
                        self.name, lam,
                        f"BlockSpec index_map takes "
                        f"{len(lam.args.args)} argument(s) but the grid "
                        f"has rank {arity} — Pallas passes one program "
                        f"id per grid axis"))

        for fn in ast.walk(tree):
            if isinstance(fn, ast.FunctionDef) and fn.name in kernel_names:
                findings.extend(self._check_body(ctx, fn))
        return findings

    def _check_body(self, ctx: ModuleCtx, fn: ast.FunctionDef):
        findings: list[Finding] = []
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)):
                kind = "if" if isinstance(node, ast.If) else "while"
                findings.append(ctx.finding(
                    self.name, node,
                    f"Python `{kind}` inside kernel body {fn.name} — "
                    f"control flow on traced values must use @pl.when / "
                    f"jnp.where; static specialization goes through "
                    f"functools.partial"))
            elif isinstance(node, ast.For):
                it = node.iter
                if not (isinstance(it, ast.Call)
                        and isinstance(it.func, ast.Name)
                        and it.func.id == "range"):
                    findings.append(ctx.finding(
                        self.name, node,
                        f"`for` over a non-range iterable inside kernel "
                        f"body {fn.name} — only static range(...) "
                        f"unrolls are traceable"))
            elif isinstance(node, ast.Call) \
                    and call_name(node) in _HOST_CALLS:
                findings.append(ctx.finding(
                    self.name, node,
                    f"host callback {call_name(node)}(...) inside kernel "
                    f"body {fn.name} — unsupported in TPU Pallas and "
                    f"stalls the pipeline"))
            elif (isinstance(node, ast.Attribute)
                  and node.attr == "float64") \
                    or (isinstance(node, ast.Name)
                        and node.id == "float64") \
                    or (isinstance(node, ast.Constant)
                        and node.value == "float64"):
                findings.append(ctx.finding(
                    self.name, node,
                    f"float64 inside kernel body {fn.name} — TPU Pallas "
                    f"has no f64 path; CHI count math is exact in "
                    f"int32/float32"))
        return findings


_FLOAT_DTYPES = {"float64", "float32", "float16", "bfloat16", "float_",
                 "double", "half"}


@register
class PopcountNoFloatRule(Rule):
    name = "popcount-no-float"
    summary = ("bitpacked popcount kernel bodies must stay integer-only — "
               "no float dtypes or float literals")
    doc = """\
Invariant: a function named `*_popcount_kernel` (the bitpacked binary-mask
tier's Pallas kernel bodies, kernels/popcount.py) mentions no float dtype
(float16/32/64, bfloat16, ...) and no float literal anywhere in its body.

Why it holds: the packed tier's entire win is that verification streams
uint32 words at 1/32 the float bytes and answers counts with bitwise
AND/OR + popcount in int32.  A float dtype inside the kernel body means
someone unpacked words back into float lanes (re-paying the 32x traffic
the tier exists to avoid) or routed the CP range / threshold compare into
the kernel.  Value semantics are precomputed OUTSIDE the kernel: the
wrappers collapse `[lv, uv)` on binary values to two int32 flags
(`f1 = lv <= 1 < uv`, `f0 = lv <= 0 < uv`) and `value > t` to effective-
word flags, so the traced body is pure integer math by construction —
which is also what makes the packed path bit-identical to the float
kernels.

Violation example:

    def _cp_popcount_kernel(roi_ref, lv_ref, mask_ref, out_ref, *, ...):
        m = mask_ref[0].astype(jnp.float32)   # unpacked float load
        out_ref[0] += jnp.sum((m >= lv_ref[0]).astype(jnp.int32))

Fix: keep words uint32 end to end; compute range/threshold flags in the
wrapper (popcount.py `_range_flags` / `_thresh_flags`) and pass them in as
int32 operands; count with `_popcount32(word & span_mask)`.
"""

    def check_module(self, ctx: ModuleCtx) -> list[Finding]:
        findings: list[Finding] = []
        for fn in ast.walk(ctx.tree):
            if not (isinstance(fn, ast.FunctionDef)
                    and fn.name.endswith("_popcount_kernel")):
                continue
            for node in ast.walk(fn):
                dtype = None
                if isinstance(node, ast.Attribute) \
                        and node.attr in _FLOAT_DTYPES:
                    dtype = node.attr
                elif isinstance(node, ast.Name) and node.id in _FLOAT_DTYPES:
                    dtype = node.id
                elif isinstance(node, ast.Constant) \
                        and isinstance(node.value, str) \
                        and node.value in _FLOAT_DTYPES:
                    dtype = node.value
                if dtype is not None:
                    findings.append(ctx.finding(
                        self.name, node,
                        f"float dtype {dtype} inside popcount kernel body "
                        f"{fn.name} — packed verification is integer-only; "
                        f"unpacking to float lanes re-pays the 32x traffic "
                        f"the bitpacked tier removes"))
                elif isinstance(node, ast.Constant) \
                        and isinstance(node.value, float):
                    findings.append(ctx.finding(
                        self.name, node,
                        f"float literal {node.value!r} inside popcount "
                        f"kernel body {fn.name} — value-range semantics "
                        f"belong in the wrapper's int32 flags "
                        f"(_range_flags/_thresh_flags), not the traced "
                        f"body"))
        return findings
