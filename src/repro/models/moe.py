"""Mixture-of-Experts FFN (DeepSeek-style: shared + fine-grained routed).

Routing: softmax top-k with a load-balancing auxiliary loss.  Dispatch uses
the sort-based capacity scheme (no (T,E,C) one-hot tensors): token→expert
assignments are sorted by expert id, each token gets its rank within its
expert's queue, ranks ≥ capacity drop (residual passthrough keeps dropped
tokens intact).  Under expert parallelism the (E, C, d) buffers are sharded
on E over the "model" axis and XLA lowers the scatter/gather into the usual
all-to-all pair.

Expert FFNs are SwiGLU with stacked weights (E, d, ff) — one einsum per
projection over all local experts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import param, shard_act, silu

Array = jax.Array


def init_moe(key, cfg, dtype):
    ks = jax.random.split(key, 5)
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    p = {
        "router": param(ks[0], (d, e), ("embed", "experts"), dtype=jnp.float32),
        "gate": param(ks[1], (e, d, ff), ("experts", "embed", "expert_mlp"),
                      dtype=dtype),
        "up": param(ks[2], (e, d, ff), ("experts", "embed", "expert_mlp"),
                    dtype=dtype),
        "down": param(ks[3], (e, ff, d), ("experts", "expert_mlp", "embed"),
                      dtype=dtype),
    }
    if cfg.num_shared_experts:
        sff = cfg.moe_d_ff * cfg.num_shared_experts
        kg, ku, kd = jax.random.split(ks[4], 3)
        p["shared"] = {
            "gate": param(kg, (d, sff), ("embed", "mlp"), dtype=dtype),
            "up": param(ku, (d, sff), ("embed", "mlp"), dtype=dtype),
            "down": param(kd, (sff, d), ("mlp", "embed"), dtype=dtype),
        }
    return p


def moe_ffn(p, cfg, x: Array):
    """x: (B, S, d) → (out (B, S, d), aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    t = b * s
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(probs, k)                     # (T, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux (Switch): E * Σ_e fraction_tokens_e · mean_prob_e
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[tope.reshape(-1)].add(1.0) / (t * k)
    aux = cfg.router_aux_weight * e * jnp.sum(me * ce)

    capacity = max(int(cfg.capacity_factor * t * k / e), 1)

    # sort-based dispatch ---------------------------------------------------
    # Flat (T·k, …) dispatch rows are annotated with the "tokens" logical
    # axis (→ data sharding): at 7168-wide models these tensors are ~15 GB
    # replicated — the single biggest memory lever in the MoE cells
    # (EXPERIMENTS.md §Perf).  GSPMD turns the token-sharded → expert-sharded
    # scatter into the EP all-to-all.
    flat_e = tope.reshape(-1)                                # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_w = topw.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st_, sw = flat_e[order], flat_t[order], flat_w[order]
    # rank within expert queue = position − start offset of that expert
    counts = jnp.zeros((e,), jnp.int32).at[se].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(t * k, dtype=jnp.int32) - starts[se]
    keep = rank < capacity
    slot = jnp.where(keep, rank, capacity - 1)

    # gather tokens into (E, C, d) expert buffers
    rows = jnp.where(keep[:, None], xf[st_], 0)
    rows = shard_act(rows, ("tokens", "embed"))
    buf = jnp.zeros((e, capacity, d), x.dtype)
    buf = buf.at[se, slot].add(rows)
    buf = shard_act(buf, ("experts", None, "embed"))

    h = silu(jnp.einsum("ecd,edf->ecf", buf, p["gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["up"])
    h = shard_act(h, ("experts", None, "expert_mlp"))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["down"])
    out_buf = shard_act(out_buf, ("experts", None, "embed"))

    # combine back to tokens, weighted by router prob
    contrib = out_buf[se, slot] * jnp.where(keep, sw, 0.0)[:, None].astype(x.dtype)
    contrib = shard_act(contrib, ("tokens", "embed"))
    yf = jnp.zeros((t, d), x.dtype).at[st_].add(contrib)
    yf = shard_act(yf, ("tokens", "embed"))

    if cfg.num_shared_experts:
        sp = p["shared"]
        sh = silu(xf @ sp["gate"]) * (xf @ sp["up"])
        yf = yf + sh @ sp["down"]
    return yf.reshape(b, s, d), aux
