"""GQA attention: full/local variants, qk-norm, RoPE, KV cache, SP decode.

Two memory/perf-critical design points (hit during the dry-run iteration —
see EXPERIMENTS.md §Perf):

* **Chunked (memory-efficient) attention.**  Materializing (S × S) f32
  scores at 4k–32k sequence lengths costs tens of GB per device; queries are
  processed in unrolled blocks of ``cfg.attn_q_block`` (exact row softmax per
  block — no online accumulation needed since each block sees all its keys).
  Blocks are a static python loop, NOT a scan, so ``cost_analysis`` counts
  their FLOPs (the roofline methodology depends on this).

* **Local layers slice K/V.**  Sliding-window layers (gemma3 5:1,
  recurrentgemma) gather only the ``q_block + window`` keys a block can see —
  O(S·W) compute and memory instead of O(S²), matching production kernels.

* **KV repeat for TP.**  K/V are repeated to the full query-head count
  before the score einsum so the "heads" axis shards over "model" even when
  ``kv_heads`` doesn't divide it (kv=8 on a 16-way axis).  The repeat is
  cheap (bf16 K/V, heads sharded); the scores it unlocks sharding for are
  the expensive tensor.

Cache layout is ``(B, S_max, kv_heads, head_dim)``.  For decode shapes the
launcher shards the cache's **sequence** axis over "model"
(flash-decoding-style SP): per-step scores come out seq-sharded and XLA
inserts the partial-softmax all-reduces.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import (apply_rope, init_rms, param, rms_norm, shard_act)

Array = jax.Array
NEG_INF = -2.0e38


def init_attention(key, cfg, dtype):
    k1, k2, k3, k4, kn1, kn2 = jax.random.split(key, 6)
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": param(k1, (d, hq, hd), ("embed", "q_heads", "head_dim"), dtype=dtype),
        "wk": param(k2, (d, hkv, hd), ("embed", "kv_heads", "head_dim"), dtype=dtype),
        "wv": param(k3, (d, hkv, hd), ("embed", "kv_heads", "head_dim"), dtype=dtype),
        "wo": param(k4, (hq, hd, d), ("q_heads", "head_dim", "embed"), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rms(kn1, hd, axes=("head_dim",))
        p["k_norm"] = init_rms(kn2, hd, axes=("head_dim",))
    return p


def _theta(cfg, kind: str) -> float:
    if kind == "local" and cfg.rope_theta_local is not None:
        return cfg.rope_theta_local
    return cfg.rope_theta


def _qkv(p, cfg, x: Array, positions: Array, kind: str):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos_embedding == "rope":
        theta = _theta(cfg, kind)
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    q = shard_act(q, ("batch", "seq", "q_heads", None))
    k = shard_act(k, ("batch", "seq", "kv_heads", None))
    v = shard_act(v, ("batch", "seq", "kv_heads", None))
    return q, k, v


def repeat_kv(k: Array, groups: int) -> Array:
    if groups == 1:
        return k
    out = jnp.repeat(k, groups, axis=2)
    return shard_act(out, ("batch", "kv_seq", "heads", None))


def _block_attend(qb: Array, k: Array, v: Array, q_pos: Array, k_pos: Array,
                  causal: bool, window: int) -> Array:
    """One query block against a key slice.  qb: (B,bq,H,D), k/v: (B,T,H,D),
    q_pos: (bq,), k_pos: (T,).  Full heads (already repeated)."""
    d = qb.shape[-1]
    scores = jnp.einsum("bshd,bthd->bhst", qb, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    scores = shard_act(scores, ("batch", "heads", None, "kv_seq"))
    mask = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos[None, :] > (q_pos[:, None] - window)
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def _pick_block(cfg, s: int) -> int:
    bq = cfg.attn_q_block or s
    bq = min(bq, s)
    while s % bq:
        bq -= 1
    return max(bq, 1)


def _sdpa(q: Array, k: Array, v: Array, cfg, *, causal: bool, window: int,
          offset: int = 0) -> Array:
    """(B,S,Hq,D) × (B,T,Hkv,D) chunked grouped attention, f32 softmax."""
    b, s, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    k = repeat_kv(k, hq // hkv)
    v = repeat_kv(v, hq // hkv)
    bq = _pick_block(cfg, s)
    k_pos_all = jnp.arange(t)
    outs = []
    for i in range(s // bq):                      # static unroll (see module doc)
        qs = i * bq
        qb = jax.lax.slice_in_dim(q, qs, qs + bq, axis=1)
        q_pos = jnp.arange(qs, qs + bq) + offset
        if window > 0 and t > bq + window:
            # local layers: only the visible key stripe
            ks = max(qs + offset - window + 1, 0)
            klen = min(bq + window, t - ks)
            kb = jax.lax.slice_in_dim(k, ks, ks + klen, axis=1)
            vb = jax.lax.slice_in_dim(v, ks, ks + klen, axis=1)
            k_pos = jnp.arange(ks, ks + klen)
        else:
            kb, vb, k_pos = k, v, k_pos_all
        outs.append(_block_attend(qb, kb, vb, q_pos, k_pos, causal, window))
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return shard_act(out, ("batch", "seq", "heads", None))


def attention(p, cfg, x: Array, positions: Array, kind: str = "global") -> Array:
    """Training/prefill self-attention (causal; sliding window if local)."""
    q, k, v = _qkv(p, cfg, x, positions, kind)
    window = cfg.local_window if kind == "local" else 0
    out = _sdpa(q, k, v, cfg, causal=True, window=window)
    return jnp.einsum("bshd,hdo->bso", out, p["wo"])


def bidirectional_attention(p, cfg, x: Array, positions: Array) -> Array:
    """Encoder self-attention (whisper encoder)."""
    q, k, v = _qkv(p, cfg, x, positions, "global")
    out = _sdpa(q, k, v, cfg, causal=False, window=0)
    return jnp.einsum("bshd,hdo->bso", out, p["wo"])


# ---------------------------------------------------------------------------
# KV cache (prefill + decode)
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, max_len: int, kind: str, dtype):
    """Zeroed cache for one attention layer.  Local layers only retain a
    window-sized ring (sub-quadratic memory for the hybrid archs)."""
    length = min(max_len, cfg.local_window) if (kind == "local" and
                                                cfg.local_window) else max_len
    shape = (batch, length, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill_attention(p, cfg, x, positions, kind, cache):
    """Run self-attention AND fill the cache (positions 0..s)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions, kind)
    window = cfg.local_window if kind == "local" else 0
    out = _sdpa(q, k, v, cfg, causal=True, window=window)
    length = cache["k"].shape[1]
    if length >= s:
        cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0)),
        }
    else:  # ring for local windows shorter than the prompt
        cache = {"k": k[:, -length:], "v": v[:, -length:]}
    return jnp.einsum("bshd,hdo->bso", out, p["wo"]), cache


def decode_attention(p, cfg, x, pos: Array, kind: str, cache):
    """One-token decode against the cache.

    ``pos``: () int32 — current absolute position.  The new K/V is written at
    ``pos`` (global layers) or ``pos % window`` (local ring); the softmax
    masks out unwritten / out-of-window slots.  With the cache's seq axis
    sharded over "model", the (1 × T) score row is seq-sharded and XLA
    all-reduces the partial softmax (SP decode).
    """
    b = x.shape[0]
    q, k_new, v_new = _qkv(p, cfg, x, jnp.full((b, 1), pos), kind)
    length = cache["k"].shape[1]
    window = cfg.local_window if (kind == "local" and cfg.local_window) else 0
    slot = (pos % length) if window else pos
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    hq, hkv = q.shape[2], k.shape[2]
    kf = repeat_kv(k, hq // hkv)
    vf = repeat_kv(v, hq // hkv)
    idx = jnp.arange(length)
    if window:
        age = (slot - idx) % length
        valid = (age < jnp.minimum(pos + 1, window))
    else:
        valid = idx <= pos
    d = q.shape[-1]
    scores = jnp.einsum("bshd,bthd->bhst", q, kf).astype(jnp.float32)
    scores = scores / jnp.sqrt(d).astype(jnp.float32)
    scores = shard_act(scores, ("batch", "heads", None, "kv_seq"))
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(vf.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, vf)
    return (jnp.einsum("bshd,hdo->bso", out, p["wo"]),
            {"k": k, "v": v})


def causal_mask(s: int, t: int, offset: int, window: int = 0) -> Array:
    """(1,1,s,t) bool helper retained for tests."""
    qi = jnp.arange(s)[:, None] + offset
    kj = jnp.arange(t)[None, :]
    m = kj <= qi
    if window > 0:
        m &= kj > (qi - window)
    return m[None, None]


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder → encoder states)
# ---------------------------------------------------------------------------


def init_cross_attention(key, cfg, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": param(k1, (d, hq, hd), ("embed", "q_heads", "head_dim"), dtype=dtype),
        "wk": param(k2, (d, hkv, hd), ("embed", "kv_heads", "head_dim"), dtype=dtype),
        "wv": param(k3, (d, hkv, hd), ("embed", "kv_heads", "head_dim"), dtype=dtype),
        "wo": param(k4, (hq, hd, d), ("q_heads", "head_dim", "embed"), dtype=dtype),
    }


def cross_kv(p, enc_out: Array):
    k = jnp.einsum("btd,dhk->bthk", enc_out, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc_out, p["wv"])
    return {"k": shard_act(k, ("batch", "kv_seq", "kv_heads", None)),
            "v": shard_act(v, ("batch", "kv_seq", "kv_heads", None))}


def cross_attention(p, cfg, x: Array, kv) -> Array:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    out = _sdpa(q, kv["k"], kv["v"], cfg, causal=False, window=0)
    return jnp.einsum("bshd,hdo->bso", out, p["wo"])
