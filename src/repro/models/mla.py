"""Multi-head Latent Attention (DeepSeek-V2/V3).

Projections (per DeepSeek-V2 paper §2.1.1–2.1.3):

    c_q   = x W_dq                         (q_lora_rank)
    q     = RMS(c_q) W_uq     → per head: [q_nope (nope_dim) ; q_pe (rope_dim)]
    c_kv  = x W_dkv                        (kv_lora_rank)
    k_pe  = x W_kpe                        (rope_dim, shared across heads)
    k     = [RMS(c_kv) W_uk ; k_pe]        per head
    v     = RMS(c_kv) W_uv                 (v_head_dim per head)

Train/prefill materialize k/v.  **Decode caches only (c_kv, k_pe)** —
``kv_lora_rank + rope_dim`` floats per position — and uses the *absorbed*
form: W_uk folds into the query (q_nope → latent space) and W_uv folds into
the output projection, so per-step attention works directly against the
compressed cache.  This is the memory- and bandwidth-optimal MLA decode and
what makes deepseek's decode_32k/500k-class cells cache-light.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import apply_rope, init_rms, param, rms_norm, shard_act

Array = jax.Array
NEG_INF = -2.0e38


def init_mla(key, cfg, dtype):
    ks = jax.random.split(key, 8)
    d, h = cfg.d_model, cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "w_dq": param(ks[0], (d, qr), ("embed", "q_lora"), dtype=dtype),
        "q_norm": init_rms(ks[1], qr, axes=("q_lora",)),
        "w_uq": param(ks[2], (qr, h, nd + rd), ("q_lora", "q_heads", "head_dim"),
                      dtype=dtype),
        "w_dkv": param(ks[3], (d, kvr), ("embed", "kv_lora"), dtype=dtype),
        "kv_norm": init_rms(ks[4], kvr, axes=("kv_lora",)),
        "w_kpe": param(ks[5], (d, rd), ("embed", "head_dim"), dtype=dtype),
        "w_ukv": param(ks[6], (kvr, h, nd + vd), ("kv_lora", "q_heads", "head_dim"),
                       dtype=dtype),
        "w_o": param(ks[7], (h, vd, d), ("q_heads", "head_dim", "embed"),
                     dtype=dtype),
    }


def _queries(p, cfg, x, positions):
    nd, rd = cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = rms_norm(x @ p["w_dq"], p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhd->bshd", cq, p["w_uq"])
    q_nope, q_pe = q[..., :nd], q[..., nd:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    return shard_act(q_nope, ("batch", "seq", "q_heads", None)), \
        shard_act(q_pe, ("batch", "seq", "q_heads", None))


def _latents(p, cfg, x, positions):
    ckv = rms_norm(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)
    k_pe = apply_rope((x @ p["w_kpe"])[:, :, None, :], positions,
                      cfg.rope_theta)[:, :, 0]
    return (shard_act(ckv, ("batch", "seq", None)),
            shard_act(k_pe, ("batch", "seq", None)))


def mla_attention(p, cfg, x: Array, positions: Array) -> Array:
    """Training/prefill: materialized per-head K/V, causal, **chunked** over
    query blocks (same memory-efficient scheme as attention._sdpa — scores
    for 128 MLA heads at 4k+ would otherwise dominate device memory)."""
    b, s, _ = x.shape
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_pe = _queries(p, cfg, x, positions)
    ckv, k_pe = _latents(p, cfg, x, positions)
    kv = jnp.einsum("bsr,rhd->bshd", ckv, p["w_ukv"])
    k_nope, v = kv[..., :nd], kv[..., nd:]
    k_nope = shard_act(k_nope, ("batch", "kv_seq", "q_heads", None))
    v = shard_act(v, ("batch", "kv_seq", "q_heads", None))

    bq = cfg.attn_q_block or s
    bq = min(bq, s)
    while s % bq:
        bq -= 1
    scale = 1.0 / jnp.sqrt(nd + rd).astype(jnp.float32)
    k_pos = jnp.arange(s)
    outs = []
    for i in range(s // bq):                      # static unroll
        qs = i * bq
        qn = jax.lax.slice_in_dim(q_nope, qs, qs + bq, axis=1)
        qp = jax.lax.slice_in_dim(q_pe, qs, qs + bq, axis=1)
        scores = (jnp.einsum("bshd,bthd->bhst", qn, k_nope) +
                  jnp.einsum("bshd,btd->bhst", qp, k_pe)).astype(jnp.float32)
        scores = scores * scale
        scores = shard_act(scores, ("batch", "q_heads", None, "kv_seq"))
        causal = k_pos[None, :] <= (jnp.arange(qs, qs + bq))[:, None]
        scores = jnp.where(causal[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        outs.append(jnp.einsum("bhst,bthd->bshd", probs, v))
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return jnp.einsum("bshd,hdo->bso", out, p["w_o"])


# -- compressed cache --------------------------------------------------------


def init_mla_cache(cfg, batch: int, max_len: int, dtype):
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "kpe": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
    }


def mla_prefill(p, cfg, x, positions, cache):
    out = mla_attention(p, cfg, x, positions)
    ckv, k_pe = _latents(p, cfg, x, positions)
    cache = {
        "ckv": jax.lax.dynamic_update_slice(cache["ckv"], ckv, (0, 0, 0)),
        "kpe": jax.lax.dynamic_update_slice(cache["kpe"], k_pe, (0, 0, 0)),
    }
    return out, cache


def mla_decode(p, cfg, x, pos: Array, cache):
    """Absorbed one-token decode against the compressed (c_kv, k_pe) cache.

    q_lat = q_nope @ W_uk          (fold key up-proj into the query)
    score = q_lat · c_kv + q_pe · k_pe
    o_lat = probs · c_kv           (attend in latent space)
    out   = (o_lat @ W_uv) @ W_o   (fold value up-proj into output)
    """
    b = x.shape[0]
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    positions = jnp.full((b, 1), pos)
    q_nope, q_pe = _queries(p, cfg, x, positions)
    ckv_new, kpe_new = _latents(p, cfg, x, positions)
    cache = {
        "ckv": jax.lax.dynamic_update_slice(cache["ckv"], ckv_new, (0, pos, 0)),
        "kpe": jax.lax.dynamic_update_slice(cache["kpe"], kpe_new, (0, pos, 0)),
    }
    w_uk = p["w_ukv"][..., :nd]                        # (r, h, nd)
    w_uv = p["w_ukv"][..., nd:]                        # (r, h, vd)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)  # (b,1,h,r)
    t = cache["ckv"].shape[1]
    scores = (jnp.einsum("bshr,btr->bhst", q_lat, cache["ckv"]) +
              jnp.einsum("bshd,btd->bhst", q_pe, cache["kpe"]))
    scores = scores.astype(jnp.float32) / jnp.sqrt(nd + rd).astype(jnp.float32)
    valid = (jnp.arange(t) <= pos)[None, None, None]
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhst,btr->bshr", probs, cache["ckv"])
    out = jnp.einsum("bshr,rhd->bshd", o_lat, w_uv)
    return jnp.einsum("bshd,hdo->bso", out, p["w_o"]), cache
