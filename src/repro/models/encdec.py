"""Encoder–decoder LM (Whisper-large-v3 backbone).

The conv frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings ``audio_feats (B, S_enc, d_model)`` (what
whisper's two stride-2 convs would emit).  Positions are absolute sinusoidal
(whisper uses no RoPE).

Encoder: bidirectional MHA + GELU-MLP blocks (scanned).
Decoder: causal self-attn (+cache) → cross-attn over encoder states → MLP.
Decode shapes put the 32k/500k length in the *cross* KV (encoder frames);
decoder self-KV is capped at the arch's 448-token context (DESIGN.md §7).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn
from .layers import (cross_entropy, embed, gelu_mlp, init_embedding,
                     maybe_scan,
                     init_gelu_mlp, init_rms, logits_from_tied,
                     rms_norm, shard_act, sinusoidal_positions, split_params)

Array = jax.Array


def _init_enc_block(key, cfg, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "ln1": init_rms(k1, cfg.d_model),
        "attn": attn.init_attention(k2, cfg, dtype),
        "ln2": init_rms(k3, cfg.d_model),
        "mlp": init_gelu_mlp(k4, cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_block(key, cfg, dtype):
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "ln1": init_rms(k1, cfg.d_model),
        "self": attn.init_attention(k2, cfg, dtype),
        "ln_x": init_rms(k3, cfg.d_model),
        "cross": attn.init_cross_attention(k4, cfg, dtype),
        "ln2": init_rms(k5, cfg.d_model),
        "mlp": init_gelu_mlp(k6, cfg.d_model, cfg.d_ff, dtype),
    }


class EncDecLM:
    def __init__(self, cfg):
        self.cfg = cfg
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def init(self, rng):
        return split_params(self.init_tree(rng))

    def init_tree(self, rng):
        cfg = self.cfg
        kE, kEnc, kDec, kN1, kN2 = jax.random.split(rng, 5)
        enc_keys = jax.random.split(kEnc, cfg.enc_layers)
        dec_keys = jax.random.split(kDec, cfg.dec_layers)
        tree: dict[str, Any] = {
            "embedding": init_embedding(kE, cfg.padded_vocab, cfg.d_model,
                                        self.dtype),
            "enc": jax.vmap(lambda k: _init_enc_block(k, cfg, self.dtype))(
                enc_keys),
            "dec": jax.vmap(lambda k: _init_dec_block(k, cfg, self.dtype))(
                dec_keys),
            "enc_norm": init_rms(kN1, cfg.d_model),
            "dec_norm": init_rms(kN2, cfg.d_model),
        }
        from .layers import Param
        for name in ("enc", "dec"):
            tree[name] = jax.tree.map(
                lambda p: Param(p.value, ("layers",) + p.axes),
                tree[name], is_leaf=lambda x: isinstance(x, Param))
        return tree

    # -- encoder -------------------------------------------------------------

    def encode(self, params, audio_feats: Array) -> Array:
        cfg = self.cfg
        x = audio_feats.astype(self.dtype)
        pe = jnp.asarray(sinusoidal_positions(x.shape[1], cfg.d_model),
                         self.dtype)
        x = x + pe[None]
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

        def body(x, bp):
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            x = x + attn.bidirectional_attention(bp["attn"], cfg, h, positions)
            h = rms_norm(x, bp["ln2"], cfg.norm_eps)
            x = x + gelu_mlp(bp["mlp"], h)
            return shard_act(x, ("batch", "seq", "embed")), None

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = maybe_scan(body, x, params["enc"], cfg.unroll_groups)
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    # -- decoder (train) -------------------------------------------------------

    def _decoder(self, params, tokens: Array, enc_out: Array) -> Array:
        cfg = self.cfg
        x = embed(params["embedding"], tokens)
        pe = jnp.asarray(sinusoidal_positions(x.shape[1], cfg.d_model),
                         self.dtype)
        x = x + pe[None]
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

        def body(x, bp):
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            x = x + attn.attention(bp["self"], cfg, h, positions, "global")
            h = rms_norm(x, bp["ln_x"], cfg.norm_eps)
            kv = attn.cross_kv(bp["cross"], enc_out)
            x = x + attn.cross_attention(bp["cross"], cfg, h, kv)
            h = rms_norm(x, bp["ln2"], cfg.norm_eps)
            x = x + gelu_mlp(bp["mlp"], h)
            return shard_act(x, ("batch", "seq", "embed")), None

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = maybe_scan(body, x, params["dec"], cfg.unroll_groups)
        return rms_norm(x, params["dec_norm"], cfg.norm_eps)

    def loss(self, params, batch):
        """batch: audio_feats (B,S_enc,D), tokens (B,S_dec), labels (B,S_dec)."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["audio_feats"])
        h = self._decoder(params, batch["tokens"], enc_out)
        logits = logits_from_tied(params["embedding"], h, cfg.vocab_size)
        ce = cross_entropy(logits, batch["labels"])
        return ce, {"ce": ce, "loss": ce}

    # -- serving ----------------------------------------------------------------

    def init_cache(self, batch: int, enc_len: int):
        cfg = self.cfg
        self_len = cfg.max_decode_len

        def one(_):
            return {
                "k": jnp.zeros((batch, self_len, cfg.num_kv_heads,
                                cfg.head_dim), self.dtype),
                "v": jnp.zeros((batch, self_len, cfg.num_kv_heads,
                                cfg.head_dim), self.dtype),
                "xk": jnp.zeros((batch, enc_len, cfg.num_kv_heads,
                                 cfg.head_dim), self.dtype),
                "xv": jnp.zeros((batch, enc_len, cfg.num_kv_heads,
                                 cfg.head_dim), self.dtype),
            }
        return {"dec": jax.vmap(one)(jnp.arange(cfg.dec_layers))}

    def prefill(self, params, batch, cache):
        """Encode audio + consume a decoder prompt; fills self+cross caches."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["audio_feats"])
        tokens = batch["tokens"]
        x = embed(params["embedding"], tokens)
        pe = jnp.asarray(sinusoidal_positions(x.shape[1], cfg.d_model),
                         self.dtype)
        x = x + pe[None]
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

        def body(x, bp_c):
            bp, c = bp_c
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            sa, sc = attn.prefill_attention(bp["self"], cfg, h, positions,
                                            "global",
                                            {"k": c["k"], "v": c["v"]})
            x = x + sa
            h = rms_norm(x, bp["ln_x"], cfg.norm_eps)
            kv = attn.cross_kv(bp["cross"], enc_out)
            x = x + attn.cross_attention(bp["cross"], cfg, h, kv)
            h = rms_norm(x, bp["ln2"], cfg.norm_eps)
            x = x + gelu_mlp(bp["mlp"], h)
            newc = {"k": sc["k"], "v": sc["v"], "xk": kv["k"], "xv": kv["v"]}
            return x, newc

        x, cache["dec"] = maybe_scan(body, x, (params["dec"], cache["dec"]),
                                     cfg.unroll_groups)
        h = rms_norm(x[:, -1:], params["dec_norm"], cfg.norm_eps)
        return logits_from_tied(params["embedding"], h, cfg.vocab_size), cache

    def decode_step(self, params, cache, token: Array, pos):
        cfg = self.cfg
        x = embed(params["embedding"], token)
        pe = jnp.asarray(sinusoidal_positions(cfg.max_decode_len, cfg.d_model),
                         self.dtype)
        pe_pos = jax.lax.dynamic_slice_in_dim(pe, pos, 1, axis=0)   # (1, d)
        x = x + pe_pos[None]                                        # (B,1,d)

        def body(x, bp_c):
            bp, c = bp_c
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            sa, sc = attn.decode_attention(bp["self"], cfg, h, pos, "global",
                                           {"k": c["k"], "v": c["v"]})
            x = x + sa
            h = rms_norm(x, bp["ln_x"], cfg.norm_eps)
            x = x + attn.cross_attention(bp["cross"], cfg, h,
                                         {"k": c["xk"], "v": c["xv"]})
            h = rms_norm(x, bp["ln2"], cfg.norm_eps)
            x = x + gelu_mlp(bp["mlp"], h)
            newc = {"k": sc["k"], "v": sc["v"], "xk": c["xk"], "xv": c["xv"]}
            return x, newc

        x, cache["dec"] = maybe_scan(body, x, (params["dec"], cache["dec"]),
                                     cfg.unroll_groups)
        h = rms_norm(x, params["dec_norm"], cfg.norm_eps)
        return logits_from_tied(params["embedding"], h, cfg.vocab_size), cache

    def cross_attention_maps(self, params, batch):
        """(B, heads, S_dec, S_enc) maps from the last decoder block — the
        whisper mask source for the MaskSearch DB."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["audio_feats"])
        h = self._decoder(params, batch["tokens"], enc_out)  # final hidden
        bp = jax.tree.map(lambda x: x[-1], params["dec"])
        hn = rms_norm(h, bp["ln_x"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", hn, bp["cross"]["wq"])
        k = jnp.einsum("btd,dhk->bthk", enc_out, bp["cross"]["wk"])
        scores = jnp.einsum("bshd,bthd->bhst", q, k) / jnp.sqrt(cfg.head_dim)
        return jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
