"""RG-LRU recurrent block (Griffin, arXiv:2402.19427; RecurrentGemma).

The recurrent block is:   x → [linear branch: GeLU(W_gate x)]
                            → [recurrence branch: conv1d(W_x x) → RG-LRU]
                          merged by elementwise product → W_out.

RG-LRU recurrence (per channel):
    r_t = σ(W_r x_t)                      recurrence gate
    i_t = σ(W_i x_t)                      input gate
    a_t = exp(c · r_t · log a)            with  log a = −softplus(Λ) < 0, c = 8
    h_t = a_t ⊙ h_{t−1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

Training uses ``jax.lax.associative_scan`` over the sequence (the linear
recurrence (a, b) composes associatively) — O(log S) depth, fully parallel;
decode is the one-step recurrence with an O(1) state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import gelu, param, shard_act

Array = jax.Array
_C = 8.0


def init_rglru(key, cfg, dtype):
    w = cfg.lru_width or cfg.d_model
    ks = jax.random.split(key, 7)
    return {
        "w_x": param(ks[0], (cfg.d_model, w), ("embed", "mlp"), dtype=dtype),
        "w_gate": param(ks[1], (cfg.d_model, w), ("embed", "mlp"), dtype=dtype),
        "conv_w": param(ks[2], (cfg.conv_width, w), ("conv", "mlp"),
                        dtype=dtype, scale=0.5),
        "conv_b": param(ks[3], (w,), ("mlp",), scale="zeros"),
        "w_r": param(ks[4], (w, w), ("mlp", "mlp2"), dtype=dtype),
        "w_i": param(ks[5], (w, w), ("mlp", "mlp2"), dtype=dtype),
        # Λ init so that a ∈ (0.9, 0.999) at r=1 (Griffin appendix)
        "lam": param(ks[6], (w,), ("mlp",), scale=1.0),
        "w_out": param(jax.random.fold_in(key, 9), (w, cfg.d_model),
                       ("mlp", "embed"), dtype=dtype),
    }


def _conv(cfg, p, x: Array, conv_state: Array | None = None):
    w = cfg.conv_width
    if conv_state is not None:
        xin = jnp.concatenate([conv_state, x], axis=1)
    else:
        xin = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    out = sum(xin[:, i:i + x.shape[1]] * p["conv_w"][i] for i in range(w))
    return (out + p["conv_b"]).astype(x.dtype), xin[:, -(w - 1):]


def _gates(p, x: Array):
    """log_a (f32) and gated input; x is the conv'd recurrence branch."""
    r = jax.nn.sigmoid((x @ p["w_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ p["w_i"]).astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(p["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * x.astype(jnp.float32)
    return a, gated


def rglru_scan(p, x: Array):
    """(B,S,W) → (B,S,W) via associative scan of h_t = a_t h_{t−1} + b_t."""
    a, b = _gates(p, x)

    def combine(l, r):
        (al, bl), (ar, br) = l, r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block(p, cfg, x: Array):
    """Full recurrent block, training path."""
    gate = gelu(x @ p["w_gate"])
    rec, _ = _conv(cfg, p, x @ p["w_x"])
    h = rglru_scan(p, rec)
    h = shard_act(h.astype(x.dtype), ("batch", "seq", "mlp"))
    return (h * gate) @ p["w_out"]


def init_rglru_cache(cfg, batch: int, dtype):
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }


def rglru_prefill(p, cfg, x: Array, cache):
    gate = gelu(x @ p["w_gate"])
    rec, conv_state = _conv(cfg, p, x @ p["w_x"])
    h = rglru_scan(p, rec)
    out = (h.astype(x.dtype) * gate) @ p["w_out"]
    return out, {"h": h[:, -1], "conv": conv_state}


def rglru_decode(p, cfg, x: Array, cache):
    gate = gelu(x @ p["w_gate"])
    rec, conv_state = _conv(cfg, p, x @ p["w_x"], cache["conv"])
    a, b = _gates(p, rec)
    h = a[:, 0] * cache["h"] + b[:, 0]
    out = (h[:, None].astype(x.dtype) * gate) @ p["w_out"]
    return out, {"h": h, "conv": conv_state}
