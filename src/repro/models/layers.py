"""Shared model components: params-with-logical-axes, norms, RoPE, MLPs.

Parameters are plain pytrees of arrays.  Every parameter is created through
:func:`param`, which records a tuple of *logical axis names* alongside the
value; :func:`split_params` separates the two trees.  The launcher maps
logical names onto mesh axes (launch/sharding.py) — models never mention
physical axes.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass
class Param:
    """A parameter leaf paired with its logical sharding axes."""

    value: Array
    axes: tuple[str | None, ...]


# Registered as a pytree (axes static) so param trees survive vmap/scan —
# group stacking vmaps the init function directly.
jax.tree_util.register_pytree_node(
    Param,
    lambda p: ((p.value,), p.axes),
    lambda axes, children: Param(children[0], axes),
)


def is_param(x) -> bool:
    return isinstance(x, Param)


def param(key, shape, axes, *, dtype=jnp.float32, scale: float | str = "fan_in"):
    """Create a Param with truncated-normal init (or zeros/ones)."""
    assert len(shape) == len(axes), (shape, axes)
    if scale == "zeros":
        v = jnp.zeros(shape, dtype)
    elif scale == "ones":
        v = jnp.ones(shape, dtype)
    else:
        if scale == "fan_in":
            fan = shape[-2] if len(shape) >= 2 else shape[-1]
            scale = 1.0 / math.sqrt(fan)
        v = (scale * jax.random.truncated_normal(
            key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)
    return Param(v, tuple(axes))


def split_params(tree):
    """Param tree → (values tree, axes tree)."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)
    return values, axes


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# Activation sharding constraints (logical → physical happens in launch/)
# ---------------------------------------------------------------------------

_ACT_RULE: Callable[[Array, tuple], Array] | None = None


def set_activation_rule(fn) -> None:
    """Install the logical→physical activation-sharding hook (launcher only)."""
    global _ACT_RULE
    _ACT_RULE = fn


def shard_act(x: Array, axes: tuple[str | None, ...]) -> Array:
    """Annotate an activation with logical axes (no-op without a launcher)."""
    if _ACT_RULE is None:
        return x
    return _ACT_RULE(x, axes)


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------


def maybe_scan(body, carry, xs, unroll: bool):
    """lax.scan, or a python-unrolled equivalent when ``unroll``.

    The unrolled form exists for the roofline cost compiles: XLA's
    cost_analysis counts a while-loop body once regardless of trip count, so
    the 1-group/2-group measurement variants must not scan.
    """
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a, i=i: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: Array, weight: Array, eps: float = 1e-6) -> Array:
    """RMSNorm with a hand-written VJP (the fused-layernorm-backward
    pattern): reductions run in f32, but the (B,S,D) output AND its
    cotangent stay in the compute dtype.  Without this, the f32 variance
    branch keeps the whole backward residual stream in f32 and every TP
    boundary collective pays 2× ICI bytes (EXPERIMENTS.md §Perf iter 7)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * scale * (1.0 + weight.astype(x.dtype))


def _rms_fwd(x, weight, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    s32 = jax.lax.rsqrt(var + eps)                     # (B,S,1) f32
    y = x * s32.astype(x.dtype) * (1.0 + weight.astype(x.dtype))
    return y, (x, s32, weight)


def _rms_bwd(eps, res, g):
    x, s32, weight = res
    xf = x.astype(jnp.float32)
    gw = g.astype(jnp.float32) * (1.0 + weight.astype(jnp.float32))
    d = x.shape[-1]
    # dx = s·gw − x·s³·mean(gw·x)
    m = jnp.sum(gw * xf, axis=-1, keepdims=True) / d
    dx = s32 * gw - xf * (s32 ** 3) * m
    dw = jnp.sum((g.astype(jnp.float32) * xf * s32).reshape(-1, d), axis=0)
    return dx.astype(x.dtype), dw.astype(weight.dtype)


rms_norm.defvjp(_rms_fwd, _rms_bwd)


def init_rms(key, dim, axes=("embed",)):
    # stored as (weight - 1): zeros init → identity norm (gemma convention,
    # shared across all archs here)
    return param(key, (dim,), axes, scale="zeros")


def silu(x):
    return x * jax.nn.sigmoid(x)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


# ---------------------------------------------------------------------------
# Rotary position embeddings (NeoX half-rotation)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, D) with positions (..., S) — rotate pairs (d, d+D/2).

    Angle tables are computed in f32 (positions up to 512k need it) but the
    rotation itself runs in the compute dtype: sin/cos ∈ [−1, 1] lose ~3
    bits in bf16 (standard practice) and keeping the (B,S,H,D) tensors
    bf16 keeps their backward cotangents bf16 (§Perf iter 7)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d, theta), jnp.float32)
    ang = positions.astype(jnp.float32)[..., None] * freqs     # (..., S, D/2)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)           # (..., S, 1, D/2)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1)


def sinusoidal_positions(length: int, dim: int) -> np.ndarray:
    pos = np.arange(length)[:, None]
    div = np.exp(-math.log(10000.0) * np.arange(0, dim, 2) / dim)
    enc = np.zeros((length, dim), np.float32)
    enc[:, 0::2] = np.sin(pos * div)
    enc[:, 1::2] = np.cos(pos * div)
    return enc


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_swiglu(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": param(k1, (d_model, d_ff), ("embed", "mlp"), dtype=dtype),
        "up": param(k2, (d_model, d_ff), ("embed", "mlp"), dtype=dtype),
        "down": param(k3, (d_ff, d_model), ("mlp", "embed"), dtype=dtype),
    }


def swiglu(p, x: Array) -> Array:
    h = silu(x @ p["gate"]) * (x @ p["up"])
    h = shard_act(h, ("batch", "seq", "mlp"))
    return h @ p["down"]


def init_gelu_mlp(key, d_model: int, d_ff: int, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "up": param(k1, (d_model, d_ff), ("embed", "mlp"), dtype=dtype),
        "down": param(k2, (d_ff, d_model), ("mlp", "embed"), dtype=dtype),
    }


def gelu_mlp(p, x: Array) -> Array:
    h = gelu(x @ p["up"])
    h = shard_act(h, ("batch", "seq", "mlp"))
    return h @ p["down"]


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int, dtype):
    return param(key, (vocab, d_model), ("vocab", "embed"), dtype=dtype,
                 scale=1.0)


def embed(p_emb: Array, tokens: Array) -> Array:
    x = jnp.take(p_emb, tokens, axis=0)
    return shard_act(x, ("batch", "seq", "embed"))


def logits_from_tied(p_emb: Array, h: Array, valid_vocab: int = 0) -> Array:
    """LM head against (possibly pad-extended) embedding rows.  Columns
    ≥ valid_vocab (the padding that made vocab 16-divisible) are masked to
    −inf so softmax/argmax never see them."""
    out = h @ p_emb.T
    out = shard_act(out, ("batch", "seq", "vocab"))
    if valid_vocab and valid_vocab < p_emb.shape[0]:
        col = jax.lax.broadcasted_iota(jnp.int32, out.shape, out.ndim - 1)
        out = jnp.where(col < valid_vocab, out, jnp.asarray(-2.0e38, out.dtype))
    return out


def cross_entropy(logits: Array, labels: Array, mask: Array | None = None):
    """Mean token cross-entropy in f32; labels < 0 are ignored."""
    logits = logits.astype(jnp.float32)
    valid = labels >= 0 if mask is None else mask & (labels >= 0)
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    ll = jnp.where(valid, ll, 0.0)
    denom = jnp.maximum(jnp.sum(valid), 1)
    return -jnp.sum(ll) / denom
