"""build_model(cfg) — the single constructor the launcher/tests/examples use."""

from __future__ import annotations

from .encdec import EncDecLM
from .transformer import DecoderLM


def build_model(cfg):
    if cfg.is_encoder_decoder:
        return EncDecLM(cfg)
    return DecoderLM(cfg)
