"""Model zoo: the 10 assigned architectures as composable JAX modules."""

from .model import build_model  # noqa: F401
