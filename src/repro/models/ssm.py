"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060).

Training/prefill use the chunked SSD algorithm: quadratic attention-like
einsums *within* fixed-size chunks plus a linear inter-chunk state
recurrence; decode is the pure recurrence with an O(1) state
``(B, H, P, N)`` + a depthwise-conv ring — which is why this arch owns the
long_500k cell.

Block layout (mamba2-style):
    in_proj → [z (gate) | x | B | C | dt]
    depthwise causal conv over [x|B|C] (width 4), SiLU
    SSD(x·dt, A·dt, B, C) + D·x skip
    RMSNorm(gated by z) → out_proj
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import init_rms, param, rms_norm, shard_act, silu

Array = jax.Array


def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = cfg.ssm_heads or d_inner // cfg.ssm_head_dim
    return d_inner, nheads, cfg.ssm_head_dim, cfg.ssm_state


def init_ssm(key, cfg, dtype):
    d_inner, h, p_, n = _dims(cfg)
    conv_dim = d_inner + 2 * n
    ks = jax.random.split(key, 6)
    proj_out = 2 * d_inner + 2 * n + h
    return {
        "in_proj": param(ks[0], (cfg.d_model, proj_out), ("embed", "mlp"),
                         dtype=dtype),
        "conv_w": param(ks[1], (cfg.conv_width, conv_dim), ("conv", "mlp"),
                        dtype=dtype, scale=0.5),
        "conv_b": param(ks[2], (conv_dim,), ("mlp",), scale="zeros"),
        # A stored as log(-A): A = -exp(a_log) ∈ (−∞, 0)
        "a_log": param(ks[3], (h,), ("heads",), scale="zeros"),
        "d_skip": param(ks[4], (h,), ("heads",), scale="ones"),
        "dt_bias": param(ks[5], (h,), ("heads",), scale="zeros"),
        "out_norm": init_rms(jax.random.fold_in(key, 7), d_inner,
                             axes=("mlp",)),
        "out_proj": param(jax.random.fold_in(key, 8), (d_inner, cfg.d_model),
                          ("mlp", "embed"), dtype=dtype),
    }


def _split_proj(cfg, zxbcdt: Array):
    d_inner, h, p_, n = _dims(cfg)
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    return z, xbc, dt


def _conv(cfg, p, xbc: Array, conv_state: Array | None = None):
    """Depthwise causal conv1d (width W).  conv_state: (B, W-1, C) history."""
    w = cfg.conv_width
    if conv_state is not None:
        xbc_in = jnp.concatenate([conv_state, xbc], axis=1)
    else:
        xbc_in = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
    out = sum(xbc_in[:, i:i + xbc.shape[1]] * p["conv_w"][i]
              for i in range(w))
    return silu(out + p["conv_b"]).astype(xbc.dtype), xbc_in[:, -(w - 1):]


def _segsum(x: Array) -> Array:
    """(..., T) → (..., T, T) lower-tri cumulative sums: out[i,j] = Σ_{j<k≤i} x_k."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(cfg, x: Array, dt: Array, b_in: Array, c_in: Array, a: Array,
                init_state: Array | None = None):
    """Chunked SSD scan.

    x: (B,S,H,P)  dt: (B,S,H)  b_in/c_in: (B,S,N)  a: (H,) negative reals.
    Returns y: (B,S,H,P), final_state: (B,H,P,N).
    """
    bsz, s, h, p_ = x.shape
    in_dtype = x.dtype
    n = b_in.shape[-1]
    cs = min(cfg.chunk_size, s)
    assert s % cs == 0, f"seq {s} not divisible by chunk {cs}"
    nc = s // cs

    dt = jax.nn.softplus(dt.astype(jnp.float32))              # (B,S,H) ≥ 0
    dta = dt * a[None, None, :]                               # (B,S,H) ≤ 0
    xdt = x * dt[..., None].astype(x.dtype)

    def r(t_):  # (B,S,…) → (B,nc,cs,…)
        return t_.reshape((bsz, nc, cs) + t_.shape[2:])

    xc, dtac, bc, cc = r(xdt), r(dta), r(b_in), r(c_in)

    # 1) intra-chunk (quadratic within the chunk)
    l = jnp.exp(_segsum(dtac.transpose(0, 1, 3, 2)))          # (B,nc,H,cs,cs)
    scores = jnp.einsum("bcin,bcjn,bchij->bchij",
                        cc, bc, l.astype(cc.dtype))
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", scores, xc)

    # 2) chunk-final states
    a_cum = jnp.cumsum(dtac, axis=2)                          # (B,nc,cs,H)
    decay_to_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum)       # (B,nc,cs,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn",
                        bc, decay_to_end.astype(bc.dtype), xc)

    # 3) inter-chunk recurrence over nc (sequential scan, tiny trip count)
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])                 # (B,nc,H)

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None].astype(carry.dtype) + st
        return new, carry  # emit the state *entering* this chunk

    init = (jnp.zeros((bsz, h, p_, n), x.dtype) if init_state is None
            else init_state)
    final, entering = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    entering = entering.transpose(1, 0, 2, 3, 4)              # (B,nc,H,P,N)

    # 4) inter-chunk contribution
    decay_from_start = jnp.exp(a_cum)                         # (B,nc,cs,H)
    y_off = jnp.einsum("bcin,bcih,bchpn->bcihp",
                       cc, decay_from_start.astype(cc.dtype), entering)

    y = (y_diag + y_off).reshape(bsz, s, h, p_).astype(in_dtype)
    return y, final


def ssm_block(p, cfg, x: Array):
    """Full Mamba-2 block, training path.  x: (B,S,D) → (B,S,D)."""
    d_inner, h, hp, n = _dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc, _ = _conv(cfg, p, xbc)
    xs, b_in, c_in = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    xs = shard_act(xs.reshape(x.shape[0], x.shape[1], h, hp),
                   ("batch", "seq", "heads", None))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, _ = ssd_chunked(cfg, xs, dt + p["dt_bias"], b_in, c_in, a)
    y = y + xs * p["d_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(x.shape[0], x.shape[1], d_inner)
    y = rms_norm((y * silu(z)).astype(x.dtype), p["out_norm"], cfg.norm_eps)
    return y @ p["out_proj"]


# -- cache (decode) ----------------------------------------------------------


def init_ssm_cache(cfg, batch: int, dtype):
    d_inner, h, hp, n = _dims(cfg)
    conv_dim = d_inner + 2 * n
    return {
        "state": jnp.zeros((batch, h, hp, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
    }


def ssm_prefill(p, cfg, x: Array, cache):
    d_inner, h, hp, n = _dims(cfg)
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc_conv, conv_state = _conv(cfg, p, xbc)
    xs, b_in, c_in = jnp.split(xbc_conv, [d_inner, d_inner + n], axis=-1)
    xs = xs.reshape(x.shape[0], x.shape[1], h, hp)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, final = ssd_chunked(cfg, xs, dt + p["dt_bias"], b_in, c_in, a)
    y = y + xs * p["d_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(x.shape[0], x.shape[1], d_inner)
    y = rms_norm((y * silu(z)).astype(x.dtype), p["out_norm"], cfg.norm_eps)
    cache = {"state": final.astype(jnp.float32), "conv": conv_state}
    return y @ p["out_proj"], cache


def ssm_decode(p, cfg, x: Array, cache):
    """One-token recurrence: h' = exp(dt·A)·h + dt·B·x ; y = C·h' + D·x."""
    d_inner, h, hp, n = _dims(cfg)
    bsz = x.shape[0]
    zxbcdt = x @ p["in_proj"]                                 # (B,1,…)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc, conv_state = _conv(cfg, p, xbc, cache["conv"])
    xs, b_in, c_in = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    xs = xs.reshape(bsz, h, hp)
    dt = jax.nn.softplus(dt[:, 0] + p["dt_bias"])             # (B,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a[None, :])                          # (B,H)
    dbx = jnp.einsum("bh,bn,bhp->bhpn", dt, b_in[:, 0], xs.astype(jnp.float32))
    state = cache["state"] * decay[..., None, None] + dbx
    y = jnp.einsum("bhpn,bn->bhp", state, c_in[:, 0].astype(jnp.float32))
    y = y.astype(x.dtype) + xs * p["d_skip"][None, :, None].astype(x.dtype)
    y = y.reshape(bsz, 1, d_inner)
    y = rms_norm((y * silu(z)).astype(x.dtype), p["out_norm"], cfg.norm_eps)
    return y @ p["out_proj"], {"state": state, "conv": conv_state}
