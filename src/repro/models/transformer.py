"""Decoder-LM assembly: every non-enc-dec architecture in the zoo.

Layer stacking follows the config's ``layer_pattern`` repeating unit
(DESIGN.md §5): the stack is

    [prefix]  first_k_dense layers, unrolled   (DeepSeek's leading dense FFNs)
    [groups]  num_scan_groups × pattern, **scanned** (compile-time O(1) HLO)
    [tail]    pattern remainder, unrolled      (gemma3's trailing 2 locals)

Group parameters are stacked pytrees (leading "layers" axis) built by
vmapping the group initializer.  Scan keeps compile time flat across 24–64
layer models; the roofline extractor linearizes costs from 1-group/2-group
unrolled compiles (launch/dryrun.py).

Block kinds: "global"/"local" (GQA or MLA attention + FFN), "rglru"
(recurrent block + FFN), "ssm" (Mamba-2 block, no separate FFN).
MoE replaces the dense FFN after ``first_k_dense`` layers.  DeepSeek-V3's
MTP head is an extra shared-embedding block predicting t+2.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mla as mla_lib
from . import moe as moe_lib
from . import rglru as rglru_lib
from . import ssm as ssm_lib
from .layers import (Param, cross_entropy, embed, init_embedding, init_rms,
                     maybe_scan,
                     init_swiglu, logits_from_tied, param, rms_norm, shard_act,
                     split_params, swiglu)

Array = jax.Array


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _uses_moe(cfg) -> bool:
    return cfg.num_experts > 0


def init_block(key, cfg, kind: str, use_moe: bool, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": init_rms(k1, cfg.d_model)}
    if kind in ("global", "local"):
        if cfg.attention == "mla":
            p["mixer"] = mla_lib.init_mla(k2, cfg, dtype)
        else:
            p["mixer"] = attn.init_attention(k2, cfg, dtype)
    elif kind == "rglru":
        p["mixer"] = rglru_lib.init_rglru(k2, cfg, dtype)
    elif kind == "ssm":
        p["mixer"] = ssm_lib.init_ssm(k2, cfg, dtype)
    else:
        raise ValueError(kind)
    if kind != "ssm":
        p["ln2"] = init_rms(k3, cfg.d_model)
        if use_moe:
            p["ffn"] = moe_lib.init_moe(k4, cfg, dtype)
        else:
            p["ffn"] = init_swiglu(k4, cfg.d_model, cfg.d_ff, dtype)
    return p


def apply_block(p, cfg, kind: str, use_moe: bool, x: Array,
                positions: Array) -> tuple[Array, Array]:
    """Training-path block.  Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("global", "local"):
        if cfg.attention == "mla":
            h = mla_lib.mla_attention(p["mixer"], cfg, h, positions)
        else:
            h = attn.attention(p["mixer"], cfg, h, positions, kind)
    elif kind == "rglru":
        h = rglru_lib.rglru_block(p["mixer"], cfg, h)
    else:  # ssm
        h = ssm_lib.ssm_block(p["mixer"], cfg, h)
    x = x + h
    if kind != "ssm":
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if use_moe:
            h, aux = moe_lib.moe_ffn(p["ffn"], cfg, h)
        else:
            h = swiglu(p["ffn"], h)
        x = x + h
    return shard_act(x, ("batch", "seq", "embed")), aux


def init_block_cache(cfg, kind: str, batch: int, max_len: int, dtype):
    if kind in ("global", "local"):
        if cfg.attention == "mla":
            return mla_lib.init_mla_cache(cfg, batch, max_len, dtype)
        return attn.init_cache(cfg, batch, max_len, kind, dtype)
    if kind == "rglru":
        return rglru_lib.init_rglru_cache(cfg, batch, dtype)
    return ssm_lib.init_ssm_cache(cfg, batch, dtype)


def apply_block_prefill(p, cfg, kind, use_moe, x, positions, cache):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("global", "local"):
        if cfg.attention == "mla":
            h, cache = mla_lib.mla_prefill(p["mixer"], cfg, h, positions, cache)
        else:
            h, cache = attn.prefill_attention(p["mixer"], cfg, h, positions,
                                              kind, cache)
    elif kind == "rglru":
        h, cache = rglru_lib.rglru_prefill(p["mixer"], cfg, h, cache)
    else:
        h, cache = ssm_lib.ssm_prefill(p["mixer"], cfg, h, cache)
    x = x + h
    if kind != "ssm":
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        h = moe_lib.moe_ffn(p["ffn"], cfg, h)[0] if use_moe else swiglu(p["ffn"], h)
        x = x + h
    return x, cache


def apply_block_decode(p, cfg, kind, use_moe, x, pos, cache):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("global", "local"):
        if cfg.attention == "mla":
            h, cache = mla_lib.mla_decode(p["mixer"], cfg, h, pos, cache)
        else:
            h, cache = attn.decode_attention(p["mixer"], cfg, h, pos, kind,
                                             cache)
    elif kind == "rglru":
        h, cache = rglru_lib.rglru_decode(p["mixer"], cfg, h, cache)
    else:
        h, cache = ssm_lib.ssm_decode(p["mixer"], cfg, h, cache)
    x = x + h
    if kind != "ssm":
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        h = moe_lib.moe_ffn(p["ffn"], cfg, h)[0] if use_moe else swiglu(p["ffn"], h)
        x = x + h
    return x, cache


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


def _stack_plan(cfg):
    """(prefix_kinds, group_kinds, n_groups, tail_kinds)."""
    kinds = list(cfg.pattern_layers)
    nprefix = cfg.first_k_dense if _uses_moe(cfg) else 0
    prefix = tuple(kinds[:nprefix])
    rest = kinds[nprefix:]
    glen = len(cfg.layer_pattern)
    n_groups = len(rest) // glen
    tail = tuple(rest[n_groups * glen:])
    return prefix, tuple(cfg.layer_pattern), n_groups, tail


class DecoderLM:
    """Functional LM: params are plain pytrees; methods are jit-safe."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        self.prefix_kinds, self.group_kinds, self.n_groups, self.tail_kinds = \
            _stack_plan(cfg)

    # -- init ---------------------------------------------------------------

    def _init_group(self, key):
        moe = _uses_moe(self.cfg)
        keys = jax.random.split(key, len(self.group_kinds))
        return {f"block{i}": init_block(keys[i], self.cfg, kind, moe, self.dtype)
                for i, kind in enumerate(self.group_kinds)}

    def init(self, rng):
        """→ (params, logical_axes) — two same-structure pytrees."""
        return split_params(self.init_tree(rng))

    def init_tree(self, rng):
        """Param-node tree (axes as static pytree aux — eval_shape-safe)."""
        cfg = self.cfg
        kE, kP, kG, kT, kM, kN = jax.random.split(rng, 6)
        tree: dict[str, Any] = {
            "embedding": init_embedding(kE, cfg.padded_vocab, cfg.d_model,
                                        self.dtype),
            "final_norm": init_rms(kN, cfg.d_model),
        }
        if self.prefix_kinds:
            keys = jax.random.split(kP, len(self.prefix_kinds))
            tree["prefix"] = {
                f"block{i}": init_block(keys[i], cfg, kind, False, self.dtype)
                for i, kind in enumerate(self.prefix_kinds)}
        if self.n_groups:
            gkeys = jax.random.split(kG, self.n_groups)
            stacked = jax.vmap(self._init_group)(gkeys)
            # prepend the scanned "layers" axis to every logical-axes tuple
            stacked = jax.tree.map(
                lambda p: Param(p.value, ("layers",) + p.axes),
                stacked, is_leaf=lambda x: isinstance(x, Param))
            tree["groups"] = stacked
        if self.tail_kinds:
            keys = jax.random.split(kT, len(self.tail_kinds))
            tree["tail"] = {
                f"block{i}": init_block(keys[i], cfg, kind, _uses_moe(cfg),
                                        self.dtype)
                for i, kind in enumerate(self.tail_kinds)}
        if cfg.mtp_depth:
            km1, km2, km3 = jax.random.split(kM, 3)
            tree["mtp"] = {
                "proj": param(km1, (2 * cfg.d_model, cfg.d_model),
                              ("embed", "embed"), dtype=self.dtype),
                "block": init_block(km2, cfg, "global", _uses_moe(cfg),
                                    self.dtype),
                "norm": init_rms(km3, cfg.d_model),
            }
        return tree

    # -- forward (train) ------------------------------------------------------

    def _inputs(self, params, batch):
        """Token (+ optional patch) embeddings and positions."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = embed(params["embedding"], tokens) * jnp.asarray(
            cfg.embed_scale, self.dtype)
        if cfg.num_patches and "patches" in batch:
            patches = batch["patches"].astype(self.dtype)
            x = jnp.concatenate([patches, x], axis=1)
        if cfg.pos_embedding == "absolute":
            from .layers import sinusoidal_positions
            pe = jnp.asarray(sinusoidal_positions(x.shape[1], cfg.d_model),
                             self.dtype)
            x = x + pe[None]
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]),
                                     (x.shape[0], x.shape[1]))
        return x, positions

    def hidden_states(self, params, batch):
        """Full stack forward → (h (B,S,D), aux_loss)."""
        cfg = self.cfg
        x, positions = self._inputs(params, batch)
        aux = jnp.zeros((), jnp.float32)
        moe = _uses_moe(cfg)
        for i, kind in enumerate(self.prefix_kinds):
            x, a = apply_block(params["prefix"][f"block{i}"], cfg, kind, False,
                               x, positions)
            aux += a
        if self.n_groups:
            def group_fn(x, gp):
                a_g = jnp.zeros((), jnp.float32)
                for i, kind in enumerate(self.group_kinds):
                    x, a = apply_block(gp[f"block{i}"], cfg, kind, moe, x,
                                       positions)
                    a_g += a
                return x, a_g

            if cfg.remat:
                group_fn = jax.checkpoint(
                    group_fn, policy=jax.checkpoint_policies.nothing_saveable)

            def body(carry, gp):
                x, aux = carry
                x, a_g = group_fn(x, gp)
                return (x, aux + a_g), None

            (x, aux), _ = maybe_scan(body, (x, aux), params["groups"],
                                     cfg.unroll_groups)
        for i, kind in enumerate(self.tail_kinds):
            x, a = apply_block(params["tail"][f"block{i}"], cfg, kind, moe, x,
                               positions)
            aux += a
        return rms_norm(x, params["final_norm"], cfg.norm_eps), aux

    def logits(self, params, batch):
        h, aux = self.hidden_states(params, batch)
        return logits_from_tied(params["embedding"], h,
                                self.cfg.vocab_size), aux

    def loss(self, params, batch):
        """batch: tokens (B,S), labels (B,S) [-1 = pad] (+ patches for VLM).

        Returns (loss, metrics-dict).  VLM: labels cover text positions only;
        patch positions are prepended and excluded automatically.
        """
        cfg = self.cfg
        h, aux = self.hidden_states(params, batch)
        labels = batch["labels"]
        if cfg.num_patches and "patches" in batch:
            h_text = h[:, -labels.shape[1]:]
        else:
            h_text = h
        logits = logits_from_tied(params["embedding"], h_text, cfg.vocab_size)
        ce = cross_entropy(logits, labels)
        total = ce + aux
        metrics = {"ce": ce, "aux": aux}
        if cfg.mtp_depth and "labels_mtp" in batch:
            mtp = params["mtp"]
            # combine h_t with the embedding of token_{t+1} (= the main label)
            emb_next = embed(params["embedding"],
                             jnp.maximum(batch["labels"], 0))
            hin = jnp.concatenate(
                [rms_norm(h_text, mtp["norm"], cfg.norm_eps),
                 emb_next.astype(h_text.dtype)], axis=-1) @ mtp["proj"]
            positions = jnp.broadcast_to(
                jnp.arange(hin.shape[1]), hin.shape[:2])
            h_mtp, _ = apply_block(mtp["block"], cfg, "global", _uses_moe(cfg),
                                   hin, positions)
            logits_mtp = logits_from_tied(params["embedding"], h_mtp, cfg.vocab_size)
            ce_mtp = cross_entropy(logits_mtp, batch["labels_mtp"])
            total = total + cfg.mtp_weight * ce_mtp
            metrics["ce_mtp"] = ce_mtp
        metrics["loss"] = total
        return total, metrics

    # -- serving ----------------------------------------------------------------

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        cache: dict[str, Any] = {}
        if self.prefix_kinds:
            cache["prefix"] = {
                f"block{i}": init_block_cache(cfg, kind, batch, max_len,
                                              self.dtype)
                for i, kind in enumerate(self.prefix_kinds)}
        if self.n_groups:
            def one(_):
                return {f"block{i}": init_block_cache(cfg, kind, batch,
                                                      max_len, self.dtype)
                        for i, kind in enumerate(self.group_kinds)}
            cache["groups"] = jax.vmap(one)(jnp.arange(self.n_groups))
        if self.tail_kinds:
            cache["tail"] = {
                f"block{i}": init_block_cache(cfg, kind, batch, max_len,
                                              self.dtype)
                for i, kind in enumerate(self.tail_kinds)}
        return cache

    def prefill(self, params, batch, cache):
        """Consume the prompt; → (last-position logits, cache)."""
        cfg = self.cfg
        x, positions = self._inputs(params, batch)
        moe = _uses_moe(cfg)
        for i, kind in enumerate(self.prefix_kinds):
            x, cache["prefix"][f"block{i}"] = apply_block_prefill(
                params["prefix"][f"block{i}"], cfg, kind, False, x, positions,
                cache["prefix"][f"block{i}"])
        if self.n_groups:
            def body(x, gp_gc):
                gp, gc = gp_gc
                newc = {}
                for i, kind in enumerate(self.group_kinds):
                    x, newc[f"block{i}"] = apply_block_prefill(
                        gp[f"block{i}"], cfg, kind, moe, x, positions,
                        gc[f"block{i}"])
                return x, newc
            x, cache["groups"] = maybe_scan(
                body, x, (params["groups"], cache["groups"]),
                cfg.unroll_groups)
        for i, kind in enumerate(self.tail_kinds):
            x, cache["tail"][f"block{i}"] = apply_block_prefill(
                params["tail"][f"block{i}"], cfg, kind, moe, x, positions,
                cache["tail"][f"block{i}"])
        h = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        return logits_from_tied(params["embedding"], h, cfg.vocab_size), cache

    def decode_step(self, params, cache, token: Array, pos):
        """One token for the whole batch.  token: (B, 1) int32, pos: () int32."""
        cfg = self.cfg
        x = embed(params["embedding"], token) * jnp.asarray(
            cfg.embed_scale, self.dtype)
        moe = _uses_moe(cfg)
        for i, kind in enumerate(self.prefix_kinds):
            x, cache["prefix"][f"block{i}"] = apply_block_decode(
                params["prefix"][f"block{i}"], cfg, kind, False, x, pos,
                cache["prefix"][f"block{i}"])
        if self.n_groups:
            def body(x, gp_gc):
                gp, gc = gp_gc
                newc = {}
                for i, kind in enumerate(self.group_kinds):
                    x, newc[f"block{i}"] = apply_block_decode(
                        gp[f"block{i}"], cfg, kind, moe, x, pos,
                        gc[f"block{i}"])
                return x, newc
            x, cache["groups"] = maybe_scan(
                body, x, (params["groups"], cache["groups"]),
                cfg.unroll_groups)
        for i, kind in enumerate(self.tail_kinds):
            x, cache["tail"][f"block{i}"] = apply_block_decode(
                params["tail"][f"block{i}"], cfg, kind, moe, x, pos,
                cache["tail"][f"block{i}"])
        h = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return logits_from_tied(params["embedding"], h, cfg.vocab_size), cache

    # -- mask extraction (MaskSearch integration) ------------------------------

    def attention_maps(self, params, batch):
        """Post-softmax attention of the *last* attention layer, for the mask
        DB (small models / examples; recomputes the stack).  Returns
        (B, heads, S, S) or None for attention-free stacks."""
        cfg = self.cfg
        kinds = (list(self.prefix_kinds) +
                 list(self.group_kinds) * self.n_groups +
                 list(self.tail_kinds))
        if not any(k in ("global", "local") for k in kinds):
            return None
        if cfg.attention == "mla":
            return None  # examples use GQA archs for attention masks
        x, positions = self._inputs(params, batch)
        # run blocks sequentially (examples-only path, small models) so the
        # last attention block sees its true input
        last = max(i for i, k in enumerate(kinds) if k in ("global", "local"))
        moe = _uses_moe(cfg)
        for i in range(last):
            dense_prefix = i < len(self.prefix_kinds)
            x, _ = apply_block(self._block_params(params, i), cfg, kinds[i],
                               moe and not dense_prefix, x, positions)
        p_block = self._block_params(params, last)
        hn = rms_norm(x, p_block["ln1"], cfg.norm_eps)
        q, k, v = attn._qkv(p_block["mixer"], cfg, hn, positions,
                            kinds[last])
        del v
        b, s, hq, d = q.shape
        hkv = k.shape[2]
        q = q.reshape(b, s, hkv, hq // hkv, d)
        scores = jnp.einsum("bshgd,bthd->bhgst", q, k) / jnp.sqrt(d)
        mask = attn.causal_mask(s, s, 0,
                                cfg.local_window if kinds[last] == "local"
                                else 0)
        scores = jnp.where(mask, scores.astype(jnp.float32), attn.NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        return probs.reshape(b, hkv * (hq // hkv), s, s)

    def _block_params(self, params, flat_idx: int):
        np_, ng, gl = (len(self.prefix_kinds), self.n_groups,
                       len(self.group_kinds))
        if flat_idx < np_:
            return params["prefix"][f"block{flat_idx}"]
        flat_idx -= np_
        if flat_idx < ng * gl:
            g, i = divmod(flat_idx, gl)
            return jax.tree.map(lambda x: x[g], params["groups"])[f"block{i}"]
        return params["tail"][f"block{flat_idx - ng * gl}"]
