"""Minimal stdlib client for the MaskSearch query service.

Mirrors the HTTP API one-to-one; used by the interactive example, the
service smoke tests, and ``bench_serve``.
"""

from __future__ import annotations

import json
from typing import Optional, Sequence
from urllib import request as _request
from urllib.error import HTTPError


class ServiceError(RuntimeError):
    def __init__(self, code: int, message: str):
        super().__init__(f"HTTP {code}: {message}")
        self.code = code


class ServiceClient:
    def __init__(self, base_url: str, *, timeout: float = 30.0):
        self.base_url = base_url.rstrip("/")
        self.timeout = timeout

    # -- plumbing ---------------------------------------------------------
    def _call(self, method: str, path: str, body: Optional[dict] = None,
              *, raw: bool = False):
        data = json.dumps(body).encode() if body is not None else None
        req = _request.Request(
            self.base_url + path, data=data, method=method,
            headers={"Content-Type": "application/json"} if data else {})
        try:
            with _request.urlopen(req, timeout=self.timeout) as resp:
                payload = resp.read()
                return payload.decode() if raw else json.loads(payload)
        except HTTPError as e:
            try:
                message = json.loads(e.read()).get("error", str(e))
            except Exception:          # noqa: BLE001 — best-effort decode
                message = str(e)
            raise ServiceError(e.code, message) from e

    # -- API --------------------------------------------------------------
    def query(self, sql: str, *, rois=None, session: bool = False,
              page_size: Optional[int] = None) -> dict:
        body = {"sql": sql, "session": session}
        if page_size is not None:
            body["page_size"] = page_size
        if rois is not None:
            body["rois"] = [[int(v) for v in row] for row in rois]
        return self._call("POST", "/query", body)

    def workload(self, sqls: Sequence[str], *, rois=None) -> list:
        body = {"sqls": list(sqls)}
        if rois is not None:
            body["rois"] = [[int(v) for v in row] for row in rois]
        return self._call("POST", "/workload", body)

    def ingest(self, masks, *, mask_ids=None, image_ids=None, model_ids=None,
               mask_types=None, on_conflict: str = "error") -> dict:
        """Append/upsert masks (nested lists or arrays) into the database."""
        body = {"masks": [[[float(v) for v in row] for row in m]
                          for m in masks],
                "on_conflict": on_conflict}
        if mask_ids is not None:
            body["mask_ids"] = [int(x) for x in mask_ids]
        if image_ids is not None:
            body["image_ids"] = [int(x) for x in image_ids]
        if model_ids is not None:
            body["model_ids"] = (int(model_ids)
                                 if not hasattr(model_ids, "__len__")
                                 else [int(x) for x in model_ids])
        if mask_types is not None:
            body["mask_types"] = (int(mask_types)
                                  if not hasattr(mask_types, "__len__")
                                  else [int(x) for x in mask_types])
        return self._call("POST", "/ingest", body)

    def delete_masks(self, mask_ids) -> dict:
        return self._call("POST", "/delete",
                          {"mask_ids": [int(x) for x in mask_ids]})

    def next_page(self, session_id: str, k: Optional[int] = None) -> dict:
        suffix = f"?k={int(k)}" if k is not None else ""
        return self._call("GET", f"/session/{session_id}/page{suffix}")

    def drop_session(self, session_id: str) -> dict:
        return self._call("DELETE", f"/session/{session_id}")

    def stats(self) -> dict:
        return self._call("GET", "/stats")

    def healthz(self) -> dict:
        return self._call("GET", "/healthz")

    # -- observability ----------------------------------------------------
    def explain(self, sql: str, *, analyze: bool = True, rois=None) -> dict:
        """``EXPLAIN [ANALYZE] <sql>`` → the (annotated) operator tree.
        Idempotent if ``sql`` already carries an EXPLAIN prefix."""
        if not sql.lstrip().upper().startswith("EXPLAIN"):
            sql = ("EXPLAIN ANALYZE " if analyze else "EXPLAIN ") + sql
        return self.query(sql, rois=rois)

    def metrics(self) -> str:
        """The Prometheus text exposition from ``GET /metrics``."""
        return self._call("GET", "/metrics", raw=True)

    def trace(self, query_id: str = "last", *, fmt: str = "json") -> dict:
        """A retained span tree (``fmt="chrome"`` → trace-event JSON)."""
        suffix = f"?format={fmt}" if fmt != "json" else ""
        return self._call("GET", f"/trace/{query_id}{suffix}")
