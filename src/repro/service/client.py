"""Stdlib client for the MaskSearch query service, speaking the ``/v1``
API (structured error envelopes, opaque continuation cursors).

Public method signatures are unchanged from the legacy client, and the
dict shapes they return keep the historical layout (``session``/``page``
keys) so existing callers and tests need no edits — the ``session`` value
is now an opaque ``/v1`` continuation cursor rather than a bare session
id (the server accepts either).

Resilience: ``_call`` retries transparently on connection errors and
429 shed responses with jittered exponential backoff, honouring the
server's ``Retry-After``.  Mutations (``ingest``/``delete_masks``) are
**not** retried by default — a timed-out ingest may have applied, and a
blind resend with ``on_conflict="error"`` would double-apply or fault;
opt in with ``retry_mutations=True`` if the workload is idempotent.
"""

from __future__ import annotations

import json
import random
import time
from typing import Optional, Sequence
from urllib import request as _request
from urllib.error import HTTPError, URLError


class ServiceError(RuntimeError):
    """An HTTP error from the service.

    ``code`` is the HTTP status (historical name, kept for
    compatibility); the ``/v1`` envelope's machine-readable fields are
    ``error_code`` (e.g. ``"rate_limited"``), ``error_type`` (the
    server-side exception class) and ``retry_after`` (seconds, when the
    response was a shed)."""

    def __init__(self, code: int, message: str, *,
                 error_code: Optional[str] = None,
                 error_type: Optional[str] = None,
                 retry_after: Optional[float] = None):
        super().__init__(f"HTTP {code}: {message}")
        self.code = code
        self.error_code = error_code
        self.error_type = error_type
        self.retry_after = retry_after


class ServiceClient:
    def __init__(self, base_url: str, *, timeout: float = 30.0,
                 retries: int = 2, backoff_s: float = 0.05,
                 max_backoff_s: float = 2.0,
                 retry_mutations: bool = False):
        self.base_url = base_url.rstrip("/")
        self.timeout = timeout
        self.retries = max(int(retries), 0)
        self.backoff_s = backoff_s
        self.max_backoff_s = max_backoff_s
        self.retry_mutations = retry_mutations
        self._rng = random.Random()

    # -- plumbing ---------------------------------------------------------
    def _sleep(self, attempt: int, retry_after: Optional[float]) -> None:
        # full jitter over an exponential ceiling; a server-provided
        # Retry-After is a floor (the shed really is that long)
        ceiling = min(self.backoff_s * (2 ** attempt), self.max_backoff_s)
        delay = ceiling * (0.5 + 0.5 * self._rng.random())
        if retry_after is not None:
            delay = max(delay, float(retry_after))
        time.sleep(delay)

    @staticmethod
    def _error_from(e: HTTPError) -> ServiceError:
        error_code = error_type = retry_after = None
        try:
            body = json.loads(e.read())
            err = body.get("error")
            if isinstance(err, dict):            # /v1 envelope
                message = err.get("message", str(e))
                error_code = err.get("code")
                error_type = err.get("type")
                retry_after = err.get("retry_after")
            else:                                # legacy {"error": "<str>"}
                message = err if err is not None else str(e)
        except Exception:          # noqa: BLE001 — best-effort decode
            message = str(e)
        if retry_after is None:
            header = e.headers.get("Retry-After") if e.headers else None
            if header is not None:
                try:
                    retry_after = float(header)
                except ValueError:
                    pass
        return ServiceError(e.code, message, error_code=error_code,
                            error_type=error_type, retry_after=retry_after)

    def _call(self, method: str, path: str, body: Optional[dict] = None,
              *, raw: bool = False, idempotent: bool = True):
        data = json.dumps(body).encode() if body is not None else None
        retriable = idempotent or self.retry_mutations
        attempt = 0
        while True:
            req = _request.Request(
                self.base_url + path, data=data, method=method,
                headers={"Content-Type": "application/json"} if data else {})
            try:
                with _request.urlopen(req, timeout=self.timeout) as resp:
                    payload = resp.read()
                    return payload.decode() if raw else json.loads(payload)
            except HTTPError as e:
                err = self._error_from(e)
                if e.code == 429 and retriable and attempt < self.retries:
                    self._sleep(attempt, err.retry_after)
                    attempt += 1
                    continue
                raise err from e
            except URLError as e:
                if retriable and attempt < self.retries:
                    self._sleep(attempt, None)
                    attempt += 1
                    continue
                raise

    # -- legacy-shape adapters -------------------------------------------
    @staticmethod
    def _page_compat(payload: dict, fallback_cursor: str = "") -> dict:
        """/v1 cursor-paged payload → the historical session/page layout
        (``session`` carries the continuation cursor)."""
        if "items" not in payload:
            return payload                      # one-shot / explain: as-is
        items = payload["items"]
        out = {
            "kind": payload["kind"],
            "session": payload["cursor"] or fallback_cursor,
            "page": {"offset": payload["offset"],
                     "ids": [it["id"] for it in items],
                     "scores": [it["score"] for it in items]},
            "served": payload["served"],
            "total_candidates": payload["total_candidates"],
            "exhausted": payload["exhausted"],
            "stats": payload["stats"],
            "cache_hit": payload["cache_hit"],
        }
        if "query_id" in payload:
            out["query_id"] = payload["query_id"]
        return out

    # -- API --------------------------------------------------------------
    def query(self, sql: str, *, rois=None, session: bool = False,
              page_size: Optional[int] = None) -> dict:
        body = {"sql": sql, "session": session}
        if page_size is not None:
            body["page_size"] = page_size
        if rois is not None:
            body["rois"] = [[int(v) for v in row] for row in rois]
        return self._page_compat(self._call("POST", "/v1/query", body))

    def workload(self, sqls: Sequence[str], *, rois=None) -> list:
        body = {"sqls": list(sqls)}
        if rois is not None:
            body["rois"] = [[int(v) for v in row] for row in rois]
        return [self._page_compat(p)
                for p in self._call("POST", "/v1/workload", body)["items"]]

    def ingest(self, masks, *, mask_ids=None, image_ids=None, model_ids=None,
               mask_types=None, on_conflict: str = "error") -> dict:
        """Append/upsert masks (nested lists or arrays) into the database.

        Returns the ``/v1`` mutation envelope ``{"epoch", "applied":
        {"appended", "updated"}, ...}`` with the legacy flat counters
        mirrored at top level."""
        body = {"masks": [[[float(v) for v in row] for row in m]
                          for m in masks],
                "on_conflict": on_conflict}
        if mask_ids is not None:
            body["mask_ids"] = [int(x) for x in mask_ids]
        if image_ids is not None:
            body["image_ids"] = [int(x) for x in image_ids]
        if model_ids is not None:
            body["model_ids"] = (int(model_ids)
                                 if not hasattr(model_ids, "__len__")
                                 else [int(x) for x in model_ids])
        if mask_types is not None:
            body["mask_types"] = (int(mask_types)
                                  if not hasattr(mask_types, "__len__")
                                  else [int(x) for x in mask_types])
        out = self._call("POST", "/v1/ingest", body, idempotent=False)
        return {**out, **out["applied"]}

    def delete_masks(self, mask_ids) -> dict:
        out = self._call("POST", "/v1/delete",
                         {"mask_ids": [int(x) for x in mask_ids]},
                         idempotent=False)
        return {**out, **out["applied"]}

    def next_page(self, session_id: str, k: Optional[int] = None) -> dict:
        """Advance a session: ``session_id`` is the cursor returned in the
        previous payload's ``session`` field (bare legacy ids work too)."""
        body: dict = {"cursor": session_id}
        if k is not None:
            body["k"] = int(k)
        return self._page_compat(self._call("POST", "/v1/page", body),
                                 fallback_cursor=session_id)

    def drop_session(self, session_id: str) -> dict:
        return self._call("POST", "/v1/session/drop",
                          {"cursor": session_id}, idempotent=False)

    def stats(self) -> dict:
        return self._call("GET", "/v1/stats")

    def healthz(self) -> dict:
        return self._call("GET", "/v1/healthz")

    # -- observability ----------------------------------------------------
    def explain(self, sql: str, *, analyze: bool = True, rois=None) -> dict:
        """``EXPLAIN [ANALYZE] <sql>`` → the (annotated) operator tree.
        Idempotent if ``sql`` already carries an EXPLAIN prefix."""
        if not sql.lstrip().upper().startswith("EXPLAIN"):
            sql = ("EXPLAIN ANALYZE " if analyze else "EXPLAIN ") + sql
        return self.query(sql, rois=rois)

    def metrics(self) -> str:
        """The Prometheus text exposition from ``GET /v1/metrics``."""
        return self._call("GET", "/v1/metrics", raw=True)

    def trace(self, query_id: str = "last", *, fmt: str = "json") -> dict:
        """A retained span tree (``fmt="chrome"`` → trace-event JSON)."""
        suffix = f"?format={fmt}" if fmt != "json" else ""
        return self._call("GET", f"/v1/trace/{query_id}{suffix}")

    def stream_query(self, sql: str, *, rois=None,
                     page_size: Optional[int] = None, k: Optional[int] = None):
        """Open a streaming session against the async tier: yields one
        cursor-paged ``/v1`` payload per chunk until the ranking is
        exhausted.  (The threaded server does not stream; use the async
        tier — :mod:`repro.service.asyncserver`.)"""
        body: dict = {"sql": sql, "session": True, "stream": True}
        if page_size is not None:
            body["page_size"] = page_size
        if k is not None:
            body["k"] = int(k)
        if rois is not None:
            body["rois"] = [[int(v) for v in row] for row in rois]
        req = _request.Request(
            self.base_url + "/v1/query", data=json.dumps(body).encode(),
            method="POST", headers={"Content-Type": "application/json"})
        try:
            with _request.urlopen(req, timeout=self.timeout) as resp:
                for line in resp:
                    line = line.strip()
                    if line:
                        yield json.loads(line)
        except HTTPError as e:
            raise self._error_from(e) from e
