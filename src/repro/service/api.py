"""MaskSearchService — the stateful layer between the SQL front-end and the
engine (the demo GUI's backend).

Responsibilities:

* **plan + cache**: parse SQL once to the logical-plan IR
  (:mod:`repro.core.plan`), canonicalize it into cache keys; answer repeated
  queries from an LRU result cache (zero mask loads) and refined queries
  (same expressions, new thresholds / rearranged predicates / larger LIMIT)
  from a per-expression CHI-bounds cache (no new bounds pass).
* **sessions**: top-k queries can open a session whose pages resume the
  verification frontier incrementally (:mod:`.session`).
* **concurrency**: batches of queries — and concurrent session pages — are
  admitted together and their verification residues are merged into fused
  ``cp_count_multi`` passes behind the store's shared-load cache
  (:mod:`.scheduler`).

All public methods are thread-safe (one lock: the store's I/O meters and
caches are shared mutable state) and return JSON-serializable dicts, so the
HTTP layer in :mod:`.server` is a thin translation.
"""

from __future__ import annotations

import contextlib
import copy
import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from .. import lockcheck
from ..core.backend import get_backend, is_packed
from ..core.engine import ExecStats
from ..core.plan import LogicalPlan, compile_plan
from ..core.queries import Query, parse
from ..core.store import MASK_META_DTYPE, StaleRunError
from ..obs import trace as trace_mod
from ..obs.explain import explain_analyze, explain_plan
from ..obs.metrics import REGISTRY as GLOBAL_REGISTRY
from ..obs.metrics import MetricsRegistry, dataclass_sampler
from .errors import NotFoundError
from .planner import Planner, roi_signature
from .scheduler import FusedScheduler
from .session import SessionManager

DEFAULT_PAGE = 25


def _stats_dict(stats: ExecStats) -> dict:
    d = dataclasses.asdict(stats)
    d["load_fraction"] = stats.load_fraction
    return {k: float(v) if isinstance(v, float) else int(v)
            for k, v in d.items()}


def _ids_list(ids) -> list:
    return [int(x) for x in np.asarray(ids).tolist()]


def _scores_list(scores) -> list:
    return [float(x) for x in np.asarray(scores, np.float64).tolist()]


class MaskSearchService:
    """One service per mask-store partition."""

    def __init__(self, store, *, provided_rois: Optional[np.ndarray] = None,
                 result_cache_size: int = 128, bounds_cache_size: int = 64,
                 verify_batch: int = 256, share_loads: bool = True,
                 max_sessions: int = 256, backend=None, trace: bool = False):
        self.store = store
        # The physical execution layer every plan compiles onto: host
        # (default), the HBM-resident device tier, or the shard_map mesh.
        self.backend = get_backend(store, backend)
        # Representation tag folded into every planner cache key: a packed
        # store must never serve (or be served) float-era entries.
        self._packed = is_packed(store)
        self.default_rois = provided_rois
        # Hash the default ROI array once — per-query hashing of a large
        # per-mask box array would serialize O(n) work behind the lock.
        self._default_roi_sig = roi_signature(provided_rois)
        self.verify_batch = verify_batch
        self.planner = Planner(result_cache_size=result_cache_size,
                               bounds_cache_size=bounds_cache_size)
        self.sessions = SessionManager(max_sessions=max_sessions)
        self.scheduler = FusedScheduler(store, backend=self.backend)
        self._lock = lockcheck.make_rlock("service")
        # guard_dict: under REPRO_LOCK_CHECK=1, mutations of the counter
        # dict assert the service lock is held (reads stay lock-free —
        # the /metrics scrape tolerates torn reads of monotonic counts).
        self._counts = lockcheck.guard_dict(
            {"total": 0, "filter": 0, "topk": 0,
             "filtered_topk": 0, "scalar_agg": 0,
             "result_cache_hits": 0}, self._lock)
        self._started_s = time.monotonic()
        # Observability: a per-service tracer (its ring buffer backs
        # ``GET /trace/<query_id>``; ``trace=True`` traces every query, and
        # EXPLAIN ANALYZE forces it on per query regardless) and a
        # per-service metrics registry (the process-global registry carries
        # kernel/jit/backend counters and is appended at scrape time).
        self.tracer = trace_mod.Tracer(enabled=trace)
        self.metrics = MetricsRegistry()
        self._phase_hist = self.metrics.histogram(
            "masksearch_query_phase_seconds",
            "Per-query phase latency: parse, plan, bounds, verify",
            ("phase",))
        self._query_seconds = self.metrics.histogram(
            "masksearch_query_seconds",
            "End-to-end service query latency by plan kind", ("kind",))
        self._register_metrics()
        # Long-lived cross-session shared-load cache: every verification load
        # any query pays for is reusable by every later query.
        self._owns_cache = store.enable_cache() if share_loads else False

    def close(self) -> None:
        with self._lock:
            if self._owns_cache:
                self.store.clear_cache()
                self._owns_cache = False

    # -- internals --------------------------------------------------------

    def _register_metrics(self) -> None:
        """Wire every live stats object into the pull-based registry — the
        collectors sample at scrape time, so the query path never pushes."""
        reg = self.metrics
        reg.register_collector(dataclass_sampler(
            "masksearch_store_io", "counter",
            "Store I/O meters (monotonic)", lambda: self.store.io))
        reg.register_collector(dataclass_sampler(
            "masksearch_shared_cache", "counter",
            "Cross-query shared-load cache", lambda: self.store.cache_stats))
        reg.register_collector(dataclass_sampler(
            "masksearch_scheduler", "counter",
            "Fused cross-query verification scheduler",
            lambda: self.scheduler.stats))
        self.planner.register_metrics(reg)

        def _query_counts() -> list:
            counts = dict(self._counts)
            return [("masksearch_queries_total", "counter",
                     "Queries served by kind",
                     [({"kind": k}, float(v)) for k, v in counts.items()])]

        def _gauges() -> list:
            n_sess = len(self.sessions)
            return [
                ("masksearch_sessions_active", "gauge",
                 "Live interactive sessions", [({}, float(n_sess))]),
                ("masksearch_sessions_created_total", "counter",
                 "Sessions ever created",
                 [({}, float(self.sessions.created))]),
                ("masksearch_sessions_evicted_total", "counter",
                 "Sessions LRU-evicted",
                 [({}, float(self.sessions.evicted))]),
                ("masksearch_store_epoch", "gauge",
                 "Mask-store epoch (mutation counter)",
                 [({}, float(self.store.epoch))]),
                ("masksearch_store_masks", "gauge",
                 "Masks resident in the store",
                 [({}, float(len(self.store)))]),
                ("masksearch_uptime_seconds", "gauge", "Service uptime",
                 [({}, time.monotonic() - self._started_s)]),
            ]

        reg.register_collector(_query_counts)
        reg.register_collector(_gauges)

    @contextlib.contextmanager
    def _traced(self, label: str, kind: str):
        """Root query span on the service tracer when tracing is on; yields
        the root span (or None) so callers can stamp ``query_id`` into
        their payloads."""
        tr = self.tracer
        if not tr.enabled:
            yield None
            return
        with tr.activate():
            with tr.query_span(label=label) as root:
                root.set(kind=kind)
                yield root

    def _observe_phases(self, parse_s: float, build_s: float, run,
                        kind: str, total_s: float) -> None:
        ph = self._phase_hist
        ph.labels(phase="parse").observe(parse_s)
        if run is None:                      # result-cache hit: no run
            ph.labels(phase="plan").observe(build_s)
        else:
            s = run.stats
            # build_s wraps compile+ensure; carve out the metered bounds
            # and verify time so "plan" is the pure lowering cost.
            ph.labels(phase="plan").observe(
                max(build_s - s.bound_time_s - s.verify_time_s, 0.0))
            ph.labels(phase="bounds").observe(s.bound_time_s)
            ph.labels(phase="verify").observe(s.verify_time_s)
        self._query_seconds.labels(kind=kind).observe(total_s)

    def _plan(self, sql) -> LogicalPlan:
        """Normalize any front-end shape (SQL text, compat Query, or a
        LogicalPlan built directly) to the IR."""
        plan, _ = self._plan_explain(sql)
        return plan

    def _plan_explain(self, sql) -> tuple:
        """→ (LogicalPlan, explain mode) — mode is "plan"/"analyze" when the
        SQL carried an EXPLAIN [ANALYZE] prefix, else None."""
        if isinstance(sql, str):
            q = parse(sql)
            return q.plan, q.explain
        if isinstance(sql, Query):
            return sql.sync_plan(), sql.explain  # honor post-parse mutations
        return sql, None

    def _explain_payload(self, plan: LogicalPlan, mode: str, rois,
                         roi_sig: str, sql) -> dict:
        """Serve EXPLAIN / EXPLAIN ANALYZE.  ANALYZE always executes —
        never the result cache (the point is the fresh per-operator
        stats) — but goes through the bounds cache like a real query, so
        the report shows genuine cache interplay.  The trace lands in the
        service tracer's ring buffer (``GET /trace/<query_id>``)."""
        self._counts["explain"] = self._counts.get("explain", 0) + 1
        if mode == "plan":
            report = explain_plan(plan)
        else:
            report = explain_analyze(
                self.store, plan, provided_rois=rois,
                backend=self.backend, verify_batch=self.verify_batch,
                bounds_hook=self.planner.bounds_hook(
                    plan, roi_sig, self.backend.name, self.store.epoch,
                    packed=self._packed),
                tracer=self.tracer,
                label=sql if isinstance(sql, str) else plan.signature())
        report["explain"] = mode
        return report

    def _rois(self, rois):
        """→ (resolved roi array, content signature)."""
        if rois is None:
            return self.default_rois, self._default_roi_sig
        rois = np.asarray(rois)
        return rois, roi_signature(rois)

    def _build_run(self, plan: LogicalPlan, rois, roi_sig: str):
        """Compile the plan to its resumable run on the service's backend,
        going through the per-expression bounds cache (a hit skips that
        CHI pass entirely).  Bounds keys carry the store epoch, so a
        mutation can never feed a dead index's bounds into a new run."""
        return compile_plan(self.store, plan, provided_rois=rois,
                            verify_batch=self.verify_batch,
                            backend=self.backend,
                            bounds_hook=self.planner.bounds_hook(
                                plan, roi_sig, self.backend.name,
                                self.store.epoch, packed=self._packed))

    def _finish_payload(self, plan: LogicalPlan, run, *,
                        cache_hit: bool = False,
                        session_id: Optional[str] = None) -> dict:
        if plan.kind in ("topk", "filtered_topk"):
            ids, scores = run.result()
            body = {"ids": _ids_list(ids), "scores": _scores_list(scores)}
        elif plan.kind == "scalar_agg":
            value = float(run.result())
            # NaN (empty candidate set) is not valid JSON — serve null.
            body = {"value": None if np.isnan(value) else value}
        else:
            body = {"ids": _ids_list(run.result())}
        payload = {"kind": plan.kind, **body,
                   "stats": _stats_dict(run.stats), "cache_hit": cache_hit}
        if session_id is not None:
            payload["session"] = session_id
        return payload

    def _cache_hit_payload(self, cached: dict) -> dict:
        """A warm hit re-serves the stored body with zeroed I/O stats — no
        mask loads, no bounds pass (the acceptance contract).  Deep copy:
        the caller must not be able to mutate the cached ids/scores."""
        payload = copy.deepcopy(cached)
        zero = ExecStats(n_candidates=cached["stats"].get("n_candidates", 0))
        payload["stats"] = _stats_dict(zero)
        payload["cache_hit"] = True
        self._counts["result_cache_hits"] += 1
        return payload

    # -- one-shot queries -------------------------------------------------

    def query(self, sql, *, rois=None, session: bool = False,
              page_size: Optional[int] = None) -> dict:
        """Execute one query.  ``session=True`` (rankings only — plain or
        predicate-filtered top-k) opens an incremental session and returns
        its first page.  SQL carrying an ``EXPLAIN [ANALYZE]`` prefix is
        routed to the annotated-operator-tree report instead."""
        t_start = time.perf_counter()
        with self._lock:
            t0 = time.perf_counter()
            plan, explain = self._plan_explain(sql)
            parse_s = time.perf_counter() - t0
            rois, roi_sig = self._rois(rois)
            if explain is not None:
                return self._explain_payload(plan, explain, rois, roi_sig,
                                             sql)
            self._counts["total"] += 1
            self._counts[plan.kind] = self._counts.get(plan.kind, 0) + 1
            label = sql if isinstance(sql, str) else plan.signature()

            if session:
                if plan.kind not in ("topk", "filtered_topk"):
                    raise ValueError("sessions require a ranking (ORDER BY … "
                                     f"LIMIT) query, got {plan.kind!r}")
                size = page_size or plan.k or DEFAULT_PAGE
                with self._traced(label, plan.kind) as root:
                    t1 = time.perf_counter()
                    run = self._build_run(plan, rois, roi_sig)
                    build_s = time.perf_counter() - t1
                    sess = self.sessions.create(
                        sql if isinstance(sql, str) else repr(plan), run,
                        size, kind=plan.kind)
                    payload = self._serve_page(sess, size)
                if root is not None:
                    payload["query_id"] = root.attrs.get("query_id")
                self._observe_phases(parse_s, build_s, run, plan.kind,
                                     time.perf_counter() - t_start)
                return payload

            cached = self.planner.cached_result(plan, roi_sig,
                                                self.backend.name,
                                                self.store.epoch,
                                                packed=self._packed)
            if cached is not None:
                payload = self._cache_hit_payload(cached)
                self._observe_phases(parse_s, 0.0, None, plan.kind,
                                     time.perf_counter() - t_start)
                return payload

            with self._traced(label, plan.kind) as root:
                t1 = time.perf_counter()
                run = self._build_run(plan, rois, roi_sig)
                run.ensure(plan.k)
                build_s = time.perf_counter() - t1
            payload = self._finish_payload(plan, run)
            if root is not None:
                payload["query_id"] = root.attrs.get("query_id")
            self.planner.store_result(plan, roi_sig, copy.deepcopy(payload),
                                      self.backend.name, self.store.epoch,
                                      packed=self._packed)
            self._observe_phases(parse_s, build_s, run, plan.kind,
                                 time.perf_counter() - t_start)
            return payload

    def submit_batch(self, sqls: Sequence, *, rois=None) -> list:
        """Admit several queries at once; their verification residues are
        merged into fused kernel passes (the online multi-query path)."""
        with self._lock:
            rois, roi_sig = self._rois(rois)
            entries = []
            jobs = []
            for sql in sqls:
                plan, explain = self._plan_explain(sql)
                if explain is not None:
                    entries.append((plan, None, self._explain_payload(
                        plan, explain, rois, roi_sig, sql)))
                    continue
                self._counts["total"] += 1
                self._counts[plan.kind] = self._counts.get(plan.kind, 0) + 1
                cached = self.planner.cached_result(plan, roi_sig,
                                                    self.backend.name,
                                                    self.store.epoch,
                                                    packed=self._packed)
                if cached is not None:
                    entries.append((plan, None, self._cache_hit_payload(cached)))
                    continue
                # every plan kind — scalar aggregations included — compiles
                # to a resumable run, so the whole batch fuses together
                run = self._build_run(plan, rois, roi_sig)
                if plan.k is not None:
                    run.target(plan.k)
                jobs.append(run)
                entries.append((plan, run, None))
            if jobs:
                with self._traced(f"batch[{len(jobs)}]", "batch"):
                    self.scheduler.drive(jobs)
            results = []
            for plan, run, payload in entries:
                if payload is None:
                    payload = self._finish_payload(plan, run)
                    self.planner.store_result(plan, roi_sig,
                                              copy.deepcopy(payload),
                                              self.backend.name,
                                              self.store.epoch,
                                              packed=self._packed)
                results.append(payload)
            return results

    def execute_many(self, items: Sequence) -> list:
        """The async tier's admitted-batch entry point: run a heterogeneous
        batch — one-shot queries, session opens, session pages — under one
        lock acquisition and **one** fused scheduler drive, with every run
        tagged by the tenant that submitted it.  Verification residues
        from different tenants merge into the same fused kernel passes
        (``SchedulerStats.cross_tenant_*``): the paper's multi-query
        optimization applied *across users*, not just within one batch.

        Each item is a dict::

            {"op": "query", "sql": ..., "rois"?, "session"?: bool,
             "page_size"?, "tenant"?}
            {"op": "page", "session_id": ..., "k"?, "tenant"?}

        Returns a list aligned with ``items`` of ``("ok", payload)`` /
        ``("error", exc)`` — a bad item never poisons its batchmates.
        """
        with self._lock:
            results: list = [None] * len(items)
            pending: list = []            # (slot, tag, *state) to finish
            runs: list = []
            tenants: list = []

            for slot, item in enumerate(items):
                try:
                    tenant = item.get("tenant", "default")
                    if item.get("op", "query") == "page":
                        sess = self.sessions.get(item["session_id"])
                        k = item.get("k")
                        if not sess.done:
                            _, hi = sess.page_bounds(k)
                            sess.run.target(hi)
                            if not sess.run.resumable():
                                raise StaleRunError(
                                    f"session pinned at epoch "
                                    f"{sess.run.epoch}; store moved to "
                                    f"epoch {self.store.epoch}")
                            runs.append(sess.run)
                            tenants.append(tenant)
                        pending.append((slot, "page", sess, k))
                        continue

                    sql = item["sql"]
                    rois, roi_sig = self._rois(item.get("rois"))
                    plan, explain = self._plan_explain(sql)
                    if explain is not None:
                        results[slot] = ("ok", self._explain_payload(
                            plan, explain, rois, roi_sig, sql))
                        continue
                    self._counts["total"] += 1
                    self._counts[plan.kind] = \
                        self._counts.get(plan.kind, 0) + 1
                    if item.get("session"):
                        if plan.kind not in ("topk", "filtered_topk"):
                            raise ValueError(
                                "sessions require a ranking (ORDER BY … "
                                f"LIMIT) query, got {plan.kind!r}")
                        size = item.get("page_size") or plan.k or DEFAULT_PAGE
                        run = self._build_run(plan, rois, roi_sig)
                        sess = self.sessions.create(
                            sql if isinstance(sql, str) else repr(plan),
                            run, size, kind=plan.kind)
                        _, hi = sess.page_bounds(size)
                        run.target(hi)
                        runs.append(run)
                        tenants.append(tenant)
                        pending.append((slot, "open", sess, size))
                        continue
                    cached = self.planner.cached_result(
                        plan, roi_sig, self.backend.name, self.store.epoch,
                        packed=self._packed)
                    if cached is not None:
                        results[slot] = ("ok",
                                         self._cache_hit_payload(cached))
                        continue
                    run = self._build_run(plan, rois, roi_sig)
                    if plan.k is not None:
                        run.target(plan.k)
                    runs.append(run)
                    tenants.append(tenant)
                    pending.append((slot, "oneshot", plan, run, roi_sig))
                except Exception as e:      # noqa: BLE001 — per-item fault
                    results[slot] = ("error", e)

            if runs:
                with self._traced(f"admit[{len(runs)}]", "admitted_batch"):
                    self.scheduler.drive(runs, tenants=tenants)

            for entry in pending:
                slot, tag = entry[0], entry[1]
                try:
                    if tag == "oneshot":
                        _, _, plan, run, roi_sig = entry
                        payload = self._finish_payload(plan, run)
                        self.planner.store_result(
                            plan, roi_sig, copy.deepcopy(payload),
                            self.backend.name, self.store.epoch,
                            packed=self._packed)
                    else:                   # "open" | "page"
                        _, _, sess, k = entry
                        payload = self._serve_page(sess, k,
                                                   scheduler_driven=True)
                    results[slot] = ("ok", payload)
                except Exception as e:      # noqa: BLE001 — per-item fault
                    results[slot] = ("error", e)
            return results

    # -- sessions ---------------------------------------------------------

    def _serve_page(self, sess, k: Optional[int], *,
                    scheduler_driven: bool = False) -> dict:
        lo, hi = sess.page_bounds(k)
        if sess.done:
            hi = lo                              # nothing left to deliver
        elif not scheduler_driven:
            sess.run.ensure(hi)
        ids, scores = sess.run.result(hi)
        page_ids, page_scores = ids[lo:hi], scores[lo:hi]
        if not sess.done and len(ids) < hi:
            # Fewer qualifying rows than the target: the run drained every
            # possibly-qualifying candidate (a filtered ranking whose
            # predicate matched < hi rows) — the result set is complete.
            sess.done = True
        sess.served = min(hi, len(ids)) if sess.done else hi
        sess.pages_served += 1
        return {"kind": sess.kind, "session": sess.id,
                "page": {"offset": lo, "ids": _ids_list(page_ids),
                         "scores": _scores_list(page_scores)},
                "served": sess.served, "total_candidates": sess.run.n,
                "exhausted": sess.exhausted,
                "stats": _stats_dict(sess.run.stats), "cache_hit": False}

    def next_page(self, session_id: str, k: Optional[int] = None) -> dict:
        """Resume a session's verification frontier for the next page."""
        t_start = time.perf_counter()
        with self._lock:
            sess = self.sessions.get(session_id)
            v0 = sess.run.stats.verify_time_s
            with self._traced(f"session:{session_id}", sess.kind) as root:
                payload = self._serve_page(sess, k)
            if root is not None:
                payload["query_id"] = root.attrs.get("query_id")
            self._phase_hist.labels(phase="verify").observe(
                sess.run.stats.verify_time_s - v0)
            self._query_seconds.labels(kind="page").observe(
                time.perf_counter() - t_start)
            return payload

    def next_pages(self, requests: dict) -> dict:
        """Advance several sessions at once: their frontiers are fused into
        shared verification passes.  ``requests`` maps session_id → k
        (None → session page size).  A session whose run can no longer be
        served consistently (the store mutated and its snapshot cannot
        finish) gets a per-session ``stale`` error entry instead of
        poisoning the whole batch."""
        with self._lock:
            sessions = []
            stale = {}
            for sid, k in requests.items():
                sess = self.sessions.get(sid)
                if not sess.done:
                    _, hi = sess.page_bounds(k)
                    sess.run.target(hi)
                sessions.append((sess, k))
            live = []
            for sess, k in sessions:
                if sess.done or sess.run.resumable():
                    live.append((sess, k))
                else:
                    stale[sess.id] = {
                        "session": sess.id, "stale": True,
                        "error": f"session pinned at epoch "
                                 f"{sess.run.epoch}; store moved to epoch "
                                 f"{self.store.epoch}"}
            with self._traced(f"pages[{len(live)}]", "page_batch"):
                self.scheduler.drive([s.run for s, _ in live])
                out = {s.id: self._serve_page(s, k, scheduler_driven=True)
                       for s, k in live}
            out.update(stale)
            return out

    def drop_session(self, session_id: str) -> bool:
        with self._lock:
            return self.sessions.drop(session_id)

    # -- mutation (the epoch-versioned write path) ------------------------

    def ingest(self, masks, *, mask_ids=None, image_ids=None, model_ids=None,
               mask_types=None, on_conflict: str = "error") -> dict:
        """Append (or, with ``on_conflict="update"``, upsert) masks.

        The model-iteration workflow: a retrained model's regenerated
        saliency maps re-ingest under their existing mask_ids (bytes +
        CHI rows replaced incrementally), new masks append as a new CHI
        chunk.  Either way the store epoch advances, every cached result
        and bounds entry from before the ingest becomes unreachable, and
        in-flight sessions keep their pinned-epoch view (or report
        staleness on their next page).

        Metadata on the update path: fields the caller supplies
        (``image_ids``/``model_ids``/``mask_types``) replace the existing
        rows' values; omitted fields keep their current values.  New rows
        default to ``image_id=mask_id``, ``model_id=0``, ``mask_type=1``.
        """
        if on_conflict not in ("error", "update"):
            raise ValueError(f"on_conflict must be 'error' or 'update', "
                             f"got {on_conflict!r}")
        with self._lock:
            masks = np.asarray(masks, np.float32)
            if masks.ndim == 2:
                masks = masks[None]
            n = len(masks)
            existing = self.store.mask_ids
            if mask_ids is None:
                base = int(existing.max()) + 1 if len(existing) else 0
                mask_ids = np.arange(base, base + n, dtype=np.int64)
            else:
                mask_ids = np.asarray(mask_ids, np.int64)
                if len(mask_ids) != n:
                    raise ValueError("mask_ids length must match masks")
            meta = np.zeros(n, MASK_META_DTYPE)
            meta["mask_id"] = mask_ids
            meta["image_id"] = (mask_ids if image_ids is None
                                else np.asarray(image_ids, np.int64))
            meta["model_id"] = (0 if model_ids is None
                                else np.asarray(model_ids, np.int32))
            meta["mask_type"] = (1 if mask_types is None
                                 else np.asarray(mask_types, np.int32))
            known = np.isin(mask_ids, existing)
            if np.any(known) and on_conflict == "error":
                raise ValueError(
                    f"{int(known.sum())} mask_ids already exist; pass "
                    f"on_conflict='update' to replace their bytes")
            n_updated = n_appended = 0
            if np.any(known):
                upd_meta = None
                if any(a is not None
                       for a in (image_ids, model_ids, mask_types)):
                    pos = self.store.positions_of(mask_ids[known])
                    upd_meta = self.store.meta[pos].copy()
                    for field, arg in (("image_id", image_ids),
                                       ("model_id", model_ids),
                                       ("mask_type", mask_types)):
                        if arg is not None:
                            upd_meta[field] = meta[field][known]
                self.store.update(mask_ids[known], masks[known],
                                  meta=upd_meta)
                n_updated = int(known.sum())
            if np.any(~known):
                self.store.append(masks[~known], meta[~known])
                n_appended = int((~known).sum())
            # The mutation retired every pre-epoch cache generation; sweep
            # it out instead of letting dead entries squat in the LRUs.
            evicted = self.planner.evict_dead_epochs(self.store.epoch)
            return {"epoch": self.store.epoch, "appended": n_appended,
                    "updated": n_updated, "n_masks": len(self.store),
                    "evicted_cache_entries": evicted,
                    "mask_ids": _ids_list(mask_ids)}

    def delete(self, mask_ids) -> dict:
        """Delete masks by id; positions renumber, epoch advances."""
        with self._lock:
            ids = np.unique(np.atleast_1d(np.asarray(mask_ids, np.int64)))
            self.store.delete(ids)
            evicted = self.planner.evict_dead_epochs(self.store.epoch)
            return {"epoch": self.store.epoch, "deleted": int(len(ids)),
                    "evicted_cache_entries": evicted,
                    "n_masks": len(self.store)}

    # -- introspection ----------------------------------------------------

    def stats(self) -> dict:
        with self._lock:
            io = self.store.io
            cache = self.store.cache_stats
            phases = {labels.get("phase", "_"): child.summary()
                      for labels, child in self._phase_hist.samples()}
            return {
                "uptime_s": time.monotonic() - self._started_s,
                "backend": self.backend.name,
                "epoch": self.store.epoch,
                "n_masks": len(self.store),
                "queries": dict(self._counts),
                **self.planner.stats(),
                "sessions": self.sessions.stats(),
                "scheduler": self.scheduler.stats.as_dict(),
                "phases": phases,
                "trace": {"enabled": self.tracer.enabled,
                          "retained": self.tracer.trace_ids()},
                # Reflected, not hand-listed: a field added to IOStats or
                # CacheStats shows up here (and in /metrics) automatically.
                "store_io": {**dataclasses.asdict(io),
                             "modeled_ebs_time_s": io.modeled_ebs_time_s},
                "shared_cache": {**dataclasses.asdict(cache),
                                 "hit_rate": cache.hit_rate},
            }

    def metrics_text(self) -> str:
        """The Prometheus text exposition ``GET /metrics`` serves: this
        service's registry (queries, phases, store I/O, caches, sessions)
        followed by the process-global registry (kernel launches, jit
        compiles, backend resolutions)."""
        return (self.metrics.prometheus_text() +
                GLOBAL_REGISTRY.prometheus_text())

    def trace(self, query_id: str = "last", *, fmt: str = "json") -> dict:
        """A retained trace by query id (``"last"`` → most recent), as
        nested JSON or, with ``fmt="chrome"``, the Chrome trace-event
        format (load in Perfetto / chrome://tracing)."""
        root = (self.tracer.last_trace() if query_id in ("", "last")
                else self.tracer.get_trace(query_id))
        if root is None:
            raise NotFoundError(f"no retained trace for {query_id!r}; "
                                f"retained: {self.tracer.trace_ids()}")
        if fmt == "chrome":
            return trace_mod.chrome_trace(root)
        return root.to_dict()
