"""Async serving tier: thousands of concurrent connections over one
:class:`MaskSearchService` (DESIGN.md §14).

The legacy :mod:`.server` front is a ``ThreadingHTTPServer`` — a thread
per request, HTTP/1.0 close-per-connection, a listen backlog of five.
Past a few hundred concurrent clients it drowns in thread churn and
refused connects while the service lock (the real bottleneck) sits
mostly idle between requests.  This tier inverts the design:

* **asyncio event loop** — one thread multiplexes every connection with
  keep-alive HTTP/1.1; accepting a client costs a coroutine, not a
  thread.  Connections beyond ``max_connections`` are shed immediately
  with 429 + ``Retry-After`` instead of queueing in the kernel backlog.
* **Admission control** (:mod:`.admission`) — per-tenant token buckets
  and bounded FIFOs drained deficit-round-robin, so overload degrades
  into fast, honest 429s and no tenant starves another.
* **Batch dispatcher** — admitted work is drained in weighted-fair
  batches into :meth:`MaskSearchService.execute_many` on a bounded
  executor pool: one service-lock acquisition and **one** fused
  scheduler drive per batch.  Queries that arrive together — from
  *different tenants* — merge their verification residues into the same
  fused kernel passes (``SchedulerStats.cross_tenant_*``), which is
  where the throughput win comes from: the paper's multi-query
  optimization applied across users.
* **Streaming sessions** — ``POST /v1/query`` with ``"stream": true``
  returns a chunked NDJSON response, one cursor-paged ``/v1`` payload
  per chunk until the ranking is exhausted; continuation pages re-enter
  the dispatcher depth-exempt (already-admitted work is never shed
  mid-stream) and still fuse with whatever else is in flight.

Both the ``/v1`` namespace and the legacy unversioned routes are served,
through the same :mod:`.routes` core as the threaded server.

Run it::

    PYTHONPATH=src python -m repro.service.asyncserver --synthetic 500 \\
        --port 8766 --tenant-rate 200 --queue-depth 128
"""

from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import threading
from concurrent.futures import ThreadPoolExecutor
from http.client import responses as _http_reasons
from typing import Optional
from urllib.parse import parse_qs, urlparse

from . import routes
from .admission import AdmissionController
from .api import MaskSearchService
from .errors import NotFoundError, OverloadedError, error_envelope
from .server import _SESSION_PAGE_RE, _SESSION_RE, _TRACE_RE

_MAX_BODY = 64 * 1024 * 1024


@dataclasses.dataclass
class TierStats:
    """Monotonic tier counters (+ one gauge), surfaced at ``/metrics`` as
    ``repro_async_tier_*``.  Torn cross-thread reads from the scraper
    are tolerated, same stance as the service's query counts."""
    connections_total: int = 0
    connections_open: int = 0            # gauge
    shed_connections: int = 0            # over max_connections
    requests_total: int = 0
    completed: int = 0
    http_errors: int = 0                 # responses with status >= 400
    batches: int = 0                     # execute_many dispatches
    batched_requests: int = 0            # pendings folded into them
    stream_pages: int = 0                # chunks pushed on NDJSON streams

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class _Pending:
    """One admitted request: its execute_many items and the future the
    connection coroutine awaits."""

    __slots__ = ("items", "future")

    def __init__(self, items: list, future: asyncio.Future):
        self.items = items
        self.future = future


class AsyncTier:
    def __init__(self, service: MaskSearchService, *,
                 host: str = "127.0.0.1", port: int = 0,
                 max_connections: int = 4096,
                 executor_workers: int = 4,
                 tenant_rate: float = 500.0, tenant_burst: float = 250.0,
                 queue_depth: int = 256,
                 tenant_weights: Optional[dict] = None,
                 batch_max: int = 32, max_inflight_batches: int = 2,
                 stream_page_limit: int = 10_000):
        self.service = service
        self.host = host
        self.port = port
        self.max_connections = max_connections
        self.batch_max = max(int(batch_max), 1)
        self.max_inflight_batches = max(int(max_inflight_batches), 1)
        self.stream_page_limit = stream_page_limit
        self.stats = TierStats()
        self.admission = AdmissionController(
            rate=tenant_rate, burst=tenant_burst, depth=queue_depth,
            weights=tenant_weights)
        # bounded pool: execute_many serializes on the service lock anyway,
        # so a couple of workers keep it saturated while one drains results
        self._pool = ThreadPoolExecutor(
            max_workers=max(int(executor_workers), 1),
            thread_name_prefix="repro-async-tier")
        self._server: Optional[asyncio.AbstractServer] = None
        self._dispatcher: Optional[asyncio.Task] = None
        self._wake: Optional[asyncio.Event] = None
        self._inflight: Optional[asyncio.Semaphore] = None
        self._closing = False
        service.metrics.register_collector(_tier_sampler(self))

    # -- lifecycle --------------------------------------------------------
    async def start(self) -> None:
        self._wake = asyncio.Event()
        self._inflight = asyncio.Semaphore(self.max_inflight_batches)
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self.port,
            backlog=min(self.max_connections, 4096))
        self.port = self._server.sockets[0].getsockname()[1]
        self._dispatcher = asyncio.ensure_future(self._dispatch_loop())

    async def close(self) -> None:
        self._closing = True
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        if self._dispatcher is not None:
            self._dispatcher.cancel()
            try:
                await self._dispatcher
            except asyncio.CancelledError:
                pass
        self._pool.shutdown(wait=False)

    @property
    def base_url(self) -> str:
        return f"http://{self.host}:{self.port}"

    # -- HTTP plumbing ----------------------------------------------------
    @staticmethod
    async def _read_request(reader: asyncio.StreamReader):
        """→ (method, target, headers, body) or None on EOF/garbage."""
        try:
            line = await reader.readline()
            if not line:
                return None
            parts = line.decode("latin-1").split()
            if len(parts) < 2:
                return None
            method, target = parts[0], parts[1]
            headers: dict = {}
            while True:
                h = await reader.readline()
                if not h:
                    return None
                if h in (b"\r\n", b"\n"):
                    break
                name, _, value = h.decode("latin-1").partition(":")
                headers[name.strip().lower()] = value.strip()
            n = int(headers.get("content-length") or 0)
            if not 0 <= n <= _MAX_BODY:
                return None
            body = await reader.readexactly(n) if n else b""
            return method, target, headers, body
        except (ConnectionError, asyncio.IncompleteReadError, ValueError,
                UnicodeDecodeError):
            return None

    @staticmethod
    def _response_bytes(code: int, body: bytes, *,
                        content_type: str = "application/json",
                        retry_after: Optional[float] = None,
                        close: bool = False) -> bytes:
        reason = _http_reasons.get(code, "Unknown")
        head = [f"HTTP/1.1 {code} {reason}",
                f"Content-Type: {content_type}",
                f"Content-Length: {len(body)}"]
        if retry_after is not None:
            head.append(f"Retry-After: {max(1, int(-(-retry_after // 1)))}")
        head.append(f"Connection: {'close' if close else 'keep-alive'}")
        return ("\r\n".join(head) + "\r\n\r\n").encode("latin-1") + body

    def _json_response(self, code: int, obj, *,
                       retry_after: Optional[float] = None,
                       close: bool = False) -> bytes:
        if code >= 400:
            self.stats.http_errors += 1
        return self._response_bytes(
            code, json.dumps(obj).encode(), retry_after=retry_after,
            close=close)

    def _error_response(self, exc: Exception, *, v1: bool) -> bytes:
        status, envelope, retry_after = error_envelope(exc)
        obj = envelope if v1 else {"error": envelope["error"]["message"]}
        return self._json_response(status, obj, retry_after=retry_after)

    # -- connection loop --------------------------------------------------
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        self.stats.connections_total += 1
        if self.stats.connections_open >= self.max_connections:
            self.stats.shed_connections += 1
            try:
                writer.write(self._error_response(
                    OverloadedError(
                        f"connection limit {self.max_connections} reached",
                        0.5),
                    v1=True))
                await writer.drain()
            except (ConnectionError, OSError):
                pass
            finally:
                writer.close()
            return
        self.stats.connections_open += 1
        try:
            while not self._closing:
                req = await self._read_request(reader)
                if req is None:
                    break
                method, target, headers, body = req
                self.stats.requests_total += 1
                keep = headers.get("connection", "").lower() != "close"
                try:
                    streamed = await self._route(method, target, headers,
                                                 body, writer, keep=keep)
                except (ConnectionError, OSError):
                    break
                self.stats.completed += 1
                if streamed or not keep:
                    break
        finally:
            self.stats.connections_open -= 1
            try:
                writer.close()
            except Exception:       # noqa: BLE001 — teardown best-effort
                pass

    # -- routing ----------------------------------------------------------
    async def _route(self, method: str, target: str, headers: dict,
                     body: bytes, writer: asyncio.StreamWriter, *,
                     keep: bool) -> bool:
        """Serve one request; → True when the response was streamed (the
        connection closes afterwards)."""
        parsed = urlparse(target)
        path = parsed.path
        v1 = path.startswith("/v1/")
        tenant = headers.get("x-tenant", "default")
        loop = asyncio.get_running_loop()

        async def send(payload: bytes) -> None:
            writer.write(payload)
            await writer.drain()

        try:
            if method == "GET":
                if path in ("/healthz", "/v1/healthz"):
                    await send(self._json_response(200, {"ok": True},
                                                   close=not keep))
                    return False
                if path in ("/stats", "/v1/stats"):
                    out = await loop.run_in_executor(self._pool,
                                                     self.service.stats)
                    await send(self._json_response(200, out, close=not keep))
                    return False
                if path in ("/metrics", "/v1/metrics"):
                    text = await loop.run_in_executor(
                        self._pool, self.service.metrics_text)
                    await send(self._response_bytes(
                        200, text.encode(),
                        content_type="text/plain; version=0.0.4; "
                                     "charset=utf-8", close=not keep))
                    return False
                m = _TRACE_RE.match(path)
                if m:
                    qid = m.group(1)
                    fmt = (parse_qs(parsed.query).get("format")
                           or ["json"])[0]
                    if fmt not in ("json", "chrome"):
                        raise ValueError(f"format must be json|chrome, "
                                         f"got {fmt!r}")
                    out = await loop.run_in_executor(
                        self._pool,
                        lambda: self.service.trace(qid, fmt=fmt))
                    await send(self._json_response(200, out, close=not keep))
                    return False
                m = _SESSION_PAGE_RE.match(path)
                if m:                       # legacy GET session page
                    sid = m.group(1)
                    qs = parse_qs(parsed.query)
                    try:
                        k = int(qs["k"][0]) if "k" in qs else None
                    except ValueError:
                        raise ValueError(f"bad page size k={qs['k'][0]!r}")
                    payload = await self._execute_one(
                        tenant, {"op": "page", "session_id": sid, "k": k})
                    await send(self._json_response(200, payload,
                                                   close=not keep))
                    return False
                raise NotFoundError(f"no route {path}")

            if method == "DELETE":
                m = _SESSION_RE.match(path)
                if m:
                    sid = m.group(1)
                    out = await loop.run_in_executor(
                        self._pool,
                        lambda: {"dropped": self.service.drop_session(sid)})
                    await send(self._json_response(200, out, close=not keep))
                    return False
                raise NotFoundError(f"no route {path}")

            if method != "POST":
                raise NotFoundError(f"no route {method} {path}")

            req_body = json.loads(body or b"{}")

            if path in ("/query", "/v1/query"):
                kw = routes.query_kwargs(req_body)
                if v1 and req_body.get("stream"):
                    await self._stream_query(tenant, req_body, writer)
                    return True
                item = {"op": "query", "sql": kw["sql"], "rois": kw["rois"],
                        "session": kw["session"],
                        "page_size": kw["page_size"]}
                payload = await self._execute_one(tenant, item)
                out = routes.shape_query(payload) if v1 else payload
                await send(self._json_response(200, out, close=not keep))
                return False

            if path in ("/workload", "/v1/workload"):
                sqls = routes.workload_sqls(req_body)
                rois = routes.parse_rois(req_body)
                items = [{"op": "query", "sql": sql, "rois": rois}
                         for sql in sqls]
                results = await self._submit(tenant, items)
                for status, value in results:
                    if status == "error":   # legacy submit_batch semantics:
                        raise value         # one bad query fails the batch
                payloads = [value for _, value in results]
                out = (routes.shape_workload(payloads) if v1 else payloads)
                await send(self._json_response(200, out, close=not keep))
                return False

            if path == "/v1/page":
                sid, k = routes.page_request(req_body)
                payload = await self._execute_one(
                    tenant, {"op": "page", "session_id": sid, "k": k})
                await send(self._json_response(200, routes.shape_page(payload),
                                               close=not keep))
                return False

            if path in ("/ingest", "/v1/ingest"):
                kw = routes.ingest_kwargs(req_body)
                self.admission.charge(tenant)
                out = await loop.run_in_executor(
                    self._pool, lambda: self.service.ingest(**kw))
                await send(self._json_response(
                    200, routes.shape_ingest(out) if v1 else out,
                    close=not keep))
                return False

            if path in ("/delete", "/v1/delete"):
                ids = routes.delete_ids(req_body)
                self.admission.charge(tenant)
                out = await loop.run_in_executor(
                    self._pool, lambda: self.service.delete(ids))
                await send(self._json_response(
                    200, routes.shape_delete(out) if v1 else out,
                    close=not keep))
                return False

            if path == "/v1/session/drop":
                if "cursor" not in req_body:
                    raise ValueError("body must contain 'cursor'")
                sid = routes.decode_cursor(req_body["cursor"])
                out = await loop.run_in_executor(
                    self._pool,
                    lambda: {"dropped": self.service.drop_session(sid)})
                await send(self._json_response(200, out, close=not keep))
                return False

            raise NotFoundError(f"no route {path}")
        except (ConnectionError, OSError):
            raise
        except Exception as e:          # noqa: BLE001 — serving loop
            await send(self._error_response(e, v1=v1))
            return False

    # -- admitted execution ----------------------------------------------
    async def _submit(self, tenant: str, items: list, *,
                      force: bool = False) -> list:
        """Admit a request's items and await the dispatcher's results
        (aligned ``("ok", payload) | ("error", exc)`` tuples)."""
        for item in items:
            item["tenant"] = tenant
        future: asyncio.Future = asyncio.get_running_loop().create_future()
        self.admission.admit(tenant, _Pending(items, future), force=force)
        self._wake.set()
        return await future

    async def _execute_one(self, tenant: str, item: dict, *,
                           force: bool = False) -> dict:
        status, value = (await self._submit(tenant, [item],
                                            force=force))[0]
        if status == "error":
            raise value
        return value

    async def _dispatch_loop(self) -> None:
        """Drain the admission queue in weighted-fair batches; each batch
        is one ``execute_many`` call — one lock acquisition, one fused
        drive — on the executor pool."""
        while True:
            await self._wake.wait()
            self._wake.clear()
            while len(self.admission.queue):
                await self._inflight.acquire()
                batch = self.admission.queue.pop_batch(self.batch_max)
                if not batch:
                    self._inflight.release()
                    break
                pendings = [p for _, p in batch]
                asyncio.ensure_future(self._run_batch(pendings))

    async def _run_batch(self, pendings: list) -> None:
        items: list = []
        for p in pendings:
            items.extend(p.items)
        try:
            results = await asyncio.get_running_loop().run_in_executor(
                self._pool, self.service.execute_many, items)
        except Exception as e:          # noqa: BLE001 — batch-level fault
            for p in pendings:
                if not p.future.done():
                    p.future.set_exception(e)
        else:
            i = 0
            for p in pendings:
                n = len(p.items)
                if not p.future.done():
                    p.future.set_result(results[i:i + n])
                i += n
            self.stats.batches += 1
            self.stats.batched_requests += len(pendings)
        finally:
            self._inflight.release()
            self._wake.set()

    # -- streaming --------------------------------------------------------
    async def _stream_query(self, tenant: str, req_body: dict,
                            writer: asyncio.StreamWriter) -> None:
        """Chunked NDJSON: the opening page, then every continuation page
        until the ranking is exhausted.  The open is admitted normally;
        continuation pages are depth-exempt (``force=True``) — the tier
        never sheds a stream it already accepted."""
        kw = routes.query_kwargs(req_body)
        item = {"op": "query", "sql": kw["sql"], "rois": kw["rois"],
                "session": True, "page_size": kw["page_size"]}
        payload = await self._execute_one(tenant, item)
        if "session" not in payload:
            raise ValueError("stream requires a ranking (ORDER BY … LIMIT) "
                             "query")
        sid = payload["session"]
        k = req_body.get("k")

        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: application/x-ndjson\r\n"
                     b"Transfer-Encoding: chunked\r\n"
                     b"Connection: close\r\n\r\n")

        async def chunk(obj) -> None:
            data = json.dumps(obj).encode() + b"\n"
            writer.write(f"{len(data):x}\r\n".encode() + data + b"\r\n")
            await writer.drain()
            self.stats.stream_pages += 1

        try:
            shaped = routes.shape_page(payload)
            await chunk(shaped)
            pages = 1
            while not shaped["exhausted"] and pages < self.stream_page_limit:
                payload = await self._execute_one(
                    tenant, {"op": "page", "session_id": sid, "k": k},
                    force=True)
                shaped = routes.shape_page(payload)
                await chunk(shaped)
                pages += 1
        finally:
            try:
                await asyncio.get_running_loop().run_in_executor(
                    self._pool, lambda: self.service.drop_session(sid))
            except Exception:       # noqa: BLE001 — teardown best-effort
                pass
        writer.write(b"0\r\n\r\n")
        await writer.drain()


def _tier_sampler(tier: AsyncTier):
    """Scrape-time collector reflecting tier + admission counters into the
    service registry (``repro_async_tier_*`` / ``repro_admission_*``)."""
    def collect() -> list:
        out = []
        for prefix, stats in (("repro_async_tier", tier.stats),
                              ("repro_admission", tier.admission.stats)):
            for f in dataclasses.fields(stats):
                v = getattr(stats, f.name)
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    continue
                out.append((f"{prefix}_{f.name}", "gauge",
                            "async tier counter", [({}, float(v))]))
        out.append(("repro_admission_queued", "gauge",
                    "work waiting in the fair queue",
                    [({}, float(len(tier.admission.queue)))]))
        return out
    return collect


# -- embedding helpers (tests / benchmarks) --------------------------------

class TierHandle:
    """A tier running on a daemon event-loop thread."""

    def __init__(self, tier: AsyncTier, loop: asyncio.AbstractEventLoop,
                 thread: threading.Thread):
        self.tier = tier
        self.loop = loop
        self.thread = thread
        self.base_url = tier.base_url

    def stop(self, timeout: float = 10.0) -> None:
        asyncio.run_coroutine_threadsafe(
            self.tier.close(), self.loop).result(timeout=timeout)
        self.loop.call_soon_threadsafe(self.loop.stop)
        self.thread.join(timeout=timeout)


def serve_in_thread(service: MaskSearchService, **tier_kwargs) -> TierHandle:
    """Start an :class:`AsyncTier` on a background event loop; → handle
    with ``base_url`` and ``stop()``."""
    loop = asyncio.new_event_loop()
    tier = AsyncTier(service, **tier_kwargs)
    started = threading.Event()
    boot_error: list = []

    def run() -> None:
        asyncio.set_event_loop(loop)
        try:
            loop.run_until_complete(tier.start())
        except Exception as e:      # noqa: BLE001 — surfaced to caller
            boot_error.append(e)
            started.set()
            return
        started.set()
        loop.run_forever()

    thread = threading.Thread(target=run, daemon=True,
                              name="repro-async-tier-loop")
    thread.start()
    started.wait()
    if boot_error:
        raise boot_error[0]
    return TierHandle(tier, loop, thread)


# -- CLI -------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser(
        description="MaskSearch async serving tier")
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--root", help="existing on-disk mask DB root")
    src.add_argument("--synthetic", type=int, metavar="N",
                     help="serve an N-mask synthetic in-memory DB")
    ap.add_argument("--size", type=int, default=128,
                    help="mask side for --synthetic")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8766)
    ap.add_argument("--verify-batch", type=int, default=256)
    ap.add_argument("--backend", default="host",
                    choices=("host", "device", "mesh"))
    ap.add_argument("--trace", action="store_true")
    ap.add_argument("--max-connections", type=int, default=4096)
    ap.add_argument("--executor-workers", type=int, default=4)
    ap.add_argument("--tenant-rate", type=float, default=500.0,
                    help="per-tenant admission rate (tokens/s)")
    ap.add_argument("--tenant-burst", type=float, default=250.0)
    ap.add_argument("--queue-depth", type=int, default=256,
                    help="per-tenant bounded queue depth")
    ap.add_argument("--batch-max", type=int, default=32,
                    help="max admitted requests per execute_many batch")
    ap.add_argument("--max-inflight-batches", type=int, default=2)
    args = ap.parse_args(argv)

    from .server import _synthetic_store
    if args.root:
        from ..core import MaskStore
        store, rois = MaskStore.open_disk(args.root), None
    else:
        store, rois = _synthetic_store(args.synthetic, args.size)
    service = MaskSearchService(store, provided_rois=rois,
                                verify_batch=args.verify_batch,
                                backend=args.backend, trace=args.trace)
    tier = AsyncTier(service, host=args.host, port=args.port,
                     max_connections=args.max_connections,
                     executor_workers=args.executor_workers,
                     tenant_rate=args.tenant_rate,
                     tenant_burst=args.tenant_burst,
                     queue_depth=args.queue_depth,
                     batch_max=args.batch_max,
                     max_inflight_batches=args.max_inflight_batches)

    async def serve() -> None:
        await tier.start()
        print(f"masksearch async tier: {len(store)} masks on "
              f"{tier.base_url}", flush=True)
        await asyncio.Event().wait()        # forever

    try:
        asyncio.run(serve())
    except KeyboardInterrupt:
        pass
    finally:
        service.close()


if __name__ == "__main__":
    main()
