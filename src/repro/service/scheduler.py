"""Cross-query verification scheduler — the multi-query optimization online.

Admitted jobs (filter or top-k runs from any number of in-flight sessions)
are driven round-robin; each round the scheduler

1. pops one ``verify_batch`` of undecided candidates from every live job,
2. loads the **union** of their mask positions once through the store's
   shared-load cache (overlapping residues pay I/O once), and
3. answers every job's CP descriptors in **one fused kernel pass** via
   ``kernels.ops.cp_count_multi`` — Q descriptors over one read of the mask
   bytes, the full paper's workload optimization applied across concurrent
   sessions instead of a pre-declared batch.

Dual-mask (pair) jobs fuse with each other the same way: the union of
their per-image (role_a, role_b) row pairs is loaded once and every
distinct (rois, ta, tb) pair descriptor is answered across all jobs in one
dual-mask kernel pass per descriptor (``_fused_pair_pass``).  Jobs whose
expressions can't be fused either way (MASK_AGG group queries) fall back
to their own verification path, still behind the shared cache, so they
share I/O even when they can't share compute.

The scheduler is operator-agnostic: any run implementing the uniform
``take_batch / cp_terms / fused_values / apply_exact / finished`` interface
(filter, top-k, filtered top-k, scalar aggregation — see DESIGN.md §6)
fuses here without the scheduler knowing which it is driving.  It is also
backend-agnostic: the fused pass runs on whichever
:class:`repro.core.backend.ExecBackend` owns the store — the host path
loads the union through the shared-load cache; the device path gathers it
from the HBM-resident tier; the mesh path runs the sharded
``cp_multi_step``.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..core.backend import F32_MAX as _F32_MAX
from ..core.backend import get_backend
from ..core.exprs import CP, MaskEvalContext, PairEvalContext, PairTerm
from ..obs import trace as _trace


@dataclasses.dataclass
class SchedulerStats:
    rounds: int = 0
    fused_passes: int = 0
    fused_descriptors: int = 0   # CP rows answered by cp_count_multi
    fused_masks: int = 0         # union masks per fused pass, summed
    fused_bytes_loaded: int = 0  # exact shared-load bytes across passes
    fused_time_s: float = 0.0
    pair_passes: int = 0         # fused dual-mask passes
    pair_descriptors: int = 0    # (rois, ta, tb) pair specs answered
    pair_pairs: int = 0          # union mask pairs per pair pass, summed
    fallback_batches: int = 0
    # Cross-tenant fusion (the async tier's multi-user batching): passes
    # whose participating jobs span more than one tenant, the jobs that
    # rode them, and the distinct-tenant width summed over every fused
    # pass (avg width = fused_tenant_width / (fused_passes + pair_passes)).
    cross_tenant_passes: int = 0
    cross_tenant_jobs: int = 0
    fused_tenant_width: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _fusable(job) -> bool:
    """A job fuses iff its verification evaluates pure per-mask CP terms
    and it is still fresh — a stale run (store mutated past its pinned
    epoch) must verify through its own epoch-pinned snapshot, not the
    store's current bytes."""
    if not isinstance(job.ctx, MaskEvalContext):
        return False
    if not job.fresh():
        return False
    terms = job.cp_terms()
    return bool(terms) and all(isinstance(t, CP) for t in terms)


def _spec_key(job, term) -> tuple:
    """Cross-job dedup key for one term's kernel descriptor: the term's
    value fields plus the identity of the ROI source when the term uses
    caller-provided boxes (those resolve against each job's own array, so
    they share a row only within one ROI source).  Single definition for
    both the CP and the pair pass — the key must never diverge between
    the build and slice loops."""
    roi_src = id(job.ctx.provided_rois) if term.roi == "provided" else None
    if isinstance(term, PairTerm):
        return (term.ta, term.tb, term.roi, roi_src)
    return (term, roi_src)


def _apportion(total: int, weights) -> list:
    """Split integer ``total`` proportionally to ``weights`` so the shares
    sum to exactly ``total`` (largest-remainder method, deterministic
    tie-break by position)."""
    total = int(total)
    wsum = sum(weights)
    if wsum <= 0 or total <= 0:
        return [0] * len(weights)
    raw = [total * w / wsum for w in weights]
    shares = [int(r) for r in raw]
    rest = total - sum(shares)
    order = sorted(range(len(weights)), key=lambda i: raw[i] - shares[i],
                   reverse=True)
    for i in order[:rest]:
        shares[i] += 1
    return shares


def _pair_fusable(job) -> bool:
    """Dual-mask jobs fuse with each other: same freshness contract, pure
    pair-term verification over a :class:`PairEvalContext`."""
    if not isinstance(job.ctx, PairEvalContext):
        return False
    if not job.fresh():
        return False
    terms = job.cp_terms()
    return bool(terms) and all(isinstance(t, PairTerm) for t in terms)


class FusedScheduler:
    """Drives a set of FilterRun/TopKRun jobs to completion concurrently.

    Round size is each run's own ``verify_batch`` — the scheduler only
    sequences and fuses the batches the runs produce."""

    def __init__(self, store, backend=None):
        self.store = store
        self.backend = get_backend(store, backend)
        self.stats = SchedulerStats()
        # id(job) -> tenant for the drive in flight (drives run under the
        # service lock, so one map at a time is safe).
        self._tenant_of: dict = {}

    def _note_tenants(self, pairs, span) -> None:
        """Account one fused pass's tenant mix: distinct-tenant width and,
        when jobs from different tenants merged into the same kernel pass
        (the async tier's cross-tenant batching), the cross-tenant
        counters.  Untagged jobs all count as one anonymous tenant."""
        tenants = {self._tenant_of.get(id(j), "") for j, _ in pairs}
        self.stats.fused_tenant_width += len(tenants)
        if len(tenants) > 1:
            self.stats.cross_tenant_passes += 1
            self.stats.cross_tenant_jobs += len(pairs)
        span.set(tenants=len(tenants))

    def drive(self, jobs, tenants=None) -> None:
        """Run every job to its finality target, fusing verification.

        ``tenants`` (optional, aligned with ``jobs``) tags each job with
        the tenant that submitted it so the stats can attribute fusion
        *across* tenants — the async tier's admission batches are the
        caller that exercises this."""
        if tenants is not None:
            self._tenant_of = {id(j): t for j, t in zip(jobs, tenants)
                               if j is not None}
        else:
            self._tenant_of = {}
        jobs = [j for j in jobs if j is not None]
        owns_cache = self.store.enable_cache()
        try:
            while True:
                takes = []
                for job in jobs:
                    if job.finished():
                        continue
                    batch = job.take_batch()
                    if len(batch):
                        takes.append((job, batch))
                if not takes:
                    break
                self.stats.rounds += 1
                fused = [(j, b) for j, b in takes if _fusable(j)]
                pair_fused = [(j, b) for j, b in takes if _pair_fusable(j)]
                direct = [(j, b) for j, b in takes
                          if not (_fusable(j) or _pair_fusable(j))]
                if fused:
                    self._fused_pass(fused)
                if pair_fused:
                    self._fused_pair_pass(pair_fused)
                for job, batch in direct:
                    self.stats.fallback_batches += 1
                    job.self_verify(batch)
        finally:
            self._tenant_of = {}
            if owns_cache:
                self.store.clear_cache()

    # -- the fused kernel pass -------------------------------------------
    def _fused_pass(self, pairs) -> None:
        store = self.store
        all_pos = np.unique(np.concatenate(
            [j.ctx.positions[b] for j, b in pairs]))
        io0 = store.io.bytes_read
        saved0 = store.cache_stats.bytes_saved
        t0 = time.perf_counter()

        with _trace.span("scheduler.fused_pass") as sp:
            # Dedupe CP descriptors across jobs.  CP nodes hash by value, so
            # two sessions ranking by the same term share one kernel row
            # (see _spec_key for the "provided"-ROI caveat).
            rows: dict = {}
            specs: list = []
            for job, _ in pairs:
                for term in set(job.cp_terms()):
                    key = _spec_key(job, term)
                    if key not in rows:
                        rois = job.ctx.resolve_rois(term.roi, all_pos)
                        rows[key] = len(specs)
                        specs.append((rois, term.lv, min(term.uv, _F32_MAX)))
            counts = self.backend.fused_counts(store, all_pos, specs)

            self.stats.fused_passes += 1
            self.stats.fused_descriptors += len(specs)
            self.stats.fused_masks += len(all_pos)
            self._note_tenants(pairs, sp)

            for job, batch in pairs:
                pos = job.ctx.positions[batch]
                sub = np.searchsorted(all_pos, pos)
                cdict = {}
                for term in set(job.cp_terms()):
                    cdict[term] = counts[rows[_spec_key(job, term)]][sub]
                job.apply_exact(batch, job.fused_values(batch, cdict))
            sp.set(jobs=len(pairs), descriptors=len(specs),
                   union_masks=len(all_pos),
                   bytes_loaded=store.io.bytes_read - io0,
                   bytes_saved=store.cache_stats.bytes_saved - saved0)

        # Per-job ExecStats get a fair share of the round's shared load and
        # wall time (proportional to batch size); the exact aggregate lives
        # in SchedulerStats.fused_bytes_loaded / fused_time_s.
        self._account(pairs, store.io.bytes_read - io0,
                      store.cache_stats.bytes_saved - saved0,
                      time.perf_counter() - t0)

    def _account(self, pairs, bytes_delta: int, saved_delta: int,
                 elapsed: float) -> None:
        """Attribute one fused round's *metered* bytes and wall time to the
        participating runs, proportional to batch size.  The byte
        apportionment is exact (largest remainder), so the sum of per-run
        ``bytes_loaded`` equals the store's metered delta — never the
        truncation drift of per-job ``int(delta * share)``.  Bytes the
        shared-load cache served count once globally (the store meters only
        misses) and are attributed per run as ``bytes_saved``."""
        self.stats.fused_bytes_loaded += bytes_delta
        self.stats.fused_time_s += elapsed
        weights = [len(b) for _, b in pairs]
        for (job, batch), share_bytes, share_saved in zip(
                pairs, _apportion(bytes_delta, weights),
                _apportion(saved_delta, weights)):
            job.stats.bytes_loaded += share_bytes
            job.stats.bytes_saved += share_saved
            job.stats.verify_time_s += \
                elapsed * len(batch) / max(sum(weights), 1)

    # -- the fused dual-mask pass ----------------------------------------
    def _fused_pair_pass(self, pairs) -> None:
        """One fused pass over the union of the jobs' pair batches: load
        the union of (pos_a, pos_b) rows once (shared-load cache), answer
        every distinct (rois, ta, tb) pair descriptor across all jobs, and
        hand each job its slice — the cross-query analogue of the single
        job's ``pair_verify_counts`` route."""
        store = self.store

        def keys_of(job, batch):
            ctx = job.ctx
            return (ctx.pos_a[batch].astype(np.int64) << 32) | \
                ctx.pos_b[batch].astype(np.int64)

        all_keys = np.unique(np.concatenate(
            [keys_of(j, b) for j, b in pairs]))
        u_pa = (all_keys >> 32).astype(np.int64)
        u_pb = (all_keys & 0xffffffff).astype(np.int64)
        io0 = store.io.bytes_read
        saved0 = store.cache_stats.bytes_saved
        t0 = time.perf_counter()

        with _trace.span("scheduler.pair_pass") as sp:
            rows: dict = {}
            specs: list = []
            for job, _ in pairs:
                for term in set(job.cp_terms()):
                    key = _spec_key(job, term)
                    if key not in rows:
                        rows[key] = len(specs)
                        specs.append(
                            (job.ctx.resolve_pair_rois(term.roi, u_pa),
                             term.ta, term.tb))
            counts = self.backend.fused_pair_counts(store, u_pa, u_pb, specs)

            self.stats.pair_passes += 1
            self.stats.pair_descriptors += len(specs)
            self.stats.pair_pairs += len(all_keys)
            self._note_tenants(pairs, sp)

            stat_row = self.backend.PAIR_STAT_ROW
            for job, batch in pairs:
                sub = np.searchsorted(all_keys, keys_of(job, batch))
                cdict = {}
                for term in set(job.cp_terms()):
                    cdict[term] = np.asarray(
                        counts[rows[_spec_key(job, term)],
                               stat_row[term.stat]], np.float64)[sub]
                job.apply_exact(batch, job.fused_values(batch, cdict))
            sp.set(jobs=len(pairs), descriptors=len(specs),
                   union_pairs=len(all_keys),
                   bytes_loaded=store.io.bytes_read - io0,
                   bytes_saved=store.cache_stats.bytes_saved - saved0)

        self._account(pairs, store.io.bytes_read - io0,
                      store.cache_stats.bytes_saved - saved0,
                      time.perf_counter() - t0)
