"""Query planning for the interactive service: canonical cache keys + LRU
result/bounds caches.

Two cache tiers, matching how a GUI session actually refines queries:

* **result cache** — keyed by the *whole* plan (expression, comparison,
  threshold, k, order, mask_types, ROI content).  A repeated query is
  answered with zero mask loads.
* **bounds cache** — keyed by everything that determines the candidate set
  and the CHI bounds pass, but *not* by threshold/op/k.  A refined query
  (same expression, new threshold or larger LIMIT) reuses the prior bounds
  pass for free and pays only for the changed verification residue.

Keys are canonical strings built from the frozen-dataclass expression reprs
(deterministic) plus a content hash of any caller-provided ROI array.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Optional

import numpy as np

from ..core.exprs import Node, is_group_expr
from ..core.queries import Query


def expr_signature(node: Optional[Node]) -> str:
    """Deterministic canonical form of an expression tree (frozen dataclass
    reprs are stable and include every field)."""
    return repr(node)


def roi_signature(rois: Optional[np.ndarray]) -> str:
    """Content hash of a provided-ROI array (the per-mask boxes a session
    queries against); two sessions sharing boxes share cache entries."""
    if rois is None:
        return "none"
    arr = np.ascontiguousarray(np.asarray(rois))
    return hashlib.sha1(arr.tobytes() + str(arr.shape).encode()).hexdigest()[:16]


def result_key(q: Query, roi_sig: str) -> str:
    return "|".join([
        q.kind, q.select, expr_signature(q.expr), str(q.op), str(q.threshold),
        str(q.k), str(q.desc), str(q.agg), str(q.mask_types),
        str(q.group_by_image), roi_sig,
    ])


def bounds_key(q: Query, roi_sig: str) -> str:
    """Everything that pins the candidate set + bounds — NOT op/threshold/k,
    so a refined query hits the same entry."""
    grouped = q.group_by_image or (q.expr is not None and is_group_expr(q.expr))
    return "|".join([
        expr_signature(q.expr), str(q.mask_types), str(grouped), roi_sig,
    ])


@dataclasses.dataclass
class CacheInfo:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    size: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class LRUCache:
    """Tiny ordered-dict LRU with hit/miss/eviction accounting."""

    def __init__(self, capacity: int):
        self.capacity = max(int(capacity), 0)
        self._data: OrderedDict = OrderedDict()
        self.info = CacheInfo()

    def get(self, key):
        if key in self._data:
            self._data.move_to_end(key)
            self.info.hits += 1
            return self._data[key]
        self.info.misses += 1
        return None

    def put(self, key, value) -> None:
        if self.capacity == 0:
            return
        if key in self._data:
            self._data.move_to_end(key)
        self._data[key] = value
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)
            self.info.evictions += 1
        self.info.size = len(self._data)

    def __len__(self) -> int:
        return len(self._data)

    def clear(self) -> None:
        self._data.clear()
        self.info.size = 0


class Planner:
    """Canonicalizes parsed plans into cache keys and owns the two caches."""

    def __init__(self, *, result_cache_size: int = 128,
                 bounds_cache_size: int = 64):
        self.result_cache = LRUCache(result_cache_size)
        self.bounds_cache = LRUCache(bounds_cache_size)

    # -- result tier ------------------------------------------------------
    def cached_result(self, q: Query, roi_sig: str):
        return self.result_cache.get(result_key(q, roi_sig))

    def store_result(self, q: Query, roi_sig: str, payload) -> None:
        self.result_cache.put(result_key(q, roi_sig), payload)

    # -- bounds tier ------------------------------------------------------
    def cached_bounds(self, q: Query, roi_sig: str):
        """(lb, ub) float64 arrays from a prior bounds pass, or None."""
        return self.bounds_cache.get(bounds_key(q, roi_sig))

    def store_bounds(self, q: Query, roi_sig: str, lb: np.ndarray,
                     ub: np.ndarray) -> None:
        self.bounds_cache.put(bounds_key(q, roi_sig), (lb, ub))

    def stats(self) -> dict:
        return {"result_cache": self.result_cache.info.as_dict(),
                "bounds_cache": self.bounds_cache.info.as_dict()}
